//! Ablations on the BPipe mechanism itself:
//!
//! * activation bound sweep — how tight can the bound go before load
//!   stalls stop hiding under compute;
//! * pipeline-depth sweep — memory imbalance (stage-0 vs stage-(p−1)
//!   stash ratio) and the BPipe bound across p;
//! * schedule comparison — GPipe vs 1F1B vs interleaved vs 1F1B+BPipe on
//!   the same workload (memory/bubble/makespan trade-off table).

use bpipe::util::bench;

use bpipe::bpipe::{apply_bpipe, pair_adjacent_layout, pairing};
use bpipe::config::paper_experiment;
use bpipe::model::memory::MemoryModel;
use bpipe::schedule::{gpipe, interleaved, one_f_one_b};
use bpipe::sim::simulate;

fn main() {
    let e = paper_experiment(8).unwrap();
    let p = e.parallel.p;
    let m = e.parallel.num_microbatches();
    let layout = pair_adjacent_layout(p, e.cluster.n_nodes);

    println!("\n=== Ablation A: BPipe bound sweep (GPT-3 96B, b=2) ===");
    println!("{:>6} {:>12} {:>12} {:>14} {:>12}", "bound", "makespan s", "stall ms", "stage0 GiB", "MFU %");
    for bound in [3u64, 4, 5, 6, 7, 8] {
        let sched = if bound >= p { one_f_one_b(p, m) } else { apply_bpipe(&one_f_one_b(p, m), Some(bound)) };
        let r = simulate(&e, &sched, &layout);
        println!(
            "{:>6} {:>12.3} {:>12.1} {:>14.1} {:>12.1}",
            bound,
            r.makespan,
            r.load_stall * 1e3,
            r.mem_high_water[0] as f64 / (1u64 << 30) as f64,
            r.mfu_pct()
        );
    }
    println!("(paper bound = ceil((p+2)/2) = {})", pairing::bound(p));

    println!("\n=== Ablation B: memory imbalance vs pipeline depth ===");
    println!("{:>4} {:>8} {:>22} {:>18}", "p", "bound", "stage0:last stash", "stage0 mem ratio");
    for pp in [4u64, 8, 16, 32] {
        let mut ep = e.clone();
        ep.parallel.p = pp;
        ep.model.l = 160; // keep layers divisible across depths
        let mm = MemoryModel::new(&ep);
        let prof = mm.profile_gib(false);
        println!(
            "{:>4} {:>8} {:>18}:{:<3} {:>17.2}x",
            pp,
            pairing::bound(pp),
            pp,
            1,
            prof[0] / prof[pp as usize - 1]
        );
    }

    println!("\n=== Ablation C: schedule comparison (GPT-3 96B, b=2, feasibility aside) ===");
    println!("{:<22} {:>12} {:>10} {:>14} {:>10}", "schedule", "makespan s", "bubble %", "stage0 GiB", "MFU %");
    let schedules: Vec<(&str, bpipe::schedule::Schedule)> = vec![
        ("GPipe", gpipe(p, m)),
        ("1F1B", one_f_one_b(p, m)),
        ("1F1B interleaved v=2", interleaved(p, m, 2)),
        ("1F1B + BPipe", apply_bpipe(&one_f_one_b(p, m), None)),
    ];
    for (name, sched) in schedules {
        let r = simulate(&e, &sched, &layout);
        println!(
            "{:<22} {:>12.3} {:>10.1} {:>14.1} {:>10.1}",
            name,
            r.makespan,
            r.bubble_fraction * 100.0,
            r.mem_high_water[0] as f64 / (1u64 << 30) as f64,
            r.mfu_pct()
        );
    }
    println!();

    let sched = apply_bpipe(&one_f_one_b(p, m), None);
    bench("ablation_bpipe/sim_full_iteration_bpipe", 20, || {
        simulate(std::hint::black_box(&e), &sched, &layout)
    });
}
