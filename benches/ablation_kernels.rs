//! Ablation: the §3.2 kernel analysis — unfused vs fused vs flash
//! attention-softmax, at GPT-3 and LLaMA shapes, and the fused-kernel
//! eligibility sweep over (b, heads/rank) that explains why only GPT-3
//! hits the slow path at b=1.

use bpipe::util::bench;

use bpipe::config::{paper_experiment, AttentionMethod};
use bpipe::sim::costmodel::fused_softmax_eligible;
use bpipe::sim::CostModel;

fn main() {
    println!("\n=== §3.2 ablation: softmax kernel cost per layer ===");
    println!("{:<12} {:>4} {:>10} {:>14} {:>14}", "model", "b", "kernel", "fwd layer (ms)", "stage MFU (%)");
    for id in [7u32, 8, 9, 1, 2] {
        let e = paper_experiment(id).unwrap();
        let cm = CostModel::new(&e);
        println!(
            "{:<12} {:>4} {:>10} {:>14.3} {:>14.1}",
            e.model.name,
            e.parallel.microbatch,
            format!("{:?}", cm.softmax_kernel()),
            cm.layer_fwd_time() * 1e3,
            cm.single_stage_mfu() * 100.0
        );
    }

    println!("\nMegatron fused-softmax eligibility (attn_batches = b·a/t, needs % 4 == 0):");
    println!("{:<12} {:>8} {:>6} {:>6} {:>6}", "model", "a/t", "b=1", "b=2", "b=4");
    for (name, a, t) in [("GPT-3 96B", 104u64, 4u64), ("LLaMA 65B", 64, 4)] {
        let marks: Vec<&str> = [1u64, 2, 4]
            .iter()
            .map(|&b| if fused_softmax_eligible(b, a, t, 2048) { "fused" } else { "UNFUSED" })
            .collect();
        println!("{:<12} {:>8} {:>6} {:>6} {:>6}", name, a / t, marks[0], marks[1], marks[2]);
    }

    // counterfactual: what exp (7) would score if the fused kernel HAD
    // been eligible at b=1 — isolates the kernel effect from BPipe
    let mut e7 = paper_experiment(7).unwrap();
    let base = CostModel::new(&e7).single_stage_mfu();
    e7.model.a = 96; // 96/4 = 24 heads/rank → b=1 eligible
    let counterfactual = CostModel::new(&e7).single_stage_mfu();
    println!("\ncounterfactual exp(7) with fused-eligible head count: {:.1}% vs {:.1}% actual", counterfactual * 100.0, base * 100.0);
    println!("(most of the Table-3 exp7→8 'BPipe' gain is this kernel switch)\n");

    let e = paper_experiment(7).unwrap();
    let cm = CostModel::new(&e);
    bench("ablation/layer_fwd_time", 100_000, || cm.layer_fwd_time());
    let _ = AttentionMethod::ALL; // keep the import honest
}
