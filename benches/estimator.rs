//! Bench + regeneration of the paper's §4 estimator results (Eqs. 2–4):
//! for every adjacent microbatch transition in Tables 3/5, compare the
//! Eq. 4 prediction (from single-stage MFUs) against the measured
//! whole-model speedup — reproducing the paper's "1.39 predicted vs 1.35
//! measured" style of validation, from BOTH the paper's numbers and our
//! simulator's numbers.

use bpipe::util::bench;

use bpipe::config::{paper_experiment, paper_table3_mfu, paper_table5_mfu};
use bpipe::estimator::{estimate, predicted_speedup, StageMeasurement};
use bpipe::sim::{simulate_experiment, CostModel};

/// The microbatch transitions the paper discusses: (from_id, to_id).
const TRANSITIONS: [(u32, u32, &str); 4] = [
    (7, 8, "GPT-3 recompute b1→b2 (the BPipe win)"),
    (9, 10, "GPT-3 flash b1→b2 (the null result)"),
    (2, 3, "LLaMA recompute b2→b4 (negative)"),
    (5, 6, "LLaMA flash b2→b4 (negative)"),
];

fn main() {
    println!("\n=== Paper §4 estimator validation (Eq. 4) ===");
    println!("{:<38} {:>10} {:>10} {:>10} {:>10}", "transition", "pred-paper", "meas-paper", "pred-sim", "meas-sim");
    for (x, y, label) in TRANSITIONS {
        let (ex, ey) = (paper_experiment(x).unwrap(), paper_experiment(y).unwrap());
        // prediction from the paper's own Table 5 stage MFUs
        let pred_paper = predicted_speedup(
            128,
            8,
            StageMeasurement { b: ex.parallel.microbatch, mfu_stage: paper_table5_mfu(x).unwrap() / 100.0 },
            StageMeasurement { b: ey.parallel.microbatch, mfu_stage: paper_table5_mfu(y).unwrap() / 100.0 },
        );
        let meas_paper = paper_table3_mfu(y).unwrap() / paper_table3_mfu(x).unwrap();
        // prediction + measurement from OUR stack
        let pred_sim = predicted_speedup(
            128,
            8,
            StageMeasurement { b: ex.parallel.microbatch, mfu_stage: CostModel::new(&ex).single_stage_mfu() },
            StageMeasurement { b: ey.parallel.microbatch, mfu_stage: CostModel::new(&ey).single_stage_mfu() },
        );
        let meas_sim = simulate_experiment(&ey).mfu / simulate_experiment(&ex).mfu;
        println!("{label:<38} {pred_paper:>9.3}x {meas_paper:>9.3}x {pred_sim:>9.3}x {meas_sim:>9.3}x");
    }
    println!("(Eq. 4 is an upper bound: pred ≥ meas, gap = BPipe overhead)\n");

    let x = StageMeasurement { b: 1, mfu_stage: 0.378 };
    let y = StageMeasurement { b: 2, mfu_stage: 0.552 };
    bench("estimator/eq4", 100_000, || estimate(std::hint::black_box(128), 8, x, y));
}
