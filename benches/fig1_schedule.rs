//! Bench + regeneration of paper Figure 1: BPipe inside a 4-way 1F1B
//! schedule — evictions after over-bound forwards, loads before the
//! matching backwards — rendered as a timed Gantt chart from the DES.

use bpipe::util::bench;

use bpipe::bpipe::{apply_bpipe, pair_adjacent_layout, pairing};
use bpipe::config::paper_experiment;
use bpipe::report::{render_timeline, timeline::render_program};
use bpipe::schedule::one_f_one_b;
use bpipe::sim::simulate;

fn main() {
    let mut e = paper_experiment(8).unwrap();
    e.parallel.p = 4;
    e.parallel.global_batch = 8 * e.parallel.microbatch;
    let m = 8;
    let layout = pair_adjacent_layout(4, 1);
    let base = one_f_one_b(4, m);
    let bp = apply_bpipe(&base, None);

    println!("\n=== Paper Figure 1 (reproduced): 4-way 1F1B, m=8 ===");
    println!("bound = ceil((p+2)/2) = {}", pairing::bound(4));
    println!("\n-- plain 1F1B --");
    print!("{}", render_timeline(&simulate(&e, &base, &layout).trace, 4, 110));
    println!("\n-- BPipe --");
    print!("{}", render_timeline(&simulate(&e, &bp, &layout).trace, 4, 110));
    println!("\n-- program order --");
    print!("{}", render_program(&bp));

    bench("fig1/schedule_gen_1f1b_p8_m64", 50_000, || one_f_one_b(8, 64));
    let base8 = one_f_one_b(8, 64);
    bench("fig1/apply_bpipe_p8_m64", 50_000, || {
        apply_bpipe(std::hint::black_box(&base8), None)
    });
}
