//! Bench + regeneration of paper Figure 2: pair-adjacent assignment for
//! 16-way pipeline parallelism on two 8-GPU nodes, and its end-to-end
//! effect — BPipe with a sequential layout pays inter-node (IB) transfer
//! latency the pair-adjacent layout hides under NVLink.

use bpipe::util::bench;

use bpipe::bpipe::{apply_bpipe, pair_adjacent_layout, sequential_layout};
use bpipe::config::paper_experiment;
use bpipe::report::render_layout;
use bpipe::schedule::one_f_one_b;
use bpipe::sim::simulate;

fn main() {
    println!("\n=== Paper Figure 2 (reproduced): 16-way PP on 2 nodes ===");
    print!("{}", render_layout(&sequential_layout(16, 2), 16));
    println!();
    print!("{}", render_layout(&pair_adjacent_layout(16, 2), 16));

    // end-to-end effect on the paper's main config (p=8, 4 nodes):
    let e = paper_experiment(8).unwrap();
    let m = e.parallel.num_microbatches();
    let bp = apply_bpipe(&one_f_one_b(8, m), None);
    let seq = simulate(&e, &bp, &sequential_layout(8, 4));
    let adj = simulate(&e, &bp, &pair_adjacent_layout(8, 4));
    println!("\nBPipe iteration, sequential layout   : {:.3} s (load stall {:.3} s)", seq.makespan, seq.load_stall);
    println!("BPipe iteration, pair-adjacent layout: {:.3} s (load stall {:.3} s)", adj.makespan, adj.load_stall);
    println!("pair-adjacent speedup: {:.3}x\n", seq.makespan / adj.makespan);

    bench("fig2/pair_adjacent_layout_p32_n4", 100_000, || pair_adjacent_layout(32, 4));
    bench("fig2/sim_bpipe_seq_layout", 20, || simulate(&e, &bp, &sequential_layout(8, 4)));
}
