//! Hot-path benches for the sweep engine: the DES inner loop is the cost
//! of every cell in `sim::sweep`'s grids, so this bench times
//!
//! * (a) single sweep cells per schedule family through a reused
//!   [`SimWorkspace`] — the zero-allocation steady state (CSR edges,
//!   dense op index, opt-in trace) that replaced the per-cell
//!   `Vec<Vec<usize>>`/`BinaryHeap`/trace allocations;
//! * (b) the same cell through the allocating `simulate` wrapper, so the
//!   workspace win stays visible as a ratio in one report;
//! * (c) the schedule generators + rebalance transform that build grid
//!   cells lazily on the worker threads;
//! * (d) the full 300-cell ranking grid and the ~3600-cell
//!   bound-sensitivity grid end to end through the parallel driver.
//!
//! `BPIPE_BENCH_SMOKE=1` caps iteration counts so CI can run this as a
//! non-blocking smoke step (hot-path regressions show up in PR logs
//! without gating merges).

use bpipe::bpipe::{
    capacity_stage_bounds, pair_adjacent_layout, rebalance, rebalance_bounded,
    RebalanceWorkspace,
};
use bpipe::config::paper_experiment;
use bpipe::schedule::{interleaved, one_f_one_b, v_shaped, zigzag};
use bpipe::sim::{bounds_grid, paper_grid, simulate, sweep, SimOptions, SimWorkspace};
use bpipe::util::bench;

fn main() {
    let smoke = std::env::var("BPIPE_BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let iters = |n: u32| if smoke { n.min(3) } else { n };

    let e = paper_experiment(8).unwrap();
    let p = e.parallel.p;
    let m = e.parallel.num_microbatches();
    let layout = pair_adjacent_layout(p, e.cluster.n_nodes);

    println!("=== DES engine inner loop (one sweep cell each, reused workspace) ===");
    let s_1f1b = one_f_one_b(p, m);
    let s_bp = rebalance(&s_1f1b, None);
    let s_il = interleaved(p, m, 2);
    let s_il_rb = rebalance(&s_il, None);
    let s_v = v_shaped(p, m);
    let mut ws = SimWorkspace::new();
    let opts = SimOptions { trace: false };
    bench("hotpath/sim_1f1b_p8_m64", iters(500), || {
        ws.run(std::hint::black_box(&e), &s_1f1b, &layout, opts)
    });
    bench("hotpath/sim_1f1b_rebalanced", iters(500), || {
        ws.run(std::hint::black_box(&e), &s_bp, &layout, opts)
    });
    bench("hotpath/sim_interleaved_v2", iters(500), || {
        ws.run(std::hint::black_box(&e), &s_il, &layout, opts)
    });
    bench("hotpath/sim_interleaved_v2_rebalanced", iters(500), || {
        ws.run(std::hint::black_box(&e), &s_il_rb, &layout, opts)
    });
    bench("hotpath/sim_v_shaped", iters(500), || {
        ws.run(std::hint::black_box(&e), &s_v, &layout, opts)
    });

    println!("\n=== allocating wrapper (fresh workspace + trace per call), for the ratio ===");
    bench("hotpath/sim_1f1b_alloc_wrapper", iters(200), || {
        simulate(std::hint::black_box(&e), &s_1f1b, &layout)
    });
    bench("hotpath/sim_interleaved_rb_alloc_wrapper", iters(200), || {
        simulate(std::hint::black_box(&e), &s_il_rb, &layout)
    });

    println!("\n=== grid construction (generators + transform, per lazy cell) ===");
    bench("hotpath/gen_interleaved_p8_m64_v2", iters(20_000), || interleaved(p, m, 2));
    bench("hotpath/gen_v_shaped_p8_m64", iters(2_000), || v_shaped(p, m));
    bench("hotpath/gen_zigzag_w_p8_m64", iters(1_000), || zigzag(p, m, 4));
    bench("hotpath/rebalance_interleaved", iters(10_000), || {
        rebalance(std::hint::black_box(&s_il), None)
    });
    let cap_bounds = capacity_stage_bounds(&e, &s_1f1b);
    bench("hotpath/rebalance_per_stage_1f1b", iters(10_000), || {
        rebalance_bounded(std::hint::black_box(&s_1f1b), &cap_bounds)
    });

    println!("\n=== bound-sweep cell setup: fresh generator+transform vs cached base + reused scratch ===");
    // what one bound-sensitivity cell used to cost: regenerate the base
    // (the zigzag W's virtual list-schedule dominates), then rebalance
    bench("hotpath/bound_cell_fresh_w_shaped", iters(500), || {
        let base = zigzag(p, m, 4);
        rebalance(&base, Some(8))
    });
    bench("hotpath/bound_cell_fresh_1f1b", iters(2_000), || {
        let base = one_f_one_b(p, m);
        rebalance(&base, Some(4))
    });
    // what it costs now: the worker's ScheduleCache keeps the base and a
    // RebalanceWorkspace, so only the transform runs per bound
    let w_base = zigzag(p, m, 4);
    let mut rb_ws = RebalanceWorkspace::new();
    bench("hotpath/bound_cell_cached_w_shaped", iters(500), || {
        rb_ws.rebalance(std::hint::black_box(&w_base), Some(8))
    });
    bench("hotpath/bound_cell_cached_1f1b", iters(2_000), || {
        rb_ws.rebalance(std::hint::black_box(&s_1f1b), Some(4))
    });

    println!("\n=== full grids through the parallel sweep driver ===");
    let ranking_cells = paper_grid(2).len();
    bench(
        &format!("hotpath/sweep_paper_grid_{ranking_cells}_cells"),
        iters(5),
        || sweep(paper_grid(2), 0),
    );
    let bounds_cells = bounds_grid(2).len();
    bench(
        &format!("hotpath/sweep_bounds_grid_{bounds_cells}_cells"),
        iters(3),
        || sweep(bounds_grid(2), 0),
    );
}
