//! Hot-path benches for BOTH execution substrates: the DES sweep engine
//! and the REAL pipeline's training step.  This bench times
//!
//! * (a) single sweep cells per schedule family through a reused
//!   [`SimWorkspace`] — the zero-allocation steady state (CSR edges,
//!   dense op index, opt-in trace) that replaced the per-cell
//!   `Vec<Vec<usize>>`/`BinaryHeap`/trace allocations;
//! * (b) the same cell through the allocating `simulate` wrapper, so the
//!   workspace win stays visible as a ratio in one report;
//! * (c) the schedule generators + rebalance transform that build grid
//!   cells lazily on the worker threads;
//! * (d) the full 300-cell ranking grid and the ~3600-cell
//!   bound-sensitivity grid end to end through the parallel driver;
//! * (e) the real `train --backend sim` step — pooled/donating
//!   (`SimBackend`) vs the owned-value baseline (`UnpooledSimBackend`):
//!   steps/sec plus **allocations per steady-state step of a stage-0
//!   worker**, counted by a thread-local `#[global_allocator]` through
//!   `train_probed`.  The group's numbers are also written to
//!   `BENCH_runtime.json` (schema below) so CI can archive the perf
//!   trajectory and diff steps/sec against the committed baseline;
//! * (f) a supervised crash-recovery cycle and a 2-replica elastic
//!   fleet serve run with one injected replica kill — fleet throughput,
//!   p50/p99 step latency and time-to-recover land in
//!   `BENCH_runtime.json` (`recovery`, `fleet`).
//!
//! `BPIPE_BENCH_SMOKE=1` caps iteration counts so CI can run this as a
//! non-blocking smoke step (hot-path regressions show up in PR logs
//! without gating merges).

use std::collections::HashMap;

use bpipe::bpipe::{
    capacity_stage_bounds, pair_adjacent_layout, rebalance, rebalance_bounded,
    RebalanceWorkspace,
};
use bpipe::config::paper_experiment;
use bpipe::coordinator::{
    supervise, train, train_probed, RebalancePlan, SuperviseConfig, TrainConfig,
};
use bpipe::fleet::{serve, FleetConfig, TrafficPattern};
use bpipe::runtime::{
    kernels, Backend, Fault, FaultPlan, FaultyBackend, Manifest, SimBackend, UnpooledSimBackend,
};
use bpipe::schedule::{interleaved, one_f_one_b, v_shaped, zigzag, Family};
use bpipe::sim::{
    bound_sensitivity_tasks, bounds_grid, paper_grid, simulate, sweep, sweep_with, SimOptions,
    SimWorkspace, SweepOptions,
};
use bpipe::util::{bench, Json};

// the thread-local counting #[global_allocator] shared with the
// zero-alloc test binary: `train_probed` runs the probed stage worker
// on THIS thread, so the counter sees exactly its hot path
#[path = "../rust/tests/support/counting_alloc.rs"]
mod counting_alloc;
use counting_alloc::allocs;

/// Mean allocations per steady-state step (warm-up step excluded) of the
/// stage-0 worker, measured on this thread via `train_probed`.
fn allocs_per_step<B: Backend>(cfg: &TrainConfig) -> f64 {
    let mut deltas: Vec<f64> = Vec::with_capacity(cfg.steps as usize);
    let mut last = 0u64;
    let mut first = true;
    train_probed::<B>(cfg, 0, &mut |_step| {
        let now = allocs();
        if !first {
            deltas.push((now - last) as f64);
        }
        first = false;
        last = now;
    })
    .expect("probed train run failed");
    if deltas.is_empty() {
        0.0
    } else {
        deltas.iter().sum::<f64>() / deltas.len() as f64
    }
}

fn main() {
    let smoke = std::env::var("BPIPE_BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let iters = |n: u32| if smoke { n.min(3) } else { n };

    let e = paper_experiment(8).unwrap();
    let p = e.parallel.p;
    let m = e.parallel.num_microbatches();
    let layout = pair_adjacent_layout(p, e.cluster.n_nodes);

    println!("=== DES engine inner loop (one sweep cell each, reused workspace) ===");
    let s_1f1b = one_f_one_b(p, m);
    let s_bp = rebalance(&s_1f1b, None);
    let s_il = interleaved(p, m, 2);
    let s_il_rb = rebalance(&s_il, None);
    let s_v = v_shaped(p, m);
    let mut ws = SimWorkspace::new();
    let opts = SimOptions { trace: false, warm: false, recompute: false };
    bench("hotpath/sim_1f1b_p8_m64", iters(500), || {
        ws.run(std::hint::black_box(&e), &s_1f1b, &layout, opts)
    });
    bench("hotpath/sim_1f1b_rebalanced", iters(500), || {
        ws.run(std::hint::black_box(&e), &s_bp, &layout, opts)
    });
    bench("hotpath/sim_interleaved_v2", iters(500), || {
        ws.run(std::hint::black_box(&e), &s_il, &layout, opts)
    });
    bench("hotpath/sim_interleaved_v2_rebalanced", iters(500), || {
        ws.run(std::hint::black_box(&e), &s_il_rb, &layout, opts)
    });
    bench("hotpath/sim_v_shaped", iters(500), || {
        ws.run(std::hint::black_box(&e), &s_v, &layout, opts)
    });

    println!("\n=== SIMD kernels: chunked 8-lane loops vs mirrored-order scalar twins ===");
    let nk = (1usize << 16) + 5; // ragged tail on purpose
    let kx: Vec<f32> = (0..nk).map(|i| kernels::unit(i as u64 * 3 + 1)).collect();
    let kdy: Vec<f32> = (0..nk).map(|i| kernels::unit(i as u64 * 7 + 2)).collect();
    assert_eq!(
        kernels::reduce_dot_bias(&kdy, &kx).0.to_bits(),
        kernels::reduce_dot_bias_scalar(&kdy, &kx).0.to_bits(),
        "chunked and scalar kernels must agree before being timed"
    );
    let k_chunked = bench("hotpath/kernel_dot_bias_chunked_64k", iters(2_000), || {
        kernels::reduce_dot_bias(std::hint::black_box(&kdy), &kx)
    });
    let k_scalar = bench("hotpath/kernel_dot_bias_scalar_64k", iters(2_000), || {
        kernels::reduce_dot_bias_scalar(std::hint::black_box(&kdy), &kx)
    });
    let mut ka = kx.clone();
    let k_affine = bench("hotpath/kernel_affine_in_place_64k", iters(2_000), || {
        kernels::affine_in_place(std::hint::black_box(&mut ka), 1.000_000_1, 1e-7)
    });
    println!(
        "hotpath/kernel_dot_bias: chunked runs {:.2}x the lane-major scalar twin",
        k_scalar.median.as_secs_f64() / k_chunked.median.as_secs_f64().max(1e-12)
    );

    println!("\n=== allocating wrapper (fresh workspace + trace per call), for the ratio ===");
    bench("hotpath/sim_1f1b_alloc_wrapper", iters(200), || {
        simulate(std::hint::black_box(&e), &s_1f1b, &layout)
    });
    bench("hotpath/sim_interleaved_rb_alloc_wrapper", iters(200), || {
        simulate(std::hint::black_box(&e), &s_il_rb, &layout)
    });

    println!("\n=== grid construction (generators + transform, per lazy cell) ===");
    bench("hotpath/gen_interleaved_p8_m64_v2", iters(20_000), || interleaved(p, m, 2));
    bench("hotpath/gen_v_shaped_p8_m64", iters(2_000), || v_shaped(p, m));
    bench("hotpath/gen_zigzag_w_p8_m64", iters(1_000), || zigzag(p, m, 4));
    bench("hotpath/rebalance_interleaved", iters(10_000), || {
        rebalance(std::hint::black_box(&s_il), None)
    });
    let cap_bounds = capacity_stage_bounds(&e, &s_1f1b);
    bench("hotpath/rebalance_per_stage_1f1b", iters(10_000), || {
        rebalance_bounded(std::hint::black_box(&s_1f1b), &cap_bounds)
    });

    println!("\n=== bound-sweep cell setup: fresh generator+transform vs cached base + reused scratch ===");
    // what one bound-sensitivity cell used to cost: regenerate the base
    // (the zigzag W's virtual list-schedule dominates), then rebalance
    bench("hotpath/bound_cell_fresh_w_shaped", iters(500), || {
        let base = zigzag(p, m, 4);
        rebalance(&base, Some(8))
    });
    bench("hotpath/bound_cell_fresh_1f1b", iters(2_000), || {
        let base = one_f_one_b(p, m);
        rebalance(&base, Some(4))
    });
    // what it costs now: the worker's ScheduleCache keeps the base and a
    // RebalanceWorkspace, so only the transform runs per bound
    let w_base = zigzag(p, m, 4);
    let mut rb_ws = RebalanceWorkspace::new();
    bench("hotpath/bound_cell_cached_w_shaped", iters(500), || {
        rb_ws.rebalance(std::hint::black_box(&w_base), Some(8))
    });
    bench("hotpath/bound_cell_cached_1f1b", iters(2_000), || {
        rb_ws.rebalance(std::hint::black_box(&s_1f1b), Some(4))
    });

    println!("\n=== full grids through the parallel sweep driver ===");
    let ranking_cells = paper_grid(2).len();
    bench(
        &format!("hotpath/sweep_paper_grid_{ranking_cells}_cells"),
        iters(5),
        || sweep(paper_grid(2), 0),
    );
    let bounds_cells = bounds_grid(2).len();
    bench(
        &format!("hotpath/sweep_bounds_grid_{bounds_cells}_cells"),
        iters(3),
        || sweep(bounds_grid(2), 0),
    );

    println!("\n=== warm-start delta-DES: bounds grid (exp 8), warm vs forced-cold ===");
    let wvc_cells = bound_sensitivity_tasks(&e, 2).len();
    let t_cold = std::time::Instant::now();
    let cold_report = sweep_with(
        bound_sensitivity_tasks(&e, 2),
        0,
        SweepOptions { force_cold: true, ..Default::default() },
    );
    let cold_s = t_cold.elapsed().as_secs_f64();
    let t_warm = std::time::Instant::now();
    let warm_report = sweep_with(bound_sensitivity_tasks(&e, 2), 0, SweepOptions::default());
    let warm_s = t_warm.elapsed().as_secs_f64();
    assert_eq!(cold_report.outcomes.len(), warm_report.outcomes.len());
    let replay_frac =
        warm_report.events_replayed as f64 / warm_report.events_total.max(1) as f64;
    println!(
        "hotpath/sweep_warm_vs_cold_{wvc_cells}_cells  cold {cold_s:.3}s  warm {warm_s:.3}s  \
         ({:.2}x, {:.1}% of events replayed)",
        cold_s / warm_s.max(1e-9),
        replay_frac * 100.0
    );

    println!("\n=== real train step on the SimBackend: pooled vs owned baseline ===");
    let train_steps: u64 = if smoke { 4 } else { 24 };
    let t_cfg = TrainConfig {
        manifest: Some(Manifest::synthetic(4, 16, 8, 2, 64, &[1, 2])),
        family: Family::OneFOneB,
        steps: train_steps,
        microbatches: 8,
        lr: 1e-3,
        seed: 0,
        rebalance: RebalancePlan::Uniform { bound: None },
        ..TrainConfig::default()
    };
    let pooled = train::<SimBackend>(&t_cfg).expect("pooled train run failed");
    let owned = train::<UnpooledSimBackend>(&t_cfg).expect("owned train run failed");
    assert_eq!(
        pooled.losses, owned.losses,
        "pooled and owned training must be bit-identical"
    );
    let (sp_pooled, sp_owned) =
        (1.0 / pooled.mean_step_time(), 1.0 / owned.mean_step_time());
    let ap_pooled = allocs_per_step::<SimBackend>(&t_cfg);
    let ap_owned = allocs_per_step::<UnpooledSimBackend>(&t_cfg);
    println!(
        "hotpath/train_step_sim_pooled   {sp_pooled:>10.1} steps/s  {ap_pooled:>8.1} allocs/step (stage-0 worker)"
    );
    println!(
        "hotpath/train_step_sim_owned    {sp_owned:>10.1} steps/s  {ap_owned:>8.1} allocs/step (stage-0 worker)"
    );
    println!(
        "hotpath/train_step delta: pooled runs {:.2}x the owned steps/s and saves {:.0} allocs/step",
        sp_pooled / sp_owned,
        ap_owned - ap_pooled
    );

    println!("\n=== supervised crash recovery (FaultyBackend<SimBackend>) ===");
    // one injected crash mid-run: measures the full detect → drain →
    // checkpoint → re-plan → resume cycle (time-to-recover), feeding the
    // recovery sample in BENCH_runtime.json
    let ck = std::env::temp_dir().join(format!("bpipe-bench-recover-{}", std::process::id()));
    let mut r_cfg = t_cfg.clone();
    r_cfg.steps = if smoke { 6 } else { 12 };
    r_cfg.checkpoint_dir = Some(ck.clone());
    r_cfg.checkpoint_every = 1;
    let scfg = SuperviseConfig {
        train: r_cfg,
        faults: Some(std::sync::Arc::new(FaultPlan::new(
            7,
            vec![Fault::Crash { stage: 1, step: 3 }],
        ))),
        max_restarts: 2,
        recover_timeout: Some(std::time::Duration::from_millis(2000)),
        backoff_base_ms: 1,
        log: false,
    };
    let recovered =
        supervise::<FaultyBackend<SimBackend>>(&scfg).expect("supervised bench run failed");
    let _ = std::fs::remove_dir_all(&ck);
    let ttr = recovered.time_to_recover_s.first().copied().unwrap_or(0.0);
    println!(
        "hotpath/recover_crash_p4        restarts={} steps_lost={} time_to_recover={:.4}s",
        recovered.restarts, recovered.steps_lost, ttr
    );

    println!("\n=== elastic fleet serve (2 replicas, one injected replica kill) ===");
    // a full fleet round trip: traffic admission, segment dispatch, one
    // replica-scoped crash, drain/redistribute, re-admission — feeding
    // the fleet sample in BENCH_runtime.json
    let f_dir = std::env::temp_dir().join(format!("bpipe-bench-fleet-{}", std::process::id()));
    let f_cfg = FleetConfig {
        replicas: 2,
        steps: if smoke { 12 } else { 24 },
        traffic: TrafficPattern::Steady,
        queue_cap: 32,
        segment_len: 2,
        seed: 11,
        manifest: Some(Manifest::synthetic(2, 16, 8, 2, 64, &[1, 2])),
        faults: Some(std::sync::Arc::new(FaultPlan::new_scoped(
            0,
            vec![(Some(1), Fault::Crash { stage: 1, step: 2 })],
        ))),
        max_restarts: 0,
        readmit_after: 1,
        sync_every: 0,
        run_dir: f_dir.clone(),
        ..FleetConfig::default()
    };
    let fleet_out = serve::<FaultyBackend<SimBackend>>(&f_cfg).expect("fleet bench run failed");
    let _ = std::fs::remove_dir_all(&f_dir);
    let fstats = &fleet_out.stats;
    let fleet_ttr = fstats.time_to_recover_s.first().copied().unwrap_or(0.0);
    println!(
        "hotpath/fleet_serve_r2          {:>10.1} steps/s  p99 {:.4}s/step  \
         time_to_recover={fleet_ttr:.4}s  shed={}",
        fstats.steps_per_s(),
        fstats.p99_latency_s(),
        fstats.shed
    );

    // machine-readable perf trajectory (CI archives this and diffs the
    // steps/s against the committed baseline, advisory-only)
    let side = |steps_per_s: f64, mean_step_s: f64, allocs_step: f64| -> Json {
        let mut o = HashMap::new();
        o.insert("steps_per_s".to_string(), Json::Num(steps_per_s));
        o.insert("mean_step_s".to_string(), Json::Num(mean_step_s));
        o.insert("allocs_per_step_stage0".to_string(), Json::Num(allocs_step));
        Json::Obj(o)
    };
    let mut root = HashMap::new();
    root.insert("schema".to_string(), Json::Num(1.0));
    root.insert(
        "bench".to_string(),
        Json::Str("train_step_sim_p4_m8_bpipe_uniform".to_string()),
    );
    root.insert("smoke".to_string(), Json::Bool(smoke));
    root.insert("steps".to_string(), Json::Num(train_steps as f64));
    root.insert("pooled".to_string(), side(sp_pooled, pooled.mean_step_time(), ap_pooled));
    root.insert("owned".to_string(), side(sp_owned, owned.mean_step_time(), ap_owned));
    root.insert(
        "speedup_pooled_vs_owned".to_string(),
        Json::Num(sp_pooled / sp_owned),
    );
    let mut rec = HashMap::new();
    rec.insert("restarts".to_string(), Json::Num(recovered.restarts as f64));
    rec.insert("steps_lost".to_string(), Json::Num(recovered.steps_lost as f64));
    rec.insert("time_to_recover_s".to_string(), Json::Num(ttr));
    root.insert("recovery".to_string(), Json::Obj(rec));
    let mut flt = HashMap::new();
    flt.insert("replicas".to_string(), Json::Num(f_cfg.replicas as f64));
    flt.insert("steps".to_string(), Json::Num(f_cfg.steps as f64));
    flt.insert("steps_per_s".to_string(), Json::Num(fstats.steps_per_s()));
    flt.insert("p50_step_latency_s".to_string(), Json::Num(fstats.p50_latency_s()));
    flt.insert("p99_step_latency_s".to_string(), Json::Num(fstats.p99_latency_s()));
    flt.insert("time_to_recover_s".to_string(), Json::Num(fleet_ttr));
    flt.insert("shed".to_string(), Json::Num(fstats.shed as f64));
    root.insert("fleet".to_string(), Json::Obj(flt));
    let mut simd = HashMap::new();
    simd.insert("elements".to_string(), Json::Num(nk as f64));
    simd.insert(
        "dot_bias_chunked_s".to_string(),
        Json::Num(k_chunked.median.as_secs_f64()),
    );
    simd.insert("dot_bias_scalar_s".to_string(), Json::Num(k_scalar.median.as_secs_f64()));
    simd.insert(
        "speedup_chunked_vs_scalar".to_string(),
        Json::Num(k_scalar.median.as_secs_f64() / k_chunked.median.as_secs_f64().max(1e-12)),
    );
    simd.insert("affine_in_place_s".to_string(), Json::Num(k_affine.median.as_secs_f64()));
    root.insert("simd".to_string(), Json::Obj(simd));
    let mut wvc = HashMap::new();
    wvc.insert("cells".to_string(), Json::Num(wvc_cells as f64));
    wvc.insert("cold_s".to_string(), Json::Num(cold_s));
    wvc.insert("warm_s".to_string(), Json::Num(warm_s));
    wvc.insert(
        "speedup_warm_vs_cold".to_string(),
        Json::Num(cold_s / warm_s.max(1e-9)),
    );
    wvc.insert("events_replayed_frac".to_string(), Json::Num(replay_frac));
    root.insert("sweep_warm_vs_cold".to_string(), Json::Obj(wvc));
    match std::fs::write("BENCH_runtime.json", format!("{}\n", Json::Obj(root))) {
        Ok(()) => println!("wrote BENCH_runtime.json"),
        Err(e) => eprintln!("could not write BENCH_runtime.json: {e}"),
    }
}
