//! Hot-path benches for the sweep engine: the DES inner loop is the cost
//! of every cell in `sim::sweep`'s experiment × schedule × layout grid,
//! so this bench times (a) single simulations per schedule family —
//! exercising the dense compute-op index that replaced the per-op
//! `HashMap` lookups — (b) the schedule generators + rebalance transform
//! that build the grid, and (c) the full paper grid end to end through
//! the parallel driver.
//!
//! (The PJRT execute-latency benches this file used to hold need the
//! `pjrt` feature + AOT artifacts; the simulator path is the default
//! build's hot path now that the sweep is the headline workload.)

use bpipe::bpipe::{pair_adjacent_layout, rebalance};
use bpipe::config::paper_experiment;
use bpipe::schedule::{interleaved, one_f_one_b, v_shaped};
use bpipe::sim::{paper_grid, simulate, sweep};
use bpipe::util::bench;

fn main() {
    let e = paper_experiment(8).unwrap();
    let p = e.parallel.p;
    let m = e.parallel.num_microbatches();
    let layout = pair_adjacent_layout(p, e.cluster.n_nodes);

    println!("=== DES engine inner loop (one sweep cell each) ===");
    let s_1f1b = one_f_one_b(p, m);
    let s_bp = rebalance(&s_1f1b, None);
    let s_il = interleaved(p, m, 2);
    let s_il_rb = rebalance(&s_il, None);
    let s_v = v_shaped(p, m);
    bench("hotpath/sim_1f1b_p8_m64", 200, || {
        simulate(std::hint::black_box(&e), &s_1f1b, &layout)
    });
    bench("hotpath/sim_1f1b_rebalanced", 200, || {
        simulate(std::hint::black_box(&e), &s_bp, &layout)
    });
    bench("hotpath/sim_interleaved_v2", 200, || {
        simulate(std::hint::black_box(&e), &s_il, &layout)
    });
    bench("hotpath/sim_interleaved_v2_rebalanced", 200, || {
        simulate(std::hint::black_box(&e), &s_il_rb, &layout)
    });
    bench("hotpath/sim_v_shaped", 200, || {
        simulate(std::hint::black_box(&e), &s_v, &layout)
    });

    println!("\n=== grid construction (generators + transform) ===");
    bench("hotpath/gen_interleaved_p8_m64_v2", 20_000, || interleaved(p, m, 2));
    bench("hotpath/gen_v_shaped_p8_m64", 2_000, || v_shaped(p, m));
    bench("hotpath/rebalance_interleaved", 10_000, || {
        rebalance(std::hint::black_box(&s_il), None)
    });

    println!("\n=== full paper grid through the parallel sweep driver ===");
    bench("hotpath/sweep_paper_grid_140_cells", 5, || sweep(paper_grid(2), 0));
}
