//! L3 hot-path benches over the REAL runtime: PJRT execute latency per
//! stage op, coordinator overhead (channel + literal plumbing) vs pure
//! execute time, and end-to-end step latency ±BPipe at tiny scale.
//!
//! Requires `make artifacts` (skips gracefully if absent, so `cargo
//! bench` works in a fresh checkout).

use bpipe::util::bench;
use std::path::Path;

use bpipe::coordinator::{self, TrainConfig};
use bpipe::runtime::{literal_f32, Manifest, Runtime};

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("runtime_hotpath: artifacts/ missing — run `make artifacts`; skipping");
        return;
    }
    let manifest = Manifest::load(dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let spec = &manifest.spec;
    let n = manifest.param_count("mid").unwrap() as usize;
    let fwd = rt.load(&manifest.path_of("mid_fwd").unwrap()).unwrap();
    let bwd = rt.load(&manifest.path_of("mid_bwd").unwrap()).unwrap();
    let params = xla::Literal::vec1(&vec![0.01f32; n]);
    let act_len = (spec.b * spec.s * spec.h) as usize;
    let shape = [spec.b as i64, spec.s as i64, spec.h as i64];
    let x = literal_f32(&vec![0.1f32; act_len], &shape).unwrap();
    let dy = literal_f32(&vec![0.05f32; act_len], &shape).unwrap();

    bench("runtime/mid_fwd_execute", 30, || fwd.run1(&[&params, &x]).unwrap());
    bench("runtime/mid_bwd_execute", 30, || bwd.run(&[&params, &x, &dy]).unwrap());
    let host = vec![0.1f32; act_len];
    bench("runtime/literal_upload_act", 1000, || {
        literal_f32(std::hint::black_box(&host), &shape).unwrap()
    });

    // end-to-end short training run ±BPipe: BPipe overhead at tiny scale
    println!("\n=== e2e step latency ±BPipe (tiny model, 2 steps × 8 microbatches) ===");
    for bpipe in [false, true] {
        let cfg = TrainConfig {
            artifacts_dir: dir.to_path_buf(),
            steps: 2,
            microbatches: 8,
            bpipe,
            ..Default::default()
        };
        let r = coordinator::train(&cfg).unwrap();
        let stalls: f64 = r.stage_stats.iter().map(|s| s.load_wait_s).sum();
        println!(
            "bpipe={bpipe:<5} mean step {:.2}s, stage0 stash hw {}, total load-wait {:.3}s, final loss {:.4}",
            r.mean_step_time(),
            r.stage_stats[0].stash_high_water,
            stalls,
            r.final_loss()
        );
    }
}
