//! Bench + regeneration of paper Table 3: whole-model MFU for the ten
//! experiments, paper-reported vs simulated, plus timing of the DES
//! engine itself (a full 1F1B iteration of GPT-3 96B, 64–128
//! microbatches, is the hot simulation workload).

use bpipe::util::bench;

use bpipe::config::{paper_experiment, paper_table3_mfu};
use bpipe::report::render_table3;
use bpipe::sim::simulate_experiment;

fn main() {
    // print the reproduced table once, before timing
    println!("\n=== Paper Table 3 (reproduced) ===");
    print!("{}", render_table3());

    // the headline comparisons the paper's abstract makes:
    let mfu = |id: u32| simulate_experiment(&paper_experiment(id).unwrap()).mfu_pct();
    let speedup_gpt_recompute = mfu(8) / mfu(7);
    let speedup_gpt_flash = mfu(10) / mfu(9);
    let speedup_llama_flash = mfu(6) / mfu(5);
    println!("BPipe speedup, GPT-3 + recompute : {speedup_gpt_recompute:.3}x (paper: {:.3}x)", 45.8 / 34.0);
    println!("BPipe speedup, GPT-3 + flash     : {speedup_gpt_flash:.3}x (paper: {:.3}x)", 51.7 / 52.0);
    println!("BPipe speedup, LLaMA + flash     : {speedup_llama_flash:.3}x (paper: {:.3}x)", 44.0 / 49.2);
    let mean_abs_err: f64 = (1..=10)
        .map(|id| (mfu(id) - paper_table3_mfu(id).unwrap()).abs())
        .sum::<f64>()
        / 10.0;
    println!("mean |MFU error| vs paper: {mean_abs_err:.2} points\n");

    for id in [7u32, 8] {
        let e = paper_experiment(id).unwrap();
        bench(&format!("table3/simulate_exp{id}"), 20, || {
            simulate_experiment(std::hint::black_box(&e))
        });
    }
}
