//! Bench + regeneration of paper Table 5: single-stage MFU for the ten
//! experiment configurations (the cost-model calibration target).

use bpipe::util::bench;

use bpipe::config::{paper_experiment, paper_table5_mfu};
use bpipe::report::render_table5;
use bpipe::sim::CostModel;

fn main() {
    println!("\n=== Paper Table 5 (reproduced) ===");
    print!("{}", render_table5());

    // the single-stage ratios that §4 plugs into Eq. 4:
    let mfu = |id: u32| CostModel::new(&paper_experiment(id).unwrap()).single_stage_mfu();
    println!(
        "stage MFU ratio b1→b2, GPT recompute: {:.3} (paper {:.3})",
        mfu(8) / mfu(7),
        55.2 / 37.8
    );
    println!(
        "stage MFU ratio b2→b4, LLaMA flash  : {:.3} (paper {:.3})\n",
        mfu(6) / mfu(5),
        61.9 / 58.6
    );
    let max_err = (1..=10u32)
        .map(|id| (mfu(id) * 100.0 - paper_table5_mfu(id).unwrap()).abs())
        .fold(0.0f64, f64::max);
    println!("max |stage MFU error| vs paper: {max_err:.2} points\n");

    let e = paper_experiment(7).unwrap();
    bench("table5/cost_model_stage_mfu", 10_000, || {
        CostModel::new(std::hint::black_box(&e)).single_stage_mfu()
    });
}
