//! The paper's §4/§5 recipe, end to end on the execution backend:
//!
//! "Prior to applying the BPipe technique, we can evaluate a small part
//!  of the model with fewer resources to estimate the entire model's
//!  performance following an increase in the micro batch size."
//!
//! 1. Time ONE mid pipeline stage at every b in the manifest's sweep.
//! 2. Convert to single-stage MFU ratios (peak cancels in Eq. 4).
//! 3. Predict the whole-pipeline speedup of raising b with Eq. 4.
//! 4. Verify: run the REAL pipeline at each effective batch and compare
//!    measured step-time ratios against the work-bound prediction.
//!
//! Runs on the in-tree [`SimBackend`] by default (synthetic manifest, no
//! artifacts needed): `cargo run --release --example estimate_bpipe`.
//! Point `BPIPE_ARTIFACTS` at a lowered artifact directory to measure
//! those shapes instead.

use bpipe::coordinator::{measure_stage, train, TrainConfig};
use bpipe::estimator::{estimate, StageMeasurement};
use bpipe::runtime::{Manifest, SimBackend};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let manifest = match std::env::var("BPIPE_ARTIFACTS") {
        Ok(dir) => Manifest::load(&PathBuf::from(dir))?,
        Err(_) => Manifest::synthetic(4, 16, 8, 2, 64, &[1, 2, 4]),
    };
    let sweep = manifest.bs_sweep.clone();
    anyhow::ensure!(sweep.len() >= 2, "need ≥2 microbatch sizes in the artifact sweep");
    let p = manifest.spec.stages;

    // --- 1+2: single-stage measurements --------------------------------
    println!("=== single-stage timings (mid stage, sim backend) ===");
    let mut timings = Vec::new();
    for &b in &sweep {
        let t = measure_stage::<SimBackend>(&manifest, b, 5)?;
        println!(
            "  b={b}: {:>9.3} ms/microbatch  {:>12.0} tokens/s  {:.3e} model FLOP/s",
            t.t_b * 1e3,
            t.tokens_per_s,
            t.flops_per_s
        );
        timings.push(t);
    }
    let peak = timings.iter().map(|t| t.flops_per_s).fold(0.0, f64::max) * 1.25;
    let meas: Vec<StageMeasurement> = timings
        .iter()
        .map(|t| StageMeasurement { b: t.b, mfu_stage: t.flops_per_s / peak })
        .collect();

    // --- 3: Eq. 4 predictions for every adjacent transition -------------
    // The prediction is for a fixed global batch: B = max_b in the sweep
    // times the microbatch count we will actually run below.
    let m_at_max = 4u64; // microbatches when running the largest b
    let global_tokens_b = sweep.iter().max().unwrap() * m_at_max;
    println!("\n=== Eq. 4 predictions (B = {global_tokens_b} sequences, p = {p}) ===");
    for w in meas.windows(2) {
        let est = estimate(global_tokens_b, p, w[0], w[1]);
        println!(
            "  b {}→{}: stage factor {:.3} × bubble factor {:.3} = predicted {:.3}x",
            w[0].b, w[1].b, est.stage_factor, est.bubble_factor, est.speedup_bound
        );
    }

    // --- 4: verify against the real pipeline ----------------------------
    // Same number of TOKENS per step in each run: b doubles → m halves.
    // CAVEAT for this testbed: Eq. 2's bubble term (m + p − 1)·T assumes
    // p stages computing in PARALLEL; with every stage worker sharing
    // one host, wall-clock is work-bound (∝ m·T), so we verify the
    // work-bound prediction here and leave the bubble factor to the DES
    // simulator (which models the parallel cluster the paper ran on).
    // The synthetic manifest fixes b per run, so "raising b" is emulated
    // by shrinking m at constant tokens/step.
    println!("\n=== verification: real {p}-stage pipeline, same tokens/step ===");
    println!("(single host → wall time is work-bound: step ∝ m; the bubble");
    println!(" factor of Eq. 2 is validated against the cluster simulator)");
    let max_b = *sweep.iter().max().unwrap();
    let mut measured = Vec::new();
    for &b in &sweep {
        let m = m_at_max * max_b / b; // fixed global tokens
        let cfg = TrainConfig {
            manifest: Some(manifest.clone()),
            steps: 3,
            microbatches: m,
            lr: 1e-3,
            seed: 0,
            ..TrainConfig::default()
        };
        let r = train::<SimBackend>(&cfg)?;
        let st0 = &r.stage_stats[0];
        println!(
            "  m={m:>3}: mean step {:.5}s  (stage-0 pool: {} hits / {} misses)",
            r.mean_step_time(),
            st0.pool_hits,
            st0.pool_misses
        );
        measured.push((b, m, r.mean_step_time()));
    }
    println!("\nwork-bound check (one host: step time ∝ m · T_artifact):");
    for w in measured.windows(2) {
        let (b0, m0, t0) = w[0];
        let (b1, m1, t1) = w[1];
        let pred = m1 as f64 / m0 as f64;
        let got = t1 / t0;
        println!(
            "  b {b0}→{b1}: predicted step-time ratio {pred:.3}, measured {got:.3} (err {:+.1}%)",
            (got / pred - 1.0) * 100.0
        );
    }
    println!("\nverdict per the paper's §5: measure one stage first; only if the");
    println!("Eq. 4 bound exceeds ~1.05x is implementing BPipe worth the effort.");
    Ok(())
}
