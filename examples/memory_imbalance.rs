//! The memory-imbalance story across models, microbatches and depths —
//! the paper's §2.2 motivation, quantified.
//!
//! Prints, for LLaMA 65B and GPT-3 96B at every attention method and
//! microbatch size: which configurations fit in 80 GiB under plain 1F1B,
//! which need BPipe, and which don't fit at all — the feasibility
//! boundary that dictates the ten runnable rows of Table 3.
//!
//! Run with: `cargo run --release --example memory_imbalance`

use bpipe::config::{
    gpt3_96b, llama_65b, paper_cluster, paper_parallel, AttentionMethod, ExperimentConfig,
};
use bpipe::model::memory::{bpipe_bound, MemoryModel};

fn main() {
    let gib = (1u64 << 30) as f64;
    for model in [llama_65b(), gpt3_96b()] {
        println!("=== {} (t=4, p=8, B=128, 80 GiB A100) ===", model.name);
        println!(
            "{:<12} {:>3} {:>14} {:>14} {:>18}",
            "attention", "b", "1F1B peak GiB", "BPipe peak GiB", "verdict"
        );
        for att in AttentionMethod::ALL {
            for b in [1u64, 2, 4, 8] {
                let e = ExperimentConfig {
                    id: None,
                    model: model.clone(),
                    parallel: paper_parallel(b),
                    cluster: paper_cluster(),
                    bpipe: false,
                    attention: att,
                };
                let mm = MemoryModel::new(&e);
                let plain = mm.max_peak_bytes(false) as f64 / gib;
                let bal = mm.max_peak_bytes(true) as f64 / gib;
                let verdict = match (mm.fits(false), mm.fits(true)) {
                    (true, _) => "fits plain",
                    (false, true) => "NEEDS BPIPE",
                    (false, false) => "OOM even w/ BPipe",
                };
                println!(
                    "{:<12} {:>3} {:>14.1} {:>14.1} {:>18}",
                    att.label(),
                    b,
                    plain,
                    bal,
                    verdict
                );
            }
        }
        println!();
    }

    println!("=== per-stage profile, GPT-3 96B b=2 recompute (the exp-8 case) ===");
    let e = bpipe::config::paper_experiment(8).unwrap();
    let mm = MemoryModel::new(&e);
    let cap = e.cluster.hbm_bytes as f64 / gib;
    println!("{:>6} {:>12} {:>12}   (HBM = {cap:.0} GiB)", "stage", "1F1B GiB", "BPipe GiB");
    for (s, (a, b)) in mm.profile_gib(false).iter().zip(mm.profile_gib(true).iter()).enumerate() {
        let bar = |v: f64| "#".repeat((v / cap * 40.0) as usize);
        println!("{s:>6} {a:>12.1} {b:>12.1}   |{:<40}|", bar(*b));
    }
    println!(
        "\nBPipe bound for p=8: ⌈(8+2)/2⌉ = {} stashes per device (stage 0 had 8)",
        bpipe_bound(8)
    );
}
