//! Quickstart: the whole BPipe story in ~60 lines of library calls.
//!
//! 1. Build the paper's GPT-3 96B experiment (t=4, p=8, B=128).
//! 2. Show the 1F1B memory imbalance and why b=2 OOMs without BPipe.
//! 3. Apply BPipe, simulate both, compare MFU.
//! 4. Run the paper's Eq. 4 estimator to see the same answer analytically.
//!
//! Run with: `cargo run --release --example quickstart`

use bpipe::bpipe::{apply_bpipe, pair_adjacent_layout, pairing};
use bpipe::config::paper_experiment;
use bpipe::estimator::{estimate, StageMeasurement};
use bpipe::model::memory::MemoryModel;
use bpipe::schedule::one_f_one_b;
use bpipe::sim::{simulate, CostModel};

fn main() {
    // --- 1. the paper's headline experiment: GPT-3 96B, b=2, recompute ---
    let e = paper_experiment(8).expect("paper experiment");
    println!("experiment: {}\n", e.summary());

    // --- 2. memory imbalance under plain 1F1B ---------------------------
    let mm = MemoryModel::new(&e);
    println!("per-stage peak memory (GiB), HBM = 80:");
    println!("  stage:  {}", (0..8).map(|s| format!("{s:>6}")).collect::<String>());
    let plain = mm.profile_gib(false);
    let bal = mm.profile_gib(true);
    println!("  1F1B :  {}", plain.iter().map(|g| format!("{g:>6.1}")).collect::<String>());
    println!("  BPipe:  {}", bal.iter().map(|g| format!("{g:>6.1}")).collect::<String>());
    println!(
        "  → stage 0 holds p={} stashes under 1F1B; BPipe bounds every stage to ⌈(p+2)/2⌉ = {}\n",
        e.parallel.p,
        pairing::bound(e.parallel.p)
    );
    assert!(!mm.fits(false), "b=2 must OOM without BPipe (that's the point)");
    assert!(mm.fits(true));

    // --- 3. simulate b=1 plain vs b=2 BPipe ------------------------------
    let layout = pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
    let e1 = paper_experiment(7).unwrap(); // b=1, no BPipe
    let m1 = e1.parallel.num_microbatches();
    let r1 = simulate(&e1, &one_f_one_b(e1.parallel.p, m1), &layout);
    let m2 = e.parallel.num_microbatches();
    let r2 = simulate(&e, &apply_bpipe(&one_f_one_b(e.parallel.p, m2), None), &layout);
    println!("simulated: b=1 plain  → MFU {:.1}% ({:.1}s/iter)", r1.mfu_pct(), r1.makespan);
    println!("simulated: b=2 BPipe  → MFU {:.1}% ({:.1}s/iter)", r2.mfu_pct(), r2.makespan);
    println!("speedup: {:.2}x (paper: 45.8/34.0 = 1.35x)\n", r2.mfu / r1.mfu);

    // --- 4. the §4 estimator reaches the same verdict cheaply ------------
    let sx = StageMeasurement { b: 1, mfu_stage: CostModel::new(&e1).single_stage_mfu() };
    let sy = StageMeasurement { b: 2, mfu_stage: CostModel::new(&e).single_stage_mfu() };
    let est = estimate(e.parallel.global_batch, e.parallel.p, sx, sy);
    println!(
        "Eq. 4 estimate from single-stage MFUs ({:.1}% → {:.1}%): {:.2}x upper bound",
        sx.mfu_stage * 100.0,
        sy.mfu_stage * 100.0,
        est.speedup_bound
    );
    println!("verdict: {}", if est.speedup_bound > 1.05 { "worth implementing BPipe here" } else { "not worth it" });
}
