//! END-TO-END DRIVER: real pipeline-parallel training of a transformer
//! through the full three-layer stack.
//!
//! * L1 — the attention inside every stage artifact is the Pallas kernel
//!   (flash attention by default; set at `make artifacts` time);
//! * L2 — the JAX stage graphs AOT-lowered to HLO text;
//! * L3 — this binary: 4 stage workers, 1F1B schedule, Adam, synthetic
//!   corpus, and (second phase) BPipe activation balancing on real
//!   buffers.
//!
//! The run proves all layers compose: the loss curve drops from ~ln(v)
//! toward the corpus's structural entropy, and the BPipe phase computes
//! **bit-identical** losses while stage 0 holds fewer stashes.
//!
//! Usage: cargo run --release --example train_tiny -- [steps] [microbatches]
//! (artifacts must exist: `make artifacts`)

use bpipe::coordinator::{train, TrainConfig};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(60);
    let microbatches: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);
    let artifacts = PathBuf::from(
        std::env::var("BPIPE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );

    println!("=== phase 1: plain 1F1B, {steps} steps × {microbatches} microbatches ===");
    let cfg = TrainConfig {
        artifacts_dir: artifacts.clone(),
        steps,
        microbatches,
        lr: 3e-3,
        bpipe: false,
        bound: None,
        seed: 0,
        log_every: 5,
        checkpoint_dir: None,
        checkpoint_every: 0,
        resume: false,
    };
    let plain = train(&cfg)?;
    println!("\nloss curve (every 5th step):");
    for (i, loss) in plain.losses.iter().enumerate().step_by(5) {
        let bar = "*".repeat((loss * 6.0) as usize);
        println!("  step {i:>4}  {loss:>7.4}  |{bar}");
    }
    println!(
        "first {:.4} → final {:.4} (corpus rule floor ≈ entropy of 25% noise)",
        plain.losses[0],
        plain.final_loss()
    );

    println!("\n=== phase 2: same run under BPipe (memory-balanced) ===");
    let steps_b = steps.min(8); // enough to verify numerics + stash balance
    let cfg_b = TrainConfig { bpipe: true, steps: steps_b, ..cfg.clone() };
    let bpipe_run = train(&cfg_b)?;

    // BPipe must be a pure memory optimization: bit-identical losses
    for (i, (a, b)) in plain.losses.iter().zip(bpipe_run.losses.iter()).enumerate() {
        assert_eq!(a, b, "step {i}: BPipe changed the numerics!");
    }
    println!("numerics: first {steps_b} losses bit-identical to plain 1F1B ✓");
    println!("\nstash high-water per stage (the balancing effect):");
    println!("  stage |  1F1B | BPipe | evictions | load-wait");
    for (a, b) in plain.stage_stats.iter().zip(bpipe_run.stage_stats.iter()) {
        println!(
            "  {:>5} | {:>5} | {:>5} | {:>9} | {:>8.3}s",
            a.stage, a.stash_high_water, b.stash_high_water, b.evictions, b.load_wait_s
        );
    }
    println!(
        "\nstep time: plain {:.2}s vs bpipe {:.2}s ({:+.1}% overhead)",
        plain.mean_step_time(),
        bpipe_run.mean_step_time(),
        (bpipe_run.mean_step_time() / plain.mean_step_time() - 1.0) * 100.0
    );
    println!("tokens trained: {}", plain.tokens + bpipe_run.tokens);
    Ok(())
}
