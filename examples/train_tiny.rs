//! END-TO-END DRIVER: real pipeline-parallel training through the full
//! coordinator stack — leader, 4 stage-worker threads, 1F1B schedule,
//! Adam, synthetic corpus, and (second phase) BPipe activation balancing
//! on real buffers.
//!
//! Runs on the in-tree deterministic [`SimBackend`] with an in-memory
//! synthetic manifest, so it works in a fresh checkout with zero
//! dependencies: `cargo run --release --example train_tiny -- [steps]
//! [microbatches]`.  Point `BPIPE_ARTIFACTS` at a lowered artifact
//! directory to train that manifest's shapes instead (the PJRT backend
//! itself needs the `pjrt` build feature: `bpipe train --backend pjrt`).
//!
//! The run proves the layers compose: the pipeline streams microbatches
//! through the stage workers, and the BPipe phase computes
//! **bit-identical** losses while the front stage holds fewer stashes.

use bpipe::coordinator::{train, RebalancePlan, TrainConfig};
use bpipe::runtime::{Manifest, SimBackend};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(20);
    let microbatches: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);
    let manifest = match std::env::var("BPIPE_ARTIFACTS") {
        Ok(dir) => Manifest::load(&PathBuf::from(dir))?,
        Err(_) => Manifest::synthetic(4, 16, 8, 2, 64, &[1, 2]),
    };

    println!("=== phase 1: plain 1F1B, {steps} steps × {microbatches} microbatches ===");
    let cfg = TrainConfig {
        manifest: Some(manifest),
        steps,
        microbatches,
        lr: 2e-2,
        seed: 0,
        log_every: 5,
        ..TrainConfig::default()
    };
    let plain = train::<SimBackend>(&cfg)?;
    println!("\nloss curve (every 5th step):");
    for (i, loss) in plain.losses.iter().enumerate().step_by(5) {
        let bar = "*".repeat((loss * 200.0) as usize);
        println!("  step {i:>4}  {loss:>8.5}  |{bar}");
    }
    println!("first {:.5} → final {:.5}", plain.losses[0], plain.final_loss());

    println!("\n=== phase 2: same run under BPipe (memory-balanced) ===");
    let steps_b = steps.min(8); // enough to verify numerics + stash balance
    let cfg_b = TrainConfig {
        rebalance: RebalancePlan::Uniform { bound: None },
        steps: steps_b,
        ..cfg.clone()
    };
    let bpipe_run = train::<SimBackend>(&cfg_b)?;

    // BPipe must be a pure memory optimization: bit-identical losses
    for (i, (a, b)) in plain.losses.iter().zip(bpipe_run.losses.iter()).enumerate() {
        assert_eq!(a, b, "step {i}: BPipe changed the numerics!");
    }
    println!("numerics: first {steps_b} losses bit-identical to plain 1F1B ✓");
    println!("\nstash high-water per stage (the balancing effect), plus the");
    println!("buffer-pool hit rate (steady-state steps allocate nothing):");
    println!("  stage |  1F1B | BPipe | evictions | load-wait | pool hit-rate");
    for (a, b) in plain.stage_stats.iter().zip(bpipe_run.stage_stats.iter()) {
        let total = b.pool_hits + b.pool_misses;
        println!(
            "  {:>5} | {:>5} | {:>5} | {:>9} | {:>8.3}s | {:>6.1}% ({} misses)",
            a.stage,
            a.stash_high_water,
            b.stash_high_water,
            b.evictions,
            b.load_wait_s,
            if total > 0 { 100.0 * b.pool_hits as f64 / total as f64 } else { 0.0 },
            b.pool_misses
        );
    }
    println!(
        "\nstep time: plain {:.4}s vs bpipe {:.4}s",
        plain.mean_step_time(),
        bpipe_run.mean_step_time(),
    );
    println!("tokens trained: {}", plain.tokens + bpipe_run.tokens);
    Ok(())
}
