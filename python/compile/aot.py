"""AOT lowering: JAX stage functions → HLO-text artifacts + manifest.

Python runs ONCE, at build time (``make artifacts``); the rust
coordinator loads the emitted ``artifacts/*.hlo.txt`` through the PJRT C
API and never touches Python again.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
Functions are lowered with ``return_tuple=True`` so every artifact's
output is a tuple the rust side unpacks uniformly.

Artifact set (per ModelSpec):

  {first,mid,last}_init   (seed:i32) -> flat params
  first_fwd/bwd           embedding + blocks
  mid_fwd/bwd             blocks            (+ ``mid_{fwd,bwd}_b{N}``
                                             microbatch sweep for the
                                             paper-§4 estimator example)
  last_fwd/bwd            blocks + head + mean-CE loss
  adam_{first,mid,last}   Adam over flat vectors
  mid_fwd_att_{naive,fused,flash}  attention-variant ablation artifacts

plus ``manifest.json`` describing shapes/dtypes/param counts, and
``model.hlo.txt`` (= mid_fwd) as the Makefile's freshness sentinel.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ModelSpec, adam_step, make_stage_fns

__all__ = ["lower_to_hlo_text", "emit_artifacts", "main"]


def lower_to_hlo_text(fn, *example_args) -> str:
    """Lower a jittable fn to XLA HLO text (the rust-loadable format)."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


_DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}


def _sig(avals) -> list[dict]:
    out = []
    for a in avals:
        out.append({"shape": list(a.shape), "dtype": _DTYPE_NAMES[jnp.asarray(a, dtype=a.dtype).dtype if not hasattr(a, "dtype") else a.dtype]})
    return out


def _spec_struct(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def emit_artifacts(
    spec: ModelSpec,
    out_dir: Path,
    bs_sweep: tuple[int, ...] = (1, 2, 4),
    attention_variants: tuple[str, ...] = ("naive", "fused", "flash"),
    verbose: bool = True,
) -> dict:
    """Lower every artifact for ``spec`` into ``out_dir``; return manifest."""
    out_dir.mkdir(parents=True, exist_ok=True)
    fns = {k: make_stage_fns(spec, k) for k in ("first", "mid", "last")}
    b, s, h = spec.b, spec.s, spec.h

    f32 = jnp.float32
    i32 = jnp.int32
    act = _spec_struct((b, s, h), f32)
    tok = _spec_struct((b, s), i32)
    scalar_i = _spec_struct((), i32)
    scalar_f = _spec_struct((), f32)

    manifest: dict = {
        "spec": dataclasses.asdict(spec),
        "params": {k: fns[k].n_params for k in fns},
        "bs_sweep": list(bs_sweep),
        "artifacts": {},
    }

    def emit(name: str, fn, *args):
        text = lower_to_hlo_text(fn, *args)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        lowered_out = jax.eval_shape(fn, *args)
        manifest["artifacts"][name] = {
            "file": path.name,
            "inputs": [{"shape": list(a.shape), "dtype": _DTYPE_NAMES[a.dtype]} for a in args],
            "outputs": [
                {"shape": list(o.shape), "dtype": _DTYPE_NAMES[o.dtype]} for o in lowered_out
            ],
        }
        if verbose:
            print(f"  wrote {path.name} ({len(text) / 1024:.0f} KiB)")

    for kind in ("first", "mid", "last"):
        sf = fns[kind]
        flat = _spec_struct((sf.n_params,), f32)
        emit(f"{kind}_init", sf.init, scalar_i)
        if kind == "first":
            emit("first_fwd", sf.fwd, flat, tok)
            emit("first_bwd", sf.bwd, flat, tok, act)
        elif kind == "mid":
            emit("mid_fwd", sf.fwd, flat, act)
            emit("mid_bwd", sf.bwd, flat, act, act)
        else:
            emit("last_fwd", sf.fwd, flat, act, tok)
            emit("last_bwd", sf.bwd, flat, act, tok)
        emit(
            f"adam_{kind}",
            adam_step,
            flat,
            flat,
            flat,
            flat,
            scalar_i,
            scalar_f,
        )

    # Microbatch-size sweep over the mid stage: the measurement the
    # paper's §4 estimator consumes (single-stage time at b ∈ sweep).
    for bb in bs_sweep:
        sweep_spec = spec.with_b(bb)
        sf = make_stage_fns(sweep_spec, "mid")
        flat = _spec_struct((sf.n_params,), f32)
        act_b = _spec_struct((bb, s, h), f32)
        emit(f"mid_fwd_b{bb}", sf.fwd, flat, act_b)
        emit(f"mid_bwd_b{bb}", sf.bwd, flat, act_b, act_b)

    # Attention-variant ablation (paper §3.2 kernel analysis) at default b.
    for att in attention_variants:
        var_spec = dataclasses.replace(spec, attention=att)
        sf = make_stage_fns(var_spec, "mid")
        flat = _spec_struct((sf.n_params,), f32)
        emit(f"mid_fwd_att_{att}", sf.fwd, flat, act)

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    # Makefile freshness sentinel: a copy of mid_fwd.
    shutil.copyfile(out_dir / "mid_fwd.hlo.txt", out_dir / "model.hlo.txt")
    if verbose:
        print(f"  wrote manifest.json + model.hlo.txt sentinel → {out_dir}")
    return manifest


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--family", default="llama", choices=["gpt", "llama"])
    ap.add_argument("--h", type=int, default=256)
    ap.add_argument("--a", type=int, default=8)
    ap.add_argument("--s", type=int, default=128)
    ap.add_argument("--v", type=int, default=4096)
    ap.add_argument("--layers-per-stage", type=int, default=2)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--b", type=int, default=2)
    ap.add_argument(
        "--attention", default="flash", choices=["naive", "fused", "flash"]
    )
    ap.add_argument("--bs-sweep", default="1,2,4")
    ap.add_argument("--no-variants", action="store_true", help="skip ablation artifacts")
    args = ap.parse_args(argv)

    spec = ModelSpec(
        family=args.family,
        h=args.h,
        a=args.a,
        s=args.s,
        v=args.v,
        layers_per_stage=args.layers_per_stage,
        stages=args.stages,
        b=args.b,
        attention=args.attention,
    )
    bs_sweep = tuple(int(x) for x in args.bs_sweep.split(",") if x)
    variants = () if args.no_variants else ("naive", "fused", "flash")
    print(f"AOT lowering {spec} → {args.out_dir}")
    emit_artifacts(spec, Path(args.out_dir), bs_sweep=bs_sweep, attention_variants=variants)


if __name__ == "__main__":
    main()
