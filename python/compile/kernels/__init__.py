"""L1 Pallas kernels + pure-jnp oracles.

``flash_attention`` — blockwise online-softmax attention (paper's
    "flash attn 2" arm).
``fused_scaled_softmax`` — Megatron-style fused scale+mask+softmax
    (the kernel behind the paper's §3.2 GPT-3 analysis).
``ref`` — jnp reference implementations, including the *unfused*
    softmax baseline whose extra cast kernels the paper profiles.
"""

from . import ref
from .flash_attention import FlashBlockSizes, flash_attention, vmem_analysis
from .fused_softmax import fused_scaled_softmax
from .rmsnorm import fused_rmsnorm, ref_rmsnorm

__all__ = [
    "ref",
    "flash_attention",
    "FlashBlockSizes",
    "vmem_analysis",
    "fused_scaled_softmax",
    "fused_rmsnorm",
    "ref_rmsnorm",
]
