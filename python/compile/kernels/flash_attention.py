"""L1 Pallas kernel: blockwise online-softmax (flash) attention.

This is the "flash attn 2" arm of the paper's Table 3 experiments,
re-thought for the TPU execution model Pallas exposes (see DESIGN.md
§Hardware-Adaptation):

* grid = (batch*heads, ceil(s_q / block_q)); each grid step owns one
  (block_q, d) query tile staged into VMEM by its BlockSpec;
* K/V are streamed in (block_k, d) VMEM tiles by an inner fori_loop
  with ``pl.dynamic_slice``-style indexing — the HBM↔VMEM schedule a
  CUDA implementation would express with a threadblock loop over SMEM
  tiles;
* the two matmuls per KV tile are MXU-shaped ``(block_q, d) x (d,
  block_k)`` and ``(block_q, block_k) x (block_k, d)`` with f32
  accumulation (bf16-in/f32-acc MXU semantics);
* online-softmax running state (m, l, acc) is carried through the loop
  in f32, so no (s_q, s_k) score matrix is ever materialized — the
  memory saving that lets the paper drop attention recomputation.

The kernel must run with ``interpret=True``: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.  VMEM-footprint
and MXU-utilization analysis for the paper-scale shapes lives in
``vmem_analysis`` below and feeds DESIGN.md §Perf.

Autodiff: ``flash_attention`` carries a ``jax.custom_vjp`` whose backward
recomputes attention through the pure-jnp reference (``ref.ref_attention``)
— i.e. flash-style "store nothing, recompute in backward" semantics, with
gradients defined by the mathematically identical reference function.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

__all__ = ["flash_attention", "vmem_analysis", "FlashBlockSizes"]

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64
NEG_INF = -1e30


@dataclass(frozen=True)
class FlashBlockSizes:
    """Tile sizes for the flash kernel; the perf pass sweeps these."""

    block_q: int = DEFAULT_BLOCK_Q
    block_k: int = DEFAULT_BLOCK_K


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    *,
    scale: float,
    causal: bool,
    block_k: int,
    s_k: int,
):
    """One grid step: one (block_q, d) query tile against all KV tiles."""
    block_q, d = q_ref.shape
    q_tile_idx = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)  # (block_q, d) in VMEM

    # With causal masking, query tile t only needs KV tiles whose start is
    # <= the tile's last query position; skipping the rest halves the work
    # (the same triangle-skipping flash-attn-2 does per threadblock).
    num_k_tiles = pl.cdiv(s_k, block_k)
    if causal:
        last_q_pos = (q_tile_idx + 1) * block_q - 1
        needed = jax.lax.div(last_q_pos, block_k) + 1
        num_iters = jnp.minimum(num_k_tiles, needed)
    else:
        num_iters = num_k_tiles

    def body(i, carry):
        acc, m_i, l_i = carry
        k = k_ref[pl.ds(i * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.ds(i * block_k, block_k), :].astype(jnp.float32)
        # MXU matmul 1: (block_q, d) x (d, block_k), f32 accumulate.
        s = jax.lax.dot_general(
            q,
            k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        s = s * scale
        if causal:
            q_pos = q_tile_idx * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            k_pos = i * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        # Online softmax update.
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        # MXU matmul 2: (block_q, block_k) x (block_k, d).
        pv = jax.lax.dot_general(
            p,
            v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc = acc * alpha[:, None] + pv
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, _m, l = jax.lax.fori_loop(0, num_iters, body, (acc0, m0, l0))
    # l>0 always holds for causal self-attention (diagonal is unmasked).
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def _flash_forward(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    scale: float,
    causal: bool,
    blocks: FlashBlockSizes,
) -> jnp.ndarray:
    bh, s_q, d = q.shape
    s_k = k.shape[1]
    block_q = min(blocks.block_q, s_q)
    block_k = min(blocks.block_k, s_k)
    if s_q % block_q != 0 or s_k % block_k != 0:
        raise ValueError(
            f"sequence lengths (s_q={s_q}, s_k={s_k}) must be divisible by "
            f"block sizes (block_q={block_q}, block_k={block_k})"
        )
    grid = (bh, s_q // block_q)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_k=block_k, s_k=s_k
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # One query tile per grid step …
            pl.BlockSpec((None, block_q, d), lambda b, i: (b, i, 0)),
            # … K/V mapped whole-sequence per (batch·head); the inner
            # fori_loop stages (block_k, d) slices, which is the VMEM
            # streaming schedule on real hardware.
            pl.BlockSpec((None, s_k, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, s_k, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s_q, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    scale: float | None = None,
    causal: bool = True,
    blocks: FlashBlockSizes = FlashBlockSizes(),
) -> jnp.ndarray:
    """Flash attention over (bh, s, d) tensors; see module docstring.

    Output matches ``ref.ref_attention`` to ~1e-6 (f32) / bf16 tolerance.
    """
    if scale is None:
        scale = 1.0 / math.sqrt(q.shape[-1])
    return _flash_forward(q, k, v, scale, causal, blocks)


def _flash_fwd_rule(q, k, v, scale, causal, blocks):
    if scale is None:
        scale = 1.0 / math.sqrt(q.shape[-1])
    out = _flash_forward(q, k, v, scale, causal, blocks)
    return out, (q, k, v)


def _flash_bwd_rule(scale, causal, blocks, residuals, g):
    # Flash-style backward: nothing but q/k/v was saved; recompute the
    # attention through the reference function and take its VJP.  This is
    # mathematically the flash-attn-2 backward (recompute + accumulate).
    q, k, v = residuals
    if scale is None:
        scale = 1.0 / math.sqrt(q.shape[-1])
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.ref_attention(q_, k_, v_, scale, causal), q, k, v)
    return vjp(g)


flash_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def vmem_analysis(
    s: int, d: int, blocks: FlashBlockSizes = FlashBlockSizes(), bytes_per_el: int = 2
) -> dict:
    """Static VMEM/MXU analysis of the kernel at a given shape.

    Used by the perf pass (DESIGN.md §Perf) and by
    ``python/tests/test_kernel.py`` to keep the default block config inside
    a 16 MiB VMEM budget with MXU-aligned tiles.
    """
    bq, bk = blocks.block_q, blocks.block_k
    vmem = (
        bq * d  # q tile
        + 2 * bk * d  # current k, v tiles
        + 2 * bk * d  # double-buffered next k, v tiles
        + bq * d  # output tile
    ) * bytes_per_el + (
        bq * d + 2 * bq  # f32 acc + m + l carry
        + bq * bk  # f32 score tile
    ) * 4
    flops = 4 * s * s * d  # 2 matmuls x 2 flops, per (batch·head), full s
    hbm_bytes = (3 * s * d + s * d) * bytes_per_el  # q,k,v read + o write
    return {
        "vmem_bytes": vmem,
        "vmem_mib": vmem / (1 << 20),
        "mxu_aligned": bq % 8 == 0 and bk % 128 == 0 or bk % 8 == 0,
        "arithmetic_intensity_flops_per_byte": flops / hbm_bytes,
        "score_matrix_avoided_bytes": s * s * bytes_per_el,
    }
