"""L1 Pallas kernel: fused scale + causal-mask + softmax.

This is Megatron-LM's "scaled masked softmax" fusion, the kernel the
paper's §3.2 identifies as the real source of BPipe's GPT-3 win: the
unfused path (see ``ref.unfused_scaled_softmax``) launches separate
bf16→f32 cast, scale, mask, softmax and f32→bf16 kernels — five-plus HBM
round-trips over the (b·a, s, s) score tensor — while the fused kernel
does one read and one write with the f32 math kept in VMEM.

TPU adaptation (DESIGN.md §Hardware-Adaptation): instead of a CUDA block
per softmax row with warp reductions, we grid over
(batch·heads, ceil(s_q / rows_block)) and stage a (rows_block, s_k) tile
in VMEM; the row reductions are plain VPU reductions over the lane axis.

Runs under ``interpret=True`` (CPU PJRT); numerics validated against
``ref.ref_scaled_softmax`` in python/tests/test_kernel.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

__all__ = ["fused_scaled_softmax"]

DEFAULT_ROWS_BLOCK = 64
NEG_INF = -1e30


def _softmax_kernel(x_ref, o_ref, *, scale: float, causal: bool, s_q: int, s_k: int):
    rows_block = x_ref.shape[0]
    row_tile = pl.program_id(1)
    # Single VMEM-resident pass: upcast once, scale, mask, reduce, exp,
    # normalize, downcast once.
    x = x_ref[...].astype(jnp.float32) * scale
    if causal:
        q_pos = (
            row_tile * rows_block
            + jax.lax.broadcasted_iota(jnp.int32, (rows_block, s_k), 0)
            + (s_k - s_q)
        )
        k_pos = jax.lax.broadcasted_iota(jnp.int32, (rows_block, s_k), 1)
        x = jnp.where(k_pos <= q_pos, x, NEG_INF)
    m = jnp.max(x, axis=-1, keepdims=True)
    p = jnp.exp(x - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = (p / denom).astype(o_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def fused_scaled_softmax(
    scores: jnp.ndarray,
    scale: float,
    causal: bool = True,
    rows_block: int = DEFAULT_ROWS_BLOCK,
) -> jnp.ndarray:
    """Fused scale+mask+softmax over (bh, s_q, s_k) scores.

    Semantically identical to ``ref.ref_scaled_softmax`` /
    ``ref.unfused_scaled_softmax``; structurally a single Pallas kernel.
    """
    bh, s_q, s_k = scores.shape
    rb = min(rows_block, s_q)
    if s_q % rb != 0:
        raise ValueError(f"s_q={s_q} must be divisible by rows_block={rb}")
    kernel = functools.partial(
        _softmax_kernel, scale=scale, causal=causal, s_q=s_q, s_k=s_k
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, s_q // rb),
        in_specs=[pl.BlockSpec((None, rb, s_k), lambda b, i: (b, i, 0))],
        out_specs=pl.BlockSpec((None, rb, s_k), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(scores.shape, scores.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(scores)


def _fused_fwd(scores, scale, causal, rows_block):
    out = fused_scaled_softmax(scores, scale, causal, rows_block)
    return out, out


def _fused_bwd(scale, causal, rows_block, out, g):
    # d softmax: p * (g - sum(g * p)).  The mask/scale fold into the chain
    # rule the same way as for the reference implementation.
    out_f = out.astype(jnp.float32)
    g_f = g.astype(jnp.float32)
    dot = jnp.sum(g_f * out_f, axis=-1, keepdims=True)
    dscores = out_f * (g_f - dot) * scale
    return (dscores.astype(out.dtype),)


fused_scaled_softmax.defvjp(_fused_fwd, _fused_bwd)


# Re-export the unfused baseline so model.py has one import site for all
# three attention-softmax variants.
unfused_scaled_softmax = ref.unfused_scaled_softmax
