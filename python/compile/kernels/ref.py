"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Everything here is deliberately written in the most direct jnp form; the
pytest suite asserts the Pallas kernels (fused_softmax, flash_attention)
match these references to tight tolerances across shape/dtype sweeps.

The *unfused* softmax path (``unfused_scaled_softmax``) is also the
performance baseline the paper's §3.2 profiles on GPT-3: separate
bf16→f32 cast, scale, mask, softmax and f32→bf16 cast kernels, each a
full HBM round-trip on a real accelerator.  We keep the casts explicit so
they survive into the lowered HLO and can be pointed at from the cost
model in ``rust/src/sim/costmodel.rs``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "ref_scaled_softmax",
    "unfused_scaled_softmax",
    "ref_attention",
]


def _causal_mask(s_q: int, s_k: int) -> jnp.ndarray:
    """Boolean (s_q, s_k) mask, True where attention is allowed.

    Query i (global position ``s_k - s_q + i``) may attend to keys ``<= i``;
    supports rectangular score matrices for block-wise tests.
    """
    q_pos = jnp.arange(s_q)[:, None] + (s_k - s_q)
    k_pos = jnp.arange(s_k)[None, :]
    return k_pos <= q_pos


def ref_scaled_softmax(scores: jnp.ndarray, scale: float, causal: bool = True) -> jnp.ndarray:
    """Numerically stable scale+mask+softmax over the last axis, f32 math.

    ``scores``: (..., s_q, s_k).  Returns the same dtype as the input.
    This is the semantic oracle for the fused Pallas kernel.
    """
    dtype = scores.dtype
    x = scores.astype(jnp.float32) * scale
    if causal:
        mask = _causal_mask(scores.shape[-2], scores.shape[-1])
        x = jnp.where(mask, x, jnp.float32(-1e30))
    x = x - jnp.max(x, axis=-1, keepdims=True)
    p = jnp.exp(x)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p.astype(dtype)


def unfused_scaled_softmax(scores: jnp.ndarray, scale: float, causal: bool = True) -> jnp.ndarray:
    """The *unfused* baseline: distinct cast / scale / mask / softmax steps.

    Matches ``ref_scaled_softmax`` numerically; differs in op structure —
    each `astype` and elementwise op is a separate HLO op (a separate
    memory-bound kernel on a real GPU, cf. paper §3.2 experiment (7)).
    """
    dtype = scores.dtype
    x = scores.astype(jnp.float32)  # cast kernel 1: bf16 -> f32
    x = x * jnp.float32(scale)  # scale kernel
    if causal:
        mask = _causal_mask(scores.shape[-2], scores.shape[-1])
        x = jnp.where(mask, x, jnp.float32(-1e30))  # mask kernel
    x = jax.nn.softmax(x, axis=-1)  # softmax (itself ≥3 passes)
    return x.astype(dtype)  # cast kernel 2: f32 -> bf16


def ref_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    scale: float | None = None,
    causal: bool = True,
) -> jnp.ndarray:
    """Reference multi-head attention core.

    q, k, v: (bh, s, d) — batch*heads collapsed in the leading dim.
    Returns (bh, s_q, d), same dtype as q.  All math in f32.
    """
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    dtype = q.dtype
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqd,bkd->bqk", qf, kf)
    p = ref_scaled_softmax(scores, scale, causal=causal).astype(jnp.float32)
    out = jnp.einsum("bqk,bkd->bqd", p, vf)
    return out.astype(dtype)
