"""L1 Pallas kernel: fused RMSNorm (LLaMA's normalization).

RMSNorm is one of the memory-bound elementwise ops the simulator's cost
model charges per layer (`ELEM_FWD_B`/`ELEM_BWD_B` in
rust/src/sim/costmodel.rs).  Unfused it is ≥3 HBM passes (square-mean
reduce, rsqrt broadcast, scale-by-gain); fused it is one read + one
write with the reduction kept in VMEM — the same single-pass argument as
the fused softmax of paper §3.2, applied to the norm.

TPU adaptation: grid over row tiles of the flattened (rows, h) input;
one (rows_block, h) tile resident in VMEM per step; the row reduction is
a VPU lane reduction.  `interpret=True` as always (CPU PJRT).

Autodiff: custom_vjp with the closed-form RMSNorm gradient.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_rmsnorm", "ref_rmsnorm"]

DEFAULT_ROWS_BLOCK = 64
EPS = 1e-5


def ref_rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = EPS) -> jnp.ndarray:
    """Reference RMSNorm over the last axis: x * rsqrt(mean(x²) + ε) * g."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(ms + eps) * g).astype(x.dtype)


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (rows_block, h) in VMEM
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def fused_rmsnorm(
    x: jnp.ndarray,
    g: jnp.ndarray,
    eps: float = EPS,
    rows_block: int = DEFAULT_ROWS_BLOCK,
) -> jnp.ndarray:
    """Fused RMSNorm over (..., h); `g` is the (h,) gain vector."""
    orig_shape = x.shape
    h = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, h)
    rb = min(rows_block, rows)
    if rows % rb != 0:
        # fall back to a single whole-array tile for awkward row counts
        rb = rows
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(rows // rb,),
        in_specs=[
            pl.BlockSpec((rb, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rb, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, h), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x2, g)
    return out.reshape(orig_shape)


def _fwd(x, g, eps, rows_block):
    return fused_rmsnorm(x, g, eps, rows_block), (x, g)


def _bwd(eps, rows_block, res, dy):
    # closed-form RMSNorm VJP:
    #   r = rsqrt(mean(x²)+ε); y = x·r·g
    #   dx = r·(dy·g − x·r²·mean(x·dy·g))
    #   dg = Σ_rows dy·x·r
    x, g = res
    xf = x.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    r = jax.lax.rsqrt(ms + eps)
    dyg = dyf * gf
    dx = r * (dyg - xf * (r * r) * jnp.mean(xf * dyg, axis=-1, keepdims=True))
    dg = jnp.sum((dyf * xf * r).reshape(-1, x.shape[-1]), axis=0)
    return dx.astype(x.dtype), dg.astype(g.dtype)


fused_rmsnorm.defvjp(_fwd, _bwd)
