"""L2: JAX transformer pipeline-stage model (build-time only).

Defines the compute graphs that the rust coordinator (L3) executes through
AOT-compiled XLA artifacts.  The model is cut into pipeline *stages* the
way Megatron-LM cuts it (paper §3.1):

* ``first`` stage — token (+ learned position, GPT) embedding, then
  ``layers_per_stage`` transformer blocks;
* ``mid`` stages — ``layers_per_stage`` transformer blocks;
* ``last`` stage — blocks, final norm, LM head and mean cross-entropy.

Two model families, matching the paper's Table 2 subjects:

* ``gpt``  — GPT-3 style: LayerNorm, learned positions, GELU 4h FFN;
* ``llama``— LLaMA style: RMSNorm, rotary embeddings, SwiGLU FFN whose
  three matmuls give the same 16bsh² FLOPs as GPT's FFN (paper Eq. 1
  discussion).

Three attention paths, matching Table 3's "attention method" column:

* ``naive`` — unfused scale/softmax with explicit f32 casts (the slow
  kernels the paper profiles in experiment (7));
* ``fused`` — Pallas fused scale+mask+softmax (Megatron's fused kernel,
  experiment (8));
* ``flash`` — Pallas flash attention (experiments (4)–(6), (9)–(10)).

Parameters cross the rust boundary as a single flat f32 vector per stage
(``ravel_pytree``), so the coordinator stays shape-agnostic; every
function here is pure and jit/lowerable.  Backward functions recompute
the forward from the stashed stage *input* (stage-granularity activation
checkpointing) — the stash is exactly the tensor BPipe evicts/loads.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels import FlashBlockSizes, flash_attention, fused_scaled_softmax
from .kernels.ref import unfused_scaled_softmax
from .kernels.rmsnorm import fused_rmsnorm

__all__ = ["ModelSpec", "StageFns", "make_stage_fns", "adam_step", "ADAM_HYPERS"]


@dataclass(frozen=True)
class ModelSpec:
    """Static model + parallelism shape; fixed at AOT-lowering time."""

    family: str = "gpt"  # 'gpt' | 'llama'
    h: int = 256  # hidden size
    a: int = 8  # attention heads
    s: int = 128  # sequence length
    v: int = 4096  # vocabulary size
    layers_per_stage: int = 2
    stages: int = 4  # pipeline stages (p)
    b: int = 2  # microbatch size
    attention: str = "fused"  # 'naive' | 'fused' | 'flash'
    flash_block_q: int = 64
    flash_block_k: int = 64
    #: route LLaMA's RMSNorm through the fused Pallas kernel
    fused_rmsnorm: bool = False

    def __post_init__(self):
        if self.family not in ("gpt", "llama"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.attention not in ("naive", "fused", "flash"):
            raise ValueError(f"unknown attention {self.attention!r}")
        if self.h % self.a != 0:
            raise ValueError("h must be divisible by a")

    @property
    def d_head(self) -> int:
        return self.h // self.a

    @property
    def ffn_hidden(self) -> int:
        if self.family == "gpt":
            return 4 * self.h
        # LLaMA: 8h/3 rounded up to a multiple of 128 (weight-matrix tiling).
        f = (8 * self.h) // 3
        return ((f + 127) // 128) * 128

    @property
    def total_layers(self) -> int:
        return self.layers_per_stage * self.stages

    def with_b(self, b: int) -> "ModelSpec":
        return replace(self, b=b)


ADAM_HYPERS = dict(b1=0.9, b2=0.95, eps=1e-8)


# --------------------------------------------------------------------------
# Parameter initialization (pytrees; flattened at the API boundary)
# --------------------------------------------------------------------------


def _init_linear(key, n_in, n_out, scale=0.02, bias=True):
    w = jax.random.normal(key, (n_in, n_out), jnp.float32) * scale
    if bias:
        return {"w": w, "b": jnp.zeros((n_out,), jnp.float32)}
    return {"w": w}


def _init_block(key, spec: ModelSpec):
    ks = jax.random.split(key, 8)
    # Residual-output projections scaled down with depth (GPT-2 init).
    out_scale = 0.02 / (2.0 * spec.total_layers) ** 0.5
    bias = spec.family == "gpt"
    p: dict[str, Any] = {
        "attn": {
            "wq": _init_linear(ks[0], spec.h, spec.h, bias=bias),
            "wk": _init_linear(ks[1], spec.h, spec.h, bias=bias),
            "wv": _init_linear(ks[2], spec.h, spec.h, bias=bias),
            "wo": _init_linear(ks[3], spec.h, spec.h, scale=out_scale, bias=bias),
        },
    }
    if spec.family == "gpt":
        p["ln1"] = {"g": jnp.ones((spec.h,)), "b": jnp.zeros((spec.h,))}
        p["ln2"] = {"g": jnp.ones((spec.h,)), "b": jnp.zeros((spec.h,))}
        p["ffn"] = {
            "w1": _init_linear(ks[4], spec.h, spec.ffn_hidden),
            "w2": _init_linear(ks[5], spec.ffn_hidden, spec.h, scale=out_scale),
        }
    else:
        p["ln1"] = {"g": jnp.ones((spec.h,))}
        p["ln2"] = {"g": jnp.ones((spec.h,))}
        p["ffn"] = {
            "w1": _init_linear(ks[4], spec.h, spec.ffn_hidden, bias=False),
            "w3": _init_linear(ks[6], spec.h, spec.ffn_hidden, bias=False),
            "w2": _init_linear(ks[5], spec.ffn_hidden, spec.h, scale=out_scale, bias=False),
        }
    return p


def _init_stage(key, spec: ModelSpec, kind: str):
    ks = jax.random.split(key, spec.layers_per_stage + 2)
    p: dict[str, Any] = {
        "blocks": [_init_block(ks[i], spec) for i in range(spec.layers_per_stage)]
    }
    if kind == "first":
        p["tok_emb"] = jax.random.normal(ks[-1], (spec.v, spec.h), jnp.float32) * 0.02
        if spec.family == "gpt":
            p["pos_emb"] = jax.random.normal(ks[-2], (spec.s, spec.h), jnp.float32) * 0.01
    elif kind == "last":
        if spec.family == "gpt":
            p["ln_f"] = {"g": jnp.ones((spec.h,)), "b": jnp.zeros((spec.h,))}
        else:
            p["ln_f"] = {"g": jnp.ones((spec.h,))}
        p["head"] = _init_linear(ks[-1], spec.h, spec.v, bias=False)
    elif kind != "mid":
        raise ValueError(f"unknown stage kind {kind!r}")
    return p


# --------------------------------------------------------------------------
# Forward pieces
# --------------------------------------------------------------------------


def _layernorm(x, p, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * p["g"] + p["b"]


def _rmsnorm(x, p, eps=1e-5):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * p["g"]


def _norm(x, p, spec: ModelSpec):
    if spec.family == "gpt":
        return _layernorm(x, p)
    if spec.fused_rmsnorm:
        return fused_rmsnorm(x, p["g"])
    return _rmsnorm(x, p)


def _linear(x, p):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def _rotary(x: jnp.ndarray, base: float = 10000.0) -> jnp.ndarray:
    """RoPE over (b, s, a, d): rotate consecutive feature pairs."""
    b, s, a, d = x.shape
    half = d // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]  # (s, half)
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(x, p, spec: ModelSpec):
    b, s, h = x.shape
    a, d = spec.a, spec.d_head
    q = _linear(x, p["wq"]).reshape(b, s, a, d)
    k = _linear(x, p["wk"]).reshape(b, s, a, d)
    v = _linear(x, p["wv"]).reshape(b, s, a, d)
    if spec.family == "llama":
        q, k = _rotary(q), _rotary(k)
    # (b, s, a, d) -> (b*a, s, d)
    to_bh = lambda t: t.transpose(0, 2, 1, 3).reshape(b * a, s, d)
    q, k, v = to_bh(q), to_bh(k), to_bh(v)
    scale = 1.0 / (d**0.5)

    if spec.attention == "flash":
        o = flash_attention(
            q, k, v, scale, True, FlashBlockSizes(spec.flash_block_q, spec.flash_block_k)
        )
    else:
        scores = jnp.einsum("bqd,bkd->bqk", q, k)
        if spec.attention == "fused":
            probs = fused_scaled_softmax(scores, scale, True)
        else:  # 'naive' — the unfused multi-kernel path of paper exp. (7)
            probs = unfused_scaled_softmax(scores, scale, True)
        o = jnp.einsum("bqk,bkd->bqd", probs, v)

    o = o.reshape(b, a, s, d).transpose(0, 2, 1, 3).reshape(b, s, h)
    return _linear(o, p["wo"])


def _ffn(x, p, spec: ModelSpec):
    if spec.family == "gpt":
        return _linear(jax.nn.gelu(_linear(x, p["w1"])), p["w2"])
    return _linear(jax.nn.silu(_linear(x, p["w1"])) * _linear(x, p["w3"]), p["w2"])


def _block(x, p, spec: ModelSpec):
    x = x + _attention(_norm(x, p["ln1"], spec), p["attn"], spec)
    x = x + _ffn(_norm(x, p["ln2"], spec), p["ffn"], spec)
    return x


def _blocks(x, p, spec: ModelSpec):
    for bp in p["blocks"]:
        x = _block(x, bp, spec)
    return x


def _embed(tokens, p, spec: ModelSpec):
    x = p["tok_emb"][tokens]
    if spec.family == "gpt":
        x = x + p["pos_emb"][None, : tokens.shape[1], :]
    return x


def _head_loss(x, targets, p, spec: ModelSpec):
    x = _norm(x, p["ln_f"], spec)
    logits = _linear(x, p["head"])  # (b, s, v)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# --------------------------------------------------------------------------
# Stage-level API (flat parameter vectors)
# --------------------------------------------------------------------------


@dataclass
class StageFns:
    """Pure functions for one stage kind over *flat* f32 param vectors.

    fwd/bwd signatures (x: f32[b,s,h], tokens/targets: i32[b,s]):
      first: fwd(flat, tokens) -> x          bwd(flat, tokens, dy) -> (dflat,)
      mid:   fwd(flat, x) -> y               bwd(flat, x, dy) -> (dx, dflat)
      last:  fwd(flat, x, targets) -> loss   bwd(flat, x, targets) -> (dx, dflat, loss)

    ``bwd`` recomputes the forward from the stashed stage input (the
    BPipe-evictable activation) — stage-granularity checkpointing.
    """

    kind: str
    n_params: int
    init: Callable  # (seed: i32) -> flat
    fwd: Callable
    bwd: Callable
    unravel: Callable = field(repr=False, default=None)


def make_stage_fns(spec: ModelSpec, kind: str) -> StageFns:
    """Build flat-parameter stage functions for ``kind`` ∈ first|mid|last."""
    template = _init_stage(jax.random.PRNGKey(0), spec, kind)
    flat0, unravel = ravel_pytree(template)
    n = flat0.size

    def init(seed):
        p = _init_stage(jax.random.PRNGKey(seed), spec, kind)
        return (ravel_pytree(p)[0],)

    if kind == "first":

        def fwd(flat, tokens):
            return (_blocks(_embed(tokens, unravel(flat), spec), unravel(flat), spec),)

        def bwd(flat, tokens, dy):
            _, vjp = jax.vjp(lambda f: fwd(f, tokens)[0], flat)
            return (vjp(dy)[0],)

    elif kind == "mid":

        def fwd(flat, x):
            return (_blocks(x, unravel(flat), spec),)

        def bwd(flat, x, dy):
            _, vjp = jax.vjp(lambda f, x_: fwd(f, x_)[0], flat, x)
            dflat, dx = vjp(dy)
            return (dx, dflat)

    elif kind == "last":

        def fwd(flat, x, targets):
            p = unravel(flat)
            return (_head_loss(_blocks(x, p, spec), targets, p, spec),)

        def bwd(flat, x, targets):
            loss, vjp = jax.vjp(lambda f, x_: fwd(f, x_, targets)[0], flat, x)
            dflat, dx = vjp(jnp.float32(1.0))
            return (dx, dflat, loss)

    else:
        raise ValueError(f"unknown stage kind {kind!r}")

    return StageFns(kind=kind, n_params=int(n), init=init, fwd=fwd, bwd=bwd, unravel=unravel)


# --------------------------------------------------------------------------
# Optimizer (one artifact per flat-vector length)
# --------------------------------------------------------------------------


def adam_step(p, g, m, v, step, lr):
    """Adam with bias correction; (β1, β2, ε) = (0.9, 0.95, 1e-8).

    ``step`` is the 1-based update index (i32 scalar), ``lr`` an f32
    scalar, everything else flat f32 vectors of equal length.  Returns
    (p', m', v').  The paper's §4 model ignores optimizer cost; we still
    run it for real so training actually converges.
    """
    b1, b2, eps = ADAM_HYPERS["b1"], ADAM_HYPERS["b2"], ADAM_HYPERS["eps"]
    t = step.astype(jnp.float32)
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * jnp.square(g)
    m_hat = m / (1.0 - b1**t)
    v_hat = v / (1.0 - b2**t)
    p = p - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return (p, m, v)
