"""AOT pipeline: lowering produces parseable, well-formed HLO text + manifest.

These tests guard the python→rust interchange contract: HLO *text* with
``return_tuple=True`` outputs, and a manifest whose shapes the rust
runtime (rust/src/runtime/artifact.rs) trusts verbatim.
"""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from compile.aot import emit_artifacts, lower_to_hlo_text
from compile.model import ModelSpec

jax.config.update("jax_platform_name", "cpu")

MICRO = ModelSpec(
    family="llama", h=64, a=4, s=64, v=256, layers_per_stage=1, stages=2, b=1,
    attention="fused",
)


@pytest.fixture(scope="module")
def artifact_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = emit_artifacts(
        MICRO, out, bs_sweep=(1, 2), attention_variants=("naive",), verbose=False
    )
    return out, manifest


def test_lower_simple_fn_has_entry():
    text = lower_to_hlo_text(
        lambda x: (x * 2.0,), jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    assert "ENTRY" in text
    assert "HloModule" in text


def test_all_artifacts_exist_and_parse(artifact_dir):
    out, manifest = artifact_dir
    for name, meta in manifest["artifacts"].items():
        path = out / meta["file"]
        assert path.exists(), name
        text = path.read_text()
        assert "ENTRY" in text, name
        # text format (rust loads via HloModuleProto::from_text_file);
        # serialized protos would start with binary bytes.
        assert text.lstrip().startswith("HloModule"), name


def test_manifest_shapes_consistent(artifact_dir):
    out, manifest = artifact_dir
    n_mid = manifest["params"]["mid"]
    a = manifest["artifacts"]
    assert a["mid_fwd"]["inputs"][0]["shape"] == [n_mid]
    assert a["mid_fwd"]["inputs"][1]["shape"] == [MICRO.b, MICRO.s, MICRO.h]
    assert a["mid_fwd"]["outputs"][0]["shape"] == [MICRO.b, MICRO.s, MICRO.h]
    # bwd returns (dx, dflat)
    assert a["mid_bwd"]["outputs"][0]["shape"] == [MICRO.b, MICRO.s, MICRO.h]
    assert a["mid_bwd"]["outputs"][1]["shape"] == [n_mid]
    # last_bwd returns (dx, dflat, loss)
    assert a["last_bwd"]["outputs"][2]["shape"] == []
    # adam: (p, g, m, v, step, lr) -> (p, m, v)
    assert len(a["adam_mid"]["inputs"]) == 6
    assert len(a["adam_mid"]["outputs"]) == 3
    assert a["adam_mid"]["inputs"][4]["dtype"] == "i32"


def test_manifest_bs_sweep_artifacts(artifact_dir):
    out, manifest = artifact_dir
    for bb in manifest["bs_sweep"]:
        meta = manifest["artifacts"][f"mid_fwd_b{bb}"]
        assert meta["inputs"][1]["shape"] == [bb, MICRO.s, MICRO.h]


def test_sentinel_written(artifact_dir):
    out, _ = artifact_dir
    assert (out / "model.hlo.txt").exists()
    assert (out / "manifest.json").exists()
    m = json.loads((out / "manifest.json").read_text())
    assert m["spec"]["h"] == MICRO.h


def test_param_counts_match_closed_form(artifact_dir):
    _, manifest = artifact_dir
    h, f, v_ = MICRO.h, MICRO.ffn_hidden, MICRO.v
    # llama block: 4 h*h attn + 3 h*f ffn + 2 h norms
    block = 4 * h * h + 3 * h * f + 2 * h
    assert manifest["params"]["mid"] == block * MICRO.layers_per_stage
    assert manifest["params"]["first"] == block + v_ * h
    assert manifest["params"]["last"] == block + v_ * h + h
