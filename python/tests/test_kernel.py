"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/block sizes; assert_allclose against
ref.py.  This is the CORE correctness signal for the kernels that end up
inside every AOT artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (
    FlashBlockSizes,
    flash_attention,
    fused_scaled_softmax,
    ref,
    vmem_analysis,
)

jax.config.update("jax_platform_name", "cpu")

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    bh=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([64, 128, 256]),
    d=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_matches_ref(bh, s, d, causal, dtype):
    q, k, v = (_rand(i, (bh, s, d), dtype) for i in range(3))
    out = flash_attention(q, k, v, None, causal)
    want = ref.ref_attention(q, k, v, None, causal)
    assert out.dtype == dtype
    assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


@settings(max_examples=10, deadline=None)
@given(
    block_q=st.sampled_from([16, 32, 64, 128]),
    block_k=st.sampled_from([16, 32, 64, 128]),
)
def test_flash_block_size_invariance(block_q, block_k):
    """Result must not depend on the tiling (pure performance knob)."""
    q, k, v = (_rand(i, (2, 128, 32), jnp.float32) for i in range(3))
    out = flash_attention(q, k, v, None, True, FlashBlockSizes(block_q, block_k))
    want = ref.ref_attention(q, k, v)
    assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_custom_scale():
    q, k, v = (_rand(i, (2, 64, 32), jnp.float32) for i in range(3))
    out = flash_attention(q, k, v, 0.05, True)
    want = ref.ref_attention(q, k, v, 0.05, True)
    assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_rejects_indivisible_seq():
    q, k, v = (_rand(i, (1, 96, 16), jnp.float32) for i in range(3))
    with pytest.raises(ValueError, match="divisible"):
        flash_attention(q, k, v, None, True, FlashBlockSizes(64, 64))


def test_flash_grads_match_ref():
    q, k, v = (_rand(i, (2, 128, 32), jnp.float32) for i in range(3))

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.ref_attention(q, k, v) ** 2)

    g = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gw = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for got, want in zip(g, gw):
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_flash_causality():
    """Perturbing future keys must not change earlier outputs."""
    q, k, v = (_rand(i, (1, 128, 16), jnp.float32) for i in range(3))
    out = flash_attention(q, k, v, None, True)
    k2 = k.at[:, 100:, :].add(7.0)
    v2 = v.at[:, 100:, :].add(-3.0)
    out2 = flash_attention(q, k2, v2, None, True)
    assert_allclose(np.asarray(out[:, :100]), np.asarray(out2[:, :100]), rtol=1e-6, atol=1e-6)
    assert not np.allclose(np.asarray(out[:, 100:]), np.asarray(out2[:, 100:]))


def test_flash_rows_sum_via_uniform_v():
    """With v = ones, output must be exactly ones (softmax rows sum to 1)."""
    q, k = (_rand(i, (2, 64, 16), jnp.float32) for i in range(2))
    v = jnp.ones((2, 64, 16), jnp.float32)
    out = flash_attention(q, k, v, None, True)
    assert_allclose(np.asarray(out), np.ones_like(out), rtol=1e-6, atol=1e-6)


def test_flash_jit_and_lowerable():
    q, k, v = (_rand(i, (2, 64, 16), jnp.float32) for i in range(3))
    jitted = jax.jit(lambda q, k, v: flash_attention(q, k, v))
    assert_allclose(
        np.asarray(jitted(q, k, v)),
        np.asarray(ref.ref_attention(q, k, v)),
        rtol=2e-5,
        atol=2e-5,
    )
    # and it lowers to HLO text (the AOT interchange format)
    hlo = jax.jit(lambda q, k, v: (flash_attention(q, k, v),)).lower(q, k, v)
    assert "ENTRY" in hlo.compiler_ir("hlo").as_hlo_text()


# --------------------------------------------------------------------------
# fused softmax
# --------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    bh=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([64, 128, 256]),
    causal=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    scale=st.sampled_from([1.0, 0.125, 0.08838834764831845]),
)
def test_fused_softmax_matches_ref(bh, s, causal, dtype, scale):
    x = _rand(11, (bh, s, s), dtype)
    out = fused_scaled_softmax(x, scale, causal)
    want = ref.ref_scaled_softmax(x, scale, causal)
    assert out.dtype == dtype
    assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


def test_fused_matches_unfused_baseline():
    """The fused kernel and the paper's unfused path are numerically equal."""
    x = _rand(3, (4, 128, 128), jnp.float32)
    fused = fused_scaled_softmax(x, 0.125, True)
    unfused = ref.unfused_scaled_softmax(x, 0.125, True)
    assert_allclose(np.asarray(fused), np.asarray(unfused), rtol=1e-6, atol=1e-6)


def test_fused_softmax_rows_sum_to_one():
    x = _rand(5, (2, 128, 128), jnp.float32)
    out = np.asarray(fused_scaled_softmax(x, 0.3, True))
    assert_allclose(out.sum(-1), np.ones(out.shape[:-1]), rtol=1e-6, atol=1e-6)


def test_fused_softmax_causal_zeros():
    x = _rand(6, (1, 64, 64), jnp.float32)
    out = np.asarray(fused_scaled_softmax(x, 1.0, True))
    assert np.all(out[0][np.triu_indices(64, k=1)] == 0.0)


def test_fused_softmax_grad_matches_ref():
    x = _rand(7, (2, 64, 64), jnp.float32)
    g = jax.grad(lambda x: jnp.sum(fused_scaled_softmax(x, 0.2, True) ** 3))(x)
    gw = jax.grad(lambda x: jnp.sum(ref.ref_scaled_softmax(x, 0.2, True) ** 3))(x)
    assert_allclose(np.asarray(g), np.asarray(gw), rtol=1e-4, atol=1e-5)


def test_fused_softmax_rows_block_invariance():
    x = _rand(8, (2, 128, 128), jnp.float32)
    a = fused_scaled_softmax(x, 0.5, True, rows_block=16)
    b = fused_scaled_softmax(x, 0.5, True, rows_block=128)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-7, atol=1e-7)


def test_fused_softmax_extreme_values_stable():
    """Large logits must not overflow (the f32-in-VMEM argument of §3.2)."""
    x = jnp.full((1, 64, 64), 3e4, jnp.float32)
    out = np.asarray(fused_scaled_softmax(x, 1.0, True))
    assert np.isfinite(out).all()


# --------------------------------------------------------------------------
# structural / perf analysis
# --------------------------------------------------------------------------


def test_vmem_budget_default_blocks():
    """Default flash tiles stay inside a 16 MiB VMEM budget at paper scale."""
    for d in (64, 96, 128):
        info = vmem_analysis(s=2048, d=d)
        assert info["vmem_mib"] < 16.0, info


def test_vmem_analysis_reports_score_matrix_saving():
    info = vmem_analysis(s=2048, d=128)
    # the avoided (s, s) score tensor dominates what non-flash stores
    assert info["score_matrix_avoided_bytes"] == 2048 * 2048 * 2
    assert info["arithmetic_intensity_flops_per_byte"] > 100
