"""L2 correctness: stage functions, attention-variant equivalence, Adam.

The pipeline-stage decomposition must be *exactly* the monolithic model:
chaining first→mid→last forwards equals a single full-model forward, and
the chained backward (stage-granularity recompute, the thing BPipe's
activation stash feeds) equals full-model autodiff.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.model import ADAM_HYPERS, ModelSpec, adam_step, make_stage_fns

jax.config.update("jax_platform_name", "cpu")

TINY = dict(h=64, a=4, s=64, v=256, layers_per_stage=1, stages=3, b=2)


def _spec(**kw):
    return ModelSpec(**{**TINY, **kw})


def _tokens(spec, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (spec.b, spec.s), 0, spec.v)


@pytest.mark.parametrize("family", ["gpt", "llama"])
@pytest.mark.parametrize("attention", ["naive", "fused", "flash"])
def test_stage_shapes(family, attention):
    spec = _spec(family=family, attention=attention)
    tok = _tokens(spec)
    first = make_stage_fns(spec, "first")
    mid = make_stage_fns(spec, "mid")
    last = make_stage_fns(spec, "last")
    x = first.fwd(first.init(0)[0], tok)[0]
    assert x.shape == (spec.b, spec.s, spec.h)
    y = mid.fwd(mid.init(1)[0], x)[0]
    assert y.shape == x.shape
    loss = last.fwd(last.init(2)[0], y, tok)[0]
    assert loss.shape == ()
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("family", ["gpt", "llama"])
def test_attention_variants_agree(family):
    """naive / fused / flash are three implementations of one function."""
    outs = {}
    for att in ("naive", "fused", "flash"):
        spec = _spec(family=family, attention=att)
        mid = make_stage_fns(spec, "mid")
        flat = mid.init(7)[0]
        x = jax.random.normal(jax.random.PRNGKey(3), (spec.b, spec.s, spec.h))
        outs[att] = np.asarray(mid.fwd(flat, x)[0])
    assert_allclose(outs["fused"], outs["naive"], rtol=2e-5, atol=2e-5)
    assert_allclose(outs["flash"], outs["naive"], rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("family", ["gpt", "llama"])
def test_attention_variant_grads_agree(family):
    for att in ("fused", "flash"):
        spec_n = _spec(family=family, attention="naive")
        spec_a = _spec(family=family, attention=att)
        mid_n = make_stage_fns(spec_n, "mid")
        mid_a = make_stage_fns(spec_a, "mid")
        flat = mid_n.init(7)[0]
        x = jax.random.normal(jax.random.PRNGKey(3), (spec_n.b, spec_n.s, spec_n.h))
        dy = jax.random.normal(jax.random.PRNGKey(4), x.shape)
        dx_n, df_n = mid_n.bwd(flat, x, dy)
        dx_a, df_a = mid_a.bwd(flat, x, dy)
        assert_allclose(np.asarray(dx_a), np.asarray(dx_n), rtol=5e-4, atol=5e-4)
        assert_allclose(np.asarray(df_a), np.asarray(df_n), rtol=5e-4, atol=5e-4)


def test_pipeline_equals_monolith():
    """Chained stage fwd/bwd == full-model autodiff (same flat params)."""
    spec = _spec(family="llama", attention="naive")
    first = make_stage_fns(spec, "first")
    mid = make_stage_fns(spec, "mid")
    last = make_stage_fns(spec, "last")
    tok = _tokens(spec)
    f0, f1, f2 = first.init(0)[0], mid.init(1)[0], last.init(2)[0]

    def monolith(f0, f1, f2):
        x = first.fwd(f0, tok)[0]
        y = mid.fwd(f1, x)[0]
        return last.fwd(f2, y, tok)[0]

    loss_ref, grads_ref = jax.value_and_grad(monolith, argnums=(0, 1, 2))(f0, f1, f2)

    # pipeline-style: fwd chain, then bwd chain through stashed inputs
    x = first.fwd(f0, tok)[0]
    y = mid.fwd(f1, x)[0]
    dy, g2, loss = last.bwd(f2, y, tok)
    dx, g1 = mid.bwd(f1, x, dy)
    (g0,) = first.bwd(f0, tok, dx)

    assert_allclose(float(loss), float(loss_ref), rtol=1e-6)
    for got, want in zip((g0, g1, g2), grads_ref):
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_first_stage_gpt_uses_positions():
    spec = _spec(family="gpt")
    first = make_stage_fns(spec, "first")
    flat = first.init(0)[0]
    tok = jnp.zeros((spec.b, spec.s), jnp.int32)  # same token everywhere
    x = np.asarray(first.fwd(flat, tok)[0])
    # learned positions make otherwise-identical tokens distinct
    assert not np.allclose(x[:, 0, :], x[:, 1, :])


def test_rotary_embedding_properties():
    from compile.model import _rotary

    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 32))
    r = _rotary(x)
    # rotation preserves per-pair norms …
    assert_allclose(
        np.linalg.norm(np.asarray(r), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )
    # … is the identity at position 0 …
    assert_allclose(np.asarray(r[:, 0]), np.asarray(x[:, 0]), rtol=1e-6, atol=1e-6)
    # … and differs at later positions (position-dependent phases)
    assert not np.allclose(np.asarray(r[:, 5]), np.asarray(x[:, 5]))


def test_loss_at_init_is_log_v():
    spec = _spec()
    first = make_stage_fns(spec, "first")
    last = make_stage_fns(spec, "last")
    tok = _tokens(spec)
    x = first.fwd(first.init(0)[0], tok)[0]
    loss = float(last.fwd(last.init(1)[0], x, tok)[0])
    assert abs(loss - np.log(spec.v)) < 0.3


def test_ffn_hidden_llama_flops_match_gpt():
    """Paper §3.1: LLaMA's 3-matmul SwiGLU ≈ GPT's 2-matmul GELU FFN FLOPs."""
    spec_l = _spec(family="llama", h=1024)
    spec_g = _spec(family="gpt", h=1024)
    flops_llama = 3 * 2 * spec_l.h * spec_l.ffn_hidden
    flops_gpt = 2 * 2 * spec_g.h * spec_g.ffn_hidden
    # equal up to the round-to-128 widening of the SwiGLU hidden dim
    assert abs(flops_llama - flops_gpt) / flops_gpt < 0.05
    assert spec_l.ffn_hidden % 128 == 0


def test_adam_step_matches_reference():
    n = 257
    key = jax.random.PRNGKey(0)
    p, g, m, v = (jax.random.normal(jax.random.PRNGKey(i), (n,)) for i in range(4))
    v = jnp.abs(v)
    p2, m2, v2 = adam_step(p, g, m, v, jnp.int32(3), jnp.float32(1e-3))

    b1, b2, eps = ADAM_HYPERS["b1"], ADAM_HYPERS["b2"], ADAM_HYPERS["eps"]
    m_ref = b1 * np.asarray(m) + (1 - b1) * np.asarray(g)
    v_ref = b2 * np.asarray(v) + (1 - b2) * np.asarray(g) ** 2
    mh = m_ref / (1 - b1**3)
    vh = v_ref / (1 - b2**3)
    p_ref = np.asarray(p) - 1e-3 * mh / (np.sqrt(vh) + eps)
    assert_allclose(np.asarray(p2), p_ref, rtol=1e-6, atol=1e-7)
    assert_allclose(np.asarray(m2), m_ref, rtol=1e-6)
    assert_allclose(np.asarray(v2), v_ref, rtol=1e-6)


def test_adam_descends_quadratic():
    p = jnp.array([5.0, -3.0, 2.0])
    m = jnp.zeros(3)
    v = jnp.zeros(3)
    for t in range(1, 200):
        g = 2.0 * p  # d/dp p^2
        p, m, v = adam_step(p, g, m, v, jnp.int32(t), jnp.float32(0.05))
    assert float(jnp.abs(p).max()) < 0.1


def test_tiny_training_loss_decreases():
    """Three-stage pipeline math overfits a fixed batch (sanity e2e)."""
    spec = _spec(family="llama", attention="fused", v=64, s=32, b=2)
    first = make_stage_fns(spec, "first")
    mid = make_stage_fns(spec, "mid")
    last = make_stage_fns(spec, "last")
    tok = jax.random.randint(jax.random.PRNGKey(9), (spec.b, spec.s), 0, spec.v)
    params = [first.init(0)[0], mid.init(1)[0], last.init(2)[0]]
    opt = [(jnp.zeros_like(p), jnp.zeros_like(p)) for p in params]

    @jax.jit
    def step_fn(params, opt, t):
        f0, f1, f2 = params
        x = first.fwd(f0, tok)[0]
        y = mid.fwd(f1, x)[0]
        dy, g2, loss = last.bwd(f2, y, tok)
        dx, g1 = mid.bwd(f1, x, dy)
        (g0,) = first.bwd(f0, tok, dx)
        new_params, new_opt = [], []
        for p, g, (m, v) in zip(params, (g0, g1, g2), opt):
            p, m, v = adam_step(p, g, m, v, t, jnp.float32(1e-2))
            new_params.append(p)
            new_opt.append((m, v))
        return new_params, new_opt, loss

    losses = []
    for t in range(1, 31):
        params, opt, loss = step_fn(params, opt, jnp.int32(t))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses


def test_fused_rmsnorm_path_is_exact():
    """The fused-RMSNorm Pallas path is a drop-in for the jnp norm."""
    spec_a = _spec(family="llama", attention="fused")
    import dataclasses

    spec_b = dataclasses.replace(spec_a, fused_rmsnorm=True)
    ma = make_stage_fns(spec_a, "mid")
    mb = make_stage_fns(spec_b, "mid")
    flat = ma.init(3)[0]
    x = jax.random.normal(jax.random.PRNGKey(8), (spec_a.b, spec_a.s, spec_a.h))
    ya = np.asarray(ma.fwd(flat, x)[0])
    yb = np.asarray(mb.fwd(flat, x)[0])
    assert_allclose(yb, ya, rtol=1e-6, atol=1e-6)
    da = ma.bwd(flat, x, jnp.ones_like(x))
    db = mb.bwd(flat, x, jnp.ones_like(x))
    assert_allclose(np.asarray(db[1]), np.asarray(da[1]), rtol=1e-4, atol=1e-5)
