"""Fused RMSNorm Pallas kernel vs oracle (values + closed-form VJP)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.rmsnorm import fused_rmsnorm, ref_rmsnorm

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=15, deadline=None)
@given(
    rows=st.sampled_from([1, 7, 64, 128]),
    h=st.sampled_from([32, 128, 256]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_fused_rmsnorm_matches_ref(rows, h, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (rows, h), jnp.float32).astype(dtype)
    g = (1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(1), (h,), jnp.float32)).astype(dtype)
    out = fused_rmsnorm(x, g)
    want = ref_rmsnorm(x, g)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    assert out.dtype == dtype
    assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_fused_rmsnorm_3d_shapes():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 48, 64))
    g = jnp.ones((64,))
    out = fused_rmsnorm(x, g)
    assert out.shape == x.shape
    assert_allclose(np.asarray(out), np.asarray(ref_rmsnorm(x, g)), rtol=1e-6, atol=1e-6)


def test_fused_rmsnorm_unit_rows_have_unit_rms():
    x = jax.random.normal(jax.random.PRNGKey(3), (16, 256))
    out = np.asarray(fused_rmsnorm(x, jnp.ones((256,))))
    rms = np.sqrt((out**2).mean(-1))
    assert_allclose(rms, np.ones(16), rtol=1e-4)


def test_fused_rmsnorm_grads_match_autodiff_of_ref():
    x = jax.random.normal(jax.random.PRNGKey(4), (32, 64))
    g = 1.0 + 0.05 * jax.random.normal(jax.random.PRNGKey(5), (64,))

    def loss_fused(x, g):
        return jnp.sum(fused_rmsnorm(x, g) ** 2)

    def loss_ref(x, g):
        return jnp.sum(ref_rmsnorm(x, g) ** 2)

    gx, gg = jax.grad(loss_fused, argnums=(0, 1))(x, g)
    wx, wg = jax.grad(loss_ref, argnums=(0, 1))(x, g)
    assert_allclose(np.asarray(gx), np.asarray(wx), rtol=1e-4, atol=1e-5)
    assert_allclose(np.asarray(gg), np.asarray(wg), rtol=1e-4, atol=1e-5)


def test_fused_rmsnorm_lowers_to_hlo():
    x = jax.random.normal(jax.random.PRNGKey(6), (64, 128))
    g = jnp.ones((128,))
    hlo = jax.jit(lambda x, g: (fused_rmsnorm(x, g),)).lower(x, g)
    assert "ENTRY" in hlo.compiler_ir("hlo").as_hlo_text()
