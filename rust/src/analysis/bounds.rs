//! Pass 3 — **static memory bounds**: closed-form per-stage stash
//! high-waters computed from the schedule's op order alone (no discrete
//! event simulation), in the spirit of the paper's Eq. 3/4 but extended
//! to evict/load traffic between BPipe pairs.
//!
//! Three numbers per stage bracket what the DES (and the real
//! coordinator) can observe:
//!
//! * `lo` — the stage's **own** resident high-water
//!   ([`StageProgram::stash_high_water`]): +1 per Fwd/Load, −1 per
//!   Bwd/Evict, prefix max.  A sound *lower* bound on the dynamic peak
//!   (accepted partner stashes only add), so `lo`-based OOM verdicts
//!   are safe to act on — this is what the sweep's skip gate uses.
//! * `pred` — `lo` plus the partner stage's *planned* accepted
//!   high-water (prefix max of +1 per partner Evict, −1 per partner
//!   Load).  On contention-free pair-adjacent layouts the DES peak is
//!   `pred` or `pred + 1` (one transient slot while a load overlaps the
//!   retiring stash) on every golden cell.
//! * `hi` — a sound *upper* bound: the stage's own high-water with
//!   evict frees **delayed indefinitely** (+1 Fwd/Load, −1 Bwd, Evict
//!   ignored) plus the worst-case set of simultaneously-parked partner
//!   stashes (every partner Evict adds its `(mb, chunk)` key to the
//!   remote set, the partner's Bwd removes it; max set size).  Holds on
//!   every golden cell including sequential layouts, where inter-node
//!   link contention delays evict frees far past the planned schedule.
//!
//! Diagnostic codes emitted here: `static-bound-exceeded` (error — a
//! stage's own static high-water cannot fit under the planned
//! bound/`stage_bounds`) and `provably-oom` (warning — with an
//! experiment's cluster attached, even the `lo` peak exceeds HBM).

use super::diagnostics::Diagnostic;
use crate::bpipe::pairing;
use crate::config::ExperimentConfig;
use crate::model::MemoryModel;
use crate::schedule::{OpKind, Schedule, ScheduleKind, StageProgram};

/// The static bracket for one stage (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageBoundEstimate {
    pub stage: u64,
    /// Own resident high-water — sound lower bound on the dynamic peak.
    pub lo: i64,
    /// `lo` + partner's planned accepted high-water — matches the DES
    /// within +1 on contention-free pair-adjacent layouts.
    pub pred: i64,
    /// Delayed-free + worst-case accepted — sound upper bound.
    pub hi: i64,
    /// The planned resident cap (`stage_bounds[s]`, else the uniform
    /// BPipe bound), when the schedule is rebalanced.
    pub planned: Option<u64>,
}

/// Partner's *planned* accepted high-water: +1 per Evict, −1 per Load,
/// prefix max over the partner's program.
fn accepted_planned(prog: &StageProgram) -> i64 {
    let mut cur = 0i64;
    let mut hw = 0i64;
    for op in &prog.ops {
        match op.kind {
            OpKind::Evict => cur += 1,
            OpKind::Load => cur -= 1,
            OpKind::Fwd | OpKind::Bwd => {}
        }
        hw = hw.max(cur);
    }
    hw
}

/// Own high-water with evict frees delayed indefinitely: +1 per
/// Fwd/Load, −1 per Bwd, Evict ignored.
fn own_delayed(prog: &StageProgram) -> i64 {
    let mut cur = 0i64;
    let mut hw = 0i64;
    for op in &prog.ops {
        match op.kind {
            OpKind::Fwd | OpKind::Load => cur += 1,
            OpKind::Bwd => cur -= 1,
            OpKind::Evict => {}
        }
        hw = hw.max(cur);
    }
    hw
}

/// Worst-case count of partner stashes parked here at once: an Evict
/// parks `(mb, chunk)` until the partner's *backward* for that key
/// retires it (the load only copies; the slot is reclaimed at retire),
/// so the bound is the max size of the evicted-key set.
fn accepted_worst(prog: &StageProgram) -> i64 {
    let mut parked: Vec<(u64, u64)> = Vec::new();
    let mut hw = 0usize;
    for op in &prog.ops {
        match op.kind {
            OpKind::Evict => {
                parked.push((op.mb, op.chunk));
                hw = hw.max(parked.len());
            }
            OpKind::Bwd => parked.retain(|&k| k != (op.mb, op.chunk)),
            OpKind::Fwd | OpKind::Load => {}
        }
    }
    hw as i64
}

/// The planned resident cap for `stage`, if the schedule carries one.
pub fn planned_cap(s: &Schedule, stage: u64) -> Option<u64> {
    if let Some(sb) = &s.stage_bounds {
        return sb.get(stage as usize).copied();
    }
    match s.kind {
        ScheduleKind::BPipe { bound } => Some(bound),
        _ => None,
    }
}

/// Compute the `[lo, pred, hi]` bracket for every stage.
pub fn static_bounds(s: &Schedule) -> Vec<StageBoundEstimate> {
    (0..s.p)
        .map(|stage| {
            let own = s.program(stage);
            let partner = s.program(pairing::partner(s.p, stage));
            let lo = own.stash_high_water();
            StageBoundEstimate {
                stage,
                lo,
                pred: lo + accepted_planned(partner),
                hi: own_delayed(own) + accepted_worst(partner),
                planned: planned_cap(s, stage),
            }
        })
        .collect()
}

/// Error-level findings: a stage whose own static high-water exceeds
/// its planned cap (the plan cannot hold, no matter the interleaving).
pub fn check_bounds(s: &Schedule) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    for est in static_bounds(s) {
        if let Some(cap) = est.planned {
            if est.lo > cap as i64 {
                diags.push(Diagnostic::error(
                    "static-bound-exceeded",
                    Some(est.stage),
                    format!(
                        "own static stash high-water {} exceeds the planned bound {cap}",
                        est.lo
                    ),
                ));
            }
        }
    }
    diags
}

/// Per-stage **lower-bound** peak bytes on `e`'s cluster: weights +
/// optimizer state + reserved pool + `lo` stashes of one chunk's
/// activation each — the fewest bytes any execution of this schedule
/// can peak at.
pub fn static_peak_bytes(e: &ExperimentConfig, s: &Schedule) -> Vec<u64> {
    let mm = MemoryModel::new(e);
    let chunks = s.chunks.max(1);
    (0..s.p)
        .map(|stage| {
            let lo = s.program(stage).stash_high_water().max(0) as u64;
            let act = mm.activation_bytes_per_microbatch(stage) / chunks;
            mm.weight_opt_bytes(stage) + lo * act + e.cluster.reserved_bytes
        })
        .collect()
}

/// Sweep skip gate: the first stage whose **lower-bound** peak already
/// exceeds HBM on `e`'s cluster, with the peak bytes.  Sound: the
/// dynamic stash peak is ≥ `lo` on every stage, and peak bytes are
/// monotone in resident stashes, so a `Some` here means the DES cell
/// must OOM — it can be skipped without simulating.
pub fn provably_oom_stage(e: &ExperimentConfig, s: &Schedule) -> Option<(u64, u64)> {
    static_peak_bytes(e, s)
        .into_iter()
        .enumerate()
        .find(|&(_, bytes)| bytes > e.cluster.hbm_bytes)
        .map(|(stage, bytes)| (stage as u64, bytes))
}

/// Warning-level findings from the capacity model (used when the plan
/// carries an experiment, i.e. `RebalancePlan::Capacity`).
pub fn check_capacity(e: &ExperimentConfig, s: &Schedule) -> Vec<Diagnostic> {
    match provably_oom_stage(e, s) {
        Some((stage, bytes)) => vec![Diagnostic::warning(
            "provably-oom",
            Some(stage),
            format!(
                "lower-bound peak {:.1} GiB exceeds HBM {:.1} GiB — every run of this plan OOMs",
                bytes as f64 / (1u64 << 30) as f64,
                e.cluster.hbm_bytes as f64 / (1u64 << 30) as f64,
            ),
        )],
        None => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bpipe::rebalance;
    use crate::schedule::Family;

    #[test]
    fn base_1f1b_bracket_is_tight() {
        // no evict/load traffic: lo == pred == hi == the 1F1B in-flight
        let s = Family::OneFOneB.build(8, 16);
        for est in static_bounds(&s) {
            assert_eq!(est.lo, est.pred, "stage {}", est.stage);
            assert_eq!(est.lo, est.hi, "stage {}", est.stage);
            let natural =
                crate::model::memory::one_f_one_b_in_flight(8, est.stage, 16) as i64;
            assert_eq!(est.lo, natural, "stage {}", est.stage);
            assert_eq!(est.planned, None);
        }
    }

    #[test]
    fn rebalanced_schedule_brackets_the_accepted_traffic() {
        let s = rebalance(&Family::OneFOneB.build(8, 16), None);
        let ests = static_bounds(&s);
        for est in &ests {
            let cap = est.planned.expect("rebalanced schedules carry a bound") as i64;
            assert!(est.lo <= cap, "stage {}: lo {} over cap {cap}", est.stage, est.lo);
            assert!(est.pred >= est.lo && est.hi >= est.pred, "{est:?}");
        }
        // acceptor stages (partners of evictors) see accepted traffic
        assert!(ests.iter().any(|e| e.pred > e.lo), "no accepted traffic found");
        assert!(check_bounds(&s).is_empty());
    }

    #[test]
    fn undersized_stage_bounds_flag_static_bound_exceeded() {
        let mut s = Family::OneFOneB.build(4, 8);
        // stage 0's natural in-flight is 4; claim a cap of 2 without
        // rebalancing — statically impossible
        s.stage_bounds = Some(vec![2, 2, 2, 1]);
        let diags = check_bounds(&s);
        assert!(
            diags.iter().any(|d| d.code == "static-bound-exceeded" && d.stage == Some(0)),
            "{diags:?}"
        );
    }

    #[test]
    fn exp8_base_1f1b_is_provably_oom_at_stage_0() {
        let e = crate::config::paper_experiment(8).unwrap();
        let base = Family::OneFOneB.build(e.parallel.p, e.parallel.num_microbatches());
        let (stage, _) = provably_oom_stage(&e, &base).expect("exp 8 base 1F1B OOMs");
        assert_eq!(stage, 0);
        assert_eq!(check_capacity(&e, &base).len(), 1);
        // the capacity-planned rebalance fits — no OOM verdict
        let bounds = rebalance::capacity_stage_bounds(&e, &base);
        let planned = rebalance::rebalance_bounded(&base, &bounds);
        assert_eq!(provably_oom_stage(&e, &planned), None);
        assert!(check_capacity(&e, &planned).is_empty());
    }
}
