//! Diagnostic values produced by the static analyzer.
//!
//! Every finding — a deadlock cycle, a donation-linearity violation, a
//! memory bound that cannot hold — is reported as a [`Diagnostic`] with
//! a stable machine-readable `code`, a severity, and a human-readable
//! message naming the ops and channels involved.  The JSON form
//! (`bpipe check --json`) reuses [`util::json`](crate::util) so
//! downstream tools (the planned schedule synthesizer, CI) can gate on
//! exact codes instead of scraping prose.

use crate::util::Json;

/// How bad a finding is.  `Error` findings make [`super::check_plan`]
/// callers reject the plan; `Warning` and `Info` are advisory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warning,
    Error,
}

impl Severity {
    pub fn label(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One finding from a static-analysis pass.
///
/// `code` is a stable kebab-case identifier (see the module docs of
/// [`super::protocol`], [`super::linearity`] and [`super::bounds`] for
/// the full vocabulary); `stage` is the physical stage the finding is
/// anchored to, when one exists.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub severity: Severity,
    pub code: &'static str,
    pub stage: Option<u64>,
    pub message: String,
}

impl Diagnostic {
    pub fn error(code: &'static str, stage: Option<u64>, message: String) -> Self {
        Diagnostic { severity: Severity::Error, code, stage, message }
    }

    pub fn warning(code: &'static str, stage: Option<u64>, message: String) -> Self {
        Diagnostic { severity: Severity::Warning, code, stage, message }
    }

    pub fn info(code: &'static str, stage: Option<u64>, message: String) -> Self {
        Diagnostic { severity: Severity::Info, code, stage, message }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("severity", Json::str(self.severity.label())),
            ("code", Json::str(self.code)),
            (
                "stage",
                match self.stage {
                    Some(s) => Json::Num(s as f64),
                    None => Json::Null,
                },
            ),
            ("message", Json::str(&self.message)),
        ])
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.stage {
            Some(s) => {
                write!(f, "{}[{}] stage {}: {}", self.severity.label(), self.code, s, self.message)
            }
            None => write!(f, "{}[{}]: {}", self.severity.label(), self.code, self.message),
        }
    }
}

/// True iff any finding is error-level (the gate condition used by
/// `plan_schedule` and the `bpipe check` exit code).
pub fn has_errors(diags: &[Diagnostic]) -> bool {
    diags.iter().any(|d| d.severity == Severity::Error)
}

/// Render findings one per line, errors first.
pub fn render_diagnostics(diags: &[Diagnostic]) -> String {
    let mut sorted: Vec<&Diagnostic> = diags.iter().collect();
    sorted.sort_by(|a, b| b.severity.cmp(&a.severity));
    let mut out = String::new();
    for d in sorted {
        out.push_str(&d.to_string());
        out.push('\n');
    }
    out
}

/// JSON array of findings (the payload of `bpipe check --json`).
pub fn diagnostics_to_json(diags: &[Diagnostic]) -> Json {
    Json::Arr(diags.iter().map(Diagnostic::to_json).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_json_name_the_code() {
        let d = Diagnostic::error("deadlock-cycle", Some(3), "stuck".into());
        let text = d.to_string();
        assert!(text.contains("error[deadlock-cycle]") && text.contains("stage 3"), "{text}");
        let j = d.to_json().to_string();
        assert!(j.contains("\"code\":\"deadlock-cycle\"") && j.contains("\"stage\":3"), "{j}");
    }

    #[test]
    fn severity_orders_and_gates() {
        assert!(Severity::Error > Severity::Warning && Severity::Warning > Severity::Info);
        let ds = vec![Diagnostic::info("x", None, "i".into())];
        assert!(!has_errors(&ds));
        let ds = vec![
            Diagnostic::info("x", None, "i".into()),
            Diagnostic::error("y", None, "e".into()),
        ];
        assert!(has_errors(&ds));
        let rendered = render_diagnostics(&ds);
        let first = rendered.lines().next().unwrap();
        assert!(first.starts_with("error["), "errors sort first: {rendered}");
    }
}
