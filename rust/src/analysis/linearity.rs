//! Pass 2 — **donation linearity**: an abstract interpretation of the
//! `StageRunner` argument lifecycle, proving every `Arg::Donated`
//! handle is spent exactly once and never read after donation, and that
//! the stash slot array `(capacity, m, chunks)` is never exceeded.
//!
//! The runner's donation masks are fixed per op kind: a forward stashes
//! its input (one live handle per `(mb, chunk)` key), a backward
//! donates the stashed input and the incoming gradient, an evict
//! donates the stash to the remote store, a load re-materializes it.
//! So each key walks a four-state lattice:
//!
//! ```text
//!              Fwd                Evict
//!   Unborn ─────────▶ Resident ◀─────────▶ Remote
//!                        │          Load
//!                    Bwd │
//!                        ▼
//!                      Spent      (re-entered by a later Fwd: the slot
//!                                  is free and a NEW handle is created)
//! ```
//!
//! Any transition outside this diagram is a linearity violation the
//! runtime would hit as a panic (`double stash`, `not resident`,
//! `load of non-evicted`) or as silent memory unsafety if unchecked.
//! The Adam flush's donations (`w`, `g`, `m`, `v`, one mask per chunk,
//! outputs re-captured into the same slots) are structurally linear —
//! fixed code path, no schedule dependence — and need no per-schedule
//! check.
//!
//! Diagnostic codes emitted here: `slot-out-of-range` (a key outside
//! the `m × chunks` slot array), `double-stash` (Fwd/Load into an
//! occupied slot), `use-uninitialized` (Bwd/Evict of a never-stashed
//! key), `use-after-donate` (Bwd of a key whose handle lives in the
//! remote store, or Load of a key never donated there),
//! `double-donate` (Bwd/Evict of an already-spent handle),
//! `stash-overflow` (resident count above the planned capacity), and
//! `donation-leak` (handles still live at end of step, where the runner
//! asserts its stash is empty).  All are error-level.

use std::collections::HashMap;

use super::bounds::planned_cap;
use super::diagnostics::Diagnostic;
use crate::schedule::{OpKind, Schedule};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum KeyState {
    Resident,
    Remote,
    Spent,
}

/// Check donation linearity with an explicit per-stage stash capacity
/// (resident-handle ceiling).  [`check_linearity`] derives the capacity
/// from the schedule itself; synthesis tools can probe tighter ones.
pub fn check_linearity_with_caps(s: &Schedule, caps: &[i64]) -> Vec<Diagnostic> {
    let chunks = s.chunks.max(1);
    let mut diags = Vec::new();
    for stage in 0..s.p {
        let cap = caps.get(stage as usize).copied().unwrap_or(i64::MAX);
        let mut state: HashMap<(u64, u64), KeyState> = HashMap::new();
        let mut resident = 0i64;
        for op in &s.program(stage).ops {
            let key = (op.mb, op.chunk);
            let at = format!("{:?} mb{} c{}", op.kind, op.mb, op.chunk);
            if op.mb >= s.m || op.chunk >= chunks {
                diags.push(Diagnostic::error(
                    "slot-out-of-range",
                    Some(stage),
                    format!("{at} is outside the {}x{} slot array", s.m, chunks),
                ));
                continue;
            }
            match op.kind {
                OpKind::Fwd => match state.get(&key) {
                    Some(KeyState::Resident) | Some(KeyState::Remote) => {
                        diags.push(Diagnostic::error(
                            "double-stash",
                            Some(stage),
                            format!("{at} stashes into an occupied slot"),
                        ));
                    }
                    // Unborn or Spent: the slot is free, a new handle is born
                    None | Some(KeyState::Spent) => {
                        state.insert(key, KeyState::Resident);
                        resident += 1;
                    }
                },
                OpKind::Bwd => match state.get(&key) {
                    Some(KeyState::Resident) => {
                        state.insert(key, KeyState::Spent);
                        resident -= 1;
                    }
                    Some(KeyState::Remote) => diags.push(Diagnostic::error(
                        "use-after-donate",
                        Some(stage),
                        format!("{at} reads a stash donated to the remote store (no Load)"),
                    )),
                    Some(KeyState::Spent) => diags.push(Diagnostic::error(
                        "double-donate",
                        Some(stage),
                        format!("{at} donates an already-spent handle"),
                    )),
                    None => diags.push(Diagnostic::error(
                        "use-uninitialized",
                        Some(stage),
                        format!("{at} consumes a never-stashed key"),
                    )),
                },
                OpKind::Evict => match state.get(&key) {
                    Some(KeyState::Resident) => {
                        state.insert(key, KeyState::Remote);
                        resident -= 1;
                    }
                    Some(KeyState::Remote) | Some(KeyState::Spent) => {
                        diags.push(Diagnostic::error(
                            "double-donate",
                            Some(stage),
                            format!("{at} donates an already-donated handle"),
                        ));
                    }
                    None => diags.push(Diagnostic::error(
                        "use-uninitialized",
                        Some(stage),
                        format!("{at} evicts a never-stashed key"),
                    )),
                },
                OpKind::Load => match state.get(&key) {
                    Some(KeyState::Remote) => {
                        state.insert(key, KeyState::Resident);
                        resident += 1;
                    }
                    Some(KeyState::Resident) => diags.push(Diagnostic::error(
                        "double-stash",
                        Some(stage),
                        format!("{at} loads into an occupied slot"),
                    )),
                    Some(KeyState::Spent) | None => diags.push(Diagnostic::error(
                        "use-after-donate",
                        Some(stage),
                        format!("{at} loads a key the remote store never received"),
                    )),
                },
            }
            if resident > cap {
                diags.push(Diagnostic::error(
                    "stash-overflow",
                    Some(stage),
                    format!("{at} raises the resident count to {resident}, over capacity {cap}"),
                ));
            }
        }
        let leaked: Vec<String> = state
            .iter()
            .filter(|(_, &st)| st != KeyState::Spent)
            .map(|(&(mb, c), &st)| format!("mb{mb} c{c} ({st:?})"))
            .collect();
        if !leaked.is_empty() {
            let mut sorted = leaked;
            sorted.sort();
            diags.push(Diagnostic::error(
                "donation-leak",
                Some(stage),
                format!(
                    "{} handle(s) still live at end of step: {}",
                    sorted.len(),
                    sorted.join(", ")
                ),
            ));
        }
    }
    diags
}

/// Pass-2 entry point: capacities default to the planned per-stage
/// bound (`stage_bounds` / uniform BPipe bound) or, for un-rebalanced
/// schedules, the program's own high-water — the value `plan_schedule`
/// sizes the slot arrays with.
pub fn check_linearity(s: &Schedule) -> Vec<Diagnostic> {
    let caps: Vec<i64> = (0..s.p)
        .map(|st| match planned_cap(s, st) {
            Some(c) => c as i64,
            None => s.program(st).stash_high_water().max(1),
        })
        .collect();
    check_linearity_with_caps(s, &caps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bpipe::rebalance;
    use crate::schedule::{Family, Op, Placement, ScheduleKind, StageProgram};

    fn sched(ops: Vec<Op>) -> Schedule {
        Schedule {
            p: 1,
            m: 8,
            chunks: 1,
            placement: Placement::Sequential,
            kind: ScheduleKind::OneFOneB,
            stage_bounds: None,
            programs: vec![StageProgram { stage: 0, ops }],
        }
    }

    fn codes(ds: &[Diagnostic]) -> Vec<&'static str> {
        ds.iter().map(|d| d.code).collect()
    }

    #[test]
    fn generated_schedules_are_linear() {
        let fams = [
            Family::OneFOneB,
            Family::GPipe,
            Family::Interleaved { v: 2 },
            Family::VShaped,
            Family::ZigZag { v: 4 },
        ];
        for f in fams {
            let p = 8 / f.chunks();
            let base = f.build(p, 6);
            assert!(check_linearity(&base).is_empty(), "{f:?} base");
            let reb = rebalance(&base, None);
            assert!(check_linearity(&reb).is_empty(), "{f:?} rebalanced");
        }
    }

    #[test]
    fn double_donate_and_use_after_donate() {
        // Bwd twice on the same key: second one donates a spent handle
        let ds = check_linearity(&sched(vec![Op::fwd(0), Op::bwd(0), Op::bwd(0)]));
        assert!(codes(&ds).contains(&"double-donate"), "{ds:?}");
        // Bwd of an evicted key without a Load: reads a donated handle
        let ds = check_linearity(&sched(vec![Op::fwd(0), Op::evict(0), Op::bwd(0)]));
        assert!(codes(&ds).contains(&"use-after-donate"), "{ds:?}");
        // double evict
        let ds = check_linearity(&sched(vec![Op::fwd(0), Op::evict(0), Op::evict(0)]));
        assert!(codes(&ds).contains(&"double-donate"), "{ds:?}");
    }

    #[test]
    fn stash_misuse_variants() {
        let ds = check_linearity(&sched(vec![Op::fwd(0), Op::fwd(0)]));
        assert!(codes(&ds).contains(&"double-stash"), "{ds:?}");
        let ds = check_linearity(&sched(vec![Op::bwd(0)]));
        assert!(codes(&ds).contains(&"use-uninitialized"), "{ds:?}");
        let ds = check_linearity(&sched(vec![Op::fwd(0), Op::load(0)]));
        assert!(codes(&ds).contains(&"double-stash"), "{ds:?}");
        let ds = check_linearity(&sched(vec![Op::load(0)]));
        assert!(codes(&ds).contains(&"use-after-donate"), "{ds:?}");
        let mut s = sched(vec![Op::fwd(9), Op::bwd(9)]);
        s.m = 8;
        let ds = check_linearity(&s);
        assert!(codes(&ds).contains(&"slot-out-of-range"), "{ds:?}");
    }

    #[test]
    fn overflow_and_leak() {
        let ds = check_linearity_with_caps(
            &sched(vec![Op::fwd(0), Op::fwd(1), Op::fwd(2), Op::bwd(0), Op::bwd(1), Op::bwd(2)]),
            &[2],
        );
        assert!(codes(&ds).contains(&"stash-overflow"), "{ds:?}");
        // forward without a backward leaks its handle past end of step
        let ds = check_linearity(&sched(vec![Op::fwd(0), Op::fwd(1), Op::bwd(0)]));
        assert!(codes(&ds).contains(&"donation-leak"), "{ds:?}");
        // an evicted-but-never-retired key leaks in the remote store
        let ds = check_linearity(&sched(vec![Op::fwd(0), Op::evict(0)]));
        assert!(codes(&ds).contains(&"donation-leak"), "{ds:?}");
    }
}
