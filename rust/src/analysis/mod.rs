//! Static schedule/protocol analyzer (`bpipe check`): proves
//! deadlock-freedom, donation linearity, and memory bounds from the
//! schedule structure alone — before a single step runs.
//!
//! PR 5 turned the coordinator into a web of bounded channels,
//! busy-polled sends, handle-based stashes and donation masks, with
//! safety argued in prose.  This module machine-checks those arguments,
//! in the spirit of the paper's thesis that pipeline memory behavior is
//! *predictable from the schedule* (Eq. 3/4) — and gives the planned
//! schedule synthesizer (ROADMAP item 1) a fast run-free verifier to
//! reject unsound candidates.
//!
//! Three passes, each a module:
//!
//! | pass | module | proves | codes |
//! |------|--------|--------|-------|
//! | 1 | [`protocol`] | progress: the bounded-channel protocol derived from op order + placement routing completes (Kahn-network confluence makes one capacity-semantics run decisive) | `deadlock-cycle`, `fifo-mismatch`, `channel-residue` |
//! | 2 | [`linearity`] | every donated handle is spent exactly once, never read after donation; slot array never exceeded | `double-donate`, `use-after-donate`, `double-stash`, `use-uninitialized`, `stash-overflow`, `slot-out-of-range`, `donation-leak` |
//! | 3 | [`bounds`] | closed-form per-stage high-water bracket `[lo, hi]` (with `pred` matching the DES within one transient slot on pair-adjacent layouts); planned bounds hold; provable OOMs found without simulating | `static-bound-exceeded`, `provably-oom` |
//!
//! Structural validation ([`crate::schedule::validate`]) runs first and
//! is reported under the `invalid-schedule` code, so one `check_plan`
//! call subsumes the old gate.  `plan_schedule` rejects plans carrying
//! error-level findings, and `sim::sweep` (with
//! [`SweepOptions::skip_provable_oom`](crate::sim::sweep::SweepOptions))
//! uses pass 3 to skip provably-OOM grid cells before simulating them.

pub mod bounds;
pub mod diagnostics;
pub mod linearity;
pub mod protocol;

pub use bounds::{
    check_bounds, check_capacity, planned_cap, provably_oom_stage, static_bounds,
    static_peak_bytes, StageBoundEstimate,
};
pub use diagnostics::{
    diagnostics_to_json, has_errors, render_diagnostics, Diagnostic, Severity,
};
pub use linearity::{check_linearity, check_linearity_with_caps};
pub use protocol::{check_protocol, ChannelCaps, ProtocolModel, ProtocolRun};

use crate::coordinator::RebalancePlan;
use crate::schedule::{validate, Schedule};

/// Run every pass over a schedule: structural validation, protocol
/// progress, donation linearity, and static bounds.
pub fn check_schedule(s: &Schedule, caps: &ChannelCaps) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    if let Err(e) = validate(s) {
        diags.push(Diagnostic::error("invalid-schedule", None, e.to_string()));
    }
    diags.extend(check_protocol(s, caps));
    diags.extend(check_linearity(s));
    diags.extend(check_bounds(s));
    diags
}

/// Check a schedule under a concrete [`RebalancePlan`]: everything
/// [`check_schedule`] proves, plus — for capacity plans, which carry a
/// cluster — pass-3 provable-OOM verdicts against HBM.
pub fn check_plan(s: &Schedule, plan: &RebalancePlan, caps: &ChannelCaps) -> Vec<Diagnostic> {
    let mut diags = check_schedule(s, caps);
    if let RebalancePlan::Capacity { experiment } = plan {
        diags.extend(check_capacity(experiment, s));
    }
    diags
}

/// The go/no-go gate over [`check_plan`]: `Ok(warnings)` admits the
/// plan, `Err(diags)` rejects it on any error-level finding.  This is
/// the single entry point every plan must clear before it reaches the
/// channel web — initial planning (`plan_schedule`) and the
/// supervisor's re-plan-under-reduced-HBM path
/// ([`crate::coordinator::supervisor::replan_for_cap`]) both route
/// through it, so a recovery plan is held to exactly the same proof
/// obligations as a cold-start plan.
pub fn gate_plan(
    s: &Schedule,
    plan: &RebalancePlan,
    caps: &ChannelCaps,
) -> Result<Vec<Diagnostic>, Vec<Diagnostic>> {
    let diags = check_plan(s, plan, caps);
    if has_errors(&diags) {
        Err(diags)
    } else {
        Ok(diags)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bpipe::rebalance;
    use crate::schedule::Family;

    #[test]
    fn clean_plans_have_no_findings() {
        let caps = ChannelCaps::for_run(8, 1);
        let s = rebalance(&Family::OneFOneB.build(8, 8), None);
        let diags = check_plan(&s, &RebalancePlan::Uniform { bound: None }, &caps);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn invalid_schedules_surface_the_validator_error() {
        let mut s = Family::OneFOneB.build(4, 4);
        s.programs[2].ops.pop(); // drop stage 2's last backward
        let caps = ChannelCaps::for_run(4, 1);
        let diags = check_schedule(&s, &caps);
        assert!(
            diags.iter().any(|d| d.code == "invalid-schedule"),
            "{diags:?}"
        );
        // the dropped backward also starves the protocol and leaks a handle
        assert!(diags.iter().any(|d| d.code == "deadlock-cycle"), "{diags:?}");
        assert!(diags.iter().any(|d| d.code == "donation-leak"), "{diags:?}");
    }

    #[test]
    fn gate_plan_splits_go_from_no_go() {
        let caps = ChannelCaps::for_run(8, 1);
        let s = rebalance(&Family::OneFOneB.build(8, 8), None);
        assert!(gate_plan(&s, &RebalancePlan::Uniform { bound: None }, &caps).is_ok());

        let mut bad = Family::OneFOneB.build(4, 4);
        bad.programs[2].ops.pop();
        let caps4 = ChannelCaps::for_run(4, 1);
        let diags = gate_plan(&bad, &RebalancePlan::Uniform { bound: None }, &caps4)
            .expect_err("a broken schedule must not clear the gate");
        assert!(has_errors(&diags));
    }

    #[test]
    fn capacity_plans_carry_oom_verdicts() {
        let e = crate::config::paper_experiment(8).unwrap();
        let s = Family::OneFOneB.build(e.parallel.p, e.parallel.num_microbatches());
        let caps = ChannelCaps::for_run(s.m, s.chunks);
        let diags = check_plan(&s, &RebalancePlan::Capacity { experiment: e }, &caps);
        assert!(
            diags.iter().any(|d| d.code == "provably-oom" && d.stage == Some(0)),
            "{diags:?}"
        );
        // warnings don't gate
        assert!(!has_errors(&diags));
    }
}
