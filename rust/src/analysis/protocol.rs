//! Pass 1 — **deadlock/progress**: a static model of the coordinator's
//! channel protocol, checked under bounded-capacity semantics.
//!
//! The real coordinator ([`crate::coordinator`]) is a fixed set of
//! threads (feeder, one worker per stage, loss collector, one remote
//! store per evicting stage) joined by bounded SPSC channels whose
//! capacities come from [`ChannelCaps`].  Each thread's channel-op
//! sequence is fully determined by the [`Schedule`]'s op order and the
//! [`Placement`](crate::schedule::Placement) routing — no data-dependent
//! branching — so the system is a Kahn network with bounded FIFO links.
//! Such networks are **confluent**: whether any execution deadlocks (and
//! which sends/recvs are stuck when it does) is independent of the
//! interleaving, so ONE deterministic greedy run under capacity
//! semantics decides deadlock-freedom for ALL interleavings.  The
//! exhaustive p=2/m=2 interleaving test (`interleaving_protocol.rs`)
//! verifies this confluence claim dynamically on a small model.
//!
//! One step's analysis covers the whole run: every channel's per-step
//! send count equals its recv count (checked — residue is reported), so
//! the network returns to the empty marking after each step and the
//! wait-cycle structure is step-invariant.
//!
//! The feeder-recycle channel is deliberately absent from the model:
//! the worker side uses `try_send` with a local-pool fallback and the
//! feeder side uses `try_recv`, so that channel can never block either
//! endpoint.
//!
//! Diagnostic codes emitted here: `deadlock-cycle` (error — a wait-for
//! cycle, or a wait on a finished producer; the message names each
//! blocked thread, its op, and the channel), `fifo-mismatch` (error — a
//! receiver's expected microbatch differs from the channel's FIFO head,
//! which the runtime's `recv_expect` would panic on), and
//! `channel-residue` (warning — a channel left non-empty at the end of
//! the step, meaning send/recv counts drift across steps).

use std::collections::VecDeque;

use super::diagnostics::Diagnostic;
use crate::schedule::{OpKind, Schedule};

/// Capacities of the coordinator's bounded channels, mirroring the
/// values `train_inner` wires up.  Tests (and `bpipe check --hot-cap`)
/// can shrink them to probe where the protocol starts deadlocking.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChannelCaps {
    /// Per-boundary activation/gradient channel capacity (runtime: m+1).
    pub hot: usize,
    /// Token/target feed channel capacity (runtime: 2m).
    pub feed: usize,
    /// Loss channel capacity (runtime: 2m).
    pub loss: usize,
    /// Remote-store in-flight limit (runtime: m·chunks; the store's
    /// message channel holds one more than this).
    pub remote_inflight: usize,
}

impl ChannelCaps {
    /// The capacities the real coordinator runs with.
    pub fn for_run(m: u64, chunks: u64) -> Self {
        ChannelCaps {
            hot: (m + 1) as usize,
            feed: (2 * m) as usize,
            loss: (2 * m) as usize,
            remote_inflight: (m * chunks).max(1) as usize,
        }
    }
}

/// Send or receive on one channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    Send,
    Recv,
}

/// One channel operation in a thread's trace.  `expect` carries the
/// microbatch the runtime's `recv_expect` would assert on (None for the
/// collector, which accepts losses in arrival order).
#[derive(Debug, Clone)]
pub struct ChanOp {
    pub dir: Dir,
    pub chan: usize,
    /// Microbatch tag carried by a send / asserted by a recv.
    pub mb: u64,
    /// Whether the receiving side asserts the tag (worker `recv_expect`).
    pub expect: bool,
    /// Human label of the schedule op this belongs to, e.g. "Fwd mb1 c0".
    pub label: String,
}

/// One bounded SPSC channel.
#[derive(Debug, Clone)]
pub struct ChannelSpec {
    pub name: String,
    pub cap: usize,
    pub producer: usize,
    pub consumer: usize,
}

/// One thread's full channel-op trace for a step.
#[derive(Debug, Clone)]
pub struct ThreadTrace {
    pub name: String,
    pub ops: Vec<ChanOp>,
}

/// The protocol model: threads × channels, derived from a schedule.
#[derive(Debug, Clone)]
pub struct ProtocolModel {
    pub threads: Vec<ThreadTrace>,
    pub channels: Vec<ChannelSpec>,
}

impl ProtocolModel {
    /// Derive the thread/channel structure `train_inner` would build for
    /// this schedule, with the given capacities.
    pub fn build(s: &Schedule, caps: &ChannelCaps) -> ProtocolModel {
        let p = s.p;
        let vp = p * s.chunks.max(1);
        assert!(vp >= 2, "protocol model needs at least 2 virtual stages");
        let first_host = s.placement.host_stage(p, 0);
        let last_host = s.placement.host_stage(p, vp - 1);

        // thread indices: feeder, workers 0..p, collector, stores
        let feeder = 0usize;
        let worker = |st: u64| 1 + st as usize;
        let collector = 1 + p as usize;

        let mut channels: Vec<ChannelSpec> = Vec::new();
        let mut chan = |name: String, cap: usize, producer: usize, consumer: usize| -> usize {
            channels.push(ChannelSpec { name, cap: cap.max(1), producer, consumer });
            channels.len() - 1
        };

        // per-boundary activation/gradient channels
        let mut act = Vec::with_capacity((vp - 1) as usize);
        let mut grad = Vec::with_capacity((vp - 1) as usize);
        for d in 0..vp - 1 {
            let src = s.placement.host_stage(p, d);
            let dst = s.placement.host_stage(p, d + 1);
            act.push(chan(format!("act[d{d}] s{src}->s{dst}"), caps.hot, worker(src), worker(dst)));
            grad.push(chan(
                format!("grad[d{d}] s{dst}->s{src}"),
                caps.hot,
                worker(dst),
                worker(src),
            ));
        }
        let tok = chan(format!("tokens feeder->s{first_host}"), caps.feed, feeder, worker(first_host));
        let tgt = chan(format!("targets feeder->s{last_host}"), caps.feed, feeder, worker(last_host));
        let loss = chan(format!("loss s{last_host}->collector"), caps.loss, worker(last_host), collector);

        // remote-store message/response channels, only for stages that evict
        let mut store_of: Vec<Option<(usize, usize, usize)>> = vec![None; p as usize]; // (thread, msg, resp)
        let mut store_threads: Vec<(u64, ThreadTrace)> = Vec::new();
        for st in 0..p {
            let prog = s.program(st);
            if prog.ops.iter().any(|o| matches!(o.kind, OpKind::Evict | OpKind::Load)) {
                let thread = collector + 1 + store_threads.len();
                let msg = chan(format!("store-msg s{st}"), caps.remote_inflight + 1, worker(st), thread);
                let resp = chan(format!("store-resp s{st}"), 1, thread, worker(st));
                store_of[st as usize] = Some((thread, msg, resp));
                let mut ops = Vec::new();
                for op in &prog.ops {
                    let label = format!("{:?} mb{} c{}", op.kind, op.mb, op.chunk);
                    match op.kind {
                        OpKind::Evict => {
                            ops.push(ChanOp { dir: Dir::Recv, chan: msg, mb: op.mb, expect: true, label });
                        }
                        OpKind::Load => {
                            ops.push(ChanOp {
                                dir: Dir::Recv,
                                chan: msg,
                                mb: op.mb,
                                expect: true,
                                label: label.clone(),
                            });
                            ops.push(ChanOp { dir: Dir::Send, chan: resp, mb: op.mb, expect: true, label });
                        }
                        OpKind::Fwd | OpKind::Bwd => {}
                    }
                }
                store_threads.push((st, ThreadTrace { name: format!("store s{st}"), ops }));
            }
        }

        let mut threads = Vec::with_capacity(2 + p as usize + store_threads.len());
        // feeder: m tokens to the first host, m targets to the last host,
        // interleaved per microbatch exactly as `train_inner` sends them
        let mut fops = Vec::with_capacity(2 * s.m as usize);
        for mb in 0..s.m {
            fops.push(ChanOp { dir: Dir::Send, chan: tok, mb, expect: true, label: format!("feed mb{mb}") });
            fops.push(ChanOp { dir: Dir::Send, chan: tgt, mb, expect: true, label: format!("feed mb{mb}") });
        }
        threads.push(ThreadTrace { name: "feeder".into(), ops: fops });

        // workers: expand each schedule op into its channel ops in the
        // exact order `StageRunner::run_step` performs them
        for st in 0..p {
            let mut ops = Vec::new();
            for op in &s.program(st).ops {
                let virt = s.placement.virtual_stage(p, st, op.chunk);
                let label = format!("{:?} mb{} c{}", op.kind, op.mb, op.chunk);
                let mut push = |dir: Dir, chan: usize, expect: bool| {
                    ops.push(ChanOp { dir, chan, mb: op.mb, expect, label: label.clone() });
                };
                match op.kind {
                    OpKind::Fwd => {
                        if virt == 0 {
                            push(Dir::Recv, tok, true);
                        } else {
                            push(Dir::Recv, act[(virt - 1) as usize], true);
                        }
                        if virt == vp - 1 {
                            push(Dir::Recv, tgt, true);
                        } else {
                            push(Dir::Send, act[virt as usize], true);
                        }
                    }
                    OpKind::Bwd => {
                        if virt < vp - 1 {
                            push(Dir::Recv, grad[virt as usize], true);
                        }
                        if virt > 0 {
                            push(Dir::Send, grad[(virt - 1) as usize], true);
                        }
                        if virt == vp - 1 {
                            push(Dir::Send, loss, true);
                        }
                    }
                    OpKind::Evict => {
                        let (_, msg, _) = store_of[st as usize].expect("evict without store");
                        push(Dir::Send, msg, true);
                    }
                    OpKind::Load => {
                        let (_, msg, resp) = store_of[st as usize].expect("load without store");
                        push(Dir::Send, msg, true);
                        push(Dir::Recv, resp, true);
                    }
                }
            }
            threads.push(ThreadTrace { name: format!("stage {st}"), ops });
        }

        // collector: one loss per microbatch, any order
        let cops = (0..s.m)
            .map(|mb| ChanOp {
                dir: Dir::Recv,
                chan: loss,
                mb,
                expect: false,
                label: format!("collect loss #{mb}"),
            })
            .collect();
        threads.push(ThreadTrace { name: "collector".into(), ops: cops });
        threads.extend(store_threads.into_iter().map(|(_, t)| t));

        ProtocolModel { threads, channels }
    }
}

/// Executable state of a [`ProtocolModel`] under capacity semantics.
/// Clonable and hashable-by-parts so the exhaustive interleaving test
/// can DFS over it; [`ProtocolRun::run`] is the greedy single run the
/// analyzer uses (sufficient by confluence, see module docs).
#[derive(Debug, Clone)]
pub struct ProtocolRun<'m> {
    model: &'m ProtocolModel,
    pc: Vec<usize>,
    queues: Vec<VecDeque<u64>>,
    fifo_flagged: Vec<bool>,
    pub diagnostics: Vec<Diagnostic>,
}

impl<'m> ProtocolRun<'m> {
    pub fn new(model: &'m ProtocolModel) -> Self {
        ProtocolRun {
            model,
            pc: vec![0; model.threads.len()],
            queues: model.channels.iter().map(|_| VecDeque::new()).collect(),
            fifo_flagged: vec![false; model.channels.len()],
            diagnostics: Vec::new(),
        }
    }

    pub fn num_threads(&self) -> usize {
        self.model.threads.len()
    }

    pub fn thread_finished(&self, t: usize) -> bool {
        self.pc[t] >= self.model.threads[t].ops.len()
    }

    pub fn all_finished(&self) -> bool {
        (0..self.num_threads()).all(|t| self.thread_finished(t))
    }

    /// The DFS memo key: program counters plus channel contents.
    pub fn state(&self) -> (Vec<usize>, Vec<Vec<u64>>) {
        (
            self.pc.clone(),
            self.queues.iter().map(|q| q.iter().copied().collect()).collect(),
        )
    }

    /// Can thread `t` perform its next channel op right now?
    pub fn enabled(&self, t: usize) -> bool {
        let trace = &self.model.threads[t];
        match trace.ops.get(self.pc[t]) {
            None => false,
            Some(op) => match op.dir {
                Dir::Send => self.queues[op.chan].len() < self.model.channels[op.chan].cap,
                Dir::Recv => !self.queues[op.chan].is_empty(),
            },
        }
    }

    /// Perform thread `t`'s next channel op.  Returns false if it was
    /// not enabled.  FIFO mismatches are recorded as diagnostics (once
    /// per channel) and execution continues past them.
    pub fn step(&mut self, t: usize) -> bool {
        if !self.enabled(t) {
            return false;
        }
        let op = &self.model.threads[t].ops[self.pc[t]];
        match op.dir {
            Dir::Send => self.queues[op.chan].push_back(op.mb),
            Dir::Recv => {
                let got = self.queues[op.chan].pop_front().expect("enabled recv");
                if op.expect && got != op.mb && !self.fifo_flagged[op.chan] {
                    self.fifo_flagged[op.chan] = true;
                    self.diagnostics.push(Diagnostic::error(
                        "fifo-mismatch",
                        None,
                        format!(
                            "{} at {} expects mb{} on {} but the FIFO head is mb{got}",
                            self.model.threads[t].name,
                            op.label,
                            op.mb,
                            self.model.channels[op.chan].name,
                        ),
                    ));
                }
            }
        }
        self.pc[t] += 1;
        true
    }

    /// Where thread `t` is stuck: "(thread) blocked (dir) (channel) at (op)".
    fn wait_description(&self, t: usize) -> String {
        let op = &self.model.threads[t].ops[self.pc[t]];
        let ch = &self.model.channels[op.chan];
        match op.dir {
            Dir::Send => format!(
                "{} blocked sending {} (cap {} full) at {}",
                self.model.threads[t].name, ch.name, ch.cap, op.label
            ),
            Dir::Recv => format!(
                "{} blocked receiving {} (empty) at {}",
                self.model.threads[t].name, ch.name, op.label
            ),
        }
    }

    /// The thread a stuck thread `t` is waiting on.
    fn waits_on(&self, t: usize) -> usize {
        let op = &self.model.threads[t].ops[self.pc[t]];
        let ch = &self.model.channels[op.chan];
        match op.dir {
            Dir::Send => ch.consumer,
            Dir::Recv => ch.producer,
        }
    }

    /// Greedy run to completion or to a stuck state.  Appends
    /// diagnostics for any deadlock (wait-for cycle or starved wait on a
    /// finished producer) and any end-of-step channel residue, then
    /// returns the collected findings.
    pub fn run(&mut self) -> Vec<Diagnostic> {
        loop {
            let mut progressed = false;
            for t in 0..self.num_threads() {
                while self.step(t) {
                    progressed = true;
                }
            }
            if self.all_finished() {
                break;
            }
            if !progressed {
                self.report_stuck();
                break;
            }
        }
        for (i, q) in self.queues.iter().enumerate() {
            if !q.is_empty() {
                self.diagnostics.push(Diagnostic::warning(
                    "channel-residue",
                    None,
                    format!(
                        "{} holds {} undelivered message(s) at end of step — \
                         send/recv counts drift across steps",
                        self.model.channels[i].name,
                        q.len()
                    ),
                ));
            }
        }
        std::mem::take(&mut self.diagnostics)
    }

    /// Follow wait-for edges from a stuck thread until the walk closes a
    /// cycle or lands on a finished producer, and report the chain.
    fn report_stuck(&mut self) {
        let start = (0..self.num_threads())
            .find(|&t| !self.thread_finished(t))
            .expect("stuck run has an unfinished thread");
        let mut path: Vec<usize> = Vec::new();
        let mut t = start;
        let message = loop {
            if self.thread_finished(t) {
                let chain: Vec<String> =
                    path.iter().map(|&x| self.wait_description(x)).collect();
                break format!(
                    "progress failure: {} — but {} has already finished its step",
                    chain.join("; which waits on "),
                    self.model.threads[t].name
                );
            }
            if let Some(pos) = path.iter().position(|&x| x == t) {
                let cycle: Vec<String> =
                    path[pos..].iter().map(|&x| self.wait_description(x)).collect();
                break format!("wait-for cycle: {}", cycle.join("; which waits on "));
            }
            path.push(t);
            t = self.waits_on(t);
        };
        self.diagnostics.push(Diagnostic::error("deadlock-cycle", None, message));
    }
}

/// Pass-1 entry point: model the protocol and decide progress.
pub fn check_protocol(s: &Schedule, caps: &ChannelCaps) -> Vec<Diagnostic> {
    if s.p * s.chunks.max(1) < 2 {
        return Vec::new(); // a single virtual stage has no channel protocol
    }
    let model = ProtocolModel::build(s, caps);
    ProtocolRun::new(&model).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bpipe::rebalance;
    use crate::schedule::{Family, Op, Schedule, ScheduleKind, StageProgram};

    fn families() -> Vec<Family> {
        vec![
            Family::OneFOneB,
            Family::GPipe,
            Family::Interleaved { v: 2 },
            Family::VShaped,
            Family::ZigZag { v: 4 },
        ]
    }

    #[test]
    fn run_capacities_are_deadlock_free_for_every_family() {
        for f in families() {
            let p = 8 / f.chunks();
            for s in [f.build(p, 4), rebalance(&f.build(p, 4), None)] {
                let caps = ChannelCaps::for_run(s.m, s.chunks);
                let diags = check_protocol(&s, &caps);
                assert!(diags.is_empty(), "{f:?}: {diags:?}");
            }
        }
    }

    #[test]
    fn undersized_hot_cap_deadlocks_the_zigzag_junction() {
        // stage 1 hosts both sides of the d1 boundary in the V shape; at
        // cap 1 its second chunk-0 forward blocks sending to itself
        let s = Family::VShaped.build(2, 4);
        let caps = ChannelCaps { hot: 1, ..ChannelCaps::for_run(s.m, s.chunks) };
        let diags = check_protocol(&s, &caps);
        let dead: Vec<_> = diags.iter().filter(|d| d.code == "deadlock-cycle").collect();
        assert_eq!(dead.len(), 1, "{diags:?}");
        assert!(
            dead[0].message.contains("act[d1]") && dead[0].message.contains("stage 1"),
            "cycle must name the stuck channel and thread: {}",
            dead[0].message
        );
    }

    #[test]
    fn starved_wait_on_a_finished_producer_is_reported() {
        // stage 1 never runs its backward, so stage 0's grad recv starves
        let s = Schedule {
            p: 2,
            m: 1,
            chunks: 1,
            placement: crate::schedule::Placement::Sequential,
            kind: ScheduleKind::OneFOneB,
            stage_bounds: None,
            programs: vec![
                StageProgram { stage: 0, ops: vec![Op::fwd(0), Op::bwd(0)] },
                StageProgram { stage: 1, ops: vec![Op::fwd(0)] },
            ],
        };
        let diags = check_protocol(&s, &ChannelCaps::for_run(1, 1));
        assert!(
            diags.iter().any(|d| d.code == "deadlock-cycle" && d.message.contains("finished")),
            "{diags:?}"
        );
    }

    #[test]
    fn out_of_order_forwards_flag_fifo_mismatch() {
        // stage 1 expects mb1 first, but stage 0 sends mb0 first
        let s = Schedule {
            p: 2,
            m: 2,
            chunks: 1,
            placement: crate::schedule::Placement::Sequential,
            kind: ScheduleKind::OneFOneB,
            stage_bounds: None,
            programs: vec![
                StageProgram {
                    stage: 0,
                    ops: vec![Op::fwd(0), Op::fwd(1), Op::bwd(1), Op::bwd(0)],
                },
                StageProgram {
                    stage: 1,
                    ops: vec![Op::fwd(1), Op::fwd(0), Op::bwd(1), Op::bwd(0)],
                },
            ],
        };
        let diags = check_protocol(&s, &ChannelCaps::for_run(2, 1));
        assert!(
            diags.iter().any(|d| d.code == "fifo-mismatch" && d.message.contains("act[d0]")),
            "{diags:?}"
        );
    }
}
