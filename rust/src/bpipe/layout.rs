//! Device placement of pipeline stages — paper Figure 2.
//!
//! BPipe's evict/load traffic rides the evictor↔acceptor link.  If the
//! pair lives inside one node it uses NVLink (~300 GB/s) and hides under
//! compute; across nodes it shares InfiniBand (~25 GB/s per GPU) and may
//! not.  The **pair-adjacent** assignment places stages so every
//! (x, p−1−x) pair is intra-node: node `k` hosts the k-th quarter of
//! stages from the *front* of the pipeline and the k-th quarter from the
//! *back* (Figure 2's 16-way/2-node example: node 0 = {0..3, 12..15},
//! node 1 = {4..11}).

use super::pairing::partner;

/// A stage → node assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layout {
    /// `node_of[stage]` = node index hosting that stage's devices.
    pub node_of: Vec<u64>,
    pub n_nodes: u64,
    pub name: &'static str,
}

impl Layout {
    pub fn node_of(&self, stage: u64) -> u64 {
        self.node_of[stage as usize]
    }

    /// Is the (stage, partner) pair intra-node?
    pub fn pair_intra_node(&self, p: u64, stage: u64) -> bool {
        self.node_of(stage) == self.node_of(partner(p, stage))
    }

    /// Fraction of evictor/acceptor pairs that stay on-node.
    pub fn intra_node_pair_fraction(&self, p: u64) -> f64 {
        let pairs = p / 2;
        if pairs == 0 {
            return 1.0;
        }
        let ok = (0..pairs).filter(|&x| self.pair_intra_node(p, x)).count();
        ok as f64 / pairs as f64
    }

    /// Stages hosted per node (for capacity checks / pretty-printing).
    pub fn stages_per_node(&self) -> Vec<Vec<u64>> {
        let mut out = vec![Vec::new(); self.n_nodes as usize];
        for (stage, &node) in self.node_of.iter().enumerate() {
            out[node as usize].push(stage as u64);
        }
        out
    }
}

/// Naive sequential layout: stage `x` → node `x / (p / n_nodes)`.
/// Pairs span nodes as soon as `n_nodes > 1`.
pub fn sequential_layout(p: u64, n_nodes: u64) -> Layout {
    assert!(p % n_nodes == 0, "p ({p}) must divide across nodes ({n_nodes})");
    let per = p / n_nodes;
    Layout {
        node_of: (0..p).map(|x| x / per).collect(),
        n_nodes,
        name: "sequential",
    }
}

/// Pair-adjacent layout (paper Figure 2): node `k` hosts the k-th slice
/// of `per/2` stages from the front AND the matching slice from the back,
/// so every (x, p−1−x) pair is intra-node.
pub fn pair_adjacent_layout(p: u64, n_nodes: u64) -> Layout {
    assert!(p % n_nodes == 0, "p ({p}) must divide across nodes ({n_nodes})");
    let per = p / n_nodes;
    assert!(per % 2 == 0 || n_nodes == 1, "need an even number of stages per node");
    let mut node_of = vec![0u64; p as usize];
    if n_nodes == 1 {
        return Layout { node_of, n_nodes, name: "pair-adjacent" };
    }
    let half = per / 2;
    for k in 0..n_nodes {
        for i in 0..half {
            let front = k * half + i;
            node_of[front as usize] = k;
            node_of[partner(p, front) as usize] = k;
        }
    }
    Layout { node_of, n_nodes, name: "pair-adjacent" }
}

/// Scatter layout: stage `x` → node `x % n_nodes` (round-robin).  The
/// classic "spread for compute balance" placement — it maximises
/// cross-node traffic, since consecutive stages (and, for even
/// `n_nodes`, every evictor/acceptor pair) land on different nodes.
/// The adversarial end of the sweep grid's layout axis.
pub fn scatter_layout(p: u64, n_nodes: u64) -> Layout {
    assert!(p % n_nodes == 0, "p ({p}) must divide across nodes ({n_nodes})");
    Layout {
        node_of: (0..p).map(|x| x % n_nodes).collect(),
        n_nodes,
        name: "scatter",
    }
}

/// Ring layout: the front half of the pipeline is laid out in
/// sequential blocks, and each back-half stage lands one node
/// *clockwise* of its pair partner — evict/load traffic hops exactly one
/// ring link instead of converging on a single boundary.  Every pair is
/// inter-node (for `n_nodes > 1`) but the pair traffic is spread evenly
/// over the ring rather than funneled like `sequential`.
pub fn ring_layout(p: u64, n_nodes: u64) -> Layout {
    assert!(p % n_nodes == 0, "p ({p}) must divide across nodes ({n_nodes})");
    assert!(
        n_nodes == 1 || (p / 2) % n_nodes == 0,
        "front half ({}) must divide across nodes ({n_nodes})",
        p / 2
    );
    if n_nodes == 1 {
        return Layout { node_of: vec![0; p as usize], n_nodes, name: "ring" };
    }
    let per_front = (p / 2) / n_nodes;
    let mut node_of = vec![0u64; p as usize];
    for x in 0..p / 2 {
        node_of[x as usize] = x / per_front;
    }
    for x in p / 2..p {
        node_of[x as usize] = (node_of[partner(p, x) as usize] + 1) % n_nodes;
    }
    Layout { node_of, n_nodes, name: "ring" }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure2_sixteen_way_two_nodes() {
        // paper Figure 2: p=16 on 2 × 8-GPU nodes
        let l = pair_adjacent_layout(16, 2);
        assert_eq!(l.stages_per_node()[0], vec![0, 1, 2, 3, 12, 13, 14, 15]);
        assert_eq!(l.stages_per_node()[1], vec![4, 5, 6, 7, 8, 9, 10, 11]);
        assert_eq!(l.intra_node_pair_fraction(16), 1.0);
    }

    #[test]
    fn sequential_breaks_pairs() {
        let l = sequential_layout(16, 2);
        // every pair (x, 15−x) spans the node boundary
        assert_eq!(l.intra_node_pair_fraction(16), 0.0);
    }

    #[test]
    fn pair_adjacent_always_intra_node() {
        for (p, n) in [(8u64, 2u64), (8, 4), (16, 2), (16, 4), (32, 4)] {
            let l = pair_adjacent_layout(p, n);
            assert_eq!(l.intra_node_pair_fraction(p), 1.0, "p={p} n={n}");
            // every node hosts exactly p/n stages
            for stages in l.stages_per_node() {
                assert_eq!(stages.len() as u64, p / n);
            }
        }
    }

    #[test]
    fn single_node_trivially_adjacent() {
        let l = pair_adjacent_layout(8, 1);
        assert_eq!(l.intra_node_pair_fraction(8), 1.0);
        let l = sequential_layout(8, 1);
        assert_eq!(l.intra_node_pair_fraction(8), 1.0);
    }

    #[test]
    fn paper_config_p8_four_nodes() {
        // the paper's main runs: t=4, p=8 on 4 nodes → 2 stages/node
        let l = pair_adjacent_layout(8, 4);
        assert_eq!(l.intra_node_pair_fraction(8), 1.0);
        let seq = sequential_layout(8, 4);
        assert!(seq.intra_node_pair_fraction(8) < 1.0);
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn rejects_indivisible() {
        sequential_layout(10, 4);
    }

    #[test]
    fn scatter_round_robins() {
        let l = scatter_layout(8, 2);
        assert_eq!(l.node_of, vec![0, 1, 0, 1, 0, 1, 0, 1]);
        // 7−x flips parity, so every pair spans nodes
        assert_eq!(l.intra_node_pair_fraction(8), 0.0);
        for stages in l.stages_per_node() {
            assert_eq!(stages.len(), 4);
        }
    }

    #[test]
    fn ring_spreads_pairs_one_hop() {
        let l = ring_layout(8, 2);
        assert_eq!(l.node_of, vec![0, 0, 1, 1, 0, 0, 1, 1]);
        assert_eq!(l.intra_node_pair_fraction(8), 0.0);
        // every back-half stage is exactly one node clockwise of its pair
        for x in 4..8u64 {
            assert_eq!(l.node_of(x), (l.node_of(partner(8, x)) + 1) % 2);
        }
        for stages in l.stages_per_node() {
            assert_eq!(stages.len(), 4);
        }
    }

    #[test]
    fn ring_balanced_four_nodes() {
        let l = ring_layout(8, 4);
        assert_eq!(l.node_of, vec![0, 1, 2, 3, 0, 3, 2, 1]);
        for stages in l.stages_per_node() {
            assert_eq!(stages.len(), 2);
        }
    }

    #[test]
    fn scatter_and_ring_single_node() {
        assert_eq!(scatter_layout(8, 1).intra_node_pair_fraction(8), 1.0);
        assert_eq!(ring_layout(8, 1).intra_node_pair_fraction(8), 1.0);
    }
}
