//! BPipe — memory-balanced pipeline parallelism (Kim et al. ICML'23,
//! re-evaluated by the reproduced paper).
//!
//! Plain 1F1B leaves stage `x` holding `p − x` activation stashes.  BPipe
//! pairs stage `x` (the **evictor**) with stage `p − 1 − x` (the
//! **acceptor**): whenever the evictor's stash count is about to exceed
//! `⌈(p+2)/2⌉`, it ships a stash to the acceptor, and loads it back in
//! time for that microbatch's backward pass (paper §2.2, Figure 1).
//!
//! * [`pairing`] — the evictor/acceptor relation and per-stage bounds;
//! * [`rebalance()`] — the schedule-agnostic transform inserting Evict/Load
//!   ops into ANY schedule, keyed by `(mb, chunk)` — composes with
//!   interleaved and V-shaped bases;
//! * [`apply_bpipe`] — the paper's 1F1B-specific wrapper around
//!   [`rebalance()`] with the `⌈(p+2)/2⌉` bound;
//! * [`layout`] — pair-adjacent device placement so every pair stays
//!   inside one NVLink island (paper Figure 2).

pub mod layout;
pub mod pairing;
pub mod rebalance;

pub use layout::{pair_adjacent_layout, ring_layout, scatter_layout, sequential_layout, Layout};
pub use pairing::{acceptor_extra_stashes, bound, evictions_at, is_acceptor, is_evictor, partner};
pub use rebalance::{
    bound_range, capacity_stage_bounds, derived_bound, rebalance, rebalance_bounded,
    RebalanceWorkspace,
};

use crate::schedule::{Schedule, ScheduleKind};

/// Transform a 1F1B schedule into the paper's BPipe schedule by
/// inserting Evict/Load ops on evictor stages — a thin wrapper over the
/// schedule-agnostic [`rebalance()`] pass that pins the paper's bound.
///
/// `bound` defaults to [`pairing::bound`]`(p)` (= `⌈(p+2)/2⌉`); tests
/// inject tighter bounds to probe edge cases.  For non-1F1B bases call
/// [`rebalance()`] directly.
pub fn apply_bpipe(base: &Schedule, bound_override: Option<u64>) -> Schedule {
    assert_eq!(
        base.kind,
        ScheduleKind::OneFOneB,
        "BPipe applies to the 1F1B schedule (paper §2.2); use rebalance() for other bases"
    );
    let k = bound_override.unwrap_or_else(|| pairing::bound(base.p));
    rebalance(base, Some(k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{one_f_one_b, validate, OpKind};

    #[test]
    fn bounds_every_stage() {
        let base = one_f_one_b(8, 64);
        let bp = apply_bpipe(&base, None);
        validate(&bp).unwrap();
        for s in 0..8 {
            assert!(bp.program(s).stash_high_water() <= pairing::bound(8) as i64);
        }
    }

    #[test]
    fn eviction_counts_match_pairing_formula() {
        let (p, m) = (8, 64);
        let bp = apply_bpipe(&one_f_one_b(p, m), None);
        for s in 0..p {
            let expect = pairing::evictions_at(p, s, m);
            assert_eq!(bp.count(s, OpKind::Evict) as u64, expect, "stage {s}");
            assert_eq!(bp.count(s, OpKind::Load) as u64, expect, "stage {s}");
        }
    }

    #[test]
    fn paper_figure1_shape_p4() {
        // Figure 1: 4-way 1F1B; bound = ceil(6/2) = 3; only stage 0
        // (natural in-flight 4) evicts.
        let bp = apply_bpipe(&one_f_one_b(4, 8), None);
        assert!(bp.count(0, OpKind::Evict) > 0);
        for s in 1..4 {
            assert_eq!(bp.count(s, OpKind::Evict), 0, "stage {s} must not evict");
        }
    }

    #[test]
    fn load_precedes_its_bwd() {
        let bp = apply_bpipe(&one_f_one_b(8, 16), None);
        for prog in &bp.programs {
            for (i, op) in prog.ops.iter().enumerate() {
                if op.kind == OpKind::Bwd {
                    // if this mb was evicted, a Load must appear before
                    let evict_pos =
                        prog.ops.iter().position(|o| o.kind == OpKind::Evict && o.mb == op.mb);
                    if let Some(e) = evict_pos {
                        let load_pos = prog
                            .ops
                            .iter()
                            .position(|o| o.kind == OpKind::Load && o.mb == op.mb)
                            .expect("evicted mb never loaded");
                        assert!(e < load_pos && load_pos < i);
                    }
                }
            }
        }
    }

    #[test]
    fn no_eviction_when_m_small() {
        // m ≤ bound: nothing ever exceeds the cap
        let bp = apply_bpipe(&one_f_one_b(8, 4), None);
        for s in 0..8 {
            assert_eq!(bp.count(s, OpKind::Evict), 0);
        }
    }

    #[test]
    fn tighter_override_bound() {
        let bp = apply_bpipe(&one_f_one_b(8, 32), Some(3));
        validate(&bp).unwrap();
        for s in 0..8 {
            assert!(bp.program(s).stash_high_water() <= 3);
        }
    }

    #[test]
    #[should_panic(expected = "1F1B")]
    fn rejects_non_1f1b_base() {
        apply_bpipe(&crate::schedule::gpipe(4, 8), None);
    }
}
