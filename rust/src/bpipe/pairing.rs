//! Evictor/acceptor pairing arithmetic (paper §2.2).
//!
//! Stage `x` pairs with stage `p − 1 − x`; the pairing is an involution.
//! Stages in the front half whose natural 1F1B stash count `p − x`
//! exceeds the bound `⌈(p+2)/2⌉` are evictors; their partners accept.

/// The BPipe per-device stash bound, `⌈(p+2)/2⌉`.
pub fn bound(p: u64) -> u64 {
    crate::model::memory::bpipe_bound(p)
}

/// The paired stage: `p − 1 − x`.
pub fn partner(p: u64, stage: u64) -> u64 {
    assert!(stage < p);
    p - 1 - stage
}

/// Does `stage` evict under BPipe with `m` microbatches?
/// True iff its natural 1F1B stash count `min(m, p − x)` exceeds the bound.
pub fn is_evictor(p: u64, stage: u64, m: u64) -> bool {
    crate::model::memory::one_f_one_b_in_flight(p, stage, m) > bound(p)
}

/// Does `stage` accept a partner's evictions?
pub fn is_acceptor(p: u64, stage: u64, m: u64) -> bool {
    is_evictor(p, partner(p, stage), m)
}

/// How many stashes stage `x` must evict over one iteration — the count
/// of forwards that would push it past the bound.  Under 1F1B every
/// forward beyond the first `bound` ones (while backwards haven't caught
/// up) triggers exactly one eviction; in steady state each (Fwd, Bwd)
/// pair cycles one (Evict, Load).  Total = `m − bound` clipped at 0 when
/// the stage's warmup never reaches the bound.
pub fn evictions_at(p: u64, stage: u64, m: u64) -> u64 {
    let natural = crate::model::memory::one_f_one_b_in_flight(p, stage, m);
    let k = bound(p);
    if natural <= k {
        0
    } else {
        // every fwd after the k-th and before the last (natural − k)
        // backwards have retired pushes one stash out
        m - k
    }
}

/// Extra stashes the acceptor holds at its peak: its partner's overflow,
/// `max(0, min(m, p − x) − bound)` — at most `⌊(p−2)/2⌋`, keeping the
/// acceptor itself at ≤ the bound (the balancing theorem of §2.2).
pub fn acceptor_extra_stashes(p: u64, stage: u64, m: u64) -> u64 {
    let partner_natural =
        crate::model::memory::one_f_one_b_in_flight(p, partner(p, stage), m);
    partner_natural.saturating_sub(bound(p))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairing_is_involution() {
        for p in [2u64, 4, 8, 16] {
            for x in 0..p {
                assert_eq!(partner(p, partner(p, x)), x);
            }
        }
    }

    #[test]
    fn evictors_are_front_half() {
        let (p, m) = (8, 64);
        for x in 0..p {
            if is_evictor(p, x, m) {
                assert!(x < p / 2, "evictor {x} must be in the front half");
                assert!(is_acceptor(p, partner(p, x), m));
            }
        }
        // p=8: bound 5; stages 0,1,2 have natural 8,7,6 > 5 → evictors
        assert!(is_evictor(8, 0, 64) && is_evictor(8, 2, 64));
        assert!(!is_evictor(8, 3, 64)); // natural 5 == bound
    }

    #[test]
    fn acceptor_total_never_exceeds_bound() {
        for p in [4u64, 8, 16] {
            let m = 4 * p;
            for x in 0..p {
                let own = crate::model::memory::one_f_one_b_in_flight(p, x, m);
                let extra = acceptor_extra_stashes(p, x, m);
                if own <= bound(p) {
                    assert!(own + extra <= bound(p), "p={p} stage {x}: {own}+{extra}");
                }
            }
        }
    }

    #[test]
    fn no_evictions_for_tiny_m() {
        for x in 0..8 {
            assert_eq!(evictions_at(8, x, 3), 0);
        }
    }

    #[test]
    fn eviction_count_example() {
        // p=8, m=64, bound=5: stage 0 evicts m−5 = 59 stashes over the run
        assert_eq!(evictions_at(8, 0, 64), 59);
        assert_eq!(evictions_at(8, 3, 64), 0);
    }
}
