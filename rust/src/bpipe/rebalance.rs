//! The schedule-agnostic memory rebalancing transform — BPipe's
//! evict/load insertion generalized beyond 1F1B.
//!
//! [`rebalance`] takes ANY valid schedule (1F1B, GPipe, interleaved,
//! V-shaped) and inserts Evict/Load ops so every stage's own resident
//! stash count never exceeds a bound, at every op boundary.  All state is
//! keyed by `(mb, chunk)`, so virtual-pipeline chunks are first-class.
//!
//! Policy (the paper's §2.2 "about to exceed" rule, generalized):
//!
//! * **pre-evict** — immediately before a forward that would push the
//!   resident set past the bound, evict the resident stash whose backward
//!   lies *furthest in program order* (the classic Belady victim; for
//!   1F1B this is the newest microbatch, reproducing `apply_bpipe`'s
//!   output op-for-op).  The transfer overlaps that forward's compute;
//! * **prefetch-load** — after a backward frees a slot, load back the
//!   evicted stash needed *soonest*, which always lands before its own
//!   backward.  A prefetched stash may be re-evicted under later
//!   pressure; the validator and simulator both support repeated
//!   Evict→Load cycles per key.
//!
//! ## Choosing the bound
//!
//! With no override, [`derived_bound`] balances each evictor/acceptor
//! pair `(x, p−1−x)` to its mean residency and takes the max over pairs:
//! `max_x ⌈(hw_x + hw_{p−1−x}) / 2⌉`.  For 1F1B with even `p` this is
//! exactly the paper's `⌈(p+2)/2⌉`; for interleaved schedules (whose
//! high-water ramps from `~2pv/…` at stage 0 down the pipe) it is the
//! unique uniform bound that flattens every pair without forcing the two
//! sides of a pair to evict into each other simultaneously.
//!
//! ## Per-stage (non-uniform) bounds
//!
//! A uniform bound ignores that stages have different *headroom*: stage
//! 0 carries the embedding, stage `p−1` the LM head, so the stash budget
//! that actually fits differs per device (the SlimPipe observation).
//! [`rebalance_bounded`] runs the same transform with an independent
//! bound per stage, and [`capacity_stage_bounds`] derives the natural
//! non-uniform vector from an experiment's memory model: the largest
//! resident count whose conservative DES peak (high-water + 1 transient
//! slot) still fits in HBM, clamped to `[2, natural high-water]`.
//! Stages that naturally fit keep their natural bound and never evict —
//! on paper experiment (8) this rescues 1F1B with ~34% less transfer
//! traffic than the uniform derived bound (117 vs 177 evictions).

use super::pairing;
use crate::config::ExperimentConfig;
use crate::model::memory::MemoryModel;
use crate::schedule::{Op, OpKind, Schedule, ScheduleKind, StageProgram};

/// Default bound for [`rebalance`]: balance every `(x, p−1−x)` pair to
/// its mean stash high-water, `max_x ⌈(hw_x + hw_{p−1−x}) / 2⌉` (≥ 2).
/// Reduces to the paper's `⌈(p+2)/2⌉` for 1F1B with even `p`.
pub fn derived_bound(base: &Schedule) -> u64 {
    let p = base.p;
    let hw: Vec<i64> = (0..p).map(|s| base.program(s).stash_high_water()).collect();
    let k = (0..p)
        .map(|x| {
            let px = pairing::partner(p, x);
            let sum = (hw[x as usize] + hw[px as usize]) as u64;
            sum.div_ceil(2)
        })
        .max()
        .unwrap_or(2);
    k.max(2)
}

/// The feasible rebalance-bound range for a base schedule: from the
/// derived pair-mean value down to 2 (one live + one incoming stash, the
/// tightest the transform admits).  The sweep's bound-sensitivity grid
/// walks this range high→low to trace the memory/stall frontier.
pub fn bound_range(base: &Schedule) -> std::ops::RangeInclusive<u64> {
    2..=derived_bound(base)
}

/// Rebalance `base` so every stage's own resident stash count stays ≤
/// the bound at every op boundary, by inserting Evict/Load transfer ops
/// keyed by `(mb, chunk)`.  `bound_override` defaults to
/// [`derived_bound`]`(base)`.
///
/// The base must be transfer-free (no Evict/Load); the result carries
/// `ScheduleKind::BPipe { bound }` so [`crate::schedule::validate`]
/// enforces the bound, and inherits the base's `chunks`/`placement` so
/// the simulator keeps the right dataflow.
pub fn rebalance(base: &Schedule, bound_override: Option<u64>) -> Schedule {
    RebalanceWorkspace::new().rebalance(base, bound_override)
}

/// Rebalance `base` with an independent bound per stage (non-uniform
/// BPipe): stage `s`'s own resident stash count stays ≤ `bounds[s]`.
/// The result carries `ScheduleKind::BPipe { bound: max(bounds) }` plus
/// `stage_bounds: Some(bounds)` so the validator enforces every stage's
/// own cap, not just the uniform ceiling.
pub fn rebalance_bounded(base: &Schedule, bounds: &[u64]) -> Schedule {
    RebalanceWorkspace::new().rebalance_bounded(base, bounds)
}

/// Capacity-aware per-stage bounds for `base` on experiment `e`'s
/// cluster: per stage, the largest resident stash count whose
/// conservative DES peak (one extra transient slot from the
/// load-overlaps-retire accounting) still fits in HBM after weights,
/// optimizer state and the reserved pool — clamped to
/// `[2, natural high-water]`, so stages that already fit keep their
/// natural bound (and the transform leaves them untouched).
pub fn capacity_stage_bounds(e: &ExperimentConfig, base: &Schedule) -> Vec<u64> {
    let mm = MemoryModel::new(e);
    let chunks = base.chunks.max(1);
    let act = mm.activation_bytes_per_microbatch(0) / chunks;
    (0..base.p)
        .map(|s| {
            let budget = e
                .cluster
                .hbm_bytes
                .saturating_sub(mm.weight_opt_bytes(s) + e.cluster.reserved_bytes);
            let raw_fit = if act == 0 { u64::MAX } else { budget / act };
            let fit = raw_fit.saturating_sub(1);
            let hw = base.program(s).stash_high_water().max(0) as u64;
            fit.clamp(2, hw.max(2))
        })
        .collect()
}

/// Reusable scratch for the rebalance transform: the per-key
/// backward-position table and the resident/evicted working sets.
/// The bound-sensitivity sweep re-rebalances the SAME base schedule at
/// every bound from derived down to 2 — holding one workspace per
/// worker (see `sim::sweep::ScheduleCache`) keeps those cells from
/// re-allocating (and, paired with the cached base, from re-running the
/// zigzag generator's virtual list-schedule, which dominates cell
/// setup).  The output `Schedule` is always freshly allocated; only the
/// transform's internal scratch is reused.
#[derive(Debug, Default)]
pub struct RebalanceWorkspace {
    bwd_pos: Vec<usize>,
    resident: Vec<(u64, u64)>,
    evicted: Vec<(u64, u64)>,
}

impl RebalanceWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// [`rebalance`] through this workspace's scratch.
    pub fn rebalance(&mut self, base: &Schedule, bound_override: Option<u64>) -> Schedule {
        let k = bound_override.unwrap_or_else(|| derived_bound(base));
        let programs = self.programs(base, &vec![k; base.p as usize]);
        Schedule {
            p: base.p,
            m: base.m,
            chunks: base.chunks,
            placement: base.placement,
            kind: ScheduleKind::BPipe { bound: k },
            stage_bounds: None,
            programs,
        }
    }

    /// [`rebalance_bounded`] through this workspace's scratch.
    pub fn rebalance_bounded(&mut self, base: &Schedule, bounds: &[u64]) -> Schedule {
        assert_eq!(bounds.len(), base.p as usize, "one bound per stage");
        let programs = self.programs(base, bounds);
        let max = *bounds.iter().max().expect("at least one stage");
        Schedule {
            p: base.p,
            m: base.m,
            chunks: base.chunks,
            placement: base.placement,
            kind: ScheduleKind::BPipe { bound: max },
            stage_bounds: Some(bounds.to_vec()),
            programs,
        }
    }

    /// The transform core: per-stage evict/load insertion at per-stage caps.
    fn programs(&mut self, base: &Schedule, bounds: &[u64]) -> Vec<StageProgram> {
        let key_count = (base.m * base.chunks) as usize;
        let key_of = |op: &Op| (op.mb * base.chunks + op.chunk) as usize;
        let RebalanceWorkspace { bwd_pos, resident, evicted } = self;

        base.programs
            .iter()
            .zip(bounds)
            .map(|(prog, &k)| {
                assert!(k >= 2, "rebalance bound must be ≥ 2 (one live + one incoming stash)");
                // program-order position of each key's backward: the victim
                // metric (evict whoever is needed furthest in the future)
                bwd_pos.clear();
                bwd_pos.resize(key_count, usize::MAX);
                for (j, op) in prog.ops.iter().enumerate() {
                    if op.kind == OpKind::Bwd {
                        bwd_pos[key_of(op)] = j;
                    }
                }
                let mut ops: Vec<Op> = Vec::with_capacity(prog.ops.len() + 8);
                // members carry (mb, chunk); sets stay ≤ max(k, evicted peak)
                resident.clear();
                evicted.clear();
                let pos = |key: (u64, u64)| bwd_pos[(key.0 * base.chunks + key.1) as usize];
                for op in &prog.ops {
                    let key = (op.mb, op.chunk);
                    match op.kind {
                        OpKind::Fwd => {
                            if resident.len() as u64 == k {
                                evict_furthest(resident, evicted, &mut ops, pos);
                            }
                            ops.push(*op);
                            resident.push(key);
                        }
                        OpKind::Bwd => {
                            if !resident.contains(&key) {
                                // late load (tight bounds): make room, load
                                // back (key is off-device here, so the victim
                                // can never be the stash being loaded)
                                if resident.len() as u64 == k {
                                    evict_furthest(resident, evicted, &mut ops, pos);
                                }
                                let at = evicted
                                    .iter()
                                    .position(|&e| e == key)
                                    .expect("bwd of a stash that was never forwarded");
                                evicted.swap_remove(at);
                                resident.push(key);
                                ops.push(Op { kind: OpKind::Load, mb: key.0, chunk: key.1 });
                            }
                            ops.push(*op);
                            let at = resident.iter().position(|&r| r == key).unwrap();
                            resident.swap_remove(at);
                            // slot freed: prefetch the soonest-needed evictee
                            if (resident.len() as u64) < k && !evicted.is_empty() {
                                let at = (0..evicted.len())
                                    .min_by_key(|&i| pos(evicted[i]))
                                    .unwrap();
                                let nxt = evicted.swap_remove(at);
                                resident.push(nxt);
                                ops.push(Op { kind: OpKind::Load, mb: nxt.0, chunk: nxt.1 });
                            }
                        }
                        OpKind::Evict | OpKind::Load => {
                            panic!("rebalance base must be transfer-free (got {:?})", op.kind)
                        }
                    }
                }
                StageProgram { stage: prog.stage, ops }
            })
            .collect()
    }
}

/// Evict the resident stash whose backward is furthest in program
/// order, appending the Evict op.
fn evict_furthest(
    resident: &mut Vec<(u64, u64)>,
    evicted: &mut Vec<(u64, u64)>,
    ops: &mut Vec<Op>,
    pos: impl Fn((u64, u64)) -> usize,
) {
    let at = (0..resident.len())
        .max_by_key(|&i| pos(resident[i]))
        .expect("nothing evictable below the bound");
    let victim = resident.swap_remove(at);
    evicted.push(victim);
    ops.push(Op { kind: OpKind::Evict, mb: victim.0, chunk: victim.1 });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{gpipe, interleaved, one_f_one_b, v_shaped, validate, OpKind};

    #[test]
    fn derived_bound_matches_paper_for_1f1b() {
        for p in [2u64, 4, 8, 16] {
            let b = derived_bound(&one_f_one_b(p, 8 * p));
            assert_eq!(b, crate::model::memory::bpipe_bound(p), "p={p}");
        }
    }

    #[test]
    fn bound_range_spans_derived_down_to_two() {
        let il = interleaved(8, 64, 2);
        assert_eq!(bound_range(&il), 2..=16);
        // every bound in the range produces a valid schedule
        for k in bound_range(&il) {
            validate(&rebalance(&il, Some(k))).unwrap_or_else(|e| panic!("k={k}: {e}"));
        }
    }

    #[test]
    fn every_family_validates_across_its_full_bound_range() {
        // the bound-sensitivity sweep feeds rebalance(base, k) for every
        // k in bound_range to the non-validating workspace hot path, so
        // pin validity for ALL four base families here — including
        // GPipe, whose all-Fwd-then-all-Bwd programs stress the
        // late-load path hardest at tight bounds
        let bases = [
            one_f_one_b(8, 24),
            gpipe(8, 24),
            interleaved(8, 24, 2),
            v_shaped(8, 24),
        ];
        for base in &bases {
            for k in bound_range(base) {
                let rb = rebalance(base, Some(k));
                validate(&rb).unwrap_or_else(|e| panic!("{:?} k={k}: {e}", base.kind));
                for s in 0..base.p {
                    assert!(
                        rb.program(s).stash_high_water() <= k as i64,
                        "{:?} k={k} stage {s}",
                        base.kind
                    );
                }
            }
        }
    }

    #[test]
    fn derived_bound_flattens_interleaved_pairs() {
        // interleaved(8, 64, 2): per-stage hw ramps 23..9; every pair
        // sums to 32, so the derived bound is 16
        let il = interleaved(8, 64, 2);
        assert_eq!(derived_bound(&il), 16);
    }

    #[test]
    fn rebalanced_interleaved_validates_and_bounds() {
        for (p, mult, v) in [(4u64, 2u64, 2u64), (8, 4, 2), (8, 8, 2), (4, 4, 3)] {
            let base = interleaved(p, p * mult, v);
            let rb = rebalance(&base, None);
            validate(&rb).unwrap_or_else(|e| panic!("p={p} m={} v={v}: {e}", p * mult));
            let k = derived_bound(&base) as i64;
            for s in 0..p {
                assert!(rb.program(s).stash_high_water() <= k);
            }
        }
    }

    #[test]
    fn rebalance_matches_golden_1f1b_sequence() {
        // Pin the paper's Figure-1 policy as a golden op sequence so a
        // future change to the generalized victim/prefetch rules that
        // diverges from the 1F1B-specific behavior (newest-mb victim,
        // oldest-mb prefetch) fails loudly.  p=4, m=8, bound 3.
        let bp = rebalance(&one_f_one_b(4, 8), Some(crate::model::memory::bpipe_bound(4)));
        let render = |stage: u64| -> String {
            bp.program(stage)
                .ops
                .iter()
                .map(|o| {
                    let c = match o.kind {
                        OpKind::Fwd => 'F',
                        OpKind::Bwd => 'B',
                        OpKind::Evict => 'E',
                        OpKind::Load => 'L',
                    };
                    format!("{c}{}", o.mb)
                })
                .collect::<Vec<_>>()
                .join(" ")
        };
        assert_eq!(
            render(0),
            "F0 F1 F2 E2 F3 B0 L2 E3 F4 B1 L3 E4 F5 B2 L4 E5 F6 B3 L5 E6 F7 B4 L6 B5 B6 B7"
        );
        // stage 1's natural in-flight (3) equals the bound: untouched
        assert_eq!(render(1), "F0 F1 F2 B0 F3 B1 F4 B2 F5 B3 F6 B4 F7 B5 B6 B7");
    }

    #[test]
    fn rebalance_handles_gpipe_and_vshaped() {
        let g = rebalance(&gpipe(4, 12), Some(4));
        validate(&g).unwrap();
        for s in 0..4 {
            assert!(g.program(s).stash_high_water() <= 4);
        }
        let v = rebalance(&v_shaped(8, 32), Some(8));
        validate(&v).unwrap();
        for s in 0..8 {
            assert!(v.program(s).stash_high_water() <= 8);
        }
    }

    #[test]
    fn preserves_compute_subsequence() {
        let base = interleaved(8, 32, 2);
        let rb = rebalance(&base, Some(4));
        for s in 0..8 {
            let compute = |prog: &crate::schedule::StageProgram| {
                prog.ops
                    .iter()
                    .filter(|o| matches!(o.kind, OpKind::Fwd | OpKind::Bwd))
                    .cloned()
                    .collect::<Vec<_>>()
            };
            assert_eq!(compute(base.program(s)), compute(rb.program(s)), "stage {s}");
        }
    }

    #[test]
    #[should_panic(expected = "transfer-free")]
    fn rejects_already_rebalanced_base() {
        let once = rebalance(&one_f_one_b(8, 64), None);
        rebalance(&once, None);
    }

    #[test]
    fn per_stage_bounds_enforced_independently() {
        let base = one_f_one_b(8, 32);
        let bounds: Vec<u64> = vec![5, 6, 6, 5, 4, 3, 2, 2];
        let rb = rebalance_bounded(&base, &bounds);
        validate(&rb).unwrap();
        assert_eq!(rb.stage_bounds.as_deref(), Some(&bounds[..]));
        assert_eq!(rb.kind, crate::schedule::ScheduleKind::BPipe { bound: 6 });
        for s in 0..8u64 {
            assert!(
                rb.program(s).stash_high_water() <= bounds[s as usize] as i64,
                "stage {s}: hw {} > {}",
                rb.program(s).stash_high_water(),
                bounds[s as usize]
            );
        }
        // stages whose natural high-water fits their bound stay untouched
        assert_eq!(rb.count(4, OpKind::Evict), 0, "natural hw 4 ≤ bound 4");
        assert!(rb.count(0, OpKind::Evict) > 0, "natural hw 8 > bound 5");
    }

    #[test]
    fn uniform_bounded_matches_uniform_rebalance_ops() {
        // same caps → same op streams; only the stage_bounds tag differs
        let base = interleaved(8, 32, 2);
        let uni = rebalance(&base, Some(10));
        let per = rebalance_bounded(&base, &[10; 8]);
        assert_eq!(uni.programs, per.programs);
        assert_eq!(uni.stage_bounds, None);
        assert_eq!(per.stage_bounds, Some(vec![10; 8]));
    }

    #[test]
    #[should_panic(expected = "one bound per stage")]
    fn bounded_rejects_wrong_length() {
        rebalance_bounded(&one_f_one_b(4, 8), &[3, 3]);
    }

    #[test]
    fn capacity_bounds_clamped_and_feasible() {
        let e = crate::config::paper_experiment(8).unwrap();
        let p = e.parallel.p;
        let m = e.parallel.num_microbatches();
        for base in [one_f_one_b(p, m), gpipe(p, m), interleaved(p, m, 2), v_shaped(p, m)] {
            let bounds = capacity_stage_bounds(&e, &base);
            assert_eq!(bounds.len(), p as usize);
            for (s, &k) in bounds.iter().enumerate() {
                assert!(k >= 2, "{:?} stage {s}: {k}", base.kind);
                assert!(
                    k as i64 <= base.program(s as u64).stash_high_water().max(2),
                    "{:?} stage {s}: {k}",
                    base.kind
                );
            }
            validate(&rebalance_bounded(&base, &bounds))
                .unwrap_or_else(|e| panic!("{:?}: {e}", base.kind));
        }
    }

    #[test]
    fn capacity_bounds_rescue_exp8_1f1b_with_less_traffic() {
        // the SlimPipe-motivated scenario: per-stage capacity bounds on
        // exp (8)'s 1F1B leave stages 2..7 untouched (they already fit),
        // so far fewer stashes travel than under the uniform bound
        let e = crate::config::paper_experiment(8).unwrap();
        let base = one_f_one_b(e.parallel.p, e.parallel.num_microbatches());
        let bounds = capacity_stage_bounds(&e, &base);
        assert_eq!(bounds, vec![5, 6, 6, 5, 4, 3, 2, 2]);
        let per = rebalance_bounded(&base, &bounds);
        let uni = rebalance(&base, None);
        let evicts = |s: &crate::schedule::Schedule| -> usize {
            (0..s.p).map(|st| s.count(st, OpKind::Evict)).sum()
        };
        assert!(evicts(&per) < evicts(&uni), "{} vs {}", evicts(&per), evicts(&uni));
    }

    #[test]
    fn workspace_reuse_is_op_identical_across_bounds_and_bases() {
        // the bound-sensitivity sweep reuses one workspace per worker
        // across consecutive cells (different bounds, then a different
        // base entirely): every reused result must equal a fresh one
        let mut ws = RebalanceWorkspace::new();
        let bases =
            [one_f_one_b(8, 24), interleaved(8, 24, 2), crate::schedule::zigzag(8, 24, 4)];
        for base in &bases {
            for k in bound_range(base).rev() {
                let fresh = rebalance(base, Some(k));
                let reused = ws.rebalance(base, Some(k));
                assert_eq!(fresh, reused, "{:?} k={k}", base.kind);
            }
        }
        let bounds: Vec<u64> = (0..8u64).map(|s| 2 + (s % 3)).collect();
        assert_eq!(
            rebalance_bounded(&bases[0], &bounds),
            ws.rebalance_bounded(&bases[0], &bounds)
        );
    }

    #[test]
    fn per_stage_bounds_compose_with_every_family() {
        for base in [
            one_f_one_b(8, 24),
            gpipe(8, 24),
            interleaved(8, 24, 2),
            v_shaped(8, 24),
            crate::schedule::zigzag(8, 24, 4),
        ] {
            // an asymmetric cap vector exercising late loads on one side
            let bounds: Vec<u64> = (0..8u64).map(|s| 2 + (s % 3)).collect();
            let rb = rebalance_bounded(&base, &bounds);
            validate(&rb).unwrap_or_else(|e| panic!("{:?}: {e}", base.kind));
        }
    }
}
