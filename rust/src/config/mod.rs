//! Configuration system: model shapes, parallelism, cluster hardware.
//!
//! Mirrors the paper's notation (Table 1): `h` hidden size, `a` heads,
//! `s` sequence length, `l` layers, `v` vocabulary, `b` microbatch size,
//! `B` global batch size, `t` tensor-parallel size, `p` pipeline stages.
//!
//! Experiment configs round-trip through a flat `key = value` config
//! format so runs are launchable as `bpipe simulate --config f.cfg`.

mod presets;

pub use presets::*;


/// Which attention implementation a run uses — the paper's Table 3
/// "attention method" column.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AttentionMethod {
    /// Original attention: unfused scale/softmax kernels with f32
    /// round-trips (paper experiments (1), (7) profile these as the
    /// slow path) and full activation storage.
    None,
    /// Selective activation checkpointing on the attention block
    /// (Korthikanti et al.): the fused-softmax forward is re-run in the
    /// backward pass; scores/probs are never stashed.
    Recompute,
    /// FlashAttention-2: online-softmax tiling; no (s, s) tensor is ever
    /// materialized, and the backward recomputes from q/k/v.
    FlashAttn2,
}

impl AttentionMethod {
    pub const ALL: [AttentionMethod; 3] = [
        AttentionMethod::None,
        AttentionMethod::Recompute,
        AttentionMethod::FlashAttn2,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            AttentionMethod::None => "none",
            AttentionMethod::Recompute => "recompute",
            AttentionMethod::FlashAttn2 => "flash attn 2",
        }
    }
}

/// Transformer model family; affects FFN structure, norms and the
/// attention-softmax kernel mix (paper §3.1 / §3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelFamily {
    /// GPT-3 style: LayerNorm, learned positions, 4h GELU FFN.
    Gpt,
    /// LLaMA style: RMSNorm, RoPE, SwiGLU FFN (3 matmuls, ~8h/3 wide —
    /// same 16bsh² FLOPs as GPT's FFN, paper Eq. 1 discussion).
    Llama,
}

/// Model architecture (paper Table 2).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub family: ModelFamily,
    /// hidden dimension size (h)
    pub h: u64,
    /// number of attention heads (a)
    pub a: u64,
    /// sequence length (s)
    pub s: u64,
    /// number of transformer layers (l)
    pub l: u64,
    /// vocabulary size (v)
    pub v: u64,
}

impl ModelConfig {
    /// Total parameter count: `12 l h² (1 + 13/(12h)) + v h + s h` — the
    /// standard GPT estimate (Narayanan et al. 2021, Eq. "P").
    pub fn total_params(&self) -> u64 {
        let (h, l, v, s) = (self.h, self.l, self.v, self.s);
        12 * l * h * h + 13 * l * h + v * h + s * h
    }

    /// Head dimension (h / a).
    pub fn d_head(&self) -> u64 {
        self.h / self.a
    }
}

/// Parallelism strategy (paper §3.1: t=4, p=8, B=128).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParallelConfig {
    /// tensor parallel size (t)
    pub t: u64,
    /// pipeline parallel size (p)
    pub p: u64,
    /// global batch size (B), in sequences
    pub global_batch: u64,
    /// microbatch size (b), in sequences
    pub microbatch: u64,
    /// Megatron sequence parallelism (the paper enables it)
    pub sequence_parallel: bool,
}

impl ParallelConfig {
    /// Number of microbatches per iteration (B / b / dp); the paper runs
    /// dp = 1 (32 GPUs = t·p = 4·8).
    pub fn num_microbatches(&self) -> u64 {
        assert!(
            self.global_batch % self.microbatch == 0,
            "B={} not divisible by b={}",
            self.global_batch,
            self.microbatch
        );
        self.global_batch / self.microbatch
    }

    /// Devices used by one model replica.
    pub fn devices(&self) -> u64 {
        self.t * self.p
    }
}

/// Hardware description of the training cluster (paper §3.1: 4 nodes ×
/// 8 × A100-80GB over NVLink, IB across nodes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterConfig {
    pub n_nodes: u64,
    pub gpus_per_node: u64,
    /// device memory capacity in bytes (80 GiB A100)
    pub hbm_bytes: u64,
    /// theoretical peak bf16 FLOP/s per device (A100: 312e12)
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s (A100: 2.0e12)
    pub hbm_bw: f64,
    /// NVLink bandwidth per direction, bytes/s (A100: 300e9)
    pub nvlink_bw: f64,
    /// inter-node (InfiniBand) bandwidth per GPU, bytes/s
    pub ib_bw: f64,
    /// fixed kernel-launch overhead, seconds
    pub kernel_launch_s: f64,
    /// memory reserved by framework/context/fragmentation, bytes
    pub reserved_bytes: u64,
}

impl ClusterConfig {
    pub fn total_gpus(&self) -> u64 {
        self.n_nodes * self.gpus_per_node
    }
}

/// One experiment row (paper Table 3): model + parallelism + BPipe flag +
/// attention method, on a cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// paper experiment id ("(1)" … "(10)"), if reproducing a table row
    pub id: Option<u32>,
    pub model: ModelConfig,
    pub parallel: ParallelConfig,
    pub cluster: ClusterConfig,
    pub bpipe: bool,
    pub attention: AttentionMethod,
}

impl ExperimentConfig {
    /// Serialize to the launchable flat `key = value` config format.
    pub fn to_config_text(&self) -> String {
        let m = &self.model;
        let p = &self.parallel;
        let c = &self.cluster;
        format!(
            "# bpipe experiment config\n\
             id = {}\n\
             model.name = {}\n\
             model.family = {}\n\
             model.h = {}\nmodel.a = {}\nmodel.s = {}\nmodel.l = {}\nmodel.v = {}\n\
             parallel.t = {}\nparallel.p = {}\n\
             parallel.global_batch = {}\nparallel.microbatch = {}\n\
             parallel.sequence_parallel = {}\n\
             cluster.n_nodes = {}\ncluster.gpus_per_node = {}\n\
             cluster.hbm_bytes = {}\ncluster.peak_flops = {}\n\
             cluster.hbm_bw = {}\ncluster.nvlink_bw = {}\ncluster.ib_bw = {}\n\
             cluster.kernel_launch_s = {}\ncluster.reserved_bytes = {}\n\
             bpipe = {}\nattention = {}\n",
            self.id.map(|i| i.to_string()).unwrap_or_else(|| "none".into()),
            m.name,
            match m.family {
                ModelFamily::Gpt => "gpt",
                ModelFamily::Llama => "llama",
            },
            m.h, m.a, m.s, m.l, m.v,
            p.t, p.p, p.global_batch, p.microbatch, p.sequence_parallel,
            c.n_nodes, c.gpus_per_node, c.hbm_bytes, c.peak_flops,
            c.hbm_bw, c.nvlink_bw, c.ib_bw, c.kernel_launch_s, c.reserved_bytes,
            self.bpipe,
            match self.attention {
                AttentionMethod::None => "none",
                AttentionMethod::Recompute => "recompute",
                AttentionMethod::FlashAttn2 => "flash_attn2",
            },
        )
    }

    /// Parse the flat `key = value` config format ('#' starts a comment).
    pub fn from_config_text(s: &str) -> anyhow::Result<Self> {
        let mut kv = std::collections::HashMap::new();
        for (lineno, raw) in s.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| {
            kv.get(k).cloned().ok_or_else(|| anyhow::anyhow!("config missing key {k:?}"))
        };
        let get_u64 = |k: &str| -> anyhow::Result<u64> { Ok(get(k)?.parse()?) };
        let get_f64 = |k: &str| -> anyhow::Result<f64> { Ok(get(k)?.parse()?) };
        let get_bool = |k: &str| -> anyhow::Result<bool> { Ok(get(k)?.parse()?) };
        let id = match get("id")?.as_str() {
            "none" => None,
            other => Some(other.parse()?),
        };
        Ok(ExperimentConfig {
            id,
            model: ModelConfig {
                name: get("model.name")?,
                family: match get("model.family")?.as_str() {
                    "gpt" => ModelFamily::Gpt,
                    "llama" => ModelFamily::Llama,
                    other => anyhow::bail!("unknown model.family {other:?}"),
                },
                h: get_u64("model.h")?,
                a: get_u64("model.a")?,
                s: get_u64("model.s")?,
                l: get_u64("model.l")?,
                v: get_u64("model.v")?,
            },
            parallel: ParallelConfig {
                t: get_u64("parallel.t")?,
                p: get_u64("parallel.p")?,
                global_batch: get_u64("parallel.global_batch")?,
                microbatch: get_u64("parallel.microbatch")?,
                sequence_parallel: get_bool("parallel.sequence_parallel")?,
            },
            cluster: ClusterConfig {
                n_nodes: get_u64("cluster.n_nodes")?,
                gpus_per_node: get_u64("cluster.gpus_per_node")?,
                hbm_bytes: get_u64("cluster.hbm_bytes")?,
                peak_flops: get_f64("cluster.peak_flops")?,
                hbm_bw: get_f64("cluster.hbm_bw")?,
                nvlink_bw: get_f64("cluster.nvlink_bw")?,
                ib_bw: get_f64("cluster.ib_bw")?,
                kernel_launch_s: get_f64("cluster.kernel_launch_s")?,
                reserved_bytes: get_u64("cluster.reserved_bytes")?,
            },
            bpipe: get_bool("bpipe")?,
            attention: match get("attention")?.as_str() {
                "none" => AttentionMethod::None,
                "recompute" => AttentionMethod::Recompute,
                "flash_attn2" => AttentionMethod::FlashAttn2,
                other => anyhow::bail!("unknown attention {other:?}"),
            },
        })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_config_text(&std::fs::read_to_string(path)?)
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        Ok(std::fs::write(path, self.to_config_text())?)
    }

    /// Human-readable one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "{} t={} p={} b={} B={} bpipe={} attn={}",
            self.model.name,
            self.parallel.t,
            self.parallel.p,
            self.parallel.microbatch,
            self.parallel.global_batch,
            self.bpipe,
            self.attention.label()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama_65b_params_close_to_65e9() {
        let m = llama_65b();
        let p = m.total_params() as f64;
        assert!((p - 65e9).abs() / 65e9 < 0.05, "got {p:.3e}");
    }

    #[test]
    fn gpt3_96b_params_close_to_96e9() {
        let m = gpt3_96b();
        let p = m.total_params() as f64;
        assert!((p - 96e9).abs() / 96e9 < 0.05, "got {p:.3e}");
    }

    #[test]
    fn microbatch_count() {
        let p = ParallelConfig {
            t: 4,
            p: 8,
            global_batch: 128,
            microbatch: 2,
            sequence_parallel: true,
        };
        assert_eq!(p.num_microbatches(), 64);
        assert_eq!(p.devices(), 32);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn microbatch_must_divide() {
        ParallelConfig {
            t: 4,
            p: 8,
            global_batch: 128,
            microbatch: 3,
            sequence_parallel: true,
        }
        .num_microbatches();
    }

    #[test]
    fn config_text_roundtrip() {
        for id in [1u32, 8] {
            let e = paper_experiment(id).unwrap();
            let s = e.to_config_text();
            let back = ExperimentConfig::from_config_text(&s).unwrap();
            assert_eq!(e, back);
        }
    }

    #[test]
    fn config_text_rejects_garbage() {
        assert!(ExperimentConfig::from_config_text("nonsense line").is_err());
        assert!(ExperimentConfig::from_config_text("id = 1").is_err()); // missing keys
    }

    #[test]
    fn paper_cluster_is_32_gpus() {
        assert_eq!(paper_cluster().total_gpus(), 32);
    }
}
