//! Canonical configurations: the paper's models (Table 2), cluster
//! (§3.1) and the ten Table-3 experiment rows.

use super::*;

/// LLaMA 65B (paper Table 2; architecture constants from Touvron et al.).
pub fn llama_65b() -> ModelConfig {
    ModelConfig {
        name: "LLaMA 65B".into(),
        family: ModelFamily::Llama,
        h: 8192,
        a: 64,
        s: 2048,
        l: 80,
        v: 32000,
    }
}

/// GPT-3 96B (paper Table 2: h=9984, a=104, s=2048, l=80).
pub fn gpt3_96b() -> ModelConfig {
    ModelConfig {
        name: "GPT-3 96B".into(),
        family: ModelFamily::Gpt,
        h: 9984,
        a: 104,
        s: 2048,
        l: 80,
        v: 51200,
    }
}

/// The paper's testbed: 4 nodes × 8 × NVIDIA A100-80GiB, NVLink inside a
/// node, InfiniBand across nodes (§3.1).
pub fn paper_cluster() -> ClusterConfig {
    ClusterConfig {
        n_nodes: 4,
        gpus_per_node: 8,
        hbm_bytes: 80 * (1 << 30),
        peak_flops: 312e12, // A100 bf16 dense
        hbm_bw: 2.0e12,     // HBM2e
        nvlink_bw: 300e9,   // per direction
        ib_bw: 25e9,        // 200 Gb/s HDR per GPU
        kernel_launch_s: 4e-6,
        // CUDA context + NCCL buffers + allocator fragmentation; tuned so
        // the paper's feasibility pattern (which b fits without BPipe)
        // reproduces — see EXPERIMENTS.md §Memory.
        reserved_bytes: 6 * (1 << 30),
    }
}

/// The paper's parallelism: t=4, p=8, B=128, sequence parallel on (§3.1).
pub fn paper_parallel(microbatch: u64) -> ParallelConfig {
    ParallelConfig {
        t: 4,
        p: 8,
        global_batch: 128,
        microbatch,
        sequence_parallel: true,
    }
}

/// Table 3, experiments (1)–(10).
///
/// | id | model | b | BPipe | attention | paper MFU % |
/// |----|-----------|---|-------|-----------|-------------|
/// | 1  | LLaMA 65B | 1 | no    | none      | 45.3 |
/// | 2  | LLaMA 65B | 2 | no    | recompute | 46.0 |
/// | 3  | LLaMA 65B | 4 | yes   | recompute | 42.7 |
/// | 4  | LLaMA 65B | 1 | no    | flash     | 47.8 |
/// | 5  | LLaMA 65B | 2 | no    | flash     | 49.2 |
/// | 6  | LLaMA 65B | 4 | yes   | flash     | 44.0 |
/// | 7  | GPT-3 96B | 1 | no    | recompute | 34.0 |
/// | 8  | GPT-3 96B | 2 | yes   | recompute | 45.8 |
/// | 9  | GPT-3 96B | 1 | no    | flash     | 52.0 |
/// | 10 | GPT-3 96B | 2 | yes   | flash     | 51.7 |
pub fn paper_experiment(id: u32) -> Option<ExperimentConfig> {
    let (model, b, bpipe, attention) = match id {
        1 => (llama_65b(), 1, false, AttentionMethod::None),
        2 => (llama_65b(), 2, false, AttentionMethod::Recompute),
        3 => (llama_65b(), 4, true, AttentionMethod::Recompute),
        4 => (llama_65b(), 1, false, AttentionMethod::FlashAttn2),
        5 => (llama_65b(), 2, false, AttentionMethod::FlashAttn2),
        6 => (llama_65b(), 4, true, AttentionMethod::FlashAttn2),
        7 => (gpt3_96b(), 1, false, AttentionMethod::Recompute),
        8 => (gpt3_96b(), 2, true, AttentionMethod::Recompute),
        9 => (gpt3_96b(), 1, false, AttentionMethod::FlashAttn2),
        10 => (gpt3_96b(), 2, true, AttentionMethod::FlashAttn2),
        _ => return None,
    };
    Some(ExperimentConfig {
        id: Some(id),
        model,
        parallel: paper_parallel(b),
        cluster: paper_cluster(),
        bpipe,
        attention,
    })
}

/// Paper-reported whole-model MFU (Table 3), for paper-vs-ours reports.
pub fn paper_table3_mfu(id: u32) -> Option<f64> {
    Some(match id {
        1 => 45.3,
        2 => 46.0,
        3 => 42.7,
        4 => 47.8,
        5 => 49.2,
        6 => 44.0,
        7 => 34.0,
        8 => 45.8,
        9 => 52.0,
        10 => 51.7,
        _ => return None,
    })
}

/// Paper-reported single-stage MFU (Table 5).
pub fn paper_table5_mfu(id: u32) -> Option<f64> {
    Some(match id {
        1 => 51.1,
        2 => 54.5,
        3 => 57.6,
        4 => 53.6,
        5 => 58.6,
        6 => 61.9,
        7 => 37.8,
        8 => 55.2,
        9 => 57.7,
        10 => 62.4,
        _ => return None,
    })
}

/// All ten Table-3 experiment configs in order.
pub fn paper_experiments() -> Vec<ExperimentConfig> {
    (1..=10).map(|i| paper_experiment(i).unwrap()).collect()
}

/// A laptop-scale config matching the default AOT artifact set
/// (python/compile/aot.py defaults) — used by the real runtime examples.
pub fn tiny_model() -> ModelConfig {
    ModelConfig {
        name: "tiny-llama".into(),
        family: ModelFamily::Llama,
        h: 256,
        a: 8,
        s: 128,
        l: 8,
        v: 4096,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_ten_experiments_exist() {
        for i in 1..=10 {
            let e = paper_experiment(i).unwrap();
            assert_eq!(e.id, Some(i));
            assert!(paper_table3_mfu(i).is_some());
            assert!(paper_table5_mfu(i).is_some());
        }
        assert!(paper_experiment(0).is_none());
        assert!(paper_experiment(11).is_none());
    }

    #[test]
    fn bpipe_rows_match_paper() {
        // BPipe on exactly for experiments 3, 6, 8, 10
        for i in 1..=10u32 {
            let e = paper_experiment(i).unwrap();
            assert_eq!(e.bpipe, matches!(i, 3 | 6 | 8 | 10), "exp {i}");
        }
    }

    #[test]
    fn experiment_summary_contains_key_fields() {
        let s = paper_experiment(8).unwrap().summary();
        assert!(s.contains("GPT-3 96B") && s.contains("bpipe=true"));
    }
}
