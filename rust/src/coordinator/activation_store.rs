//! Bounded activation stash + the BPipe remote store.
//!
//! Each stage worker owns an [`ActivationStore`] holding the stage-input
//! tensor(s) of every in-flight `(microbatch, chunk)` key (the thing a
//! backward pass needs and the thing BPipe ships around).  The store
//! enforces the capacity bound the schedule was built for — exceeding it
//! is a bug, caught here rather than as a silent OOM.  Multi-chunk
//! (interleaved / V / zig-zag) programs share ONE store per worker: the
//! rebalance transform bounds the stage's resident count across all of
//! its chunks, and so does the store.
//!
//! The acceptor side of a BPipe pair is a [`RemoteStore`] service thread
//! owning the evicted tensors (the "partner device's free memory"): the
//! evictor pushes stashes to it and pulls them back before the backward.

use std::collections::HashMap;
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};

pub use crate::runtime::HostTensor;

/// A stash key: `(microbatch, chunk)` — chunk is always 0 for
/// single-chunk schedules.
pub type StashKey = (u64, u64);

/// Per-stage bounded stash: `(mb, chunk)` → stage-input tensor(s).
pub struct ActivationStore {
    stash: HashMap<StashKey, Vec<HostTensor>>,
    capacity: usize,
    /// peak resident entries (for the balance report)
    pub high_water: usize,
    /// total bytes currently resident
    pub resident_bytes: usize,
    /// peak resident bytes
    pub high_water_bytes: usize,
}

impl ActivationStore {
    pub fn new(capacity: usize) -> Self {
        Self {
            stash: HashMap::new(),
            capacity,
            high_water: 0,
            resident_bytes: 0,
            high_water_bytes: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.stash.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stash.is_empty()
    }

    /// Insert a stash; panics if the schedule violated its own bound.
    pub fn put(&mut self, key: StashKey, tensors: Vec<HostTensor>) {
        assert!(
            self.stash.len() < self.capacity,
            "activation store over capacity ({}): schedule bound violated at (mb {}, chunk {})",
            self.capacity,
            key.0,
            key.1
        );
        self.resident_bytes += tensors.iter().map(|t| t.bytes()).sum::<usize>();
        let prev = self.stash.insert(key, tensors);
        assert!(prev.is_none(), "double stash for (mb {}, chunk {})", key.0, key.1);
        self.high_water = self.high_water.max(self.stash.len());
        self.high_water_bytes = self.high_water_bytes.max(self.resident_bytes);
    }

    /// Remove and return a stash (for Bwd or Evict).
    pub fn take(&mut self, key: StashKey) -> Vec<HostTensor> {
        let t = self
            .stash
            .remove(&key)
            .unwrap_or_else(|| panic!("stash for (mb {}, chunk {}) not resident", key.0, key.1));
        self.resident_bytes -= t.iter().map(|x| x.bytes()).sum::<usize>();
        t
    }

    pub fn contains(&self, key: StashKey) -> bool {
        self.stash.contains_key(&key)
    }
}

/// Messages to a BPipe remote store.
enum StoreMsg {
    Evict { key: StashKey, tensors: Vec<HostTensor> },
    Load { key: StashKey },
    Shutdown,
}

/// Client handle an evictor stage uses to talk to its acceptor-side store.
pub struct RemoteStoreClient {
    tx: Sender<StoreMsg>,
    resp_rx: Receiver<(StashKey, Vec<HostTensor>)>,
}

impl RemoteStoreClient {
    /// Ship a stash to the acceptor (non-blocking).
    pub fn evict(&self, key: StashKey, tensors: Vec<HostTensor>) {
        self.tx.send(StoreMsg::Evict { key, tensors }).expect("remote store gone");
    }

    /// Fetch a stash back (blocks until the acceptor responds).
    pub fn load(&self, key: StashKey) -> Vec<HostTensor> {
        self.tx.send(StoreMsg::Load { key }).expect("remote store gone");
        let (got, tensors) = self.resp_rx.recv().expect("remote store gone");
        assert_eq!(got, key, "remote store returned the wrong stash");
        tensors
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(StoreMsg::Shutdown);
    }
}

/// Stats the remote store reports when it shuts down.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RemoteStoreStats {
    pub evictions: u64,
    pub loads: u64,
    pub high_water_entries: usize,
    pub high_water_bytes: usize,
}

/// Spawn the acceptor-side store service thread for one evictor/acceptor
/// pair.  Returns the evictor's client handle and a receiver for the
/// final stats.
pub fn spawn_remote_store() -> (RemoteStoreClient, Receiver<RemoteStoreStats>) {
    let (tx, rx) = channel::<StoreMsg>();
    let (resp_tx, resp_rx) = channel();
    let (stats_tx, stats_rx): (SyncSender<RemoteStoreStats>, Receiver<RemoteStoreStats>) =
        sync_channel(1);
    std::thread::Builder::new()
        .name("bpipe-remote-store".into())
        .spawn(move || {
            let mut held: HashMap<StashKey, Vec<HostTensor>> = HashMap::new();
            let mut stats = RemoteStoreStats::default();
            let mut bytes = 0usize;
            for msg in rx {
                match msg {
                    StoreMsg::Evict { key, tensors } => {
                        bytes += tensors.iter().map(|t| t.bytes()).sum::<usize>();
                        held.insert(key, tensors);
                        stats.evictions += 1;
                        stats.high_water_entries = stats.high_water_entries.max(held.len());
                        stats.high_water_bytes = stats.high_water_bytes.max(bytes);
                    }
                    StoreMsg::Load { key } => {
                        let tensors = held.remove(&key).unwrap_or_else(|| {
                            panic!("load of non-evicted (mb {}, chunk {})", key.0, key.1)
                        });
                        bytes -= tensors.iter().map(|t| t.bytes()).sum::<usize>();
                        stats.loads += 1;
                        resp_tx.send((key, tensors)).ok();
                    }
                    StoreMsg::Shutdown => break,
                }
            }
            assert!(held.is_empty(), "remote store shut down with stashes still held");
            stats_tx.send(stats).ok();
        })
        .expect("spawn remote store");
    (RemoteStoreClient { tx, resp_rx }, stats_rx)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(n: usize) -> Vec<HostTensor> {
        vec![HostTensor::F32 { data: vec![0.5; n], shape: vec![n as i64] }]
    }

    #[test]
    fn store_tracks_high_water() {
        let mut s = ActivationStore::new(3);
        s.put((0, 0), t(4));
        s.put((1, 0), t(4));
        assert_eq!(s.high_water, 2);
        assert_eq!(s.resident_bytes, 32);
        s.take((0, 0));
        s.put((2, 0), t(4));
        assert_eq!(s.high_water, 2);
        assert_eq!(s.len(), 2);
        assert!(s.contains((2, 0)) && !s.contains((0, 0)));
    }

    #[test]
    fn chunk_keys_are_independent() {
        let mut s = ActivationStore::new(4);
        s.put((0, 0), t(2));
        s.put((0, 1), t(6));
        assert_eq!(s.len(), 2);
        assert_eq!(s.take((0, 1))[0].len(), 6);
        assert!(s.contains((0, 0)));
    }

    #[test]
    #[should_panic(expected = "over capacity")]
    fn store_enforces_bound() {
        let mut s = ActivationStore::new(1);
        s.put((0, 0), t(1));
        s.put((1, 0), t(1));
    }

    #[test]
    #[should_panic(expected = "not resident")]
    fn take_missing_panics() {
        let mut s = ActivationStore::new(2);
        s.take((7, 0));
    }

    #[test]
    fn remote_store_round_trip() {
        let (client, stats_rx) = spawn_remote_store();
        let payload = t(8);
        client.evict((3, 0), payload.clone());
        client.evict((3, 1), t(8));
        let back = client.load((3, 0));
        assert_eq!(back, payload);
        let _ = client.load((3, 1));
        client.shutdown();
        let stats = stats_rx.recv().unwrap();
        assert_eq!(stats.evictions, 2);
        assert_eq!(stats.loads, 2);
        assert_eq!(stats.high_water_entries, 2);
        assert_eq!(stats.high_water_bytes, 64);
    }
}
