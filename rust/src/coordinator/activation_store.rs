//! Bounded activation stash + the BPipe remote store.
//!
//! Each stage worker owns an [`ActivationStore`] holding the stage-input
//! tensor of every in-flight microbatch (the thing a backward pass needs
//! and the thing BPipe ships around).  The store enforces the capacity
//! bound the schedule was built for — exceeding it is a bug, caught here
//! rather than as a silent OOM.
//!
//! The acceptor side of a BPipe pair is a [`RemoteStore`] service thread
//! owning the evicted tensors (the "partner device's free memory"): the
//! evictor pushes stashes to it and pulls them back before the backward.

use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::collections::HashMap;

/// A tensor crossing thread boundaries: host data + logical shape.
/// (xla::Literal wraps raw pointers and is not Send; the coordinator
/// moves host vectors and re-materializes literals at the use site.)
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { data: Vec<f32>, shape: Vec<i64> },
    I32 { data: Vec<i32>, shape: Vec<i64> },
}

impl HostTensor {
    pub fn bytes(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len() * 4,
            HostTensor::I32 { data, .. } => data.len() * 4,
        }
    }

    /// Upload straight to a device buffer (synchronous copy semantics;
    /// see `runtime::Runtime::upload_f32`) — the hot-path conversion.
    pub fn to_buffer(&self, rt: &crate::runtime::Runtime) -> anyhow::Result<xla::PjRtBuffer> {
        let dims: Vec<usize> = match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => {
                shape.iter().map(|&d| d as usize).collect()
            }
        };
        match self {
            HostTensor::F32 { data, .. } => rt.upload_f32(data, &dims),
            HostTensor::I32 { data, .. } => rt.upload_i32(data, &dims),
        }
    }

    /// Materialize an xla literal (on the calling thread).
    pub fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        match self {
            HostTensor::F32 { data, shape } => crate::runtime::literal_f32(data, shape),
            HostTensor::I32 { data, shape } => {
                let lit = xla::Literal::vec1(data.as_slice());
                if shape.len() <= 1 {
                    Ok(lit)
                } else {
                    Ok(lit.reshape(shape)?)
                }
            }
        }
    }
}

/// Per-stage bounded stash: microbatch id → stage-input tensor(s).
pub struct ActivationStore {
    stash: HashMap<u64, Vec<HostTensor>>,
    capacity: usize,
    /// peak resident entries (for the balance report)
    pub high_water: usize,
    /// total bytes currently resident
    pub resident_bytes: usize,
    /// peak resident bytes
    pub high_water_bytes: usize,
}

impl ActivationStore {
    pub fn new(capacity: usize) -> Self {
        Self {
            stash: HashMap::new(),
            capacity,
            high_water: 0,
            resident_bytes: 0,
            high_water_bytes: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.stash.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stash.is_empty()
    }

    /// Insert a stash; panics if the schedule violated its own bound.
    pub fn put(&mut self, mb: u64, tensors: Vec<HostTensor>) {
        assert!(
            self.stash.len() < self.capacity,
            "activation store over capacity ({}): schedule bound violated at mb {mb}",
            self.capacity
        );
        self.resident_bytes += tensors.iter().map(|t| t.bytes()).sum::<usize>();
        let prev = self.stash.insert(mb, tensors);
        assert!(prev.is_none(), "double stash for microbatch {mb}");
        self.high_water = self.high_water.max(self.stash.len());
        self.high_water_bytes = self.high_water_bytes.max(self.resident_bytes);
    }

    /// Remove and return a stash (for Bwd or Evict).
    pub fn take(&mut self, mb: u64) -> Vec<HostTensor> {
        let t = self
            .stash
            .remove(&mb)
            .unwrap_or_else(|| panic!("stash for microbatch {mb} not resident"));
        self.resident_bytes -= t.iter().map(|x| x.bytes()).sum::<usize>();
        t
    }

    pub fn contains(&self, mb: u64) -> bool {
        self.stash.contains_key(&mb)
    }
}

/// Messages to a BPipe remote store.
enum StoreMsg {
    Evict { mb: u64, tensors: Vec<HostTensor> },
    Load { mb: u64 },
    Shutdown,
}

/// Client handle an evictor stage uses to talk to its acceptor-side store.
pub struct RemoteStoreClient {
    tx: Sender<StoreMsg>,
    resp_rx: Receiver<(u64, Vec<HostTensor>)>,
}

impl RemoteStoreClient {
    /// Ship a stash to the acceptor (non-blocking).
    pub fn evict(&self, mb: u64, tensors: Vec<HostTensor>) {
        self.tx.send(StoreMsg::Evict { mb, tensors }).expect("remote store gone");
    }

    /// Fetch a stash back (blocks until the acceptor responds).
    pub fn load(&self, mb: u64) -> Vec<HostTensor> {
        self.tx.send(StoreMsg::Load { mb }).expect("remote store gone");
        let (got, tensors) = self.resp_rx.recv().expect("remote store gone");
        assert_eq!(got, mb, "remote store returned the wrong microbatch");
        tensors
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(StoreMsg::Shutdown);
    }
}

/// Stats the remote store reports when it shuts down.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RemoteStoreStats {
    pub evictions: u64,
    pub loads: u64,
    pub high_water_entries: usize,
    pub high_water_bytes: usize,
}

/// Spawn the acceptor-side store service thread for one evictor/acceptor
/// pair.  Returns the evictor's client handle and a receiver for the
/// final stats.
pub fn spawn_remote_store() -> (RemoteStoreClient, Receiver<RemoteStoreStats>) {
    let (tx, rx) = channel::<StoreMsg>();
    let (resp_tx, resp_rx) = channel();
    let (stats_tx, stats_rx): (SyncSender<RemoteStoreStats>, Receiver<RemoteStoreStats>) = sync_channel(1);
    std::thread::Builder::new()
        .name("bpipe-remote-store".into())
        .spawn(move || {
            let mut held: HashMap<u64, Vec<HostTensor>> = HashMap::new();
            let mut stats = RemoteStoreStats::default();
            let mut bytes = 0usize;
            for msg in rx {
                match msg {
                    StoreMsg::Evict { mb, tensors } => {
                        bytes += tensors.iter().map(|t| t.bytes()).sum::<usize>();
                        held.insert(mb, tensors);
                        stats.evictions += 1;
                        stats.high_water_entries = stats.high_water_entries.max(held.len());
                        stats.high_water_bytes = stats.high_water_bytes.max(bytes);
                    }
                    StoreMsg::Load { mb } => {
                        let tensors = held
                            .remove(&mb)
                            .unwrap_or_else(|| panic!("load of non-evicted microbatch {mb}"));
                        bytes -= tensors.iter().map(|t| t.bytes()).sum::<usize>();
                        stats.loads += 1;
                        resp_tx.send((mb, tensors)).ok();
                    }
                    StoreMsg::Shutdown => break,
                }
            }
            assert!(held.is_empty(), "remote store shut down with stashes still held");
            stats_tx.send(stats).ok();
        })
        .expect("spawn remote store");
    (RemoteStoreClient { tx, resp_rx }, stats_rx)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(n: usize) -> Vec<HostTensor> {
        vec![HostTensor::F32 { data: vec![0.5; n], shape: vec![n as i64] }]
    }

    #[test]
    fn store_tracks_high_water() {
        let mut s = ActivationStore::new(3);
        s.put(0, t(4));
        s.put(1, t(4));
        assert_eq!(s.high_water, 2);
        assert_eq!(s.resident_bytes, 32);
        s.take(0);
        s.put(2, t(4));
        assert_eq!(s.high_water, 2);
        assert_eq!(s.len(), 2);
        assert!(s.contains(2) && !s.contains(0));
    }

    #[test]
    #[should_panic(expected = "over capacity")]
    fn store_enforces_bound() {
        let mut s = ActivationStore::new(1);
        s.put(0, t(1));
        s.put(1, t(1));
    }

    #[test]
    #[should_panic(expected = "not resident")]
    fn take_missing_panics() {
        let mut s = ActivationStore::new(2);
        s.take(7);
    }

    #[test]
    fn remote_store_round_trip() {
        let (client, stats_rx) = spawn_remote_store();
        let payload = t(8);
        client.evict(3, payload.clone());
        client.evict(4, t(8));
        let back = client.load(3);
        assert_eq!(back, payload);
        let _ = client.load(4);
        client.shutdown();
        let stats = stats_rx.recv().unwrap();
        assert_eq!(stats.evictions, 2);
        assert_eq!(stats.loads, 2);
        assert_eq!(stats.high_water_entries, 2);
        assert_eq!(stats.high_water_bytes, 64);
    }

    #[test]
    fn host_tensor_literal_round_trip() {
        let ht = HostTensor::F32 { data: vec![1.0, 2.0, 3.0, 4.0], shape: vec![2, 2] };
        let lit = ht.to_literal().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let hi = HostTensor::I32 { data: vec![5, 6], shape: vec![2] };
        assert_eq!(hi.to_literal().unwrap().to_vec::<i32>().unwrap(), vec![5, 6]);
    }
}
