//! Bounded activation stash + the BPipe remote store — the hot path
//! moves [`Stash`] handles, never cloned tensor values.
//!
//! Each stage worker owns an [`ActivationStore`] holding the stage-input
//! tensor(s) of every in-flight `(microbatch, chunk)` key (the thing a
//! backward pass needs and the thing BPipe ships around).  The store
//! enforces the capacity bound the schedule was built for — exceeding it
//! is a bug, caught here rather than as a silent OOM.  Multi-chunk
//! (interleaved / V / zig-zag) programs share ONE store per worker: the
//! rebalance transform bounds the stage's resident count across all of
//! its chunks, and so does the store.
//!
//! Zero-alloc discipline: keys are dense (`mb < m`, `chunk < chunks`),
//! so the store is a preallocated slot array, not a map — `put`/`take`
//! are an `Option` swap, and a [`Stash`] is a fixed-size handle (input
//! tensor + optional targets), so stashing, evicting and loading move
//! ownership without ever touching the heap.  The remote-store channels
//! are *bounded* (`sync_channel`), whose ring buffers are allocated once
//! at wiring time — a send transfers the stash by value into
//! preallocated slots.
//!
//! The acceptor side of a BPipe pair is a [`RemoteStore`] service thread
//! owning the evicted tensors (the "partner device's free memory"): the
//! evictor pushes stashes to it and pulls them back before the backward.

use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};

pub use crate::runtime::HostTensor;

/// A stash key: `(microbatch, chunk)` — chunk is always 0 for
/// single-chunk schedules.
pub type StashKey = (u64, u64);

/// What one Fwd leaves behind for its Bwd: the stage-input tensor, plus
/// the target tokens on the loss stage.  Fixed-size by design — moving a
/// stash (into the store, through a BPipe channel) allocates nothing.
#[derive(Debug, Clone, PartialEq)]
pub struct Stash {
    pub x: HostTensor,
    pub extra: Option<HostTensor>,
}

impl Stash {
    pub fn single(x: HostTensor) -> Self {
        Stash { x, extra: None }
    }

    pub fn pair(x: HostTensor, extra: HostTensor) -> Self {
        Stash { x, extra: Some(extra) }
    }

    /// Payload bytes across both tensors.
    pub fn bytes(&self) -> usize {
        self.x.bytes() + self.extra.as_ref().map_or(0, |t| t.bytes())
    }
}

/// Per-stage bounded stash: `(mb, chunk)` → [`Stash`], backed by a
/// dense preallocated slot array.
pub struct ActivationStore {
    slots: Vec<Option<Stash>>,
    chunks: usize,
    len: usize,
    capacity: usize,
    /// peak resident entries (for the balance report)
    pub high_water: usize,
    /// total bytes currently resident
    pub resident_bytes: usize,
    /// peak resident bytes
    pub high_water_bytes: usize,
}

impl ActivationStore {
    /// A store enforcing `capacity` resident entries, with one slot per
    /// `(mb, chunk)` key of the program it serves.
    pub fn new(capacity: usize, microbatches: u64, chunks: u64) -> Self {
        let chunks = chunks.max(1) as usize;
        let n = microbatches.max(1) as usize * chunks;
        Self {
            slots: (0..n).map(|_| None).collect(),
            chunks,
            len: 0,
            capacity,
            high_water: 0,
            resident_bytes: 0,
            high_water_bytes: 0,
        }
    }

    /// The slot a key maps to, or `None` when it lies outside the
    /// planned program (the single source of truth for the layout).
    fn slot(&self, key: StashKey) -> Option<usize> {
        let i = key.0 as usize * self.chunks + key.1 as usize;
        ((key.1 as usize) < self.chunks && i < self.slots.len()).then_some(i)
    }

    fn idx(&self, key: StashKey) -> usize {
        self.slot(key).unwrap_or_else(|| {
            panic!(
                "stash key (mb {}, chunk {}) outside the planned program",
                key.0, key.1
            )
        })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert a stash; panics if the schedule violated its own bound.
    pub fn put(&mut self, key: StashKey, stash: Stash) {
        assert!(
            self.len < self.capacity,
            "activation store over capacity ({}): schedule bound violated at (mb {}, chunk {})",
            self.capacity,
            key.0,
            key.1
        );
        self.resident_bytes += stash.bytes();
        let slot = self.idx(key);
        let prev = self.slots[slot].replace(stash);
        assert!(prev.is_none(), "double stash for (mb {}, chunk {})", key.0, key.1);
        self.len += 1;
        self.high_water = self.high_water.max(self.len);
        self.high_water_bytes = self.high_water_bytes.max(self.resident_bytes);
    }

    /// Remove and return a stash (for Bwd or Evict).
    pub fn take(&mut self, key: StashKey) -> Stash {
        let slot = self.idx(key);
        let st = self.slots[slot]
            .take()
            .unwrap_or_else(|| panic!("stash for (mb {}, chunk {}) not resident", key.0, key.1));
        self.len -= 1;
        self.resident_bytes -= st.bytes();
        st
    }

    pub fn contains(&self, key: StashKey) -> bool {
        self.slot(key).map_or(false, |i| self.slots[i].is_some())
    }
}

/// Three-tier allocation-free wait: spin briefly (latency), yield a
/// while (let a runnable peer in), then sleep in 50 µs slices (release
/// the core through long pipeline bubbles — `nanosleep` touches no
/// heap).  Parking instead would register a waker with the channel,
/// which can allocate the first time each channel parks — and a
/// channel's *first* park can land after the warm-up step, breaking the
/// steady-state zero-alloc guarantee; polling keeps the worker hot path
/// off the allocator entirely, the laptop-scale analogue of a
/// NCCL-style progress loop.
fn backoff(spins: &mut u32) {
    *spins = spins.saturating_add(1);
    if *spins < 64 {
        std::hint::spin_loop();
    } else if *spins < 512 {
        std::thread::yield_now();
    } else {
        std::thread::sleep(std::time::Duration::from_micros(50));
    }
}

/// Why a spin-channel operation gave up: the peer hung up, or (with a
/// deadline) the peer went silent past the deadline.  `Timeout` is the
/// typed signal that turns a stalled pipeline neighbor into a
/// recoverable failure instead of an infinite spin — the supervisor
/// classifies it as `FailureCause::ChannelTimeout`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChannelError {
    /// Every peer is gone (disconnect cascade — usually secondary to a
    /// failure elsewhere in the pipeline).
    Closed,
    /// The peer is still connected but made no progress within the
    /// deadline.
    Timeout { waited_ms: u64 },
}

impl std::fmt::Display for ChannelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ChannelError::Closed => write!(f, "channel closed (peer gone)"),
            ChannelError::Timeout { waited_ms } => {
                write!(f, "channel timeout after {waited_ms} ms (peer silent)")
            }
        }
    }
}

impl std::error::Error for ChannelError {}

/// Allocation-free bounded-channel send: busy-polls `try_send` instead
/// of parking (see [`backoff`]).  Returns `Err(())` when the receiver
/// is gone.
pub fn spin_send<T>(tx: &SyncSender<T>, v: T) -> Result<(), ()> {
    spin_send_deadline(tx, v, None).map_err(|_| ())
}

/// Receive twin of [`spin_send`]: `Err(())` once every sender is gone
/// and the channel is drained (matching `recv`'s disconnect semantics).
pub fn spin_recv<T>(rx: &Receiver<T>) -> Result<T, ()> {
    spin_recv_deadline(rx, None).map_err(|_| ())
}

/// [`spin_send`] with an optional deadline.  `deadline: None` is
/// byte-for-byte the old unbounded spin (no clock reads on the hot
/// path); with a deadline, the clock is only consulted once the wait
/// leaves the short spin tier, and the value is dropped on timeout (the
/// peer was not making progress anyway).
pub fn spin_send_deadline<T>(
    tx: &SyncSender<T>,
    mut v: T,
    deadline: Option<std::time::Duration>,
) -> Result<(), ChannelError> {
    use std::sync::mpsc::TrySendError;
    let mut spins = 0u32;
    let started = deadline.map(|_| std::time::Instant::now());
    loop {
        match tx.try_send(v) {
            Ok(()) => return Ok(()),
            Err(TrySendError::Full(back)) => {
                v = back;
                if let (Some(limit), Some(t0)) = (deadline, started) {
                    if spins >= 64 && t0.elapsed() >= limit {
                        return Err(ChannelError::Timeout { waited_ms: limit.as_millis() as u64 });
                    }
                }
                backoff(&mut spins);
            }
            Err(TrySendError::Disconnected(_)) => return Err(ChannelError::Closed),
        }
    }
}

/// [`spin_recv`] with an optional deadline (see
/// [`spin_send_deadline`] for the deadline semantics).
pub fn spin_recv_deadline<T>(
    rx: &Receiver<T>,
    deadline: Option<std::time::Duration>,
) -> Result<T, ChannelError> {
    use std::sync::mpsc::TryRecvError;
    let mut spins = 0u32;
    let started = deadline.map(|_| std::time::Instant::now());
    loop {
        match rx.try_recv() {
            Ok(v) => return Ok(v),
            Err(TryRecvError::Empty) => {
                if let (Some(limit), Some(t0)) = (deadline, started) {
                    if spins >= 64 && t0.elapsed() >= limit {
                        return Err(ChannelError::Timeout { waited_ms: limit.as_millis() as u64 });
                    }
                }
                backoff(&mut spins);
            }
            Err(TryRecvError::Disconnected) => return Err(ChannelError::Closed),
        }
    }
}

/// Messages to a BPipe remote store.
enum StoreMsg {
    Evict { key: StashKey, stash: Stash },
    Load { key: StashKey },
    Shutdown,
}

/// Client handle an evictor stage uses to talk to its acceptor-side store.
pub struct RemoteStoreClient {
    tx: SyncSender<StoreMsg>,
    resp_rx: Receiver<(StashKey, Stash)>,
    deadline: Option<std::time::Duration>,
}

impl RemoteStoreClient {
    /// Ship a stash to the acceptor (non-blocking while the acceptor's
    /// in-flight window has room; allocation-free either way).  A typed
    /// [`ChannelError`] (closed store, or deadline exceeded) surfaces as
    /// a worker failure for the supervisor instead of a panic.
    pub fn evict(&self, key: StashKey, stash: Stash) -> anyhow::Result<()> {
        spin_send_deadline(&self.tx, StoreMsg::Evict { key, stash }, self.deadline)
            .map_err(|e| anyhow::Error::new(e).context("BPipe evict to remote store"))
    }

    /// Fetch a stash back (busy-waits until the acceptor responds, up to
    /// the client's deadline when one is set).
    pub fn load(&self, key: StashKey) -> anyhow::Result<Stash> {
        spin_send_deadline(&self.tx, StoreMsg::Load { key }, self.deadline)
            .map_err(|e| anyhow::Error::new(e).context("BPipe load request to remote store"))?;
        let (got, stash) = spin_recv_deadline(&self.resp_rx, self.deadline)
            .map_err(|e| anyhow::Error::new(e).context("BPipe load response from remote store"))?;
        anyhow::ensure!(got == key, "remote store returned the wrong stash");
        Ok(stash)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(StoreMsg::Shutdown);
    }
}

/// Stats the remote store reports when it shuts down.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RemoteStoreStats {
    pub evictions: u64,
    pub loads: u64,
    pub high_water_entries: usize,
    pub high_water_bytes: usize,
}

/// Spawn the acceptor-side store service thread for one evictor/acceptor
/// pair.  `max_inflight` bounds the evictions simultaneously held (the
/// schedule's resident-eviction high water — `m × chunks` is always
/// safe); the channel ring buffers are sized once from it, so the
/// evictor's steady-state sends allocate nothing.  Returns the evictor's
/// client handle and a receiver for the final stats.
pub fn spawn_remote_store(
    max_inflight: usize,
) -> (RemoteStoreClient, Receiver<RemoteStoreStats>) {
    spawn_remote_store_with(max_inflight, None)
}

/// [`spawn_remote_store`] with an optional client-side deadline on every
/// evict/load interaction (the supervised runtime's stall detector).
///
/// Teardown discipline: the `held.is_empty()` invariant is asserted only
/// on an orderly [`RemoteStoreClient::shutdown`].  When the client side
/// simply disappears (a worker failed and the disconnect cascade is
/// tearing the pipeline down), the store drops whatever it still holds
/// and exits quietly — a secondary panic here would mask the root cause.
pub fn spawn_remote_store_with(
    max_inflight: usize,
    deadline: Option<std::time::Duration>,
) -> (RemoteStoreClient, Receiver<RemoteStoreStats>) {
    let cap = max_inflight.max(1);
    let (tx, rx) = sync_channel::<StoreMsg>(cap + 1);
    let (resp_tx, resp_rx) = sync_channel::<(StashKey, Stash)>(1);
    let (stats_tx, stats_rx): (SyncSender<RemoteStoreStats>, Receiver<RemoteStoreStats>) =
        sync_channel(1);
    std::thread::Builder::new()
        .name("bpipe-remote-store".into())
        .spawn(move || {
            let mut held: HashMap<StashKey, Stash> = HashMap::with_capacity(cap);
            let mut stats = RemoteStoreStats::default();
            let mut bytes = 0usize;
            let mut orderly = false;
            for msg in rx {
                match msg {
                    StoreMsg::Evict { key, stash } => {
                        bytes += stash.bytes();
                        held.insert(key, stash);
                        stats.evictions += 1;
                        stats.high_water_entries = stats.high_water_entries.max(held.len());
                        stats.high_water_bytes = stats.high_water_bytes.max(bytes);
                    }
                    StoreMsg::Load { key } => {
                        let stash = held.remove(&key).unwrap_or_else(|| {
                            panic!("load of non-evicted (mb {}, chunk {})", key.0, key.1)
                        });
                        bytes -= stash.bytes();
                        stats.loads += 1;
                        resp_tx.send((key, stash)).ok();
                    }
                    StoreMsg::Shutdown => {
                        orderly = true;
                        break;
                    }
                }
            }
            if orderly {
                assert!(held.is_empty(), "remote store shut down with stashes still held");
            }
            stats_tx.send(stats).ok();
        })
        .expect("spawn remote store");
    (RemoteStoreClient { tx, resp_rx, deadline }, stats_rx)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(n: usize) -> Stash {
        Stash::single(HostTensor::F32 { data: vec![0.5; n], shape: vec![n as i64] })
    }

    #[test]
    fn store_tracks_high_water() {
        let mut s = ActivationStore::new(3, 4, 1);
        s.put((0, 0), t(4));
        s.put((1, 0), t(4));
        assert_eq!(s.high_water, 2);
        assert_eq!(s.resident_bytes, 32);
        s.take((0, 0));
        s.put((2, 0), t(4));
        assert_eq!(s.high_water, 2);
        assert_eq!(s.len(), 2);
        assert!(s.contains((2, 0)) && !s.contains((0, 0)));
    }

    #[test]
    fn chunk_keys_are_independent() {
        let mut s = ActivationStore::new(4, 2, 2);
        s.put((0, 0), t(2));
        s.put((0, 1), t(6));
        assert_eq!(s.len(), 2);
        assert_eq!(s.take((0, 1)).x.len(), 6);
        assert!(s.contains((0, 0)));
    }

    #[test]
    fn pair_stash_counts_both_tensors() {
        let mut s = ActivationStore::new(2, 2, 1);
        let st = Stash::pair(
            HostTensor::vec_f32(vec![0.0; 4]),
            HostTensor::I32 { data: vec![0; 2], shape: vec![2] },
        );
        assert_eq!(st.bytes(), 24);
        s.put((1, 0), st);
        assert_eq!(s.resident_bytes, 24);
        let back = s.take((1, 0));
        assert!(back.extra.is_some());
        assert_eq!(s.resident_bytes, 0);
    }

    #[test]
    #[should_panic(expected = "over capacity")]
    fn store_enforces_bound() {
        let mut s = ActivationStore::new(1, 4, 1);
        s.put((0, 0), t(1));
        s.put((1, 0), t(1));
    }

    #[test]
    #[should_panic(expected = "not resident")]
    fn take_missing_panics() {
        let mut s = ActivationStore::new(2, 8, 1);
        s.take((7, 0));
    }

    #[test]
    #[should_panic(expected = "outside the planned program")]
    fn out_of_range_key_panics() {
        let mut s = ActivationStore::new(2, 2, 1);
        s.put((5, 0), t(1));
    }

    #[test]
    fn remote_store_round_trip() {
        let (client, stats_rx) = spawn_remote_store(4);
        let payload = t(8);
        client.evict((3, 0), payload.clone()).unwrap();
        client.evict((3, 1), t(8)).unwrap();
        let back = client.load((3, 0)).unwrap();
        assert_eq!(back, payload);
        let _ = client.load((3, 1)).unwrap();
        client.shutdown();
        let stats = stats_rx.recv().unwrap();
        assert_eq!(stats.evictions, 2);
        assert_eq!(stats.loads, 2);
        assert_eq!(stats.high_water_entries, 2);
        assert_eq!(stats.high_water_bytes, 64);
    }

    #[test]
    fn recv_deadline_times_out_instead_of_spinning() {
        let (_tx, rx) = sync_channel::<u32>(1);
        let started = std::time::Instant::now();
        let got = spin_recv_deadline(&rx, Some(std::time::Duration::from_millis(30)));
        assert_eq!(got, Err(ChannelError::Timeout { waited_ms: 30 }));
        assert!(started.elapsed() < std::time::Duration::from_secs(5), "bounded wait");
    }

    #[test]
    fn send_deadline_times_out_when_ring_is_full() {
        let (tx, _rx) = sync_channel::<u32>(1);
        tx.send(1).unwrap(); // fill the ring; nobody drains it
        let got = spin_send_deadline(&tx, 2, Some(std::time::Duration::from_millis(30)));
        assert_eq!(got, Err(ChannelError::Timeout { waited_ms: 30 }));
    }

    #[test]
    fn disconnect_reports_closed_not_timeout() {
        let (tx, rx) = sync_channel::<u32>(1);
        drop(tx);
        let got = spin_recv_deadline(&rx, Some(std::time::Duration::from_millis(30)));
        assert_eq!(got, Err(ChannelError::Closed));
    }

    #[test]
    fn abandoned_store_exits_without_panicking() {
        let (client, stats_rx) = spawn_remote_store(2);
        client.evict((0, 0), t(4)).unwrap();
        drop(client); // disconnect cascade: stash still held, no Shutdown
        let stats = stats_rx.recv().unwrap();
        assert_eq!(stats.evictions, 1, "store exits cleanly and still reports stats");
    }
}
