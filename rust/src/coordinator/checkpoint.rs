//! Training-state checkpointing: per-stage parameters + Adam moments,
//! plus a leader-side metadata file, in a dependency-free binary format.
//!
//! Layout on disk (one directory per run):
//!
//! ```text
//! <dir>/meta.txt            # key = value: steps_done, stages, microbatches
//! <dir>/stage<k>.ckpt       # [magic u32][n u64][params f32*n][m f32*n][v f32*n]
//! ```
//!
//! Writes are atomic (tmp file + rename) so a crash mid-checkpoint never
//! corrupts the previous one.  Resume is exact: together with the
//! deterministic corpus fast-forward in the leader, a resumed run
//! produces bit-identical losses to an uninterrupted one (see
//! `integration_runtime::checkpoint_resume_is_bit_identical`).

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: u32 = 0xB1_9E_C4_99;

/// One stage's optimizer-visible state.
#[derive(Debug, Clone, PartialEq)]
pub struct StageCheckpoint {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> anyhow::Result<()> {
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

fn read_f32s(r: &mut impl Read, n: usize) -> anyhow::Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

impl StageCheckpoint {
    /// Atomically write this checkpoint to `<dir>/stage<k>.ckpt`.
    pub fn save(&self, dir: &Path, stage: u64) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.params.len() == self.m.len() && self.m.len() == self.v.len(),
            "inconsistent checkpoint vector lengths"
        );
        std::fs::create_dir_all(dir)?;
        let tmp = dir.join(format!(".stage{stage}.ckpt.tmp"));
        {
            let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            f.write_all(&MAGIC.to_le_bytes())?;
            f.write_all(&(self.params.len() as u64).to_le_bytes())?;
            write_f32s(&mut f, &self.params)?;
            write_f32s(&mut f, &self.m)?;
            write_f32s(&mut f, &self.v)?;
            f.flush()?;
        }
        std::fs::rename(&tmp, Self::path(dir, stage))?;
        Ok(())
    }

    /// Load `<dir>/stage<k>.ckpt`, verifying magic and length.
    pub fn load(dir: &Path, stage: u64, expect_n: usize) -> anyhow::Result<Self> {
        let path = Self::path(dir, stage);
        let mut f = std::io::BufReader::new(
            std::fs::File::open(&path)
                .map_err(|e| anyhow::anyhow!("cannot open checkpoint {path:?}: {e}"))?,
        );
        let mut word = [0u8; 4];
        f.read_exact(&mut word)?;
        anyhow::ensure!(u32::from_le_bytes(word) == MAGIC, "bad checkpoint magic in {path:?}");
        let mut len = [0u8; 8];
        f.read_exact(&mut len)?;
        let n = u64::from_le_bytes(len) as usize;
        anyhow::ensure!(
            n == expect_n,
            "checkpoint {path:?} has {n} params, stage expects {expect_n} \
             (artifacts changed since the checkpoint was written?)"
        );
        Ok(Self {
            params: read_f32s(&mut f, n)?,
            m: read_f32s(&mut f, n)?,
            v: read_f32s(&mut f, n)?,
        })
    }

    pub fn path(dir: &Path, stage: u64) -> PathBuf {
        dir.join(format!("stage{stage}.ckpt"))
    }
}

/// Leader-side run metadata.  `chunks` is the virtual-pipeline chunk
/// count of the schedule family the run used (1 for 1F1B/GPipe) —
/// per-chunk state files are keyed by VIRTUAL stage id, so a resumed
/// run must re-plan with the same family shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CheckpointMeta {
    pub steps_done: u64,
    pub stages: u64,
    pub chunks: u64,
    pub microbatches: u64,
    pub seed: u64,
}

impl CheckpointMeta {
    pub fn save(&self, dir: &Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        let tmp = dir.join(".meta.txt.tmp");
        std::fs::write(
            &tmp,
            format!(
                "steps_done = {}\nstages = {}\nchunks = {}\nmicrobatches = {}\nseed = {}\n",
                self.steps_done, self.stages, self.chunks, self.microbatches, self.seed
            ),
        )?;
        std::fs::rename(tmp, dir.join("meta.txt"))?;
        Ok(())
    }

    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(dir.join("meta.txt"))?;
        let mut kv = std::collections::HashMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                kv.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        let get = |k: &str| -> anyhow::Result<u64> {
            Ok(kv.get(k).ok_or_else(|| anyhow::anyhow!("meta missing {k}"))?.parse()?)
        };
        Ok(Self {
            steps_done: get("steps_done")?,
            stages: get("stages")?,
            // absent in pre-virtual-pipeline checkpoints: single-chunk
            chunks: match kv.get("chunks") {
                Some(v) => v.parse()?,
                None => 1,
            },
            microbatches: get("microbatches")?,
            seed: get("seed")?,
        })
    }

    pub fn exists(dir: &Path) -> bool {
        dir.join("meta.txt").exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("bpipe-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn stage_checkpoint_round_trip() {
        let dir = tdir("rt");
        let ck = StageCheckpoint {
            params: (0..1000).map(|i| i as f32 * 0.5).collect(),
            m: vec![1.5; 1000],
            v: vec![-0.25; 1000],
        };
        ck.save(&dir, 2).unwrap();
        let back = StageCheckpoint::load(&dir, 2, 1000).unwrap();
        assert_eq!(ck, back);
    }

    #[test]
    fn wrong_length_is_rejected() {
        let dir = tdir("len");
        let ck = StageCheckpoint { params: vec![1.0; 10], m: vec![0.0; 10], v: vec![0.0; 10] };
        ck.save(&dir, 0).unwrap();
        let err = StageCheckpoint::load(&dir, 0, 11).unwrap_err();
        assert!(err.to_string().contains("expects 11"));
    }

    #[test]
    fn corrupt_magic_is_rejected() {
        let dir = tdir("magic");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(StageCheckpoint::path(&dir, 1), b"garbage-not-a-checkpoint").unwrap();
        assert!(StageCheckpoint::load(&dir, 1, 4).is_err());
    }

    #[test]
    fn meta_round_trip_and_exists() {
        let dir = tdir("meta");
        assert!(!CheckpointMeta::exists(&dir));
        let meta =
            CheckpointMeta { steps_done: 42, stages: 4, chunks: 2, microbatches: 8, seed: 7 };
        meta.save(&dir).unwrap();
        assert!(CheckpointMeta::exists(&dir));
        assert_eq!(CheckpointMeta::load(&dir).unwrap(), meta);
    }

    #[test]
    fn meta_without_chunks_defaults_to_one() {
        // pre-virtual-pipeline checkpoints carried no chunks line
        let dir = tdir("meta-compat");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.txt"),
            "steps_done = 3\nstages = 4\nmicrobatches = 8\nseed = 0\n",
        )
        .unwrap();
        assert_eq!(CheckpointMeta::load(&dir).unwrap().chunks, 1);
    }

    #[test]
    fn missing_checkpoint_is_clean_error() {
        let dir = tdir("missing");
        assert!(StageCheckpoint::load(&dir, 0, 10).is_err());
        assert!(CheckpointMeta::load(&dir).is_err());
    }
}
