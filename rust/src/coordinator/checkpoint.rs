//! Training-state checkpointing: per-stage parameters + Adam moments,
//! plus a leader-side metadata file, in a dependency-free binary format.
//!
//! Layout on disk (one directory per run):
//!
//! ```text
//! <dir>/meta.txt            # key = value: steps_done, stages, microbatches
//! <dir>/stage<k>.ckpt       # current generation
//! <dir>/stage<k>.prev.ckpt  # previous generation (crash-recovery fallback)
//! ```
//!
//! File format (all little-endian):
//!
//! ```text
//! [magic u32][step u64][n u64][params f32*n][m f32*n][v f32*n][fnv1a-64 u64]
//! ```
//!
//! The trailing checksum is FNV-1a-64 over every preceding byte; a
//! mismatch (torn write, bit rot, truncation) surfaces as a typed
//! [`CorruptCheckpoint`] instead of a garbage resume.  Writes are atomic
//! *and* two-generation: the new file is fully written and fsynced to a
//! temp name, the old current is rotated to `.prev.ckpt`, and only then
//! is the temp renamed into place — a crash at any instant leaves at
//! least one valid generation on disk.
//!
//! Two generations matter for crash recovery: stages checkpoint
//! independently, so a mid-step failure can leave stage A at step k and
//! stage B at step k−1.  With the step recorded in each file,
//! [`latest_common_step`] finds the newest step EVERY stage can restore
//! (pipeline data dependencies bound the skew to one generation), which
//! is what the supervisor rolls back to.  Resume is exact: together with
//! the deterministic corpus fast-forward in the leader, a resumed run
//! produces bit-identical losses to an uninterrupted one (see
//! `integration_runtime::checkpoint_resume_is_bit_identical`).

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

/// v2 magic — v1 (`0xB1_9E_C4_99`) files carried no step or checksum
/// and are rejected as corrupt (clean format break; checkpoints are
/// per-run scratch state, not long-lived archives).
const MAGIC: u32 = 0xB1_9E_C4_9A;

/// FNV-1a 64-bit over `bytes` — dependency-free content integrity.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Typed integrity failure on checkpoint load: bad magic, truncation,
/// or checksum mismatch.  The supervisor treats a stage whose current
/// generation is corrupt as simply not having that generation — it falls
/// back to `.prev.ckpt` or, failing that, a fresh start.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CorruptCheckpoint {
    pub path: PathBuf,
    pub detail: String,
}

impl std::fmt::Display for CorruptCheckpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "corrupt checkpoint {:?}: {}", self.path, self.detail)
    }
}

impl std::error::Error for CorruptCheckpoint {}

/// One stage's optimizer-visible state.
#[derive(Debug, Clone, PartialEq)]
pub struct StageCheckpoint {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn read_f32s(r: &mut impl Read, n: usize) -> std::io::Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

impl StageCheckpoint {
    /// [`Self::save_at`] without a step tag (step 0) — kept for callers
    /// that only ever want the latest state.
    pub fn save(&self, dir: &Path, stage: u64) -> anyhow::Result<()> {
        self.save_at(dir, stage, 0)
    }

    /// Atomically write this checkpoint as the stage's current
    /// generation, tagged with the global step it snapshots; the old
    /// current generation rotates to `.prev.ckpt`.
    ///
    /// Crash-safety order: (1) the new file is fully written and synced
    /// under a temp name, (2) current → prev, (3) temp → current.  Any
    /// interruption leaves ≥ 1 valid generation.
    ///
    /// One-shot convenience over [`CheckpointWriter`]; hot paths that
    /// checkpoint repeatedly should hold a writer instead so the
    /// serialization buffer is reused across saves.
    pub fn save_at(&self, dir: &Path, stage: u64, step: u64) -> anyhow::Result<()> {
        CheckpointWriter::new(dir, stage).save(step, &self.params, &self.m, &self.v)
    }

    fn load_file(path: &Path, expect_n: usize) -> anyhow::Result<(u64, Self)> {
        let corrupt = |detail: String| {
            anyhow::Error::new(CorruptCheckpoint { path: path.to_path_buf(), detail })
        };
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("cannot open checkpoint {path:?}: {e}"))?;
        if bytes.len() < 4 + 8 + 8 + 8 {
            return Err(corrupt(format!("only {} bytes — truncated header", bytes.len())));
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().unwrap());
        let computed = fnv1a64(body);
        if stored != computed {
            return Err(corrupt(format!(
                "checksum mismatch (stored {stored:#018x}, computed {computed:#018x})"
            )));
        }
        let mut r = body;
        let mut word = [0u8; 4];
        r.read_exact(&mut word)?;
        let magic = u32::from_le_bytes(word);
        if magic != MAGIC {
            return Err(corrupt(format!("bad magic {magic:#010x}")));
        }
        let mut u64buf = [0u8; 8];
        r.read_exact(&mut u64buf)?;
        let step = u64::from_le_bytes(u64buf);
        r.read_exact(&mut u64buf)?;
        let n = u64::from_le_bytes(u64buf) as usize;
        anyhow::ensure!(
            n == expect_n,
            "checkpoint {path:?} has {n} params, stage expects {expect_n} \
             (artifacts changed since the checkpoint was written?)"
        );
        if body.len() != 4 + 8 + 8 + n * 12 {
            return Err(corrupt(format!("payload is {} bytes, expected {}", body.len(), n * 12)));
        }
        let ck = Self {
            params: read_f32s(&mut r, n)?,
            m: read_f32s(&mut r, n)?,
            v: read_f32s(&mut r, n)?,
        };
        Ok((step, ck))
    }

    /// Load the stage's newest valid generation, whatever step it holds.
    pub fn load(dir: &Path, stage: u64, expect_n: usize) -> anyhow::Result<Self> {
        match Self::load_file(&Self::path(dir, stage), expect_n) {
            Ok((_, ck)) => Ok(ck),
            Err(cur_err) => match Self::load_file(&Self::prev_path(dir, stage), expect_n) {
                Ok((_, ck)) => Ok(ck),
                Err(_) => Err(cur_err),
            },
        }
    }

    /// Load the generation snapshotting exactly `step`, searching
    /// current then previous.
    pub fn load_at(dir: &Path, stage: u64, expect_n: usize, step: u64) -> anyhow::Result<Self> {
        for path in [Self::path(dir, stage), Self::prev_path(dir, stage)] {
            if let Ok((s, ck)) = Self::load_file(&path, expect_n) {
                if s == step {
                    return Ok(ck);
                }
            }
        }
        anyhow::bail!("no valid generation of stage {stage} in {dir:?} holds step {step}")
    }

    /// Steps of the stage's valid generations, newest first (loadable
    /// headers + intact checksums only; length is not checked).
    pub fn available_steps(dir: &Path, stage: u64) -> Vec<u64> {
        let mut steps = Vec::with_capacity(2);
        for path in [Self::path(dir, stage), Self::prev_path(dir, stage)] {
            if let Ok(bytes) = std::fs::read(&path) {
                if bytes.len() >= 4 + 8 + 8 + 8 {
                    let (body, tail) = bytes.split_at(bytes.len() - 8);
                    let stored = u64::from_le_bytes(tail.try_into().unwrap());
                    if stored == fnv1a64(body) && body[..4] == MAGIC.to_le_bytes() {
                        steps.push(u64::from_le_bytes(body[4..12].try_into().unwrap()));
                    }
                }
            }
        }
        steps
    }

    pub fn path(dir: &Path, stage: u64) -> PathBuf {
        dir.join(format!("stage{stage}.ckpt"))
    }

    pub fn prev_path(dir: &Path, stage: u64) -> PathBuf {
        dir.join(format!("stage{stage}.prev.ckpt"))
    }
}

/// Reusable save path for one (virtual) stage: holds the stage's three
/// paths and the serialization buffer across saves, and borrows the
/// state slices directly instead of staging them through owned `Vec`s.
/// The first save grows `scratch` to the file's full size; every later
/// save of the same shape reuses it, so steady-state checkpointing is
/// allocation-free on the caller's side (see
/// `rust/tests/alloc_steady_state.rs`).  On-disk result and
/// crash-safety order are identical to [`StageCheckpoint::save_at`].
#[derive(Debug)]
pub struct CheckpointWriter {
    tmp: PathBuf,
    cur: PathBuf,
    prev: PathBuf,
    scratch: Vec<u8>,
}

impl CheckpointWriter {
    pub fn new(dir: &Path, stage: u64) -> Self {
        Self {
            tmp: dir.join(format!(".stage{stage}.ckpt.tmp")),
            cur: StageCheckpoint::path(dir, stage),
            prev: StageCheckpoint::prev_path(dir, stage),
            scratch: Vec::new(),
        }
    }

    /// Atomic two-generation save of borrowed state slices, tagged with
    /// the global step they snapshot.
    pub fn save(&mut self, step: u64, params: &[f32], m: &[f32], v: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(
            params.len() == m.len() && m.len() == v.len(),
            "inconsistent checkpoint vector lengths"
        );
        if let Some(dir) = self.cur.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let n = params.len();
        let buf = &mut self.scratch;
        buf.clear();
        buf.reserve(4 + 8 + 8 + n * 12 + 8);
        buf.extend_from_slice(&MAGIC.to_le_bytes());
        buf.extend_from_slice(&step.to_le_bytes());
        buf.extend_from_slice(&(n as u64).to_le_bytes());
        push_f32s(buf, params);
        push_f32s(buf, m);
        push_f32s(buf, v);
        let sum = fnv1a64(buf);
        buf.extend_from_slice(&sum.to_le_bytes());

        {
            let mut f = std::fs::File::create(&self.tmp)?;
            f.write_all(buf)?;
            f.sync_all()?;
        }
        if self.cur.exists() {
            std::fs::rename(&self.cur, &self.prev)?;
        }
        std::fs::rename(&self.tmp, &self.cur)?;
        Ok(())
    }
}

/// The newest global step EVERY listed (virtual) stage can restore from
/// a valid on-disk generation — the supervisor's rollback target.
/// Returns 0 (fresh start) when any stage has no valid generation at
/// all.
pub fn latest_common_step(dir: &Path, stages: impl IntoIterator<Item = u64>) -> u64 {
    let mut common = u64::MAX;
    let mut any = false;
    for stage in stages {
        any = true;
        let newest = StageCheckpoint::available_steps(dir, stage).into_iter().max();
        match newest {
            Some(s) => common = common.min(s),
            None => return 0,
        }
    }
    if any && common != u64::MAX {
        common
    } else {
        0
    }
}

/// Leader-side run metadata.  `chunks` is the virtual-pipeline chunk
/// count of the schedule family the run used (1 for 1F1B/GPipe) —
/// per-chunk state files are keyed by VIRTUAL stage id, so a resumed
/// run must re-plan with the same family shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CheckpointMeta {
    pub steps_done: u64,
    pub stages: u64,
    pub chunks: u64,
    pub microbatches: u64,
    pub seed: u64,
}

impl CheckpointMeta {
    pub fn save(&self, dir: &Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        let tmp = dir.join(".meta.txt.tmp");
        std::fs::write(
            &tmp,
            format!(
                "steps_done = {}\nstages = {}\nchunks = {}\nmicrobatches = {}\nseed = {}\n",
                self.steps_done, self.stages, self.chunks, self.microbatches, self.seed
            ),
        )?;
        std::fs::rename(tmp, dir.join("meta.txt"))?;
        Ok(())
    }

    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(dir.join("meta.txt"))?;
        let mut kv = std::collections::HashMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                kv.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        let get = |k: &str| -> anyhow::Result<u64> {
            Ok(kv.get(k).ok_or_else(|| anyhow::anyhow!("meta missing {k}"))?.parse()?)
        };
        Ok(Self {
            steps_done: get("steps_done")?,
            stages: get("stages")?,
            // absent in pre-virtual-pipeline checkpoints: single-chunk
            chunks: match kv.get("chunks") {
                Some(v) => v.parse()?,
                None => 1,
            },
            microbatches: get("microbatches")?,
            seed: get("seed")?,
        })
    }

    pub fn exists(dir: &Path) -> bool {
        dir.join("meta.txt").exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("bpipe-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn ck(fill: f32, n: usize) -> StageCheckpoint {
        StageCheckpoint { params: vec![fill; n], m: vec![fill * 0.5; n], v: vec![fill * 0.25; n] }
    }

    #[test]
    fn stage_checkpoint_round_trip() {
        let dir = tdir("rt");
        let ck = StageCheckpoint {
            params: (0..1000).map(|i| i as f32 * 0.5).collect(),
            m: vec![1.5; 1000],
            v: vec![-0.25; 1000],
        };
        ck.save(&dir, 2).unwrap();
        let back = StageCheckpoint::load(&dir, 2, 1000).unwrap();
        assert_eq!(ck, back);
    }

    #[test]
    fn wrong_length_is_rejected() {
        let dir = tdir("len");
        let ck = StageCheckpoint { params: vec![1.0; 10], m: vec![0.0; 10], v: vec![0.0; 10] };
        ck.save(&dir, 0).unwrap();
        let err = StageCheckpoint::load(&dir, 0, 11).unwrap_err();
        assert!(err.to_string().contains("expects 11"));
    }

    #[test]
    fn corrupt_magic_is_rejected() {
        let dir = tdir("magic");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(StageCheckpoint::path(&dir, 1), b"garbage-not-a-checkpoint").unwrap();
        assert!(StageCheckpoint::load(&dir, 1, 4).is_err());
    }

    #[test]
    fn bit_flip_is_a_typed_corruption() {
        let dir = tdir("flip");
        ck(1.0, 16).save_at(&dir, 0, 3).unwrap();
        let path = StageCheckpoint::path(&dir, 0);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, bytes).unwrap();
        let err = StageCheckpoint::load_at(&dir, 0, 16, 3).unwrap_err();
        assert!(err.to_string().contains("no valid generation"), "{err}");
        // with only the corrupt generation, the direct load surfaces the
        // typed error
        let err = StageCheckpoint::load_file(&path, 16).unwrap_err();
        assert!(err.downcast_ref::<CorruptCheckpoint>().is_some(), "{err}");
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn writer_reuses_scratch_and_matches_save_at() {
        let dir = tdir("writer");
        let mut w = CheckpointWriter::new(&dir, 5);
        let a = ck(1.0, 64);
        w.save(1, &a.params, &a.m, &a.v).unwrap();
        let cap = w.scratch.capacity();
        assert!(cap >= 4 + 8 + 8 + 64 * 12 + 8);
        let b = ck(2.0, 64);
        w.save(2, &b.params, &b.m, &b.v).unwrap();
        assert_eq!(w.scratch.capacity(), cap, "steady-state save must not regrow scratch");
        // same generations and bytes a pair of save_at calls would leave
        assert_eq!(StageCheckpoint::available_steps(&dir, 5), vec![2, 1]);
        assert_eq!(StageCheckpoint::load_at(&dir, 5, 64, 1).unwrap(), a);
        assert_eq!(StageCheckpoint::load_at(&dir, 5, 64, 2).unwrap(), b);
    }

    #[test]
    fn generations_rotate_and_load_by_step() {
        let dir = tdir("gen");
        ck(1.0, 8).save_at(&dir, 3, 1).unwrap();
        ck(2.0, 8).save_at(&dir, 3, 2).unwrap();
        assert_eq!(StageCheckpoint::available_steps(&dir, 3), vec![2, 1]);
        assert_eq!(StageCheckpoint::load_at(&dir, 3, 8, 2).unwrap(), ck(2.0, 8));
        assert_eq!(StageCheckpoint::load_at(&dir, 3, 8, 1).unwrap(), ck(1.0, 8), "prev gen");
        assert!(StageCheckpoint::load_at(&dir, 3, 8, 5).is_err());
        // plain load picks the newest
        assert_eq!(StageCheckpoint::load(&dir, 3, 8).unwrap(), ck(2.0, 8));
    }

    #[test]
    fn corrupt_current_falls_back_to_prev() {
        let dir = tdir("fallback");
        ck(1.0, 8).save_at(&dir, 0, 1).unwrap();
        ck(2.0, 8).save_at(&dir, 0, 2).unwrap();
        std::fs::write(StageCheckpoint::path(&dir, 0), b"torn write").unwrap();
        assert_eq!(StageCheckpoint::load(&dir, 0, 8).unwrap(), ck(1.0, 8));
        assert_eq!(StageCheckpoint::available_steps(&dir, 0), vec![1]);
    }

    #[test]
    fn latest_common_step_is_min_over_stage_max() {
        let dir = tdir("common");
        // stage 0 reached step 3 (prev 2); stage 1 only reached step 2
        ck(1.0, 4).save_at(&dir, 0, 2).unwrap();
        ck(1.5, 4).save_at(&dir, 0, 3).unwrap();
        ck(2.0, 4).save_at(&dir, 1, 1).unwrap();
        ck(2.5, 4).save_at(&dir, 1, 2).unwrap();
        assert_eq!(latest_common_step(&dir, [0, 1]), 2);
        assert_eq!(latest_common_step(&dir, [0]), 3);
        // a stage with no files at all forces a fresh start
        assert_eq!(latest_common_step(&dir, [0, 1, 9]), 0);
        assert_eq!(latest_common_step(&dir, std::iter::empty::<u64>()), 0);
    }

    #[test]
    fn meta_round_trip_and_exists() {
        let dir = tdir("meta");
        assert!(!CheckpointMeta::exists(&dir));
        let meta =
            CheckpointMeta { steps_done: 42, stages: 4, chunks: 2, microbatches: 8, seed: 7 };
        meta.save(&dir).unwrap();
        assert!(CheckpointMeta::exists(&dir));
        assert_eq!(CheckpointMeta::load(&dir).unwrap(), meta);
    }

    #[test]
    fn meta_without_chunks_defaults_to_one() {
        // pre-virtual-pipeline checkpoints carried no chunks line
        let dir = tdir("meta-compat");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.txt"),
            "steps_done = 3\nstages = 4\nmicrobatches = 8\nseed = 0\n",
        )
        .unwrap();
        assert_eq!(CheckpointMeta::load(&dir).unwrap().chunks, 1);
    }

    #[test]
    fn missing_checkpoint_is_clean_error() {
        let dir = tdir("missing");
        assert!(StageCheckpoint::load(&dir, 0, 10).is_err());
        assert!(CheckpointMeta::load(&dir).is_err());
    }
}
