//! Synthetic training corpus with learnable structure.
//!
//! The generator mixes a deterministic affine bigram rule (token t →
//! `(3t + 7) mod v` with probability 0.75) with Zipf-distributed noise
//! tokens, so a language model can actually reduce loss on it — the
//! end-to-end example's loss curve is the proof that the whole
//! rust↔PJRT↔artifact pipeline trains for real.

use crate::util::SplitMix64;

/// Deterministic synthetic token stream.
pub struct SyntheticCorpus {
    rng: SplitMix64,
    vocab: u32,
    /// probability of following the deterministic bigram rule
    pub rule_prob: f64,
    /// Zipf CDF over the vocabulary for the noise branch
    zipf_cdf: Vec<f64>,
}

impl SyntheticCorpus {
    pub fn new(vocab: u32, seed: u64) -> Self {
        assert!(vocab >= 8, "vocabulary too small");
        // Zipf(1.1) over the vocab
        let weights: Vec<f64> = (1..=vocab).map(|r| 1.0 / (r as f64).powf(1.1)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        let zipf_cdf = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        Self { rng: SplitMix64::new(seed), vocab, rule_prob: 0.75, zipf_cdf }
    }

    fn zipf(&mut self) -> u32 {
        let u: f64 = self.rng.next_f64();
        match self.zipf_cdf.binary_search_by(|c| c.total_cmp(&u)) {
            Ok(i) | Err(i) => (i as u32).min(self.vocab - 1)
        }
    }

    fn next_token(&mut self, cur: u32) -> u32 {
        if self.rng.next_f64() < self.rule_prob {
            (3 * cur + 7) % self.vocab
        } else {
            self.zipf()
        }
    }

    /// One (tokens, targets) pair of shape `[b, s]` each, where targets
    /// are the next-token shift of the same underlying stream.
    pub fn microbatch(&mut self, b: usize, s: usize) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        self.microbatch_into(b, s, &mut tokens, &mut targets);
        (tokens, targets)
    }

    /// [`Self::microbatch`] into caller-owned buffers — the feeder's
    /// recycling path: once `tokens`/`targets` have capacity `b * s`,
    /// filling them allocates nothing.  Identical RNG walk, so the
    /// stream is byte-for-byte the same either way.
    pub fn microbatch_into(
        &mut self,
        b: usize,
        s: usize,
        tokens: &mut Vec<i32>,
        targets: &mut Vec<i32>,
    ) {
        tokens.clear();
        targets.clear();
        tokens.reserve(b * s);
        targets.reserve(b * s);
        for _ in 0..b {
            let mut cur = self.zipf();
            for _ in 0..s {
                tokens.push(cur as i32);
                cur = self.next_token(cur);
                targets.push(cur as i32);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = SyntheticCorpus::new(256, 42);
        let mut b = SyntheticCorpus::new(256, 42);
        assert_eq!(a.microbatch(2, 16), b.microbatch(2, 16));
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SyntheticCorpus::new(256, 1);
        let mut b = SyntheticCorpus::new(256, 2);
        assert_ne!(a.microbatch(2, 16).0, b.microbatch(2, 16).0);
    }

    #[test]
    fn tokens_in_vocab_and_targets_shifted() {
        let mut c = SyntheticCorpus::new(64, 0);
        let (tok, tgt) = c.microbatch(4, 32);
        assert_eq!(tok.len(), 128);
        assert!(tok.iter().chain(tgt.iter()).all(|&t| (0..64).contains(&t)));
        // shift property within each row: targets[i] == tokens[i+1]
        for row in 0..4 {
            for i in 0..31 {
                assert_eq!(tgt[row * 32 + i], tok[row * 32 + i + 1]);
            }
        }
    }

    #[test]
    fn rule_dominates() {
        // ~75% of transitions must follow the affine rule
        let mut c = SyntheticCorpus::new(256, 7);
        let (tok, tgt) = c.microbatch(8, 64);
        let follows = tok
            .iter()
            .zip(tgt.iter())
            .filter(|&(&t, &n)| n == (3 * t + 7) % 256)
            .count();
        let frac = follows as f64 / tok.len() as f64;
        assert!(frac > 0.6 && frac < 0.9, "rule fraction {frac}");
    }
}
