//! The real pipeline-parallel training coordinator (substrate S2).
//!
//! * [`pipeline`] — the leader: schedule planning, worker wiring, data
//!   streaming, loss/stat collection;
//! * [`stage_worker`] — one thread per pipeline stage executing its
//!   [`crate::schedule::StageProgram`] against PJRT executables;
//! * [`activation_store`] — the bounded stash + the BPipe remote store
//!   (the acceptor's memory pool);
//! * [`data`] — deterministic synthetic corpus with learnable structure;
//! * [`stage_bench`] — single-stage timing for the paper-§4 estimator.
//!
//! The key BPipe property is tested end to end: a BPipe run computes
//! **bit-identical losses** to the plain 1F1B run (eviction is pure data
//! movement), while stage 0's stash high-water drops to the bound.

pub mod activation_store;
pub mod checkpoint;
pub mod data;
pub mod pipeline;
pub mod stage_bench;
pub mod stage_worker;

pub use activation_store::{ActivationStore, HostTensor};
pub use checkpoint::{CheckpointMeta, StageCheckpoint};
pub use data::SyntheticCorpus;
pub use pipeline::{plan_schedule, train, TrainConfig, TrainResult};
pub use stage_bench::{measure_stage, StageTiming};
pub use stage_worker::StageStats;
