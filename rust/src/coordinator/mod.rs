//! The real pipeline-parallel training coordinator (substrate S2) —
//! generic over the execution [`crate::runtime::Backend`], so it runs in
//! tier-1 on the in-tree [`crate::runtime::SimBackend`] and, with
//! `--features pjrt`, on real AOT-compiled XLA artifacts.
//!
//! * [`pipeline`] — the leader: schedule planning ([`plan_schedule`]:
//!   any [`crate::schedule::Family`] × any [`RebalancePlan`]), worker
//!   wiring per virtual-stage boundary, data streaming, loss/stat
//!   collection;
//! * [`stage_worker`] — one thread per pipeline stage executing its
//!   [`crate::schedule::StageProgram`] (multi-chunk aware) against
//!   backend executables;
//! * [`activation_store`] — the bounded `(mb, chunk)`-keyed stash + the
//!   BPipe remote store (the acceptor's memory pool);
//! * [`data`] — deterministic synthetic corpus with learnable structure;
//! * [`stage_bench`] — single-stage timing for the paper-§4 estimator;
//! * [`checkpoint`] — per-virtual-stage state + run metadata, now with
//!   two rotated generations, step tags and content checksums;
//! * [`supervisor`] — the fault-tolerant outer loop: classifies worker
//!   failures into [`supervisor::FailureReport`]s, then
//!   checkpoint–re-plan–resume ([`supervisor::supervise`]).
//!
//! The key BPipe property is tested end to end IN TIER-1: a rebalanced
//! run computes **bit-identical losses** to its baseline (eviction is
//! pure data movement) for 1F1B and zig-zag bases alike, while the
//! evictor stages' stash high-water drops to the planned bound
//! (`rust/tests/integration_runtime.rs`).
//!
//! The hot path is **zero-alloc in steady state**: tensors move by
//! handle ([`activation_store::Stash`] slots, bounded channels, the
//! per-worker [`crate::runtime::BufferPool`] with
//! [`crate::runtime::Backend::execute_pooled`] donation), pinned by the
//! counting-allocator test through [`pipeline::train_probed`]
//! (`rust/tests/alloc_steady_state.rs`).

pub mod activation_store;
pub mod checkpoint;
pub mod data;
pub mod pipeline;
pub mod stage_bench;
pub mod stage_worker;
pub mod supervisor;

pub use activation_store::{
    spin_recv, spin_recv_deadline, spin_send, spin_send_deadline, ActivationStore, ChannelError,
    HostTensor, Stash, StashKey,
};
pub use checkpoint::{
    latest_common_step, CheckpointMeta, CheckpointWriter, CorruptCheckpoint, StageCheckpoint,
};
pub use data::SyntheticCorpus;
pub use pipeline::{
    plan_schedule, train, train_probed, train_probed_feeder, try_plan_schedule, PlanRejected,
    ProgressLog, RebalancePlan, TrainConfig, TrainResult,
};
pub use stage_bench::{measure_stage, StageTiming};
pub use stage_worker::{StageRunner, StageStats};
pub use supervisor::{
    supervise, FailureCause, FailureReport, RecoveryEvent, SuperviseConfig, SuperviseOutcome,
};
