//! The training leader: builds the schedule, wires the stage workers,
//! streams data, and collects losses/stats.
//!
//! This is substrate S2 of DESIGN.md — a *real* pipeline-parallel
//! training run over AOT-compiled XLA artifacts, with BPipe activation
//! balancing done on real buffers.  Stage workers are threads (the
//! laptop-scale analogue of one rank per GPU); the leader is the analogue
//! of the launcher + rank-0 logging in Megatron.

use std::sync::mpsc::channel;
use std::path::PathBuf;
use std::time::Instant;

use super::activation_store::{spawn_remote_store, HostTensor};
use super::checkpoint::CheckpointMeta;
use super::data::SyntheticCorpus;
use super::stage_worker::{worker_main, StageStats, WorkerChannels, WorkerConfig};
use crate::bpipe::pairing;
use crate::model::memory::{bpipe_bound, one_f_one_b_in_flight};
use crate::runtime::Manifest;
use crate::schedule::{validate, Schedule};

/// Configuration of one real training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub artifacts_dir: PathBuf,
    pub steps: u64,
    /// microbatches per step (global batch = microbatches × artifact b)
    pub microbatches: u64,
    pub lr: f32,
    pub bpipe: bool,
    /// override the BPipe bound (default ⌈(p+2)/2⌉)
    pub bound: Option<u64>,
    pub seed: u64,
    /// print a progress line every n steps (0 = silent)
    pub log_every: u64,
    /// checkpoint directory; state is saved per stage + run metadata
    pub checkpoint_dir: Option<PathBuf>,
    /// checkpoint every n steps (0 = only after the final step)
    pub checkpoint_every: u64,
    /// resume from `checkpoint_dir` (cfg.steps is the TOTAL step target)
    pub resume: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: PathBuf::from("artifacts"),
            steps: 20,
            microbatches: 8,
            lr: 1e-3,
            bpipe: false,
            bound: None,
            seed: 0,
            log_every: 0,
            checkpoint_dir: None,
            checkpoint_every: 0,
            resume: false,
        }
    }
}

/// Output of a training run.
#[derive(Debug, Clone)]
pub struct TrainResult {
    /// mean loss per step
    pub losses: Vec<f32>,
    /// wall-clock per step (leader-observed, seconds)
    pub step_times: Vec<f64>,
    pub stage_stats: Vec<StageStats>,
    pub schedule: Schedule,
    /// total tokens consumed
    pub tokens: u64,
}

impl TrainResult {
    pub fn final_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }

    pub fn mean_step_time(&self) -> f64 {
        // skip the first (compile-warm) step when there are enough
        let ts = if self.step_times.len() > 2 { &self.step_times[1..] } else { &self.step_times };
        ts.iter().sum::<f64>() / ts.len().max(1) as f64
    }
}

/// Build the schedule a run implies and the per-stage store capacities.
pub fn plan_schedule(p: u64, m: u64, bpipe: bool, bound: Option<u64>) -> (Schedule, Vec<usize>) {
    let base = crate::schedule::one_f_one_b(p, m);
    let schedule = if bpipe { crate::bpipe::apply_bpipe(&base, bound) } else { base };
    validate(&schedule).expect("generated schedule must validate");
    let caps: Vec<usize> = (0..p)
        .map(|s| {
            let cap = if bpipe {
                bound.unwrap_or_else(|| bpipe_bound(p)).min(m)
            } else {
                one_f_one_b_in_flight(p, s, m)
            };
            cap as usize
        })
        .collect();
    (schedule, caps)
}

/// Run pipeline-parallel training end to end.  Blocks until done.
pub fn train(cfg: &TrainConfig) -> anyhow::Result<TrainResult> {
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let p = manifest.spec.stages;
    let m = cfg.microbatches;
    anyhow::ensure!(p >= 2, "pipeline needs at least 2 stages");
    let (schedule, caps) = plan_schedule(p, m, cfg.bpipe, cfg.bound);

    // resume bookkeeping: cfg.steps is the TOTAL target; a resumed run
    // executes the remainder and fast-forwards the corpus
    let start_step = if cfg.resume {
        let dir = cfg
            .checkpoint_dir
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("--resume requires a checkpoint dir"))?;
        let meta = CheckpointMeta::load(dir)?;
        anyhow::ensure!(meta.stages == p, "checkpoint stages {} != {}", meta.stages, p);
        anyhow::ensure!(
            meta.microbatches == m && meta.seed == cfg.seed,
            "checkpoint run shape (m={}, seed={}) differs from this run (m={m}, seed={})",
            meta.microbatches,
            meta.seed,
            cfg.seed
        );
        meta.steps_done
    } else {
        0
    };
    let run_steps = cfg.steps.saturating_sub(start_step);
    anyhow::ensure!(run_steps > 0, "nothing to do: {start_step} steps already done");

    // -- channel topology ---------------------------------------------------
    let mut act_txs = Vec::new();
    let mut act_rxs = vec![None];
    let mut grad_txs = vec![None];
    let mut grad_rxs = Vec::new();
    for _ in 0..p - 1 {
        let (atx, arx) = channel();
        act_txs.push(Some(atx));
        act_rxs.push(Some(arx));
        let (gtx, grx) = channel();
        grad_txs.push(Some(gtx));
        grad_rxs.push(Some(grx));
    }
    act_txs.push(None);
    grad_rxs.push(None);
    let (tok_tx, tok_rx) = channel();
    let (tgt_tx, tgt_rx) = channel();
    let (loss_tx, loss_rx) = channel();

    // -- workers -------------------------------------------------------------
    let mut handles = Vec::new();
    let mut tok_rx = Some(tok_rx);
    let mut tgt_rx = Some(tgt_rx);
    for s in 0..p {
        let needs_store = schedule
            .program(s)
            .ops
            .iter()
            .any(|o| matches!(o.kind, crate::schedule::OpKind::Evict | crate::schedule::OpKind::Load));
        let remote = if needs_store {
            // stage s evicts to acceptor stage pairing::partner(p, s)
            let _ = pairing::partner(p, s);
            let (client, _stats_rx) = spawn_remote_store();
            Some(client)
        } else {
            None
        };
        let wcfg = WorkerConfig {
            stage: s,
            stages: p,
            steps: run_steps,
            microbatches: m,
            lr: cfg.lr,
            seed: cfg.seed as i32,
            artifacts_dir: cfg.artifacts_dir.clone(),
            program: schedule.program(s).clone(),
            capacity: caps[s as usize],
            checkpoint_dir: cfg.checkpoint_dir.clone(),
            checkpoint_every: cfg.checkpoint_every,
            resume: cfg.resume,
            start_step,
        };
        let wch = WorkerChannels {
            act_in: act_rxs[s as usize].take(),
            act_out: act_txs[s as usize].take(),
            grad_in: grad_rxs[s as usize].take(),
            grad_out: grad_txs[s as usize].take(),
            tokens_in: if s == 0 { tok_rx.take() } else { None },
            targets_in: if s == p - 1 { tgt_rx.take() } else { None },
            loss_out: if s == p - 1 { Some(loss_tx.clone()) } else { None },
            remote,
        };
        handles.push(
            std::thread::Builder::new()
                .name(format!("stage-{s}"))
                .spawn(move || worker_main(wcfg, wch))?,
        );
    }
    drop(loss_tx);

    // -- data feeding ----------------------------------------------------------
    let spec = &manifest.spec;
    let (b, s_len) = (spec.b as usize, spec.s as usize);
    let mut corpus = SyntheticCorpus::new(spec.v as u32, cfg.seed);
    let shape = vec![b as i64, s_len as i64];
    // fast-forward past the data a resumed checkpoint already consumed
    for _ in 0..start_step * m {
        corpus.microbatch(b, s_len);
    }
    for _step in 0..run_steps {
        for mb in 0..m {
            let (tokens, targets) = corpus.microbatch(b, s_len);
            tok_tx
                .send((mb, HostTensor::I32 { data: tokens, shape: shape.clone() }))
                .map_err(|_| anyhow::anyhow!("stage 0 died early"))?;
            tgt_tx
                .send((mb, HostTensor::I32 { data: targets, shape: shape.clone() }))
                .map_err(|_| anyhow::anyhow!("last stage died early"))?;
        }
    }
    drop(tok_tx);
    drop(tgt_tx);

    // -- loss collection ---------------------------------------------------------
    let mut losses = Vec::with_capacity(run_steps as usize);
    let mut step_times = Vec::with_capacity(run_steps as usize);
    let mut t_prev = Instant::now();
    for step in 1..=run_steps {
        let mut sum = 0f32;
        for _ in 0..m {
            let (got_step, _mb, loss) =
                loss_rx.recv().map_err(|_| anyhow::anyhow!("pipeline died mid-step {step}"))?;
            anyhow::ensure!(got_step == step, "loss for step {got_step}, expected {step}");
            sum += loss;
        }
        losses.push(sum / m as f32);
        step_times.push(t_prev.elapsed().as_secs_f64());
        t_prev = Instant::now();
        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            println!(
                "step {:>4}/{}  loss {:.4}  ({:.2}s/step)",
                start_step + step,
                cfg.steps,
                losses.last().unwrap(),
                step_times.last().unwrap()
            );
        }
    }

    // -- join ------------------------------------------------------------------
    let mut stage_stats = Vec::new();
    for h in handles {
        stage_stats.push(h.join().map_err(|e| anyhow::anyhow!("worker panicked: {e:?}"))??);
    }
    if let Some(dir) = &cfg.checkpoint_dir {
        CheckpointMeta {
            steps_done: start_step + run_steps,
            stages: p,
            microbatches: m,
            seed: cfg.seed,
        }
        .save(dir)?;
    }
    Ok(TrainResult {
        losses,
        step_times,
        stage_stats,
        schedule,
        tokens: run_steps * m * (b * s_len) as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_schedule_capacities() {
        let (sched, caps) = plan_schedule(4, 8, false, None);
        assert_eq!(caps, vec![4, 3, 2, 1]);
        assert_eq!(sched.kind, crate::schedule::ScheduleKind::OneFOneB);
        let (sched_b, caps_b) = plan_schedule(4, 8, true, None);
        assert_eq!(caps_b, vec![3, 3, 3, 3]);
        assert!(matches!(sched_b.kind, crate::schedule::ScheduleKind::BPipe { bound: 3 }));
    }

    #[test]
    fn plan_schedule_small_m_clips() {
        let (_s, caps) = plan_schedule(4, 2, true, None);
        assert_eq!(caps, vec![2, 2, 2, 2]);
    }
}
