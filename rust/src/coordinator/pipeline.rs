//! The training leader: plans the schedule, wires the stage workers,
//! streams data, and collects losses/stats.
//!
//! This is substrate S2 of DESIGN.md — a *real* pipeline-parallel
//! training run, generic over the execution [`Backend`]: AOT-compiled
//! XLA artifacts on PJRT (`--features pjrt`) or the in-tree
//! deterministic [`crate::runtime::SimBackend`] (tier-1 default), with
//! BPipe activation balancing done on real buffers either way.  Stage
//! workers are threads (the laptop-scale analogue of one rank per GPU);
//! the leader is the analogue of the launcher + rank-0 logging in
//! Megatron.
//!
//! Planning goes through [`plan_schedule`]: any [`Family`] (1F1B, GPipe,
//! interleaved, V-shaped, zig-zag/W) composed with any
//! [`RebalancePlan`] — off, uniform BPipe ([`crate::bpipe::rebalance`]),
//! explicit per-stage caps ([`crate::bpipe::rebalance_bounded`]), or
//! capacity-derived per-stage caps
//! ([`crate::bpipe::capacity_stage_bounds`]) — so every schedule the
//! simulator sweeps also runs on the REAL pipeline.
//!
//! Wiring uses **bounded** channels throughout (ring buffers allocated
//! once at setup, sized from the microbatch count), so steady-state
//! sends transfer tensor ownership without touching the heap; a
//! dedicated feeder thread streams the synthetic corpus under that
//! backpressure while the leader collects losses.  [`train_probed`] runs
//! one chosen stage's worker on the *calling* thread — the hook between
//! steps is how the counting-allocator test and the hot-path bench
//! observe per-step allocations of a real stage worker.

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::activation_store::{
    spawn_remote_store_with, spin_recv_deadline, spin_send_deadline, HostTensor,
};
use super::checkpoint::CheckpointMeta;
use super::data::SyntheticCorpus;
use super::stage_worker::{worker_main, StageRunner, StageStats, WorkerChannels, WorkerConfig};
use super::supervisor::{self, FailureCause, FailureReport};
use crate::config::ExperimentConfig;
use crate::runtime::{Backend, FaultPlan, Manifest};
use crate::schedule::{Family, OpKind, Schedule};

/// How to compose the base schedule with the rebalance transform.
#[derive(Debug, Clone, PartialEq)]
pub enum RebalancePlan {
    /// Run the family's natural schedule untouched.
    Off,
    /// Uniform BPipe: every stage capped at `bound` (the derived
    /// pair-mean bound when `None` — `⌈(p+2)/2⌉` on 1F1B).
    Uniform { bound: Option<u64> },
    /// Explicit per-stage caps (SlimPipe-style non-uniform BPipe).
    PerStage { bounds: Vec<u64> },
    /// Per-stage caps derived from an experiment's memory model
    /// ([`crate::bpipe::capacity_stage_bounds`]).
    Capacity { experiment: ExperimentConfig },
}

/// Configuration of one real training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// artifact directory (ignored when `manifest` is given)
    pub artifacts_dir: PathBuf,
    /// in-memory manifest override — sim runs need no artifacts on disk
    pub manifest: Option<Manifest>,
    /// base schedule family; its chunk count must divide the manifest's
    /// virtual-stage count (`p = stages / chunks`)
    pub family: Family,
    /// run THIS schedule instead of building one from `family` +
    /// `rebalance` — the `bpipe train --schedule synth` path, where the
    /// schedule comes from [`crate::schedule::synthesize`] rather than a
    /// family generator.  The override is still gated through the static
    /// analyzer before any thread spawns; its `p`/`m`/`chunks` must
    /// match the run shape.  `family` and `rebalance` are ignored for
    /// schedule construction when set.
    pub schedule_override: Option<Schedule>,
    pub steps: u64,
    /// microbatches per step (global batch = microbatches × artifact b)
    pub microbatches: u64,
    pub lr: f32,
    pub rebalance: RebalancePlan,
    pub seed: u64,
    /// print a progress line every n steps (0 = silent)
    pub log_every: u64,
    /// checkpoint directory; state is saved per virtual stage + run metadata
    pub checkpoint_dir: Option<PathBuf>,
    /// checkpoint every n steps (0 = only after the final step)
    pub checkpoint_every: u64,
    /// resume from `checkpoint_dir` (cfg.steps is the TOTAL step target)
    pub resume: bool,
    /// deadline on pipeline channel waits (feeder, collector, worker
    /// boundaries).  `None` — the default — keeps the unbounded spin
    /// waits; the supervisor sets it so a silent peer becomes a typed
    /// `ChannelTimeout` instead of a hang.
    pub recover_timeout: Option<Duration>,
    /// in-place retries per transient `execute` failure (0 = fail fast)
    pub retry_budget: u32,
    /// base backoff between execute retries (doubles per attempt)
    pub retry_backoff_ms: u64,
    /// shared per-step progress log (global step, loss, wall-clock) the
    /// collector appends to as losses arrive — the supervisor's source
    /// for loss stitching and time-to-recover accounting
    pub progress: Option<ProgressLog>,
    /// fleet replica this run belongs to (`None` for a standalone run).
    /// Bound into every worker's backend and used by the feeder, so
    /// replica-scoped faults hit exactly the replica they name.
    pub replica: Option<usize>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: PathBuf::from("artifacts"),
            manifest: None,
            family: Family::OneFOneB,
            schedule_override: None,
            steps: 20,
            microbatches: 8,
            lr: 1e-3,
            rebalance: RebalancePlan::Off,
            seed: 0,
            log_every: 0,
            checkpoint_dir: None,
            checkpoint_every: 0,
            resume: false,
            recover_timeout: None,
            retry_budget: 0,
            retry_backoff_ms: 10,
            progress: None,
            replica: None,
        }
    }
}

/// One completed step as the collector saw it.
#[derive(Debug, Clone, Copy)]
pub struct ProgressEntry {
    /// GLOBAL (resume-aware) 1-based step
    pub step: u64,
    /// mean loss over the step's microbatches
    pub loss: f32,
    /// when the collector recorded it
    pub at: Instant,
}

/// Thread-safe append-only log of completed steps, shared between the
/// in-run loss collector and the out-of-run supervisor.  Entries carry
/// the GLOBAL step, so a resumed attempt's entries interleave correctly
/// with the pre-failure attempt's.
#[derive(Debug, Clone, Default)]
pub struct ProgressLog(Arc<Mutex<Vec<ProgressEntry>>>);

impl ProgressLog {
    pub fn new() -> Self {
        Self::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<ProgressEntry>> {
        self.0.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn record(&self, step: u64, loss: f32) {
        self.lock().push(ProgressEntry { step, loss, at: Instant::now() });
    }

    pub fn len(&self) -> usize {
        self.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.lock().is_empty()
    }

    pub fn snapshot(&self) -> Vec<ProgressEntry> {
        self.lock().clone()
    }
}

/// Output of a training run.
#[derive(Debug, Clone)]
pub struct TrainResult {
    /// mean loss per step
    pub losses: Vec<f32>,
    /// wall-clock per step (leader-observed, seconds)
    pub step_times: Vec<f64>,
    pub stage_stats: Vec<StageStats>,
    pub schedule: Schedule,
    /// total tokens consumed
    pub tokens: u64,
}

impl TrainResult {
    pub fn final_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }

    pub fn mean_step_time(&self) -> f64 {
        // skip the first (compile-warm) step when there are enough
        let ts = if self.step_times.len() > 2 { &self.step_times[1..] } else { &self.step_times };
        ts.iter().sum::<f64>() / ts.len().max(1) as f64
    }
}

/// Build the schedule a run implies and the per-stage store capacities:
/// the family's base schedule composed with the rebalance plan, then
/// gated through the static analyzer ([`crate::analysis::check_plan`]:
/// structural validation, protocol progress, donation linearity, memory
/// bounds).  Capacities are each stage's realized stash high-water —
/// the tightest bound the activation store can enforce without ever
/// rejecting a scheduled put (for a rebalanced schedule, the planned
/// per-stage cap; for a base schedule, its natural in-flight count).
pub fn plan_schedule(
    family: Family,
    p: u64,
    m: u64,
    plan: &RebalancePlan,
) -> (Schedule, Vec<usize>) {
    match try_plan_schedule(family, p, m, plan) {
        Ok(v) => v,
        Err(rej) if !rej.diagnostics.is_empty() => panic!(
            "generated schedule failed static analysis:\n{}",
            crate::analysis::render_diagnostics(&rej.diagnostics)
        ),
        Err(rej) => panic!("{rej}"),
    }
}

/// An infeasible plan request, reported instead of panicking — what the
/// supervisor's re-plan path receives when a post-fault capacity admits
/// no valid schedule (`FailureCause::NoFeasiblePlan`).
#[derive(Debug)]
pub struct PlanRejected {
    pub reason: String,
    /// analyzer findings when the rejection came from the static gate
    /// (empty for builder-precondition rejections)
    pub diagnostics: Vec<crate::analysis::Diagnostic>,
}

impl std::fmt::Display for PlanRejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "schedule plan rejected: {}", self.reason)?;
        for d in &self.diagnostics {
            write!(f, "\n  {d}")?;
        }
        Ok(())
    }
}

impl std::error::Error for PlanRejected {}

/// Non-panicking [`plan_schedule`]: builder preconditions (bound shape
/// and the BPipe k ≥ 2 floor) are validated up front, and analyzer
/// errors come back as [`PlanRejected`] instead of aborting the process.
pub fn try_plan_schedule(
    family: Family,
    p: u64,
    m: u64,
    plan: &RebalancePlan,
) -> Result<(Schedule, Vec<usize>), PlanRejected> {
    let reject = |reason: String| PlanRejected { reason, diagnostics: Vec::new() };
    match plan {
        RebalancePlan::PerStage { bounds } => {
            if bounds.len() != p as usize {
                return Err(reject(format!(
                    "per-stage plan has {} bounds for a {p}-stage pipeline",
                    bounds.len()
                )));
            }
            if let Some((s, &k)) = bounds.iter().enumerate().find(|&(_, &k)| k < 2) {
                return Err(reject(format!(
                    "stage {s} bound {k} is below the BPipe floor of 2 \
                     (one live activation + one incoming stash)"
                )));
            }
        }
        RebalancePlan::Uniform { bound: Some(k) } if *k < 2 => {
            return Err(reject(format!("uniform bound {k} is below the BPipe floor of 2")));
        }
        RebalancePlan::Capacity { experiment } if experiment.parallel.p != p => {
            return Err(reject(format!(
                "capacity plan's experiment models a {}-stage pipeline, schedule has {p}",
                experiment.parallel.p
            )));
        }
        _ => {}
    }
    let base = family.build(p, m);
    let schedule = match plan {
        RebalancePlan::Off => base,
        RebalancePlan::Uniform { bound } => crate::bpipe::rebalance(&base, *bound),
        RebalancePlan::PerStage { bounds } => crate::bpipe::rebalance_bounded(&base, bounds),
        RebalancePlan::Capacity { experiment } => {
            let bounds = crate::bpipe::capacity_stage_bounds(experiment, &base);
            crate::bpipe::rebalance_bounded(&base, &bounds)
        }
    };
    // the static analyzer gate: structural validation plus the
    // protocol/linearity/bounds passes — a plan with any error-level
    // finding must never reach the channel web
    let chan_caps = crate::analysis::ChannelCaps::for_run(m, schedule.chunks);
    if let Err(diags) = crate::analysis::gate_plan(&schedule, plan, &chan_caps) {
        return Err(PlanRejected {
            reason: "static analysis found errors".into(),
            diagnostics: diags,
        });
    }
    let caps: Vec<usize> =
        (0..p).map(|s| schedule.program(s).stash_high_water().max(1) as usize).collect();
    Ok((schedule, caps))
}

/// Gate a caller-supplied schedule (the `schedule_override` path) the
/// same way [`plan_schedule`] gates a generated one: shape checks, then
/// the full static-analyzer gate, then store capacities from the
/// realized per-stage stash high-water.  The rebalance plan passed to
/// the analyzer is `Off` — an override's eviction bounds are already
/// baked into its programs and `stage_bounds`, so the validator's
/// stage-bound pass (not a plan cross-check) is what enforces them.
fn plan_override(s: &Schedule, p: u64, m: u64) -> anyhow::Result<(Schedule, Vec<usize>)> {
    anyhow::ensure!(
        s.p == p,
        "override schedule spans {} stages, run shape needs {p}",
        s.p
    );
    anyhow::ensure!(
        s.m == m,
        "override schedule was built for {} microbatches, run feeds {m}",
        s.m
    );
    let chan_caps = crate::analysis::ChannelCaps::for_run(m, s.chunks);
    if let Err(diags) = crate::analysis::gate_plan(s, &RebalancePlan::Off, &chan_caps) {
        anyhow::bail!(
            "override schedule failed static analysis:\n{}",
            crate::analysis::render_diagnostics(&diags)
        );
    }
    let caps: Vec<usize> =
        (0..p).map(|st| s.program(st).stash_high_water().max(1) as usize).collect();
    Ok((s.clone(), caps))
}

/// Run pipeline-parallel training end to end on backend `B`.  Blocks
/// until done.
pub fn train<B: Backend>(cfg: &TrainConfig) -> anyhow::Result<TrainResult> {
    train_inner::<B>(cfg, None)
}

/// [`train`], but with stage `probe_stage`'s worker running on the
/// CALLING thread, `hook(step)` invoked after each of its completed
/// steps.  This is the instrumentation point for per-worker, per-step
/// measurements — a thread-local counting allocator sees exactly the
/// probed stage's hot path (`rust/tests/alloc_steady_state.rs`,
/// `benches/runtime_hotpath.rs`).
pub fn train_probed<B: Backend>(
    cfg: &TrainConfig,
    probe_stage: u64,
    hook: &mut dyn FnMut(u64),
) -> anyhow::Result<TrainResult> {
    train_inner::<B>(cfg, Some(Probe::Stage(probe_stage, hook)))
}

/// [`train`], but with the DATA FEEDER running on the CALLING thread,
/// `hook(step)` invoked after each step's microbatches are fed — the
/// feeder-side twin of [`train_probed`], so the counting-allocator test
/// can pin the feeder's steady-state token recycling too.
pub fn train_probed_feeder<B: Backend>(
    cfg: &TrainConfig,
    hook: &mut dyn FnMut(u64),
) -> anyhow::Result<TrainResult> {
    train_inner::<B>(cfg, Some(Probe::Feeder(hook)))
}

/// Which thread of the run executes on the caller (for instrumentation).
enum Probe<'a> {
    /// one stage's worker, hook after each completed step
    Stage(u64, &'a mut dyn FnMut(u64)),
    /// the data feeder, hook after each step's microbatches are fed
    Feeder(&'a mut dyn FnMut(u64)),
}

fn train_inner<B: Backend>(
    cfg: &TrainConfig,
    mut probe: Option<Probe<'_>>,
) -> anyhow::Result<TrainResult> {
    let manifest = match &cfg.manifest {
        Some(m) => m.clone(),
        None => Manifest::load(&cfg.artifacts_dir)?,
    };
    let vp = manifest.spec.stages;
    let m = cfg.microbatches;
    let chunks = match &cfg.schedule_override {
        Some(s) => s.chunks,
        None => cfg.family.chunks(),
    };
    anyhow::ensure!(vp >= 2, "pipeline needs at least 2 virtual stages");
    anyhow::ensure!(
        chunks >= 1 && vp % chunks == 0,
        "manifest's {vp} virtual stages don't split into {chunks} chunks ({:?})",
        cfg.family
    );
    let p = vp / chunks;
    let (schedule, caps) = match &cfg.schedule_override {
        Some(s) => plan_override(s, p, m)?,
        None => plan_schedule(cfg.family, p, m, &cfg.rebalance),
    };
    debug_assert_eq!(schedule.chunks, chunks);
    let placement = schedule.placement;
    if let Some(Probe::Stage(ps, _)) = &probe {
        anyhow::ensure!(*ps < p, "probe stage {ps} out of range (p = {p})");
    }

    // resume bookkeeping: cfg.steps is the TOTAL target; a resumed run
    // executes the remainder and fast-forwards the corpus
    let start_step = if cfg.resume {
        let dir = cfg
            .checkpoint_dir
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("--resume requires a checkpoint dir"))?;
        let meta = CheckpointMeta::load(dir)?;
        anyhow::ensure!(meta.stages == p, "checkpoint stages {} != {}", meta.stages, p);
        anyhow::ensure!(
            meta.chunks == chunks && meta.microbatches == m && meta.seed == cfg.seed,
            "checkpoint run shape (chunks={}, m={}, seed={}) differs from this run \
             (chunks={chunks}, m={m}, seed={})",
            meta.chunks,
            meta.microbatches,
            meta.seed,
            cfg.seed
        );
        meta.steps_done
    } else {
        0
    };
    let run_steps = cfg.steps.saturating_sub(start_step);
    anyhow::ensure!(run_steps > 0, "nothing to do: {start_step} steps already done");

    // -- channel topology ---------------------------------------------------
    // one act + one grad channel per virtual-stage boundary d → d+1,
    // routed to the physical hosts of the two sides (possibly the same
    // worker, at zig-zag junction stages).  All channels are BOUNDED:
    // a boundary carries at most m messages per step (`hot_cap` adds
    // headroom), so the ring never fills in a valid schedule and a send
    // is an allocation-free slot write.
    let hot_cap = (m + 1) as usize;
    let feed_cap = (2 * m) as usize;
    type Slots<T> = Vec<Vec<Option<T>>>;
    let mut act_in: Slots<Receiver<(u64, HostTensor)>> =
        (0..p).map(|_| (0..chunks).map(|_| None).collect()).collect();
    let mut act_out: Slots<SyncSender<(u64, HostTensor)>> =
        (0..p).map(|_| (0..chunks).map(|_| None).collect()).collect();
    let mut grad_in: Slots<Receiver<(u64, HostTensor)>> =
        (0..p).map(|_| (0..chunks).map(|_| None).collect()).collect();
    let mut grad_out: Slots<SyncSender<(u64, HostTensor)>> =
        (0..p).map(|_| (0..chunks).map(|_| None).collect()).collect();
    for d in 0..vp - 1 {
        let (src_s, src_c) = (placement.host_stage(p, d) as usize, (d / p) as usize);
        let (dst_s, dst_c) = (placement.host_stage(p, d + 1) as usize, ((d + 1) / p) as usize);
        let (atx, arx) = sync_channel(hot_cap);
        act_out[src_s][src_c] = Some(atx);
        act_in[dst_s][dst_c] = Some(arx);
        let (gtx, grx) = sync_channel(hot_cap);
        grad_out[dst_s][dst_c] = Some(gtx);
        grad_in[src_s][src_c] = Some(grx);
    }
    let first_host = placement.host_stage(p, 0);
    let last_host = placement.host_stage(p, vp - 1);
    let (tok_tx, tok_rx) = sync_channel(feed_cap);
    let (tgt_tx, tgt_rx) = sync_channel(feed_cap);
    let (loss_tx, loss_rx) = sync_channel((2 * m) as usize);
    // spent token/target buffers flow back to the feeder's free list.
    // Workers return them with a NON-BLOCKING `try_send` (falling back
    // to their local pool on a full ring), so this edge can never join
    // a wait cycle — which is why the protocol model omits it.
    // ring sized past the worst burst between two feeder drains (both
    // end workers' backwards of one full step = 2m), so steady-state
    // returns virtually never fall back to the pool
    let (rec_tx, rec_rx) = sync_channel::<HostTensor>((6 * m) as usize);

    // -- data feeding state (runs on its own thread under backpressure) -----
    let spec = &manifest.spec;
    let (b, s_len) = (spec.b as usize, spec.s as usize);
    let mut corpus = SyntheticCorpus::new(spec.v as u32, cfg.seed);
    let shape = vec![b as i64, s_len as i64];
    // fast-forward past the data a resumed checkpoint already consumed
    for _ in 0..start_step * m {
        corpus.microbatch(b, s_len);
    }

    // the feeder has no backend of its own, so its stall fault is read
    // straight off the installed plan (workers inject via FaultyBackend)
    let faults = crate::runtime::fault::installed();
    let deadline = cfg.recover_timeout;

    let mut stage_stats_slots: Vec<Option<StageStats>> = (0..p).map(|_| None).collect();
    let (losses, step_times) =
        std::thread::scope(|scope| -> anyhow::Result<(Vec<f32>, Vec<f64>)> {
            // every worker/feeder/collector outcome is AGGREGATED here —
            // a failure anywhere must not early-return before the joins,
            // both so the scope can tear down (the disconnect cascade
            // unblocks every peer) and so the supervisor can rank ALL
            // the cascade's reports and pick the primary cause
            let mut failures: Vec<anyhow::Error> = Vec::new();

            // -- workers ----------------------------------------------------
            let mut handles = Vec::new();
            let mut probed_work: Option<(WorkerConfig, WorkerChannels)> = None;
            let mut tok_rx = Some(tok_rx);
            let mut tgt_rx = Some(tgt_rx);
            for s in 0..p {
                let needs_store = schedule
                    .program(s)
                    .ops
                    .iter()
                    .any(|o| matches!(o.kind, OpKind::Evict | OpKind::Load));
                let remote = if needs_store {
                    let (client, _stats_rx) =
                        spawn_remote_store_with((m * chunks) as usize, deadline);
                    Some(client)
                } else {
                    None
                };
                let wcfg = WorkerConfig {
                    stage: s,
                    stages: p,
                    chunks,
                    placement,
                    steps: run_steps,
                    microbatches: m,
                    lr: cfg.lr,
                    seed: cfg.seed as i32,
                    manifest: manifest.clone(),
                    program: schedule.program(s).clone(),
                    capacity: caps[s as usize],
                    checkpoint_dir: cfg.checkpoint_dir.clone(),
                    checkpoint_every: cfg.checkpoint_every,
                    resume: cfg.resume,
                    start_step,
                    deadline,
                    retry_budget: cfg.retry_budget,
                    retry_backoff_ms: cfg.retry_backoff_ms,
                    replica: cfg.replica,
                };
                let wch = WorkerChannels {
                    act_in: std::mem::take(&mut act_in[s as usize]),
                    act_out: std::mem::take(&mut act_out[s as usize]),
                    grad_in: std::mem::take(&mut grad_in[s as usize]),
                    grad_out: std::mem::take(&mut grad_out[s as usize]),
                    tokens_in: if s == first_host { tok_rx.take() } else { None },
                    targets_in: if s == last_host { tgt_rx.take() } else { None },
                    loss_out: if s == last_host { Some(loss_tx.clone()) } else { None },
                    recycle_out: if s == first_host || s == last_host {
                        Some(rec_tx.clone())
                    } else {
                        None
                    },
                    remote,
                };
                if matches!(&probe, Some(Probe::Stage(ps, _)) if *ps == s) {
                    probed_work = Some((wcfg, wch));
                    handles.push(None);
                } else {
                    handles.push(Some(
                        std::thread::Builder::new()
                            .name(format!("stage-{s}"))
                            .spawn_scoped(scope, move || worker_main::<B>(wcfg, wch))?,
                    ));
                }
            }
            drop(loss_tx);
            drop(rec_tx); // workers hold their clones; the feeder drains

            // -- data feeder + loss collection ------------------------------
            // the feeder normally gets its own thread; under a probe the
            // probed party (one stage worker, or the feeder itself) runs
            // HERE so a thread-local counting allocator can observe it
            let feeder_state = FeederState {
                corpus,
                tok_tx,
                tgt_tx,
                recycle_rx: rec_rx,
                shape,
                b,
                s: s_len,
                steps: run_steps,
                m,
                start_step,
                deadline,
                faults: faults.clone(),
                replica: cfg.replica,
            };
            let collect = CollectConfig {
                run_steps,
                m,
                log_every: cfg.log_every,
                total_steps: cfg.steps,
                start_step,
                deadline,
                progress: cfg.progress.clone(),
            };
            let mut feeder = None;
            let collected = match probe.take() {
                Some(Probe::Stage(ps, hook)) => {
                    feeder = Some(spawn_feeder(scope, feeder_state)?);
                    let collector = std::thread::Builder::new()
                        .name("bpipe-collector".into())
                        .spawn_scoped(scope, move || collect_losses(loss_rx, collect))?;
                    // the probed runner runs inside an immediately-invoked
                    // closure so its channels DROP on failure (starting
                    // the disconnect cascade) before the collector join
                    let probed = (|| -> anyhow::Result<()> {
                        let (wcfg, wch) = probed_work.take().expect("probed stage was planned");
                        let mut runner = StageRunner::<B>::new(wcfg, wch)?;
                        for step in 1..=run_steps {
                            runner.run_step(step)?;
                            hook(step);
                        }
                        stage_stats_slots[ps as usize] = Some(runner.finish()?);
                        Ok(())
                    })();
                    if let Err(e) = probed {
                        failures.push(e);
                    }
                    match collector.join() {
                        Ok(r) => r,
                        Err(e) => Err(anyhow::anyhow!("collector panicked: {e:?}")),
                    }
                }
                Some(Probe::Feeder(hook)) => {
                    let collector = std::thread::Builder::new()
                        .name("bpipe-collector".into())
                        .spawn_scoped(scope, move || collect_losses(loss_rx, collect))?;
                    if let Err(e) = run_feeder(feeder_state, Some(hook)) {
                        failures.push(e);
                    }
                    match collector.join() {
                        Ok(r) => r,
                        Err(e) => Err(anyhow::anyhow!("collector panicked: {e:?}")),
                    }
                }
                None => {
                    feeder = Some(spawn_feeder(scope, feeder_state)?);
                    collect_losses(loss_rx, collect)
                }
            };
            let collected = match collected {
                Ok(v) => Some(v),
                Err(e) => {
                    failures.push(e);
                    None
                }
            };

            // -- join -------------------------------------------------------
            for (s, h) in handles.into_iter().enumerate() {
                if let Some(h) = h {
                    match h.join() {
                        Ok(Ok(stats)) => stage_stats_slots[s] = Some(stats),
                        Ok(Err(e)) => failures.push(e),
                        Err(panic) => failures.push(anyhow::Error::new(FailureReport {
                            stage: Some(s as u64),
                            step: 0,
                            cause: FailureCause::WorkerPanic,
                            detail: supervisor::panic_message(&panic),
                        })),
                    }
                }
            }
            if let Some(f) = feeder {
                match f.join() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => failures.push(e),
                    Err(panic) => failures.push(anyhow::Error::new(FailureReport {
                        stage: None,
                        step: 0,
                        cause: FailureCause::WorkerPanic,
                        detail: format!("feeder: {}", supervisor::panic_message(&panic)),
                    })),
                }
            }
            if !failures.is_empty() {
                return Err(supervisor::primary_failure(failures));
            }
            Ok(collected.expect("no failures implies the collector finished"))
        })?;

    let stage_stats: Vec<StageStats> =
        stage_stats_slots.into_iter().map(|s| s.expect("every stage reports stats")).collect();
    if let Some(dir) = &cfg.checkpoint_dir {
        CheckpointMeta {
            steps_done: start_step + run_steps,
            stages: p,
            chunks,
            microbatches: m,
            seed: cfg.seed,
        }
        .save(dir)?;
    }
    Ok(TrainResult {
        losses,
        step_times,
        stage_stats,
        schedule,
        tokens: run_steps * m * (b * s_len) as u64,
    })
}

/// Everything the data feeder owns: the corpus, the feed rings, and the
/// recycle ring bringing spent token/target tensors back.
struct FeederState {
    corpus: SyntheticCorpus,
    tok_tx: SyncSender<(u64, HostTensor)>,
    tgt_tx: SyncSender<(u64, HostTensor)>,
    recycle_rx: Receiver<HostTensor>,
    shape: Vec<i64>,
    b: usize,
    s: usize,
    steps: u64,
    m: u64,
    start_step: u64,
    deadline: Option<Duration>,
    faults: Option<Arc<FaultPlan>>,
    /// fleet replica scope for the feeder's fault queries
    replica: Option<usize>,
}

/// Pop a recycled i32 tensor, or allocate a fresh one (warm-up only in
/// steady state).
fn take_i32_buf(free: &mut Vec<HostTensor>, shape: &[i64], n: usize) -> HostTensor {
    match free.pop() {
        Some(t @ HostTensor::I32 { .. }) => t,
        _ => HostTensor::I32 { data: Vec::with_capacity(n), shape: shape.to_vec() },
    }
}

/// Stream the corpus under backpressure.  Token/target tensors are drawn
/// from a free list fed by the recycle ring (the end-stage workers hand
/// their spent feeder-origin tensors back after the backward), so once
/// the list is warm a step feeds `2m` microbatches with ZERO feeder-side
/// heap allocations — sends busy-poll ([`spin_send`]) for the same
/// reason the workers do: parking can allocate on first use.
fn run_feeder(mut f: FeederState, mut hook: Option<&mut dyn FnMut(u64)>) -> anyhow::Result<()> {
    let n = f.b * f.s;
    // sized past the total feeder-origin tensor population (both feed
    // rings + both end-stage stashes + the recycle ring + two in hand),
    // so a steady-state push can never grow the list
    let mut free: Vec<HostTensor> = Vec::with_capacity(12 * f.m as usize + 16);
    for step in 1..=f.steps {
        if let Some(plan) = &f.faults {
            if let Some(ms) = plan.feeder_stall_due_for(f.replica, f.start_step + step) {
                // injected silence: downstream deadline waits must fire
                std::thread::sleep(Duration::from_millis(ms));
            }
        }
        for mb in 0..f.m {
            while let Ok(t) = f.recycle_rx.try_recv() {
                if free.len() < free.capacity() {
                    free.push(t);
                }
            }
            let mut tok_t = take_i32_buf(&mut free, &f.shape, n);
            let mut tgt_t = take_i32_buf(&mut free, &f.shape, n);
            match (&mut tok_t, &mut tgt_t) {
                (
                    HostTensor::I32 { data: tok, .. },
                    HostTensor::I32 { data: tgt, .. },
                ) => f.corpus.microbatch_into(f.b, f.s, tok, tgt),
                _ => unreachable!("take_i32_buf only yields i32 tensors"),
            }
            spin_send_deadline(&f.tok_tx, (mb, tok_t), f.deadline)
                .map_err(|e| anyhow::Error::new(e).context("feeding tokens to the first stage"))?;
            spin_send_deadline(&f.tgt_tx, (mb, tgt_t), f.deadline)
                .map_err(|e| anyhow::Error::new(e).context("feeding targets to the last stage"))?;
        }
        if let Some(h) = hook.as_mut() {
            h(step);
        }
    }
    Ok(())
}

fn spawn_feeder<'scope>(
    scope: &'scope std::thread::Scope<'scope, '_>,
    state: FeederState,
) -> anyhow::Result<std::thread::ScopedJoinHandle<'scope, anyhow::Result<()>>> {
    Ok(std::thread::Builder::new()
        .name("bpipe-feeder".into())
        .spawn_scoped(scope, move || run_feeder(state, None))?)
}

/// How the loss collector runs (its slice of the `TrainConfig` plus the
/// resume bookkeeping).
struct CollectConfig {
    run_steps: u64,
    m: u64,
    log_every: u64,
    total_steps: u64,
    start_step: u64,
    deadline: Option<Duration>,
    progress: Option<ProgressLog>,
}

/// Drain `m` losses per step from the last stage, averaging per step and
/// timing the leader-observed step wall clock.  Completed steps are
/// appended to the shared [`ProgressLog`] (when the run has one) as they
/// land — even a failed attempt leaves its completed prefix behind for
/// the supervisor to stitch.
fn collect_losses(
    loss_rx: Receiver<(u64, u64, f32)>,
    c: CollectConfig,
) -> anyhow::Result<(Vec<f32>, Vec<f64>)> {
    let mut losses = Vec::with_capacity(c.run_steps as usize);
    let mut step_times = Vec::with_capacity(c.run_steps as usize);
    let mut t_prev = Instant::now();
    for step in 1..=c.run_steps {
        let mut sum = 0f32;
        for _ in 0..c.m {
            let (got_step, _mb, loss) = spin_recv_deadline(&loss_rx, c.deadline)
                .map_err(|e| anyhow::Error::new(e).context(format!("collecting step {step}")))?;
            anyhow::ensure!(got_step == step, "loss for step {got_step}, expected {step}");
            sum += loss;
        }
        let mean = sum / c.m as f32;
        losses.push(mean);
        step_times.push(t_prev.elapsed().as_secs_f64());
        t_prev = Instant::now();
        if let Some(p) = &c.progress {
            p.record(c.start_step + step, mean);
        }
        if c.log_every > 0 && step % c.log_every == 0 {
            println!(
                "step {:>4}/{}  loss {:.4}  ({:.2}s/step)",
                c.start_step + step,
                c.total_steps,
                losses.last().unwrap(),
                step_times.last().unwrap()
            );
        }
    }
    Ok((losses, step_times))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SimBackend;
    use crate::schedule::ScheduleKind;

    #[test]
    fn plan_off_uses_natural_in_flight_capacities() {
        let (sched, caps) = plan_schedule(Family::OneFOneB, 4, 8, &RebalancePlan::Off);
        assert_eq!(caps, vec![4, 3, 2, 1]);
        assert_eq!(sched.kind, ScheduleKind::OneFOneB);
    }

    #[test]
    fn plan_uniform_caps_at_the_bound() {
        let (sched, caps) =
            plan_schedule(Family::OneFOneB, 4, 8, &RebalancePlan::Uniform { bound: None });
        // derived bound 3; stages whose natural high-water is below it
        // keep their tighter natural capacity
        assert_eq!(caps, vec![3, 3, 2, 1]);
        assert!(matches!(sched.kind, ScheduleKind::BPipe { bound: 3 }));
    }

    #[test]
    fn plan_small_m_clips() {
        let (_s, caps) =
            plan_schedule(Family::OneFOneB, 4, 2, &RebalancePlan::Uniform { bound: None });
        assert_eq!(caps, vec![2, 2, 2, 1]);
    }

    #[test]
    fn plan_per_stage_caps_follow_the_vector() {
        let bounds = vec![5u64, 6, 6, 5, 4, 3, 2, 2];
        let (sched, caps) = plan_schedule(
            Family::OneFOneB,
            8,
            32,
            &RebalancePlan::PerStage { bounds: bounds.clone() },
        );
        assert_eq!(sched.stage_bounds.as_deref(), Some(&bounds[..]));
        for (s, &cap) in caps.iter().enumerate() {
            assert!(cap as u64 <= bounds[s], "stage {s}: {cap} > {}", bounds[s]);
        }
    }

    #[test]
    fn plan_capacity_derives_from_the_experiment() {
        let e = crate::config::paper_experiment(8).unwrap();
        let (sched, _caps) = plan_schedule(
            Family::OneFOneB,
            e.parallel.p,
            e.parallel.num_microbatches(),
            &RebalancePlan::Capacity { experiment: e.clone() },
        );
        assert_eq!(sched.stage_bounds, Some(vec![5, 6, 6, 5, 4, 3, 2, 2]));
    }

    #[test]
    fn plan_covers_multi_chunk_families() {
        for family in [Family::VShaped, Family::Interleaved { v: 2 }, Family::ZigZag { v: 4 }] {
            let (sched, caps) =
                plan_schedule(family, 4, 8, &RebalancePlan::Uniform { bound: None });
            assert_eq!(sched.chunks, family.chunks());
            assert_eq!(caps.len(), 4);
            assert!(caps.iter().all(|&c| c >= 1));
        }
    }

    #[test]
    fn probed_training_matches_unprobed_and_hooks_every_step() {
        let cfg = TrainConfig {
            manifest: Some(Manifest::synthetic(4, 16, 8, 2, 64, &[1, 2])),
            steps: 3,
            microbatches: 4,
            lr: 2e-3,
            seed: 3,
            rebalance: RebalancePlan::Uniform { bound: None },
            ..TrainConfig::default()
        };
        let plain = train::<SimBackend>(&cfg).unwrap();
        let mut seen = Vec::new();
        let probed = train_probed::<SimBackend>(&cfg, 0, &mut |s| seen.push(s)).unwrap();
        assert_eq!(seen, vec![1, 2, 3], "hook must fire once per step");
        assert_eq!(plain.losses, probed.losses, "probing must not change numerics");
        let stages: Vec<u64> = probed.stage_stats.iter().map(|s| s.stage).collect();
        assert_eq!(stages, vec![0, 1, 2, 3], "stats stay in stage order");
        assert_eq!(
            plain.stage_stats[0].stash_high_water,
            probed.stage_stats[0].stash_high_water
        );
        // out-of-range probe stage is rejected up front
        assert!(train_probed::<SimBackend>(&cfg, 9, &mut |_| {}).is_err());
    }

    #[test]
    fn feeder_probe_matches_unprobed_and_hooks_every_step() {
        let cfg = TrainConfig {
            manifest: Some(Manifest::synthetic(4, 16, 8, 2, 64, &[1, 2])),
            steps: 3,
            microbatches: 4,
            lr: 2e-3,
            seed: 3,
            rebalance: RebalancePlan::Uniform { bound: None },
            ..TrainConfig::default()
        };
        let plain = train::<SimBackend>(&cfg).unwrap();
        let mut seen = Vec::new();
        let probed = train_probed_feeder::<SimBackend>(&cfg, &mut |s| seen.push(s)).unwrap();
        assert_eq!(seen, vec![1, 2, 3], "hook must fire once per fed step");
        assert_eq!(plain.losses, probed.losses, "feeder probing must not change numerics");
    }
}
