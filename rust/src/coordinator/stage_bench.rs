//! Single-stage measurement — the runtime half of the paper's §4 recipe.
//!
//! "Evaluate a small part of the model with fewer resources" (paper §5):
//! run ONE mid stage's fwd+bwd at several microbatch sizes through the
//! real PJRT executables, time them, and feed the resulting
//! `MFU_stage(b)` ratios into the Eq. 4 estimator.  On CPU the absolute
//! peak is irrelevant — Eq. 4 only consumes *ratios* of stage MFUs, and
//! throughput/time ratios are peak-independent.

use std::path::Path;
use std::time::Instant;

use crate::runtime::{literal_f32, Manifest, Runtime};

/// Timing of one stage at one microbatch size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageTiming {
    pub b: u64,
    /// mean seconds per (fwd + bwd) of one microbatch
    pub t_b: f64,
    /// tokens processed per second by the stage
    pub tokens_per_s: f64,
    /// stage model FLOPs per second (from the analytic per-token count)
    pub flops_per_s: f64,
}

/// Measure `mid_fwd_b{b}` + `mid_bwd_b{b}` over `iters` repetitions
/// (after one warmup) and return mean per-microbatch timing.
pub fn measure_stage(
    artifacts_dir: &Path,
    b: u64,
    iters: u32,
) -> anyhow::Result<StageTiming> {
    let manifest = Manifest::load(artifacts_dir)?;
    anyhow::ensure!(
        manifest.bs_sweep.contains(&b),
        "b={b} not in the artifact sweep {:?}; re-run `make artifacts` with --bs-sweep",
        manifest.bs_sweep
    );
    let rt = Runtime::cpu()?;
    let fwd = rt.load(&manifest.path_of(&format!("mid_fwd_b{b}"))?)?;
    let bwd = rt.load(&manifest.path_of(&format!("mid_bwd_b{b}"))?)?;
    let spec = &manifest.spec;
    let n = manifest.param_count("mid")? as usize;

    // deterministic pseudo-random inputs (content doesn't affect timing)
    let params: Vec<f32> = (0..n).map(|i| ((i * 2654435761) % 1000) as f32 * 1e-4 - 0.05).collect();
    let act_len = (spec.b_override(b) * spec.s * spec.h) as usize;
    let x: Vec<f32> = (0..act_len).map(|i| ((i * 40503) % 997) as f32 * 1e-3 - 0.5).collect();
    let shape = [b as i64, spec.s as i64, spec.h as i64];
    let params_lit = xla::Literal::vec1(&params);
    let x_lit = literal_f32(&x, &shape)?;
    let dy_lit = literal_f32(&x, &shape)?;

    // warmup (first execution pays one-time costs)
    let y = fwd.run1(&[&params_lit, &x_lit])?;
    let _ = bwd.run(&[&params_lit, &x_lit, &dy_lit])?;
    drop(y);

    let t0 = Instant::now();
    for _ in 0..iters {
        let _y = fwd.run1(&[&params_lit, &x_lit])?;
        let _g = bwd.run(&[&params_lit, &x_lit, &dy_lit])?;
    }
    let t_b = t0.elapsed().as_secs_f64() / iters as f64;

    // analytic stage model-FLOPs for this artifact config (fwd+bwd = 3×fwd)
    let tokens = b * spec.s;
    let flops = stage_model_flops(spec, b);
    Ok(StageTiming {
        b,
        t_b,
        tokens_per_s: tokens as f64 / t_b,
        flops_per_s: flops / t_b,
    })
}

/// Analytic fwd+bwd model FLOPs of one mid stage of the tiny artifact
/// model (matmul terms only, Eq. 1 style: 72·b·s·L·h²·(1+s/6h)).
pub fn stage_model_flops(spec: &crate::runtime::artifact::SpecMeta, b: u64) -> f64 {
    let (h, s) = (spec.h as f64, spec.s as f64);
    72.0 * b as f64 * s * spec.layers_per_stage as f64 * h * h * (1.0 + s / (6.0 * h))
}

impl crate::runtime::artifact::SpecMeta {
    /// the sweep artifacts share every dimension except b
    fn b_override(&self, b: u64) -> u64 {
        let _ = self.b;
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_model_flops_linear_in_b() {
        let spec = crate::runtime::artifact::SpecMeta {
            family: "llama".into(),
            h: 256,
            a: 8,
            s: 128,
            v: 4096,
            layers_per_stage: 2,
            stages: 4,
            b: 2,
            attention: "flash".into(),
        };
        let f1 = stage_model_flops(&spec, 1);
        let f4 = stage_model_flops(&spec, 4);
        assert!((f4 / f1 - 4.0).abs() < 1e-12);
        // 72·128·2·256²·(1+128/1536) ≈ 1.3e9
        assert!(f1 > 1e9 && f1 < 2e9, "{f1:e}");
    }
}
