//! Single-stage measurement — the runtime half of the paper's §4 recipe.
//!
//! "Evaluate a small part of the model with fewer resources" (paper §5):
//! run ONE mid stage's fwd+bwd at several microbatch sizes through the
//! execution backend, time them, and feed the resulting `MFU_stage(b)`
//! ratios into the Eq. 4 estimator.  The absolute peak is irrelevant on
//! a laptop (or under the sim backend) — Eq. 4 only consumes *ratios*
//! of stage MFUs, and throughput/time ratios are peak-independent.

use std::time::Instant;

use crate::runtime::{Arg, Backend, BufferPool, HostTensor, Manifest};

/// Timing of one stage at one microbatch size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageTiming {
    pub b: u64,
    /// mean seconds per (fwd + bwd) of one microbatch
    pub t_b: f64,
    /// tokens processed per second by the stage
    pub tokens_per_s: f64,
    /// stage model FLOPs per second (from the analytic per-token count)
    pub flops_per_s: f64,
}

/// Measure `mid_fwd_b{b}` + `mid_bwd_b{b}` over `iters` repetitions
/// (after one warmup) and return mean per-microbatch timing.
pub fn measure_stage<B: Backend>(
    manifest: &Manifest,
    b: u64,
    iters: u32,
) -> anyhow::Result<StageTiming> {
    anyhow::ensure!(
        manifest.bs_sweep.contains(&b),
        "b={b} not in the artifact sweep {:?}; re-run `make artifacts` with --bs-sweep",
        manifest.bs_sweep
    );
    let backend = B::create(manifest)?;
    let fwd = backend.compile(manifest, &format!("mid_fwd_b{b}"))?;
    let bwd = backend.compile(manifest, &format!("mid_bwd_b{b}"))?;
    let spec = &manifest.spec;
    let n = manifest.param_count("mid")? as usize;

    // deterministic pseudo-random inputs (content doesn't affect timing)
    let params: Vec<f32> =
        (0..n).map(|i| ((i * 2654435761) % 1000) as f32 * 1e-4 - 0.05).collect();
    let act_len = (b * spec.s * spec.h) as usize;
    let x: Vec<f32> = (0..act_len).map(|i| ((i * 40503) % 997) as f32 * 1e-3 - 0.5).collect();
    let shape = vec![b as i64, spec.s as i64, spec.h as i64];
    let params_buf = backend.upload(&HostTensor::vec_f32(params))?;
    let x_t = HostTensor::F32 { data: x.clone(), shape: shape.clone() };
    let dy_t = HostTensor::F32 { data: x, shape };

    // the measured loop runs the runtime's own discipline: borrowed
    // inputs, pooled outputs recycled every iteration (the warm-up
    // iteration pays the pool's one-time allocations)
    let mut pool = BufferPool::new();
    let mut out = Vec::new();
    let once = |pool: &mut BufferPool, out: &mut Vec<HostTensor>| -> anyhow::Result<()> {
        let mut fwd_args = [Arg::Borrowed(&x_t)];
        backend.execute_pooled(&fwd, Some(&params_buf), &mut fwd_args, pool, out)?;
        for t in out.drain(..) {
            pool.give(t);
        }
        let mut bwd_args = [Arg::Borrowed(&x_t), Arg::Borrowed(&dy_t)];
        backend.execute_pooled(&bwd, Some(&params_buf), &mut bwd_args, pool, out)?;
        for t in out.drain(..) {
            pool.give(t);
        }
        Ok(())
    };
    once(&mut pool, &mut out)?; // warmup (first execution pays one-time costs)

    let t0 = Instant::now();
    for _ in 0..iters {
        once(&mut pool, &mut out)?;
    }
    let t_b = t0.elapsed().as_secs_f64() / iters.max(1) as f64;

    // analytic stage model-FLOPs for this artifact config (fwd+bwd = 3×fwd)
    let tokens = b * spec.s;
    let flops = stage_model_flops(spec, b);
    Ok(StageTiming { b, t_b, tokens_per_s: tokens as f64 / t_b, flops_per_s: flops / t_b })
}

/// Analytic fwd+bwd model FLOPs of one mid stage of the tiny artifact
/// model (matmul terms only, Eq. 1 style: 72·b·s·L·h²·(1+s/6h)).
pub fn stage_model_flops(spec: &crate::runtime::artifact::SpecMeta, b: u64) -> f64 {
    let (h, s) = (spec.h as f64, spec.s as f64);
    72.0 * b as f64 * s * spec.layers_per_stage as f64 * h * h * (1.0 + s / (6.0 * h))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SimBackend;

    #[test]
    fn stage_model_flops_linear_in_b() {
        let spec = crate::runtime::artifact::SpecMeta {
            family: "llama".into(),
            h: 256,
            a: 8,
            s: 128,
            v: 4096,
            layers_per_stage: 2,
            stages: 4,
            b: 2,
            attention: "flash".into(),
        };
        let f1 = stage_model_flops(&spec, 1);
        let f4 = stage_model_flops(&spec, 4);
        assert!((f4 / f1 - 4.0).abs() < 1e-12);
        // 72·128·2·256²·(1+128/1536) ≈ 1.3e9
        assert!(f1 > 1e9 && f1 < 2e9, "{f1:e}");
    }

    #[test]
    fn measures_the_sim_backend_single_stage() {
        let m = Manifest::synthetic(4, 16, 8, 2, 64, &[1, 2]);
        let t = measure_stage::<SimBackend>(&m, 2, 2).unwrap();
        assert_eq!(t.b, 2);
        assert!(t.t_b > 0.0 && t.t_b.is_finite());
        assert!(t.tokens_per_s > 0.0 && t.flops_per_s > 0.0);
        // an unlisted microbatch size is rejected up front
        assert!(measure_stage::<SimBackend>(&m, 7, 1).is_err());
    }
}
