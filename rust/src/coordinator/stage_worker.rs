//! One pipeline-stage worker: owns the compiled executables, parameters
//! and optimizer state of every virtual-pipeline chunk it hosts, and
//! executes its [`StageProgram`] op-by-op for every training step.
//!
//! Workers are plain OS threads connected by channels (activations
//! downstream per chunk boundary, gradients upstream, BPipe evict/load
//! to the pair store), generic over the execution [`Backend`]: the PJRT
//! path and the in-tree [`crate::runtime::SimBackend`] run the exact
//! same loop.  Each worker creates its own backend client — `xla`
//! handles are not `Send`, and a per-worker client is also the honest
//! analogue of one-process-per-GPU.
//!
//! Multi-chunk programs (interleaved / V-shaped / zig-zag) are
//! first-class: ops carry a `chunk` field selecting the per-chunk state,
//! the stash is keyed by `(mb, chunk)` under ONE per-stage capacity (the
//! rebalance transform's bound is a per-stage resident count across
//! chunks), and the chunk whose virtual stage is 0 / `vp − 1` consumes
//! the leader's token / target streams.
//!
//! ## The zero-alloc hot path
//!
//! The step loop lives in [`StageRunner`] and moves every tensor **by
//! handle**: received activations are donated into the backend
//! ([`Backend::execute_pooled`]), outputs draw from the worker's
//! [`BufferPool`], stashes are fixed-size [`Stash`] handles in a
//! preallocated slot store, channel sends transfer ownership through
//! bounded ring buffers, and the Adam flush donates `(w, g, m, v)` so
//! the optimizer updates in place — no `grad_acc` clone, no parameter
//! re-upload allocation ([`Backend::upload_into`]).  After the warm-up
//! step populates the pool, a steady-state step performs **zero heap
//! allocations** on this thread — pinned by the counting-allocator test
//! in `rust/tests/alloc_steady_state.rs` via
//! [`crate::coordinator::pipeline::train_probed`].

use std::path::PathBuf;
use std::sync::mpsc::{Receiver, SyncSender};
use std::time::{Duration, Instant};

use super::activation_store::{
    spin_recv_deadline, spin_send_deadline, ActivationStore, HostTensor, RemoteStoreClient, Stash,
};
use super::checkpoint::{CheckpointWriter, StageCheckpoint};
use super::supervisor;
use crate::runtime::{Arg, Backend, BufferPool, InjectedFault, Manifest};
use crate::schedule::{OpKind, Placement, StageProgram};

/// Static configuration for one worker.
pub struct WorkerConfig {
    pub stage: u64,
    /// physical pipeline depth
    pub stages: u64,
    /// virtual chunks hosted per stage (1 unless interleaved/V/zig-zag)
    pub chunks: u64,
    pub placement: Placement,
    pub steps: u64,
    pub microbatches: u64,
    pub lr: f32,
    pub seed: i32,
    /// the artifact contract (shapes, param counts); workers get a copy
    /// so in-memory synthetic manifests need no artifacts directory
    pub manifest: Manifest,
    pub program: StageProgram,
    /// activation-store capacity this schedule was built for (resident
    /// stashes across ALL hosted chunks)
    pub capacity: usize,
    /// checkpoint directory (params + Adam moments per virtual stage)
    pub checkpoint_dir: Option<PathBuf>,
    /// save every n steps (0 = only after the final step)
    pub checkpoint_every: u64,
    /// load state from the checkpoint dir instead of initializing
    pub resume: bool,
    /// global step offset (steps already done before this run)
    pub start_step: u64,
    /// channel-wait deadline; `None` spins forever (zero-clock hot path)
    pub deadline: Option<Duration>,
    /// in-place retries for transient `execute` failures before the
    /// error escalates to the supervisor
    pub retry_budget: u32,
    /// base backoff between transient-execute retries (doubles per
    /// attempt, capped)
    pub retry_backoff_ms: u64,
    /// fleet replica this pipeline belongs to (`None` for a standalone
    /// run); bound into the backend so replica-scoped faults resolve
    pub replica: Option<usize>,
}

/// Channel endpoints for one worker, indexed by hosted chunk (`None`
/// where the topology has no edge — chunk boundaries at the pipeline
/// ends, or streams belonging to another stage).  Senders are bounded
/// ([`SyncSender`]): the ring buffers are allocated at wiring time, so a
/// steady-state send is a slot write, not an allocation.
pub struct WorkerChannels {
    pub act_in: Vec<Option<Receiver<(u64, HostTensor)>>>,
    pub act_out: Vec<Option<SyncSender<(u64, HostTensor)>>>,
    pub grad_in: Vec<Option<Receiver<(u64, HostTensor)>>>,
    pub grad_out: Vec<Option<SyncSender<(u64, HostTensor)>>>,
    /// leader → host of virtual stage 0: input tokens per microbatch
    pub tokens_in: Option<Receiver<(u64, HostTensor)>>,
    /// leader → host of the last virtual stage: target tokens
    pub targets_in: Option<Receiver<(u64, HostTensor)>>,
    /// host of the last virtual stage → leader: (step, microbatch, loss)
    pub loss_out: Option<SyncSender<(u64, u64, f32)>>,
    /// spent token/target tensors back to the feeder's free list
    /// (present on the hosts of virtual stages 0 and vp−1)
    pub recycle_out: Option<SyncSender<HostTensor>>,
    /// BPipe pair store (present iff the program contains Evict/Load)
    pub remote: Option<RemoteStoreClient>,
}

/// What a worker reports when it finishes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageStats {
    pub stage: u64,
    /// parameters across all hosted chunks
    pub param_count: usize,
    pub compile_s: f64,
    pub fwd_s: f64,
    pub bwd_s: f64,
    pub adam_s: f64,
    /// time blocked waiting for BPipe loads (the technique's overhead)
    pub load_wait_s: f64,
    pub evictions: u64,
    pub stash_high_water: usize,
    pub stash_high_water_bytes: usize,
    /// buffer-pool takes served from a free list (steady state)
    pub pool_hits: u64,
    /// buffer-pool takes that allocated fresh (warm-up)
    pub pool_misses: u64,
    /// transient `execute` failures retried in place without a restart
    pub retried_executes: u64,
}

fn recv_expect(
    rx: &Receiver<(u64, HostTensor)>,
    mb: u64,
    what: &str,
    stage: u64,
    deadline: Option<Duration>,
) -> anyhow::Result<HostTensor> {
    // busy-polled so a steady-state wait never touches the allocator;
    // the typed ChannelError stays in the chain so the supervisor can
    // tell a stalled peer (Timeout) from a dead one (Closed)
    let (got, t) = spin_recv_deadline(rx, deadline)
        .map_err(|e| anyhow::Error::new(e).context(format!("stage {stage}: waiting for {what}")))?;
    anyhow::ensure!(got == mb, "stage {stage}: expected {what} for mb {mb}, got {got}");
    Ok(t)
}

/// A channel edge the program requires: a missing one is a wiring bug,
/// reported as a typed error instead of a panic so it reaches the
/// supervisor like every other worker failure.
fn edge<'a, T>(opt: Option<&'a T>, stage: u64, what: &str) -> anyhow::Result<&'a T> {
    opt.ok_or_else(|| anyhow::anyhow!("stage {stage}: program requires {what}, but none is wired"))
}

/// `execute_pooled` with an in-place retry budget for injected transient
/// failures.  Safe to retry because [`crate::runtime::FaultyBackend`]
/// fails at entry, before any donated argument is consumed — the arg
/// slots are still live on the second attempt.  Real (non-injected)
/// errors escalate immediately.
#[allow(clippy::too_many_arguments)]
fn exec_retry<B: Backend>(
    backend: &B,
    exe: &B::Exec,
    params: Option<&B::Buffer>,
    args: &mut [Arg<'_>],
    pool: &mut BufferPool,
    outs: &mut Vec<HostTensor>,
    budget: u32,
    backoff_ms: u64,
    retried: &mut u64,
) -> anyhow::Result<()> {
    let mut attempt = 0u32;
    loop {
        match backend.execute_pooled(exe, params, args, pool, outs) {
            Ok(()) => return Ok(()),
            Err(e) => {
                let transient = e.chain().any(|c| {
                    matches!(
                        c.downcast_ref::<InjectedFault>(),
                        Some(InjectedFault::TransientExec { .. })
                    )
                });
                if !transient || attempt >= budget {
                    return Err(e);
                }
                *retried += 1;
                std::thread::sleep(Duration::from_millis(backoff_ms << attempt.min(6)));
                attempt += 1;
            }
        }
    }
}

/// Everything one hosted chunk owns: compiled executables, parameters
/// (host + device-resident copy), optimizer state, gradient accumulator.
struct ChunkState<B: Backend> {
    /// virtual-pipeline stage id (`placement.virtual_stage(p, s, c)`)
    virt: u64,
    kind: &'static str,
    n_params: usize,
    fwd: Option<B::Exec>,
    bwd: B::Exec,
    adam: B::Exec,
    params: HostTensor,
    m_state: HostTensor,
    v_state: HostTensor,
    params_buf: B::Buffer,
    grad_acc: HostTensor,
}

/// Hand a feeder-origin token tensor back: into the feeder's free list
/// when the recycle ring has room, into the local pool otherwise.
/// `try_send` only — a worker must never block towards the feeder (the
/// feeder may itself be spinning on a full feed ring), so this edge can
/// never deadlock and stays out of the protocol model's wait-for graph.
fn recycle(out: Option<&SyncSender<HostTensor>>, t: HostTensor, pool: &mut BufferPool) {
    use std::sync::mpsc::TrySendError;
    match out {
        Some(tx) => {
            if let Err(TrySendError::Full(t) | TrySendError::Disconnected(t)) = tx.try_send(t) {
                pool.give(t);
            }
        }
        None => pool.give(t),
    }
}

/// Accumulate a microbatch gradient into the chunk's running mean.
fn accumulate(acc: &mut HostTensor, dflat: &HostTensor, inv_m: f32) -> anyhow::Result<()> {
    for (a, g) in acc.f32s_mut()?.iter_mut().zip(dflat.f32s()?.iter()) {
        *a += g * inv_m;
    }
    Ok(())
}

/// The per-stage step executor: [`worker_main`] drives it to completion
/// on a worker thread, and `pipeline::train_probed` drives it on the
/// caller's thread so tests/benches can observe each step (e.g. count
/// heap allocations between steps).
pub struct StageRunner<B: Backend> {
    cfg: WorkerConfig,
    ch: WorkerChannels,
    backend: B,
    chunks: Vec<ChunkState<B>>,
    stash: ActivationStore,
    pool: BufferPool,
    /// one per chunk when checkpointing is on (empty otherwise) — holds
    /// the serialization scratch so a checkpoint step stays
    /// allocation-free after the first save
    ckpt_writers: Vec<CheckpointWriter>,
    outs: Vec<HostTensor>,
    step_t: HostTensor,
    lr_t: HostTensor,
    inv_m: f32,
    stats: StageStats,
}

impl<B: Backend> StageRunner<B> {
    pub fn new(cfg: WorkerConfig, ch: WorkerChannels) -> anyhow::Result<Self> {
        let mut backend = B::create(&cfg.manifest)?;
        backend.bind_stage(cfg.stage);
        if let Some(r) = cfg.replica {
            backend.bind_replica(r);
        }
        let manifest = &cfg.manifest;
        let spec = &manifest.spec;
        let vp = cfg.stages * cfg.chunks;
        anyhow::ensure!(
            spec.stages == vp,
            "manifest describes {} virtual stages, schedule needs {vp}",
            spec.stages
        );

        // -- per-chunk state ------------------------------------------------
        let t0 = Instant::now();
        let mut chunks: Vec<ChunkState<B>> = Vec::with_capacity(cfg.chunks as usize);
        for c in 0..cfg.chunks {
            let virt = cfg.placement.virtual_stage(cfg.stages, cfg.stage, c);
            let kind = manifest.stage_kind(virt);
            let n_params = manifest.param_count(kind)? as usize;
            // the last virtual stage computes loss+grads in one bwd artifact
            let fwd = if kind == "last" {
                None
            } else {
                Some(backend.compile(manifest, &format!("{kind}_fwd"))?)
            };
            let bwd = backend.compile(manifest, &format!("{kind}_bwd"))?;
            let adam = backend.compile(manifest, &format!("adam_{kind}"))?;
            let (params, m_state, v_state) = if cfg.resume {
                let dir = cfg
                    .checkpoint_dir
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("resume requested without a checkpoint dir"))?;
                // resume from the exact rollback step the supervisor
                // picked, not whichever generation happens to be newest
                let ck = if cfg.start_step > 0 {
                    StageCheckpoint::load_at(dir, virt, n_params, cfg.start_step)?
                } else {
                    StageCheckpoint::load(dir, virt, n_params)?
                };
                (
                    HostTensor::vec_f32(ck.params),
                    HostTensor::vec_f32(ck.m),
                    HostTensor::vec_f32(ck.v),
                )
            } else {
                let init = backend.compile(manifest, &format!("{kind}_init"))?;
                let seed = HostTensor::scalar_i32(cfg.seed + virt as i32);
                let mut outs = backend.execute_host(&init, &[&seed])?;
                anyhow::ensure!(outs.len() == 1, "{kind}_init: expected 1 output");
                let params = outs.pop().unwrap();
                anyhow::ensure!(params.len() == n_params, "{kind}_init returned a wrong size");
                let zeros = HostTensor::vec_f32(vec![0f32; n_params]);
                (params, zeros.clone(), zeros)
            };
            let params_buf = backend.upload(&params)?;
            chunks.push(ChunkState {
                virt,
                kind,
                n_params,
                fwd,
                bwd,
                adam,
                params,
                m_state,
                v_state,
                params_buf,
                grad_acc: HostTensor::vec_f32(vec![0f32; n_params]),
            });
        }
        let compile_s = t0.elapsed().as_secs_f64();

        let stats = StageStats {
            stage: cfg.stage,
            param_count: chunks.iter().map(|c| c.n_params).sum(),
            compile_s,
            ..Default::default()
        };
        let inv_m = 1.0f32 / cfg.microbatches as f32;
        let stash = ActivationStore::new(cfg.capacity, cfg.microbatches, cfg.chunks);
        // generous free-list bound: every in-flight stash and boundary
        // message of this worker fits with room to spare
        let pool_limit = (4 * cfg.microbatches * cfg.chunks) as usize + 32;
        let ckpt_writers = match &cfg.checkpoint_dir {
            Some(dir) => chunks.iter().map(|c| CheckpointWriter::new(dir, c.virt)).collect(),
            None => Vec::new(),
        };
        Ok(StageRunner {
            backend,
            chunks,
            stash,
            pool: BufferPool::with_limit(pool_limit),
            ckpt_writers,
            outs: Vec::with_capacity(4),
            step_t: HostTensor::scalar_i32(0),
            lr_t: HostTensor::scalar_f32(cfg.lr),
            inv_m,
            stats,
            cfg,
            ch,
        })
    }

    /// Execute one full training step (program ops + optimizer flush +
    /// checkpoint). `step` is 1-based within this run.  Any failure is
    /// classified into a structured [`supervisor::FailureReport`] so the
    /// leader can attribute it to this stage and global step.
    pub fn run_step(&mut self, step: u64) -> anyhow::Result<()> {
        let stage = self.cfg.stage;
        let global = self.cfg.start_step + step;
        self.run_step_inner(step)
            .map_err(|e| supervisor::into_failure(Some(stage), global, e))
    }

    fn run_step_inner(&mut self, step: u64) -> anyhow::Result<()> {
        let StageRunner {
            cfg,
            ch,
            backend,
            chunks,
            stash,
            pool,
            ckpt_writers,
            outs,
            step_t,
            lr_t,
            inv_m,
            stats,
        } = self;
        let inv_m = *inv_m;

        // injection point for crash / stall / HBM-cap faults (a no-op
        // default on real backends)
        backend.begin_step(cfg.start_step + step)?;

        for op in &cfg.program.ops {
            let ci = op.chunk as usize;
            let key = (op.mb, op.chunk);
            match op.kind {
                OpKind::Fwd => {
                    let t = Instant::now();
                    let cs = &chunks[ci];
                    if cs.kind == "last" {
                        // stash (x, targets); loss+grads run in Bwd
                        let x = recv_expect(
                            edge(ch.act_in[ci].as_ref(), cfg.stage, "act_in")?,
                            op.mb,
                            "act",
                            cfg.stage,
                            cfg.deadline,
                        )?;
                        let tgt = recv_expect(
                            edge(ch.targets_in.as_ref(), cfg.stage, "targets_in")?,
                            op.mb,
                            "targets",
                            cfg.stage,
                            cfg.deadline,
                        )?;
                        stash.put(key, Stash::pair(x, tgt));
                    } else {
                        let x = if cs.virt == 0 {
                            recv_expect(
                                edge(ch.tokens_in.as_ref(), cfg.stage, "tokens_in")?,
                                op.mb,
                                "tokens",
                                cfg.stage,
                                cfg.deadline,
                            )?
                        } else {
                            recv_expect(
                                edge(ch.act_in[ci].as_ref(), cfg.stage, "act_in")?,
                                op.mb,
                                "act",
                                cfg.stage,
                                cfg.deadline,
                            )?
                        };
                        // x stays stashed for the backward: borrowed, and
                        // y comes out of the pool
                        let mut args = [Arg::Borrowed(&x)];
                        exec_retry(
                            backend,
                            edge(cs.fwd.as_ref(), cfg.stage, "fwd executable")?,
                            Some(&cs.params_buf),
                            &mut args,
                            pool,
                            outs,
                            cfg.retry_budget,
                            cfg.retry_backoff_ms,
                            &mut stats.retried_executes,
                        )?;
                        anyhow::ensure!(outs.len() == 1, "fwd: expected 1 output");
                        let y = outs.pop().unwrap();
                        stash.put(key, Stash::single(x));
                        spin_send_deadline(
                            edge(ch.act_out[ci].as_ref(), cfg.stage, "act_out")?,
                            (op.mb, y),
                            cfg.deadline,
                        )
                        .map_err(|e| {
                            anyhow::Error::new(e)
                                .context(format!("stage {}: sending act downstream", cfg.stage))
                        })?;
                    }
                    stats.fwd_s += t.elapsed().as_secs_f64();
                }
                OpKind::Bwd => {
                    let t = Instant::now();
                    let cs = &mut chunks[ci];
                    match cs.kind {
                        "last" => {
                            let st = stash.take(key);
                            let tgt = st
                                .extra
                                .ok_or_else(|| anyhow::anyhow!("last stash missing targets"))?;
                            // targets are feeder-origin: borrowed (mask-
                            // invariant numerics) so the tensor survives
                            // to be recycled back to the feeder
                            let mut args = [Arg::Donated(st.x), Arg::Borrowed(&tgt)];
                            exec_retry(
                                backend,
                                &cs.bwd,
                                Some(&cs.params_buf),
                                &mut args,
                                pool,
                                outs,
                                cfg.retry_budget,
                                cfg.retry_backoff_ms,
                                &mut stats.retried_executes,
                            )?;
                            anyhow::ensure!(outs.len() == 3, "last_bwd: expected (dx, dw, loss)");
                            let loss = outs.pop().unwrap();
                            let dflat = outs.pop().unwrap();
                            let dx = outs.pop().unwrap();
                            spin_send_deadline(
                                edge(ch.grad_out[ci].as_ref(), cfg.stage, "grad_out")?,
                                (op.mb, dx),
                                cfg.deadline,
                            )
                            .map_err(|e| {
                                anyhow::Error::new(e)
                                    .context(format!("stage {}: sending grad upstream", cfg.stage))
                            })?;
                            spin_send_deadline(
                                edge(ch.loss_out.as_ref(), cfg.stage, "loss_out")?,
                                (step, op.mb, loss.f32s()?[0]),
                                cfg.deadline,
                            )
                            .map_err(|e| {
                                anyhow::Error::new(e)
                                    .context(format!("stage {}: reporting loss", cfg.stage))
                            })?;
                            pool.give(loss);
                            accumulate(&mut cs.grad_acc, &dflat, inv_m)?;
                            pool.give(dflat);
                            recycle(ch.recycle_out.as_ref(), tgt, pool);
                        }
                        "mid" => {
                            let dy = recv_expect(
                                edge(ch.grad_in[ci].as_ref(), cfg.stage, "grad_in")?,
                                op.mb,
                                "grad",
                                cfg.stage,
                                cfg.deadline,
                            )?;
                            let st = stash.take(key);
                            let mut args = [Arg::Donated(st.x), Arg::Donated(dy)];
                            exec_retry(
                                backend,
                                &cs.bwd,
                                Some(&cs.params_buf),
                                &mut args,
                                pool,
                                outs,
                                cfg.retry_budget,
                                cfg.retry_backoff_ms,
                                &mut stats.retried_executes,
                            )?;
                            anyhow::ensure!(outs.len() == 2, "mid_bwd: expected (dx, dw)");
                            let dflat = outs.pop().unwrap();
                            let dx = outs.pop().unwrap();
                            spin_send_deadline(
                                edge(ch.grad_out[ci].as_ref(), cfg.stage, "grad_out")?,
                                (op.mb, dx),
                                cfg.deadline,
                            )
                            .map_err(|e| {
                                anyhow::Error::new(e)
                                    .context(format!("stage {}: sending grad upstream", cfg.stage))
                            })?;
                            accumulate(&mut cs.grad_acc, &dflat, inv_m)?;
                            pool.give(dflat);
                        }
                        _ => {
                            // "first": virtual stage 0 — nothing upstream
                            let dy = recv_expect(
                                edge(ch.grad_in[ci].as_ref(), cfg.stage, "grad_in")?,
                                op.mb,
                                "grad",
                                cfg.stage,
                                cfg.deadline,
                            )?;
                            let st = stash.take(key);
                            // the stashed input is the feeder's token
                            // tensor: borrowed, then recycled
                            let mut args = [Arg::Borrowed(&st.x), Arg::Donated(dy)];
                            exec_retry(
                                backend,
                                &cs.bwd,
                                Some(&cs.params_buf),
                                &mut args,
                                pool,
                                outs,
                                cfg.retry_budget,
                                cfg.retry_backoff_ms,
                                &mut stats.retried_executes,
                            )?;
                            anyhow::ensure!(outs.len() == 1, "first_bwd: expected (dw,)");
                            let dflat = outs.pop().unwrap();
                            accumulate(&mut cs.grad_acc, &dflat, inv_m)?;
                            pool.give(dflat);
                            recycle(ch.recycle_out.as_ref(), st.x, pool);
                        }
                    }
                    stats.bwd_s += t.elapsed().as_secs_f64();
                }
                OpKind::Evict => {
                    let st = stash.take(key);
                    edge(ch.remote.as_ref(), cfg.stage, "remote store")?.evict(key, st)?;
                    stats.evictions += 1;
                }
                OpKind::Load => {
                    let t = Instant::now();
                    let st = edge(ch.remote.as_ref(), cfg.stage, "remote store")?.load(key)?;
                    stats.load_wait_s += t.elapsed().as_secs_f64();
                    stash.put(key, st);
                }
            }
        }
        anyhow::ensure!(stash.is_empty(), "stage {}: stashes leaked across steps", cfg.stage);

        // optimizer flush, per hosted chunk: donate (w, g, m, v) — Adam
        // updates in place and the spare state buffer comes back through
        // the pool as the next zeroed accumulator (no grad_acc clone)
        let t = Instant::now();
        step_t.set_scalar_i32((cfg.start_step + step) as i32)?;
        for cs in chunks.iter_mut() {
            let w = std::mem::replace(&mut cs.params, HostTensor::empty_f32());
            let g = std::mem::replace(&mut cs.grad_acc, HostTensor::empty_f32());
            let m = std::mem::replace(&mut cs.m_state, HostTensor::empty_f32());
            let v = std::mem::replace(&mut cs.v_state, HostTensor::empty_f32());
            let mut args = [
                Arg::Donated(w),
                Arg::Donated(g),
                Arg::Donated(m),
                Arg::Donated(v),
                Arg::Borrowed(&*step_t),
                Arg::Borrowed(&*lr_t),
            ];
            exec_retry(
                backend,
                &cs.adam,
                None,
                &mut args,
                pool,
                outs,
                cfg.retry_budget,
                cfg.retry_backoff_ms,
                &mut stats.retried_executes,
            )?;
            anyhow::ensure!(outs.len() == 3, "adam: expected (w, m, v)");
            cs.v_state = outs.pop().unwrap();
            cs.m_state = outs.pop().unwrap();
            cs.params = outs.pop().unwrap();
            backend.upload_into(&cs.params, &mut cs.params_buf)?; // refresh the device copy
            let mut acc = pool.take_f32_len(cs.n_params, &[cs.n_params as i64]);
            acc.f32s_mut()?.fill(0.0);
            cs.grad_acc = acc;
        }
        stats.adam_s += t.elapsed().as_secs_f64();

        // checkpoint (atomic; every n steps and always after the last)
        // — writers borrow the host buffers in place and reuse their
        // serialization scratch, so this adds no steady-state allocs
        if !ckpt_writers.is_empty() {
            let due = cfg.checkpoint_every > 0 && step % cfg.checkpoint_every == 0;
            if due || step == cfg.steps {
                for (cs, w) in chunks.iter().zip(ckpt_writers.iter_mut()) {
                    w.save(
                        cfg.start_step + step,
                        cs.params.f32s()?,
                        cs.m_state.f32s()?,
                        cs.v_state.f32s()?,
                    )?;
                }
            }
        }
        Ok(())
    }

    /// Steps this runner's program is configured for.
    pub fn steps(&self) -> u64 {
        self.cfg.steps
    }

    /// Shut down the remote store and report final statistics.
    pub fn finish(mut self) -> anyhow::Result<StageStats> {
        if let Some(remote) = &self.ch.remote {
            remote.shutdown();
        }
        self.stats.stash_high_water = self.stash.high_water;
        self.stats.stash_high_water_bytes = self.stash.high_water_bytes;
        self.stats.pool_hits = self.pool.hits;
        self.stats.pool_misses = self.pool.misses;
        Ok(self.stats)
    }
}

/// Worker entry point; runs `cfg.steps` iterations of `cfg.program`.
pub fn worker_main<B: Backend>(
    cfg: WorkerConfig,
    ch: WorkerChannels,
) -> anyhow::Result<StageStats> {
    let mut runner = StageRunner::<B>::new(cfg, ch)?;
    for step in 1..=runner.steps() {
        runner.run_step(step)?;
    }
    runner.finish()
}
