//! One pipeline-stage worker: owns the stage's compiled executables,
//! parameters and optimizer state, and executes its [`StageProgram`]
//! op-by-op for every training step.
//!
//! Workers are plain OS threads connected by channels (activations
//! downstream, gradients upstream, BPipe evict/load to the pair store).
//! Each worker creates its own PJRT client — `xla` handles are not
//! `Send`, and a per-worker client is also the honest analogue of
//! one-process-per-GPU.

use std::sync::mpsc::{Receiver, Sender};
use std::path::PathBuf;
use std::time::Instant;

use super::activation_store::{ActivationStore, HostTensor, RemoteStoreClient};
use super::checkpoint::StageCheckpoint;
use crate::runtime::{to_f32_vec, Manifest, Runtime};
use crate::schedule::{OpKind, StageProgram};

/// Static configuration for one worker.
pub struct WorkerConfig {
    pub stage: u64,
    pub stages: u64,
    pub steps: u64,
    pub microbatches: u64,
    pub lr: f32,
    pub seed: i32,
    pub artifacts_dir: PathBuf,
    pub program: StageProgram,
    /// activation-store capacity this schedule was built for
    pub capacity: usize,
    /// checkpoint directory (params + Adam moments per stage)
    pub checkpoint_dir: Option<PathBuf>,
    /// save every n steps (0 = only after the final step)
    pub checkpoint_every: u64,
    /// load state from the checkpoint dir instead of initializing
    pub resume: bool,
    /// global step offset (steps already done before this run)
    pub start_step: u64,
}

/// Channel endpoints for one worker (None where the topology has no edge).
pub struct WorkerChannels {
    pub act_in: Option<Receiver<(u64, HostTensor)>>,
    pub act_out: Option<Sender<(u64, HostTensor)>>,
    pub grad_in: Option<Receiver<(u64, HostTensor)>>,
    pub grad_out: Option<Sender<(u64, HostTensor)>>,
    /// leader → stage 0: input tokens per microbatch
    pub tokens_in: Option<Receiver<(u64, HostTensor)>>,
    /// leader → last stage: target tokens per microbatch
    pub targets_in: Option<Receiver<(u64, HostTensor)>>,
    /// last stage → leader: (step, microbatch, loss)
    pub loss_out: Option<Sender<(u64, u64, f32)>>,
    /// BPipe pair store (present iff the program contains Evict/Load)
    pub remote: Option<RemoteStoreClient>,
}

/// What a worker reports when it finishes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageStats {
    pub stage: u64,
    pub param_count: usize,
    pub compile_s: f64,
    pub fwd_s: f64,
    pub bwd_s: f64,
    pub adam_s: f64,
    /// time blocked waiting for BPipe loads (the technique's overhead)
    pub load_wait_s: f64,
    pub evictions: u64,
    pub stash_high_water: usize,
    pub stash_high_water_bytes: usize,
}

fn recv_expect(
    rx: &Receiver<(u64, HostTensor)>,
    mb: u64,
    what: &str,
    stage: u64,
) -> anyhow::Result<HostTensor> {
    let (got, t) = rx
        .recv()
        .map_err(|_| anyhow::anyhow!("stage {stage}: {what} channel closed early"))?;
    anyhow::ensure!(got == mb, "stage {stage}: expected {what} for mb {mb}, got {got}");
    Ok(t)
}

/// Worker entry point; runs `cfg.steps` iterations of `cfg.program`.
pub fn worker_main(cfg: WorkerConfig, ch: WorkerChannels) -> anyhow::Result<StageStats> {
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let kind = manifest.stage_kind(cfg.stage);
    let n_params = manifest.param_count(kind)? as usize;
    let spec = &manifest.spec;
    let act_shape = vec![spec.b as i64, spec.s as i64, spec.h as i64];

    let t0 = Instant::now();
    let init = rt.load(&manifest.path_of(&format!("{kind}_init"))?)?;
    // the last stage computes loss+grads in one bwd artifact; no fwd exe
    let fwd = if kind == "last" {
        None
    } else {
        Some(rt.load(&manifest.path_of(&format!("{kind}_fwd"))?)?)
    };
    let bwd = rt.load(&manifest.path_of(&format!("{kind}_bwd"))?)?;
    let adam = rt.load(&manifest.path_of(&format!("adam_{kind}"))?)?;
    let compile_s = t0.elapsed().as_secs_f64();

    // Parameters live as a DEVICE-RESIDENT buffer within a step (they
    // only change at the optimizer boundary), so the per-op hot path
    // uploads just the activation; optimizer state stays host-side.
    let (mut params, mut m_state, mut v_state) = if cfg.resume {
        let dir = cfg.checkpoint_dir.as_ref().expect("resume without checkpoint dir");
        let ck = StageCheckpoint::load(dir, cfg.stage, n_params)?;
        (
            xla::Literal::vec1(&ck.params),
            xla::Literal::vec1(&ck.m),
            xla::Literal::vec1(&ck.v),
        )
    } else {
        let params = init.run1(&[xla::Literal::scalar(cfg.seed + cfg.stage as i32)])?;
        let zeros = vec![0f32; n_params];
        (params, xla::Literal::vec1(&zeros), xla::Literal::vec1(&zeros))
    };
    let mut params_buf = rt.upload_literal(&params)?;
    let mut grad_acc = vec![0f32; n_params];
    let inv_m = 1.0f32 / cfg.microbatches as f32;

    let mut stash = ActivationStore::new(cfg.capacity);
    let mut stats = StageStats {
        stage: cfg.stage,
        param_count: n_params,
        compile_s,
        ..Default::default()
    };

    for step in 1..=cfg.steps {
        for op in &cfg.program.ops {
            match op.kind {
                OpKind::Fwd => {
                    let t = Instant::now();
                    if kind == "last" {
                        // last stage: stash (x, targets); loss+grads run in Bwd
                        let x = recv_expect(ch.act_in.as_ref().unwrap(), op.mb, "act", cfg.stage)?;
                        let tgt = recv_expect(
                            ch.targets_in.as_ref().unwrap(),
                            op.mb,
                            "targets",
                            cfg.stage,
                        )?;
                        stash.put(op.mb, vec![x, tgt]);
                    } else {
                        let x = if cfg.stage == 0 {
                            recv_expect(ch.tokens_in.as_ref().unwrap(), op.mb, "tokens", cfg.stage)?
                        } else {
                            recv_expect(ch.act_in.as_ref().unwrap(), op.mb, "act", cfg.stage)?
                        };
                        let x_buf = x.to_buffer(&rt)?;
                        let y = fwd.as_ref().unwrap().run1_buffers(&[&params_buf, &x_buf])?;
                        stash.put(op.mb, vec![x]);
                        ch.act_out
                            .as_ref()
                            .unwrap()
                            .send((op.mb, HostTensor::F32 {
                                data: to_f32_vec(&y)?,
                                shape: act_shape.clone(),
                            }))
                            .map_err(|_| anyhow::anyhow!("act_out closed"))?;
                    }
                    stats.fwd_s += t.elapsed().as_secs_f64();
                }
                OpKind::Bwd => {
                    let t = Instant::now();
                    let dflat = match kind {
                        "last" => {
                            let ts = stash.take(op.mb);
                            let x_buf = ts[0].to_buffer(&rt)?;
                            let tgt_buf = ts[1].to_buffer(&rt)?;
                            let outs = bwd.run_buffers(&[&params_buf, &x_buf, &tgt_buf])?;
                            let (dx, dflat, loss) = (&outs[0], &outs[1], &outs[2]);
                            ch.grad_out
                                .as_ref()
                                .unwrap()
                                .send((op.mb, HostTensor::F32 {
                                    data: to_f32_vec(dx)?,
                                    shape: act_shape.clone(),
                                }))
                                .map_err(|_| anyhow::anyhow!("grad_out closed"))?;
                            ch.loss_out
                                .as_ref()
                                .unwrap()
                                .send((step, op.mb, loss.get_first_element::<f32>()?))
                                .map_err(|_| anyhow::anyhow!("loss_out closed"))?;
                            to_f32_vec(dflat)?
                        }
                        "mid" => {
                            let dy =
                                recv_expect(ch.grad_in.as_ref().unwrap(), op.mb, "grad", cfg.stage)?;
                            let x_buf = stash.take(op.mb)[0].to_buffer(&rt)?;
                            let dy_buf = dy.to_buffer(&rt)?;
                            let outs = bwd.run_buffers(&[&params_buf, &x_buf, &dy_buf])?;
                            ch.grad_out
                                .as_ref()
                                .unwrap()
                                .send((op.mb, HostTensor::F32 {
                                    data: to_f32_vec(&outs[0])?,
                                    shape: act_shape.clone(),
                                }))
                                .map_err(|_| anyhow::anyhow!("grad_out closed"))?;
                            to_f32_vec(&outs[1])?
                        }
                        _ => {
                            // first
                            let dy =
                                recv_expect(ch.grad_in.as_ref().unwrap(), op.mb, "grad", cfg.stage)?;
                            let tok_buf = stash.take(op.mb)[0].to_buffer(&rt)?;
                            let dy_buf = dy.to_buffer(&rt)?;
                            let outs = bwd.run_buffers(&[&params_buf, &tok_buf, &dy_buf])?;
                            to_f32_vec(&outs[0])?
                        }
                    };
                    for (a, g) in grad_acc.iter_mut().zip(dflat.iter()) {
                        *a += g * inv_m;
                    }
                    stats.bwd_s += t.elapsed().as_secs_f64();
                }
                OpKind::Evict => {
                    let tensors = stash.take(op.mb);
                    ch.remote.as_ref().expect("evict without remote store").evict(op.mb, tensors);
                    stats.evictions += 1;
                }
                OpKind::Load => {
                    let t = Instant::now();
                    let tensors = ch.remote.as_ref().expect("load without remote store").load(op.mb);
                    stats.load_wait_s += t.elapsed().as_secs_f64();
                    stash.put(op.mb, tensors);
                }
            }
        }
        anyhow::ensure!(stash.is_empty(), "stage {}: stashes leaked across steps", cfg.stage);

        // optimizer step
        let t = Instant::now();
        let g_lit = xla::Literal::vec1(&grad_acc);
        let outs = adam.run(&[
            &params,
            &g_lit,
            &m_state,
            &v_state,
            &xla::Literal::scalar((cfg.start_step + step) as i32),
            &xla::Literal::scalar(cfg.lr),
        ])?;
        let mut it = outs.into_iter();
        params = it.next().unwrap();
        m_state = it.next().unwrap();
        v_state = it.next().unwrap();
        params_buf = rt.upload_literal(&params)?; // refresh the device copy
        grad_acc.iter_mut().for_each(|g| *g = 0.0);
        stats.adam_s += t.elapsed().as_secs_f64();

        // checkpoint (atomic; every n steps and always after the last)
        if let Some(dir) = &cfg.checkpoint_dir {
            let due = cfg.checkpoint_every > 0 && step % cfg.checkpoint_every == 0;
            if due || step == cfg.steps {
                StageCheckpoint {
                    params: crate::runtime::to_f32_vec(&params)?,
                    m: crate::runtime::to_f32_vec(&m_state)?,
                    v: crate::runtime::to_f32_vec(&v_state)?,
                }
                .save(dir, cfg.stage)?;
            }
        }
    }

    if let Some(remote) = &ch.remote {
        remote.shutdown();
    }
    stats.stash_high_water = stash.high_water;
    stats.stash_high_water_bytes = stash.high_water_bytes;
    Ok(stats)
}
