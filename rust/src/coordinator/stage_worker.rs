//! One pipeline-stage worker: owns the compiled executables, parameters
//! and optimizer state of every virtual-pipeline chunk it hosts, and
//! executes its [`StageProgram`] op-by-op for every training step.
//!
//! Workers are plain OS threads connected by channels (activations
//! downstream per chunk boundary, gradients upstream, BPipe evict/load
//! to the pair store), generic over the execution [`Backend`]: the PJRT
//! path and the in-tree [`crate::runtime::SimBackend`] run the exact
//! same loop.  Each worker creates its own backend client — `xla`
//! handles are not `Send`, and a per-worker client is also the honest
//! analogue of one-process-per-GPU.
//!
//! Multi-chunk programs (interleaved / V-shaped / zig-zag) are
//! first-class: ops carry a `chunk` field selecting the per-chunk state,
//! the stash is keyed by `(mb, chunk)` under ONE per-stage capacity (the
//! rebalance transform's bound is a per-stage resident count across
//! chunks), and the chunk whose virtual stage is 0 / `vp − 1` consumes
//! the leader's token / target streams.

use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

use super::activation_store::{ActivationStore, HostTensor, RemoteStoreClient};
use super::checkpoint::StageCheckpoint;
use crate::runtime::{Backend, Manifest};
use crate::schedule::{OpKind, Placement, StageProgram};

/// Static configuration for one worker.
pub struct WorkerConfig {
    pub stage: u64,
    /// physical pipeline depth
    pub stages: u64,
    /// virtual chunks hosted per stage (1 unless interleaved/V/zig-zag)
    pub chunks: u64,
    pub placement: Placement,
    pub steps: u64,
    pub microbatches: u64,
    pub lr: f32,
    pub seed: i32,
    /// the artifact contract (shapes, param counts); workers get a copy
    /// so in-memory synthetic manifests need no artifacts directory
    pub manifest: Manifest,
    pub program: StageProgram,
    /// activation-store capacity this schedule was built for (resident
    /// stashes across ALL hosted chunks)
    pub capacity: usize,
    /// checkpoint directory (params + Adam moments per virtual stage)
    pub checkpoint_dir: Option<PathBuf>,
    /// save every n steps (0 = only after the final step)
    pub checkpoint_every: u64,
    /// load state from the checkpoint dir instead of initializing
    pub resume: bool,
    /// global step offset (steps already done before this run)
    pub start_step: u64,
}

/// Channel endpoints for one worker, indexed by hosted chunk (`None`
/// where the topology has no edge — chunk boundaries at the pipeline
/// ends, or streams belonging to another stage).
pub struct WorkerChannels {
    pub act_in: Vec<Option<Receiver<(u64, HostTensor)>>>,
    pub act_out: Vec<Option<Sender<(u64, HostTensor)>>>,
    pub grad_in: Vec<Option<Receiver<(u64, HostTensor)>>>,
    pub grad_out: Vec<Option<Sender<(u64, HostTensor)>>>,
    /// leader → host of virtual stage 0: input tokens per microbatch
    pub tokens_in: Option<Receiver<(u64, HostTensor)>>,
    /// leader → host of the last virtual stage: target tokens
    pub targets_in: Option<Receiver<(u64, HostTensor)>>,
    /// host of the last virtual stage → leader: (step, microbatch, loss)
    pub loss_out: Option<Sender<(u64, u64, f32)>>,
    /// BPipe pair store (present iff the program contains Evict/Load)
    pub remote: Option<RemoteStoreClient>,
}

/// What a worker reports when it finishes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageStats {
    pub stage: u64,
    /// parameters across all hosted chunks
    pub param_count: usize,
    pub compile_s: f64,
    pub fwd_s: f64,
    pub bwd_s: f64,
    pub adam_s: f64,
    /// time blocked waiting for BPipe loads (the technique's overhead)
    pub load_wait_s: f64,
    pub evictions: u64,
    pub stash_high_water: usize,
    pub stash_high_water_bytes: usize,
}

fn recv_expect(
    rx: &Receiver<(u64, HostTensor)>,
    mb: u64,
    what: &str,
    stage: u64,
) -> anyhow::Result<HostTensor> {
    let (got, t) = rx
        .recv()
        .map_err(|_| anyhow::anyhow!("stage {stage}: {what} channel closed early"))?;
    anyhow::ensure!(got == mb, "stage {stage}: expected {what} for mb {mb}, got {got}");
    Ok(t)
}

/// Everything one hosted chunk owns: compiled executables, parameters
/// (host + device-resident copy), optimizer state, gradient accumulator.
struct ChunkState<B: Backend> {
    /// virtual-pipeline stage id (`placement.virtual_stage(p, s, c)`)
    virt: u64,
    kind: &'static str,
    n_params: usize,
    fwd: Option<B::Exec>,
    bwd: B::Exec,
    adam: B::Exec,
    params: HostTensor,
    m_state: HostTensor,
    v_state: HostTensor,
    params_buf: B::Buffer,
    grad_acc: Vec<f32>,
}

/// Worker entry point; runs `cfg.steps` iterations of `cfg.program`.
pub fn worker_main<B: Backend>(
    cfg: WorkerConfig,
    ch: WorkerChannels,
) -> anyhow::Result<StageStats> {
    let backend = B::create(&cfg.manifest)?;
    let manifest = &cfg.manifest;
    let spec = &manifest.spec;
    let vp = cfg.stages * cfg.chunks;
    anyhow::ensure!(
        spec.stages == vp,
        "manifest describes {} virtual stages, schedule needs {vp}",
        spec.stages
    );

    // -- per-chunk state ----------------------------------------------------
    let t0 = Instant::now();
    let mut chunks: Vec<ChunkState<B>> = Vec::with_capacity(cfg.chunks as usize);
    for c in 0..cfg.chunks {
        let virt = cfg.placement.virtual_stage(cfg.stages, cfg.stage, c);
        let kind = manifest.stage_kind(virt);
        let n_params = manifest.param_count(kind)? as usize;
        // the last virtual stage computes loss+grads in one bwd artifact
        let fwd = if kind == "last" {
            None
        } else {
            Some(backend.compile(manifest, &format!("{kind}_fwd"))?)
        };
        let bwd = backend.compile(manifest, &format!("{kind}_bwd"))?;
        let adam = backend.compile(manifest, &format!("adam_{kind}"))?;
        let (params, m_state, v_state) = if cfg.resume {
            let dir = cfg.checkpoint_dir.as_ref().expect("resume without checkpoint dir");
            let ck = StageCheckpoint::load(dir, virt, n_params)?;
            (
                HostTensor::vec_f32(ck.params),
                HostTensor::vec_f32(ck.m),
                HostTensor::vec_f32(ck.v),
            )
        } else {
            let init = backend.compile(manifest, &format!("{kind}_init"))?;
            let seed = HostTensor::scalar_i32(cfg.seed + virt as i32);
            let mut outs = backend.execute_host(&init, &[&seed])?;
            anyhow::ensure!(outs.len() == 1, "{kind}_init: expected 1 output");
            let params = outs.pop().unwrap();
            anyhow::ensure!(params.len() == n_params, "{kind}_init returned a wrong size");
            let zeros = HostTensor::vec_f32(vec![0f32; n_params]);
            (params, zeros.clone(), zeros)
        };
        let params_buf = backend.upload(&params)?;
        chunks.push(ChunkState {
            virt,
            kind,
            n_params,
            fwd,
            bwd,
            adam,
            params,
            m_state,
            v_state,
            params_buf,
            grad_acc: vec![0f32; n_params],
        });
    }
    let compile_s = t0.elapsed().as_secs_f64();

    let inv_m = 1.0f32 / cfg.microbatches as f32;
    let mut stash = ActivationStore::new(cfg.capacity);
    let mut stats = StageStats {
        stage: cfg.stage,
        param_count: chunks.iter().map(|c| c.n_params).sum(),
        compile_s,
        ..Default::default()
    };

    for step in 1..=cfg.steps {
        for op in &cfg.program.ops {
            let ci = op.chunk as usize;
            let key = (op.mb, op.chunk);
            match op.kind {
                OpKind::Fwd => {
                    let t = Instant::now();
                    let cs = &chunks[ci];
                    if cs.kind == "last" {
                        // stash (x, targets); loss+grads run in Bwd
                        let x = recv_expect(
                            ch.act_in[ci].as_ref().expect("last chunk without act_in"),
                            op.mb,
                            "act",
                            cfg.stage,
                        )?;
                        let tgt = recv_expect(
                            ch.targets_in.as_ref().expect("last chunk without targets"),
                            op.mb,
                            "targets",
                            cfg.stage,
                        )?;
                        stash.put(key, vec![x, tgt]);
                    } else {
                        let x = if cs.virt == 0 {
                            recv_expect(
                                ch.tokens_in.as_ref().expect("first chunk without tokens"),
                                op.mb,
                                "tokens",
                                cfg.stage,
                            )?
                        } else {
                            recv_expect(
                                ch.act_in[ci].as_ref().expect("mid chunk without act_in"),
                                op.mb,
                                "act",
                                cfg.stage,
                            )?
                        };
                        let x_buf = backend.upload(&x)?;
                        let y = backend.execute1(
                            cs.fwd.as_ref().expect("non-last chunk has a fwd exe"),
                            &[&cs.params_buf, &x_buf],
                        )?;
                        stash.put(key, vec![x]);
                        ch.act_out[ci]
                            .as_ref()
                            .expect("non-last chunk without act_out")
                            .send((op.mb, y))
                            .map_err(|_| anyhow::anyhow!("act_out closed"))?;
                    }
                    stats.fwd_s += t.elapsed().as_secs_f64();
                }
                OpKind::Bwd => {
                    let t = Instant::now();
                    let cs = &mut chunks[ci];
                    let dflat = match cs.kind {
                        "last" => {
                            let ts = stash.take(key);
                            let x_buf = backend.upload(&ts[0])?;
                            let tgt_buf = backend.upload(&ts[1])?;
                            let outs =
                                backend.execute(&cs.bwd, &[&cs.params_buf, &x_buf, &tgt_buf])?;
                            anyhow::ensure!(outs.len() == 3, "last_bwd: expected (dx, dw, loss)");
                            let mut it = outs.into_iter();
                            let dx = it.next().unwrap();
                            let dflat = it.next().unwrap();
                            let loss = it.next().unwrap();
                            ch.grad_out[ci]
                                .as_ref()
                                .expect("last chunk without grad_out")
                                .send((op.mb, dx))
                                .map_err(|_| anyhow::anyhow!("grad_out closed"))?;
                            ch.loss_out
                                .as_ref()
                                .expect("last chunk without loss_out")
                                .send((step, op.mb, loss.f32s()?[0]))
                                .map_err(|_| anyhow::anyhow!("loss_out closed"))?;
                            dflat
                        }
                        "mid" => {
                            let dy = recv_expect(
                                ch.grad_in[ci].as_ref().expect("mid chunk without grad_in"),
                                op.mb,
                                "grad",
                                cfg.stage,
                            )?;
                            let ts = stash.take(key);
                            let x_buf = backend.upload(&ts[0])?;
                            let dy_buf = backend.upload(&dy)?;
                            let outs =
                                backend.execute(&cs.bwd, &[&cs.params_buf, &x_buf, &dy_buf])?;
                            anyhow::ensure!(outs.len() == 2, "mid_bwd: expected (dx, dw)");
                            let mut it = outs.into_iter();
                            let dx = it.next().unwrap();
                            let dflat = it.next().unwrap();
                            ch.grad_out[ci]
                                .as_ref()
                                .expect("mid chunk without grad_out")
                                .send((op.mb, dx))
                                .map_err(|_| anyhow::anyhow!("grad_out closed"))?;
                            dflat
                        }
                        _ => {
                            // "first": virtual stage 0 — nothing upstream
                            let dy = recv_expect(
                                ch.grad_in[ci].as_ref().expect("first chunk without grad_in"),
                                op.mb,
                                "grad",
                                cfg.stage,
                            )?;
                            let ts = stash.take(key);
                            let tok_buf = backend.upload(&ts[0])?;
                            let dy_buf = backend.upload(&dy)?;
                            let outs =
                                backend.execute(&cs.bwd, &[&cs.params_buf, &tok_buf, &dy_buf])?;
                            anyhow::ensure!(outs.len() == 1, "first_bwd: expected (dw,)");
                            outs.into_iter().next().unwrap()
                        }
                    };
                    for (a, g) in cs.grad_acc.iter_mut().zip(dflat.f32s()?.iter()) {
                        *a += g * inv_m;
                    }
                    stats.bwd_s += t.elapsed().as_secs_f64();
                }
                OpKind::Evict => {
                    let tensors = stash.take(key);
                    ch.remote.as_ref().expect("evict without remote store").evict(key, tensors);
                    stats.evictions += 1;
                }
                OpKind::Load => {
                    let t = Instant::now();
                    let tensors =
                        ch.remote.as_ref().expect("load without remote store").load(key);
                    stats.load_wait_s += t.elapsed().as_secs_f64();
                    stash.put(key, tensors);
                }
            }
        }
        anyhow::ensure!(stash.is_empty(), "stage {}: stashes leaked across steps", cfg.stage);

        // optimizer step, per hosted chunk
        let t = Instant::now();
        for cs in &mut chunks {
            let g = HostTensor::vec_f32(cs.grad_acc.clone());
            let step_t = HostTensor::scalar_i32((cfg.start_step + step) as i32);
            let lr_t = HostTensor::scalar_f32(cfg.lr);
            let outs = backend.execute_host(
                &cs.adam,
                &[&cs.params, &g, &cs.m_state, &cs.v_state, &step_t, &lr_t],
            )?;
            anyhow::ensure!(outs.len() == 3, "adam: expected (w, m, v)");
            let mut it = outs.into_iter();
            cs.params = it.next().unwrap();
            cs.m_state = it.next().unwrap();
            cs.v_state = it.next().unwrap();
            cs.params_buf = backend.upload(&cs.params)?; // refresh the device copy
            cs.grad_acc.iter_mut().for_each(|g| *g = 0.0);
        }
        stats.adam_s += t.elapsed().as_secs_f64();

        // checkpoint (atomic; every n steps and always after the last)
        if let Some(dir) = &cfg.checkpoint_dir {
            let due = cfg.checkpoint_every > 0 && step % cfg.checkpoint_every == 0;
            if due || step == cfg.steps {
                for cs in &chunks {
                    StageCheckpoint {
                        params: cs.params.f32s()?.to_vec(),
                        m: cs.m_state.f32s()?.to_vec(),
                        v: cs.v_state.f32s()?.to_vec(),
                    }
                    .save(dir, cs.virt)?;
                }
            }
        }
    }

    if let Some(remote) = &ch.remote {
        remote.shutdown();
    }
    stats.stash_high_water = stash.high_water;
    stats.stash_high_water_bytes = stash.high_water_bytes;
    Ok(stats)
}
