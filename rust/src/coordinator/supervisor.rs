//! The training supervisor: failure classification, the
//! checkpoint–re-plan–resume loop, and structured recovery telemetry.
//!
//! [`supervise`] wraps [`crate::coordinator::train`] in a restart loop:
//!
//! ```text
//!            ┌──────────────────────────────────────────────┐
//!            │ RUN  train::<B>(cfg)                         │◄─────────┐
//!            └───────┬───────────────────────────┬──────────┘          │
//!                 Ok │                       Err │                     │
//!                    ▼                           ▼                     │
//!            ┌──────────────┐        ┌───────────────────────┐         │
//!            │ RECOVERED    │        │ CLASSIFY failure →    │         │
//!            │ stitch losses│        │ FailureReport         │         │
//!            └──────────────┘        └───────────┬───────────┘         │
//!                                HBM pressure?   │                     │
//!                               ┌────────────────┤                     │
//!                               ▼                ▼                     │
//!                     ┌──────────────┐  ┌─────────────────────┐        │
//!                     │ RE-PLAN under│  │ ROLLBACK: latest     │ resume │
//!                     │ reduced cap  │─►│ common checkpoint    │────────┘
//!                     │ (or ABORT:   │  │ step; rewrite meta;  │ (bounded
//!                     │  no feasible │  │ exponential backoff  │  restarts)
//!                     │  plan)       │  └─────────────────────┘
//!                     └──────────────┘
//! ```
//!
//! Every run failure — injected crash, worker panic, channel timeout,
//! HBM cap reduction — funnels into a [`FailureReport`]; the whole
//! disconnect cascade is aggregated and ranked so the PRIMARY cause is
//! reported, not whichever neighbor noticed first.  Recovery is exact:
//! rollback-and-replay from the last common checkpoint reproduces the
//! uninterrupted run's losses and weights bit for bit (the chaos suite's
//! core assertion), and because the BPipe rebalance transform is
//! numerics-preserving, that holds even when an HBM fault forced a
//! re-plan mid-run.  When no feasible plan exists, or the restart budget
//! is exhausted, the supervisor aborts with a structured report — it
//! degrades gracefully, it never hangs.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::activation_store::ChannelError;
use super::checkpoint::{latest_common_step, CheckpointMeta, CorruptCheckpoint};
use super::pipeline::{
    train, try_plan_schedule, PlanRejected, ProgressLog, RebalancePlan, TrainConfig, TrainResult,
};
use crate::metrics::RecoveryStats;
use crate::runtime::{fault, Backend, FaultPlan, InjectedFault, Manifest};

/// Why a training attempt failed, ordered by how much it explains.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FailureCause {
    /// a stage hit its (reduced) HBM capacity — re-plan territory
    HbmPressure { cap_bytes: u64 },
    /// a deterministic injected crash fired
    InjectedCrash,
    /// transient execute failures outlived the in-place retry budget
    ExecRetriesExhausted,
    /// a stage worker thread panicked (poisoned join)
    WorkerPanic,
    /// a channel peer went silent past the recover deadline
    ChannelTimeout { waited_ms: u64 },
    /// no plan passes the static analyzer under the post-fault caps
    NoFeasiblePlan,
    /// the restart budget ran out
    RestartsExhausted,
    /// a checkpoint failed its integrity check on load
    CorruptCheckpoint,
    /// anything else (IO, config, arithmetic)
    Other,
    /// a channel disconnected — almost always SECONDARY to a failure
    /// elsewhere in the cascade, hence the lowest rank
    ChannelClosed,
}

impl FailureCause {
    /// Stable kebab-case label for structured log lines.
    pub fn label(&self) -> &'static str {
        match self {
            FailureCause::HbmPressure { .. } => "hbm-pressure",
            FailureCause::InjectedCrash => "injected-crash",
            FailureCause::ExecRetriesExhausted => "exec-retries-exhausted",
            FailureCause::WorkerPanic => "worker-panic",
            FailureCause::ChannelTimeout { .. } => "channel-timeout",
            FailureCause::NoFeasiblePlan => "no-feasible-plan",
            FailureCause::RestartsExhausted => "restarts-exhausted",
            FailureCause::CorruptCheckpoint => "corrupt-checkpoint",
            FailureCause::Other => "other",
            FailureCause::ChannelClosed => "channel-closed",
        }
    }

    /// How much of the cascade this cause explains — [`primary_failure`]
    /// reports the highest-ranked report among all joined failures.
    fn severity(&self) -> u32 {
        match self {
            FailureCause::HbmPressure { .. } => 100,
            FailureCause::InjectedCrash => 95,
            FailureCause::ExecRetriesExhausted => 90,
            FailureCause::WorkerPanic => 80,
            FailureCause::ChannelTimeout { .. } => 60,
            FailureCause::NoFeasiblePlan => 55,
            FailureCause::RestartsExhausted => 52,
            FailureCause::CorruptCheckpoint => 50,
            FailureCause::Other => 40,
            FailureCause::ChannelClosed => 20,
        }
    }
}

/// One classified failure: which stage (when known), at which global
/// step, and why.  This is both the supervisor's decision input and the
/// typed error the runtime returns on an unrecoverable failure.
#[derive(Debug, Clone, PartialEq)]
pub struct FailureReport {
    /// physical stage, `None` for leader/feeder/collector failures
    pub stage: Option<u64>,
    /// GLOBAL step in flight when the failure surfaced (0 = unknown)
    pub step: u64,
    pub cause: FailureCause,
    pub detail: String,
}

impl std::fmt::Display for FailureReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.stage {
            Some(s) => write!(f, "stage={s} ")?,
            None => write!(f, "stage=- ")?,
        }
        write!(f, "step={} cause={} detail={:?}", self.step, self.cause.label(), self.detail)
    }
}

impl std::error::Error for FailureReport {}

/// Extract a human string from a `catch_unwind`/join panic payload.
pub fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

/// Classify an arbitrary worker/feeder/collector error into a
/// [`FailureReport`]-carrying error.  Errors already carrying a report
/// pass through unchanged; otherwise the anyhow chain is searched for
/// the typed signals ([`InjectedFault`], [`ChannelError`],
/// [`CorruptCheckpoint`]).
pub fn into_failure(stage: Option<u64>, step: u64, e: anyhow::Error) -> anyhow::Error {
    if e.chain().any(|c| c.downcast_ref::<FailureReport>().is_some()) {
        return e;
    }
    let mut cause = FailureCause::Other;
    let mut at_step = step;
    let mut at_stage = stage;
    for c in e.chain() {
        if let Some(f) = c.downcast_ref::<InjectedFault>() {
            cause = match f {
                InjectedFault::Crash { stage: s, step: k } => {
                    at_stage = Some(*s);
                    at_step = *k;
                    FailureCause::InjectedCrash
                }
                InjectedFault::TransientExec { stage: s, step: k } => {
                    at_stage = Some(*s);
                    at_step = *k;
                    FailureCause::ExecRetriesExhausted
                }
                InjectedFault::HbmCap { stage: s, step: k, cap_bytes } => {
                    at_stage = Some(*s);
                    at_step = *k;
                    FailureCause::HbmPressure { cap_bytes: *cap_bytes }
                }
            };
            break;
        }
        if let Some(ch) = c.downcast_ref::<ChannelError>() {
            cause = match ch {
                ChannelError::Timeout { waited_ms } => {
                    FailureCause::ChannelTimeout { waited_ms: *waited_ms }
                }
                ChannelError::Closed => FailureCause::ChannelClosed,
            };
            break;
        }
        if c.downcast_ref::<CorruptCheckpoint>().is_some() {
            cause = FailureCause::CorruptCheckpoint;
            break;
        }
    }
    anyhow::Error::new(FailureReport {
        stage: at_stage,
        step: at_step,
        cause,
        detail: format!("{e:#}"),
    })
}

/// Rank an aggregated failure cascade and return the PRIMARY cause as
/// the error (with the cascade size noted).  A crash cascades: the dying
/// worker's neighbors see closed channels, the collector times out — one
/// root failure, many reports.  Severity ranking picks the explanatory
/// one instead of whichever thread joined first.
pub fn primary_failure(failures: Vec<anyhow::Error>) -> anyhow::Error {
    let n = failures.len();
    let classified = failures.into_iter().map(|e| into_failure(None, 0, e));
    let best = classified
        .max_by_key(|e| {
            e.chain()
                .find_map(|c| c.downcast_ref::<FailureReport>())
                .map_or(10, |r| r.cause.severity())
        })
        .unwrap_or_else(|| anyhow::anyhow!("pipeline failed with no reports"));
    if n > 1 {
        best.context(format!("+{} secondary failure(s) in the cascade", n - 1))
    } else {
        best
    }
}

/// One structured recovery event — `Display` renders the
/// `[bpipe-recover]` log line, which the CI chaos leg archives.
#[derive(Debug, Clone)]
pub enum RecoveryEvent {
    Failure { restart: u32, report: FailureReport },
    Replan { stage: u64, cap_bytes: u64, bounds: Vec<u64>, accepted: bool },
    Resume { restart: u32, from_step: u64, steps_lost: u64, backoff_ms: u64 },
    Recovered { restarts: u32, steps_lost: u64, time_to_recover_s: Vec<f64> },
    ReplayDivergence { step: u64, before: f32, after: f32 },
    Abort { report: FailureReport },
}

impl std::fmt::Display for RecoveryEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[bpipe-recover] ")?;
        match self {
            RecoveryEvent::Failure { restart, report } => {
                write!(f, "event=failure restart={restart} {report}")
            }
            RecoveryEvent::Replan { stage, cap_bytes, bounds, accepted } => write!(
                f,
                "event=replan stage={stage} cap_bytes={cap_bytes} bounds={bounds:?} \
                 accepted={accepted}"
            ),
            RecoveryEvent::Resume { restart, from_step, steps_lost, backoff_ms } => write!(
                f,
                "event=resume restart={restart} from_step={from_step} steps_lost={steps_lost} \
                 backoff_ms={backoff_ms}"
            ),
            RecoveryEvent::Recovered { restarts, steps_lost, time_to_recover_s } => {
                write!(
                    f,
                    "event=recovered restarts={restarts} steps_lost={steps_lost} \
                     time_to_recover_s={time_to_recover_s:?}"
                )
            }
            RecoveryEvent::ReplayDivergence { step, before, after } => write!(
                f,
                "event=replay-divergence step={step} before={before} after={after}"
            ),
            RecoveryEvent::Abort { report } => write!(f, "event=abort {report}"),
        }
    }
}

/// Supervision policy around one [`TrainConfig`].
#[derive(Debug, Clone)]
pub struct SuperviseConfig {
    pub train: TrainConfig,
    /// deterministic fault plan to install for the run (None = no
    /// injection; the supervisor still recovers from organic failures)
    pub faults: Option<Arc<FaultPlan>>,
    /// checkpoint–re-plan–resume cycles before a terminal abort
    pub max_restarts: u32,
    /// channel deadline — how long a silent peer is tolerated
    pub recover_timeout: Option<Duration>,
    /// base restart backoff (doubles per restart, capped at ×64)
    pub backoff_base_ms: u64,
    /// print each recovery event as it happens
    pub log: bool,
}

impl Default for SuperviseConfig {
    fn default() -> Self {
        Self {
            train: TrainConfig::default(),
            faults: None,
            max_restarts: 3,
            recover_timeout: Some(Duration::from_millis(5000)),
            backoff_base_ms: 10,
            log: false,
        }
    }
}

/// What a supervised run produced: the final attempt's result, the
/// stitched cross-attempt loss curve, and the recovery accounting.
#[derive(Debug, Clone)]
pub struct SuperviseOutcome {
    /// the final (successful) attempt's result
    pub result: TrainResult,
    /// loss per global step 1..=steps, stitched across every attempt
    /// (bit-identical replays overwrite silently; divergence is an event)
    pub losses: Vec<f32>,
    pub restarts: u32,
    /// optimizer steps rolled back and replayed, summed over restarts
    pub steps_lost: u64,
    /// transient executes retried in place (final attempt's stats)
    pub retried_executes: u64,
    /// per-restart failure-detection → first-new-step seconds
    pub time_to_recover_s: Vec<f64>,
    pub events: Vec<RecoveryEvent>,
}

impl SuperviseOutcome {
    pub fn recovery_stats(&self) -> RecoveryStats {
        let mut stats = RecoveryStats::new();
        stats.restarts = self.restarts;
        stats.steps_lost = self.steps_lost;
        stats.retried_executes = self.retried_executes;
        for &t in &self.time_to_recover_s {
            stats.record_recovery(t);
        }
        stats
    }
}

/// Derive a tighter [`RebalancePlan`] after `stage`'s HBM capacity
/// dropped to `cap_bytes`: every stage keeps its currently realized
/// stash bound, the pressured stage is capped at how many stash entries
/// now fit.  The candidate is validated end to end through
/// [`try_plan_schedule`] (builder preconditions + the static analyzer).
pub fn replan_for_cap(
    cfg: &TrainConfig,
    manifest: &Manifest,
    p: u64,
    stage: u64,
    cap_bytes: u64,
) -> Result<(RebalancePlan, Vec<u64>), PlanRejected> {
    let (schedule, caps) = try_plan_schedule(cfg.family, p, cfg.microbatches, &cfg.rebalance)?;
    let spec = &manifest.spec;
    let vp = spec.stages;
    // the largest stash entry the stage hosts, over its virtual stages:
    // first = tokens (i32), mid = activation, last = activation + targets
    let entry_bytes = (0..vp)
        .filter(|&d| schedule.placement.host_stage(p, d) == stage)
        .map(|d| match manifest.stage_kind(d) {
            "first" => spec.b * spec.s * 4,
            "last" => spec.b * spec.s * spec.h * 4 + spec.b * spec.s * 4,
            _ => spec.b * spec.s * spec.h * 4,
        })
        .max()
        .unwrap_or(1)
        .max(1);
    let fit = cap_bytes / entry_bytes;
    if fit < 2 {
        return Err(PlanRejected {
            reason: format!(
                "stage {stage} cap of {cap_bytes} B fits {fit} stash entries of {entry_bytes} B \
                 — below the BPipe floor of 2 (one live + one incoming)"
            ),
            diagnostics: Vec::new(),
        });
    }
    let mut bounds: Vec<u64> = caps.iter().map(|&c| (c as u64).max(2)).collect();
    bounds[stage as usize] = bounds[stage as usize].min(fit);
    let plan = RebalancePlan::PerStage { bounds: bounds.clone() };
    try_plan_schedule(cfg.family, p, cfg.microbatches, &plan)?;
    Ok((plan, bounds))
}

/// Turn a run error into its [`FailureReport`] (classifying untyped
/// errors on the way).
fn to_report(e: &anyhow::Error) -> FailureReport {
    e.chain()
        .find_map(|c| c.downcast_ref::<FailureReport>())
        .cloned()
        .unwrap_or_else(|| FailureReport {
            stage: None,
            step: 0,
            cause: FailureCause::Other,
            detail: format!("{e:#}"),
        })
}

/// Run training under supervision: install the fault plan, and on each
/// failure roll back to the newest checkpoint step EVERY stage can
/// restore, re-plan if the failure reduced a stage's capacity, and
/// resume — up to `max_restarts` times with exponential backoff.
/// Terminal conditions (restart budget, no feasible plan) return the
/// [`FailureReport`] as the error; the runtime never hangs on a fault
/// (channel deadlines turn silence into typed timeouts).
pub fn supervise<B: Backend>(scfg: &SuperviseConfig) -> anyhow::Result<SuperviseOutcome> {
    let mut cfg = scfg.train.clone();
    let dir = cfg
        .checkpoint_dir
        .clone()
        .ok_or_else(|| anyhow::anyhow!("supervised training needs a checkpoint dir"))?;
    if cfg.checkpoint_every == 0 {
        // recovery granularity: without periodic checkpoints a failure
        // would always replay from scratch
        cfg.checkpoint_every = 1;
    }
    cfg.recover_timeout = scfg.recover_timeout;
    let progress = cfg.progress.get_or_insert_with(ProgressLog::new).clone();
    let _guard = scfg.faults.clone().map(fault::install);

    // resolve the pipeline shape once — rollback walks VIRTUAL stages
    let manifest = match &cfg.manifest {
        Some(m) => m.clone(),
        None => Manifest::load(&cfg.artifacts_dir)?,
    };
    let vp = manifest.spec.stages;
    let chunks = cfg.family.chunks();
    anyhow::ensure!(
        chunks >= 1 && vp % chunks == 0,
        "manifest's {vp} virtual stages don't split into {chunks} chunks"
    );
    let p = vp / chunks;

    let mut events: Vec<RecoveryEvent> = Vec::new();
    let mut restarts = 0u32;
    let mut steps_lost = 0u64;
    // (failure instant, progress length at failure) per restart — the
    // first entry recorded past the mark closes the recovery window
    let mut pending: Vec<(Instant, usize)> = Vec::new();
    let mut emit = |events: &mut Vec<RecoveryEvent>, ev: RecoveryEvent| {
        if scfg.log {
            println!("{ev}");
        }
        events.push(ev);
    };

    loop {
        match train::<B>(&cfg) {
            Ok(result) => {
                let snapshot = progress.snapshot();
                let time_to_recover_s: Vec<f64> = pending
                    .iter()
                    .filter_map(|(t_fail, mark)| {
                        snapshot
                            .get(*mark)
                            .map(|e| e.at.saturating_duration_since(*t_fail).as_secs_f64())
                    })
                    .collect();
                // stitch the loss curve across attempts; replayed steps
                // must land bit-identically (divergence = determinism bug)
                let mut slots: Vec<Option<f32>> = vec![None; cfg.steps as usize];
                for e in &snapshot {
                    if e.step >= 1 && e.step <= cfg.steps {
                        let slot = &mut slots[(e.step - 1) as usize];
                        if let Some(prev) = *slot {
                            if prev.to_bits() != e.loss.to_bits() {
                                emit(
                                    &mut events,
                                    RecoveryEvent::ReplayDivergence {
                                        step: e.step,
                                        before: prev,
                                        after: e.loss,
                                    },
                                );
                            }
                        }
                        *slot = Some(e.loss);
                    }
                }
                let losses: Vec<f32> =
                    slots.into_iter().map(|s| s.unwrap_or(f32::NAN)).collect();
                let retried_executes =
                    result.stage_stats.iter().map(|s| s.retried_executes).sum();
                emit(
                    &mut events,
                    RecoveryEvent::Recovered {
                        restarts,
                        steps_lost,
                        time_to_recover_s: time_to_recover_s.clone(),
                    },
                );
                return Ok(SuperviseOutcome {
                    result,
                    losses,
                    restarts,
                    steps_lost,
                    retried_executes,
                    time_to_recover_s,
                    events,
                });
            }
            Err(err) => {
                let t_fail = Instant::now();
                let report = to_report(&err);
                emit(
                    &mut events,
                    RecoveryEvent::Failure { restart: restarts, report: report.clone() },
                );

                // HBM pressure: the capacity is gone for good — re-plan
                // under the reduced cap BEFORE resuming, or abort when
                // nothing fits
                if let FailureCause::HbmPressure { cap_bytes } = report.cause {
                    let stage = report.stage.unwrap_or(0);
                    match replan_for_cap(&cfg, &manifest, p, stage, cap_bytes) {
                        Ok((plan, bounds)) => {
                            emit(
                                &mut events,
                                RecoveryEvent::Replan { stage, cap_bytes, bounds, accepted: true },
                            );
                            cfg.rebalance = plan;
                        }
                        Err(rej) => {
                            let abort = FailureReport {
                                stage: report.stage,
                                step: report.step,
                                cause: FailureCause::NoFeasiblePlan,
                                detail: rej.to_string(),
                            };
                            emit(&mut events, RecoveryEvent::Abort { report: abort.clone() });
                            return Err(anyhow::Error::new(abort));
                        }
                    }
                }

                if restarts >= scfg.max_restarts {
                    let abort = FailureReport {
                        stage: report.stage,
                        step: report.step,
                        cause: FailureCause::RestartsExhausted,
                        detail: format!(
                            "{} restart(s) used; last failure: {report}",
                            scfg.max_restarts
                        ),
                    };
                    emit(&mut events, RecoveryEvent::Abort { report: abort.clone() });
                    return Err(anyhow::Error::new(abort));
                }
                restarts += 1;

                // rollback target: the newest step EVERY virtual stage
                // can restore (≤ steps−1: a failed run can't have fully
                // finished, and resume needs work left to do)
                let c = latest_common_step(Path::new(&dir), 0..vp)
                    .min(cfg.steps.saturating_sub(1));
                steps_lost += report.step.saturating_sub(c);
                if c > 0 {
                    CheckpointMeta {
                        steps_done: c,
                        stages: p,
                        chunks,
                        microbatches: cfg.microbatches,
                        seed: cfg.seed,
                    }
                    .save(Path::new(&dir))?;
                    cfg.resume = true;
                } else {
                    cfg.resume = false;
                }
                let backoff_ms = scfg.backoff_base_ms << (restarts - 1).min(6);
                emit(
                    &mut events,
                    RecoveryEvent::Resume { restart: restarts, from_step: c, steps_lost, backoff_ms },
                );
                pending.push((t_fail, progress.len()));
                std::thread::sleep(Duration::from_millis(backoff_ms));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cause_labels_are_kebab_case() {
        for (cause, label) in [
            (FailureCause::InjectedCrash, "injected-crash"),
            (FailureCause::WorkerPanic, "worker-panic"),
            (FailureCause::ChannelTimeout { waited_ms: 5 }, "channel-timeout"),
            (FailureCause::ChannelClosed, "channel-closed"),
            (FailureCause::NoFeasiblePlan, "no-feasible-plan"),
            (FailureCause::HbmPressure { cap_bytes: 1 }, "hbm-pressure"),
        ] {
            assert_eq!(cause.label(), label);
        }
    }

    #[test]
    fn classification_finds_typed_signals_through_context() {
        let e = anyhow::Error::new(InjectedFault::Crash { stage: 2, step: 5 })
            .context("executing fwd")
            .context("stage worker");
        let classified = into_failure(Some(9), 9, e);
        let report = to_report(&classified);
        assert_eq!(report.cause, FailureCause::InjectedCrash);
        assert_eq!(report.stage, Some(2), "the fault's own identity wins");
        assert_eq!(report.step, 5);

        let e = anyhow::Error::new(ChannelError::Timeout { waited_ms: 250 }).context("recv act");
        let report = to_report(&into_failure(Some(1), 3, e));
        assert_eq!(report.cause, FailureCause::ChannelTimeout { waited_ms: 250 });
        assert_eq!(report.stage, Some(1));
        assert_eq!(report.step, 3);
    }

    #[test]
    fn already_classified_errors_pass_through() {
        let original = FailureReport {
            stage: Some(1),
            step: 7,
            cause: FailureCause::InjectedCrash,
            detail: "x".into(),
        };
        let e = anyhow::Error::new(original.clone()).context("outer");
        let back = to_report(&into_failure(None, 0, e));
        assert_eq!(back, original);
    }

    #[test]
    fn primary_failure_ranks_the_cascade() {
        let failures = vec![
            anyhow::Error::new(ChannelError::Closed),
            anyhow::Error::new(InjectedFault::Crash { stage: 1, step: 3 }),
            anyhow::Error::new(ChannelError::Timeout { waited_ms: 100 }),
        ];
        let primary = primary_failure(failures);
        let report = to_report(&primary);
        assert_eq!(report.cause, FailureCause::InjectedCrash, "crash outranks the cascade");
        assert!(format!("{primary:#}").contains("2 secondary"), "cascade size noted");
    }

    #[test]
    fn event_lines_are_structured() {
        let ev = RecoveryEvent::Failure {
            restart: 1,
            report: FailureReport {
                stage: Some(2),
                step: 4,
                cause: FailureCause::WorkerPanic,
                detail: "boom".into(),
            },
        };
        let line = ev.to_string();
        assert!(line.starts_with("[bpipe-recover] event=failure"), "{line}");
        assert!(line.contains("stage=2") && line.contains("cause=worker-panic"), "{line}");
    }
}
