//! The paper's §4 analytical performance estimator (Eqs. 2–4).
//!
//! Given the MFU a *single pipeline stage* achieves at microbatch sizes
//! `x` and `y` (cheap to measure: one stage, `t` GPUs, no pipeline), the
//! estimator upper-bounds the whole-model speedup of moving from `x` to
//! `y` — the "should I bother implementing BPipe?" question:
//!
//! ```text
//! MFU(b)   =  F · MFU_stage(b) / ((1 + (b/B)(p−1)) · F_stage)      (Eq. 3)
//!
//! MFU(x)     B + y(p−1)   MFU_stage(x)
//! ------  =  ---------- · ------------                              (Eq. 4)
//! MFU(y)     B + x(p−1)   MFU_stage(y)
//! ```
//!
//! Assumptions (paper §4): pipeline p2p communication and optimizer time
//! are negligible, and BPipe's own overhead is ignored — so Eq. 4 is an
//! *upper bound*; the gap to measurement is the BPipe overhead.

use crate::config::ExperimentConfig;
use crate::model::flops;

/// A single-stage measurement: microbatch size and the stage MFU
/// achieved at that size (Table 5 rows).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageMeasurement {
    pub b: u64,
    /// single-stage MFU, 0..1
    pub mfu_stage: f64,
}

/// Eq. 2: whole-model MFU from the per-stage fwd+bwd time `t_b` (s),
/// peak FLOP/s `peak` *per stage group* (t devices), microbatches
/// `m = B/b`, pipeline depth `p`, model FLOPs `f` per iteration over all
/// `p` stage groups.
pub fn mfu_eq2(f: f64, peak_per_stage_group: f64, m: u64, p: u64, t_b: f64) -> f64 {
    // devices across the pipeline: p stage groups; bubbles add (p−1)·T(b)
    f / (p as f64 * peak_per_stage_group * ((m + p - 1) as f64) * t_b)
}

/// Eq. 3: whole-model MFU from a single-stage MFU.
///
/// `f` = model FLOPs per iteration; `f_stage` = per-iteration FLOPs of
/// one stage (`B/b` microbatches' worth); `cap_b` = global batch B.
/// The `f / (p·f_stage)` prefactor is ≈1 and corrects for work the
/// measured stage does not see (LM head, attention imbalance); with
/// perfectly uniform stages Eq. 3 reduces exactly to Eq. 2 (unit test
/// below).
pub fn mfu_from_stage(
    f: f64,
    f_stage: f64,
    cap_b: u64,
    p: u64,
    b: u64,
    mfu_stage: f64,
) -> f64 {
    let uniformity = f / (p as f64 * f_stage);
    uniformity * mfu_stage / (1.0 + (b as f64 / cap_b as f64) * (p as f64 - 1.0))
}

/// Eq. 4: predicted whole-model speedup MFU(y)/MFU(x) from two
/// single-stage measurements.
pub fn predicted_speedup(
    cap_b: u64,
    p: u64,
    x: StageMeasurement,
    y: StageMeasurement,
) -> f64 {
    let bubble = (cap_b + x.b * (p - 1)) as f64 / (cap_b + y.b * (p - 1)) as f64;
    bubble * (y.mfu_stage / x.mfu_stage)
}

/// A full estimate for one (x → y) microbatch-size transition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Estimate {
    pub from: StageMeasurement,
    pub to: StageMeasurement,
    /// Eq. 4 upper bound on the whole-model speedup
    pub speedup_bound: f64,
    /// bubble-ratio factor alone (what raising b costs in pipeline fill)
    pub bubble_factor: f64,
    /// stage-efficiency factor alone (what raising b buys per stage)
    pub stage_factor: f64,
}

/// Estimate the benefit of raising the microbatch size via BPipe, from
/// single-stage measurements (the paper's §4 recipe).
pub fn estimate(cap_b: u64, p: u64, from: StageMeasurement, to: StageMeasurement) -> Estimate {
    let bubble_factor = (cap_b + from.b * (p - 1)) as f64 / (cap_b + to.b * (p - 1)) as f64;
    let stage_factor = to.mfu_stage / from.mfu_stage;
    Estimate {
        from,
        to,
        speedup_bound: bubble_factor * stage_factor,
        bubble_factor,
        stage_factor,
    }
}

/// Convenience: Eq. 3 applied to an experiment config, using the
/// analytic `F` and `F_stage` from [`crate::model::flops`].
pub fn model_mfu_from_stage(e: &ExperimentConfig, mfu_stage: f64) -> f64 {
    let b = e.parallel.microbatch;
    let f = flops::model_flops_per_iteration(&e.model, e.parallel.global_batch);
    let m = e.parallel.num_microbatches();
    let f_stage = flops::mid_stage_flops_per_microbatch(&e.model, b, e.parallel.p) * m as f64;
    mfu_from_stage(f, f_stage, e.parallel.global_batch, e.parallel.p, b, mfu_stage)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's §4 worked example: GPT-3 exp (7)→(8), stage MFU
    /// 37.8% → 55.2% at B=128, p=8 predicts ≈1.39× (measured 1.35×).
    #[test]
    fn paper_worked_example() {
        let x = StageMeasurement { b: 1, mfu_stage: 0.378 };
        let y = StageMeasurement { b: 2, mfu_stage: 0.552 };
        let s = predicted_speedup(128, 8, x, y);
        assert!((s - 1.39).abs() < 0.01, "got {s:.4}");
        // and the decomposition
        let e = estimate(128, 8, x, y);
        assert!((e.bubble_factor - 135.0 / 142.0).abs() < 1e-12);
        assert!((e.stage_factor - 0.552 / 0.378).abs() < 1e-12);
    }

    /// LLaMA flash b=2→4 (exp 5→6 stage numbers): the estimator itself
    /// predicts a SLOWDOWN — the paper's key negative result.
    #[test]
    fn llama_flash_predicts_slowdown() {
        let x = StageMeasurement { b: 2, mfu_stage: 0.586 };
        let y = StageMeasurement { b: 4, mfu_stage: 0.619 };
        let s = predicted_speedup(128, 8, x, y);
        assert!(s < 1.0, "BPipe on LLaMA+flash should predict <1.0, got {s:.3}");
        // measured 44.0/49.2 = 0.894; bound must sit above measurement
        assert!(s > 44.0 / 49.2);
    }

    #[test]
    fn identity_when_nothing_changes() {
        let m = StageMeasurement { b: 2, mfu_stage: 0.5 };
        assert!((predicted_speedup(128, 8, m, m) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn speedup_antisymmetric() {
        let x = StageMeasurement { b: 1, mfu_stage: 0.4 };
        let y = StageMeasurement { b: 4, mfu_stage: 0.6 };
        let fwd = predicted_speedup(128, 8, x, y);
        let back = predicted_speedup(128, 8, y, x);
        assert!((fwd * back - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eq3_matches_eq2() {
        // Eq. 3 is Eq. 2 with T(b) eliminated via MFU_stage; check the
        // algebra numerically.
        let (f, peak, cap_b, b, p) = (1e18f64, 1.248e15f64, 128u64, 2u64, 8u64);
        let m = cap_b / b;
        let f_stage_mb = f / (p as f64 * m as f64); // uniform stages
        let t_b = 0.25f64; // arbitrary stage time
        let mfu_stage = f_stage_mb / (peak * t_b);
        let via_eq2 = mfu_eq2(f, peak, m, p, t_b);
        let via_eq3 = mfu_from_stage(f, f_stage_mb * m as f64, cap_b, p, b, mfu_stage);
        assert!((via_eq2 - via_eq3).abs() / via_eq2 < 1e-9);
    }

    #[test]
    fn bubble_factor_worsens_with_larger_b() {
        let x = StageMeasurement { b: 1, mfu_stage: 0.5 };
        let y = StageMeasurement { b: 8, mfu_stage: 0.5 };
        let e = estimate(128, 8, x, y);
        assert!(e.speedup_bound < 1.0);
        assert!((e.stage_factor - 1.0).abs() < 1e-12);
    }
}
