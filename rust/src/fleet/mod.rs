//! Elastic fleet runtime: N data-parallel pipeline replicas under live
//! traffic, with replica-level fault domains and graceful degradation.
//!
//! Layering (one level up from [`crate::coordinator`]):
//!
//! ```text
//!  traffic gen ──► admission ──► bounded work queue
//!   (seeded)       (shed ↯)          │ take / requeue
//!                                    ▼
//!                        fleet supervisor (this module)
//!                      ┌────────────┼────────────┐
//!                      ▼            ▼            ▼
//!                  replica 0    replica 1    replica 2     ← failure
//!                 (supervise)  (supervise)  (supervise)      domains
//!                   p stages     p stages     p stages
//! ```
//!
//! Each replica is a full pipeline coordinator under its own PR-7
//! supervisor — worker crashes, transient execute failures and HBM
//! pressure are recovered *inside* the replica.  Only when a replica's
//! restart budget is exhausted does the failure escalate here, and the
//! response is fleet-level: drain the replica's in-flight work back to
//! the queue, redistribute to survivors (degraded mode), and — after a
//! configurable cool-down — elastically re-admit the replica, which
//! resumes from its own durable checkpoints.  Every plan a replica will
//! run is statically proven (analyzer-gated) BEFORE any thread spawns;
//! under a per-replica memory cap the plan is first re-derived with
//! [`replan_for_cap`], and an infeasible cap aborts the whole serve run
//! up front.
//!
//! Work items are training steps.  Item `id` is global and its home
//! replica is `id % R`; without work stealing each replica consumes
//! exactly its own deterministic slice of the stream (so a kill-free
//! run is bit-identical to R independent training runs), with stealing
//! survivors also absorb a dead replica's backlog at the cost of that
//! identity.

pub mod queue;
pub mod replica;
pub mod stats;
pub mod sync;
pub mod traffic;

pub use queue::{Admission, AdmissionController, RejectReason, WorkItem, WorkQueue};
pub use replica::{Command, ReplicaHandle, ReplicaSpec, SegmentOk, SegmentReport};
pub use stats::{FleetStats, ReplicaStats};
pub use sync::{SyncPeer, WeightSync};
pub use traffic::{TrafficGen, TrafficPattern};

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::supervisor::replan_for_cap;
use crate::coordinator::{
    latest_common_step, spin_recv_deadline, try_plan_schedule, ChannelError, CheckpointMeta,
    FailureCause, FailureReport, RebalancePlan, TrainConfig,
};
use crate::runtime::{fault, Backend, FaultPlan, Manifest};
use crate::schedule::Family;

/// Everything `bpipe serve` configures.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// pipeline replicas (each runs `p` stage workers + feeder/collector)
    pub replicas: usize,
    /// total work items (training steps) the traffic source offers
    pub steps: u64,
    pub traffic: TrafficPattern,
    /// nominal arrivals per round (0 = auto: `replicas × segment_len`,
    /// the fleet's steady-state capacity)
    pub rate: u64,
    /// bounded work-queue capacity — the backpressure knob
    pub queue_cap: usize,
    /// max steps dispatched to a replica per round
    pub segment_len: u64,
    pub seed: u64,
    /// `None` = a small synthetic manifest sized for `family`
    pub manifest: Option<Manifest>,
    pub family: Family,
    pub rebalance: RebalancePlan,
    pub microbatches: u64,
    pub lr: f32,
    /// fleet-wide fault plan (replica-scoped faults hit only the replica
    /// they name); installed once, before any replica spawns
    pub faults: Option<Arc<FaultPlan>>,
    /// per-replica supervisor restart budget (the INNER domain); 0 =
    /// every replica failure escalates to the fleet immediately
    pub max_restarts: u32,
    /// channel deadline inside each replica's pipeline
    pub recover_timeout: Option<Duration>,
    /// how long the fleet waits on a dispatched segment before declaring
    /// the replica silent (spin-deadline on the result channel)
    pub segment_timeout: Duration,
    /// rounds a failed replica sits out before elastic re-admission
    /// (0 = never re-admit)
    pub readmit_after: u64,
    /// average weights across alive replicas every n rounds (0 = off)
    pub sync_every: u64,
    /// let survivors take over a dead replica's queued work
    pub steal: bool,
    /// per-replica HBM cap: re-derive the stage plan under this cap (and
    /// refuse to serve if no feasible plan exists) before spawning
    pub replica_cap_bytes: Option<u64>,
    /// root for per-replica checkpoint directories (`replica<r>/`)
    pub run_dir: PathBuf,
    /// print each [`FleetEvent`] as it happens
    pub log: bool,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            replicas: 2,
            steps: 16,
            traffic: TrafficPattern::Steady,
            rate: 0,
            queue_cap: 8,
            segment_len: 2,
            seed: 0,
            manifest: None,
            family: Family::OneFOneB,
            rebalance: RebalancePlan::Off,
            microbatches: 4,
            lr: 2e-3,
            faults: None,
            max_restarts: 0,
            recover_timeout: Some(Duration::from_millis(5000)),
            segment_timeout: Duration::from_millis(60_000),
            readmit_after: 2,
            sync_every: 0,
            steal: true,
            replica_cap_bytes: None,
            run_dir: std::env::temp_dir().join(format!("bpipe-fleet-{}", std::process::id())),
            log: false,
        }
    }
}

/// One structured fleet event — `Display` renders the `[bpipe-fleet]`
/// log line the CI chaos-fleet leg greps.
#[derive(Debug, Clone)]
pub enum FleetEvent {
    /// per-round traffic accounting (emitted only for non-empty rounds)
    Traffic { round: u64, arrivals: u64, admitted: u64, shed: u64, queue_len: usize },
    /// the plan adopted under `--replica-cap-bytes`, before any spawn
    CapPlan { stage: u64, cap_bytes: u64, bounds: Vec<u64> },
    /// a replica escalated past its restart budget (or went silent)
    ReplicaFailed { round: u64, replica: usize, report: FailureReport },
    /// in-flight split after a failure: steps already durable vs steps
    /// returned to the queue for redistribution
    Drain { round: u64, replica: usize, completed: u64, drained: u64 },
    /// the fleet lost a replica and keeps serving on the survivors
    Degraded { round: u64, alive: usize, replicas: usize },
    /// elastic re-admission: the replica will resume from `from_step`
    ReplicaReadmitted { round: u64, replica: usize, from_step: u64 },
    /// first segment completed after re-admission
    ReplicaRecovered { round: u64, replica: usize, time_to_recover_s: f64 },
    /// cross-replica weight averaging
    Sync { round: u64, replicas: usize, elements: u64 },
    Done { rounds: u64, completed: u64, shed: u64 },
}

impl FleetEvent {
    /// Stable kebab-case event name (the `event=` field).
    pub fn label(&self) -> &'static str {
        match self {
            FleetEvent::Traffic { .. } => "traffic",
            FleetEvent::CapPlan { .. } => "cap-plan",
            FleetEvent::ReplicaFailed { .. } => "replica-failed",
            FleetEvent::Drain { .. } => "drain",
            FleetEvent::Degraded { .. } => "degraded",
            FleetEvent::ReplicaReadmitted { .. } => "replica-readmitted",
            FleetEvent::ReplicaRecovered { .. } => "replica-recovered",
            FleetEvent::Sync { .. } => "sync",
            FleetEvent::Done { .. } => "done",
        }
    }
}

impl std::fmt::Display for FleetEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[bpipe-fleet] event={}", self.label())?;
        match self {
            FleetEvent::Traffic { round, arrivals, admitted, shed, queue_len } => write!(
                f,
                " round={round} arrivals={arrivals} admitted={admitted} shed={shed} \
                 queue_len={queue_len}"
            ),
            FleetEvent::CapPlan { stage, cap_bytes, bounds } => {
                write!(f, " stage={stage} cap_bytes={cap_bytes} bounds={bounds:?}")
            }
            FleetEvent::ReplicaFailed { round, replica, report } => {
                write!(f, " round={round} replica={replica} {report}")
            }
            FleetEvent::Drain { round, replica, completed, drained } => write!(
                f,
                " round={round} replica={replica} completed={completed} drained={drained}"
            ),
            FleetEvent::Degraded { round, alive, replicas } => {
                write!(f, " round={round} alive={alive} replicas={replicas}")
            }
            FleetEvent::ReplicaReadmitted { round, replica, from_step } => {
                write!(f, " round={round} replica={replica} from_step={from_step}")
            }
            FleetEvent::ReplicaRecovered { round, replica, time_to_recover_s } => write!(
                f,
                " round={round} replica={replica} time_to_recover_s={time_to_recover_s:.3}"
            ),
            FleetEvent::Sync { round, replicas, elements } => {
                write!(f, " round={round} replicas={replicas} elements={elements}")
            }
            FleetEvent::Done { rounds, completed, shed } => {
                write!(f, " rounds={rounds} completed={completed} shed={shed}")
            }
        }
    }
}

/// What a serve run produced.
#[derive(Debug)]
pub struct FleetOutcome {
    pub stats: FleetStats,
    pub events: Vec<FleetEvent>,
    /// durable steps per replica at shutdown
    pub steps_done: Vec<u64>,
}

fn emit(log: bool, events: &mut Vec<FleetEvent>, ev: FleetEvent) {
    if log {
        println!("{ev}");
    }
    events.push(ev);
}

/// Run the fleet until the traffic source is exhausted and the queue is
/// drained (or degradation makes that impossible).  Blocks until done.
pub fn serve<B: Backend>(cfg: &FleetConfig) -> anyhow::Result<FleetOutcome> {
    anyhow::ensure!(cfg.replicas >= 1, "need at least one replica");
    anyhow::ensure!(cfg.steps >= 1, "need at least one work item");
    anyhow::ensure!(cfg.queue_cap >= 1, "need a non-empty work queue");
    anyhow::ensure!(cfg.segment_len >= 1, "need non-empty segments");
    let r_count = cfg.replicas;

    let manifest = match &cfg.manifest {
        Some(m) => m.clone(),
        None => Manifest::synthetic(4 * cfg.family.chunks(), 16, 8, 2, 64, &[1, 2]),
    };
    let vp = manifest.spec.stages;
    let chunks = cfg.family.chunks();
    anyhow::ensure!(
        chunks >= 1 && vp % chunks == 0,
        "manifest's {vp} virtual stages don't split into {chunks} chunks ({:?})",
        cfg.family
    );
    let p = vp / chunks;

    let mut events: Vec<FleetEvent> = Vec::new();

    // resolve the plan every replica will run — and PROVE it — before a
    // single thread exists
    let rebalance = match cfg.replica_cap_bytes {
        None => cfg.rebalance.clone(),
        Some(cap_bytes) => {
            let template = TrainConfig {
                manifest: Some(manifest.clone()),
                family: cfg.family,
                microbatches: cfg.microbatches,
                rebalance: cfg.rebalance.clone(),
                ..TrainConfig::default()
            };
            // the last stage hosts the largest stash entries (activation
            // + targets), so it is the binding constraint under a
            // uniform per-replica cap
            let stage = p - 1;
            let (plan, bounds) = replan_for_cap(&template, &manifest, p, stage, cap_bytes)
                .map_err(|rej| {
                    anyhow::anyhow!(
                        "no feasible plan under replica cap of {cap_bytes} B: {}",
                        rej.reason
                    )
                })?;
            emit(cfg.log, &mut events, FleetEvent::CapPlan { stage, cap_bytes, bounds });
            plan
        }
    };
    try_plan_schedule(cfg.family, p, cfg.microbatches, &rebalance).map_err(|rej| {
        anyhow::anyhow!("fleet plan failed static analysis: {}", rej.reason)
    })?;

    // one process-global fault plan, owned by the fleet; replica-scoped
    // faults reach their replica through `TrainConfig::replica`
    let _fault_guard = cfg.faults.clone().map(fault::install);

    let mut handles: Vec<ReplicaHandle> = Vec::with_capacity(r_count);
    for r in 0..r_count {
        let dir = cfg.run_dir.join(format!("replica{r}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir)?;
        handles.push(ReplicaHandle::spawn::<B>(ReplicaSpec {
            id: r,
            manifest: manifest.clone(),
            family: cfg.family,
            rebalance: rebalance.clone(),
            microbatches: cfg.microbatches,
            lr: cfg.lr,
            seed: cfg.seed.wrapping_add(r as u64),
            checkpoint_dir: dir,
            max_restarts: cfg.max_restarts,
            recover_timeout: cfg.recover_timeout,
        }));
    }

    let rate = if cfg.rate == 0 { r_count as u64 * cfg.segment_len } else { cfg.rate };
    let mut gen = TrafficGen::new(cfg.traffic, cfg.seed, rate);
    let mut queue = WorkQueue::new(cfg.queue_cap);
    let mut adm = AdmissionController::new();
    let mut sync_pool = WeightSync::new();
    let mut stats = FleetStats::default();

    let started = Instant::now();
    let mut done = vec![0u64; r_count];
    let mut failures = vec![0u32; r_count];
    let mut alive = vec![true; r_count];
    let mut dead_since: Vec<Option<u64>> = vec![None; r_count];
    let mut fail_at: Vec<Option<Instant>> = vec![None; r_count];
    let mut recovering = vec![false; r_count];
    let mut inflight: Vec<Vec<WorkItem>> = vec![Vec::new(); r_count];
    let mut next_id = 0u64;
    let mut round = 0u64;
    // enough rounds to serve everything even through failures, sit-outs
    // and re-admissions; past this the fleet is livelocked (e.g. a dead
    // replica's backlog with stealing AND re-admission disabled)
    let max_rounds = cfg.steps.saturating_mul(4) + cfg.readmit_after.saturating_mul(8) + 64;

    loop {
        // 1. traffic: seeded arrivals → admission (backpressure or shed)
        if adm.offered < cfg.steps {
            let arrivals = gen.arrivals(round).min(cfg.steps - adm.offered);
            let mut admitted = 0u64;
            let mut shed = 0u64;
            for _ in 0..arrivals {
                let item = WorkItem {
                    id: next_id,
                    home: (next_id % r_count as u64) as usize,
                    enqueued: Instant::now(),
                };
                next_id += 1;
                match adm.offer(&mut queue, item) {
                    Admission::Admitted { .. } => admitted += 1,
                    Admission::Rejected { .. } => shed += 1,
                }
            }
            if arrivals > 0 {
                let queue_len = queue.len();
                emit(
                    cfg.log,
                    &mut events,
                    FleetEvent::Traffic { round, arrivals, admitted, shed, queue_len },
                );
            }
        }

        // 2. elastic re-admission after the cool-down
        if cfg.readmit_after > 0 {
            for r in 0..r_count {
                if !alive[r] && dead_since[r].map_or(false, |d| round - d >= cfg.readmit_after) {
                    alive[r] = true;
                    recovering[r] = true;
                    dead_since[r] = None;
                    emit(
                        cfg.log,
                        &mut events,
                        FleetEvent::ReplicaReadmitted { round, replica: r, from_step: done[r] },
                    );
                }
            }
        }
        let alive_now = alive.iter().filter(|&&a| a).count();
        if alive_now < r_count {
            stats.degraded_rounds += 1;
        }
        if alive_now == 0 && cfg.readmit_after == 0 {
            anyhow::bail!("all {r_count} replicas failed with re-admission disabled");
        }

        // 3. dispatch one segment per idle alive replica
        for r in 0..r_count {
            if !alive[r] || !inflight[r].is_empty() {
                continue;
            }
            let batch = queue.take(r, cfg.steal, cfg.segment_len);
            if batch.is_empty() {
                continue;
            }
            let target = done[r] + batch.len() as u64;
            if handles[r].dispatch(target, done[r] > 0) {
                inflight[r] = batch;
            } else {
                // command channel closed: the thread is gone
                queue.requeue_front(batch);
                alive[r] = false;
                failures[r] += 1;
                dead_since[r] = Some(round);
                fail_at[r] = Some(Instant::now());
                recovering[r] = false;
                let report = FailureReport {
                    stage: None,
                    step: done[r],
                    cause: FailureCause::ChannelClosed,
                    detail: format!("replica {r} command channel closed"),
                };
                emit(cfg.log, &mut events, FleetEvent::ReplicaFailed { round, replica: r, report });
                let alive_left = alive.iter().filter(|&&a| a).count();
                emit(
                    cfg.log,
                    &mut events,
                    FleetEvent::Degraded { round, alive: alive_left, replicas: r_count },
                );
            }
        }

        // 4. collect, in replica order, with a silent-replica deadline
        for r in 0..r_count {
            if inflight[r].is_empty() {
                continue;
            }
            let expected = done[r] + inflight[r].len() as u64;
            let outcome: Result<SegmentOk, FailureReport> = loop {
                match spin_recv_deadline(handles[r].results(), Some(cfg.segment_timeout)) {
                    // a report for an older target is the late echo of a
                    // segment the fleet already timed out — drop it
                    Ok(rep) if rep.target_steps != expected => continue,
                    Ok(rep) => break rep.outcome,
                    Err(ChannelError::Timeout { waited_ms }) => {
                        break Err(FailureReport {
                            stage: None,
                            step: done[r],
                            cause: FailureCause::ChannelTimeout { waited_ms },
                            detail: format!("replica {r} silent past the segment deadline"),
                        })
                    }
                    Err(ChannelError::Closed) => {
                        break Err(FailureReport {
                            stage: None,
                            step: done[r],
                            cause: FailureCause::ChannelClosed,
                            detail: format!("replica {r} thread exited mid-segment"),
                        })
                    }
                }
            };
            let now = Instant::now();
            match outcome {
                Ok(ok) => {
                    done[r] = ok.steps_done;
                    for item in inflight[r].drain(..) {
                        stats.record_latency(now.duration_since(item.enqueued).as_secs_f64());
                    }
                    if recovering[r] {
                        recovering[r] = false;
                        let ttr = fail_at[r]
                            .take()
                            .map(|t| now.duration_since(t).as_secs_f64())
                            .unwrap_or(0.0);
                        stats.time_to_recover_s.push(ttr);
                        emit(
                            cfg.log,
                            &mut events,
                            FleetEvent::ReplicaRecovered {
                                round,
                                replica: r,
                                time_to_recover_s: ttr,
                            },
                        );
                    }
                }
                Err(report) => {
                    alive[r] = false;
                    failures[r] += 1;
                    dead_since[r] = Some(round);
                    fail_at[r] = Some(now);
                    recovering[r] = false;
                    // split the in-flight batch at the replica's durable
                    // frontier: completed steps count, the tail drains
                    // back to the queue for the survivors
                    let batch = std::mem::take(&mut inflight[r]);
                    let durable = latest_common_step(&handles[r].checkpoint_dir, 0..vp);
                    let completed =
                        (durable.saturating_sub(done[r]) as usize).min(batch.len());
                    for item in &batch[..completed] {
                        stats.record_latency(now.duration_since(item.enqueued).as_secs_f64());
                    }
                    let drained = batch[completed..].to_vec();
                    let drained_n = drained.len() as u64;
                    queue.requeue_front(drained);
                    done[r] += completed as u64;
                    if done[r] > 0 {
                        // re-point run metadata at the durable frontier so
                        // the re-admitted replica's resume validates
                        CheckpointMeta {
                            steps_done: done[r],
                            stages: p,
                            chunks,
                            microbatches: cfg.microbatches,
                            seed: cfg.seed.wrapping_add(r as u64),
                        }
                        .save(&handles[r].checkpoint_dir)?;
                    }
                    emit(
                        cfg.log,
                        &mut events,
                        FleetEvent::ReplicaFailed { round, replica: r, report },
                    );
                    emit(
                        cfg.log,
                        &mut events,
                        FleetEvent::Drain {
                            round,
                            replica: r,
                            completed: completed as u64,
                            drained: drained_n,
                        },
                    );
                    let alive_left = alive.iter().filter(|&&a| a).count();
                    emit(
                        cfg.log,
                        &mut events,
                        FleetEvent::Degraded { round, alive: alive_left, replicas: r_count },
                    );
                }
            }
        }

        // 5. periodic cross-replica weight averaging
        if cfg.sync_every > 0 && (round + 1) % cfg.sync_every == 0 {
            let peers: Vec<SyncPeer> = (0..r_count)
                .filter(|&r| alive[r] && done[r] > 0)
                .map(|r| SyncPeer {
                    replica: r,
                    dir: handles[r].checkpoint_dir.clone(),
                    step: done[r],
                })
                .collect();
            if peers.len() >= 2 {
                let n_peers = peers.len();
                let elements = sync_pool.sync(&manifest, &peers)?;
                stats.syncs += 1;
                emit(
                    cfg.log,
                    &mut events,
                    FleetEvent::Sync { round, replicas: n_peers, elements },
                );
            }
        }

        round += 1;
        if adm.offered >= cfg.steps && queue.is_empty() && inflight.iter().all(|v| v.is_empty())
        {
            break;
        }
        anyhow::ensure!(
            round <= max_rounds,
            "fleet stalled after {round} rounds: {} of {} offered, queue holds {} \
             (dead replicas with stealing and re-admission both disabled?)",
            adm.offered,
            cfg.steps,
            queue.len()
        );
    }

    for h in &mut handles {
        h.shutdown();
    }

    stats.elapsed_s = started.elapsed().as_secs_f64();
    stats.offered = adm.offered;
    stats.admitted = adm.admitted;
    stats.shed = adm.shed;
    stats.rounds = round;
    for r in 0..r_count {
        let steps_per_s = if stats.elapsed_s > 0.0 { done[r] as f64 / stats.elapsed_s } else { 0.0 };
        stats.replicas.push(ReplicaStats {
            replica: r,
            steps: done[r],
            steps_per_s,
            failures: failures[r],
        });
    }
    let completed = stats.completed();
    let shed = stats.shed;
    emit(cfg.log, &mut events, FleetEvent::Done { rounds: round, completed, shed });
    Ok(FleetOutcome { stats, events, steps_done: done })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SimBackend;

    fn base_cfg(tag: &str) -> FleetConfig {
        FleetConfig {
            replicas: 2,
            steps: 8,
            queue_cap: 16,
            segment_len: 2,
            seed: 11,
            manifest: Some(Manifest::synthetic(2, 16, 8, 2, 64, &[1, 2])),
            run_dir: std::env::temp_dir()
                .join(format!("bpipe-fleet-mod-{tag}-{}", std::process::id())),
            ..FleetConfig::default()
        }
    }

    #[test]
    fn healthy_fleet_serves_all_offered_work() {
        let cfg = base_cfg("healthy");
        let out = serve::<SimBackend>(&cfg).unwrap();
        assert_eq!(out.stats.offered, 8);
        assert_eq!(out.stats.admitted, 8, "queue cap 16 never sheds at rate 4");
        assert_eq!(out.stats.shed, 0);
        assert_eq!(out.stats.completed(), 8);
        assert_eq!(out.steps_done.iter().sum::<u64>(), 8);
        // id % 2 homing with no failures splits the stream evenly
        assert_eq!(out.steps_done, vec![4, 4]);
        assert!(out.events.iter().all(|e| !matches!(e, FleetEvent::ReplicaFailed { .. })));
        assert!(matches!(out.events.last(), Some(FleetEvent::Done { .. })));
        assert!(out.stats.p99_latency_s().is_finite());
        let _ = std::fs::remove_dir_all(&cfg.run_dir);
    }

    #[test]
    fn sync_rounds_average_without_breaking_completion() {
        let mut cfg = base_cfg("sync");
        cfg.sync_every = 1;
        let out = serve::<SimBackend>(&cfg).unwrap();
        assert_eq!(out.stats.completed(), 8);
        assert!(out.stats.syncs > 0, "sync_every=1 must sync at least once");
        assert!(out.events.iter().any(|e| matches!(e, FleetEvent::Sync { .. })));
        let _ = std::fs::remove_dir_all(&cfg.run_dir);
    }

    #[test]
    fn infeasible_replica_cap_refuses_to_spawn() {
        let mut cfg = base_cfg("cap");
        cfg.replica_cap_bytes = Some(64); // fits < 2 stash entries
        let err = serve::<SimBackend>(&cfg).unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("no feasible plan"), "{text}");
        let _ = std::fs::remove_dir_all(&cfg.run_dir);
    }
}
