//! The fleet's shared work queue and admission control.
//!
//! Work items are training steps: item `id` is globally unique and its
//! `home` replica is `id % R`, so every replica owns a deterministic
//! interleaved share of the stream.  The queue is BOUNDED — that bound
//! is the fleet's backpressure — and the admission controller turns
//! overflow into a typed [`Admission::Rejected`] (load shedding) instead
//! of blocking the traffic source or growing without limit.
//!
//! Two queue operations deliberately bypass the cap:
//!
//! * [`WorkQueue::requeue_front`] — DRAINED items (in flight on a
//!   replica that died) were already admitted once; conservation
//!   (`offered = admitted + shed`) would break if re-queueing them could
//!   shed, so they go back to the queue head even when it is full.
//! * dispatch ([`WorkQueue::take`]) — survivors pull work out, which is
//!   what relieves the pressure.

use std::collections::VecDeque;
use std::time::Instant;

/// One unit of fleet work: a single training step for its home replica.
#[derive(Debug, Clone, Copy)]
pub struct WorkItem {
    /// globally unique, monotonically assigned by the traffic loop
    pub id: u64,
    /// replica that owns the item's step (`id % replicas`)
    pub home: usize,
    /// first admission time — preserved across drain/re-queue so
    /// latency percentiles stay honest through a failure transition
    pub enqueued: Instant,
}

/// Why the admission controller shed a work item.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// the bounded queue is at capacity — the fleet is saturated
    QueueFull { cap: usize },
}

impl RejectReason {
    pub fn label(&self) -> &'static str {
        match self {
            RejectReason::QueueFull { .. } => "queue-full",
        }
    }
}

/// Typed admission outcome for one offered work item.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    Admitted { queue_len: usize },
    Rejected { reason: RejectReason },
}

/// Bounded FIFO of admitted work items.
#[derive(Debug)]
pub struct WorkQueue {
    items: VecDeque<WorkItem>,
    cap: usize,
}

impl WorkQueue {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "a zero-capacity queue can admit nothing");
        Self { items: VecDeque::with_capacity(cap), cap }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Admit `item` if the queue has room.
    pub fn admit(&mut self, item: WorkItem) -> Admission {
        if self.items.len() >= self.cap {
            return Admission::Rejected { reason: RejectReason::QueueFull { cap: self.cap } };
        }
        self.items.push_back(item);
        Admission::Admitted { queue_len: self.items.len() }
    }

    /// Return drained (already-admitted) items to the queue HEAD in
    /// their original order, bypassing the cap — see the module docs.
    pub fn requeue_front(&mut self, items: Vec<WorkItem>) {
        for item in items.into_iter().rev() {
            self.items.push_front(item);
        }
    }

    /// Pop up to `max` items for `replica`: its own (`home == replica`)
    /// items first, in FIFO order; with `steal`, any remaining slots are
    /// filled from other replicas' backlog (degraded-mode work stealing).
    pub fn take(&mut self, replica: usize, steal: bool, max: u64) -> Vec<WorkItem> {
        let max = max as usize;
        let mut taken = Vec::new();
        let mut rest = VecDeque::with_capacity(self.items.len());
        while let Some(item) = self.items.pop_front() {
            if taken.len() < max && (item.home == replica || steal) {
                taken.push(item);
            } else {
                rest.push_back(item);
            }
        }
        self.items = rest;
        taken
    }
}

/// Admission bookkeeping over the queue: every offered item is exactly
/// one of admitted or shed, so `offered = admitted + shed` always holds
/// (the chaos suite asserts it through failure transitions).
#[derive(Debug, Default)]
pub struct AdmissionController {
    pub offered: u64,
    pub admitted: u64,
    pub shed: u64,
}

impl AdmissionController {
    pub fn new() -> Self {
        Self::default()
    }

    /// Offer one item to the queue and account for the outcome.
    pub fn offer(&mut self, queue: &mut WorkQueue, item: WorkItem) -> Admission {
        self.offered += 1;
        let outcome = queue.admit(item);
        match outcome {
            Admission::Admitted { .. } => self.admitted += 1,
            Admission::Rejected { .. } => self.shed += 1,
        }
        outcome
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(id: u64, replicas: usize) -> WorkItem {
        WorkItem { id, home: (id % replicas as u64) as usize, enqueued: Instant::now() }
    }

    #[test]
    fn admission_sheds_past_capacity_and_conserves() {
        let mut q = WorkQueue::new(3);
        let mut adm = AdmissionController::new();
        let mut outcomes = Vec::new();
        for id in 0..5 {
            outcomes.push(adm.offer(&mut q, item(id, 2)));
        }
        assert_eq!(adm.offered, 5);
        assert_eq!(adm.admitted, 3);
        assert_eq!(adm.shed, 2);
        assert_eq!(adm.offered, adm.admitted + adm.shed, "conservation");
        assert!(matches!(
            outcomes[3],
            Admission::Rejected { reason: RejectReason::QueueFull { cap: 3 } }
        ));
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn take_prefers_home_items_in_fifo_order() {
        let mut q = WorkQueue::new(8);
        for id in 0..6 {
            q.admit(item(id, 2));
        }
        // replica 0 owns 0, 2, 4; without steal it gets exactly those
        let own = q.take(0, false, 8);
        assert_eq!(own.iter().map(|i| i.id).collect::<Vec<_>>(), vec![0, 2, 4]);
        assert_eq!(q.len(), 3, "replica 1's items stay queued");
        let none = q.take(0, false, 8);
        assert!(none.is_empty(), "no home items left");
    }

    #[test]
    fn steal_takes_orphaned_items_up_to_max() {
        let mut q = WorkQueue::new(8);
        for id in 0..6 {
            q.admit(item(id, 2));
        }
        let got = q.take(0, true, 4);
        assert_eq!(got.iter().map(|i| i.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn requeue_front_restores_order_and_bypasses_cap() {
        let mut q = WorkQueue::new(2);
        q.admit(item(0, 1));
        q.admit(item(1, 1));
        let drained = vec![item(10, 1), item(11, 1)];
        q.requeue_front(drained);
        assert_eq!(q.len(), 4, "drains bypass the cap");
        let got = q.take(0, false, 8);
        assert_eq!(got.iter().map(|i| i.id).collect::<Vec<_>>(), vec![10, 11, 0, 1]);
    }
}
