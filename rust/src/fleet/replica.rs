//! One fleet replica: a full supervised pipeline coordinator (PR-5
//! training loop under the PR-7 checkpoint–re-plan–resume supervisor)
//! running on its own thread, driven segment-by-segment over a command
//! channel.
//!
//! The replica is the fleet's FAILURE DOMAIN: everything below this
//! boundary (worker panics, transient execute failures, HBM pressure,
//! channel timeouts) is the per-replica supervisor's business and is
//! retried/re-planned in place.  Only when that supervisor's restart
//! budget is exhausted does the failure ESCALATE across the boundary as
//! a typed [`FailureReport`] in the [`SegmentReport`] — at which point
//! the fleet supervisor drains the replica's in-flight work and
//! redistributes it.
//!
//! Segments run under `resume: true` against the replica's private
//! checkpoint directory, so a re-admitted replica continues from its
//! last durable step with no special-case code path.

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::{
    supervise, FailureCause, FailureReport, ProgressLog, RebalancePlan, SuperviseConfig,
    TrainConfig,
};
use crate::schedule::Family;
use crate::runtime::{Backend, Manifest};

/// Everything needed to (re)build a replica's training configuration.
#[derive(Debug, Clone)]
pub struct ReplicaSpec {
    pub id: usize,
    pub manifest: Manifest,
    pub family: Family,
    pub rebalance: RebalancePlan,
    pub microbatches: u64,
    pub lr: f32,
    /// already replica-offset: `fleet_seed.wrapping_add(id)`
    pub seed: u64,
    /// this replica's private checkpoint directory
    pub checkpoint_dir: PathBuf,
    /// per-replica supervisor policy (the INNER failure domain)
    pub max_restarts: u32,
    pub recover_timeout: Option<Duration>,
}

/// A command from the fleet supervisor to a replica thread.
#[derive(Debug, Clone)]
pub enum Command {
    /// Train until the TOTAL step count reaches `target_steps` (resume
    /// semantics: the segment length is `target_steps - steps_done`).
    Segment { target_steps: u64, resume: bool },
    Shutdown,
}

/// A successfully completed segment.
#[derive(Debug, Clone)]
pub struct SegmentOk {
    /// total steps durable after the segment (== the segment's target)
    pub steps_done: u64,
    /// in-domain restarts the replica's own supervisor absorbed
    pub restarts: u32,
    pub retried_executes: u64,
}

/// What came back over the result channel for one segment.
#[derive(Debug, Clone)]
pub struct SegmentReport {
    pub replica: usize,
    pub target_steps: u64,
    /// `Err` is an ESCALATED failure — the replica's own restart budget
    /// is spent and the fleet must handle it
    pub outcome: Result<SegmentOk, FailureReport>,
}

/// Fleet-side handle to a running replica thread.
#[derive(Debug)]
pub struct ReplicaHandle {
    pub id: usize,
    pub checkpoint_dir: PathBuf,
    pub progress: ProgressLog,
    cmd: SyncSender<Command>,
    res: Receiver<SegmentReport>,
    thread: Option<JoinHandle<()>>,
}

/// Pull the typed [`FailureReport`] out of a supervisor error chain,
/// synthesizing an `Other` report for untyped errors (config/IO noise)
/// so the fleet always has a classified cause to log.
fn escalate(replica: usize, e: anyhow::Error) -> FailureReport {
    e.chain()
        .find_map(|c| c.downcast_ref::<FailureReport>())
        .cloned()
        .unwrap_or_else(|| FailureReport {
            stage: None,
            step: 0,
            cause: FailureCause::Other,
            detail: format!("replica {replica}: {e:#}"),
        })
}

impl ReplicaHandle {
    /// Spawn the replica thread.  The thread owns a persistent
    /// [`ProgressLog`] (shared with this handle) and runs one supervised
    /// training segment per [`Command::Segment`], reporting each outcome
    /// on the result channel.
    ///
    /// Faults are NOT installed here: the global fault registry is
    /// process-wide and owned by the fleet supervisor; replica scoping
    /// happens through `TrainConfig::replica` → `Backend::bind_replica`.
    pub fn spawn<B: Backend>(spec: ReplicaSpec) -> ReplicaHandle {
        let (cmd_tx, cmd_rx) = sync_channel::<Command>(2);
        let (res_tx, res_rx) = sync_channel::<SegmentReport>(1);
        let progress = ProgressLog::new();
        let thread_progress = progress.clone();
        let id = spec.id;
        let checkpoint_dir = spec.checkpoint_dir.clone();
        let thread = std::thread::Builder::new()
            .name(format!("fleet-replica-{id}"))
            .spawn(move || {
                while let Ok(cmd) = cmd_rx.recv() {
                    let (target_steps, resume) = match cmd {
                        Command::Segment { target_steps, resume } => (target_steps, resume),
                        Command::Shutdown => return,
                    };
                    let scfg = SuperviseConfig {
                        train: TrainConfig {
                            manifest: Some(spec.manifest.clone()),
                            family: spec.family,
                            steps: target_steps,
                            microbatches: spec.microbatches,
                            lr: spec.lr,
                            rebalance: spec.rebalance.clone(),
                            seed: spec.seed,
                            log_every: 0,
                            checkpoint_dir: Some(spec.checkpoint_dir.clone()),
                            checkpoint_every: 1,
                            resume,
                            recover_timeout: spec.recover_timeout,
                            retry_budget: 1,
                            retry_backoff_ms: 1,
                            progress: Some(thread_progress.clone()),
                            replica: Some(spec.id),
                            ..TrainConfig::default()
                        },
                        faults: None,
                        max_restarts: spec.max_restarts,
                        recover_timeout: spec.recover_timeout,
                        backoff_base_ms: 1,
                        log: false,
                    };
                    let outcome = match supervise::<B>(&scfg) {
                        Ok(out) => Ok(SegmentOk {
                            steps_done: target_steps,
                            restarts: out.restarts,
                            retried_executes: out.retried_executes,
                        }),
                        Err(e) => Err(escalate(spec.id, e)),
                    };
                    let report = SegmentReport { replica: spec.id, target_steps, outcome };
                    if res_tx.send(report).is_err() {
                        return; // fleet supervisor is gone
                    }
                }
            })
            .expect("spawn replica thread");
        ReplicaHandle { id, checkpoint_dir, progress, cmd: cmd_tx, res: res_rx, thread: Some(thread) }
    }

    /// Dispatch a segment.  Returns `false` when the replica thread is
    /// gone (its channel closed) — the caller treats that as a failure.
    pub fn dispatch(&self, target_steps: u64, resume: bool) -> bool {
        self.cmd.send(Command::Segment { target_steps, resume }).is_ok()
    }

    /// The segment-result channel, for deadline-bounded receives via
    /// [`crate::coordinator::spin_recv_deadline`].
    pub fn results(&self) -> &Receiver<SegmentReport> {
        &self.res
    }

    /// Ask the thread to exit and join it.  Safe to call on an
    /// already-dead replica (send/join failures are swallowed — the
    /// thread's failure was already reported through the result channel).
    pub fn shutdown(&mut self) {
        let _ = self.cmd.send(Command::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ReplicaHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::latest_common_step;
    use crate::runtime::SimBackend;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("bpipe-fleet-replica-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn spec(id: usize, dir: PathBuf) -> ReplicaSpec {
        ReplicaSpec {
            id,
            manifest: Manifest::synthetic(2, 16, 8, 2, 64, &[1, 2]),
            family: Family::OneFOneB,
            rebalance: RebalancePlan::Off,
            microbatches: 4,
            lr: 2e-3,
            seed: 7 + id as u64,
            checkpoint_dir: dir,
            max_restarts: 0,
            recover_timeout: Some(Duration::from_millis(5000)),
        }
    }

    #[test]
    fn replica_runs_segments_and_resumes_between_them() {
        let dir = tmp("segments");
        let mut h = ReplicaHandle::spawn::<SimBackend>(spec(0, dir.clone()));
        assert!(h.dispatch(2, false));
        let first = h.results().recv().unwrap();
        assert_eq!(first.replica, 0);
        let ok = first.outcome.expect("segment 1");
        assert_eq!(ok.steps_done, 2);
        assert_eq!(latest_common_step(&dir, 0..2), 2, "two steps durable");
        assert!(h.dispatch(5, true), "second segment resumes to total 5");
        let second = h.results().recv().unwrap();
        assert_eq!(second.outcome.expect("segment 2").steps_done, 5);
        assert_eq!(latest_common_step(&dir, 0..2), 5);
        assert_eq!(h.progress.len(), 5, "progress log spans both segments");
        h.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_segment_escalates_a_typed_report() {
        let dir = tmp("escalate");
        let mut h = ReplicaHandle::spawn::<SimBackend>(spec(1, dir.clone()));
        // a zero-step segment is a config error ("nothing to do") — it
        // must come back as an escalated typed report, not a hang or a
        // panic, and the thread must survive to run real segments after
        assert!(h.dispatch(0, false));
        let report = h.results().recv().unwrap();
        let err = report.outcome.expect_err("zero-step segment is rejected");
        assert!(!err.detail.is_empty());
        assert!(h.dispatch(1, false), "replica thread survives a bad segment");
        let ok = h.results().recv().unwrap().outcome.expect("recovery segment");
        assert_eq!(ok.steps_done, 1);
        h.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
