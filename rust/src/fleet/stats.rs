//! Fleet-level telemetry: per-replica throughput, work-item latency
//! percentiles, admission accounting and recovery timing — the numbers
//! behind the `bpipe serve` JSON summary and the `fleet` section of
//! `BENCH_runtime.json`.

use crate::util::json::Json;

/// One replica's contribution to the fleet run.
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    pub replica: usize,
    /// optimizer steps this replica completed (post-recovery total)
    pub steps: u64,
    /// steps per wall-clock second over the whole serve window
    pub steps_per_s: f64,
    /// terminal failures escalated to the fleet domain
    pub failures: u32,
}

/// Aggregate statistics for one `serve` run.  Latency is measured per
/// WORK ITEM — first admission to segment completion — so queue wait,
/// failure detection and drain/re-dispatch delay all show up in the
/// percentiles (the p99 through a kill is the honest recovery cost).
#[derive(Debug, Clone, Default)]
pub struct FleetStats {
    pub replicas: Vec<ReplicaStats>,
    pub offered: u64,
    pub admitted: u64,
    pub shed: u64,
    pub rounds: u64,
    /// rounds spent with at least one replica down
    pub degraded_rounds: u64,
    /// cross-replica weight syncs performed
    pub syncs: u64,
    /// seconds from each failure detection to the failed replica's first
    /// post-re-admission segment completion, in failure order
    pub time_to_recover_s: Vec<f64>,
    /// per-item first-admission → completion seconds
    latency_s: Vec<f64>,
    /// serve wall-clock, seconds
    pub elapsed_s: f64,
}

impl FleetStats {
    pub fn record_latency(&mut self, secs: f64) {
        self.latency_s.push(secs);
    }

    pub fn completed(&self) -> u64 {
        self.latency_s.len() as u64
    }

    /// Fleet-aggregate steps per second.
    pub fn steps_per_s(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.replicas.iter().map(|r| r.steps).sum::<u64>() as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    /// Nearest-rank latency percentile (`q` in 0..=1); NaN with no
    /// samples.
    pub fn latency_percentile(&self, q: f64) -> f64 {
        if self.latency_s.is_empty() {
            return f64::NAN;
        }
        let mut xs = self.latency_s.clone();
        xs.sort_by(f64::total_cmp);
        let idx = (q.clamp(0.0, 1.0) * (xs.len() - 1) as f64).round() as usize;
        xs[idx.min(xs.len() - 1)]
    }

    pub fn p50_latency_s(&self) -> f64 {
        self.latency_percentile(0.50)
    }

    pub fn p99_latency_s(&self) -> f64 {
        self.latency_percentile(0.99)
    }

    /// One human line for the end of a serve run.
    pub fn summary(&self) -> String {
        format!(
            "{} replicas, {}/{} items done ({} shed), {:.1} steps/s, \
             p50 {:.3}s p99 {:.3}s, {} recovery(ies), {} round(s)",
            self.replicas.len(),
            self.completed(),
            self.offered,
            self.shed,
            self.steps_per_s(),
            self.p50_latency_s(),
            self.p99_latency_s(),
            self.time_to_recover_s.len(),
            self.rounds
        )
    }

    /// The machine-readable summary `bpipe serve` prints (NaN-free:
    /// missing percentiles serialize as null).
    pub fn to_json(&self) -> Json {
        let num_or_null = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        let replicas: Vec<Json> = self
            .replicas
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("replica", Json::Num(r.replica as f64)),
                    ("steps", Json::Num(r.steps as f64)),
                    ("steps_per_s", num_or_null(r.steps_per_s)),
                    ("failures", Json::Num(r.failures as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("replicas", Json::Arr(replicas)),
            ("offered", Json::Num(self.offered as f64)),
            ("admitted", Json::Num(self.admitted as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("completed", Json::Num(self.completed() as f64)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("degraded_rounds", Json::Num(self.degraded_rounds as f64)),
            ("syncs", Json::Num(self.syncs as f64)),
            ("steps_per_s", num_or_null(self.steps_per_s())),
            ("p50_step_latency_s", num_or_null(self.p50_latency_s())),
            ("p99_step_latency_s", num_or_null(self.p99_latency_s())),
            (
                "time_to_recover_s",
                Json::Arr(self.time_to_recover_s.iter().map(|&t| Json::Num(t)).collect()),
            ),
            ("elapsed_s", Json::Num(self.elapsed_s)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_use_nearest_rank() {
        let mut s = FleetStats::default();
        for i in 1..=100 {
            s.record_latency(i as f64);
        }
        assert_eq!(s.p50_latency_s(), 50.0);
        assert_eq!(s.p99_latency_s(), 99.0);
        assert_eq!(s.latency_percentile(0.0), 1.0);
        assert_eq!(s.latency_percentile(1.0), 100.0);
    }

    #[test]
    fn empty_stats_are_nan_but_json_is_null() {
        let s = FleetStats::default();
        assert!(s.p99_latency_s().is_nan());
        let text = s.to_json().to_string();
        assert!(text.contains("\"p99_step_latency_s\":null"), "{text}");
        assert!(!text.contains("NaN"), "JSON must stay parseable: {text}");
    }

    #[test]
    fn json_carries_the_admission_accounting() {
        let mut s = FleetStats::default();
        s.offered = 10;
        s.admitted = 8;
        s.shed = 2;
        s.elapsed_s = 2.0;
        s.replicas.push(ReplicaStats { replica: 0, steps: 8, steps_per_s: 4.0, failures: 1 });
        s.record_latency(0.5);
        let j = s.to_json();
        assert_eq!(j.get("shed").and_then(|v| v.as_u64()), Some(2));
        assert_eq!(j.get("completed").and_then(|v| v.as_u64()), Some(1));
        let text = j.to_string();
        assert!(text.contains("\"failures\""), "{text}");
        assert!(s.summary().contains("2 shed") || s.summary().contains("(2 shed)"));
    }
}
