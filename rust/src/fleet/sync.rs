//! Periodic cross-replica weight synchronization.
//!
//! The fleet's replicas are data-parallel: each consumes its own slice
//! of the work stream, so their weights drift apart between syncs.  A
//! sync element-wise averages every stage checkpoint (params AND Adam
//! moments — averaging only params would leave the optimizer state
//! pointing at pre-average geometry) across the alive replicas and
//! writes the result back to each replica's checkpoint directory at
//! that replica's OWN step tag, so a later resume still passes the
//! step-consistency validation.
//!
//! Determinism: replicas are reduced in ascending replica-id order with
//! f64 accumulation, so the result is bit-identical across runs for the
//! same inputs — silent (dead) replicas are simply absent from the
//! `alive` slice and never block the reduction.

use std::path::PathBuf;

use anyhow::Context;

use crate::coordinator::StageCheckpoint;
use crate::runtime::Manifest;

/// One sync participant: replica id, its checkpoint directory, and the
/// step its checkpoints are tagged with.
#[derive(Debug, Clone)]
pub struct SyncPeer {
    pub replica: usize,
    pub dir: PathBuf,
    pub step: u64,
}

/// Pooled accumulation buffers for cross-replica averaging; hold one
/// across rounds so the per-sync cost is I/O plus arithmetic, with no
/// steady-state allocation.
#[derive(Debug, Default)]
pub struct WeightSync {
    acc_params: Vec<f64>,
    acc_m: Vec<f64>,
    acc_v: Vec<f64>,
}

impl WeightSync {
    pub fn new() -> Self {
        Self::default()
    }

    fn reset(&mut self, n: usize) {
        for acc in [&mut self.acc_params, &mut self.acc_m, &mut self.acc_v] {
            acc.clear();
            acc.resize(n, 0.0);
        }
    }

    /// Average every virtual stage's checkpoint across `peers` and write
    /// the result back to each peer at its own step tag.  Returns the
    /// number of f32 elements averaged (params + moments, all stages,
    /// counted once — not per peer).
    ///
    /// Requires ≥ 2 peers: a one-replica "sync" would only rewrite
    /// checkpoints it cannot change.
    pub fn sync(&mut self, manifest: &Manifest, peers: &[SyncPeer]) -> anyhow::Result<u64> {
        anyhow::ensure!(peers.len() >= 2, "weight sync needs >= 2 alive replicas, got {}", peers.len());
        let scale = 1.0 / peers.len() as f64;
        let mut elements = 0u64;
        for virt in 0..manifest.spec.stages {
            let n = manifest.param_count(manifest.stage_kind(virt))? as usize;
            self.reset(n);
            for peer in peers {
                // load the generation tagged with the peer's durable step
                // (a just-rolled-back stage can have a NEWER current
                // generation than its replica's common step)
                let ck = StageCheckpoint::load_at(&peer.dir, virt, n, peer.step).with_context(
                    || {
                        format!(
                            "sync: replica {} stage {virt} has no checkpoint at step {}",
                            peer.replica, peer.step
                        )
                    },
                )?;
                for i in 0..n {
                    self.acc_params[i] += ck.params[i] as f64;
                    self.acc_m[i] += ck.m[i] as f64;
                    self.acc_v[i] += ck.v[i] as f64;
                }
            }
            let mean = StageCheckpoint {
                params: self.acc_params.iter().map(|&x| (x * scale) as f32).collect(),
                m: self.acc_m.iter().map(|&x| (x * scale) as f32).collect(),
                v: self.acc_v.iter().map(|&x| (x * scale) as f32).collect(),
            };
            for peer in peers {
                mean.save_at(&peer.dir, virt, peer.step).with_context(|| {
                    format!("sync: replica {} stage {virt} write-back failed", peer.replica)
                })?;
            }
            elements += 3 * n as u64;
        }
        Ok(elements)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::latest_common_step;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("bpipe-fleet-sync-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn fill(dir: &std::path::Path, manifest: &Manifest, base: f32, step: u64) {
        for virt in 0..manifest.spec.stages {
            let n = manifest.param_count(manifest.stage_kind(virt)).unwrap() as usize;
            let ck = StageCheckpoint {
                params: (0..n).map(|i| base + i as f32).collect(),
                m: vec![base * 0.1; n],
                v: vec![base * 0.01; n],
            };
            ck.save_at(dir, virt, step).unwrap();
        }
    }

    #[test]
    fn sync_averages_and_preserves_step_tags() {
        let manifest = Manifest::synthetic(2, 16, 8, 2, 64, &[1, 2]);
        let a = tmp("a");
        let b = tmp("b");
        fill(&a, &manifest, 1.0, 5);
        fill(&b, &manifest, 3.0, 7);
        let peers = vec![
            SyncPeer { replica: 0, dir: a.clone(), step: 5 },
            SyncPeer { replica: 1, dir: b.clone(), step: 7 },
        ];
        let elements = WeightSync::new().sync(&manifest, &peers).unwrap();
        let mut expect = 0u64;
        for virt in 0..manifest.spec.stages {
            let n = manifest.param_count(manifest.stage_kind(virt)).unwrap() as usize;
            expect += 3 * n as u64;
            let ca = StageCheckpoint::load(&a, virt, n).unwrap();
            let cb = StageCheckpoint::load(&b, virt, n).unwrap();
            assert_eq!(ca, cb, "stage {virt}: both replicas hold the mean");
            assert_eq!(ca.params[0], 2.0, "mean of 1.0 and 3.0");
            assert_eq!(ca.params[n - 1], 2.0 + (n - 1) as f32);
            assert!((ca.m[0] - 0.2).abs() < 1e-6);
        }
        assert_eq!(elements, expect);
        // step tags survive the write-back, so resume validation still holds
        assert_eq!(latest_common_step(&a, 0..manifest.spec.stages), 5);
        assert_eq!(latest_common_step(&b, 0..manifest.spec.stages), 7);
        let _ = std::fs::remove_dir_all(&a);
        let _ = std::fs::remove_dir_all(&b);
    }

    #[test]
    fn sync_refuses_a_lonely_replica() {
        let manifest = Manifest::synthetic(2, 16, 8, 2, 64, &[1, 2]);
        let a = tmp("lonely");
        fill(&a, &manifest, 1.0, 1);
        let peers = vec![SyncPeer { replica: 0, dir: a.clone(), step: 1 }];
        assert!(WeightSync::new().sync(&manifest, &peers).is_err());
        let _ = std::fs::remove_dir_all(&a);
    }

    #[test]
    fn sync_is_deterministic_across_pool_reuse() {
        let manifest = Manifest::synthetic(2, 16, 8, 2, 64, &[1, 2]);
        let dirs: Vec<PathBuf> = (0..3).map(|i| tmp(&format!("det{i}"))).collect();
        let run = |pool: &mut WeightSync, tag: &str| -> Vec<StageCheckpoint> {
            for (i, d) in dirs.iter().enumerate() {
                let _ = std::fs::remove_dir_all(d);
                std::fs::create_dir_all(d).unwrap();
                fill(d, &manifest, 0.5 + i as f32 * 1.25, 3);
            }
            let peers: Vec<SyncPeer> = dirs
                .iter()
                .enumerate()
                .map(|(i, d)| SyncPeer { replica: i, dir: d.clone(), step: 3 })
                .collect();
            pool.sync(&manifest, &peers).unwrap_or_else(|e| panic!("{tag}: {e:#}"));
            (0..manifest.spec.stages)
                .map(|virt| {
                    let n = manifest.param_count(manifest.stage_kind(virt)).unwrap() as usize;
                    StageCheckpoint::load(&dirs[0], virt, n).unwrap()
                })
                .collect()
        };
        let mut pool = WeightSync::new();
        let first = run(&mut pool, "first");
        let second = run(&mut pool, "second (reused pool)");
        assert_eq!(first, second, "pooled buffers must not leak state across syncs");
        for d in &dirs {
            let _ = std::fs::remove_dir_all(d);
        }
    }
}
