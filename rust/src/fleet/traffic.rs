//! Seeded deterministic traffic generation for `bpipe serve`.
//!
//! The fleet runs in rounds; each round the generator emits a number of
//! work-item arrivals drawn from one of three shapes.  Everything is
//! derived from the seed and the round index — two runs with the same
//! seed offer the identical arrival sequence, which is what lets the
//! chaos suite assert exact admission/shed accounting under replica
//! kills.

use crate::util::SplitMix64;

/// Arrival shape for the fleet's work queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrafficPattern {
    /// `base` arrivals every round — the calibration shape: with all
    /// replicas alive the queue neither grows nor drains.
    Steady,
    /// Mostly half-rate with seeded 3× bursts (probability 1/4 per
    /// round) — exercises backpressure and load-shedding.
    Bursty,
    /// An 8-round diurnal cycle ramping 0 → peak → 0 — exercises both
    /// idle drain and peak shed in one run.
    Diurnal,
}

impl TrafficPattern {
    pub fn label(&self) -> &'static str {
        match self {
            TrafficPattern::Steady => "steady",
            TrafficPattern::Bursty => "bursty",
            TrafficPattern::Diurnal => "diurnal",
        }
    }

    /// Parse the `--traffic` CLI value.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "steady" => TrafficPattern::Steady,
            "bursty" => TrafficPattern::Bursty,
            "diurnal" => TrafficPattern::Diurnal,
            other => anyhow::bail!("unknown traffic pattern {other:?} (steady|bursty|diurnal)"),
        })
    }
}

/// Deterministic per-round arrival counts: one [`SplitMix64`] stream,
/// advanced exactly once per round regardless of pattern, so arrival
/// sequences are reproducible from (pattern, seed, base) alone.
#[derive(Debug, Clone)]
pub struct TrafficGen {
    pattern: TrafficPattern,
    rng: SplitMix64,
    /// steady-state arrivals per round (the fleet's nominal capacity)
    base: u64,
}

impl TrafficGen {
    pub fn new(pattern: TrafficPattern, seed: u64, base: u64) -> Self {
        Self { pattern, rng: SplitMix64::new(seed), base }
    }

    /// Work items arriving in `round` (0-based).
    pub fn arrivals(&mut self, round: u64) -> u64 {
        // one draw per round for every pattern keeps the stream aligned
        let draw = self.rng.next_f64();
        match self.pattern {
            TrafficPattern::Steady => self.base,
            TrafficPattern::Bursty => {
                if draw < 0.25 {
                    self.base * 3
                } else {
                    self.base / 2
                }
            }
            TrafficPattern::Diurnal => {
                // quarter-step ramp over an 8-round "day": the peak is
                // 2× nominal, the trough is zero
                const WAVE: [u64; 8] = [0, 1, 2, 4, 4, 2, 1, 0];
                self.base * WAVE[(round % 8) as usize] / 2
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_deterministic_per_seed() {
        for pattern in [TrafficPattern::Steady, TrafficPattern::Bursty, TrafficPattern::Diurnal] {
            let mut a = TrafficGen::new(pattern, 42, 4);
            let mut b = TrafficGen::new(pattern, 42, 4);
            let xs: Vec<u64> = (0..32).map(|r| a.arrivals(r)).collect();
            let ys: Vec<u64> = (0..32).map(|r| b.arrivals(r)).collect();
            assert_eq!(xs, ys, "{pattern:?}");
        }
    }

    #[test]
    fn bursty_actually_bursts_and_idles() {
        let mut g = TrafficGen::new(TrafficPattern::Bursty, 7, 4);
        let xs: Vec<u64> = (0..64).map(|r| g.arrivals(r)).collect();
        assert!(xs.iter().any(|&x| x == 12), "some rounds burst to 3×: {xs:?}");
        assert!(xs.iter().any(|&x| x == 2), "most rounds run at half rate: {xs:?}");
    }

    #[test]
    fn diurnal_cycles_through_trough_and_peak() {
        let mut g = TrafficGen::new(TrafficPattern::Diurnal, 0, 4);
        let day: Vec<u64> = (0..8).map(|r| g.arrivals(r)).collect();
        assert_eq!(day, vec![0, 2, 4, 8, 8, 4, 2, 0]);
        let next: Vec<u64> = (8..16).map(|r| g.arrivals(r)).collect();
        assert_eq!(next, day, "the cycle repeats");
    }

    #[test]
    fn pattern_parse_round_trips() {
        for p in [TrafficPattern::Steady, TrafficPattern::Bursty, TrafficPattern::Diurnal] {
            assert_eq!(TrafficPattern::parse(p.label()).unwrap(), p);
        }
        assert!(TrafficPattern::parse("monsoon").is_err());
    }
}
