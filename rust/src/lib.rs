//! # bpipe — Re-evaluating Memory-balanced Pipeline Parallelism
//!
//! A reproduction of *"Re-evaluating the Memory-balanced Pipeline
//! Parallelism: BPipe"* (Huang et al., Meituan 2024) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — pipeline-parallel training coordination: the
//!   1F1B/GPipe/interleaved/zig-zag schedules, the BPipe
//!   activation-balancing transformation ([`bpipe`]), a calibrated
//!   discrete-event cluster simulator ([`sim`]) that regenerates every
//!   table/figure of the paper at A100-cluster scale, the paper-§4
//!   analytical estimator ([`estimator`]), and a *real* pipeline
//!   ([`coordinator`]) generic over the [`runtime::Backend`]
//!   abstraction: the in-tree deterministic [`runtime::SimBackend`]
//!   (tier-1, no dependencies) or AOT-compiled HLO-text artifacts on a
//!   PJRT-shaped client (feature `pjrt`, backed by the vendored
//!   in-tree stub `runtime::pjrt_stub`; dropping in the real `xla`
//!   crate is a one-line alias change).
//! * **L2 (python/compile/model.py)** — JAX stage graphs (GPT-3 and
//!   LLaMA families), lowered once to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas flash-attention and fused
//!   scale+mask+softmax kernels.
//!
//! Python never runs on the training path: `make artifacts` lowers the
//! model once; the `bpipe` binary is self-contained afterwards.
//!
//! ## Paper section → module map
//!
//! | Paper artifact | Where it lives |
//! |---|---|
//! | §2.2 1F1B + BPipe transform (Fig. 1) | [`schedule::one_f_one_b()`], [`bpipe::apply_bpipe`], [`bpipe::rebalance()`] |
//! | §2.2 evictor/acceptor pairing + bound | [`bpipe::pairing`], [`model::memory::bpipe_bound`] |
//! | Fig. 2 pair-adjacent placement | [`bpipe::layout`], `bpipe figures --which 2` |
//! | §3.1 models/cluster (Tables 1–2) | [`config`] presets |
//! | §3.1 Eq. 1 FLOPs | [`model::flops`] |
//! | §3.2 fused-softmax kernel switch | [`sim::costmodel::fused_softmax_eligible`] |
//! | Table 3 / Table 5 regeneration | [`report::tables`], driven by [`sim`] |
//! | §4 estimator (Eqs. 2–4, Table 4) | [`estimator`], `bpipe estimate` |
//! | Figures 1/2 + estimator-vs-DES report | [`report::figures`], `bpipe report` |
//! | §2.2 claim on a REAL pipeline: bit-identical BPipe losses | [`coordinator::train`] over [`runtime::SimBackend`], `bpipe train --backend sim` |
//! | Beyond the paper: schedule/bound/layout design space | [`mod@sim::sweep`], [`schedule::zigzag()`], [`bpipe::rebalance_bounded`] |
//! | Beyond the paper: zero-alloc training hot path (buffer donation) | [`runtime::BufferPool`], [`runtime::Backend::execute_pooled`], [`coordinator::train_probed`] |
//! | Beyond the paper: static schedule/protocol analyzer (deadlock, linearity, bounds) | [`analysis`], `bpipe check` |
//! | Beyond the paper: deterministic fault injection (crash/stall/transient/HBM-cap) | [`runtime::FaultPlan`], [`runtime::FaultyBackend`], `bpipe train --faults` |
//! | Beyond the paper: supervised recovery — checkpoint, re-plan under reduced HBM ([`analysis::gate_plan`]), resume | [`coordinator::supervisor`], [`coordinator::latest_common_step`] |
//! | Beyond the paper: schedule synthesis under per-stage memory caps (found-vs-family frontier) | [`schedule::synthesize()`], [`sim::sweep::frontier_outcomes`], `bpipe check/train --schedule synth`, `bpipe sweep --synth` |
//! | Beyond the paper: 8-lane SIMD kernels + canonical tree reduction (bit-reproducible) | [`runtime::kernels`], `rust/tests/property_kernels.rs` |
//! | Beyond the paper: warm-start delta-DES (event-prefix replay between adjacent bounds) | [`sim::SimWorkspace`], [`sim::SweepReport`], `bpipe sweep --bounds [--force-cold]` |
//! | Beyond the paper: vendored PJRT-shaped client (compile/execute/donation aliases) | `runtime::pjrt_stub` (feature `pjrt`), `runtime::engine` |
//! | Beyond the paper: recompute-vs-stash hybrid memory model in the sweep | [`sim::SweepOptions`] (`recompute`), `bpipe sweep --recompute` |
//! | Beyond the paper: elastic fleet — N pipeline replicas under live traffic, replica-level fault domains, load shedding, elastic re-admission | [`fleet::serve`], [`fleet::WorkQueue`], [`fleet::TrafficGen`], `bpipe serve` |
//!
//! `docs/ARCHITECTURE.md` has the crate-level data-flow diagram and the
//! [`runtime::Backend`] boundary; [`sweep_schema`] documents (and
//! doc-tests) the sweep export formats.

pub mod analysis;
pub mod bpipe;
pub mod config;
pub mod coordinator;
pub mod estimator;
pub mod fleet;
pub mod metrics;
pub mod model;
pub mod report;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod util;

pub use config::{
    AttentionMethod, ClusterConfig, ExperimentConfig, ModelConfig, ParallelConfig,
};

/// The sweep CSV/JSON export schema, doc-tested from
/// `docs/SWEEP_SCHEMA.md`: the code blocks in that file compile and run
/// as part of `cargo test`, so the documented schema cannot drift from
/// the exporters without a test failure.
#[doc = include_str!("../../docs/SWEEP_SCHEMA.md")]
pub mod sweep_schema {}
