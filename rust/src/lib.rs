//! # bpipe — Re-evaluating Memory-balanced Pipeline Parallelism
//!
//! A reproduction of *"Re-evaluating the Memory-balanced Pipeline
//! Parallelism: BPipe"* (Huang et al., Meituan 2024) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — pipeline-parallel training coordination: the
//!   1F1B/GPipe/interleaved schedules, the BPipe activation-balancing
//!   transformation ([`bpipe`]), a calibrated discrete-event cluster
//!   simulator ([`sim`]) that regenerates every table/figure of the paper
//!   at A100-cluster scale, the paper-§4 analytical estimator
//!   ([`estimator`]), and a *real* pipeline runtime (`coordinator`,
//!   `runtime`; behind the `pjrt` feature, which additionally needs the
//!   `xla` crate) that trains an actual transformer through AOT-compiled
//!   XLA artifacts on the PJRT CPU client.
//! * **L2 (python/compile/model.py)** — JAX stage graphs (GPT-3 and
//!   LLaMA families), lowered once to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas flash-attention and fused
//!   scale+mask+softmax kernels.
//!
//! Python never runs on the training path: `make artifacts` lowers the
//! model once; the `bpipe` binary is self-contained afterwards.

pub mod bpipe;
pub mod config;
#[cfg(feature = "pjrt")]
pub mod coordinator;
pub mod estimator;
pub mod metrics;
pub mod model;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod util;

pub use config::{
    AttentionMethod, ClusterConfig, ExperimentConfig, ModelConfig, ParallelConfig,
};
