//! `bpipe` — CLI launcher for the BPipe re-evaluation stack.
//!
//! Subcommands map one-to-one onto the paper's artifacts:
//!
//! * `tables  --which 2|3|5` — regenerate paper Tables 2/3/5 (simulator);
//! * `figures --which 1|2`   — Figure 1 (BPipe 1F1B timeline) and
//!   Figure 2 (pair-adjacent layout);
//! * `simulate`              — one experiment through the DES, full report;
//! * `sweep`                 — the full experiment × schedule × layout
//!   grid through the parallel sweep driver, ranked by MFU; `--bounds`
//!   runs the bound × load_stall sensitivity grid (every rebalance bound
//!   from derived down to the knee) and prints the per-scenario
//!   frontier; `--csv`/`--json` export every cell;
//! * `report`                — the replication report: a self-contained
//!   markdown file with embedded SVG figures (per-stage memory,
//!   MFU ranking, bound frontier, found-vs-family frontier) and the
//!   estimator-vs-DES error tables, built from sweep outcomes
//!   in-process;
//! * `estimate`              — the §4 Eq. 4 estimator (analytic or from
//!   real single-stage runtime measurements; the latter needs the `pjrt`
//!   build feature);
//! * `memory`                — per-stage memory profile, ±BPipe;
//! * `schedule`              — print a schedule program (any generator,
//!   optionally rebalanced);
//! * `check`                 — the static schedule/protocol analyzer:
//!   deadlock-freedom, donation linearity and memory bounds proven from
//!   the schedule alone (`--grid` sweeps all 15 ranking scenarios);
//! * `train`                 — REAL pipeline training over PJRT artifacts
//!   (`pjrt` feature).
//!
//! Argument parsing is in-tree ([`Args`]) — the build is fully offline.

use std::collections::HashMap;
use std::path::PathBuf;

use bpipe::bpipe as bpipe_mod;
use bpipe::config::{self, ExperimentConfig};
use bpipe::estimator::{self, StageMeasurement};
use bpipe::model::memory::MemoryModel;
use bpipe::report;
use bpipe::sim;

const USAGE: &str = "\
bpipe — Re-evaluating Memory-balanced Pipeline Parallelism (BPipe)

USAGE: bpipe <COMMAND> [--flag value]...

COMMANDS:
  tables    --which 2|3|5                regenerate a paper table
  figures   --which 1|2 [--p N --nodes N] regenerate a paper figure
  simulate  [--experiment 1..10 | --config f.cfg] [--bpipe true|false]
            [--timeline]                 simulate one experiment
  sweep     [--experiment 1..10] [--v N] [--threads N]
            [--bounds | --synth] [--skip-oom] [--force-cold]
            [--recompute]
            [--csv f.csv] [--json f.json]  rank the experiment x schedule
                                         x layout grid (parallel DES);
                                         --bounds sweeps every rebalance
                                         bound down to the knee instead;
                                         --synth ranks a synthesized
                                         schedule against every family
                                         under a tight per-stage HBM cap
                                         (the found-vs-family frontier);
                                         --skip-oom settles provably-OOM
                                         cells statically (no DES);
                                         --force-cold disables the
                                         warm-start DES replay (A/B
                                         timing); --recompute swaps the
                                         BPipe stash transfers for a
                                         recompute-on-return memory
                                         model (discard on Evict, re-run
                                         fwd on Load; no link traffic)
  report    [--experiment 1..10 | --all] [--v N] [--threads N]
            [--out report.md]            replication report: markdown +
                                         embedded SVG figures + the
                                         estimator-vs-DES error tables;
                                         --all renders every Table-3 row
                                         into one indexed report
  estimate  [--global-batch B --p P --from b:mfu --to b:mfu]
            [--runtime --artifacts DIR]  paper §4 Eq. 4 estimator
  memory    [--experiment 1..10]         per-stage memory profile
  schedule  [--p N --m N --kind 1f1b|gpipe|interleaved|vshaped|zigzag]
            [--v N] [--bpipe | --rebalance [--bound K]]
  check     [--schedule 1f1b|gpipe|interleaved|vshaped|zigzag|synth --v N]
            [--p N --m N] [--cap-gib G]
            [--rebalance [--bound K] | --stage-bounds a,b,..
             | --capacity [--experiment 1..10]]
            [--hot-cap N --feed-cap N] [--json]
            [--grid [--experiment 1..10]] static analyzer: prove
                                         deadlock-freedom, donation
                                         linearity and memory bounds
                                         before running; --grid checks
                                         all 15 ranking-grid scenarios;
                                         exits 1 on error findings
  train     [--backend sim|pjrt] [--artifacts DIR]
            [--schedule 1f1b|gpipe|interleaved|vshaped|zigzag|synth --v N]
            [--cap-gib G]
            [--bpipe | --rebalance [--bound K] | --stage-bounds a,b,..]
            [--steps N --microbatches M --lr F --p N] [--seed N]
            [--log-every N] [--checkpoint-dir D --checkpoint-every N]
            [--resume]
            [--faults plan.json] [--max-restarts N]
            [--recover-timeout-ms T] [--retry-budget N]
            [--retry-backoff-ms T]       REAL pipeline training: the
                                         in-tree SimBackend by default
                                         (no artifacts needed), PJRT
                                         with the pjrt build feature.
                                         Any fault/restart flag turns on
                                         the supervisor: failures are
                                         classified, the run rolls back
                                         to the last common checkpoint,
                                         re-plans under reduced HBM and
                                         resumes (bounded restarts;
                                         structured [bpipe-recover]
                                         event lines; exit 1 on a
                                         terminal abort)
  serve     [--replicas R] [--traffic steady|bursty|diurnal] [--steps N]
            [--rate N] [--queue-cap N] [--segment-len N]
            [--p N --microbatches M --lr F] [--seed N]
            [--schedule 1f1b|gpipe|interleaved|vshaped|zigzag --v N]
            [--bpipe | --rebalance [--bound K] | --stage-bounds a,b,..]
            [--faults plan.json] [--max-restarts N]
            [--recover-timeout-ms T] [--segment-timeout-ms T]
            [--readmit-after R] [--sync-every N] [--no-steal]
            [--replica-cap-bytes B] [--run-dir D]
            [--json f.json]              elastic fleet: R pipeline
                                         replicas under seeded live
                                         traffic, fed from one bounded
                                         queue (backpressure, then typed
                                         load shedding). A replica
                                         failing past its restart budget
                                         is drained back to the queue,
                                         survivors absorb its work
                                         (degraded mode), and after a
                                         cool-down the replica is
                                         re-admitted and resumes from
                                         its checkpoints. Structured
                                         [bpipe-fleet] event lines plus
                                         a JSON summary; exit 1 when
                                         serving is impossible (all
                                         replicas down with re-admission
                                         off, or no feasible plan under
                                         --replica-cap-bytes)
";

/// Minimal flag parser: `--key value` pairs plus boolean `--key` flags.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String], bool_flags: &[&str]) -> anyhow::Result<Self> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --flag, got {a:?}"))?
                .to_string();
            if bool_flags.contains(&key.as_str())
                && (i + 1 >= argv.len() || argv[i + 1].starts_with("--"))
            {
                flags.insert(key, "true".into());
                i += 1;
            } else {
                let v = argv
                    .get(i + 1)
                    .ok_or_else(|| anyhow::anyhow!("flag --{key} needs a value"))?;
                flags.insert(key, v.clone());
                i += 2;
            }
        }
        Ok(Self { flags })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }

    fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
}

fn experiment_or_exit(id: u32) -> ExperimentConfig {
    config::paper_experiment(id).unwrap_or_else(|| {
        eprintln!("experiment id must be 1..=10");
        std::process::exit(2);
    })
}

fn parse_measurement(s: &str) -> anyhow::Result<StageMeasurement> {
    let (b, mfu) = s
        .split_once(':')
        .ok_or_else(|| anyhow::anyhow!("expected b:mfu, e.g. 1:0.378, got {s:?}"))?;
    Ok(StageMeasurement { b: b.trim().parse()?, mfu_stage: mfu.trim().parse()? })
}

fn parse_family(kind: &str, v: u64) -> anyhow::Result<bpipe::schedule::Family> {
    use bpipe::schedule::Family;
    Ok(match kind {
        "1f1b" => Family::OneFOneB,
        "gpipe" => Family::GPipe,
        "interleaved" => Family::Interleaved { v },
        "vshaped" => Family::VShaped,
        "zigzag" => Family::ZigZag { v },
        other => anyhow::bail!(
            "unknown schedule kind {other:?} (1f1b|gpipe|interleaved|vshaped|zigzag)"
        ),
    })
}

/// Build a synthesized schedule for the `--schedule synth` paths: the
/// per-stage memory caps are uniform at `--cap-gib` GiB (default: 90% of
/// the `--experiment` cluster's HBM), the cost model is the experiment
/// reshaped to pipeline depth `p`.  Returns the schedule and the byte
/// cap it was synthesized under.
fn synth_schedule(args: &Args, p: u64, m: u64) -> anyhow::Result<(bpipe::schedule::Schedule, u64)> {
    let mut e = experiment_or_exit(args.get("experiment", 8u32)?);
    e.parallel.p = p;
    let cap = match args.opt("cap-gib") {
        Some(g) => {
            let gib: f64 = g.parse().map_err(|err| anyhow::anyhow!("--cap-gib {g:?}: {err}"))?;
            (gib * (1u64 << 30) as f64) as u64
        }
        None => e.cluster.hbm_bytes / 10 * 9,
    };
    let cost = sim::CostModel::new(&e);
    let s = bpipe::schedule::try_synthesize(p, m, &vec![cap; p as usize], &cost)
        .map_err(|err| anyhow::anyhow!("schedule synthesis failed: {err}"))?;
    Ok((s, cap))
}

/// Shared result reporting for `bpipe train` on any backend.
fn run_train<B: bpipe::runtime::Backend>(
    cfg: &bpipe::coordinator::TrainConfig,
) -> anyhow::Result<()> {
    println!(
        "training: {} steps × {} microbatches, family {:?}, rebalance {}",
        cfg.steps,
        cfg.microbatches,
        cfg.family,
        match &cfg.rebalance {
            bpipe::coordinator::RebalancePlan::Off => "off".to_string(),
            bpipe::coordinator::RebalancePlan::Uniform { bound: None } =>
                "uniform (derived bound)".to_string(),
            bpipe::coordinator::RebalancePlan::Uniform { bound: Some(k) } =>
                format!("uniform (bound {k})"),
            bpipe::coordinator::RebalancePlan::PerStage { bounds } =>
                format!("per-stage {bounds:?}"),
            bpipe::coordinator::RebalancePlan::Capacity { .. } =>
                "capacity-derived per-stage".to_string(),
        }
    );
    let r = bpipe::coordinator::train::<B>(cfg)?;
    println!(
        "first loss {:.4} → final loss {:.4}",
        r.losses.first().unwrap(),
        r.final_loss()
    );
    println!("mean step time {:.3}s, tokens {}", r.mean_step_time(), r.tokens);
    print_stage_stats(&r.stage_stats);
    Ok(())
}

fn print_stage_stats(stats: &[bpipe::coordinator::StageStats]) {
    for st in stats {
        let pool_total = st.pool_hits + st.pool_misses;
        println!(
            "  stage {}: fwd {:.2}s bwd {:.2}s adam {:.2}s load-wait {:.2}s evictions {} \
             stash-hw {} pool-hit {:.0}% retried {}",
            st.stage,
            st.fwd_s,
            st.bwd_s,
            st.adam_s,
            st.load_wait_s,
            st.evictions,
            st.stash_high_water,
            if pool_total > 0 { 100.0 * st.pool_hits as f64 / pool_total as f64 } else { 0.0 },
            st.retried_executes,
        );
    }
}

/// `bpipe train` under the fault-tolerant supervisor: install the fault
/// plan (when given), recover from failures, report recovery telemetry.
/// A terminal abort prints its structured report and exits nonzero.
fn run_train_supervised<B: bpipe::runtime::Backend>(
    scfg: &bpipe::coordinator::SuperviseConfig,
) -> anyhow::Result<()> {
    println!(
        "supervised training: {} steps × {} microbatches, family {:?}, max restarts {}, \
         recover timeout {:?}",
        scfg.train.steps,
        scfg.train.microbatches,
        scfg.train.family,
        scfg.max_restarts,
        scfg.recover_timeout,
    );
    match bpipe::coordinator::supervise::<B>(scfg) {
        Ok(outcome) => {
            let r = &outcome.result;
            println!(
                "first loss {:.4} → final loss {:.4}",
                outcome.losses.first().copied().unwrap_or(f32::NAN),
                r.final_loss()
            );
            println!("mean step time {:.3}s, tokens {}", r.mean_step_time(), r.tokens);
            println!("recovery: {}", outcome.recovery_stats().summary());
            print_stage_stats(&r.stage_stats);
            Ok(())
        }
        Err(e) => {
            eprintln!("training aborted: {e:#}");
            std::process::exit(1);
        }
    }
}

/// Assemble the supervision policy from the `train` flags (any
/// fault/restart flag opts the run in).
fn build_supervise_config(
    args: &Args,
    mut train: bpipe::coordinator::TrainConfig,
) -> anyhow::Result<bpipe::coordinator::SuperviseConfig> {
    if train.checkpoint_dir.is_none() {
        // recovery needs somewhere to roll back to
        let dir = std::env::temp_dir().join(format!("bpipe-ck-{}", std::process::id()));
        println!("supervised run without --checkpoint-dir; checkpoints go to {dir:?}");
        train.checkpoint_dir = Some(dir);
    }
    let faults = match args.opt("faults") {
        Some(path) => Some(std::sync::Arc::new(bpipe::runtime::FaultPlan::load(
            std::path::Path::new(path),
        )?)),
        None => None,
    };
    Ok(bpipe::coordinator::SuperviseConfig {
        train,
        faults,
        max_restarts: args.get("max-restarts", 3u32)?,
        recover_timeout: Some(std::time::Duration::from_millis(
            args.get("recover-timeout-ms", 5000u64)?,
        )),
        backoff_base_ms: 10,
        log: true,
    })
}

/// Measure single-stage timings over the real PJRT runtime (Eq. 4's
/// input) — only available with the `pjrt` build feature.
#[cfg(feature = "pjrt")]
fn runtime_measurements(
    artifacts: &std::path::Path,
    fx: StageMeasurement,
    fy: StageMeasurement,
) -> anyhow::Result<(StageMeasurement, StageMeasurement)> {
    println!("measuring single-stage timings from {artifacts:?} …");
    let manifest = bpipe::runtime::Manifest::load(artifacts)?;
    let tx = bpipe::coordinator::measure_stage::<bpipe::runtime::Runtime>(&manifest, fx.b, 3)?;
    let ty = bpipe::coordinator::measure_stage::<bpipe::runtime::Runtime>(&manifest, fy.b, 3)?;
    for t in [&tx, &ty] {
        println!(
            "  b={} : {:.1} ms/microbatch, {:.2e} FLOP/s",
            t.b,
            t.t_b * 1e3,
            t.flops_per_s
        );
    }
    let peak = tx.flops_per_s.max(ty.flops_per_s) * 1.25;
    Ok((
        StageMeasurement { b: tx.b, mfu_stage: tx.flops_per_s / peak },
        StageMeasurement { b: ty.b, mfu_stage: ty.flops_per_s / peak },
    ))
}

#[cfg(not(feature = "pjrt"))]
fn runtime_measurements(
    _artifacts: &std::path::Path,
    _fx: StageMeasurement,
    _fy: StageMeasurement,
) -> anyhow::Result<(StageMeasurement, StageMeasurement)> {
    anyhow::bail!("--runtime needs the PJRT runtime: rebuild with --features pjrt")
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        std::process::exit(2);
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "tables" => {
            let args = Args::parse(rest, &[])?;
            match args.get("which", 3u32)? {
                2 => print!("{}", report::render_table2()),
                3 => print!("{}", report::render_table3()),
                5 => print!("{}", report::render_table5()),
                w => anyhow::bail!("no table {w} in the paper (2, 3 or 5)"),
            }
        }
        "figures" => {
            let args = Args::parse(rest, &[])?;
            let which = args.get("which", 1u32)?;
            let p = args.get("p", 16u64)?;
            let nodes = args.get("nodes", 2u64)?;
            match which {
                1 => {
                    let mut e4 = experiment_or_exit(8);
                    let m = 8;
                    e4.parallel.p = 4;
                    e4.parallel.global_batch = m * e4.parallel.microbatch;
                    let base = bpipe::schedule::one_f_one_b(4, m);
                    let bp = bpipe_mod::apply_bpipe(&base, None);
                    let layout = bpipe_mod::pair_adjacent_layout(4, 1);
                    println!("== plain 1F1B (p=4, m={m}) ==");
                    let r = sim::simulate(&e4, &base, &layout);
                    print!("{}", report::render_timeline(&r.trace, 4, 110));
                    println!("\n== with BPipe (bound {}) ==", bpipe_mod::pairing::bound(4));
                    let r = sim::simulate(&e4, &bp, &layout);
                    print!("{}", report::render_timeline(&r.trace, 4, 110));
                    println!("\nprogram-order view:\n{}", report::timeline::render_program(&bp));
                }
                2 => {
                    println!("== sequential (pairs cross nodes) ==");
                    print!("{}", report::render_layout(&bpipe_mod::sequential_layout(p, nodes), p));
                    println!("\n== pair-adjacent (paper Figure 2) ==");
                    print!(
                        "{}",
                        report::render_layout(&bpipe_mod::pair_adjacent_layout(p, nodes), p)
                    );
                }
                w => anyhow::bail!("no figure {w} in the paper (1 or 2)"),
            }
        }
        "simulate" => {
            let args = Args::parse(rest, &["timeline"])?;
            let mut e = if let Some(path) = args.opt("config") {
                ExperimentConfig::load(&PathBuf::from(path))?
            } else {
                experiment_or_exit(args.get("experiment", 8u32)?)
            };
            if let Some(b) = args.opt("bpipe") {
                e.bpipe = b.parse()?;
            }
            println!("simulating: {}", e.summary());
            let r = sim::simulate_experiment(&e);
            println!("  makespan        : {:.3} s/iteration", r.makespan);
            println!("  MFU             : {:.1} %", r.mfu_pct());
            println!("  bubble fraction : {:.1} %", r.bubble_fraction * 100.0);
            println!("  load stall      : {:.1} ms", r.load_stall * 1e3);
            println!(
                "  BPipe traffic   : {:.2} GiB",
                r.transfer_bytes as f64 / (1u64 << 30) as f64
            );
            for (s, hw) in r.mem_high_water.iter().enumerate() {
                let flag = if Some(s as u64) == r.oom_stage { "  << OOM" } else { "" };
                println!(
                    "  stage {s} peak mem: {:.1} GiB{flag}",
                    *hw as f64 / (1u64 << 30) as f64
                );
            }
            if args.opt("timeline").is_some() {
                print!("{}", report::render_timeline(&r.trace, e.parallel.p, 110));
            }
        }
        "sweep" => {
            let args = Args::parse(rest, &["bounds", "skip-oom", "synth", "force-cold", "recompute"])?;
            let v = args.get("v", 2u64)?;
            let threads = args.get("threads", 0usize)?;
            if args.opt("synth").is_some() {
                // found-vs-family frontier: every family scenario plus a
                // synthesized cell, all under a tight per-stage HBM cap
                let e = experiment_or_exit(args.get("experiment", 8u32)?);
                let t0 = std::time::Instant::now();
                let (cap, outcomes) = sim::frontier_outcomes(&e, v, threads);
                let dt = t0.elapsed();
                print!("{}", sim::render_sweep(&outcomes));
                if let Some(path) = args.opt("csv") {
                    std::fs::write(path, sim::sweep_to_csv(&outcomes))?;
                    println!("wrote {} CSV rows to {path}", outcomes.len());
                }
                if let Some(path) = args.opt("json") {
                    std::fs::write(path, sim::sweep_to_json(&outcomes).to_string())?;
                    println!("wrote {} JSON records to {path}", outcomes.len());
                }
                println!(
                    "\nfound-vs-family frontier: {} cells at a {:.1} GiB/stage cap \
                     in {:.2}s",
                    outcomes.len(),
                    cap as f64 / (1u64 << 30) as f64,
                    dt.as_secs_f64()
                );
                return Ok(());
            }
            let bounds_mode = args.opt("bounds").is_some();
            let tasks = match (bounds_mode, args.opt("experiment")) {
                (false, Some(id)) => sim::experiment_tasks(&experiment_or_exit(id.parse()?), v),
                (false, None) => sim::paper_grid(v),
                (true, Some(id)) => {
                    sim::bound_sensitivity_tasks(&experiment_or_exit(id.parse()?), v)
                }
                (true, None) => sim::bounds_grid(v),
            };
            let count = tasks.len();
            let skip_oom = args.opt("skip-oom").is_some();
            let opts = sim::SweepOptions {
                skip_provable_oom: skip_oom,
                force_cold: args.opt("force-cold").is_some(),
                recompute: args.opt("recompute").is_some(),
            };
            let t0 = std::time::Instant::now();
            let report = sim::sweep_with(tasks, threads, opts);
            let dt = t0.elapsed();
            let outcomes = report.outcomes;
            if bounds_mode {
                print!("{}", sim::render_bound_frontier(&outcomes));
            } else {
                print!("{}", sim::render_sweep(&outcomes));
            }
            if let Some(path) = args.opt("csv") {
                std::fs::write(path, sim::sweep_to_csv(&outcomes))?;
                println!("wrote {} CSV rows to {path}", outcomes.len());
            }
            if let Some(path) = args.opt("json") {
                std::fs::write(path, sim::sweep_to_json(&outcomes).to_string())?;
                println!("wrote {} JSON records to {path}", outcomes.len());
            }
            if skip_oom {
                println!(
                    "\n{} grid cells simulated ({} provably-OOM cells settled \
                     statically) in {:.2}s",
                    count - report.skipped,
                    report.skipped,
                    dt.as_secs_f64()
                );
            } else {
                println!(
                    "\n{count} grid cells simulated in {:.2}s ({:.1} cells/s)",
                    dt.as_secs_f64(),
                    count as f64 / dt.as_secs_f64()
                );
            }
            if report.events_total > 0 {
                println!(
                    "warm-start replay: {} of {} events ({:.1}%){}",
                    report.events_replayed,
                    report.events_total,
                    100.0 * report.events_replayed as f64 / report.events_total as f64,
                    if opts.force_cold { " [forced cold]" } else { "" }
                );
            }
        }
        "report" => {
            let args = Args::parse(rest, &["all"])?;
            let v = args.get("v", 2u64)?;
            let threads = args.get("threads", 0usize)?;
            let out = args.opt("out").unwrap_or("bpipe_report.md");
            let t0 = std::time::Instant::now();
            let (md, what) = if args.opt("all").is_some() {
                (report::replication_report_all(v, threads), "all 10 experiments".to_string())
            } else {
                let e = experiment_or_exit(args.get("experiment", 8u32)?);
                let tag = e.id.map(|i| format!("({i})")).unwrap_or_default();
                (report::replication_report(&e, v, threads), format!("experiment {tag}"))
            };
            std::fs::write(out, &md)?;
            println!(
                "wrote replication report for {what} to {out}: {} bytes, {} figures, {:.2}s",
                md.len(),
                md.matches("<svg").count(),
                t0.elapsed().as_secs_f64()
            );
        }
        "estimate" => {
            let args = Args::parse(rest, &["runtime"])?;
            let global_batch = args.get("global-batch", 128u64)?;
            let p = args.get("p", 8u64)?;
            let from = args.opt("from").unwrap_or("1:0.378").to_string();
            let to = args.opt("to").unwrap_or("2:0.552").to_string();
            let artifacts = PathBuf::from(args.opt("artifacts").unwrap_or("artifacts"));
            let fx = parse_measurement(&from)?;
            let fy = parse_measurement(&to)?;
            let (x, y) = if args.opt("runtime").is_some() {
                runtime_measurements(&artifacts, fx, fy)?
            } else {
                (fx, fy)
            };
            let est = estimator::estimate(global_batch, p, x, y);
            println!("Eq. 4 estimate (B={global_batch}, p={p}):");
            println!(
                "  stage factor  : {:.3} (MFU_stage {:.1}% → {:.1}%)",
                est.stage_factor,
                x.mfu_stage * 100.0,
                y.mfu_stage * 100.0
            );
            println!("  bubble factor : {:.3}", est.bubble_factor);
            println!(
                "  speedup bound : {:.3}x  {}",
                est.speedup_bound,
                if est.speedup_bound > 1.0 {
                    "(worth raising b)"
                } else {
                    "(NOT worth it — the paper's LLaMA case)"
                }
            );
        }
        "memory" => {
            let args = Args::parse(rest, &[])?;
            let e = experiment_or_exit(args.get("experiment", 7u32)?);
            let mm = MemoryModel::new(&e);
            println!("memory profile: {}", e.summary());
            println!(
                "  HBM capacity: {:.0} GiB",
                e.cluster.hbm_bytes as f64 / (1u64 << 30) as f64
            );
            let plain = mm.profile_gib(false);
            let bal = mm.profile_gib(true);
            println!("  stage |  1F1B (GiB) | BPipe (GiB)");
            for s in 0..e.parallel.p as usize {
                let cap = e.cluster.hbm_bytes as f64 / (1u64 << 30) as f64;
                let oom = if plain[s] > cap { " OOM!" } else { "" };
                println!("  {s:>5} | {:>10.1}{oom:<5} | {:>10.1}", plain[s], bal[s]);
            }
        }
        "schedule" => {
            let args = Args::parse(rest, &["bpipe", "rebalance"])?;
            let p = args.get("p", 4u64)?;
            let m = args.get("m", 8u64)?;
            let v = args.get("v", 2u64)?;
            let kind = args.opt("kind").unwrap_or("1f1b");
            let sched = parse_family(kind, v)?.build(p, m);
            let sched = if args.opt("bpipe").is_some() {
                bpipe_mod::apply_bpipe(&sched, None)
            } else if args.opt("rebalance").is_some() {
                let bound = match args.opt("bound") {
                    Some(b) => Some(b.parse()?),
                    None => None,
                };
                bpipe_mod::rebalance(&sched, bound)
            } else {
                sched
            };
            print!("{}", report::timeline::render_program(&sched));
        }
        "check" => {
            use bpipe::analysis;
            use bpipe::coordinator::RebalancePlan;
            use bpipe::util::json::Json;
            let args = Args::parse(rest, &["rebalance", "capacity", "grid", "json"])?;
            let v = args.get("v", 2u64)?;
            let json_out = args.opt("json").is_some();

            // the cells to analyze: the 15-scenario ranking grid with
            // --grid, otherwise the one schedule the flags describe
            let cells: Vec<(String, bpipe::schedule::Schedule, RebalancePlan)> =
                if args.opt("grid").is_some() {
                    let e = experiment_or_exit(args.get("experiment", 8u32)?);
                    sim::scenario_specs(v)
                        .into_iter()
                        .map(|spec| {
                            let s = spec.build_for(&e);
                            let plan = RebalancePlan::Capacity { experiment: e.clone() };
                            (spec.name().to_string(), s, plan)
                        })
                        .collect()
                } else if args.opt("schedule") == Some("synth") {
                    // synthesized under per-stage byte caps; eviction
                    // bounds are baked into the programs + stage_bounds,
                    // so the plan side is Off
                    let p = args.get("p", 4u64)?;
                    let m = args.get("m", 8u64)?;
                    let (s, _cap) = synth_schedule(&args, p, m)?;
                    vec![("synthesized".to_string(), s, RebalancePlan::Off)]
                } else {
                    let family = parse_family(args.opt("schedule").unwrap_or("1f1b"), v)?;
                    if args.opt("capacity").is_some() {
                        let e = experiment_or_exit(args.get("experiment", 8u32)?);
                        let base =
                            family.build(e.parallel.p, e.parallel.num_microbatches());
                        let bounds = bpipe_mod::capacity_stage_bounds(&e, &base);
                        let s = bpipe_mod::rebalance_bounded(&base, &bounds);
                        let plan = RebalancePlan::Capacity { experiment: e };
                        vec![(family.stage_bounds_label().to_string(), s, plan)]
                    } else {
                        let p = args.get("p", 4u64)?;
                        let m = args.get("m", 8u64)?;
                        let base = family.build(p, m);
                        if let Some(bs) = args.opt("stage-bounds") {
                            let bounds = bs
                                .split(',')
                                .map(|t| {
                                    t.trim().parse::<u64>().map_err(|e| {
                                        anyhow::anyhow!("--stage-bounds {t:?}: {e}")
                                    })
                                })
                                .collect::<anyhow::Result<Vec<u64>>>()?;
                            let s = bpipe_mod::rebalance_bounded(&base, &bounds);
                            let plan = RebalancePlan::PerStage { bounds };
                            vec![(family.stage_bounds_label().to_string(), s, plan)]
                        } else if args.opt("rebalance").is_some() {
                            let bound = match args.opt("bound") {
                                Some(b) => Some(b.parse()?),
                                None => None,
                            };
                            let s = bpipe_mod::rebalance(&base, bound);
                            let plan = RebalancePlan::Uniform { bound };
                            vec![(family.rebalanced_label().to_string(), s, plan)]
                        } else {
                            vec![(family.label().to_string(), base, RebalancePlan::Off)]
                        }
                    }
                };

            let mut json_cells = Vec::new();
            let mut total_errors = 0usize;
            let mut total_warnings = 0usize;
            for (label, s, plan) in &cells {
                let mut caps = analysis::ChannelCaps::for_run(s.m, s.chunks);
                if let Some(h) = args.opt("hot-cap") {
                    caps.hot = h.parse()?;
                }
                if let Some(f) = args.opt("feed-cap") {
                    caps.feed = f.parse()?;
                }
                let diags = analysis::check_plan(s, plan, &caps);
                let errors =
                    diags.iter().filter(|d| d.severity == analysis::Severity::Error).count();
                let warnings = diags
                    .iter()
                    .filter(|d| d.severity == analysis::Severity::Warning)
                    .count();
                total_errors += errors;
                total_warnings += warnings;
                if json_out {
                    let bounds: Vec<Json> = analysis::static_bounds(s)
                        .iter()
                        .map(|est| {
                            Json::obj(vec![
                                ("stage", Json::Num(est.stage as f64)),
                                ("lo", Json::Num(est.lo as f64)),
                                ("pred", Json::Num(est.pred as f64)),
                                ("hi", Json::Num(est.hi as f64)),
                                (
                                    "planned",
                                    est.planned
                                        .map(|c| Json::Num(c as f64))
                                        .unwrap_or(Json::Null),
                                ),
                            ])
                        })
                        .collect();
                    json_cells.push(Json::obj(vec![
                        ("scenario", Json::str(label)),
                        ("p", Json::Num(s.p as f64)),
                        ("m", Json::Num(s.m as f64)),
                        ("chunks", Json::Num(s.chunks as f64)),
                        ("bounds", Json::Arr(bounds)),
                        ("diagnostics", analysis::diagnostics_to_json(&diags)),
                        ("ok", Json::Bool(errors == 0)),
                    ]));
                } else {
                    println!(
                        "checking {label}: p={} m={} chunks={} (caps: hot {} feed {} \
                         loss {} store {})",
                        s.p, s.m, s.chunks, caps.hot, caps.feed, caps.loss,
                        caps.remote_inflight
                    );
                    if cells.len() == 1 {
                        println!("  stage |  lo pred  hi | planned");
                        for est in analysis::static_bounds(s) {
                            let cap = est
                                .planned
                                .map(|c| c.to_string())
                                .unwrap_or_else(|| "-".into());
                            println!(
                                "  {:>5} | {:>3} {:>4} {:>3} | {cap:>7}",
                                est.stage, est.lo, est.pred, est.hi
                            );
                        }
                    }
                    if diags.is_empty() {
                        println!("  ok — no findings");
                    } else {
                        for line in analysis::render_diagnostics(&diags).lines() {
                            println!("  {line}");
                        }
                    }
                }
            }
            if json_out {
                println!("{}", Json::Arr(json_cells));
            } else {
                println!(
                    "\n{} schedule(s) checked: {total_errors} error(s), \
                     {total_warnings} warning(s)",
                    cells.len()
                );
            }
            if total_errors > 0 {
                std::process::exit(1);
            }
        }
        "train" => {
            use bpipe::coordinator::RebalancePlan;
            let args = Args::parse(rest, &["bpipe", "rebalance", "resume"])?;
            let v = args.get("v", 2u64)?;
            let kind = args.opt("schedule").unwrap_or("1f1b");
            let synth = kind == "synth";
            // a synthesized run still carries a family for bookkeeping
            // (chunks 1, like synthesized schedules); the override below
            // bypasses its planner entirely
            let family =
                if synth { bpipe::schedule::Family::OneFOneB } else { parse_family(kind, v)? };
            let rebalance = if let Some(bs) = args.opt("stage-bounds") {
                let bounds = bs
                    .split(',')
                    .map(|t| {
                        t.trim()
                            .parse::<u64>()
                            .map_err(|e| anyhow::anyhow!("--stage-bounds {t:?}: {e}"))
                    })
                    .collect::<anyhow::Result<Vec<u64>>>()?;
                RebalancePlan::PerStage { bounds }
            } else if args.opt("bpipe").is_some() || args.opt("rebalance").is_some() {
                let bound = match args.opt("bound") {
                    Some(b) => Some(b.parse()?),
                    None => None,
                };
                RebalancePlan::Uniform { bound }
            } else {
                RebalancePlan::Off
            };
            let artifacts = PathBuf::from(args.opt("artifacts").unwrap_or("artifacts"));
            let mut cfg = bpipe::coordinator::TrainConfig {
                artifacts_dir: artifacts.clone(),
                manifest: None,
                family,
                schedule_override: None,
                steps: args.get("steps", 20u64)?,
                microbatches: args.get("microbatches", 8u64)?,
                lr: args.get("lr", 1e-3f32)?,
                rebalance,
                seed: args.get("seed", 0u64)?,
                log_every: args.get("log-every", 5u64)?,
                checkpoint_dir: args.opt("checkpoint-dir").map(PathBuf::from),
                checkpoint_every: args.get("checkpoint-every", 0u64)?,
                resume: args.opt("resume").is_some(),
                recover_timeout: None,
                retry_budget: args.get("retry-budget", 3u32)?,
                retry_backoff_ms: args.get("retry-backoff-ms", 10u64)?,
                progress: None,
                replica: None,
            };
            if synth {
                let p = args.get("p", 4u64)?;
                let (s, cap) = synth_schedule(&args, p, cfg.microbatches)?;
                println!(
                    "synthesized schedule: p={p} m={}, {:.1} GiB/stage cap, \
                     stash budgets {:?}",
                    cfg.microbatches,
                    cap as f64 / (1u64 << 30) as f64,
                    s.stage_bounds.clone().unwrap_or_default()
                );
                cfg.schedule_override = Some(s);
            }
            let supervised = ["faults", "max-restarts", "recover-timeout-ms"]
                .iter()
                .any(|f| args.opt(f).is_some());
            let default_backend = if cfg!(feature = "pjrt") { "pjrt" } else { "sim" };
            match args.opt("backend").unwrap_or(default_backend) {
                "sim" => {
                    // load a lowered manifest when one exists, otherwise
                    // run fully in memory on the synthetic model
                    cfg.manifest = if artifacts.join("manifest.json").exists() {
                        let m = bpipe::runtime::Manifest::load(&artifacts)?;
                        if let Some(p) = args.opt("p") {
                            // --p only shapes the synthetic manifest; a
                            // lowered manifest fixes the depth itself
                            let want: u64 = p.parse()?;
                            anyhow::ensure!(
                                want * family.chunks() == m.spec.stages,
                                "--p {want} × {} chunks contradicts the manifest at \
                                 {artifacts:?} ({} virtual stages); drop --p or point \
                                 --artifacts elsewhere",
                                family.chunks(),
                                m.spec.stages
                            );
                        }
                        Some(m)
                    } else {
                        let p = args.get("p", 4u64)?;
                        println!(
                            "no artifacts at {artifacts:?}; using the in-memory synthetic \
                             model (p={p} × {} chunks)",
                            family.chunks()
                        );
                        Some(bpipe::runtime::Manifest::synthetic(
                            p * family.chunks(),
                            16,
                            8,
                            2,
                            64,
                            &[1, 2],
                        ))
                    };
                    if supervised {
                        let scfg = build_supervise_config(&args, cfg)?;
                        run_train_supervised::<
                            bpipe::runtime::FaultyBackend<bpipe::runtime::SimBackend>,
                        >(&scfg)?;
                    } else {
                        run_train::<bpipe::runtime::SimBackend>(&cfg)?;
                    }
                }
                "pjrt" => {
                    #[cfg(feature = "pjrt")]
                    if supervised {
                        let scfg = build_supervise_config(&args, cfg)?;
                        run_train_supervised::<
                            bpipe::runtime::FaultyBackend<bpipe::runtime::Runtime>,
                        >(&scfg)?;
                    } else {
                        run_train::<bpipe::runtime::Runtime>(&cfg)?;
                    }
                    #[cfg(not(feature = "pjrt"))]
                    {
                        eprintln!(
                            "--backend pjrt needs the PJRT runtime: rebuild with \
                             --features pjrt, or use --backend sim"
                        );
                        std::process::exit(2);
                    }
                }
                other => anyhow::bail!("unknown backend {other:?} (sim | pjrt)"),
            }
        }
        "serve" => {
            use bpipe::coordinator::RebalancePlan;
            let args = Args::parse(rest, &["bpipe", "rebalance", "no-steal"])?;
            let v = args.get("v", 2u64)?;
            let family = parse_family(args.opt("schedule").unwrap_or("1f1b"), v)?;
            let rebalance = if let Some(bs) = args.opt("stage-bounds") {
                let bounds = bs
                    .split(',')
                    .map(|t| {
                        t.trim()
                            .parse::<u64>()
                            .map_err(|e| anyhow::anyhow!("--stage-bounds {t:?}: {e}"))
                    })
                    .collect::<anyhow::Result<Vec<u64>>>()?;
                RebalancePlan::PerStage { bounds }
            } else if args.opt("bpipe").is_some() || args.opt("rebalance").is_some() {
                let bound = match args.opt("bound") {
                    Some(b) => Some(b.parse()?),
                    None => None,
                };
                RebalancePlan::Uniform { bound }
            } else {
                RebalancePlan::Off
            };
            let artifacts = PathBuf::from(args.opt("artifacts").unwrap_or("artifacts"));
            let manifest = if artifacts.join("manifest.json").exists() {
                bpipe::runtime::Manifest::load(&artifacts)?
            } else {
                let p = args.get("p", 4u64)?;
                bpipe::runtime::Manifest::synthetic(p * family.chunks(), 16, 8, 2, 64, &[1, 2])
            };
            let faults = match args.opt("faults") {
                Some(path) => Some(std::sync::Arc::new(bpipe::runtime::FaultPlan::load(
                    std::path::Path::new(path),
                )?)),
                None => None,
            };
            let cfg = bpipe::fleet::FleetConfig {
                replicas: args.get("replicas", 3usize)?,
                steps: args.get("steps", 24u64)?,
                traffic: bpipe::fleet::TrafficPattern::parse(
                    args.opt("traffic").unwrap_or("steady"),
                )?,
                rate: args.get("rate", 0u64)?,
                queue_cap: args.get("queue-cap", 8usize)?,
                segment_len: args.get("segment-len", 2u64)?,
                seed: args.get("seed", 0u64)?,
                manifest: Some(manifest.clone()),
                family,
                rebalance,
                microbatches: args.get("microbatches", 4u64)?,
                lr: args.get("lr", 2e-3f32)?,
                faults,
                max_restarts: args.get("max-restarts", 0u32)?,
                recover_timeout: Some(std::time::Duration::from_millis(
                    args.get("recover-timeout-ms", 5000u64)?,
                )),
                segment_timeout: std::time::Duration::from_millis(
                    args.get("segment-timeout-ms", 60_000u64)?,
                ),
                readmit_after: args.get("readmit-after", 2u64)?,
                sync_every: args.get("sync-every", 4u64)?,
                steal: args.opt("no-steal").is_none(),
                replica_cap_bytes: match args.opt("replica-cap-bytes") {
                    Some(b) => Some(b.parse()?),
                    None => None,
                },
                run_dir: args.opt("run-dir").map(PathBuf::from).unwrap_or_else(|| {
                    std::env::temp_dir().join(format!("bpipe-fleet-{}", std::process::id()))
                }),
                log: true,
            };
            println!(
                "fleet: {} replicas × {} virtual stages ({:?}), {} work items under {} \
                 traffic, queue cap {}",
                cfg.replicas,
                manifest.spec.stages,
                family,
                cfg.steps,
                cfg.traffic.label(),
                cfg.queue_cap
            );
            match bpipe::fleet::serve::<bpipe::runtime::FaultyBackend<bpipe::runtime::SimBackend>>(
                &cfg,
            ) {
                Ok(out) => {
                    println!("{}", out.stats.summary());
                    let json = out.stats.to_json().to_string();
                    match args.opt("json") {
                        Some(path) => {
                            std::fs::write(path, &json)?;
                            println!("fleet summary JSON → {path}");
                        }
                        None => println!("{json}"),
                    }
                }
                Err(e) => {
                    eprintln!("serve aborted: {e:#}");
                    std::process::exit(1);
                }
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
