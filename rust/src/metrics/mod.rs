//! Metrics: MFU accounting, throughput, and run-level statistics shared
//! by the simulator, the real runtime and the benches.

use crate::config::ExperimentConfig;
use crate::model::flops;

/// Model-FLOPS-utilization bookkeeping for a run (paper §3.1: observed
/// throughput over hardware maximum, counting only Eq. 1 model FLOPs —
/// recompute FLOPs spend time but earn nothing).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MfuReport {
    /// model FLOPs per iteration (Eq. 1 over the global batch)
    pub model_flops: f64,
    /// devices × per-device peak FLOP/s
    pub aggregate_peak: f64,
    /// measured/simulated iteration time, seconds
    pub iter_time: f64,
    /// MFU in 0..1
    pub mfu: f64,
    /// tokens per second across the replica
    pub tokens_per_s: f64,
}

/// Compute an [`MfuReport`] for one iteration time.
pub fn mfu_report(e: &ExperimentConfig, iter_time: f64) -> MfuReport {
    let model_flops = flops::model_flops_per_iteration(&e.model, e.parallel.global_batch);
    let aggregate_peak = e.parallel.devices() as f64 * e.cluster.peak_flops;
    MfuReport {
        model_flops,
        aggregate_peak,
        iter_time,
        mfu: model_flops / (aggregate_peak * iter_time),
        tokens_per_s: (e.parallel.global_batch * e.model.s) as f64 / iter_time,
    }
}

/// Online mean/min/max/stddev accumulator for step timings and losses.
#[derive(Debug, Clone, Default)]
pub struct RunningStats {
    pub n: u64,
    pub mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl RunningStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Welford update.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

/// Fault-tolerance accounting for one supervised training run — what
/// the recovery runtime adds on top of [`RunningStats`]-style step
/// telemetry (see `coordinator::supervisor`).
#[derive(Debug, Clone)]
pub struct RecoveryStats {
    /// completed checkpoint–re-plan–resume cycles
    pub restarts: u32,
    /// transient `execute` failures retried in place (no restart)
    pub retried_executes: u64,
    /// optimizer steps rolled back and replayed across all restarts
    pub steps_lost: u64,
    /// failure-detection → first post-resume completed step, seconds
    pub time_to_recover: RunningStats,
}

impl RecoveryStats {
    pub fn new() -> Self {
        Self {
            restarts: 0,
            retried_executes: 0,
            steps_lost: 0,
            time_to_recover: RunningStats::new(),
        }
    }

    pub fn record_recovery(&mut self, secs: f64) {
        self.time_to_recover.push(secs);
    }

    /// One-line human summary for run logs.
    pub fn summary(&self) -> String {
        if self.restarts == 0 && self.retried_executes == 0 {
            return "no failures".into();
        }
        let ttr = if self.time_to_recover.n > 0 {
            format!(", mean time-to-recover {:.3}s", self.time_to_recover.mean)
        } else {
            String::new()
        };
        format!(
            "{} restart(s), {} retried execute(s), {} step(s) replayed{}",
            self.restarts, self.retried_executes, self.steps_lost, ttr
        )
    }
}

impl Default for RecoveryStats {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_experiment;

    #[test]
    fn mfu_report_scales_inverse_with_time() {
        let e = paper_experiment(7).unwrap();
        let fast = mfu_report(&e, 10.0);
        let slow = mfu_report(&e, 20.0);
        assert!((fast.mfu / slow.mfu - 2.0).abs() < 1e-12);
        assert!((fast.tokens_per_s / slow.tokens_per_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mfu_report_paper_scale_sanity() {
        // GPT-3 96B at 34% MFU on 32 A100s ⇒ iteration ≈ 45 s for B=128
        let e = paper_experiment(7).unwrap();
        let model_flops = flops::model_flops_per_iteration(&e.model, 128);
        let t = model_flops / (32.0 * 312e12 * 0.34);
        let rep = mfu_report(&e, t);
        assert!((rep.mfu - 0.34).abs() < 1e-9);
        assert!(t > 20.0 && t < 80.0, "iter time {t:.1}s");
    }

    #[test]
    fn recovery_stats_summary() {
        let mut r = RecoveryStats::new();
        assert_eq!(r.summary(), "no failures");
        r.restarts = 2;
        r.steps_lost = 3;
        r.record_recovery(0.5);
        r.record_recovery(1.5);
        let s = r.summary();
        assert!(s.contains("2 restart(s)"), "{s}");
        assert!(s.contains("3 step(s) replayed"), "{s}");
        assert!(s.contains("1.000s"), "{s}");
    }

    #[test]
    fn running_stats_welford() {
        let mut s = RunningStats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }
}
