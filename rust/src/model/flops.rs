//! FLOPs accounting — paper Eq. 1 and its per-stage / per-component split.
//!
//! Eq. 1 (from Narayanan et al. 2021, adopted by the paper §3.1):
//!
//! ```text
//! F = 72 b s l h² (1 + s/(6h) + v/(16 l h))
//! ```
//!
//! is the fwd+bwd matmul FLOPs for one microbatch of `b` sequences, with
//! the backward counted as 2× forward (72 = 3 × 24).  The paper shows
//! (§3.1) LLaMA's SwiGLU FFN (three matmuls to/from 8h/3) has the same
//! 16 b s h² FFN FLOPs as GPT-3's 4h GELU FFN, so one formula serves both
//! families.

use crate::config::{AttentionMethod, ModelConfig};

/// Fwd+bwd model FLOPs for a microbatch of `b` sequences — paper Eq. 1.
/// Excludes attention recomputation (see [`hardware_flops_per_microbatch`]).
pub fn model_flops_per_microbatch(m: &ModelConfig, b: u64) -> f64 {
    let (h, s, l, v) = (m.h as f64, m.s as f64, m.l as f64, m.v as f64);
    let b = b as f64;
    72.0 * b * s * l * h * h * (1.0 + s / (6.0 * h) + v / (16.0 * l * h))
}

/// Model FLOPs for a whole iteration over global batch `global_batch`.
pub fn model_flops_per_iteration(m: &ModelConfig, global_batch: u64) -> f64 {
    model_flops_per_microbatch(m, global_batch)
}

/// *Hardware* FLOPs actually executed per microbatch, including attention
/// recomputation when the method re-runs the attention forward in the
/// backward pass.  MFU per the paper divides *model* FLOPs (Eq. 1) by
/// time — recompute FLOPs cost time but earn no MFU credit.
pub fn hardware_flops_per_microbatch(m: &ModelConfig, b: u64, att: AttentionMethod) -> f64 {
    let base = model_flops_per_microbatch(m, b);
    match att {
        AttentionMethod::None => base,
        // Selective recompute re-runs the attention-core forward
        // (scores + context: 4bs²h per layer) once in the backward.
        AttentionMethod::Recompute => base + attention_core_flops(m, b),
        // Flash-attn's backward also recomputes the attention core; we
        // charge the same extra forward (flash-attn-2 does ~O(1) extra).
        AttentionMethod::FlashAttn2 => base + attention_core_flops(m, b),
    }
}

/// Attention-core (QKᵀ and PV matmuls) forward FLOPs for all layers:
/// `4 b s² h` per layer (2 matmuls × 2 flops/MAC).
pub fn attention_core_flops(m: &ModelConfig, b: u64) -> f64 {
    let (h, s, l) = (m.h as f64, m.s as f64, m.l as f64);
    4.0 * (b as f64) * s * s * h * l
}

/// Per-layer forward matmul FLOPs, split by component, for a microbatch
/// of `b` sequences on ONE tensor-parallel rank of `t`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerFlops {
    /// QKV projections: 6 b s h² / t
    pub qkv: f64,
    /// attention scores + context: 4 b s² h / t
    pub attn_core: f64,
    /// output projection: 2 b s h² / t
    pub proj: f64,
    /// FFN: 16 b s h² / t (both families, paper §3.1)
    pub ffn: f64,
}

impl LayerFlops {
    pub fn total(&self) -> f64 {
        self.qkv + self.attn_core + self.proj + self.ffn
    }
}

/// Forward matmul FLOPs of one transformer layer on one TP rank.
pub fn layer_fwd_flops(m: &ModelConfig, b: u64, t: u64) -> LayerFlops {
    let (h, s) = (m.h as f64, m.s as f64);
    let b = b as f64;
    let t = t as f64;
    LayerFlops {
        qkv: 6.0 * b * s * h * h / t,
        attn_core: 4.0 * b * s * s * h / t,
        proj: 2.0 * b * s * h * h / t,
        ffn: 16.0 * b * s * h * h / t,
    }
}

/// Model FLOPs of one pipeline stage (l/p layers), fwd+bwd, per
/// microbatch — the `F_stage` of the paper's §4 notation (Table 4).
/// The embedding/LM-head stages get the vocab-projection term.
pub fn stage_flops_per_microbatch(m: &ModelConfig, b: u64, p: u64, stage: u64) -> f64 {
    let (h, s, l, v) = (m.h as f64, m.s as f64, m.l as f64, m.v as f64);
    let b = b as f64;
    let layers = l / p as f64;
    let mut f = 72.0 * b * s * layers * h * h * (1.0 + s / (6.0 * h));
    if stage == p - 1 {
        // LM head: 6 b s h v (fwd 2bshv, ×3 for fwd+bwd)
        f += 6.0 * b * s * h * v;
    }
    f
}

/// `F_stage` for an interior stage — what §4's single-stage experiments
/// (Table 5) measure.
pub fn mid_stage_flops_per_microbatch(m: &ModelConfig, b: u64, p: u64) -> f64 {
    stage_flops_per_microbatch(m, b, p, 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{gpt3_96b, llama_65b};

    #[test]
    fn eq1_matches_closed_form_gpt3() {
        let m = gpt3_96b();
        let f = model_flops_per_microbatch(&m, 1);
        // hand-computed: 72 * 2048 * 80 * 9984^2 * (1 + 2048/(6*9984) + 51200/(16*80*9984))
        let h = 9984f64;
        let expect = 72.0 * 2048.0 * 80.0 * h * h
            * (1.0 + 2048.0 / (6.0 * h) + 51200.0 / (16.0 * 80.0 * h));
        assert!((f - expect).abs() / expect < 1e-12);
        // ~1.2 PFLOPs per sequence microbatch
        assert!(f > 1.0e15 && f < 2.0e15, "{f:e}");
    }

    #[test]
    fn flops_linear_in_batch() {
        let m = llama_65b();
        let f1 = model_flops_per_microbatch(&m, 1);
        let f4 = model_flops_per_microbatch(&m, 4);
        assert!((f4 - 4.0 * f1).abs() / f4 < 1e-12);
    }

    #[test]
    fn stage_flops_sum_close_to_eq1() {
        // Sum over stages ≈ Eq. 1 (the s/6h attention term is spread
        // uniformly; vocab term only on the last stage).
        let m = gpt3_96b();
        let p = 8;
        let total: f64 = (0..p).map(|s| stage_flops_per_microbatch(&m, 2, p, s)).sum();
        let eq1 = model_flops_per_microbatch(&m, 2);
        assert!((total - eq1).abs() / eq1 < 0.02, "{total:e} vs {eq1:e}");
    }

    #[test]
    fn recompute_adds_attention_core() {
        let m = llama_65b();
        let none = hardware_flops_per_microbatch(&m, 2, AttentionMethod::None);
        let rec = hardware_flops_per_microbatch(&m, 2, AttentionMethod::Recompute);
        assert!((rec - none - attention_core_flops(&m, 2)).abs() < 1.0);
    }

    #[test]
    fn layer_flops_components() {
        let m = llama_65b();
        let lf = layer_fwd_flops(&m, 1, 1);
        // FFN dominates at s << h
        assert!(lf.ffn > lf.qkv && lf.qkv > lf.attn_core);
        // per-rank division
        let lf4 = layer_fwd_flops(&m, 1, 4);
        assert!((lf.total() / lf4.total() - 4.0).abs() < 1e-9);
    }
}
