//! Device-memory model: weights/gradients/optimizer state + activations.
//!
//! This model decides the crux of Table 3: **which microbatch sizes fit
//! without BPipe**.  Constants follow Megatron-LM mixed-precision
//! training and the activation formulas of Korthikanti et al. 2023
//! ("Reducing Activation Recomputation…", the paper's ref [6]):
//!
//! * 18 bytes/param: bf16 weight (2) + fp32 grad (4) + fp32 master copy
//!   (4) + Adam m (4) + Adam v (4);
//! * full activations per layer per microbatch: `s·b·h·(34 + 5·a·s/h)/t`
//!   bytes (sequence parallelism divides both terms by `t`);
//! * selective attention recompute (or flash attention) drops the
//!   `5·a·s/h` score/softmax term, leaving `34·s·b·h/t`.
//!
//! Under 1F1B, stage `x` keeps up to `p − x` microbatch activation sets
//! alive (paper §2.2); BPipe bounds every stage to `⌈(p+2)/2⌉`.

use crate::config::{AttentionMethod, ExperimentConfig, ModelFamily};

/// Mixed-precision Adam bytes per parameter (Megatron-LM layout).
pub const BYTES_PER_PARAM: u64 = 18;

/// Activation element factor without the attention score term
/// (Korthikanti Eq. 2 family, bytes per `s·b·h` per layer).
pub const ACT_FACTOR_BASE: f64 = 34.0;

/// BPipe's per-device in-flight activation bound: `⌈(p+2)/2⌉` (paper §2.2).
pub fn bpipe_bound(p: u64) -> u64 {
    (p + 2).div_ceil(2)
}

/// Natural 1F1B in-flight activation count at stage `x` of `p`, with `m`
/// microbatches per iteration: `min(m, p − x)` (paper §2.2: "stage x …
/// needs to store p−x activations").
pub fn one_f_one_b_in_flight(p: u64, stage: u64, m: u64) -> u64 {
    (p - stage).min(m)
}

/// Per-device memory model for one experiment configuration.
///
/// Borrows the config instead of cloning it so constructing one is free —
/// the DES engine builds a `MemoryModel` per simulated sweep cell and must
/// not touch the heap (see [`crate::sim::engine::SimWorkspace`]).
#[derive(Debug, Clone, Copy)]
pub struct MemoryModel<'a> {
    pub e: &'a ExperimentConfig,
}

impl<'a> MemoryModel<'a> {
    pub fn new(e: &'a ExperimentConfig) -> Self {
        Self { e }
    }

    /// Transformer layers owned by each pipeline stage.
    pub fn layers_per_stage(&self) -> u64 {
        self.e.model.l / self.e.parallel.p
    }

    /// Parameters held by one device (one TP rank of one stage).
    pub fn params_per_device(&self, stage: u64) -> u64 {
        let m = &self.e.model;
        let t = self.e.parallel.t;
        let per_layer = 12 * m.h * m.h + 13 * m.h;
        let mut params = self.layers_per_stage() * per_layer / t;
        if stage == 0 {
            params += m.v * m.h / t; // token embedding
            if m.family == ModelFamily::Gpt {
                params += m.s * m.h / t; // learned positions
            }
        }
        if stage == self.e.parallel.p - 1 {
            params += m.v * m.h / t + m.h; // LM head + final norm
        }
        params
    }

    /// Weight + gradient + optimizer bytes on one device.
    pub fn weight_opt_bytes(&self, stage: u64) -> u64 {
        self.params_per_device(stage) * BYTES_PER_PARAM
    }

    /// Activation bytes one microbatch pins on one device of `stage`
    /// while it waits for its backward pass (the BPipe-evictable stash).
    pub fn activation_bytes_per_microbatch(&self, _stage: u64) -> u64 {
        let m = &self.e.model;
        let b = self.e.parallel.microbatch as f64;
        let t = self.e.parallel.t as f64;
        let (s, h, a) = (m.s as f64, m.h as f64, m.a as f64);
        let factor = match self.e.attention {
            // full activations: keep the 5·a·s/h softmax/score term
            AttentionMethod::None => ACT_FACTOR_BASE + 5.0 * a * s / h,
            // selective recompute / flash: score tensor never stashed
            AttentionMethod::Recompute | AttentionMethod::FlashAttn2 => ACT_FACTOR_BASE,
        };
        (self.layers_per_stage() as f64 * s * b * h * factor / t) as u64
    }

    /// Peak bytes on one device of `stage` holding `in_flight` stashes.
    pub fn peak_bytes(&self, stage: u64, in_flight: u64) -> u64 {
        self.weight_opt_bytes(stage)
            + in_flight * self.activation_bytes_per_microbatch(stage)
            + self.e.cluster.reserved_bytes
    }

    /// Peak bytes at `stage` under plain 1F1B.
    pub fn peak_bytes_1f1b(&self, stage: u64) -> u64 {
        let m = self.e.parallel.num_microbatches();
        self.peak_bytes(stage, one_f_one_b_in_flight(self.e.parallel.p, stage, m))
    }

    /// Peak bytes at `stage` under BPipe.  An acceptor stage `p−1−x`
    /// additionally hosts the stashes its evictor partner `x` pushed out:
    /// `(p−x) − bound` of them, bringing both sides to ≤ the bound (the
    /// balancing property the technique is named for).
    pub fn peak_bytes_bpipe(&self, stage: u64) -> u64 {
        let p = self.e.parallel.p;
        let m = self.e.parallel.num_microbatches();
        let natural = one_f_one_b_in_flight(p, stage, m);
        let bound = bpipe_bound(p).min(m);
        let partner = p - 1 - stage;
        let in_flight = if natural > bound {
            bound // evictor: BPipe caps it
        } else {
            // acceptor: own stashes + partner's overflow
            let partner_natural = one_f_one_b_in_flight(p, partner, m);
            natural + partner_natural.saturating_sub(bound)
        };
        self.peak_bytes(stage, in_flight)
    }

    /// Does the configuration fit on every device?
    pub fn fits(&self, bpipe: bool) -> bool {
        self.max_peak_bytes(bpipe) <= self.e.cluster.hbm_bytes
    }

    /// Highest per-device peak across stages.
    pub fn max_peak_bytes(&self, bpipe: bool) -> u64 {
        (0..self.e.parallel.p)
            .map(|s| {
                if bpipe {
                    self.peak_bytes_bpipe(s)
                } else {
                    self.peak_bytes_1f1b(s)
                }
            })
            .max()
            .unwrap()
    }

    /// Per-stage peak memory profile (GiB), for the memory-imbalance
    /// example and reports.
    pub fn profile_gib(&self, bpipe: bool) -> Vec<f64> {
        (0..self.e.parallel.p)
            .map(|s| {
                let b = if bpipe {
                    self.peak_bytes_bpipe(s)
                } else {
                    self.peak_bytes_1f1b(s)
                };
                b as f64 / (1u64 << 30) as f64
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{paper_experiment, paper_experiments};

    #[test]
    fn bpipe_bound_formula() {
        assert_eq!(bpipe_bound(4), 3);
        assert_eq!(bpipe_bound(8), 5);
        assert_eq!(bpipe_bound(16), 9);
        assert_eq!(bpipe_bound(7), 5); // ceil(9/2)
    }

    #[test]
    fn in_flight_monotone_decreasing_in_stage() {
        for s in 0..8 {
            assert_eq!(one_f_one_b_in_flight(8, s, 64), 8 - s);
        }
        // few microbatches clip it
        assert_eq!(one_f_one_b_in_flight(8, 0, 3), 3);
    }

    /// The paper's Table-3 feasibility pattern must emerge from the
    /// memory model: every listed experiment fits in 80 GiB as run, and
    /// the BPipe rows would NOT fit without BPipe.
    #[test]
    fn paper_feasibility_pattern() {
        for e in paper_experiments() {
            let mm = MemoryModel::new(&e);
            assert!(
                mm.fits(e.bpipe),
                "exp {:?} should fit as configured: peak {:.1} GiB",
                e.id,
                mm.max_peak_bytes(e.bpipe) as f64 / (1 << 30) as f64
            );
            if e.bpipe {
                assert!(
                    !mm.fits(false),
                    "exp {:?} should OOM without BPipe (that's why BPipe is on)",
                    e.id
                );
            }
        }
    }

    /// The next-larger microbatch must OOM even WITH BPipe for the rows
    /// where the paper stopped (BPipe rows are at the BPipe-enabled max).
    #[test]
    fn bpipe_rows_are_at_the_limit() {
        for id in [3u32, 8] {
            let mut e = paper_experiment(id).unwrap();
            e.parallel.microbatch *= 2;
            let mm = MemoryModel::new(&e);
            assert!(!mm.fits(true), "exp {id} with 2b should OOM even with BPipe");
        }
    }

    #[test]
    fn memory_imbalance_shape() {
        let e = paper_experiment(7).unwrap();
        let mm = MemoryModel::new(&e);
        let prof = mm.profile_gib(false);
        // monotone non-increasing activation pressure across stages …
        for w in prof.windows(2) {
            // (last stage has the LM head weights, allow it to bump up)
            if w[1] > w[0] {
                assert!(w[1] - w[0] < 3.0, "only the head stage may bump: {prof:?}");
            }
        }
        // … and BPipe flattens it
        let prof_b = mm.profile_gib(true);
        let spread = |v: &[f64]| {
            v.iter().cloned().fold(f64::MIN, f64::max)
                - v.iter().cloned().fold(f64::MAX, f64::min)
        };
        assert!(spread(&prof_b) < spread(&prof));
    }

    #[test]
    fn bpipe_balances_to_bound() {
        let e = paper_experiment(8).unwrap();
        let mm = MemoryModel::new(&e);
        let p = e.parallel.p;
        for s in 0..p {
            let act = mm.activation_bytes_per_microbatch(s);
            let peak = mm.peak_bytes_bpipe(s) - mm.weight_opt_bytes(s) - e.cluster.reserved_bytes;
            assert!(
                peak / act <= bpipe_bound(p),
                "stage {s}: {} stashes > bound {}",
                peak / act,
                bpipe_bound(p)
            );
        }
    }

    #[test]
    fn evictor_acceptor_conservation() {
        // total stashes with BPipe == total without (nothing is dropped)
        let e = paper_experiment(8).unwrap();
        let mm = MemoryModel::new(&e);
        let p = e.parallel.p;
        let m = e.parallel.num_microbatches();
        let act = mm.activation_bytes_per_microbatch(0);
        let total_1f1b: u64 = (0..p).map(|s| one_f_one_b_in_flight(p, s, m)).sum();
        let total_bpipe: u64 = (0..p)
            .map(|s| {
                (mm.peak_bytes_bpipe(s) - mm.weight_opt_bytes(s) - e.cluster.reserved_bytes) / act
            })
            .sum();
        assert_eq!(total_1f1b, total_bpipe);
    }

    #[test]
    fn weight_bytes_example_gpt3() {
        // GPT-3 96B, t=4, p=8: ~54 GiB of weights+opt on a mid-stage device
        let e = paper_experiment(7).unwrap();
        let mm = MemoryModel::new(&e);
        let gib = mm.weight_opt_bytes(3) as f64 / (1 << 30) as f64;
        assert!((45.0..60.0).contains(&gib), "{gib}");
    }
}
