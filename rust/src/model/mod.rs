//! Analytical model of the transformer workload: FLOPs (paper Eq. 1) and
//! device-memory footprints (weights/optimizer + activations).
//!
//! These closed forms drive both the simulator's cost model ([`crate::sim`])
//! and the feasibility analysis (which microbatch sizes OOM without BPipe —
//! the crux of Table 3).

pub mod flops;
pub mod memory;

pub use flops::*;
pub use memory::*;
