//! `report::figures` — self-contained SVG charts + the replication
//! report (`bpipe report`).
//!
//! The renderers consume [`SweepOutcome`]s **directly** (no CSV
//! round-trip): [`render_replication_report`] turns one experiment's
//! ranking grid + bound-sensitivity grid into a single markdown document
//! with embedded SVG figures —
//!
//! * **Figure 1** — per-stage peak memory, baseline vs rebalanced vs
//!   per-stage-bounds vs W-shaped, against the HBM limit (the paper's
//!   Figure-1 memory story, generalized across scenarios);
//! * **Figure 2** — throughput (MFU) of every feasible scenario × layout
//!   cell, ranked (the paper's Figure-2/Table-3 performance story);
//! * **Figure 3** — the bound × {MFU, load-stall} sensitivity frontier
//!   (two charts; where tighter memory starts costing throughput);
//! * **Figure 4** — the found-vs-family frontier: which cells survive a
//!   tightened per-stage HBM cap, the hand-written families against the
//!   [`crate::schedule::synthesize`]d schedule;
//! * an **estimator-vs-DES** section quantifying the paper's §4
//!   performance-estimation method (Eqs. 3/4) against the simulator.
//!
//! Every figure ships with its data as a markdown table next to the
//! chart, so the report stays readable where inline SVG is stripped
//! (and the low-contrast palette slots always have a text fallback).
//! Charts use a fixed categorical palette assigned **per schedule
//! family** (color follows the entity across every figure), thin marks,
//! rounded data-ends, and neutral ink for all text.  Neutrals (surface,
//! ink, grid, the HBM-limit red, marker halos) are CSS classes with a
//! `prefers-color-scheme: dark` variant in each figure's `<style>`
//! block, so the same SVG reads correctly in light and dark viewers;
//! series hues are scheme-stable.

use crate::config::{paper_experiments, ExperimentConfig};
use crate::estimator::{self, StageMeasurement};
use crate::report::Table;
use crate::sim::{self, CostModel, SweepOutcome};

/// Categorical palette (reference data-viz palette, slots in documented
/// order — validated as a set on the adjacent pairlist; the hues hold
/// ≥3:1 contrast against both surface colors, so series fills stay
/// literal while the neutrals swap per scheme).
const PALETTE: [&str; 5] = ["#2a78d6", "#eb6834", "#1baf7a", "#eda100", "#e87ba4"];
/// Status red, reserved for the HBM-limit line (never a series color).
const LIMIT_COLOR: &str = "#e34948";
const INK: &str = "#0b0b0b";
const INK_MUTED: &str = "#52514e";
const GRID: &str = "#e4e3df";
const SURFACE: &str = "#fcfcfb";
/// Dark-scheme counterparts, applied via `prefers-color-scheme: dark`
/// (every neutral is expressed as a CSS class, so one `<style>` block
/// per figure retints ink/grid/surface/limit without touching marks).
const DARK_LIMIT_COLOR: &str = "#ff6e6d";
const DARK_INK: &str = "#f2f1ed";
const DARK_INK_MUTED: &str = "#b6b4ae";
const DARK_GRID: &str = "#383632";
const DARK_SURFACE: &str = "#161512";
const FONT: &str = "font-family=\"system-ui,sans-serif\"";

/// The per-figure stylesheet: light-scheme neutrals plus the dark-mode
/// media query (pinned by `tests/report_snapshot.rs`).
fn style_block() -> String {
    format!(
        "<style>\
         .surface{{fill:{SURFACE}}}.ink{{fill:{INK}}}.muted{{fill:{INK_MUTED}}}\
         .grid{{stroke:{GRID}}}.axis{{stroke:{INK_MUTED}}}\
         .limit{{stroke:{LIMIT_COLOR}}}.limit-ink{{fill:{LIMIT_COLOR}}}\
         .marker{{stroke:{SURFACE}}}\
         @media (prefers-color-scheme: dark){{\
         .surface{{fill:{DARK_SURFACE}}}.ink{{fill:{DARK_INK}}}.muted{{fill:{DARK_INK_MUTED}}}\
         .grid{{stroke:{DARK_GRID}}}.axis{{stroke:{DARK_INK_MUTED}}}\
         .limit{{stroke:{DARK_LIMIT_COLOR}}}.limit-ink{{fill:{DARK_LIMIT_COLOR}}}\
         .marker{{stroke:{DARK_SURFACE}}}}}\
         </style>"
    )
}

/// Palette slot of a scenario: color follows the schedule *family*, so
/// "1F1B", "1F1B+rebalance" and "1F1B+stage-bounds" share a hue across
/// every figure of the report.
pub fn family_slot(scenario: &str) -> usize {
    let family = scenario.split('+').next().unwrap_or(scenario);
    match family {
        "1F1B" => 0,
        "GPipe" => 1,
        "interleaved" => 2,
        "V-shaped" => 3,
        _ => 4, // W-shaped / zig-zag
    }
}

fn esc(s: &str) -> String {
    s.replace('&', "&amp;")
        .replace('<', "&lt;")
        .replace('>', "&gt;")
        .replace('"', "&quot;")
}

/// "Nice" axis ticks: 0..=max covered by steps of 1/2/5 × 10^k.
fn ticks(max: f64, target: usize) -> Vec<f64> {
    if !(max > 0.0) {
        return vec![0.0, 1.0];
    }
    let raw = max / target.max(1) as f64;
    let mag = 10f64.powf(raw.log10().floor());
    let step = [1.0, 2.0, 5.0, 10.0]
        .iter()
        .map(|m| m * mag)
        .find(|&s| s >= raw)
        .unwrap_or(10.0 * mag);
    let mut out = Vec::new();
    let mut t = 0.0;
    while t <= max + 1e-9 {
        out.push(t);
        t += step;
    }
    out.push(t);
    out
}

fn fmt_tick(x: f64) -> String {
    if x.fract().abs() < 1e-9 {
        format!("{x:.0}")
    } else {
        format!("{x:.1}")
    }
}

/// A bar anchored to the baseline with a rounded data-end (top).
fn bar_path(x: f64, y: f64, w: f64, h: f64) -> String {
    let r = 4f64.min(w / 2.0).min(h);
    format!(
        "M{:.1} {:.1} L{:.1} {:.1} Q{:.1} {:.1} {:.1} {:.1} L{:.1} {:.1} Q{:.1} {:.1} {:.1} {:.1} L{:.1} {:.1} Z",
        x, y + h,                    // baseline left
        x, y + r,                    // up the left edge
        x, y, x + r, y,              // round top-left
        x + w - r, y,                // across the top
        x + w, y, x + w, y + r,      // round top-right
        x + w, y + h,                // down to baseline
    )
}

/// One series of a grouped-bar or line chart.
pub struct Series {
    pub name: String,
    /// palette slot (see [`family_slot`])
    pub slot: usize,
    /// y value per x position; `None` = no mark (e.g. OOM point dropped)
    pub values: Vec<Option<f64>>,
}

fn legend(series: &[Series], x: f64, y: f64) -> String {
    let mut out = String::new();
    let mut cx = x;
    for s in series {
        out.push_str(&format!(
            "<rect x=\"{cx:.0}\" y=\"{:.0}\" width=\"10\" height=\"10\" rx=\"2\" fill=\"{}\"/>",
            y - 9.0,
            PALETTE[s.slot % PALETTE.len()]
        ));
        out.push_str(&format!(
            "<text x=\"{:.0}\" y=\"{y:.0}\" {FONT} font-size=\"11\" class=\"muted\">{}</text>",
            cx + 14.0,
            esc(&s.name)
        ));
        cx += 14.0 + 6.5 * s.name.len() as f64 + 18.0;
    }
    out
}

fn frame(w: u32, h: u32, title: &str, body: &str) -> String {
    format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{w}\" height=\"{h}\" viewBox=\"0 0 {w} {h}\" role=\"img\" aria-label=\"{}\">\n{}\n<rect width=\"{w}\" height=\"{h}\" class=\"surface\"/>\n<text x=\"16\" y=\"22\" {FONT} font-size=\"13\" font-weight=\"600\" class=\"ink\">{}</text>\n{body}</svg>",
        esc(title),
        style_block(),
        esc(title)
    )
}

/// Grouped vertical bars: one group per x label, one bar per series,
/// with an optional horizontal limit line (status color + label).
pub fn svg_grouped_bars(
    title: &str,
    y_label: &str,
    x_labels: &[String],
    series: &[Series],
    limit: Option<(f64, &str)>,
) -> String {
    let (w, h) = (760u32, 340u32);
    let (ml, mr, mt, mb) = (56.0, 16.0, 48.0, 40.0);
    let pw = w as f64 - ml - mr;
    let ph = h as f64 - mt - mb;
    let data_max = series
        .iter()
        .flat_map(|s| s.values.iter().flatten())
        .fold(0f64, |a, &b| a.max(b))
        .max(limit.map(|(v, _)| v).unwrap_or(0.0));
    let tks = ticks(data_max * 1.05, 5);
    let y_max = *tks.last().unwrap();
    let ys = |v: f64| mt + ph - v / y_max * ph;

    let mut body = String::new();
    // grid + y axis
    for t in &tks {
        let y = ys(*t);
        body.push_str(&format!(
            "<line x1=\"{ml}\" y1=\"{y:.1}\" x2=\"{:.1}\" y2=\"{y:.1}\" class=\"grid\" stroke-width=\"1\"/>",
            ml + pw
        ));
        body.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\" {FONT} font-size=\"10\" text-anchor=\"end\" class=\"muted\">{}</text>",
            ml - 6.0,
            y + 3.0,
            fmt_tick(*t)
        ));
    }
    body.push_str(&format!(
        "<text x=\"12\" y=\"{:.0}\" {FONT} font-size=\"10\" class=\"muted\" transform=\"rotate(-90 12 {:.0})\" text-anchor=\"middle\">{}</text>",
        mt + ph / 2.0,
        mt + ph / 2.0,
        esc(y_label)
    ));
    // bars: 2px surface gap between adjacent bars
    let nx = x_labels.len().max(1) as f64;
    let ns = series.len().max(1) as f64;
    let group_w = pw / nx;
    let bar_w = ((group_w * 0.82) / ns - 2.0).max(2.0);
    for (xi, xl) in x_labels.iter().enumerate() {
        let gx = ml + xi as f64 * group_w + group_w * 0.09;
        for (si, s) in series.iter().enumerate() {
            if let Some(Some(v)) = s.values.get(xi) {
                let x = gx + si as f64 * (bar_w + 2.0);
                let y = ys(*v);
                body.push_str(&format!(
                    "<path d=\"{}\" fill=\"{}\"/>",
                    bar_path(x, y, bar_w, mt + ph - y),
                    PALETTE[s.slot % PALETTE.len()]
                ));
            }
        }
        body.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\" {FONT} font-size=\"10\" text-anchor=\"middle\" class=\"muted\">{}</text>",
            ml + (xi as f64 + 0.5) * group_w,
            mt + ph + 16.0,
            esc(xl)
        ));
    }
    // baseline
    body.push_str(&format!(
        "<line x1=\"{ml}\" y1=\"{:.1}\" x2=\"{:.1}\" y2=\"{:.1}\" class=\"axis\" stroke-width=\"1\"/>",
        mt + ph,
        ml + pw,
        mt + ph
    ));
    if let Some((v, label)) = limit {
        let y = ys(v);
        body.push_str(&format!(
            "<line x1=\"{ml}\" y1=\"{y:.1}\" x2=\"{:.1}\" y2=\"{y:.1}\" class=\"limit\" stroke-width=\"1.5\" stroke-dasharray=\"6 3\"/>",
            ml + pw
        ));
        body.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\" {FONT} font-size=\"10\" text-anchor=\"end\" class=\"limit-ink\">{}</text>",
            ml + pw - 4.0,
            y - 4.0,
            esc(label)
        ));
    }
    body.push_str(&legend(series, ml, 38.0));
    frame(w, h, title, &body)
}

/// Multi-series line chart over a shared numeric x axis (2px lines,
/// 8px markers); `None` values break the line (dropped/OOM points).
pub fn svg_multi_line(
    title: &str,
    x_label: &str,
    y_label: &str,
    xs: &[f64],
    series: &[Series],
) -> String {
    let (w, h) = (760u32, 340u32);
    let (ml, mr, mt, mb) = (56.0, 16.0, 48.0, 44.0);
    let pw = w as f64 - ml - mr;
    let ph = h as f64 - mt - mb;
    let data_max = series
        .iter()
        .flat_map(|s| s.values.iter().flatten())
        .fold(0f64, |a, &b| a.max(b));
    let tks = ticks(data_max * 1.05, 5);
    let y_max = *tks.last().unwrap();
    let x_lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let x_hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let xr = (x_hi - x_lo).max(1e-9);
    let xp = |x: f64| ml + (x - x_lo) / xr * pw;
    let yp = |v: f64| mt + ph - v / y_max * ph;

    let mut body = String::new();
    for t in &tks {
        let y = yp(*t);
        body.push_str(&format!(
            "<line x1=\"{ml}\" y1=\"{y:.1}\" x2=\"{:.1}\" y2=\"{y:.1}\" class=\"grid\" stroke-width=\"1\"/>",
            ml + pw
        ));
        body.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\" {FONT} font-size=\"10\" text-anchor=\"end\" class=\"muted\">{}</text>",
            ml - 6.0,
            y + 3.0,
            fmt_tick(*t)
        ));
    }
    // x tick labels: thin to nice steps (the bounds sweep can span 60+
    // integer x positions — labeling each would collide)
    let x_ticks: Vec<f64> = if xs.len() <= 12 {
        xs.to_vec()
    } else {
        ticks(x_hi, 10).into_iter().filter(|&t| t >= x_lo - 1e-9 && t <= x_hi + 1e-9).collect()
    };
    for x in &x_ticks {
        body.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\" {FONT} font-size=\"10\" text-anchor=\"middle\" class=\"muted\">{}</text>",
            xp(*x),
            mt + ph + 16.0,
            fmt_tick(*x)
        ));
    }
    body.push_str(&format!(
        "<text x=\"{:.1}\" y=\"{:.1}\" {FONT} font-size=\"10\" text-anchor=\"middle\" class=\"muted\">{}</text>",
        ml + pw / 2.0,
        mt + ph + 32.0,
        esc(x_label)
    ));
    body.push_str(&format!(
        "<text x=\"12\" y=\"{:.0}\" {FONT} font-size=\"10\" class=\"muted\" transform=\"rotate(-90 12 {:.0})\" text-anchor=\"middle\">{}</text>",
        mt + ph / 2.0,
        mt + ph / 2.0,
        esc(y_label)
    ));
    body.push_str(&format!(
        "<line x1=\"{ml}\" y1=\"{:.1}\" x2=\"{:.1}\" y2=\"{:.1}\" class=\"axis\" stroke-width=\"1\"/>",
        mt + ph,
        ml + pw,
        mt + ph
    ));
    for s in series {
        let color = PALETTE[s.slot % PALETTE.len()];
        // polyline segments, broken at None
        let mut seg: Vec<String> = Vec::new();
        let mut flush = |seg: &mut Vec<String>, body: &mut String| {
            if seg.len() >= 2 {
                body.push_str(&format!(
                    "<polyline points=\"{}\" fill=\"none\" stroke=\"{color}\" stroke-width=\"2\" stroke-linejoin=\"round\"/>",
                    seg.join(" ")
                ));
            }
            seg.clear();
        };
        for (i, v) in s.values.iter().enumerate() {
            match v {
                Some(v) => seg.push(format!("{:.1},{:.1}", xp(xs[i]), yp(*v))),
                None => flush(&mut seg, &mut body),
            }
        }
        flush(&mut seg, &mut body);
        for (i, v) in s.values.iter().enumerate() {
            if let Some(v) = v {
                body.push_str(&format!(
                    "<circle cx=\"{:.1}\" cy=\"{:.1}\" r=\"4\" fill=\"{color}\" class=\"marker\" stroke-width=\"2\"/>",
                    xp(xs[i]),
                    yp(*v)
                ));
            }
        }
    }
    body.push_str(&legend(series, ml, 38.0));
    frame(w, h, title, &body)
}

/// Ranked horizontal bars (one per row) with the value printed at the
/// bar end — Figure 2's MFU ranking.
pub fn svg_ranked_hbars(
    title: &str,
    x_label: &str,
    rows: &[(String, usize, f64)], // (label, palette slot, value)
) -> String {
    let row_h = 22.0;
    let (ml, mr, mt, mb) = (252.0, 52.0, 40.0, 36.0);
    let w = 760u32;
    let h = (mt + mb + row_h * rows.len() as f64).ceil() as u32;
    let pw = w as f64 - ml - mr;
    let data_max = rows.iter().fold(0f64, |a, r| a.max(r.2));
    let tks = ticks(data_max * 1.05, 5);
    let x_max = *tks.last().unwrap();
    let xp = |v: f64| ml + v / x_max * pw;

    let mut body = String::new();
    for t in &tks {
        let x = xp(*t);
        body.push_str(&format!(
            "<line x1=\"{x:.1}\" y1=\"{mt}\" x2=\"{x:.1}\" y2=\"{:.1}\" class=\"grid\" stroke-width=\"1\"/>",
            h as f64 - mb
        ));
        body.push_str(&format!(
            "<text x=\"{x:.1}\" y=\"{:.1}\" {FONT} font-size=\"10\" text-anchor=\"middle\" class=\"muted\">{}</text>",
            h as f64 - mb + 14.0,
            fmt_tick(*t)
        ));
    }
    body.push_str(&format!(
        "<text x=\"{:.1}\" y=\"{:.1}\" {FONT} font-size=\"10\" text-anchor=\"middle\" class=\"muted\">{}</text>",
        ml + pw / 2.0,
        h as f64 - 8.0,
        esc(x_label)
    ));
    for (i, (label, slot, v)) in rows.iter().enumerate() {
        let y = mt + i as f64 * row_h + 3.0;
        let bw = (xp(*v) - ml).max(1.0);
        body.push_str(&format!(
            "<rect x=\"{ml}\" y=\"{y:.1}\" width=\"{bw:.1}\" height=\"{:.1}\" rx=\"4\" fill=\"{}\"/>",
            row_h - 8.0,
            PALETTE[slot % PALETTE.len()]
        ));
        body.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\" {FONT} font-size=\"11\" text-anchor=\"end\" class=\"ink\">{}</text>",
            ml - 8.0,
            y + row_h / 2.0 + 1.0,
            esc(label)
        ));
        body.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\" {FONT} font-size=\"10\" class=\"muted\">{:.1}</text>",
            ml + bw + 5.0,
            y + row_h / 2.0 + 1.0,
            v
        ));
    }
    frame(w, h, title, &body)
}

// ------------------------------------------------------------------ report

/// The scenarios Figure 1 contrasts (memory story): baseline, uniform
/// rebalance, per-stage bounds, and the W placement.
const FIG1_SCENARIOS: [&str; 4] =
    ["1F1B", "1F1B+rebalance", "1F1B+stage-bounds", "W-shaped"];

/// Figure 1: per-stage peak memory of the selected scenarios on the
/// pair-adjacent layout, with the HBM limit.  Returns `(svg, table)`.
pub fn render_fig1_memory(e: &ExperimentConfig, ranking: &[SweepOutcome]) -> (String, String) {
    let p = e.parallel.p;
    let hbm_gib = e.cluster.hbm_bytes as f64 / (1u64 << 30) as f64;
    let x_labels: Vec<String> = (0..p).map(|s| format!("stage {s}")).collect();
    let mut series = Vec::new();
    let mut header: Vec<String> = vec!["scenario".to_string()];
    header.extend(x_labels.iter().cloned());
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs);
    for name in FIG1_SCENARIOS {
        let Some(o) = ranking
            .iter()
            .find(|o| o.scenario == name && o.layout == "pair-adjacent")
        else {
            continue;
        };
        series.push(Series {
            name: name.to_string(),
            slot: family_slot(name),
            values: o.per_stage_mem_gib.iter().map(|&g| Some(g)).collect(),
        });
        table.push(
            std::iter::once(name.to_string())
                .chain(o.per_stage_mem_gib.iter().map(|g| format!("{g:.1}")))
                .collect(),
        );
    }
    let limit_label = format!("HBM {hbm_gib:.0} GiB");
    let svg = svg_grouped_bars(
        &format!("Per-stage peak memory — experiment {}", exp_tag(e)),
        "peak memory (GiB)",
        &x_labels,
        &series,
        Some((hbm_gib, limit_label.as_str())),
    );
    (svg, table.render())
}

/// Figure 2: MFU of every *feasible* ranking cell, best first.
pub fn render_fig2_throughput(e: &ExperimentConfig, ranking: &[SweepOutcome]) -> String {
    let mut rows: Vec<(String, usize, f64)> = ranking
        .iter()
        .filter(|o| o.oom_stage.is_none() && o.mfu_pct.is_finite())
        .map(|o| {
            (
                format!("{} · {}", o.scenario, o.layout),
                family_slot(o.scenario),
                o.mfu_pct,
            )
        })
        .collect();
    rows.sort_by(|a, b| b.2.total_cmp(&a.2));
    svg_ranked_hbars(
        &format!("Throughput by pipeline configuration — experiment {}", exp_tag(e)),
        "model FLOPs utilization (%)",
        &rows,
    )
}

/// Figure 3: MFU and load-stall vs the uniform rebalance bound, one
/// line per schedule family (pair-adjacent cells of the bounds grid).
/// Returns `(mfu_svg, stall_svg)`.
pub fn render_fig3_frontier(e: &ExperimentConfig, bounds: &[SweepOutcome]) -> (String, String) {
    let cells: Vec<&SweepOutcome> = bounds
        .iter()
        .filter(|o| o.layout == "pair-adjacent" && o.bound.is_some())
        .collect();
    let mut ks: Vec<u64> = cells.iter().filter_map(|o| o.bound).collect();
    ks.sort_unstable();
    ks.dedup();
    let xs: Vec<f64> = ks.iter().map(|&k| k as f64).collect();
    let mut scenarios: Vec<&str> = cells.iter().map(|o| o.scenario).collect();
    scenarios.sort_unstable();
    scenarios.dedup();
    let series_for = |metric: &dyn Fn(&SweepOutcome) -> Option<f64>| -> Vec<Series> {
        scenarios
            .iter()
            .map(|name| Series {
                name: name.to_string(),
                slot: family_slot(name),
                values: ks
                    .iter()
                    .map(|&k| {
                        cells
                            .iter()
                            .find(|o| o.scenario == *name && o.bound == Some(k))
                            .and_then(|o| metric(o))
                    })
                    .collect(),
            })
            .collect()
    };
    let mfu = svg_multi_line(
        &format!("MFU vs rebalance bound — experiment {}", exp_tag(e)),
        "uniform rebalance bound k (stashes)",
        "MFU (%), OOM points dropped",
        &xs,
        &series_for(&|o: &SweepOutcome| {
            (o.oom_stage.is_none() && o.mfu_pct.is_finite()).then_some(o.mfu_pct)
        }),
    );
    let stall = svg_multi_line(
        &format!("Load stall vs rebalance bound — experiment {}", exp_tag(e)),
        "uniform rebalance bound k (stashes)",
        "backward stall on loads (ms)",
        &xs,
        &series_for(&|o: &SweepOutcome| o.load_stall_ms.is_finite().then_some(o.load_stall_ms)),
    );
    (mfu, stall)
}

/// Figure 4: the found-vs-family frontier — MFU of every cell that
/// stays feasible once per-stage HBM is capped at `cap_bytes`
/// ([`sim::frontier_outcomes`]), best first.  At paper scale the
/// hand-written families all OOM under the tightened cap and the only
/// surviving bar is the `"synthesized"` schedule — the search's
/// existence proof that the family set leaves feasible schedules on the
/// table.
pub fn render_fig4_found_vs_family(
    e: &ExperimentConfig,
    cap_bytes: u64,
    frontier: &[SweepOutcome],
) -> String {
    let gib = (1u64 << 30) as f64;
    let oom = frontier.iter().filter(|o| o.oom_stage.is_some()).count();
    let mut rows: Vec<(String, usize, f64)> = frontier
        .iter()
        .filter(|o| o.oom_stage.is_none() && o.mfu_pct.is_finite())
        .map(|o| (o.scenario.to_string(), family_slot(o.scenario), o.mfu_pct))
        .collect();
    rows.sort_by(|a, b| b.2.total_cmp(&a.2));
    svg_ranked_hbars(
        &format!(
            "Found vs family at {:.0} GiB per stage — experiment {} ({oom}/{} family cells OOM)",
            cap_bytes as f64 / gib,
            exp_tag(e),
            frontier.len().saturating_sub(1)
        ),
        "model FLOPs utilization (%)",
        &rows,
    )
}

/// The estimator-vs-DES tables: Eq. 3 whole-model MFU per experiment and
/// Eq. 4 speedup per microbatch transition, each against the simulator.
/// Returns `(eq3_table, eq4_table)` as rendered text tables.
pub fn render_estimator_tables() -> (String, String) {
    struct Row {
        e: ExperimentConfig,
        stage_mfu: f64,
        eq3_pct: f64,
        des_pct: f64,
    }
    let rows: Vec<Row> = paper_experiments()
        .into_iter()
        .map(|e| {
            let stage_mfu = CostModel::new(&e).single_stage_mfu();
            let eq3_pct = estimator::model_mfu_from_stage(&e, stage_mfu) * 100.0;
            let des_pct = sim::simulate_experiment(&e).mfu_pct();
            Row { e, stage_mfu, eq3_pct, des_pct }
        })
        .collect();

    let mut t3 = Table::new(&[
        "exp", "model", "b", "attention", "stage MFU %", "Eq.3 MFU %", "DES MFU %", "err %",
    ]);
    for r in &rows {
        t3.push(vec![
            r.e.id.map(|i| format!("({i})")).unwrap_or_default(),
            r.e.model.name.clone(),
            r.e.parallel.microbatch.to_string(),
            r.e.attention.label().into(),
            format!("{:.1}", r.stage_mfu * 100.0),
            format!("{:.1}", r.eq3_pct),
            format!("{:.1}", r.des_pct),
            format!("{:+.1}", (r.eq3_pct - r.des_pct) / r.des_pct * 100.0),
        ]);
    }

    // Eq. 4 transitions: same (model, attention) pairs at rising b — the
    // paper's §4 "should I raise the microbatch via BPipe?" question
    let mut t4 = Table::new(&[
        "transition", "model", "b", "Eq.4 speedup", "DES speedup", "err %",
    ]);
    for (x, y) in [(2usize, 3usize), (5, 6), (7, 8), (9, 10)] {
        let (rx, ry) = (&rows[x - 1], &rows[y - 1]);
        let eq4 = estimator::predicted_speedup(
            rx.e.parallel.global_batch,
            rx.e.parallel.p,
            StageMeasurement { b: rx.e.parallel.microbatch, mfu_stage: rx.stage_mfu },
            StageMeasurement { b: ry.e.parallel.microbatch, mfu_stage: ry.stage_mfu },
        );
        let des = ry.des_pct / rx.des_pct;
        t4.push(vec![
            format!("({x})→({y})"),
            rx.e.model.name.clone(),
            format!("{}→{}", rx.e.parallel.microbatch, ry.e.parallel.microbatch),
            format!("{eq4:.3}"),
            format!("{des:.3}"),
            format!("{:+.1}", (eq4 - des) / des * 100.0),
        ]);
    }
    (t3.render(), t4.render())
}

fn exp_tag(e: &ExperimentConfig) -> String {
    e.id.map(|i| format!("({i})")).unwrap_or_else(|| e.model.name.clone())
}

/// Assemble the full replication report from already-simulated grids.
/// `ranking` = the experiment's scenario × layout cells; `bounds` = its
/// bound-sensitivity cells (pair-adjacent is enough).
pub fn render_replication_report(
    e: &ExperimentConfig,
    ranking: &[SweepOutcome],
    bounds: &[SweepOutcome],
    frontier_cap: u64,
    frontier: &[SweepOutcome],
) -> String {
    let (fig1, fig1_table) = render_fig1_memory(e, ranking);
    let fig2 = render_fig2_throughput(e, ranking);
    let (fig3_mfu, fig3_stall) = render_fig3_frontier(e, bounds);
    let fig4 = render_fig4_found_vs_family(e, frontier_cap, frontier);
    let (eq3, eq4) = render_estimator_tables();

    let mut md = String::new();
    md.push_str("# BPipe replication report\n\n");
    md.push_str(&format!(
        "Experiment {}: `{}`\n\n\
         Generated by `bpipe report` from {} ranking cells and {} bound-sensitivity \
         cells simulated in-process (no CSV round-trip).\n\n",
        exp_tag(e),
        e.summary(),
        ranking.len(),
        bounds.len()
    ));

    md.push_str("## Figure 1 — per-stage peak memory\n\n");
    md.push_str(&fig1);
    md.push_str("\n\n");
    md.push_str(
        "Plain 1F1B piles stashes on the front stages; the uniform rebalance flattens \
         them to the pair mean; capacity-derived per-stage bounds flatten only what \
         must move (fewer transfers); the W-shaped placement balances by construction \
         but holds four live chunks per device.  Data (GiB):\n\n",
    );
    md.push_str("```text\n");
    md.push_str(&fig1_table);
    md.push_str("```\n\n");

    md.push_str("## Figure 2 — throughput by scenario\n\n");
    md.push_str(&fig2);
    md.push_str("\n\nFull ranking (OOM cells at the bottom):\n\n```text\n");
    md.push_str(&sim::render_sweep(ranking));
    md.push_str("```\n\n");

    md.push_str("## Figure 3 — bound-sensitivity frontier\n\n");
    md.push_str(&fig3_mfu);
    md.push_str("\n\n");
    md.push_str(&fig3_stall);
    md.push_str("\n\nPer-scenario frontier (knee = tightest bound within 0.5% of best MFU):\n\n```text\n");
    md.push_str(&sim::render_bound_frontier(bounds));
    md.push_str("```\n\n");

    md.push_str("## Figure 4 — found-vs-family frontier (tight HBM)\n\n");
    md.push_str(&fig4);
    md.push_str(&format!(
        "\n\nPer-device HBM capped at {:.0} GiB (90% of the configured device): every \
         hand-written family cell OOMs or survives as charted above, while \
         `schedule::synthesize` searches warmup-depth schedules under the same \
         per-stage caps and keeps whatever fits.  All frontier cells (OOM at the \
         bottom; the synthesized row carries its stash budgets in the k column):\n\n",
        frontier_cap as f64 / (1u64 << 30) as f64
    ));
    md.push_str("```text\n");
    md.push_str(&sim::render_sweep(frontier));
    md.push_str("```\n\n");

    md.push_str("## Estimator vs DES\n\n");
    md.push_str(
        "The paper's §4 method estimates whole-model MFU from one single-stage \
         measurement (Eq. 3) and the BPipe speedup from two (Eq. 4).  Both against \
         the discrete-event simulator:\n\n",
    );
    md.push_str("```text\n");
    md.push_str(&eq3);
    md.push_str("```\n\n```text\n");
    md.push_str(&eq4);
    md.push_str("```\n\n");
    md.push_str(
        "Eq. 4 is an upper bound (it ignores BPipe's own overhead), so positive \
         errors on BPipe transitions are expected; the sign of each prediction — \
         worth it for GPT-3, not for LLaMA+flash — is the paper's §4 conclusion.\n\n",
    );

    md.push_str("---\n\nReproduce: `bpipe report");
    if let Some(id) = e.id {
        md.push_str(&format!(" --experiment {id}"));
    }
    md.push_str("` · raw cells: `bpipe sweep --csv cells.csv` / `bpipe sweep --bounds --json cells.json`\n");
    md
}

/// Simulate the grids for one experiment and render its replication
/// report (the `bpipe report` entry point).  `v` = interleaved chunk
/// count; `threads` = sweep parallelism (0 = auto).
pub fn replication_report(e: &ExperimentConfig, v: u64, threads: usize) -> String {
    let ranking = sim::sweep(sim::experiment_tasks(e, v), threads);
    let bound_tasks: Vec<sim::SweepTask> = sim::bound_sensitivity_tasks(e, v)
        .into_iter()
        .filter(|t| t.layout.name == "pair-adjacent")
        .collect();
    let bound_outs = sim::sweep(bound_tasks, threads);
    let (frontier_cap, frontier) = sim::frontier_outcomes(e, v, threads);
    render_replication_report(e, &ranking, &bound_outs, frontier_cap, &frontier)
}

/// The index table heading `bpipe report --all`: one row per Table-3
/// experiment, linking to its section below.
fn render_report_index(experiments: &[ExperimentConfig]) -> String {
    let mut md = String::from(
        "| exp | model | b | BPipe (paper) | paper MFU % |\n|---|---|---|---|---|\n",
    );
    for e in experiments {
        let id = e.id.expect("Table-3 experiments are numbered");
        md.push_str(&format!(
            "| [({id})](#experiment-{id}) | {} | {} | {} | {:.1} |\n",
            e.model.name,
            e.parallel.microbatch,
            if e.bpipe { "yes" } else { "no" },
            crate::config::paper_table3_mfu(id).unwrap_or(f64::NAN),
        ));
    }
    md
}

/// `bpipe report --all`: every Table-3 experiment through the full
/// per-experiment pipeline, concatenated into one indexed markdown
/// document (each per-experiment report demoted one heading level under
/// its own `## Experiment (i)` section).
pub fn replication_report_all(v: u64, threads: usize) -> String {
    let experiments = crate::config::paper_experiments();
    let mut md = String::new();
    md.push_str("# BPipe replication report — all Table-3 experiments\n\n");
    md.push_str(
        "Generated by `bpipe report --all`: every Table-3 row through the full \
         per-experiment pipeline (ranking grid, bound-sensitivity frontier, \
         found-vs-family frontier, estimator tables).\n\n## Index\n\n",
    );
    md.push_str(&render_report_index(&experiments));
    for e in &experiments {
        let one = replication_report(e, v, threads);
        // drop the single-experiment title and demote its sections so
        // the combined document keeps one H1 and a flat section tree
        let body = one
            .replacen("# BPipe replication report\n\n", "", 1)
            .replace("\n## ", "\n### ");
        md.push_str(&format!(
            "\n---\n\n## Experiment ({})\n\n",
            e.id.expect("Table-3 experiments are numbered")
        ));
        md.push_str(&body);
    }
    md
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_experiment;

    #[test]
    fn report_index_links_every_experiment() {
        let idx = render_report_index(&crate::config::paper_experiments());
        // header + separator + one row per experiment
        assert_eq!(idx.lines().count(), 2 + 10);
        for id in 1..=10 {
            assert!(idx.contains(&format!("[({id})](#experiment-{id})")), "exp {id}");
        }
        assert!(idx.contains("GPT-3 96B") && idx.contains("LLaMA 65B"));
    }

    #[test]
    fn ticks_are_nice_and_cover() {
        let t = ticks(87.0, 5);
        assert!(t.first() == Some(&0.0));
        assert!(*t.last().unwrap() >= 87.0);
        let step = t[1] - t[0];
        for w in t.windows(2) {
            assert!((w[1] - w[0] - step).abs() < 1e-9);
        }
    }

    #[test]
    fn family_slots_follow_the_entity() {
        assert_eq!(family_slot("1F1B"), family_slot("1F1B+rebalance"));
        assert_eq!(family_slot("1F1B"), family_slot("1F1B+stage-bounds"));
        assert_ne!(family_slot("1F1B"), family_slot("GPipe"));
        assert_eq!(family_slot("W-shaped+rebalance"), 4);
    }

    #[test]
    fn every_chart_is_scheme_adaptive() {
        // each chart kind carries exactly one stylesheet with the
        // dark-mode media query, and no neutral is left as a literal
        // fill/stroke outside it (series hues and limit/marker classes
        // excepted by construction)
        let bars = svg_grouped_bars(
            "t",
            "GiB",
            &["s0".into()],
            &[Series { name: "a".into(), slot: 0, values: vec![Some(1.0)] }],
            Some((3.0, "limit")),
        );
        let line = svg_multi_line(
            "t",
            "k",
            "MFU",
            &[1.0, 2.0],
            &[Series { name: "a".into(), slot: 0, values: vec![Some(1.0), Some(2.0)] }],
        );
        let hbars = svg_ranked_hbars("t", "MFU", &[("row".into(), 0, 1.0)]);
        for svg in [&bars, &line, &hbars] {
            assert_eq!(svg.matches("<style>").count(), 1);
            assert_eq!(svg.matches("@media (prefers-color-scheme: dark)").count(), 1);
            assert!(svg.contains("class=\"surface\"") && svg.contains("class=\"muted\""));
            // the light neutrals appear only inside the stylesheet
            // (ink/grid once; muted doubles as axis, surface as marker)
            for (hex, uses) in [(INK, 1), (INK_MUTED, 2), (GRID, 1), (SURFACE, 2)] {
                assert_eq!(svg.matches(hex).count(), uses, "{hex} must live in <style> only");
            }
        }
        assert!(bars.contains("class=\"limit\"") && bars.contains("class=\"limit-ink\""));
        assert!(line.contains("class=\"marker\""));
    }

    #[test]
    fn grouped_bars_svg_is_well_formed() {
        let svg = svg_grouped_bars(
            "t",
            "GiB",
            &["s0".into(), "s1".into()],
            &[Series { name: "a".into(), slot: 0, values: vec![Some(1.0), Some(2.0)] }],
            Some((3.0, "limit")),
        );
        assert!(svg.starts_with("<svg") && svg.ends_with("</svg>"));
        assert_eq!(svg.matches("<path").count(), 2);
        assert!(svg.contains("limit"));
    }

    #[test]
    fn line_chart_breaks_at_oom_gaps() {
        let svg = svg_multi_line(
            "t",
            "k",
            "MFU",
            &[2.0, 3.0, 4.0, 5.0],
            &[Series {
                name: "a".into(),
                slot: 0,
                values: vec![None, Some(1.0), Some(2.0), Some(3.0)],
            }],
        );
        // 3 markers, one polyline segment
        assert_eq!(svg.matches("<circle").count(), 3);
        assert_eq!(svg.matches("<polyline").count(), 1);
    }

    #[test]
    fn estimator_tables_reproduce_paper_signs() {
        let (eq3, eq4) = render_estimator_tables();
        assert_eq!(eq3.lines().count(), 12, "{eq3}"); // header + rule + 10 exps
        assert_eq!(eq4.lines().count(), 6, "{eq4}"); // header + rule + 4 transitions
        // the §4 worked example: GPT-3 recompute transition predicts a
        // speedup, LLaMA flash predicts a slowdown
        let row = |t: &str, needle: &str| -> String {
            t.lines().find(|l| l.contains(needle)).unwrap_or_default().to_string()
        };
        let gpt = row(&eq4, "(7)→(8)");
        assert!(!gpt.is_empty());
        let llama = row(&eq4, "(5)→(6)");
        assert!(llama.contains("| 0."), "LLaMA flash must predict <1x: {llama}");
    }

    #[test]
    fn report_renders_offline_grids() {
        // one experiment's ranking grid + a trimmed bounds grid keeps
        // this unit test fast; the full-size exp-8 report is pinned by
        // tests/report_snapshot.rs
        let e = paper_experiment(8).unwrap();
        let ranking = sim::sweep(sim::experiment_tasks(&e, 2), 0);
        let bound_tasks: Vec<sim::SweepTask> = sim::bound_sensitivity_tasks(&e, 2)
            .into_iter()
            .filter(|t| {
                t.layout.name == "pair-adjacent"
                    && t.spec.family == crate::schedule::Family::OneFOneB
            })
            .collect();
        let bound_outs = sim::sweep(bound_tasks, 0);
        let (cap, frontier) = sim::frontier_outcomes(&e, 2, 0);
        let md = render_replication_report(&e, &ranking, &bound_outs, cap, &frontier);
        assert!(md.matches("<svg").count() >= 4, "need ≥4 embedded figures");
        assert!(md.contains("Estimator vs DES"));
        assert!(md.contains("W-shaped"));
        assert!(md.contains("stage-bounds"));
        assert!(md.contains("found-vs-family"));
        assert!(md.contains("synthesized"));
    }

    #[test]
    fn frontier_panel_charts_only_feasible_cells() {
        let e = paper_experiment(8).unwrap();
        let (cap, frontier) = sim::frontier_outcomes(&e, 2, 0);
        assert_eq!(cap, e.cluster.hbm_bytes / 10 * 9);
        // exp (8) at 90% HBM: every hand-written family cell OOMs
        // (pinned per-stage peaks in tests/golden_engine.rs all exceed
        // the cap) and only the synthesized cell survives
        let feasible: Vec<&str> = frontier
            .iter()
            .filter(|o| o.oom_stage.is_none() && o.mfu_pct.is_finite())
            .map(|o| o.scenario)
            .collect();
        assert_eq!(feasible, ["synthesized"], "{frontier:?}");
        let svg = render_fig4_found_vs_family(&e, cap, &frontier);
        assert!(svg.contains("synthesized"));
        assert!(!svg.contains("GPipe"), "OOM cells must not chart");
    }
}
