//! Rendering: paper-style tables, ASCII schedule timelines, and the SVG
//! replication report.
//!
//! Three output layers, from rawest to most assembled:
//!
//! * [`tables`] — the generic fixed-width [`Table`] (text + RFC-4180
//!   CSV) and the paper's Tables 2/3/5 regenerators;
//! * [`timeline`] — program-order and time-bucketed ASCII renderings of
//!   schedules (paper Figure 1) and device layouts (paper Figure 2);
//! * [`figures`] — self-contained SVG charts consuming
//!   [`crate::sim::SweepOutcome`]s directly, assembled into the
//!   `bpipe report` markdown deliverable (Figures 1/2, the
//!   bound-sensitivity frontier, and the estimator-vs-DES error tables).
//!
//! Everything here is pure string rendering over already-simulated data:
//! no module in `report` runs the DES except [`figures`]'s top-level
//! [`replication_report`] convenience entry point (which drives
//! [`crate::sim::sweep()`] and then renders).

pub mod figures;
pub mod tables;
pub mod timeline;

pub use figures::{render_replication_report, replication_report, replication_report_all};
pub use tables::{render_table2, render_table3, render_table5, Table};
pub use timeline::{render_layout, render_timeline};
