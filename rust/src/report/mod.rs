//! Rendering: paper-style tables and ASCII schedule timelines.

pub mod tables;
pub mod timeline;

pub use tables::{render_table2, render_table3, render_table5, Table};
pub use timeline::{render_layout, render_timeline};
