//! Paper-table regeneration: Markdown-ish fixed-width tables with
//! paper-reported vs simulated columns.

use crate::config::{paper_experiment, paper_table3_mfu, paper_table5_mfu};
use crate::sim::{simulate_experiment, CostModel};

/// A generic fixed-width text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len());
        self.rows.push(row);
    }

    /// Render as RFC-4180-ish CSV: header row + data rows, fields quoted
    /// only when they contain a comma, quote, CR or LF.  The
    /// machine-readable sibling of [`Table::render`] (sweep `--csv`).
    ///
    /// Audited for the sweep exports (PR 3): commas now legitimately
    /// appear in data fields (the `stage_bounds` / `per_stage_mem_gib`
    /// vector columns are comma-joined), and RFC 4180 requires quoting
    /// CR as well as LF — both covered here and pinned by tests.
    pub fn render_csv(&self) -> String {
        let field = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let line = |cells: &[String]| -> String {
            cells.iter().map(|c| field(c)).collect::<Vec<_>>().join(",")
        };
        let mut out = line(&self.header);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut w = vec![0usize; cols];
        for c in 0..cols {
            w[c] = self.header[c].len();
            for r in &self.rows {
                w[c] = w[c].max(r[c].len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", cell, width = w[c]));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.header);
        out.push('|');
        for width in &w {
            out.push_str(&format!("{:-<width$}|", "", width = width + 2));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
        }
        out
    }
}

/// Table 2: model configurations.
pub fn render_table2() -> String {
    let mut t = Table::new(&["Model", "h", "a", "s", "l", "v", "params"]);
    for m in [crate::config::llama_65b(), crate::config::gpt3_96b()] {
        t.push(vec![
            m.name.clone(),
            m.h.to_string(),
            m.a.to_string(),
            m.s.to_string(),
            m.l.to_string(),
            m.v.to_string(),
            format!("{:.1}B", m.total_params() as f64 / 1e9),
        ]);
    }
    t.render()
}

/// Table 3: the ten whole-model experiments — paper MFU vs simulated MFU,
/// with the softmax kernel the cost model selected (the §3.2 mechanism).
pub fn render_table3() -> String {
    let mut t = Table::new(&[
        "ID", "Model", "b", "BPipe", "attention", "kernel", "paper MFU %", "sim MFU %",
    ]);
    for id in 1..=10u32 {
        let e = paper_experiment(id).unwrap();
        let r = simulate_experiment(&e);
        let kernel = format!("{:?}", CostModel::new(&e).softmax_kernel());
        t.push(vec![
            format!("({id})"),
            e.model.name.clone(),
            e.parallel.microbatch.to_string(),
            if e.bpipe { "Yes" } else { "No" }.into(),
            e.attention.label().into(),
            kernel,
            format!("{:.1}", paper_table3_mfu(id).unwrap()),
            format!("{:.1}", r.mfu_pct()),
        ]);
    }
    t.render()
}

/// Table 5: single-stage MFU — paper vs cost model.
pub fn render_table5() -> String {
    let mut t = Table::new(&["ID", "Model", "b", "attention", "paper MFU %", "sim MFU %"]);
    for id in 1..=10u32 {
        let e = paper_experiment(id).unwrap();
        let cm = CostModel::new(&e);
        t.push(vec![
            format!("({id})"),
            e.model.name.clone(),
            e.parallel.microbatch.to_string(),
            e.attention.label().into(),
            format!("{:.1}", paper_table5_mfu(id).unwrap()),
            format!("{:.1}", cm.single_stage_mfu() * 100.0),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "bb"]);
        t.push(vec!["xxx".into(), "y".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    fn table_renders_csv_with_quoting() {
        let mut t = Table::new(&["a", "b"]);
        t.push(vec!["plain".into(), "with,comma".into()]);
        t.push(vec!["has \"quotes\"".into(), "x".into()]);
        let csv = t.render_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "a,b");
        assert_eq!(lines[1], "plain,\"with,comma\"");
        assert_eq!(lines[2], "\"has \"\"quotes\"\"\",x");
    }

    #[test]
    fn csv_quotes_vector_fields_and_control_chars() {
        // the sweep's stage_bounds / per_stage_mem_gib columns are
        // comma-joined vectors: they must round-trip as ONE field
        let mut t = Table::new(&["scenario", "stage_bounds"]);
        t.push(vec!["1F1B+stage-bounds".into(), "5,6,6,5,4,3,2,2".into()]);
        t.push(vec!["cr".into(), "em\rbedded".into()]);
        let csv = t.render_csv();
        let lines: Vec<&str> = csv.split('\n').collect();
        assert_eq!(lines[1], "1F1B+stage-bounds,\"5,6,6,5,4,3,2,2\"");
        // RFC 4180: CR forces quoting just like LF
        assert_eq!(lines[2], "cr,\"em\rbedded\"");
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.push(vec!["only-one".into()]);
    }

    #[test]
    fn table2_contains_both_models() {
        let r = render_table2();
        assert!(r.contains("LLaMA 65B") && r.contains("GPT-3 96B"));
        assert!(r.contains("9984"));
    }

    #[test]
    fn table5_has_ten_rows() {
        let r = render_table5();
        assert_eq!(r.lines().count(), 12); // header + rule + 10
    }
}
