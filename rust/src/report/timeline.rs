//! ASCII renderings of schedules (paper Figure 1) and device layouts
//! (paper Figure 2).

use crate::bpipe::Layout;
use crate::schedule::{OpKind, Schedule};
use crate::sim::TraceEvent;

/// Render a schedule as per-stage op rows, Figure-1 style:
///
/// ```text
/// stage 0 | F0 F1 F2 F3 E3 F4 E4 B0 L3 B1 L4 ...
/// stage 1 |    F0 F1 F2 F3 B0 F4 B1 ...
/// ```
///
/// `F`=forward, `B`=backward, `E`=BPipe evict, `L`=BPipe load; digits are
/// microbatch ids.  Purely program-order (no timing); for a timed
/// rendering use [`render_timeline`].
pub fn render_program(s: &Schedule) -> String {
    let mut out = String::new();
    for prog in &s.programs {
        out.push_str(&format!("stage {} |", prog.stage));
        for op in &prog.ops {
            let c = match op.kind {
                OpKind::Fwd => 'F',
                OpKind::Bwd => 'B',
                OpKind::Evict => 'E',
                OpKind::Load => 'L',
            };
            out.push_str(&format!(" {c}{}", op.mb));
        }
        out.push('\n');
    }
    out
}

/// Render a simulated trace as a time-bucketed Gantt chart, one row per
/// stage — the timed version of paper Figure 1.  `width` = character
/// columns for the whole makespan.
pub fn render_timeline(trace: &[TraceEvent], p: u64, width: usize) -> String {
    let makespan = trace.iter().map(|t| t.end).fold(0.0, f64::max);
    if makespan <= 0.0 {
        return String::new();
    }
    let scale = width as f64 / makespan;
    let mut rows = vec![vec![' '; width]; p as usize];
    // compute ops paint F/B; transfers paint e/l *over* idle cells only,
    // visualizing that they ride a separate stream.
    let mut paint = |ev: &TraceEvent, fill_over_idle_only: bool| {
        let row = &mut rows[ev.stage as usize];
        let a = (ev.start * scale).floor() as usize;
        let b = ((ev.end * scale).ceil() as usize).min(width).max(a + 1);
        let ch = match ev.kind {
            OpKind::Fwd => char::from_digit((ev.mb % 10) as u32, 10).unwrap(),
            OpKind::Bwd => {
                // backwards render as letters a..j cycling by microbatch
                (b'a' + (ev.mb % 10) as u8) as char
            }
            OpKind::Evict => '>',
            OpKind::Load => '<',
        };
        for cell in row.iter_mut().take(b.min(width)).skip(a) {
            if !fill_over_idle_only || *cell == ' ' {
                *cell = ch;
            }
        }
    };
    for ev in trace {
        if matches!(ev.kind, OpKind::Fwd | OpKind::Bwd) {
            paint(ev, false);
        }
    }
    for ev in trace {
        if matches!(ev.kind, OpKind::Evict | OpKind::Load) {
            paint(ev, true);
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "time → (makespan {:.3}s; digits=fwd mb, letters=bwd mb, >=evict, <=load)\n",
        makespan
    ));
    for (s, row) in rows.iter().enumerate() {
        out.push_str(&format!("stage {s} |"));
        out.extend(row.iter());
        out.push_str("|\n");
    }
    out
}

/// Render a stage→node layout, Figure-2 style, marking evictor/acceptor
/// pairs.
pub fn render_layout(layout: &Layout, p: u64) -> String {
    let mut out = format!("layout: {} ({} nodes)\n", layout.name, layout.n_nodes);
    for (node, stages) in layout.stages_per_node().iter().enumerate() {
        let tags: Vec<String> = stages
            .iter()
            .map(|&s| {
                let partner = crate::bpipe::partner(p, s);
                let mark = if layout.pair_intra_node(p, s) { "" } else { "!" };
                format!("s{s}{mark}(↔{partner})")
            })
            .collect();
        out.push_str(&format!("  node {node}: {}\n", tags.join(" ")));
    }
    let frac = layout.intra_node_pair_fraction(p);
    out.push_str(&format!(
        "  intra-node pairs: {:.0}% {}\n",
        frac * 100.0,
        if frac == 1.0 { "(all evict/load traffic on NVLink)" } else { "(! pairs cross IB)" }
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bpipe::{apply_bpipe, pair_adjacent_layout, sequential_layout};
    use crate::schedule::one_f_one_b;

    #[test]
    fn program_rendering_contains_evicts_for_bpipe() {
        let s = apply_bpipe(&one_f_one_b(4, 8), None);
        let r = render_program(&s);
        assert!(r.contains('E') && r.contains('L'));
        assert!(r.lines().count() == 4);
    }

    #[test]
    fn timeline_rendering_has_all_stages() {
        let e = crate::config::paper_experiment(8).unwrap();
        let r = crate::sim::simulate_experiment(&e);
        let txt = render_timeline(&r.trace, e.parallel.p, 100);
        assert_eq!(txt.lines().count() as u64, e.parallel.p + 1);
        assert!(txt.contains("makespan"));
    }

    #[test]
    fn layout_rendering_marks_cross_node_pairs() {
        let bad = render_layout(&sequential_layout(16, 2), 16);
        assert!(bad.contains('!'));
        let good = render_layout(&pair_adjacent_layout(16, 2), 16);
        assert!(!good.contains('!'));
        assert!(good.contains("100%"));
    }
}
