//! `artifacts/manifest.json` — the python→rust interchange contract.
//!
//! The manifest describes every lowered artifact (file, input/output
//! shapes and dtypes) plus the model spec and per-stage-kind parameter
//! counts.  The rust side trusts it verbatim; the pytest suite
//! (`python/tests/test_aot.py`) guards its consistency at build time.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Shape+dtype of one artifact input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    pub shape: Vec<u64>,
    pub dtype: String,
}

impl TensorMeta {
    pub fn elements(&self) -> u64 {
        self.shape.iter().product::<u64>().max(1)
    }

    pub fn shape_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }
}

/// One lowered artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

/// The model spec the artifacts were lowered for (mirror of
/// `python/compile/model.py::ModelSpec`).
#[derive(Debug, Clone)]
pub struct SpecMeta {
    pub family: String,
    pub h: u64,
    pub a: u64,
    pub s: u64,
    pub v: u64,
    pub layers_per_stage: u64,
    pub stages: u64,
    pub b: u64,
    pub attention: String,
}

impl SpecMeta {
    /// Total parameters across the pipeline (first + mids + last).
    pub fn total_params(&self, params: &HashMap<String, u64>) -> u64 {
        params["first"] + (self.stages - 2) * params["mid"] + params["last"]
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub spec: SpecMeta,
    pub params: HashMap<String, u64>,
    pub bs_sweep: Vec<u64>,
    pub artifacts: HashMap<String, ArtifactMeta>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?}: {e}. Run `make artifacts` first."))?;
        let mut m = Self::parse(&text)?;
        m.dir = dir.to_path_buf();
        Ok(m)
    }

    /// Parse a manifest JSON document (via the in-tree JSON parser).
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        use crate::util::Json;
        let doc = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let need = |v: Option<&Json>, what: &str| {
            v.cloned().ok_or_else(|| anyhow::anyhow!("manifest missing {what}"))
        };
        let u64_of = |v: &Json, what: &str| {
            v.as_u64().ok_or_else(|| anyhow::anyhow!("manifest: {what} not a u64"))
        };
        let str_of = |v: &Json, what: &str| -> anyhow::Result<String> {
            Ok(v.as_str().ok_or_else(|| anyhow::anyhow!("manifest: {what} not a string"))?.into())
        };

        let spec_j = need(doc.get("spec"), "spec")?;
        let sg = |k: &str| -> anyhow::Result<u64> {
            u64_of(&need(spec_j.get(k), &format!("spec.{k}"))?, k)
        };
        let spec = SpecMeta {
            family: str_of(&need(spec_j.get("family"), "spec.family")?, "family")?,
            h: sg("h")?,
            a: sg("a")?,
            s: sg("s")?,
            v: sg("v")?,
            layers_per_stage: sg("layers_per_stage")?,
            stages: sg("stages")?,
            b: sg("b")?,
            attention: str_of(&need(spec_j.get("attention"), "spec.attention")?, "attention")?,
        };

        let mut params = HashMap::new();
        for (k, v) in need(doc.get("params"), "params")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("params not an object"))?
        {
            params.insert(k.clone(), u64_of(v, k)?);
        }

        let bs_sweep = doc
            .get("bs_sweep")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_u64()).collect())
            .unwrap_or_default();

        let tensor_of = |v: &Json| -> anyhow::Result<TensorMeta> {
            let shape = v
                .get("shape")
                .and_then(|s| s.as_arr())
                .ok_or_else(|| anyhow::anyhow!("tensor missing shape"))?
                .iter()
                .map(|d| d.as_u64().ok_or_else(|| anyhow::anyhow!("bad shape dim")))
                .collect::<anyhow::Result<Vec<u64>>>()?;
            let dtype = str_of(&need(v.get("dtype"), "tensor.dtype")?, "dtype")?;
            Ok(TensorMeta { shape, dtype })
        };
        let mut artifacts = HashMap::new();
        for (name, v) in need(doc.get("artifacts"), "artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("artifacts not an object"))?
        {
            let inputs = need(v.get("inputs"), "inputs")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("inputs not an array"))?
                .iter()
                .map(tensor_of)
                .collect::<anyhow::Result<Vec<_>>>()?;
            let outputs = need(v.get("outputs"), "outputs")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("outputs not an array"))?
                .iter()
                .map(tensor_of)
                .collect::<anyhow::Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactMeta { file: str_of(&need(v.get("file"), "file")?, "file")?, inputs, outputs },
            );
        }
        Ok(Manifest { spec, params, bs_sweep, artifacts, dir: PathBuf::new() })
    }

    /// A fully in-memory manifest describing a tiny synthetic stage
    /// model — the [`crate::runtime::SimBackend`]'s default input, so
    /// the REAL pipeline (coordinator + workers) runs in tier-1 with no
    /// lowered artifacts on disk.  `stages` is the number of **virtual**
    /// stages (`p × chunks` for virtual-pipeline schedules); the
    /// artifact set mirrors what `make artifacts` lowers: per-kind
    /// `init`/`fwd`/`bwd`, `adam_*`, and the `mid_{fwd,bwd}_b{b}`
    /// single-stage sweep used by the §4 estimator.
    pub fn synthetic(stages: u64, h: u64, s: u64, b: u64, vocab: u64, bs_sweep: &[u64]) -> Self {
        assert!(stages >= 2, "need at least 2 virtual stages");
        let spec = SpecMeta {
            family: "sim-affine".into(),
            h,
            a: 1,
            s,
            v: vocab,
            layers_per_stage: 1,
            stages,
            b,
            attention: "none".into(),
        };
        let mut params = HashMap::new();
        params.insert("first".to_string(), vocab * h);
        params.insert("mid".to_string(), 8 * h);
        params.insert("last".to_string(), vocab * h + 2);
        let f32t = |shape: Vec<u64>| TensorMeta { shape, dtype: "f32".into() };
        let i32t = |shape: Vec<u64>| TensorMeta { shape, dtype: "i32".into() };
        let act = |b: u64| f32t(vec![b, s, h]);
        let tok = |b: u64| i32t(vec![b, s]);
        let mut artifacts = HashMap::new();
        let mut add = |name: String, inputs: Vec<TensorMeta>, outputs: Vec<TensorMeta>| {
            artifacts.insert(
                name.clone(),
                ArtifactMeta { file: format!("<sim:{name}>"), inputs, outputs },
            );
        };
        for kind in ["first", "mid", "last"] {
            let n = params[kind];
            let pv = f32t(vec![n]);
            add(format!("{kind}_init"), vec![i32t(vec![])], vec![pv.clone()]);
            match kind {
                "first" => {
                    add("first_fwd".into(), vec![pv.clone(), tok(b)], vec![act(b)]);
                    add("first_bwd".into(), vec![pv.clone(), tok(b), act(b)], vec![pv.clone()]);
                }
                "mid" => {
                    add("mid_fwd".into(), vec![pv.clone(), act(b)], vec![act(b)]);
                    add(
                        "mid_bwd".into(),
                        vec![pv.clone(), act(b), act(b)],
                        vec![act(b), pv.clone()],
                    );
                }
                _ => {
                    // last: loss + grads fused into one bwd artifact
                    add(
                        "last_bwd".into(),
                        vec![pv.clone(), act(b), tok(b)],
                        vec![act(b), pv.clone(), f32t(vec![])],
                    );
                }
            }
            add(
                format!("adam_{kind}"),
                vec![pv.clone(), pv.clone(), pv.clone(), pv.clone(), i32t(vec![]), f32t(vec![])],
                vec![pv.clone(), pv.clone(), pv.clone()],
            );
        }
        let n_mid = params["mid"];
        for &bs in bs_sweep {
            let pv = f32t(vec![n_mid]);
            add(format!("mid_fwd_b{bs}"), vec![pv.clone(), act(bs)], vec![act(bs)]);
            add(
                format!("mid_bwd_b{bs}"),
                vec![pv.clone(), act(bs), act(bs)],
                vec![act(bs), pv.clone()],
            );
        }
        Manifest {
            spec,
            params,
            bs_sweep: bs_sweep.to_vec(),
            artifacts,
            dir: PathBuf::new(),
        }
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, name: &str) -> anyhow::Result<PathBuf> {
        let meta = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not in manifest"))?;
        Ok(self.dir.join(&meta.file))
    }

    pub fn meta(&self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not in manifest"))
    }

    /// Parameter count for a stage kind ("first" | "mid" | "last").
    pub fn param_count(&self, kind: &str) -> anyhow::Result<u64> {
        self.params
            .get(kind)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unknown stage kind {kind:?}"))
    }

    /// Stage kind for pipeline stage index.
    pub fn stage_kind(&self, stage: u64) -> &'static str {
        if stage == 0 {
            "first"
        } else if stage + 1 == self.spec.stages {
            "last"
        } else {
            "mid"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "spec": {"family": "llama", "h": 64, "a": 4, "s": 64, "v": 256,
                 "layers_per_stage": 1, "stages": 4, "b": 2,
                 "attention": "fused", "flash_block_q": 64, "flash_block_k": 64},
        "params": {"first": 100, "mid": 80, "last": 120},
        "bs_sweep": [1, 2],
        "artifacts": {
            "mid_fwd": {"file": "mid_fwd.hlo.txt",
                         "inputs": [{"shape": [80], "dtype": "f32"},
                                    {"shape": [2, 64, 64], "dtype": "f32"}],
                         "outputs": [{"shape": [2, 64, 64], "dtype": "f32"}]}
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.spec.h, 64);
        assert_eq!(m.param_count("mid").unwrap(), 80);
        assert_eq!(m.meta("mid_fwd").unwrap().inputs[1].elements(), 2 * 64 * 64);
        assert_eq!(m.spec.total_params(&m.params), 100 + 2 * 80 + 120);
    }

    #[test]
    fn stage_kinds() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.stage_kind(0), "first");
        assert_eq!(m.stage_kind(1), "mid");
        assert_eq!(m.stage_kind(2), "mid");
        assert_eq!(m.stage_kind(3), "last");
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.meta("nope").is_err());
        assert!(m.param_count("nope").is_err());
    }

    #[test]
    fn synthetic_manifest_is_complete() {
        let m = Manifest::synthetic(8, 16, 8, 2, 64, &[1, 2]);
        assert_eq!(m.spec.stages, 8);
        assert_eq!(m.stage_kind(0), "first");
        assert_eq!(m.stage_kind(7), "last");
        for kind in ["first", "mid", "last"] {
            assert!(m.param_count(kind).unwrap() >= 2);
            assert!(m.meta(&format!("{kind}_init")).is_ok());
            assert!(m.meta(&format!("adam_{kind}")).is_ok());
        }
        assert!(m.meta("first_fwd").is_ok() && m.meta("mid_fwd").is_ok());
        assert!(m.meta("last_fwd").is_err(), "last stage fuses loss+grads into bwd");
        for b in [1u64, 2] {
            assert!(m.meta(&format!("mid_fwd_b{b}")).is_ok());
            assert!(m.meta(&format!("mid_bwd_b{b}")).is_ok());
        }
        assert_eq!(m.meta("mid_fwd").unwrap().inputs[1].shape, vec![2, 8, 16]);
        assert_eq!(m.bs_sweep, vec![1, 2]);
    }

    #[test]
    fn tensor_meta_helpers() {
        let t = TensorMeta { shape: vec![2, 3, 4], dtype: "f32".into() };
        assert_eq!(t.elements(), 24);
        assert_eq!(t.shape_i64(), vec![2i64, 3, 4]);
        let scalar = TensorMeta { shape: vec![], dtype: "i32".into() };
        assert_eq!(scalar.elements(), 1);
    }
}
