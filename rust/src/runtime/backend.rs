//! The execution-backend abstraction: client / compile / upload /
//! execute over device buffers.
//!
//! The coordinator (leader + stage workers) is generic over a
//! [`Backend`], so the REAL pipeline-parallel training loop — channels,
//! activation stashes, BPipe evict/load, Adam, checkpointing — is
//! exercised identically whether the stage functions run as
//!
//! * AOT-compiled XLA artifacts on the PJRT CPU client
//!   (`runtime::engine::Runtime`, behind the `pjrt` feature), or
//! * deterministic seeded f32 affine ops on host buffers
//!   ([`crate::runtime::SimBackend`], compiled in tier-1 by default).
//!
//! The boundary is deliberately small: a backend owns an opaque compiled
//! [`Backend::Exec`] per artifact and an opaque device-resident
//! [`Backend::Buffer`]; everything that crosses threads is a
//! [`HostTensor`] (plain host data + logical shape), which is what the
//! activation stashes, BPipe transfers and checkpoints move around.

use super::artifact::Manifest;
use super::buffer_pool::BufferPool;

/// A tensor crossing thread boundaries: host data + logical shape.
/// (Backend handles like `xla::Literal` wrap raw pointers and are not
/// `Send`; the coordinator moves host vectors and re-uploads at the use
/// site.)  An empty `shape` denotes a scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { data: Vec<f32>, shape: Vec<i64> },
    I32 { data: Vec<i32>, shape: Vec<i64> },
}

impl HostTensor {
    /// A scalar f32 (shape `[]`).
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { data: vec![v], shape: Vec::new() }
    }

    /// A scalar i32 (shape `[]`).
    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { data: vec![v], shape: Vec::new() }
    }

    /// A flat f32 vector (shape `[n]`).
    pub fn vec_f32(data: Vec<f32>) -> Self {
        let n = data.len() as i64;
        HostTensor::F32 { data, shape: vec![n] }
    }

    pub fn shape(&self) -> &[i64] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Payload bytes (both dtypes are 4-byte).
    pub fn bytes(&self) -> usize {
        self.len() * 4
    }

    /// The f32 payload, or an error for an i32 tensor.
    pub fn f32s(&self) -> anyhow::Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => anyhow::bail!("expected an f32 tensor, got i32"),
        }
    }

    /// The i32 payload, or an error for an f32 tensor.
    pub fn i32s(&self) -> anyhow::Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { .. } => anyhow::bail!("expected an i32 tensor, got f32"),
        }
    }

    /// Consume into the f32 payload.
    pub fn into_f32s(self) -> anyhow::Result<Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => anyhow::bail!("expected an f32 tensor, got i32"),
        }
    }

    /// A zero-element f32 tensor that performs **no allocation** — the
    /// placeholder `std::mem::replace` uses when handing an owned tensor
    /// to a donating execution.
    pub fn empty_f32() -> Self {
        HostTensor::F32 { data: Vec::new(), shape: Vec::new() }
    }

    /// The mutable f32 payload, or an error for an i32 tensor.
    pub fn f32s_mut(&mut self) -> anyhow::Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => anyhow::bail!("expected an f32 tensor, got i32"),
        }
    }

    /// The mutable i32 payload, or an error for an f32 tensor.
    pub fn i32s_mut(&mut self) -> anyhow::Result<&mut [i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { .. } => anyhow::bail!("expected an i32 tensor, got f32"),
        }
    }

    /// Capacity of the shape vector — what [`Self::set_shape`] can hold
    /// without reallocating.  The buffer pool matches on this so a
    /// recycled low-rank buffer is never made to serve a higher-rank
    /// take (which would grow the shape vector on the hot path).
    pub fn shape_capacity(&self) -> usize {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape.capacity(),
        }
    }

    /// Rewrite the logical shape in place (the shape vector's capacity
    /// is retained, so steady-state calls never touch the heap).
    pub fn set_shape(&mut self, new_shape: &[i64]) {
        let shape = match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        };
        shape.clear();
        shape.extend_from_slice(new_shape);
    }

    /// Overwrite a scalar i32 tensor's value in place.
    pub fn set_scalar_i32(&mut self, v: i32) -> anyhow::Result<()> {
        let data = self.i32s_mut()?;
        anyhow::ensure!(data.len() == 1, "expected a scalar, got {} elements", data.len());
        data[0] = v;
        Ok(())
    }

    /// Overwrite a scalar f32 tensor's value in place.
    pub fn set_scalar_f32(&mut self, v: f32) -> anyhow::Result<()> {
        let data = self.f32s_mut()?;
        anyhow::ensure!(data.len() == 1, "expected a scalar, got {} elements", data.len());
        data[0] = v;
        Ok(())
    }
}

/// One host-side input to a donating execution
/// ([`Backend::execute_pooled`]): either **borrowed** (the caller keeps
/// it alive — the stash still needs it) or **donated** (the computation
/// consumes it and may reuse its memory for an output, the host-level
/// mirror of PJRT/XLA input-buffer donation).  Slots are single-use —
/// spent by the execution; callers rebuild the (stack-allocated)
/// argument array per call.
pub enum Arg<'a> {
    Borrowed(&'a HostTensor),
    Donated(HostTensor),
    /// A slot whose value the backend has already consumed.
    Spent,
}

impl<'a> Arg<'a> {
    /// Read-only view of the slot's tensor (panics on a spent slot —
    /// that is a caller bug, not a data error).
    pub fn view(&self) -> &HostTensor {
        match self {
            Arg::Borrowed(t) => t,
            Arg::Donated(t) => t,
            Arg::Spent => panic!("argument slot already consumed"),
        }
    }

    /// Move the slot's value out, leaving [`Arg::Spent`] behind.
    pub fn take(&mut self) -> ArgVal<'a> {
        match std::mem::replace(self, Arg::Spent) {
            Arg::Borrowed(t) => ArgVal::Ref(t),
            Arg::Donated(t) => ArgVal::Owned(t),
            Arg::Spent => panic!("argument slot already consumed"),
        }
    }
}

/// An argument taken out of its slot: a borrowed view, or the owned
/// tensor of a donated input (whose buffer the backend may now reuse).
pub enum ArgVal<'a> {
    Ref(&'a HostTensor),
    Owned(HostTensor),
}

impl ArgVal<'_> {
    pub fn view(&self) -> &HostTensor {
        match self {
            ArgVal::Ref(t) => t,
            ArgVal::Owned(t) => t,
        }
    }

    pub fn len(&self) -> usize {
        self.view().len()
    }

    pub fn is_empty(&self) -> bool {
        self.view().is_empty()
    }

    /// Release a donated value's buffers to the pool (no-op for views).
    pub fn recycle(self, pool: &mut BufferPool) {
        if let ArgVal::Owned(t) = self {
            pool.give(t);
        }
    }
}

/// One execution backend: create a per-worker client, compile
/// manifest-described artifacts, upload host tensors to device buffers,
/// and execute.  Each stage worker creates its OWN backend instance
/// (`xla` handles are not `Send`, and a client per worker is the honest
/// analogue of one process per GPU).
pub trait Backend: Sized + 'static {
    /// A compiled stage function.
    type Exec;
    /// A device-resident buffer (parameters stay uploaded across a step).
    type Buffer;

    /// Create a client for one worker.
    fn create(manifest: &Manifest) -> anyhow::Result<Self>;

    /// Human-readable platform name ("cpu", "sim", …).
    fn platform(&self) -> String;

    /// Tell the backend which pipeline stage it serves.  Called once by
    /// the stage worker right after [`Self::create`]; the default
    /// ignores it.  Instrumenting wrappers (fault injection, tracing)
    /// use this to key per-stage behavior.
    fn bind_stage(&mut self, _stage: u64) {}

    /// Tell the backend which fleet replica it serves (`bpipe serve`
    /// runs R data-parallel pipelines in one process).  Called once by
    /// the stage worker right after [`Self::bind_stage`] when the run
    /// is part of a fleet; the default ignores it.  Fault injection
    /// uses this to scope replica-targeted faults.
    fn bind_replica(&mut self, _replica: usize) {}

    /// Step-boundary hook: called by the stage worker at the top of
    /// every training step with the GLOBAL (resume-aware) 1-based step
    /// number.  The default does nothing; an error fails the step and is
    /// routed through the supervisor like any other worker failure.
    fn begin_step(&self, _global_step: u64) -> anyhow::Result<()> {
        Ok(())
    }

    /// Compile the named artifact from the manifest.
    fn compile(&self, manifest: &Manifest, name: &str) -> anyhow::Result<Self::Exec>;

    /// Upload host data to a device-resident buffer.
    fn upload(&self, t: &HostTensor) -> anyhow::Result<Self::Buffer>;

    /// Execute with device-resident inputs; returns the decomposed
    /// output tuple as host tensors.
    fn execute(&self, exe: &Self::Exec, inputs: &[&Self::Buffer]) -> anyhow::Result<Vec<HostTensor>>;

    /// Convenience: upload host inputs, execute, return host outputs.
    fn execute_host(
        &self,
        exe: &Self::Exec,
        inputs: &[&HostTensor],
    ) -> anyhow::Result<Vec<HostTensor>> {
        let bufs: Vec<Self::Buffer> =
            inputs.iter().map(|t| self.upload(t)).collect::<anyhow::Result<_>>()?;
        let refs: Vec<&Self::Buffer> = bufs.iter().collect();
        self.execute(exe, &refs)
    }

    /// [`Self::execute`] for single-output artifacts (`*_fwd`).
    fn execute1(&self, exe: &Self::Exec, inputs: &[&Self::Buffer]) -> anyhow::Result<HostTensor> {
        let mut out = self.execute(exe, inputs)?;
        anyhow::ensure!(out.len() == 1, "expected 1 output, got {}", out.len());
        Ok(out.pop().unwrap())
    }

    /// Donating, pool-backed execution — the training hot path.
    ///
    /// `params` is the artifact's leading device-resident argument (the
    /// stage weights), when it has one; `args` are the remaining inputs
    /// in artifact order, each either borrowed or **donated** (the
    /// donation mask is simply which slots are [`Arg::Donated`]).  A
    /// donated input's buffer may be consumed by the computation — reused
    /// in place for an output of matching dtype and size, or released to
    /// `pool`.  Every slot is [`Arg::Spent`] after the call (the tensor
    /// *behind* a borrowed slot is untouched, but the slot itself is
    /// consumed): callers rebuild the — stack-allocated — argument array
    /// per call.  Outputs replace the contents of `out` (cleared first so
    /// its capacity is reused), drawing any buffers the donations didn't
    /// cover from `pool`.
    ///
    /// The contract is **value-identity with [`Self::execute`]**: the
    /// same inputs produce bit-identical outputs whatever the donation
    /// mask (pinned by `rust/tests/property_pooled.rs`).  The default
    /// implementation is the owned-value baseline: upload every input,
    /// run [`Self::execute`], and recycle the donated hosts' buffers.
    fn execute_pooled(
        &self,
        exe: &Self::Exec,
        params: Option<&Self::Buffer>,
        args: &mut [Arg<'_>],
        pool: &mut BufferPool,
        out: &mut Vec<HostTensor>,
    ) -> anyhow::Result<()> {
        out.clear();
        let uploaded: Vec<Self::Buffer> =
            args.iter().map(|a| self.upload(a.view())).collect::<anyhow::Result<_>>()?;
        let mut refs: Vec<&Self::Buffer> = Vec::with_capacity(uploaded.len() + 1);
        if let Some(p) = params {
            refs.push(p);
        }
        refs.extend(uploaded.iter());
        out.extend(self.execute(exe, &refs)?);
        for a in args.iter_mut() {
            a.take().recycle(pool); // donated buffers pool; all slots spend
        }
        Ok(())
    }

    /// Refresh an existing device buffer from host data (the parameter
    /// buffer after an optimizer step).  Implementations reuse the
    /// device allocation when they can; the default re-uploads.
    fn upload_into(&self, t: &HostTensor, buf: &mut Self::Buffer) -> anyhow::Result<()> {
        *buf = self.upload(t)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_accessors() {
        let f = HostTensor::F32 { data: vec![1.0, 2.0], shape: vec![2] };
        assert_eq!(f.len(), 2);
        assert_eq!(f.bytes(), 8);
        assert_eq!(f.f32s().unwrap(), &[1.0, 2.0]);
        assert!(f.i32s().is_err());
        let i = HostTensor::I32 { data: vec![3, 4, 5], shape: vec![3] };
        assert_eq!(i.i32s().unwrap(), &[3, 4, 5]);
        assert!(i.f32s().is_err());
        assert_eq!(i.shape(), &[3]);
    }

    #[test]
    fn scalars_have_empty_shape() {
        assert_eq!(HostTensor::scalar_f32(0.5).shape(), &[] as &[i64]);
        assert_eq!(HostTensor::scalar_i32(7).i32s().unwrap(), &[7]);
        assert_eq!(HostTensor::vec_f32(vec![0.0; 4]).shape(), &[4]);
    }

    #[test]
    fn in_place_mutators() {
        let mut t = HostTensor::vec_f32(vec![1.0, 2.0]);
        t.f32s_mut().unwrap()[1] = 5.0;
        assert_eq!(t.f32s().unwrap(), &[1.0, 5.0]);
        t.set_shape(&[2, 1]);
        assert_eq!(t.shape(), &[2, 1]);
        assert!(t.set_scalar_f32(0.0).is_err(), "two elements are not a scalar");
        let mut s = HostTensor::scalar_i32(3);
        s.set_scalar_i32(9).unwrap();
        assert_eq!(s.i32s().unwrap(), &[9]);
        assert!(HostTensor::empty_f32().is_empty());
    }

    #[test]
    fn arg_slots_take_once() {
        let kept = HostTensor::scalar_f32(1.0);
        let mut slots = [Arg::Borrowed(&kept), Arg::Donated(HostTensor::scalar_f32(2.0))];
        assert_eq!(slots[1].view().f32s().unwrap(), &[2.0]);
        let v0 = slots[0].take();
        let v1 = slots[1].take();
        assert!(matches!(v0, ArgVal::Ref(_)));
        assert!(matches!(&v1, ArgVal::Owned(t) if t.f32s().unwrap() == [2.0]));
        assert!(matches!(slots[1], Arg::Spent));
        let mut pool = BufferPool::new();
        v0.recycle(&mut pool);
        v1.recycle(&mut pool);
        assert_eq!(pool.len(), 1, "only the donated value returns to the pool");
    }
}
