//! The execution-backend abstraction: client / compile / upload /
//! execute over device buffers.
//!
//! The coordinator (leader + stage workers) is generic over a
//! [`Backend`], so the REAL pipeline-parallel training loop — channels,
//! activation stashes, BPipe evict/load, Adam, checkpointing — is
//! exercised identically whether the stage functions run as
//!
//! * AOT-compiled XLA artifacts on the PJRT CPU client
//!   (`runtime::engine::Runtime`, behind the `pjrt` feature), or
//! * deterministic seeded f32 affine ops on host buffers
//!   ([`crate::runtime::SimBackend`], compiled in tier-1 by default).
//!
//! The boundary is deliberately small: a backend owns an opaque compiled
//! [`Backend::Exec`] per artifact and an opaque device-resident
//! [`Backend::Buffer`]; everything that crosses threads is a
//! [`HostTensor`] (plain host data + logical shape), which is what the
//! activation stashes, BPipe transfers and checkpoints move around.

use super::artifact::Manifest;

/// A tensor crossing thread boundaries: host data + logical shape.
/// (Backend handles like `xla::Literal` wrap raw pointers and are not
/// `Send`; the coordinator moves host vectors and re-uploads at the use
/// site.)  An empty `shape` denotes a scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { data: Vec<f32>, shape: Vec<i64> },
    I32 { data: Vec<i32>, shape: Vec<i64> },
}

impl HostTensor {
    /// A scalar f32 (shape `[]`).
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { data: vec![v], shape: Vec::new() }
    }

    /// A scalar i32 (shape `[]`).
    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { data: vec![v], shape: Vec::new() }
    }

    /// A flat f32 vector (shape `[n]`).
    pub fn vec_f32(data: Vec<f32>) -> Self {
        let n = data.len() as i64;
        HostTensor::F32 { data, shape: vec![n] }
    }

    pub fn shape(&self) -> &[i64] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Payload bytes (both dtypes are 4-byte).
    pub fn bytes(&self) -> usize {
        self.len() * 4
    }

    /// The f32 payload, or an error for an i32 tensor.
    pub fn f32s(&self) -> anyhow::Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => anyhow::bail!("expected an f32 tensor, got i32"),
        }
    }

    /// The i32 payload, or an error for an f32 tensor.
    pub fn i32s(&self) -> anyhow::Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { .. } => anyhow::bail!("expected an i32 tensor, got f32"),
        }
    }

    /// Consume into the f32 payload.
    pub fn into_f32s(self) -> anyhow::Result<Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => anyhow::bail!("expected an f32 tensor, got i32"),
        }
    }
}

/// One execution backend: create a per-worker client, compile
/// manifest-described artifacts, upload host tensors to device buffers,
/// and execute.  Each stage worker creates its OWN backend instance
/// (`xla` handles are not `Send`, and a client per worker is the honest
/// analogue of one process per GPU).
pub trait Backend: Sized + 'static {
    /// A compiled stage function.
    type Exec;
    /// A device-resident buffer (parameters stay uploaded across a step).
    type Buffer;

    /// Create a client for one worker.
    fn create(manifest: &Manifest) -> anyhow::Result<Self>;

    /// Human-readable platform name ("cpu", "sim", …).
    fn platform(&self) -> String;

    /// Compile the named artifact from the manifest.
    fn compile(&self, manifest: &Manifest, name: &str) -> anyhow::Result<Self::Exec>;

    /// Upload host data to a device-resident buffer.
    fn upload(&self, t: &HostTensor) -> anyhow::Result<Self::Buffer>;

    /// Execute with device-resident inputs; returns the decomposed
    /// output tuple as host tensors.
    fn execute(&self, exe: &Self::Exec, inputs: &[&Self::Buffer]) -> anyhow::Result<Vec<HostTensor>>;

    /// Convenience: upload host inputs, execute, return host outputs.
    fn execute_host(
        &self,
        exe: &Self::Exec,
        inputs: &[&HostTensor],
    ) -> anyhow::Result<Vec<HostTensor>> {
        let bufs: Vec<Self::Buffer> =
            inputs.iter().map(|t| self.upload(t)).collect::<anyhow::Result<_>>()?;
        let refs: Vec<&Self::Buffer> = bufs.iter().collect();
        self.execute(exe, &refs)
    }

    /// [`Self::execute`] for single-output artifacts (`*_fwd`).
    fn execute1(&self, exe: &Self::Exec, inputs: &[&Self::Buffer]) -> anyhow::Result<HostTensor> {
        let mut out = self.execute(exe, inputs)?;
        anyhow::ensure!(out.len() == 1, "expected 1 output, got {}", out.len());
        Ok(out.pop().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_accessors() {
        let f = HostTensor::F32 { data: vec![1.0, 2.0], shape: vec![2] };
        assert_eq!(f.len(), 2);
        assert_eq!(f.bytes(), 8);
        assert_eq!(f.f32s().unwrap(), &[1.0, 2.0]);
        assert!(f.i32s().is_err());
        let i = HostTensor::I32 { data: vec![3, 4, 5], shape: vec![3] };
        assert_eq!(i.i32s().unwrap(), &[3, 4, 5]);
        assert!(i.f32s().is_err());
        assert_eq!(i.shape(), &[3]);
    }

    #[test]
    fn scalars_have_empty_shape() {
        assert_eq!(HostTensor::scalar_f32(0.5).shape(), &[] as &[i64]);
        assert_eq!(HostTensor::scalar_i32(7).i32s().unwrap(), &[7]);
        assert_eq!(HostTensor::vec_f32(vec![0.0; 4]).shape(), &[4]);
    }
}
