//! `BufferPool` — shape-keyed free lists of host buffers, the device
//! memory arena of the training hot path.
//!
//! Every steady-state tensor of a stage worker has one of a handful of
//! shapes (the activation `[b, s, h]`, the token/target `[b, s]`, the
//! per-kind parameter vector `[n]`, the loss scalar `[]`), so recycling
//! freed tensors through exact-size free lists makes the whole
//! `bpipe train --backend sim` step allocation-free after the first
//! (warm-up) step populates the pool — the runtime mirror of the
//! simulator's `SimWorkspace` discipline from PR 2, pinned by the same
//! counting-allocator test (`rust/tests/alloc_steady_state.rs`).
//!
//! The pool is **per worker and lock-free**: each stage thread owns one,
//! exactly like a PJRT client owns its device allocator, and tensors
//! that cross threads transfer ownership through the channels rather
//! than touching a shared arena.  Both the tensor's data `Vec` and its
//! shape `Vec` are recycled (shapes are set in place with retained
//! capacity), so a pool hit performs zero heap operations.
//!
//! Free lists are bounded: once a dtype's list holds `limit` buffers,
//! further returns are dropped (a plain deallocation) instead of grown,
//! so a flow that only ever *releases* one shape class — e.g. the
//! leader-streamed token tensors — cannot grow the pool without bound.
//! The list vectors reserve `limit` slots up front, which keeps the
//! steady-state `give` push allocation-free too.

use super::backend::HostTensor;

/// Default free-list bound per dtype (see [`BufferPool::with_limit`]).
const DEFAULT_LIMIT: usize = 256;

/// Per-worker free lists of [`HostTensor`] buffers, keyed by element
/// count (exact match — the shape *classes* of a worker are few and
/// fixed, so a linear scan over a short list beats any map).
#[derive(Debug)]
pub struct BufferPool {
    f32_free: Vec<HostTensor>,
    i32_free: Vec<HostTensor>,
    limit: usize,
    /// takes served from a free list
    pub hits: u64,
    /// takes that had to allocate fresh (warm-up, or a new shape class)
    pub misses: u64,
    /// tensors accepted back into a free list
    pub recycled: u64,
    /// tensors dropped because the free list was at its bound
    pub dropped: u64,
}

impl Default for BufferPool {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferPool {
    pub fn new() -> Self {
        Self::with_limit(DEFAULT_LIMIT)
    }

    /// A pool whose per-dtype free lists hold at most `limit` buffers
    /// (reserved up front, so steady-state returns never reallocate the
    /// list itself).
    pub fn with_limit(limit: usize) -> Self {
        let limit = limit.max(1);
        BufferPool {
            f32_free: Vec::with_capacity(limit),
            i32_free: Vec::with_capacity(limit),
            limit,
            hits: 0,
            misses: 0,
            recycled: 0,
            dropped: 0,
        }
    }

    /// Free buffers currently held (both dtypes).
    pub fn len(&self) -> usize {
        self.f32_free.len() + self.i32_free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.f32_free.is_empty() && self.i32_free.is_empty()
    }

    /// Payload bytes parked in the free lists.
    pub fn bytes_free(&self) -> usize {
        self.f32_free.iter().chain(self.i32_free.iter()).map(|t| t.bytes()).sum()
    }

    /// An f32 tensor with exactly `len` elements and the given logical
    /// `shape` (the two are allowed to disagree only in the degenerate
    /// ways the backends themselves allow — callers normally pass
    /// `len == shape.iter().product()`).  Contents are unspecified:
    /// callers overwrite every element.
    ///
    /// A free buffer qualifies only if its shape vector can also hold
    /// `shape` without growing — element counts can collide across
    /// tensor classes of different rank (e.g. a `[n]` gradient and a
    /// `[b, s, h]` activation with `n == b·s·h`), and serving a
    /// low-rank buffer to a high-rank take would reallocate the shape
    /// vector on the hot path.
    pub fn take_f32_len(&mut self, len: usize, shape: &[i64]) -> HostTensor {
        if let Some(i) = self
            .f32_free
            .iter()
            .position(|t| t.len() == len && t.shape_capacity() >= shape.len())
        {
            self.hits += 1;
            let mut t = self.f32_free.swap_remove(i);
            t.set_shape(shape);
            t
        } else {
            self.misses += 1;
            HostTensor::F32 { data: vec![0f32; len], shape: shape.to_vec() }
        }
    }

    /// [`Self::take_f32_len`] with `len` derived from the shape product
    /// (an empty shape is a scalar: one element).
    pub fn take_f32(&mut self, shape: &[i64]) -> HostTensor {
        self.take_f32_len(elems(shape), shape)
    }

    /// The i32 twin of [`Self::take_f32_len`].
    pub fn take_i32_len(&mut self, len: usize, shape: &[i64]) -> HostTensor {
        if let Some(i) = self
            .i32_free
            .iter()
            .position(|t| t.len() == len && t.shape_capacity() >= shape.len())
        {
            self.hits += 1;
            let mut t = self.i32_free.swap_remove(i);
            t.set_shape(shape);
            t
        } else {
            self.misses += 1;
            HostTensor::I32 { data: vec![0i32; len], shape: shape.to_vec() }
        }
    }

    /// The i32 twin of [`Self::take_f32`].
    pub fn take_i32(&mut self, shape: &[i64]) -> HostTensor {
        self.take_i32_len(elems(shape), shape)
    }

    /// Return a tensor's buffers to the pool (or drop it when the free
    /// list is at its bound).
    pub fn give(&mut self, t: HostTensor) {
        let list = match &t {
            HostTensor::F32 { .. } => &mut self.f32_free,
            HostTensor::I32 { .. } => &mut self.i32_free,
        };
        if list.len() < self.limit {
            list.push(t);
            self.recycled += 1;
        } else {
            self.dropped += 1;
        }
    }
}

/// Element count of a shape (empty shape = scalar = 1 element).
fn elems(shape: &[i64]) -> usize {
    shape.iter().map(|&d| d.max(0) as usize).product()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_miss_then_hit_round_trip() {
        let mut p = BufferPool::new();
        let t = p.take_f32(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!((p.hits, p.misses), (0, 1));
        p.give(t);
        assert_eq!(p.len(), 1);
        // same element count, different logical shape: the buffer is
        // recycled and the shape rewritten in place
        let t2 = p.take_f32(&[6]);
        assert_eq!(t2.len(), 6);
        assert_eq!(t2.shape(), &[6]);
        assert_eq!((p.hits, p.misses), (1, 1));
        assert!(p.is_empty());
    }

    #[test]
    fn exact_size_matching_never_reuses_a_wrong_buffer() {
        let mut p = BufferPool::new();
        p.give(HostTensor::vec_f32(vec![0.0; 4]));
        let t = p.take_f32(&[8]);
        assert_eq!(t.len(), 8, "a 4-element buffer must not serve an 8-element take");
        assert_eq!(p.misses, 1);
        assert_eq!(p.len(), 1, "the mismatched buffer stays pooled");
    }

    #[test]
    fn rank_collisions_do_not_cross_classes() {
        // a [6] gradient-style buffer (shape capacity 1) must not serve
        // a rank-2 take of the same element count — set_shape would have
        // to grow the shape vector, an allocation the pool exists to avoid
        let mut p = BufferPool::new();
        p.give(HostTensor::vec_f32(vec![0.0; 6]));
        let t = p.take_f32(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(p.misses, 1, "rank-1 buffer must be skipped for a rank-2 take");
        assert_eq!(p.len(), 1, "the skipped buffer stays pooled");
        // and the recycled rank-2 buffer serves both rank-2 and rank-1
        p.give(t);
        let t1 = p.take_f32(&[6]);
        assert_eq!(p.hits, 1);
        assert_eq!(t1.shape(), &[6]);
    }

    #[test]
    fn dtypes_have_independent_lists() {
        let mut p = BufferPool::new();
        p.give(HostTensor::I32 { data: vec![0; 4], shape: vec![4] });
        let t = p.take_f32(&[4]);
        assert!(matches!(t, HostTensor::F32 { .. }));
        assert_eq!(p.misses, 1);
        let t2 = p.take_i32(&[4]);
        assert!(matches!(t2, HostTensor::I32 { .. }));
        assert_eq!(p.hits, 1);
    }

    #[test]
    fn scalar_shape_is_one_element() {
        let mut p = BufferPool::new();
        let t = p.take_f32(&[]);
        assert_eq!(t.len(), 1);
        assert_eq!(t.shape(), &[] as &[i64]);
    }

    #[test]
    fn bounded_list_drops_excess_returns() {
        let mut p = BufferPool::with_limit(2);
        for _ in 0..4 {
            p.give(HostTensor::vec_f32(vec![0.0; 2]));
        }
        assert_eq!(p.len(), 2);
        assert_eq!((p.recycled, p.dropped), (2, 2));
    }
}
