//! PJRT client + compiled-executable wrappers.
//!
//! Pattern from /opt/xla-example/load_hlo: HLO text →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`.  Artifacts are lowered with
//! `return_tuple=True`, so every execution returns one tuple literal we
//! decompose into per-output literals.
//!
//! The `xla` names below resolve to the vendored
//! [`crate::runtime::pjrt_stub`] — an in-tree PJRT-shaped client with
//! the same API slice (create / compile / upload / execute /
//! donation aliases), so this module and its twin tests build and run
//! in CI; swapping in the real `xla` crate is a one-line alias change.

use crate::runtime::pjrt_stub as xla;
use std::path::Path;
use std::time::Instant;

/// A PJRT client (CPU) plus compile statistics.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> anyhow::Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: &Path) -> anyhow::Result<Executable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable {
            exe,
            name: path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
            compile_time: t0.elapsed(),
        })
    }

    /// Upload host data to a device-resident buffer.  Buffers are
    /// RAII-managed (`PjRtBuffer: Drop`) — this path, together with
    /// [`Executable::run_buffers`], avoids the upstream `xla` crate's
    /// `execute()` input-buffer leak (its C shim `release()`s every
    /// uploaded input device buffer and never frees it; ~600 MB/step at
    /// tiny scale, OOM within ~60 steps).
    ///
    /// Uses `buffer_from_host_buffer` (synchronous
    /// `kImmutableOnlyDuringCall` semantics) — NOT
    /// `buffer_from_host_literal`, whose underlying
    /// `BufferFromHostLiteral` copies *asynchronously* and races with the
    /// literal's Drop (observed as a PJRT size-mismatch CHECK crash).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// See [`Self::upload_f32`].
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload a literal by copying through a host slice (dtype-dispatched;
    /// safe-synchronous, see [`Self::upload_f32`]).
    pub fn upload_literal(&self, lit: &xla::Literal) -> anyhow::Result<xla::PjRtBuffer> {
        upload_literal_via(&self.client, lit)
    }

    /// Load every named artifact from a manifest directory.
    pub fn load_named(
        &self,
        manifest: &super::Manifest,
        names: &[&str],
    ) -> anyhow::Result<std::collections::HashMap<String, Executable>> {
        let mut out = std::collections::HashMap::new();
        for &name in names {
            let exe = self.load(&manifest.path_of(name)?)?;
            out.insert(name.to_string(), exe);
        }
        Ok(out)
    }
}

/// Synchronous literal upload through a host-slice copy (see
/// [`Runtime::upload_f32`] for why the literal path is unsafe).
fn upload_literal_via(
    client: &xla::PjRtClient,
    lit: &xla::Literal,
) -> anyhow::Result<xla::PjRtBuffer> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match lit.element_type()? {
        xla::ElementType::F32 => {
            Ok(client.buffer_from_host_buffer(&lit.to_vec::<f32>()?, &dims, None)?)
        }
        xla::ElementType::S32 => {
            Ok(client.buffer_from_host_buffer(&lit.to_vec::<i32>()?, &dims, None)?)
        }
        other => anyhow::bail!("unsupported input dtype {other:?}"),
    }
}

/// One compiled stage function.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub compile_time: std::time::Duration,
}

impl Executable {
    /// Execute with literal inputs; returns the decomposed output tuple.
    ///
    /// Inputs are uploaded to RAII-managed device buffers and executed
    /// via `execute_b` — NOT via the crate's `execute()`, whose C shim
    /// leaks every input device buffer (see [`Runtime::upload_f32`]).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let client = self.exe.client();
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|l| upload_literal_via(client, l.borrow()))
            .collect::<anyhow::Result<_>>()?;
        self.run_buffers(&bufs)
    }

    /// Execute with device-resident inputs (e.g. parameters kept on
    /// device across a whole step); returns the decomposed output tuple.
    pub fn run_buffers<B: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        inputs: &[B],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        self.run_buffers_donating(inputs, &[])
    }

    /// [`Self::run_buffers`] with donation: the inputs at `donated`
    /// positions are consumed by this execution (PJRT's
    /// `SetUpAlias`-style ownership transfer) and must not be used
    /// afterwards — `execute_pooled` routes every `Owned` argument
    /// here so its device buffer is released the moment the
    /// computation finishes with it.
    pub fn run_buffers_donating<B: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        inputs: &[B],
        donated: &[usize],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let opts = xla::ExecuteOptions { donated_input_indices: donated.to_vec() };
        let bufs = self.exe.execute_b_with_options::<B>(inputs, &opts)?;
        let tuple = bufs[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Execute and return the single output (artifacts like `*_fwd`).
    pub fn run1<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> anyhow::Result<xla::Literal> {
        let mut out = self.run(inputs)?;
        anyhow::ensure!(out.len() == 1, "{}: expected 1 output, got {}", self.name, out.len());
        Ok(out.pop().unwrap())
    }

    /// [`Self::run_buffers`] for single-output artifacts.
    pub fn run1_buffers<B: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        inputs: &[B],
    ) -> anyhow::Result<xla::Literal> {
        let mut out = self.run_buffers(inputs)?;
        anyhow::ensure!(out.len() == 1, "{}: expected 1 output, got {}", self.name, out.len());
        Ok(out.pop().unwrap())
    }
}

/// Decompose a literal into a [`HostTensor`] (shape + host copy).
fn literal_to_host(lit: &xla::Literal) -> anyhow::Result<crate::runtime::HostTensor> {
    use crate::runtime::HostTensor;
    let shape = lit.array_shape()?;
    let dims: Vec<i64> = shape.dims().iter().map(|&d| d as i64).collect();
    match lit.element_type()? {
        xla::ElementType::F32 => {
            Ok(HostTensor::F32 { data: lit.to_vec::<f32>()?, shape: dims })
        }
        xla::ElementType::S32 => {
            Ok(HostTensor::I32 { data: lit.to_vec::<i32>()?, shape: dims })
        }
        other => anyhow::bail!("unsupported output dtype {other:?}"),
    }
}

/// The PJRT path as a [`crate::runtime::Backend`]: compile loads the
/// artifact's HLO file from the manifest directory; upload goes through
/// the leak-free `buffer_from_host_buffer` path; execute decomposes the
/// output tuple into host tensors.
impl crate::runtime::Backend for Runtime {
    type Exec = Executable;
    type Buffer = xla::PjRtBuffer;

    fn create(_manifest: &crate::runtime::Manifest) -> anyhow::Result<Self> {
        Runtime::cpu()
    }

    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(
        &self,
        manifest: &crate::runtime::Manifest,
        name: &str,
    ) -> anyhow::Result<Executable> {
        self.load(&manifest.path_of(name)?)
    }

    fn upload(&self, t: &crate::runtime::HostTensor) -> anyhow::Result<xla::PjRtBuffer> {
        use crate::runtime::HostTensor;
        let dims: Vec<usize> = t.shape().iter().map(|&d| d as usize).collect();
        match t {
            HostTensor::F32 { data, .. } => self.upload_f32(data, &dims),
            HostTensor::I32 { data, .. } => self.upload_i32(data, &dims),
        }
    }

    fn execute(
        &self,
        exe: &Executable,
        inputs: &[&xla::PjRtBuffer],
    ) -> anyhow::Result<Vec<crate::runtime::HostTensor>> {
        let outs = exe.run_buffers(inputs)?;
        outs.iter().map(literal_to_host).collect()
    }

    /// The PJRT mapping of donation: every `Owned` argument's device
    /// buffer is **donated to the computation** — its position lands in
    /// the execute options' donated-input set
    /// ([`Executable::run_buffers_donating`], PJRT's `SetUpAlias`-style
    /// ownership transfer), so the runtime may reuse its storage for
    /// outputs and the buffer is invalid (and RAII-freed) the moment
    /// the call returns.  That is what keeps steady-state device memory
    /// flat.  Donated *host* buffers are dropped, not pooled: outputs
    /// come back through `Literal::to_vec` (which allocates
    /// internally), so pooling the large donated activations would only
    /// pin dead host memory the backend can never hand out again — the
    /// pool here serves the coordinator's own small-buffer cycles
    /// (gradient accumulators, loss scalars), nothing more.
    fn execute_pooled(
        &self,
        exe: &Executable,
        params: Option<&xla::PjRtBuffer>,
        args: &mut [crate::runtime::Arg<'_>],
        _pool: &mut crate::runtime::BufferPool,
        out: &mut Vec<crate::runtime::HostTensor>,
    ) -> anyhow::Result<()> {
        out.clear();
        let offset = usize::from(params.is_some());
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(args.len());
        let mut donated: Vec<usize> = Vec::new();
        for (i, a) in args.iter_mut().enumerate() {
            match a.take() {
                crate::runtime::ArgVal::Ref(t) => bufs.push(self.upload(t)?),
                crate::runtime::ArgVal::Owned(t) => {
                    bufs.push(self.upload(&t)?);
                    donated.push(offset + i);
                    drop(t);
                }
            }
        }
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(bufs.len() + 1);
        if let Some(p) = params {
            refs.push(p);
        }
        refs.extend(bufs.iter());
        let outs = exe.run_buffers_donating(&refs, &donated)?;
        for lit in &outs {
            out.push(literal_to_host(lit)?);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Minimal HLO-text module: f(x) = (x + x,) over f32[4].
    const ADD_HLO: &str = r#"HloModule jit_fn, entry_computation_layout={(f32[4]{0})->(f32[4]{0})}

ENTRY main.4 {
  Arg_0.1 = f32[4]{0} parameter(0)
  add.2 = f32[4]{0} add(Arg_0.1, Arg_0.1)
  ROOT tuple.3 = (f32[4]{0}) tuple(add.2)
}
"#;

    #[test]
    fn cpu_client_loads_and_runs_hlo_text() {
        let dir = std::env::temp_dir().join(format!("bpipe-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("add.hlo.txt");
        std::fs::File::create(&path).unwrap().write_all(ADD_HLO.as_bytes()).unwrap();
        let rt = Runtime::cpu().unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
        let exe = rt.load(&path).unwrap();
        let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]);
        let out = exe.run1(&[x]).unwrap();
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![2f32, 4., 6., 8.]);
    }

    #[test]
    fn missing_file_is_error() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.load(Path::new("/nonexistent/x.hlo.txt")).is_err());
    }

    #[test]
    fn donated_inputs_are_consumed_by_execution() {
        // execute_pooled's Owned→donated mapping, exercised at the
        // run_buffers_donating layer: the result is correct and the
        // donated device buffer is invalid afterwards (real PJRT
        // rejects donated buffers the same way)
        let dir = std::env::temp_dir().join(format!("bpipe-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("add_donate.hlo.txt");
        std::fs::File::create(&path).unwrap().write_all(ADD_HLO.as_bytes()).unwrap();
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load(&path).unwrap();
        let buf = rt.upload_f32(&[1., 2., 3., 4.], &[4]).unwrap();
        let out = exe.run_buffers_donating(&[&buf], &[0]).unwrap();
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![2f32, 4., 6., 8.]);
        assert!(buf.to_literal_sync().is_err(), "donated buffer must be consumed");
        assert!(exe.run_buffers(&[&buf]).is_err(), "consumed buffer must not re-execute");
    }
}
