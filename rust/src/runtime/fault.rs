//! Deterministic fault injection for the supervised training runtime.
//!
//! A [`FaultPlan`] is a seeded, fully explicit description of the faults
//! a run must survive — which stage fails, at which global step, and
//! how.  The plan is *armed* once and each fault is consume-once
//! (atomically), so a recovered run that replays the faulty step does
//! not re-trip the same fault forever: the supervisor's
//! checkpoint–re-plan–resume loop terminates.
//!
//! Faults are realized by [`FaultyBackend`], a transparent [`Backend`]
//! wrapper that any worker can run on.  It learns its stage identity and
//! the current global step through the [`Backend::bind_stage`] /
//! [`Backend::begin_step`] hooks and injects at exactly three points:
//!
//! * `begin_step` — worker crash (typed error), worker panic (a real
//!   `panic!`, exercising the poisoned-join path), channel stall (the
//!   worker goes silent for `stall_ms`, so its *neighbors'* deadline
//!   waits fire), and HBM cap reduction (a typed
//!   [`InjectedFault::HbmCap`] the supervisor answers with a re-plan);
//! * `execute` / `execute_pooled` — transient execution failures with a
//!   bounded budget, retried in place by the stage runner.
//!
//! The feeder has no backend, so its stall fault is consulted directly
//! by the pipeline's feeder loop ([`FaultPlan::feeder_stall_due`]).
//!
//! Plans are installed process-globally ([`install`], RAII-scoped) —
//! workers create their own backend instances on their own threads, and
//! the registry is how a `FaultyBackend::create` call finds the plan
//! without widening the [`Backend`] constructor.  JSON round-trip
//! ([`FaultPlan::from_json`] / [`FaultPlan::to_json`]) backs the
//! `bpipe train --faults plan.json` surface using the in-tree
//! dependency-free [`Json`] parser.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::runtime::{Arg, Backend, BufferPool, HostTensor, Manifest};
use crate::util::json::Json;

/// One injectable fault.  `step` is the GLOBAL 1-based training step the
/// fault arms at; a fault fires the first time its stage reaches any
/// step ≥ `step` (so resume-time step skips cannot dodge it), then never
/// again.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Stage worker fails with a typed error at the start of step `step`.
    Crash { stage: u64, step: u64 },
    /// Stage worker literally panics (poisoned-join path).
    Panic { stage: u64, step: u64 },
    /// The next `failures` executions on `stage` (from step `step`) fail
    /// transiently; the runner retries them within its budget.
    TransientExec { stage: u64, step: u64, failures: u32 },
    /// Stage worker goes silent for `stall_ms` at the start of `step` —
    /// neighbors must detect it via channel deadlines, not hang.
    ChannelStall { stage: u64, step: u64, stall_ms: u64 },
    /// The data feeder goes silent for `stall_ms` at the start of `step`.
    FeederStall { step: u64, stall_ms: u64 },
    /// The stage's HBM capacity drops to `cap_bytes` at step `step`; the
    /// supervisor must re-plan under the tighter bound or abort.
    HbmCap { stage: u64, step: u64, cap_bytes: u64 },
}

impl Fault {
    fn kind(&self) -> &'static str {
        match self {
            Fault::Crash { .. } => "crash",
            Fault::Panic { .. } => "panic",
            Fault::TransientExec { .. } => "transient_exec",
            Fault::ChannelStall { .. } => "channel_stall",
            Fault::FeederStall { .. } => "feeder_stall",
            Fault::HbmCap { .. } => "hbm_cap",
        }
    }
}

/// A fault plus its consume-once firing state (shared across restart
/// attempts through the `Arc<FaultPlan>`).
#[derive(Debug)]
struct Armed {
    fault: Fault,
    /// Replica scope: `None` arms the fault for any querier (the
    /// single-pipeline default, and the pre-fleet JSON back-compat
    /// shape); `Some(r)` arms it for fleet replica `r` ONLY — a plain
    /// (replica-less) run never consumes it, and in a fleet exactly one
    /// replica does, which is what makes chaos tests deterministic
    /// under R concurrent pipelines.
    replica: Option<usize>,
    fired: AtomicBool,
    /// remaining transient failures ([`Fault::TransientExec`] only)
    remaining: AtomicU32,
}

impl Armed {
    fn from_scoped(replica: Option<usize>, fault: Fault) -> Self {
        let remaining = match fault {
            Fault::TransientExec { failures, .. } => failures,
            _ => 0,
        };
        Armed { fault, replica, fired: AtomicBool::new(false), remaining: AtomicU32::new(remaining) }
    }

    /// Scope rule: an unscoped fault matches every querier; a
    /// replica-scoped fault matches only that replica's querier.
    fn scope_matches(&self, querier: Option<usize>) -> bool {
        self.replica.map_or(true, |r| querier == Some(r))
    }
}

/// A deterministic, seeded set of faults to inject into one supervised
/// run.  All query methods take `&self` — firing state is atomic, so one
/// plan serves every worker thread across every restart attempt.
#[derive(Debug)]
pub struct FaultPlan {
    pub seed: u64,
    armed: Vec<Armed>,
}

impl FaultPlan {
    /// An unscoped plan: every fault is armed for any querier (the
    /// single-pipeline shape every pre-fleet caller uses).
    pub fn new(seed: u64, faults: Vec<Fault>) -> Self {
        Self::new_scoped(seed, faults.into_iter().map(|f| (None, f)).collect())
    }

    /// A plan whose faults carry an explicit replica scope each
    /// (`None` = any querier, `Some(r)` = fleet replica `r` only).
    pub fn new_scoped(seed: u64, faults: Vec<(Option<usize>, Fault)>) -> Self {
        let armed = faults.into_iter().map(|(r, f)| Armed::from_scoped(r, f)).collect();
        Self { seed, armed }
    }

    /// A single seeded crash at a pseudo-random (stage, step) — the
    /// simplest chaos plan, derived entirely from `seed`.
    pub fn sampled_crash(seed: u64, stages: u64, steps: u64) -> Self {
        let mut rng = crate::util::SplitMix64::new(seed);
        let stage = rng.next_u64() % stages.max(1);
        let step = 1 + rng.next_u64() % steps.max(1);
        Self::new(seed, vec![Fault::Crash { stage, step }])
    }

    pub fn faults(&self) -> Vec<Fault> {
        self.armed.iter().map(|a| a.fault.clone()).collect()
    }

    /// Every fault with its replica scope (round-trip twin of
    /// [`FaultPlan::new_scoped`]).
    pub fn scoped_faults(&self) -> Vec<(Option<usize>, Fault)> {
        self.armed.iter().map(|a| (a.replica, a.fault.clone())).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.armed.is_empty()
    }

    /// Re-arm every fault (used by tests that replay one plan).
    pub fn rearm(&self) {
        for a in &self.armed {
            a.fired.store(false, Ordering::SeqCst);
            if let Fault::TransientExec { failures, .. } = a.fault {
                a.remaining.store(failures, Ordering::SeqCst);
            }
        }
    }

    /// Fire-once helper: consume the first matching un-fired fault whose
    /// replica scope admits `querier`.
    fn consume(
        &self,
        querier: Option<usize>,
        pred: impl Fn(&Fault) -> bool,
    ) -> Option<&Fault> {
        for a in &self.armed {
            if a.scope_matches(querier)
                && pred(&a.fault)
                && !a.fired.swap(true, Ordering::SeqCst)
            {
                return Some(&a.fault);
            }
            // keep scanning: an already-fired fault must not shadow a
            // later-armed one of the same kind
        }
        None
    }

    /// Does a [`Fault::Crash`] fire for `stage` at global step `step`?
    pub fn crash_due(&self, stage: u64, step: u64) -> bool {
        self.crash_due_for(None, stage, step)
    }

    /// [`FaultPlan::crash_due`] as queried by fleet replica `replica`.
    pub fn crash_due_for(&self, replica: Option<usize>, stage: u64, step: u64) -> bool {
        self.consume(
            replica,
            |f| matches!(f, Fault::Crash { stage: s, step: k } if *s == stage && step >= *k),
        )
        .is_some()
    }

    /// Does a [`Fault::Panic`] fire for `stage` at global step `step`?
    pub fn panic_due(&self, stage: u64, step: u64) -> bool {
        self.panic_due_for(None, stage, step)
    }

    /// [`FaultPlan::panic_due`] as queried by fleet replica `replica`.
    pub fn panic_due_for(&self, replica: Option<usize>, stage: u64, step: u64) -> bool {
        self.consume(
            replica,
            |f| matches!(f, Fault::Panic { stage: s, step: k } if *s == stage && step >= *k),
        )
        .is_some()
    }

    /// Channel stall duration (ms) for `stage` at `step`, if one fires.
    pub fn stall_due(&self, stage: u64, step: u64) -> Option<u64> {
        self.stall_due_for(None, stage, step)
    }

    /// [`FaultPlan::stall_due`] as queried by fleet replica `replica`.
    pub fn stall_due_for(&self, replica: Option<usize>, stage: u64, step: u64) -> Option<u64> {
        match self.consume(
            replica,
            |f| matches!(f, Fault::ChannelStall { stage: s, step: k, .. } if *s == stage && step >= *k),
        ) {
            Some(Fault::ChannelStall { stall_ms, .. }) => Some(*stall_ms),
            _ => None,
        }
    }

    /// Feeder stall duration (ms) at `step`, if one fires.
    pub fn feeder_stall_due(&self, step: u64) -> Option<u64> {
        self.feeder_stall_due_for(None, step)
    }

    /// [`FaultPlan::feeder_stall_due`] as queried by replica `replica`.
    pub fn feeder_stall_due_for(&self, replica: Option<usize>, step: u64) -> Option<u64> {
        match self.consume(replica, |f| matches!(f, Fault::FeederStall { step: k, .. } if step >= *k))
        {
            Some(Fault::FeederStall { stall_ms, .. }) => Some(*stall_ms),
            _ => None,
        }
    }

    /// New HBM cap (bytes) for `stage` at `step`, if one fires.
    pub fn hbm_cap_due(&self, stage: u64, step: u64) -> Option<u64> {
        self.hbm_cap_due_for(None, stage, step)
    }

    /// [`FaultPlan::hbm_cap_due`] as queried by fleet replica `replica`.
    pub fn hbm_cap_due_for(&self, replica: Option<usize>, stage: u64, step: u64) -> Option<u64> {
        match self.consume(
            replica,
            |f| matches!(f, Fault::HbmCap { stage: s, step: k, .. } if *s == stage && step >= *k),
        ) {
            Some(Fault::HbmCap { cap_bytes, .. }) => Some(*cap_bytes),
            _ => None,
        }
    }

    /// Should the next execution on `stage` at global step `step` fail
    /// transiently?  Decrements the fault's remaining budget.
    pub fn exec_should_fail(&self, stage: u64, step: u64) -> bool {
        self.exec_should_fail_for(None, stage, step)
    }

    /// [`FaultPlan::exec_should_fail`] as queried by replica `replica`.
    pub fn exec_should_fail_for(&self, replica: Option<usize>, stage: u64, step: u64) -> bool {
        for a in &self.armed {
            if !a.scope_matches(replica) {
                continue;
            }
            if let Fault::TransientExec { stage: s, step: k, .. } = a.fault {
                if s == stage && step >= k {
                    let took = a
                        .remaining
                        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |r| r.checked_sub(1));
                    if took.is_ok() {
                        return true;
                    }
                }
            }
        }
        false
    }

    // -- JSON surface -------------------------------------------------------

    /// Parse a plan from its JSON form:
    ///
    /// ```json
    /// {"seed": 0, "faults": [
    ///   {"kind": "crash", "stage": 1, "step": 3},
    ///   {"kind": "crash", "stage": 1, "step": 3, "replica": 1},
    ///   {"kind": "transient_exec", "stage": 0, "step": 2, "failures": 2},
    ///   {"kind": "channel_stall", "stage": 1, "step": 2, "stall_ms": 800},
    ///   {"kind": "feeder_stall", "step": 2, "stall_ms": 800},
    ///   {"kind": "hbm_cap", "stage": 0, "step": 3, "cap_bytes": 2048}
    /// ]}
    /// ```
    ///
    /// The optional `"replica"` field scopes a fault to one fleet
    /// replica (`bpipe serve`); omitted — the back-compat default —
    /// the fault is armed for any querier.
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let root = Json::parse(text).map_err(|e| anyhow::anyhow!("fault plan JSON: {e}"))?;
        let seed = root.get("seed").and_then(|j| j.as_u64()).unwrap_or(0);
        let mut faults: Vec<(Option<usize>, Fault)> = Vec::new();
        let arr = root
            .get("faults")
            .and_then(|j| j.as_arr())
            .ok_or_else(|| anyhow::anyhow!("fault plan needs a \"faults\" array"))?;
        for (i, f) in arr.iter().enumerate() {
            let kind = f
                .get("kind")
                .and_then(|j| j.as_str())
                .ok_or_else(|| anyhow::anyhow!("fault #{i}: missing \"kind\""))?;
            let field = |key: &str| -> anyhow::Result<u64> {
                f.get(key)
                    .and_then(|j| j.as_u64())
                    .ok_or_else(|| anyhow::anyhow!("fault #{i} ({kind}): missing \"{key}\""))
            };
            let fault = match kind {
                "crash" => Fault::Crash { stage: field("stage")?, step: field("step")? },
                "panic" => Fault::Panic { stage: field("stage")?, step: field("step")? },
                "transient_exec" => Fault::TransientExec {
                    stage: field("stage")?,
                    step: field("step")?,
                    failures: field("failures")? as u32,
                },
                "channel_stall" => Fault::ChannelStall {
                    stage: field("stage")?,
                    step: field("step")?,
                    stall_ms: field("stall_ms")?,
                },
                "feeder_stall" => {
                    Fault::FeederStall { step: field("step")?, stall_ms: field("stall_ms")? }
                }
                "hbm_cap" => Fault::HbmCap {
                    stage: field("stage")?,
                    step: field("step")?,
                    cap_bytes: field("cap_bytes")?,
                },
                other => anyhow::bail!("fault #{i}: unknown kind {other:?}"),
            };
            let step = match &fault {
                Fault::Crash { step, .. }
                | Fault::Panic { step, .. }
                | Fault::TransientExec { step, .. }
                | Fault::ChannelStall { step, .. }
                | Fault::FeederStall { step, .. }
                | Fault::HbmCap { step, .. } => *step,
            };
            anyhow::ensure!(step >= 1, "fault #{i} ({kind}): steps are 1-based, got {step}");
            let replica = f.get("replica").and_then(|j| j.as_u64()).map(|r| r as usize);
            faults.push((replica, fault));
        }
        Ok(Self::new_scoped(seed, faults))
    }

    /// Load a plan from a JSON file (the `--faults plan.json` surface).
    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read fault plan {path:?}: {e}"))?;
        Self::from_json(&text)
    }

    pub fn to_json(&self) -> Json {
        let faults: Vec<Json> = self
            .armed
            .iter()
            .map(|a| {
                let mut pairs = vec![("kind", Json::str(a.fault.kind()))];
                match &a.fault {
                    Fault::Crash { stage, step } | Fault::Panic { stage, step } => {
                        pairs.push(("stage", Json::Num(*stage as f64)));
                        pairs.push(("step", Json::Num(*step as f64)));
                    }
                    Fault::TransientExec { stage, step, failures } => {
                        pairs.push(("stage", Json::Num(*stage as f64)));
                        pairs.push(("step", Json::Num(*step as f64)));
                        pairs.push(("failures", Json::Num(*failures as f64)));
                    }
                    Fault::ChannelStall { stage, step, stall_ms } => {
                        pairs.push(("stage", Json::Num(*stage as f64)));
                        pairs.push(("step", Json::Num(*step as f64)));
                        pairs.push(("stall_ms", Json::Num(*stall_ms as f64)));
                    }
                    Fault::FeederStall { step, stall_ms } => {
                        pairs.push(("step", Json::Num(*step as f64)));
                        pairs.push(("stall_ms", Json::Num(*stall_ms as f64)));
                    }
                    Fault::HbmCap { stage, step, cap_bytes } => {
                        pairs.push(("stage", Json::Num(*stage as f64)));
                        pairs.push(("step", Json::Num(*step as f64)));
                        pairs.push(("cap_bytes", Json::Num(*cap_bytes as f64)));
                    }
                }
                if let Some(r) = a.replica {
                    pairs.push(("replica", Json::Num(r as f64)));
                }
                Json::obj(pairs)
            })
            .collect();
        Json::obj(vec![("seed", Json::Num(self.seed as f64)), ("faults", Json::Arr(faults))])
    }
}

/// Typed error a [`FaultyBackend`] surfaces; the worker/supervisor
/// classify failures by downcasting to this through the anyhow chain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InjectedFault {
    Crash { stage: u64, step: u64 },
    TransientExec { stage: u64, step: u64 },
    HbmCap { stage: u64, step: u64, cap_bytes: u64 },
}

impl std::fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InjectedFault::Crash { stage, step } => {
                write!(f, "injected crash at stage {stage}, step {step}")
            }
            InjectedFault::TransientExec { stage, step } => {
                write!(f, "injected transient execute failure at stage {stage}, step {step}")
            }
            InjectedFault::HbmCap { stage, step, cap_bytes } => {
                write!(f, "injected HBM cap reduction to {cap_bytes} B at stage {stage}, step {step}")
            }
        }
    }
}

impl std::error::Error for InjectedFault {}

// -- process-global plan registry -------------------------------------------
//
// Workers construct their backends on their own threads via
// `B::create(&manifest)`; the registry lets `FaultyBackend::create` pick
// up the active plan without changing the Backend constructor.  The
// supervisor installs a plan for the duration of one supervised run.

static INSTALLED: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);

fn registry() -> std::sync::MutexGuard<'static, Option<Arc<FaultPlan>>> {
    INSTALLED.lock().unwrap_or_else(|p| p.into_inner())
}

/// Install `plan` as the process-global fault plan; the previous plan is
/// restored when the returned guard drops.
pub fn install(plan: Arc<FaultPlan>) -> FaultGuard {
    FaultGuard { prev: registry().replace(plan) }
}

/// The currently installed plan, if any.
pub fn installed() -> Option<Arc<FaultPlan>> {
    registry().clone()
}

/// RAII scope for an installed [`FaultPlan`].
pub struct FaultGuard {
    prev: Option<Arc<FaultPlan>>,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        *registry() = self.prev.take();
    }
}

/// A transparent [`Backend`] wrapper injecting the installed
/// [`FaultPlan`]'s faults at the step boundary and execute call sites.
/// With no plan installed it is a pure passthrough.
pub struct FaultyBackend<B: Backend> {
    inner: B,
    plan: Option<Arc<FaultPlan>>,
    stage: Cell<u64>,
    step: Cell<u64>,
    /// Fleet replica this backend serves (`None` outside `bpipe serve`);
    /// scopes every plan query so a replica-scoped fault hits exactly
    /// the replica it names.
    replica: Cell<Option<usize>>,
}

impl<B: Backend> FaultyBackend<B> {
    fn maybe_fail_exec(&self) -> anyhow::Result<()> {
        if let Some(p) = &self.plan {
            let (stage, step) = (self.stage.get(), self.step.get());
            if p.exec_should_fail_for(self.replica.get(), stage, step) {
                return Err(anyhow::Error::new(InjectedFault::TransientExec { stage, step }));
            }
        }
        Ok(())
    }
}

impl<B: Backend> Backend for FaultyBackend<B> {
    type Exec = B::Exec;
    type Buffer = B::Buffer;

    fn create(manifest: &Manifest) -> anyhow::Result<Self> {
        Ok(Self {
            inner: B::create(manifest)?,
            plan: installed(),
            stage: Cell::new(0),
            step: Cell::new(0),
            replica: Cell::new(None),
        })
    }

    fn platform(&self) -> String {
        format!("faulty+{}", self.inner.platform())
    }

    fn bind_stage(&mut self, stage: u64) {
        self.stage.set(stage);
        self.inner.bind_stage(stage);
    }

    fn bind_replica(&mut self, replica: usize) {
        self.replica.set(Some(replica));
        self.inner.bind_replica(replica);
    }

    fn begin_step(&self, global_step: u64) -> anyhow::Result<()> {
        self.step.set(global_step);
        self.inner.begin_step(global_step)?;
        if let Some(p) = &self.plan {
            let stage = self.stage.get();
            let replica = self.replica.get();
            if let Some(ms) = p.stall_due_for(replica, stage, global_step) {
                // go silent: neighbors must detect this via deadlines
                std::thread::sleep(Duration::from_millis(ms));
            }
            if p.panic_due_for(replica, stage, global_step) {
                panic!("injected panic at stage {stage}, step {global_step}");
            }
            if p.crash_due_for(replica, stage, global_step) {
                return Err(anyhow::Error::new(InjectedFault::Crash {
                    stage,
                    step: global_step,
                }));
            }
            if let Some(cap_bytes) = p.hbm_cap_due_for(replica, stage, global_step) {
                return Err(anyhow::Error::new(InjectedFault::HbmCap {
                    stage,
                    step: global_step,
                    cap_bytes,
                }));
            }
        }
        Ok(())
    }

    fn compile(&self, manifest: &Manifest, name: &str) -> anyhow::Result<Self::Exec> {
        self.inner.compile(manifest, name)
    }

    fn upload(&self, t: &HostTensor) -> anyhow::Result<Self::Buffer> {
        self.inner.upload(t)
    }

    fn upload_into(&self, t: &HostTensor, buf: &mut Self::Buffer) -> anyhow::Result<()> {
        self.inner.upload_into(t, buf)
    }

    fn execute(
        &self,
        exe: &Self::Exec,
        inputs: &[&Self::Buffer],
    ) -> anyhow::Result<Vec<HostTensor>> {
        self.maybe_fail_exec()?;
        self.inner.execute(exe, inputs)
    }

    /// Injects BEFORE delegating, so on an injected failure every `args`
    /// slot is still un-spent and the caller may retry the same call.
    fn execute_pooled(
        &self,
        exe: &Self::Exec,
        params: Option<&Self::Buffer>,
        args: &mut [Arg<'_>],
        pool: &mut BufferPool,
        out: &mut Vec<HostTensor>,
    ) -> anyhow::Result<()> {
        self.maybe_fail_exec()?;
        self.inner.execute_pooled(exe, params, args, pool, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faults_fire_once() {
        let p = FaultPlan::new(0, vec![Fault::Crash { stage: 1, step: 3 }]);
        assert!(!p.crash_due(1, 2), "not yet due");
        assert!(!p.crash_due(0, 3), "wrong stage");
        assert!(p.crash_due(1, 3), "fires at its step");
        assert!(!p.crash_due(1, 3), "consumed");
        assert!(!p.crash_due(1, 4), "stays consumed on replay");
        p.rearm();
        assert!(p.crash_due(1, 5), "≥ step catches resume skips");
    }

    #[test]
    fn transient_budget_decrements() {
        let p = FaultPlan::new(0, vec![Fault::TransientExec { stage: 0, step: 2, failures: 2 }]);
        assert!(!p.exec_should_fail(0, 1));
        assert!(p.exec_should_fail(0, 2));
        assert!(p.exec_should_fail(0, 5));
        assert!(!p.exec_should_fail(0, 5), "budget spent");
    }

    #[test]
    fn json_round_trip() {
        let plan = FaultPlan::new(
            7,
            vec![
                Fault::Crash { stage: 1, step: 3 },
                Fault::Panic { stage: 0, step: 2 },
                Fault::TransientExec { stage: 0, step: 2, failures: 2 },
                Fault::ChannelStall { stage: 1, step: 2, stall_ms: 800 },
                Fault::FeederStall { step: 2, stall_ms: 400 },
                Fault::HbmCap { stage: 0, step: 3, cap_bytes: 2048 },
            ],
        );
        let text = plan.to_json().to_string();
        let back = FaultPlan::from_json(&text).unwrap();
        assert_eq!(back.seed, 7);
        assert_eq!(back.faults(), plan.faults());
    }

    #[test]
    fn replica_scope_targets_exactly_one_replica() {
        let p = FaultPlan::new_scoped(
            0,
            vec![
                (Some(1), Fault::Crash { stage: 0, step: 2 }),
                (None, Fault::Panic { stage: 0, step: 3 }),
            ],
        );
        // a replica-scoped fault is invisible to a plain (replica-less)
        // run and to every other replica
        assert!(!p.crash_due(0, 2), "unscoped querier must not consume a scoped fault");
        assert!(!p.crash_due_for(Some(0), 0, 2), "wrong replica");
        assert!(!p.crash_due_for(Some(2), 0, 2), "wrong replica");
        assert!(p.crash_due_for(Some(1), 0, 2), "fires for replica 1 only");
        assert!(!p.crash_due_for(Some(1), 0, 2), "consumed");
        // an unscoped fault matches any querier — first to reach it wins
        assert!(p.panic_due_for(Some(0), 0, 3));
        assert!(!p.panic_due(0, 3), "consumed by replica 0's querier");
        // scoping applies to transient budgets too
        let t = FaultPlan::new_scoped(
            0,
            vec![(Some(2), Fault::TransientExec { stage: 1, step: 1, failures: 1 })],
        );
        assert!(!t.exec_should_fail(1, 1));
        assert!(!t.exec_should_fail_for(Some(0), 1, 1));
        assert!(t.exec_should_fail_for(Some(2), 1, 1));
        assert!(!t.exec_should_fail_for(Some(2), 1, 1), "budget spent");
    }

    #[test]
    fn json_round_trips_replica_scope_and_defaults_to_unscoped() {
        let plan = FaultPlan::new_scoped(
            3,
            vec![
                (Some(1), Fault::Crash { stage: 1, step: 2 }),
                (None, Fault::FeederStall { step: 2, stall_ms: 100 }),
            ],
        );
        let text = plan.to_json().to_string();
        assert!(text.contains("\"replica\""), "scoped fault must serialize its scope: {text}");
        let back = FaultPlan::from_json(&text).unwrap();
        assert_eq!(back.scoped_faults(), plan.scoped_faults());
        // back-compat: a plan without "replica" fields parses unscoped
        let legacy =
            FaultPlan::from_json(r#"{"seed": 7, "faults": [{"kind": "crash", "stage": 1, "step": 3}]}"#)
                .unwrap();
        assert_eq!(legacy.scoped_faults(), vec![(None, Fault::Crash { stage: 1, step: 3 })]);
        assert!(legacy.crash_due(1, 3), "unscoped fault still fires for a plain run");
    }

    #[test]
    fn json_rejects_malformed_plans() {
        assert!(FaultPlan::from_json("{}").is_err(), "missing faults array");
        assert!(
            FaultPlan::from_json(r#"{"faults": [{"kind": "meteor", "step": 1}]}"#).is_err(),
            "unknown kind"
        );
        assert!(
            FaultPlan::from_json(r#"{"faults": [{"kind": "crash", "stage": 0, "step": 0}]}"#)
                .is_err(),
            "steps are 1-based"
        );
        assert!(
            FaultPlan::from_json(r#"{"faults": [{"kind": "crash", "stage": 0}]}"#).is_err(),
            "missing step"
        );
    }

    #[test]
    fn sampled_crash_is_deterministic() {
        let a = FaultPlan::sampled_crash(42, 4, 10).faults();
        let b = FaultPlan::sampled_crash(42, 4, 10).faults();
        assert_eq!(a, b);
        match &a[0] {
            Fault::Crash { stage, step } => {
                assert!(*stage < 4 && (1..=10).contains(step));
            }
            other => panic!("expected a crash, got {other:?}"),
        }
    }

    #[test]
    fn install_scope_nests_and_restores() {
        // serialize against other tests touching the global registry
        let p1 = Arc::new(FaultPlan::new(1, vec![]));
        let p2 = Arc::new(FaultPlan::new(2, vec![]));
        let g1 = install(p1.clone());
        assert_eq!(installed().unwrap().seed, 1);
        {
            let _g2 = install(p2);
            assert_eq!(installed().unwrap().seed, 2);
        }
        assert_eq!(installed().unwrap().seed, 1, "inner scope restored the outer plan");
        drop(g1);
    }
}
