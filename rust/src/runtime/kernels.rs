//! Fixed-width f32 kernels for [`super::SimBackend`] — the fused
//! forward/backward/Adam loops, vectorized as explicit 8-lane chunks.
//!
//! ## Canonical reduction order
//!
//! Every reduction here accumulates **chunk-major into 8 lane
//! accumulators** and collapses them with a fixed tree ([`tree8`]):
//! lane `l` sums the elements at flat indices `l, 8+l, 16+l, …` (tail
//! elements land in lanes `0..n%8`), then
//! `((a0+a4)+(a2+a6)) + ((a1+a5)+(a3+a7))`.  That chunked order *is*
//! the crate's canonical numerics: the independent per-lane sums give
//! LLVM a straight-line 8-wide vector body (no loop-carried scalar
//! dependence, the reason the old sequential loops couldn't vectorize),
//! and the fixed tree keeps results bit-reproducible across runs,
//! donation masks, and backends.
//!
//! ## The mirrored scalar fallback
//!
//! Each kernel has a `*_scalar` twin that walks **lane-major** (one
//! lane's full element sequence at a time) — a genuinely different,
//! unvectorizable loop structure that performs the *same per-lane
//! addition sequence* and the same [`tree8`] collapse, so the two paths
//! are bit-identical by construction.  `rust/tests/property_kernels.rs`
//! pins that equivalence across donation masks, odd lengths, and
//! ±0.0/subnormal inputs; the twins are also the reference if a target
//! ever needs to opt out of the wide path.
//!
//! Elementwise kernels (affine, scale, fill, Adam) have no reduction,
//! so their twins differ only in loop shape and match trivially.

use crate::util::SplitMix64;

/// Accumulator width: 8 f32 lanes (one AVX2 register, two NEON ones).
pub const LANES: usize = 8;

/// Adam hyperparameters (the python side's defaults).
pub const BETA1: f32 = 0.9;
pub const BETA2: f32 = 0.999;
pub const EPS: f32 = 1e-8;

/// SplitMix64 finalizer over a raw index — the pseudo-embedding hash.
#[inline]
pub fn mix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic value in [−1, 1) from the hash's top 24 bits (exactly
/// representable in f32).
#[inline]
pub fn unit(x: u64) -> f32 {
    (mix(x) >> 40) as f32 / (1u64 << 23) as f32 - 1.0
}

/// The fixed pseudo-embedding of `(token, feature j)`.
#[inline]
pub fn emb(token: i32, j: u64) -> f32 {
    unit((token as u32 as u64).wrapping_mul(0x0100_0003).wrapping_add(j))
}

/// The canonical lane collapse: `((a0+a4)+(a2+a6)) + ((a1+a5)+(a3+a7))`.
#[inline]
fn tree8(acc: [f32; LANES]) -> f32 {
    let a0 = acc[0] + acc[4];
    let a1 = acc[1] + acc[5];
    let a2 = acc[2] + acc[6];
    let a3 = acc[3] + acc[7];
    (a0 + a2) + (a1 + a3)
}

/// Chunk-major single reduction: `Σ f(i)` in canonical order.
#[inline]
fn reduce1(n: usize, mut f: impl FnMut(usize) -> f32) -> f32 {
    let mut acc = [0f32; LANES];
    let full = n / LANES;
    for c in 0..full {
        let base = c * LANES;
        for (l, a) in acc.iter_mut().enumerate() {
            *a += f(base + l);
        }
    }
    let base = full * LANES;
    for l in 0..n - base {
        acc[l] += f(base + l);
    }
    tree8(acc)
}

/// Lane-major twin of [`reduce1`]: same per-lane addition sequence, same
/// tree, different loop nest.
#[inline]
fn reduce1_scalar(n: usize, mut f: impl FnMut(usize) -> f32) -> f32 {
    let mut acc = [0f32; LANES];
    let full = n / LANES;
    let base = full * LANES;
    for (l, a) in acc.iter_mut().enumerate() {
        let mut s = 0f32;
        for c in 0..full {
            s += f(c * LANES + l);
        }
        if base + l < n {
            s += f(base + l);
        }
        *a = s;
    }
    tree8(acc)
}

/// Chunk-major paired reduction: `(Σ f(i).0, Σ f(i).1)`, both in
/// canonical order (the fused `(g0, g1)` gradient accumulations).
#[inline]
fn reduce2(n: usize, mut f: impl FnMut(usize) -> (f32, f32)) -> (f32, f32) {
    let mut acc0 = [0f32; LANES];
    let mut acc1 = [0f32; LANES];
    let full = n / LANES;
    for c in 0..full {
        let base = c * LANES;
        for l in 0..LANES {
            let (t0, t1) = f(base + l);
            acc0[l] += t0;
            acc1[l] += t1;
        }
    }
    let base = full * LANES;
    for l in 0..n - base {
        let (t0, t1) = f(base + l);
        acc0[l] += t0;
        acc1[l] += t1;
    }
    (tree8(acc0), tree8(acc1))
}

/// Lane-major twin of [`reduce2`].
#[inline]
fn reduce2_scalar(n: usize, mut f: impl FnMut(usize) -> (f32, f32)) -> (f32, f32) {
    let mut acc0 = [0f32; LANES];
    let mut acc1 = [0f32; LANES];
    let full = n / LANES;
    let base = full * LANES;
    for l in 0..LANES {
        let (mut s0, mut s1) = (0f32, 0f32);
        for c in 0..full {
            let (t0, t1) = f(c * LANES + l);
            s0 += t0;
            s1 += t1;
        }
        if base + l < n {
            let (t0, t1) = f(base + l);
            s0 += t0;
            s1 += t1;
        }
        acc0[l] = s0;
        acc1[l] = s1;
    }
    (tree8(acc0), tree8(acc1))
}

/// `first_fwd`: fill `y[p·h + j] = w0·emb(tok[p], j) + w1` (elementwise
/// over the flat index, 8-wide chunks).
pub fn fwd_first_fill(y: &mut [f32], tok: &[i32], h: usize, w0: f32, w1: f32) {
    debug_assert_eq!(y.len(), tok.len() * h);
    let mut chunks = y.chunks_exact_mut(LANES);
    let mut i = 0;
    for chunk in &mut chunks {
        for o in chunk.iter_mut() {
            *o = w0 * emb(tok[i / h], (i % h) as u64) + w1;
            i += 1;
        }
    }
    for o in chunks.into_remainder() {
        *o = w0 * emb(tok[i / h], (i % h) as u64) + w1;
        i += 1;
    }
}

/// Lane-shape-free twin of [`fwd_first_fill`] (elementwise: same values
/// in any order; kept as the original nested `(position, feature)` walk).
pub fn fwd_first_fill_scalar(y: &mut [f32], tok: &[i32], h: usize, w0: f32, w1: f32) {
    debug_assert_eq!(y.len(), tok.len() * h);
    let mut i = 0;
    for &t in tok {
        for j in 0..h {
            y[i] = w0 * emb(t, j as u64) + w1;
            i += 1;
        }
    }
}

/// `mid_fwd`: `data[i] = scale·data[i] + shift` in place, 8-wide chunks.
pub fn affine_in_place(data: &mut [f32], scale: f32, shift: f32) {
    let mut chunks = data.chunks_exact_mut(LANES);
    for chunk in &mut chunks {
        for v in chunk.iter_mut() {
            *v = scale * *v + shift;
        }
    }
    for v in chunks.into_remainder() {
        *v = scale * *v + shift;
    }
}

/// Plain-loop twin of [`affine_in_place`].
pub fn affine_in_place_scalar(data: &mut [f32], scale: f32, shift: f32) {
    for v in data.iter_mut() {
        *v = scale * *v + shift;
    }
}

/// `mid_bwd` dx (donated-dy arm): `data[i] *= scale` in place.
pub fn scale_in_place(data: &mut [f32], scale: f32) {
    let mut chunks = data.chunks_exact_mut(LANES);
    for chunk in &mut chunks {
        for v in chunk.iter_mut() {
            *v *= scale;
        }
    }
    for v in chunks.into_remainder() {
        *v *= scale;
    }
}

/// Plain-loop twin of [`scale_in_place`].
pub fn scale_in_place_scalar(data: &mut [f32], scale: f32) {
    for v in data.iter_mut() {
        *v *= scale;
    }
}

/// `mid_bwd` dx (copy arms): `dst[i] = src[i]·scale`.
pub fn scale_into(dst: &mut [f32], src: &[f32], scale: f32) {
    debug_assert_eq!(dst.len(), src.len());
    let mut dc = dst.chunks_exact_mut(LANES);
    let mut sc = src.chunks_exact(LANES);
    for (d, s) in (&mut dc).zip(&mut sc) {
        for (o, v) in d.iter_mut().zip(s.iter()) {
            *o = *v * scale;
        }
    }
    for (o, v) in dc.into_remainder().iter_mut().zip(sc.remainder().iter()) {
        *o = *v * scale;
    }
}

/// Plain-loop twin of [`scale_into`].
pub fn scale_into_scalar(dst: &mut [f32], src: &[f32], scale: f32) {
    for (o, v) in dst.iter_mut().zip(src.iter()) {
        *o = *v * scale;
    }
}

/// `mid_bwd` gradients: `(Σ dy[i]·x[i], Σ dy[i])` in canonical order.
pub fn reduce_dot_bias(dy: &[f32], x: &[f32]) -> (f32, f32) {
    debug_assert_eq!(dy.len(), x.len());
    reduce2(dy.len(), |i| (dy[i] * x[i], dy[i]))
}

/// Mirrored-order twin of [`reduce_dot_bias`] — bit-identical.
pub fn reduce_dot_bias_scalar(dy: &[f32], x: &[f32]) -> (f32, f32) {
    debug_assert_eq!(dy.len(), x.len());
    reduce2_scalar(dy.len(), |i| (dy[i] * x[i], dy[i]))
}

/// `first_bwd` gradients over the flat `(position·h + feature)` index:
/// `(Σ dy[i]·emb(tok[i/h], i%h), Σ dy[i])` in canonical order.
pub fn reduce_emb_bias(dy: &[f32], tok: &[i32], h: usize) -> (f32, f32) {
    debug_assert_eq!(dy.len(), tok.len() * h);
    reduce2(dy.len(), |i| (dy[i] * emb(tok[i / h], (i % h) as u64), dy[i]))
}

/// Mirrored-order twin of [`reduce_emb_bias`] — bit-identical.
pub fn reduce_emb_bias_scalar(dy: &[f32], tok: &[i32], h: usize) -> (f32, f32) {
    debug_assert_eq!(dy.len(), tok.len() * h);
    reduce2_scalar(dy.len(), |i| (dy[i] * emb(tok[i / h], (i % h) as u64), dy[i]))
}

/// `last_bwd` per-position row sum `Σ row[j]` in canonical order (the
/// cross-position loss/gradient epilogue stays sequential in the caller:
/// positions are few and its order is part of the loss's numerics).
pub fn row_sum(row: &[f32]) -> f32 {
    reduce1(row.len(), |i| row[i])
}

/// Mirrored-order twin of [`row_sum`] — bit-identical.
pub fn row_sum_scalar(row: &[f32]) -> f32 {
    reduce1_scalar(row.len(), |i| row[i])
}

/// Bias-corrected Adam with the buffer-rotation contract: updates `w`
/// in place, writes the new first moment into `g`'s buffer and the new
/// second moment into `m`'s buffer (`v` is read-only and its buffer is
/// the caller's to recycle).  Elementwise, 8-wide chunks.
pub fn adam_update(w: &mut [f32], g: &mut [f32], m: &mut [f32], v: &[f32], step: i32, lr: f32) {
    let (bc1, bc2) = (1.0 - BETA1.powi(step), 1.0 - BETA2.powi(step));
    let n = w.len();
    debug_assert!(g.len() == n && m.len() == n && v.len() == n);
    let body = |wi: &mut f32, gi: &mut f32, mi: &mut f32, vi: f32| {
        let gv = *gi;
        let m1 = BETA1 * *mi + (1.0 - BETA1) * gv;
        let v1 = BETA2 * vi + (1.0 - BETA2) * gv * gv;
        let mhat = m1 / bc1;
        let vhat = v1 / bc2;
        *wi -= lr * mhat / (vhat.sqrt() + EPS);
        *gi = m1; // g's buffer becomes m'
        *mi = v1; // m's buffer becomes v'
    };
    let full = n / LANES;
    for c in 0..full {
        let base = c * LANES;
        for l in 0..LANES {
            let i = base + l;
            body(&mut w[i], &mut g[i], &mut m[i], v[i]);
        }
    }
    for i in full * LANES..n {
        body(&mut w[i], &mut g[i], &mut m[i], v[i]);
    }
}

/// Plain-loop twin of [`adam_update`].
pub fn adam_update_scalar(
    w: &mut [f32],
    g: &mut [f32],
    m: &mut [f32],
    v: &[f32],
    step: i32,
    lr: f32,
) {
    let (bc1, bc2) = (1.0 - BETA1.powi(step), 1.0 - BETA2.powi(step));
    let n = w.len();
    debug_assert!(g.len() == n && m.len() == n && v.len() == n);
    for i in 0..n {
        let gv = g[i];
        let m1 = BETA1 * m[i] + (1.0 - BETA1) * gv;
        let v1 = BETA2 * v[i] + (1.0 - BETA2) * gv * gv;
        let mhat = m1 / bc1;
        let vhat = v1 / bc2;
        w[i] -= lr * mhat / (vhat.sqrt() + EPS);
        g[i] = m1;
        m[i] = v1;
    }
}

/// Seeded parameter init (`{kind}_init`): SplitMix64 values in ±0.1.
pub fn init_fill(w: &mut [f32], seed: i32) {
    let mut rng = SplitMix64::new((seed as i64 as u64) ^ 0x5EED_BA5E);
    for v in w.iter_mut() {
        *v = (rng.next_f64() * 0.2 - 0.1) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic "awkward" f32s: mixes signs, magnitudes spanning
    /// ~40 orders, ±0.0 and subnormals — cancellation-heavy on purpose.
    fn awkward(n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| match i % 7 {
                0 => 0.0,
                1 => -0.0,
                2 => f32::MIN_POSITIVE / 2.0, // subnormal
                3 => -(i as f32) * 1e-20,
                4 => (i as f32).sin() * 1e3,
                5 => -(i as f32).cos() * 1e-3,
                _ => unit(i as u64 * 11),
            })
            .collect()
    }

    #[test]
    fn chunked_and_lane_major_reductions_are_bit_identical() {
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 64, 100, 1023] {
            let x = awkward(n);
            let dy = awkward(n + 1)[1..].to_vec();
            let (a0, a1) = reduce_dot_bias(&dy, &x);
            let (b0, b1) = reduce_dot_bias_scalar(&dy, &x);
            assert_eq!(a0.to_bits(), b0.to_bits(), "dot n={n}");
            assert_eq!(a1.to_bits(), b1.to_bits(), "bias n={n}");
            assert_eq!(row_sum(&x).to_bits(), row_sum_scalar(&x).to_bits(), "sum n={n}");
        }
    }

    #[test]
    fn tree_reduction_matches_a_hand_sum_on_small_inputs() {
        // n=3 tail lands in lanes 0..3: tree8 degenerates to a0+a1+a2
        assert_eq!(row_sum(&[1.0, -2.0, 0.0]), -1.0);
        assert_eq!(reduce_dot_bias(&[1.0, 1.0, 1.0], &[1.0, -2.0, 0.0]), (-1.0, 3.0));
        // one full chunk: ((1+16)+(4+64)) + ((2+32)+(8+128))
        let pow: Vec<f32> = (0..8).map(|i| (1u32 << i) as f32).collect();
        assert_eq!(row_sum(&pow), 255.0);
    }

    #[test]
    fn elementwise_kernels_match_their_twins() {
        let src = awkward(37);
        let mut a = src.clone();
        let mut b = src.clone();
        affine_in_place(&mut a, 1.5, -0.25);
        affine_in_place_scalar(&mut b, 1.5, -0.25);
        assert_eq!(a, b);
        scale_in_place(&mut a, -3.0);
        scale_in_place_scalar(&mut b, -3.0);
        assert_eq!(a, b);
        let (mut da, mut db) = (vec![0f32; 37], vec![0f32; 37]);
        scale_into(&mut da, &src, 0.7);
        scale_into_scalar(&mut db, &src, 0.7);
        assert_eq!(da, db);
    }

    #[test]
    fn adam_twins_rotate_identically() {
        let n = 29; // odd on purpose
        let mk = |s: u64| -> Vec<f32> { (0..n).map(|i| unit(i as u64 * 3 + s)).collect() };
        let (mut w1, mut g1, mut m1) = (mk(1), mk(2), mk(3));
        let (mut w2, mut g2, mut m2) = (w1.clone(), g1.clone(), m1.clone());
        let v: Vec<f32> = mk(4).iter().map(|x| x.abs()).collect();
        adam_update(&mut w1, &mut g1, &mut m1, &v, 3, 1e-2);
        adam_update_scalar(&mut w2, &mut g2, &mut m2, &v, 3, 1e-2);
        assert_eq!(w1, w2);
        assert_eq!(g1, g2);
        assert_eq!(m1, m2);
    }

    #[test]
    fn emb_reduction_twins_agree_on_odd_shapes() {
        for (positions, h) in [(1usize, 1usize), (3, 5), (4, 8), (5, 13)] {
            let tok: Vec<i32> = (0..positions as i32).collect();
            let dy = awkward(positions * h);
            let a = reduce_emb_bias(&dy, &tok, h);
            let b = reduce_emb_bias_scalar(&dy, &tok, h);
            assert_eq!(a.0.to_bits(), b.0.to_bits());
            assert_eq!(a.1.to_bits(), b.1.to_bits());
            let mut ya = vec![0f32; positions * h];
            let mut yb = vec![0f32; positions * h];
            fwd_first_fill(&mut ya, &tok, h, 0.9, -0.1);
            fwd_first_fill_scalar(&mut yb, &tok, h, 0.9, -0.1);
            assert_eq!(ya, yb);
        }
    }
}
