//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only module that touches the `xla` crate.  The python
//! side (`python/compile/aot.py`) lowers every stage function ONCE to
//! HLO text (the interchange format xla_extension 0.5.1 can parse — see
//! DESIGN.md); everything here is pure rust and runs on the request
//! path with no Python anywhere.

pub mod artifact;
pub mod engine;

pub use artifact::{ArtifactMeta, Manifest, TensorMeta};
pub use engine::{Executable, Runtime};

/// Convert a flat f32 slice into a Literal of the given shape.
pub fn literal_f32(data: &[f32], shape: &[i64]) -> anyhow::Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    Ok(lit.reshape(shape)?)
}

/// Convert a token slice into an i32 Literal of shape `[b, s]`.
pub fn literal_tokens(tokens: &[i32], b: i64, s: i64) -> anyhow::Result<xla::Literal> {
    anyhow::ensure!(tokens.len() as i64 == b * s, "token count mismatch");
    Ok(xla::Literal::vec1(tokens).reshape(&[b, s])?)
}

/// Extract an f32 vector from a Literal.
pub fn to_f32_vec(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
