//! The execution runtime: manifest-described stage artifacts behind the
//! [`Backend`] abstraction (client / compile / upload / execute over
//! device buffers).
//!
//! Two backends implement it:
//!
//! * [`SimBackend`] — deterministic in-tree execution of the artifacts
//!   as seeded f32 affine ops on host buffers.  No dependencies,
//!   compiled by default: this is what puts the REAL pipeline
//!   (`coordinator`) into tier-1.
//! * `engine::Runtime` (feature `pjrt`) — the PJRT CPU client
//!   executing AOT-compiled HLO-text artifacts.  The python side
//!   (`python/compile/aot.py`) lowers every stage function ONCE to HLO
//!   text.  The client behind it is the vendored [`pjrt_stub`] — a
//!   minimal in-tree PJRT-shaped implementation (create / compile /
//!   upload / execute / donation aliases) that keeps the feature
//!   compiling and its gated tests running in CI until the real `xla`
//!   crate is dropped in under the same names.
//!
//! [`kernels`] holds the fixed-width f32 compute kernels behind the
//! sim backend — chunk-major 8-lane accumulation with a fixed tree
//! reduction, the crate's canonical numerics — plus their mirrored
//! scalar twins for the bit-identity property suite.
//!
//! [`artifact::Manifest`] is the shared contract: the python→rust
//! manifest.json describing every artifact's shapes and the per-kind
//! parameter counts — loadable from disk, or built fully in memory by
//! [`Manifest::synthetic`] for artifact-free sim runs.
//!
//! [`buffer_pool::BufferPool`] + [`Backend::execute_pooled`] form the
//! buffer lifecycle layer: per-worker shape-keyed free lists and
//! donation semantics (which inputs a computation may consume) that make
//! the steady-state training step allocation-free on the sim backend and
//! map onto immediate device-buffer release on PJRT.  See
//! `docs/ARCHITECTURE.md` § "Buffer lifecycle & donation".
//!
//! [`fault::FaultyBackend`] wraps any backend with deterministic,
//! seeded fault injection (crash / panic / transient execute / channel
//! stall / HBM cap reduction per [`fault::FaultPlan`]) — the chaos half
//! of the supervised recovery runtime in [`crate::coordinator`].  See
//! `docs/ARCHITECTURE.md` § "Failure domains & recovery".

pub mod artifact;
pub mod backend;
pub mod buffer_pool;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod fault;
pub mod kernels;
#[cfg(feature = "pjrt")]
pub mod pjrt_stub;
pub mod sim_backend;

pub use artifact::{ArtifactMeta, Manifest, TensorMeta};
pub use backend::{Arg, ArgVal, Backend, HostTensor};
pub use buffer_pool::BufferPool;
pub use fault::{Fault, FaultPlan, FaultyBackend, InjectedFault};
#[cfg(feature = "pjrt")]
pub use engine::{Executable, Runtime};
pub use sim_backend::{SimBackend, UnpooledSimBackend};

#[cfg(feature = "pjrt")]
use pjrt_stub as xla;

/// Convert a flat f32 slice into a Literal of the given shape.
#[cfg(feature = "pjrt")]
pub fn literal_f32(data: &[f32], shape: &[i64]) -> anyhow::Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if shape.len() <= 1 {
        return Ok(lit);
    }
    Ok(lit.reshape(shape)?)
}

/// Convert a token slice into an i32 Literal of shape `[b, s]`.
#[cfg(feature = "pjrt")]
pub fn literal_tokens(tokens: &[i32], b: i64, s: i64) -> anyhow::Result<xla::Literal> {
    anyhow::ensure!(tokens.len() as i64 == b * s, "token count mismatch");
    Ok(xla::Literal::vec1(tokens).reshape(&[b, s])?)
}

/// Extract an f32 vector from a Literal.
#[cfg(feature = "pjrt")]
pub fn to_f32_vec(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
