//! Vendored PJRT stub — a minimal in-tree PJRT-shaped client.
//!
//! The `pjrt` feature's real backend is the `xla` crate (a PJRT CPU
//! client executing AOT-lowered HLO text).  That crate is not vendored;
//! this module implements the exact slice of its API that
//! [`crate::runtime::engine`] consumes — create / compile / upload /
//! execute / donation aliases — so the feature compiles, its gated twin
//! tests run in CI, and the day the real crate lands it drops in under
//! the same names (`use ... as xla`).
//!
//! Semantics, not ceremony:
//!
//! * **Compile** parses the HLO text (the same modules
//!   `python/compile/aot.py` lowers) into a tiny instruction list and
//!   **execute** interprets it — `parameter` / `add` / `multiply` /
//!   `subtract` / `negate` / `tuple` over `f32`/`s32` arrays — so the
//!   `f(x) = (x + x,)` twin tests exercise a real
//!   upload→execute→download round trip, not a mock that echoes inputs.
//! * **Donation** follows PJRT's model: inputs donated via
//!   [`ExecuteOptions::donated_input_indices`] (or pre-declared with
//!   [`CompileOptions::set_up_alias`], XLA's `SetUpAlias`) are invalid
//!   after the execution — any later use errors, exactly how a real
//!   PJRT client rejects a donated buffer.  `execute_pooled`'s
//!   `Owned`-argument donation maps straight onto this.
//! * **Buffers** are host-backed and RAII-managed; `to_literal_sync`
//!   copies out, mirroring the synchronous
//!   `buffer_from_host_buffer` semantics the engine relies on.

use std::borrow::Borrow;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Stub error type (the `xla` crate's error, shaped for `anyhow?`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "pjrt-stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

fn err<T>(msg: impl Into<String>) -> Result<T, Error> {
    Err(Error(msg.into()))
}

/// XLA element types (only the slice the runtime touches is
/// interpreted; the rest exist so dtype dispatch stays a real `match`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    U32,
    F32,
    F64,
}

/// Array dimensions of a non-tuple literal.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host types that map onto an XLA element type.
pub trait NativeType: Copy + 'static {
    const ELEMENT_TYPE: ElementType;
    fn literal_from(data: &[Self], dims: Vec<i64>) -> Literal;
    fn vec_from(lit: &Literal) -> Result<Vec<Self>, Error>;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
    fn literal_from(data: &[Self], dims: Vec<i64>) -> Literal {
        Literal::F32(data.to_vec(), dims)
    }
    fn vec_from(lit: &Literal) -> Result<Vec<Self>, Error> {
        match lit {
            Literal::F32(d, _) => Ok(d.clone()),
            other => err(format!("to_vec::<f32> on {:?}", other.type_tag())),
        }
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
    fn literal_from(data: &[Self], dims: Vec<i64>) -> Literal {
        Literal::I32(data.to_vec(), dims)
    }
    fn vec_from(lit: &Literal) -> Result<Vec<Self>, Error> {
        match lit {
            Literal::I32(d, _) => Ok(d.clone()),
            other => err(format!("to_vec::<i32> on {:?}", other.type_tag())),
        }
    }
}

/// A host-side value: flat data + dims, or a tuple of values.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::literal_from(data, vec![data.len() as i64])
    }

    fn type_tag(&self) -> &'static str {
        match self {
            Literal::F32(..) => "f32",
            Literal::I32(..) => "s32",
            Literal::Tuple(_) => "tuple",
        }
    }

    fn len(&self) -> usize {
        match self {
            Literal::F32(d, _) => d.len(),
            Literal::I32(d, _) => d.len(),
            Literal::Tuple(t) => t.len(),
        }
    }

    /// Same data, new dims (element counts must agree).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.len() {
            return err(format!("reshape {:?} to {dims:?}: element count mismatch", self.len()));
        }
        match self {
            Literal::F32(d, _) => Ok(Literal::F32(d.clone(), dims.to_vec())),
            Literal::I32(d, _) => Ok(Literal::I32(d.clone(), dims.to_vec())),
            Literal::Tuple(_) => err("reshape on a tuple literal"),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::vec_from(self)
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        match self {
            Literal::F32(_, dims) | Literal::I32(_, dims) => {
                Ok(ArrayShape { dims: dims.clone() })
            }
            Literal::Tuple(_) => err("array_shape on a tuple literal"),
        }
    }

    pub fn element_type(&self) -> Result<ElementType, Error> {
        match self {
            Literal::F32(..) => Ok(ElementType::F32),
            Literal::I32(..) => Ok(ElementType::S32),
            Literal::Tuple(_) => err("element_type on a tuple literal"),
        }
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        match self {
            Literal::Tuple(t) => Ok(t.clone()),
            other => err(format!("to_tuple on a {:?} literal", other.type_tag())),
        }
    }
}

/// The raw HLO text of a module (parsing happens at compile, like a
/// real client; `from_text_file` only touches the filesystem).
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self, Error> {
        match std::fs::read_to_string(path) {
            Ok(text) => Ok(Self { text }),
            Err(e) => err(format!("read {path}: {e}")),
        }
    }

    pub fn from_text(text: &str) -> Self {
        Self { text: text.to_string() }
    }
}

/// A computation handed to [`PjRtClient::compile`].
pub struct XlaComputation {
    text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        Self { text: proto.text.clone() }
    }
}

/// Compile-time input/output aliasing — XLA's `SetUpAlias`.  An aliased
/// parameter's buffer is donated on **every** execution of the
/// compiled executable (its storage is reused for the output), on top
/// of any per-call [`ExecuteOptions::donated_input_indices`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CompileOptions {
    aliased_params: Vec<usize>,
}

impl CompileOptions {
    /// Alias output `_output_index` with parameter `param_index` (the
    /// stub records the donation side; output placement is host-backed
    /// so the storage reuse itself is a no-op).
    pub fn set_up_alias(&mut self, _output_index: usize, param_index: usize) {
        if !self.aliased_params.contains(&param_index) {
            self.aliased_params.push(param_index);
        }
    }
}

/// Per-execution options — PJRT's donation control.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ExecuteOptions {
    /// Input positions whose buffers this execution consumes.
    pub donated_input_indices: Vec<usize>,
}

// ---------------------------------------------------------------------
// HLO text interpreter

#[derive(Debug, Clone)]
enum Op {
    Parameter(usize),
    Add(String, String),
    Multiply(String, String),
    Subtract(String, String),
    Negate(String),
    Tuple(Vec<String>),
}

#[derive(Debug, Clone)]
struct Instr {
    result: String,
    is_root: bool,
    op: Op,
}

/// Parse the ENTRY block of an HLO-text module into an instruction
/// list.  Grammar: `[ROOT] name = TYPE opcode(args)` — the shape
/// `aot.py` lowers and the twin tests feed.
fn parse_entry(text: &str) -> Result<Vec<Instr>, Error> {
    let mut instrs = Vec::new();
    let mut in_entry = false;
    for raw in text.lines() {
        let line = raw.trim();
        if line.starts_with("ENTRY ") && line.ends_with('{') {
            in_entry = true;
            continue;
        }
        if !in_entry {
            continue;
        }
        if line.starts_with('}') {
            break;
        }
        if line.is_empty() {
            continue;
        }
        let (is_root, line) = match line.strip_prefix("ROOT ") {
            Some(rest) => (true, rest),
            None => (false, line),
        };
        let Some((result, rhs)) = line.split_once(" = ") else {
            return err(format!("malformed instruction {line:?}"));
        };
        let op = parse_op(rhs.trim())?;
        instrs.push(Instr { result: result.trim().to_string(), is_root, op });
    }
    if instrs.is_empty() {
        return err("no ENTRY block found");
    }
    if !instrs.iter().any(|i| i.is_root) {
        return err("ENTRY block has no ROOT instruction");
    }
    Ok(instrs)
}

/// Parse `TYPE opcode(args...)` — the type annotation is skipped (the
/// interpreter is shape-polymorphic), the opcode located by name.
fn parse_op(rhs: &str) -> Result<Op, Error> {
    const OPS: [&str; 6] = ["parameter", "add", "multiply", "subtract", "negate", "tuple"];
    for name in OPS {
        let needle = format!(" {name}(");
        let Some(at) = rhs.find(&needle) else { continue };
        let open = at + needle.len();
        let Some(close_rel) = rhs[open..].find(')') else {
            return err(format!("unterminated {name}(...) in {rhs:?}"));
        };
        let args: Vec<String> = rhs[open..open + close_rel]
            .split(',')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect();
        let arity = |n: usize| -> Result<(), Error> {
            if args.len() == n {
                Ok(())
            } else {
                err(format!("{name} wants {n} operand(s), got {} in {rhs:?}", args.len()))
            }
        };
        return Ok(match name {
            "parameter" => {
                arity(1)?;
                Op::Parameter(
                    args[0]
                        .parse()
                        .map_err(|e| Error(format!("parameter index {:?}: {e}", args[0])))?,
                )
            }
            "add" => {
                arity(2)?;
                Op::Add(args[0].clone(), args[1].clone())
            }
            "multiply" => {
                arity(2)?;
                Op::Multiply(args[0].clone(), args[1].clone())
            }
            "subtract" => {
                arity(2)?;
                Op::Subtract(args[0].clone(), args[1].clone())
            }
            "negate" => {
                arity(1)?;
                Op::Negate(args[0].clone())
            }
            _ => Op::Tuple(args),
        });
    }
    err(format!("unsupported HLO opcode in {rhs:?}"))
}

fn binary(
    env: &HashMap<String, Literal>,
    a: &str,
    b: &str,
    name: &str,
    f32_op: impl Fn(f32, f32) -> f32,
    i32_op: impl Fn(i32, i32) -> i32,
) -> Result<Literal, Error> {
    let (x, y) = (lookup(env, a)?, lookup(env, b)?);
    match (x, y) {
        (Literal::F32(xa, xd), Literal::F32(ya, yd)) if xd == yd => {
            Ok(Literal::F32(xa.iter().zip(ya).map(|(&p, &q)| f32_op(p, q)).collect(), xd.clone()))
        }
        (Literal::I32(xa, xd), Literal::I32(ya, yd)) if xd == yd => {
            Ok(Literal::I32(xa.iter().zip(ya).map(|(&p, &q)| i32_op(p, q)).collect(), xd.clone()))
        }
        (x, y) => err(format!(
            "{name}({a}, {b}): operand mismatch ({:?} vs {:?})",
            x.type_tag(),
            y.type_tag()
        )),
    }
}

fn lookup<'e>(env: &'e HashMap<String, Literal>, name: &str) -> Result<&'e Literal, Error> {
    env.get(name).ok_or_else(|| Error(format!("undefined operand {name:?}")))
}

fn evaluate(instrs: &[Instr], params: &[Literal]) -> Result<Literal, Error> {
    let mut env: HashMap<String, Literal> = HashMap::new();
    let mut root = None;
    for i in instrs {
        let v = match &i.op {
            Op::Parameter(k) => match params.get(*k) {
                Some(p) => p.clone(),
                None => {
                    return err(format!(
                        "parameter({k}) but only {} argument(s) were passed",
                        params.len()
                    ))
                }
            },
            Op::Add(a, b) => binary(&env, a, b, "add", |p, q| p + q, |p, q| p.wrapping_add(q))?,
            Op::Multiply(a, b) => {
                binary(&env, a, b, "multiply", |p, q| p * q, |p, q| p.wrapping_mul(q))?
            }
            Op::Subtract(a, b) => {
                binary(&env, a, b, "subtract", |p, q| p - q, |p, q| p.wrapping_sub(q))?
            }
            Op::Negate(a) => match lookup(&env, a)? {
                Literal::F32(d, dims) => {
                    Literal::F32(d.iter().map(|&x| -x).collect(), dims.clone())
                }
                Literal::I32(d, dims) => {
                    Literal::I32(d.iter().map(|&x| x.wrapping_neg()).collect(), dims.clone())
                }
                Literal::Tuple(_) => return err(format!("negate({a}) on a tuple")),
            },
            Op::Tuple(names) => Literal::Tuple(
                names
                    .iter()
                    .map(|n| lookup(&env, n).cloned())
                    .collect::<Result<Vec<_>, Error>>()?,
            ),
        };
        if i.is_root {
            root = Some(v.clone());
        }
        env.insert(i.result.clone(), v);
    }
    root.ok_or_else(|| Error("ROOT instruction produced no value".into()))
}

// ---------------------------------------------------------------------
// Client / buffers / executables

/// A device-resident buffer (host-backed).  Donation invalidates it:
/// every access after a donating execution errors, like real PJRT.
pub struct PjRtBuffer {
    lit: Literal,
    consumed: AtomicBool,
}

impl PjRtBuffer {
    fn new(lit: Literal) -> Self {
        Self { lit, consumed: AtomicBool::new(false) }
    }

    fn literal(&self) -> Result<&Literal, Error> {
        if self.consumed.load(Ordering::Acquire) {
            return err("buffer was donated to a computation and is no longer valid");
        }
        Ok(&self.lit)
    }

    /// Copy the buffer back to the host.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Ok(self.literal()?.clone())
    }
}

/// The stub PJRT client ("cpu-stub" platform).  `Clone` shares the
/// underlying client like the real crate's refcounted handle.
#[derive(Clone)]
pub struct PjRtClient {
    _inner: Arc<()>,
}

impl PjRtClient {
    /// Create the CPU client.
    pub fn cpu() -> Result<Self, Error> {
        Ok(Self { _inner: Arc::new(()) })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    /// Compile a computation (parses the HLO text here, so malformed
    /// modules fail at compile like a real client).
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        self.compile_with_options(comp, CompileOptions::default())
    }

    /// [`PjRtClient::compile`] with donation aliases pre-declared.
    pub fn compile_with_options(
        &self,
        comp: &XlaComputation,
        options: CompileOptions,
    ) -> Result<PjRtLoadedExecutable, Error> {
        let instrs = parse_entry(&comp.text)?;
        Ok(PjRtLoadedExecutable { client: self.clone(), instrs, options })
    }

    /// Synchronous host→device upload (`kImmutableOnlyDuringCall`
    /// semantics: `data` is copied before this returns).
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        let dims_i: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let n: i64 = dims_i.iter().product();
        if n as usize != data.len() {
            return err(format!("upload: {} elements do not fill shape {dims:?}", data.len()));
        }
        Ok(PjRtBuffer::new(T::literal_from(data, dims_i)))
    }
}

/// A compiled executable: the parsed instruction list plus its client
/// handle and compile-time aliasing.
pub struct PjRtLoadedExecutable {
    client: PjRtClient,
    instrs: Vec<Instr>,
    options: CompileOptions,
}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Execute on device-resident inputs; one output buffer per device
    /// (single device here), holding the ROOT value.
    pub fn execute_b<B: Borrow<PjRtBuffer>>(
        &self,
        inputs: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        self.execute_b_with_options(inputs, &ExecuteOptions::default())
    }

    /// [`PjRtLoadedExecutable::execute_b`] with per-call donation: the
    /// buffers at `donated_input_indices` (plus any compile-time
    /// aliases) are consumed by this execution and invalid afterwards.
    pub fn execute_b_with_options<B: Borrow<PjRtBuffer>>(
        &self,
        inputs: &[B],
        options: &ExecuteOptions,
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        for &i in options.donated_input_indices.iter().chain(&self.options.aliased_params) {
            if i >= inputs.len() {
                return err(format!("donated index {i} out of range ({} inputs)", inputs.len()));
            }
        }
        let params: Vec<Literal> = inputs
            .iter()
            .map(|b| b.borrow().literal().cloned())
            .collect::<Result<Vec<_>, Error>>()?;
        let root = evaluate(&self.instrs, &params)?;
        // donation takes effect only once the execution has succeeded
        for &i in options.donated_input_indices.iter().chain(&self.options.aliased_params) {
            inputs[i].borrow().consumed.store(true, Ordering::Release);
        }
        Ok(vec![vec![PjRtBuffer::new(root)]])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ADD_HLO: &str = r#"HloModule jit_fn, entry_computation_layout={(f32[4]{0})->(f32[4]{0})}

ENTRY main.4 {
  Arg_0.1 = f32[4]{0} parameter(0)
  add.2 = f32[4]{0} add(Arg_0.1, Arg_0.1)
  ROOT tuple.3 = (f32[4]{0}) tuple(add.2)
}
"#;

    fn compile(text: &str) -> PjRtLoadedExecutable {
        let client = PjRtClient::cpu().unwrap();
        client.compile(&XlaComputation::from_proto(&HloModuleProto::from_text(text))).unwrap()
    }

    #[test]
    fn interprets_the_twin_module() {
        let exe = compile(ADD_HLO);
        let client = exe.client().clone();
        let x = client.buffer_from_host_buffer(&[1f32, 2., 3., 4.], &[4], None).unwrap();
        let out = exe.execute_b(&[&x]).unwrap();
        let tuple = out[0][0].to_literal_sync().unwrap().to_tuple().unwrap();
        assert_eq!(tuple.len(), 1);
        assert_eq!(tuple[0].to_vec::<f32>().unwrap(), vec![2f32, 4., 6., 8.]);
        // non-donated inputs survive the execution
        assert_eq!(x.to_literal_sync().unwrap().to_vec::<f32>().unwrap(), vec![1., 2., 3., 4.]);
    }

    #[test]
    fn arithmetic_opcodes_and_s32() {
        let hlo = r#"HloModule ops
ENTRY main {
  a = s32[3]{0} parameter(0)
  b = s32[3]{0} parameter(1)
  prod = s32[3]{0} multiply(a, b)
  diff = s32[3]{0} subtract(prod, a)
  neg = s32[3]{0} negate(diff)
  ROOT out = (s32[3]{0}, s32[3]{0}) tuple(diff, neg)
}
"#;
        let exe = compile(hlo);
        let c = exe.client().clone();
        let a = c.buffer_from_host_buffer(&[1i32, 2, 3], &[3], None).unwrap();
        let b = c.buffer_from_host_buffer(&[10i32, 20, 30], &[3], None).unwrap();
        let out = exe.execute_b(&[&a, &b]).unwrap();
        let t = out[0][0].to_literal_sync().unwrap().to_tuple().unwrap();
        assert_eq!(t[0].to_vec::<i32>().unwrap(), vec![9, 38, 87]);
        assert_eq!(t[1].to_vec::<i32>().unwrap(), vec![-9, -38, -87]);
        assert_eq!(t[0].element_type().unwrap(), ElementType::S32);
    }

    #[test]
    fn execute_donation_invalidates_the_input() {
        let exe = compile(ADD_HLO);
        let x = exe
            .client()
            .buffer_from_host_buffer(&[1f32, 2., 3., 4.], &[4], None)
            .unwrap();
        let opts = ExecuteOptions { donated_input_indices: vec![0] };
        let out = exe.execute_b_with_options(&[&x], &opts).unwrap();
        let t = out[0][0].to_literal_sync().unwrap().to_tuple().unwrap();
        assert_eq!(t[0].to_vec::<f32>().unwrap(), vec![2., 4., 6., 8.]);
        // the donated buffer is dead: reads and re-executions both fail
        assert!(x.to_literal_sync().is_err());
        assert!(exe.execute_b(&[&x]).is_err());
    }

    #[test]
    fn set_up_alias_donates_on_every_execution() {
        let client = PjRtClient::cpu().unwrap();
        let mut copts = CompileOptions::default();
        copts.set_up_alias(0, 0);
        let exe = client
            .compile_with_options(
                &XlaComputation::from_proto(&HloModuleProto::from_text(ADD_HLO)),
                copts,
            )
            .unwrap();
        let x = client.buffer_from_host_buffer(&[1f32, 0., 0., 0.], &[4], None).unwrap();
        exe.execute_b(&[&x]).unwrap();
        assert!(x.to_literal_sync().is_err(), "aliased param must be consumed");
    }

    #[test]
    fn failed_execution_does_not_consume_donations() {
        let hlo = r#"HloModule two
ENTRY main {
  a = f32[2]{0} parameter(0)
  b = f32[2]{0} parameter(1)
  ROOT t = (f32[2]{0}) tuple(a)
}
"#;
        let exe = compile(hlo);
        let c = exe.client().clone();
        let a = c.buffer_from_host_buffer(&[1f32, 2.], &[2], None).unwrap();
        // arity error: executable wants 2 params, gets 1 — but index 0
        // must still be alive afterwards
        let opts = ExecuteOptions { donated_input_indices: vec![0] };
        assert!(exe.execute_b_with_options(&[&a], &opts).is_err());
        assert!(a.to_literal_sync().is_ok(), "failed run must not consume the donation");
    }

    #[test]
    fn literal_shape_round_trips() {
        let lit = Literal::vec1(&[1f32, 2., 3., 4., 5., 6.]);
        let r = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.element_type().unwrap(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        assert!(lit.reshape(&[4, 2]).is_err());
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.to_tuple().is_err());
        let tup = Literal::Tuple(vec![lit]);
        assert!(tup.array_shape().is_err());
        assert!(tup.element_type().is_err());
    }

    #[test]
    fn malformed_modules_fail_at_compile() {
        let client = PjRtClient::cpu().unwrap();
        for bad in [
            "",                                       // no ENTRY
            "ENTRY main {\n}\n",                      // empty body
            "ENTRY main {\n  a = f32[1]{0} parameter(0)\n}\n", // no ROOT
            "ENTRY main {\n  ROOT a = f32[1]{0} cosine(a)\n}\n", // unknown opcode
        ] {
            let comp = XlaComputation::from_proto(&HloModuleProto::from_text(bad));
            assert!(client.compile(&comp).is_err(), "{bad:?} must not compile");
        }
    }

    #[test]
    fn upload_validates_shape() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.buffer_from_host_buffer(&[1f32, 2.], &[3], None).is_err());
        assert_eq!(c.platform_name(), "cpu-stub");
    }
}
