//! `SimBackend` — the deterministic in-tree execution backend.
//!
//! Executes manifest-described stage artifacts as **seeded f32 affine
//! ops on host buffers**: real forward/backward/loss plumbing with
//! reproducible numerics and no external dependency, so the whole
//! coordinator (channels, stashes, BPipe evict/load, Adam,
//! checkpointing) runs under `cargo test -q` by default.
//!
//! ## Stage semantics
//!
//! Every artifact name the python side would lower has a closed-form
//! interpretation over the manifest's shapes (`b`, `s`, `h`, `v`) and
//! per-kind parameter counts.  Only `params[0]` and `params[1]` carry
//! gradients (an affine model); the rest are inert ballast so parameter
//! vectors, optimizer state and checkpoints have realistic sizes:
//!
//! * `{kind}_init(seed) → params` — SplitMix64-seeded values in ±0.1;
//! * `first_fwd(w, tokens) → y` — `y[r,t,j] = w₀·emb(tok,j) + w₁`, with
//!   `emb` a fixed hash-based pseudo-embedding in [−1, 1);
//! * `mid_fwd(w, x) → y` — `y = (1 + w₀)·x + w₁`;
//! * `mid_bwd(w, x, dy) → (dx, dw)` — the exact reverse-mode adjoints;
//! * `last_bwd(w, x, targets) → (dx, dw, loss)` — per-position affine
//!   head `pred = w₀·mean_j(x) + w₁` against the normalized target
//!   token, mean-squared-error loss, exact gradients;
//! * `adam_{kind}(w, g, m, v, step, lr) → (w', m', v')` — standard
//!   bias-corrected Adam, f32 throughout.
//!
//! All loops run through the fixed-width kernels in
//! [`super::kernels`]: reductions accumulate chunk-major into 8 lane
//! accumulators and collapse through a fixed tree (the crate's
//! canonical reduction order — vectorizable *and* bit-reproducible),
//! and because the ops are pure functions of their inputs, a
//! BPipe-rebalanced run (whose Evict/Load just move stashes between
//! stores) computes bit-identical losses to its baseline, the paper's
//! central claim, now asserted in tier-1
//! (`rust/tests/integration_runtime.rs`).
//!
//! ## Buffer donation
//!
//! [`Backend::execute_pooled`] is implemented as **true in-place
//! reuse**: donated inputs become outputs of matching size without a
//! copy (fwd's `y` over `x`, bwd's `dx` over `x`/`dy`, Adam's rotated
//! state triple), other outputs draw from the caller's
//! [`BufferPool`], and `execute` itself is just the donating path with
//! nothing donated — so pooled and owned execution are bit-identical by
//! construction.  [`UnpooledSimBackend`] keeps the trait's
//! fresh-allocation defaults observable as a baseline.

use super::artifact::Manifest;
use super::backend::{Arg, ArgVal, Backend, HostTensor};
use super::buffer_pool::BufferPool;
use super::kernels;

/// What a compiled sim artifact computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SimOp {
    Init,
    FwdFirst,
    FwdMid,
    BwdFirst,
    BwdMid,
    BwdLast,
    Adam,
}

/// A "compiled" sim artifact: the op plus the parameter-vector length
/// of its stage kind (used to size/validate parameter inputs).
pub struct SimExec {
    op: SimOp,
    n_params: usize,
    name: String,
}

/// The in-tree deterministic backend.  Device buffers are host tensors;
/// `upload` is a clone.
pub struct SimBackend {
    h: usize,
    vocab: u64,
}

impl SimBackend {
    fn check_params(&self, exe: &SimExec, t: &HostTensor) -> anyhow::Result<()> {
        anyhow::ensure!(
            t.len() == exe.n_params,
            "{}: params length {} != manifest's {}",
            exe.name,
            t.len(),
            exe.n_params
        );
        Ok(())
    }
}

impl Backend for SimBackend {
    type Exec = SimExec;
    type Buffer = HostTensor;

    fn create(manifest: &Manifest) -> anyhow::Result<Self> {
        anyhow::ensure!(manifest.spec.h >= 1, "sim backend needs h >= 1");
        anyhow::ensure!(manifest.spec.v >= 1, "sim backend needs v >= 1");
        Ok(SimBackend { h: manifest.spec.h as usize, vocab: manifest.spec.v })
    }

    fn platform(&self) -> String {
        "sim".into()
    }

    fn compile(&self, manifest: &Manifest, name: &str) -> anyhow::Result<SimExec> {
        // the artifact must be manifest-described, like a real lowered file
        manifest.meta(name)?;
        let (op, kind) = if let Some(kind) = name.strip_prefix("adam_") {
            (SimOp::Adam, kind)
        } else {
            let (kind, rest) = name
                .split_once('_')
                .ok_or_else(|| anyhow::anyhow!("unparseable artifact name {name:?}"))?;
            // strip the single-stage-sweep suffix: "fwd_b4" → "fwd"
            let base = rest.split('_').next().unwrap_or(rest);
            let op = match (kind, base) {
                (_, "init") => SimOp::Init,
                ("first", "fwd") => SimOp::FwdFirst,
                ("mid", "fwd") => SimOp::FwdMid,
                ("first", "bwd") => SimOp::BwdFirst,
                ("mid", "bwd") => SimOp::BwdMid,
                ("last", "bwd") => SimOp::BwdLast,
                _ => anyhow::bail!("artifact {name:?} has no sim semantics"),
            };
            (op, kind)
        };
        Ok(SimExec { op, n_params: manifest.param_count(kind)? as usize, name: name.to_string() })
    }

    fn upload(&self, t: &HostTensor) -> anyhow::Result<HostTensor> {
        Ok(t.clone())
    }

    fn execute(
        &self,
        exe: &SimExec,
        inputs: &[&HostTensor],
    ) -> anyhow::Result<Vec<HostTensor>> {
        // the owned-value path IS the donating path with nothing donated
        // and a throwaway pool (limit 1: no free-list reservation to
        // inflate the owned baseline's allocation count): one
        // implementation, two disciplines, so pooled/fresh bit-identity
        // holds by construction
        let mut args: Vec<Arg<'_>> = inputs.iter().map(|&t| Arg::Borrowed(t)).collect();
        let mut pool = BufferPool::with_limit(1);
        let mut out = Vec::new();
        self.execute_pooled(exe, None, &mut args, &mut pool, &mut out)?;
        Ok(out)
    }

    /// True donation/reuse: donated inputs are consumed **in place**
    /// where an output matches their dtype and size (fwd's `y` over `x`,
    /// bwd's `dx` over `x` or `dy`, Adam's state triple over `w`/`g`/`m`
    /// — the spare old-`v` buffer returns to the pool), and every other
    /// output draws from the pool.  All loops read each element before
    /// overwriting it, in the exact iteration order of the owned path,
    /// so results are bit-identical whatever the donation mask.
    fn execute_pooled(
        &self,
        exe: &SimExec,
        params: Option<&HostTensor>,
        args: &mut [Arg<'_>],
        pool: &mut BufferPool,
        out: &mut Vec<HostTensor>,
    ) -> anyhow::Result<()> {
        out.clear();
        let mut inp = SimInputs { params, args };
        let argc = |n: usize, got: usize| -> anyhow::Result<()> {
            anyhow::ensure!(got == n, "{}: expected {n} inputs, got {got}", exe.name);
            Ok(())
        };
        let h = self.h;
        match exe.op {
            SimOp::Init => {
                argc(1, inp.count())?;
                let seedv = inp.take(0);
                let seed = seedv.view().i32s()?[0];
                seedv.recycle(pool);
                let mut w_out = pool.take_f32_len(exe.n_params, &[exe.n_params as i64]);
                kernels::init_fill(w_out.f32s_mut()?, seed);
                out.push(w_out);
            }
            SimOp::FwdFirst => {
                argc(2, inp.count())?;
                let wv = inp.take(0);
                self.check_params(exe, wv.view())?;
                let (w0, w1) = {
                    let w = wv.view().f32s()?;
                    (w[0], w[1])
                };
                wv.recycle(pool);
                let tokv = inp.take(1);
                let y = {
                    let tok = tokv.view().i32s()?;
                    let ts = tokv.view().shape();
                    anyhow::ensure!(ts.len() < 4, "{}: token rank too high", exe.name);
                    let mut sh = [0i64; 4];
                    sh[..ts.len()].copy_from_slice(ts);
                    sh[ts.len()] = h as i64;
                    let mut y = pool.take_f32_len(tok.len() * h, &sh[..=ts.len()]);
                    kernels::fwd_first_fill(y.f32s_mut()?, tok, h, w0, w1);
                    y
                };
                tokv.recycle(pool);
                out.push(y);
            }
            SimOp::FwdMid => {
                argc(2, inp.count())?;
                let wv = inp.take(0);
                self.check_params(exe, wv.view())?;
                let (scale, shift) = {
                    let w = wv.view().f32s()?;
                    (1.0 + w[0], w[1])
                };
                wv.recycle(pool);
                // a donated x is consumed in place; a borrowed x is copied
                // into a pooled buffer first — same arithmetic either way
                let mut y = owned_f32_or_copy(inp.take(1), pool)?;
                kernels::affine_in_place(y.f32s_mut()?, scale, shift);
                out.push(y);
            }
            SimOp::BwdFirst => {
                argc(3, inp.count())?;
                let wv = inp.take(0);
                self.check_params(exe, wv.view())?;
                wv.recycle(pool);
                let tokv = inp.take(1);
                let dyv = inp.take(2);
                let (g0, g1) = {
                    let tok = tokv.view().i32s()?;
                    let dy = dyv.view().f32s()?;
                    anyhow::ensure!(dy.len() == tok.len() * h, "{}: dy shape mismatch", exe.name);
                    kernels::reduce_emb_bias(dy, tok, h)
                };
                tokv.recycle(pool);
                dyv.recycle(pool);
                out.push(grad_out(exe, g0, g1, pool)?);
            }
            SimOp::BwdMid => {
                argc(3, inp.count())?;
                let wv = inp.take(0);
                self.check_params(exe, wv.view())?;
                let scale = 1.0 + wv.view().f32s()?[0];
                wv.recycle(pool);
                let xv = inp.take(1);
                let dyv = inp.take(2);
                let (g0, g1) = {
                    let x = xv.view().f32s()?;
                    let dy = dyv.view().f32s()?;
                    anyhow::ensure!(x.len() == dy.len(), "{}: x/dy length mismatch", exe.name);
                    kernels::reduce_dot_bias(dy, x)
                };
                // dx = dy · (1 + w0), shaped like dy; donated buffers are
                // reused (x's first, else dy's in place), pooled otherwise
                let mut dsh = [0i64; 4];
                let dk = dyv.view().shape().len();
                anyhow::ensure!(dk <= 4, "{}: dy rank too high", exe.name);
                dsh[..dk].copy_from_slice(dyv.view().shape());
                let dx = match (xv, dyv) {
                    (ArgVal::Owned(xb), dyv) if matches!(xb, HostTensor::F32 { .. }) => {
                        let mut xb = xb;
                        kernels::scale_into(xb.f32s_mut()?, dyv.view().f32s()?, scale);
                        xb.set_shape(&dsh[..dk]);
                        dyv.recycle(pool);
                        xb
                    }
                    (xv, ArgVal::Owned(db)) if matches!(db, HostTensor::F32 { .. }) => {
                        xv.recycle(pool);
                        let mut db = db;
                        kernels::scale_in_place(db.f32s_mut()?, scale);
                        db
                    }
                    (xv, dyv) => {
                        let mut dx = pool.take_f32_len(dyv.len(), &dsh[..dk]);
                        kernels::scale_into(dx.f32s_mut()?, dyv.view().f32s()?, scale);
                        xv.recycle(pool);
                        dyv.recycle(pool);
                        dx
                    }
                };
                out.push(dx);
                out.push(grad_out(exe, g0, g1, pool)?);
            }
            SimOp::BwdLast => {
                argc(3, inp.count())?;
                let wv = inp.take(0);
                self.check_params(exe, wv.view())?;
                let (w0, w1) = {
                    let w = wv.view().f32s()?;
                    (w[0], w[1])
                };
                wv.recycle(pool);
                let xv = inp.take(1);
                let tgtv = inp.take(2);
                // dx shares x's shape (and, when donated, x's buffer: each
                // position's row is fully read before it is overwritten)
                let mut dx = owned_f32_or_copy(xv, pool)?;
                let (loss, g0, g1) = {
                    let tgt = tgtv.view().i32s()?;
                    let x = dx.f32s_mut()?; // holds x's values; rewritten row by row
                    anyhow::ensure!(x.len() == tgt.len() * h, "{}: x shape mismatch", exe.name);
                    let inv_h = 1.0f32 / h as f32;
                    let inv_n = 1.0f32 / tgt.len() as f32;
                    let inv_v = 1.0f32 / self.vocab as f32;
                    let (mut loss, mut g0, mut g1) = (0f32, 0f32, 0f32);
                    // per-row sums go through the canonical chunked
                    // reduction; the cross-position accumulation below
                    // stays sequential (position order is part of the
                    // loss numerics)
                    for (p, &t) in tgt.iter().enumerate() {
                        let mut u = kernels::row_sum(&x[p * h..(p + 1) * h]);
                        u *= inv_h;
                        let pred = w0 * u + w1;
                        let target = t as f32 * inv_v - 0.5;
                        let e = pred - target;
                        loss += e * e;
                        let dpred = 2.0 * e * inv_n;
                        g0 += dpred * u;
                        g1 += dpred;
                        let dxv = dpred * w0 * inv_h;
                        x[p * h..(p + 1) * h].fill(dxv);
                    }
                    loss *= inv_n;
                    (loss, g0, g1)
                };
                tgtv.recycle(pool);
                out.push(dx);
                out.push(grad_out(exe, g0, g1, pool)?);
                let mut l = pool.take_f32_len(1, &[]);
                l.f32s_mut()?[0] = loss;
                out.push(l);
            }
            SimOp::Adam => {
                argc(6, inp.count())?;
                let wv = inp.take(0);
                self.check_params(exe, wv.view())?;
                let gv = inp.take(1);
                let mv = inp.take(2);
                let vv = inp.take(3);
                let n = wv.len();
                anyhow::ensure!(
                    gv.len() == n && mv.len() == n && vv.len() == n,
                    "{}: state length mismatch",
                    exe.name
                );
                let stepv = inp.take(4);
                let step = stepv.view().i32s()?[0];
                anyhow::ensure!(step >= 1, "{}: adam step must be >= 1", exe.name);
                stepv.recycle(pool);
                let lrv = inp.take(5);
                let lr = lrv.view().f32s()?[0];
                lrv.recycle(pool);
                // working buffers: donated state updates in place (borrowed
                // inputs are copied into pooled buffers); `g`'s buffer
                // becomes the new `m`, `m`'s the new `v`, and the spare old
                // `v` returns to the pool — buffers rotate, nothing allocates
                let mut wb = owned_f32_or_copy(wv, pool)?;
                let mut gb = owned_f32_or_copy(gv, pool)?;
                let mut mb = owned_f32_or_copy(mv, pool)?;
                let vb = owned_f32_or_copy(vv, pool)?;
                kernels::adam_update(
                    wb.f32s_mut()?,
                    gb.f32s_mut()?,
                    mb.f32s_mut()?,
                    vb.f32s()?,
                    step,
                    lr,
                );
                let flat = [n as i64];
                wb.set_shape(&flat);
                gb.set_shape(&flat);
                mb.set_shape(&flat);
                pool.give(vb);
                out.push(wb);
                out.push(gb);
                out.push(mb);
            }
        }
        Ok(())
    }

    fn upload_into(&self, t: &HostTensor, buf: &mut HostTensor) -> anyhow::Result<()> {
        // refresh the device copy without reallocating it
        match (t, buf) {
            (HostTensor::F32 { data, shape }, HostTensor::F32 { data: bd, shape: bs })
                if bd.len() == data.len() =>
            {
                bd.copy_from_slice(data);
                bs.clear();
                bs.extend_from_slice(shape);
            }
            (HostTensor::I32 { data, shape }, HostTensor::I32 { data: bd, shape: bs })
                if bd.len() == data.len() =>
            {
                bd.copy_from_slice(data);
                bs.clear();
                bs.extend_from_slice(shape);
            }
            (t, buf) => *buf = t.clone(),
        }
        Ok(())
    }
}

/// Logical input indexing over (optional leading `params`, remaining
/// `args`): the donating execute sees the same flat argument list as
/// [`Backend::execute`], whether the caller keeps the stage weights
/// device-resident or passes them inline.
struct SimInputs<'s, 'a> {
    params: Option<&'s HostTensor>,
    args: &'s mut [Arg<'a>],
}

impl<'s, 'a: 's> SimInputs<'s, 'a> {
    fn count(&self) -> usize {
        self.args.len() + usize::from(self.params.is_some())
    }

    /// Move logical input `i` out of its slot (the params slot is always
    /// a borrow).
    fn take(&mut self, i: usize) -> ArgVal<'s> {
        match self.params {
            Some(p) if i == 0 => ArgVal::Ref(p),
            Some(_) => self.args[i - 1].take(),
            None => self.args[i].take(),
        }
    }
}

/// A pooled `[n_params]` gradient vector with only the two learnable
/// slots set (the rest stay zero ballast, as in the owned path).
fn grad_out(
    exe: &SimExec,
    g0: f32,
    g1: f32,
    pool: &mut BufferPool,
) -> anyhow::Result<HostTensor> {
    let mut dw = pool.take_f32_len(exe.n_params, &[exe.n_params as i64]);
    let d = dw.f32s_mut()?;
    d.fill(0.0);
    d[0] = g0;
    d[1] = g1;
    Ok(dw)
}

/// Materialize an argument as an owned f32 working buffer: donated
/// values pass through untouched (in-place update), borrowed ones are
/// copied into a pooled buffer.
fn owned_f32_or_copy(v: ArgVal<'_>, pool: &mut BufferPool) -> anyhow::Result<HostTensor> {
    match v {
        ArgVal::Owned(t) if matches!(t, HostTensor::F32 { .. }) => Ok(t),
        other => {
            let src = other.view();
            let mut t = pool.take_f32_len(src.len(), src.shape());
            t.f32s_mut()?.copy_from_slice(src.f32s()?);
            other.recycle(pool);
            Ok(t)
        }
    }
}

/// The owned-value baseline: bit-identical numerics to [`SimBackend`]
/// through the *default* (fresh-allocation) `execute_pooled` and
/// `upload_into` paths — no donation, no in-place reuse, an `upload`
/// clone per input.  Tests pin pooled-vs-owned equivalence against it
/// (`rust/tests/property_pooled.rs`) and the hot-path bench measures the
/// allocation/throughput delta
/// (`benches/runtime_hotpath.rs` → `BENCH_runtime.json`).
pub struct UnpooledSimBackend(SimBackend);

impl Backend for UnpooledSimBackend {
    type Exec = SimExec;
    type Buffer = HostTensor;

    fn create(manifest: &Manifest) -> anyhow::Result<Self> {
        Ok(UnpooledSimBackend(SimBackend::create(manifest)?))
    }

    fn platform(&self) -> String {
        "sim-unpooled".into()
    }

    fn compile(&self, manifest: &Manifest, name: &str) -> anyhow::Result<SimExec> {
        self.0.compile(manifest, name)
    }

    fn upload(&self, t: &HostTensor) -> anyhow::Result<HostTensor> {
        self.0.upload(t)
    }

    fn execute(
        &self,
        exe: &SimExec,
        inputs: &[&HostTensor],
    ) -> anyhow::Result<Vec<HostTensor>> {
        self.0.execute(exe, inputs)
    }
    // no execute_pooled / upload_into overrides: this backend exists to
    // exercise the trait's owned-value defaults
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Manifest, SimBackend) {
        let m = Manifest::synthetic(4, 8, 4, 2, 32, &[1, 2]);
        let b = SimBackend::create(&m).unwrap();
        (m, b)
    }

    #[test]
    fn init_is_deterministic_in_seed() {
        let (m, b) = setup();
        let init = b.compile(&m, "mid_init").unwrap();
        let run = |seed: i32| {
            b.execute_host(&init, &[&HostTensor::scalar_i32(seed)])
                .unwrap()
                .pop()
                .unwrap()
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
        let p = run(3);
        assert_eq!(p.len(), m.param_count("mid").unwrap() as usize);
        assert!(p.f32s().unwrap().iter().all(|v| v.abs() <= 0.1));
    }

    #[test]
    fn fwd_mid_is_affine_and_bwd_is_its_adjoint() {
        let (m, b) = setup();
        let n = m.param_count("mid").unwrap() as usize;
        let mut w = vec![0f32; n];
        (w[0], w[1]) = (0.5, 0.25);
        let wt = HostTensor::vec_f32(w);
        let x = HostTensor::F32 { data: vec![1.0, -2.0, 0.0], shape: vec![3] };
        let fwd = b.compile(&m, "mid_fwd").unwrap();
        let y = b.execute_host(&fwd, &[&wt, &x]).unwrap().pop().unwrap();
        assert_eq!(y.f32s().unwrap(), &[1.75, -2.75, 0.25]);
        let dy = HostTensor::F32 { data: vec![1.0, 1.0, 1.0], shape: vec![3] };
        let bwd = b.compile(&m, "mid_bwd").unwrap();
        let outs = b.execute_host(&bwd, &[&wt, &x, &dy]).unwrap();
        assert_eq!(outs[0].f32s().unwrap(), &[1.5, 1.5, 1.5]); // dy · (1 + w0)
        let dw = outs[1].f32s().unwrap();
        assert_eq!(dw[0], -1.0); // Σ dy·x
        assert_eq!(dw[1], 3.0); // Σ dy
        assert!(dw[2..].iter().all(|&g| g == 0.0));
    }

    #[test]
    fn last_bwd_gradient_matches_finite_difference() {
        let (m, b) = setup();
        let n = m.param_count("last").unwrap() as usize;
        let spec = &m.spec;
        let bwd = b.compile(&m, "last_bwd").unwrap();
        let positions = (spec.b * spec.s) as usize;
        let x: Vec<f32> = (0..positions * spec.h as usize)
            .map(|i| ((i % 13) as f32) * 0.05 - 0.3)
            .collect();
        let xt = HostTensor::F32 {
            data: x,
            shape: vec![spec.b as i64, spec.s as i64, spec.h as i64],
        };
        let tgt = HostTensor::I32 {
            data: (0..positions as i32).map(|i| i % spec.v as i32).collect(),
            shape: vec![spec.b as i64, spec.s as i64],
        };
        let loss_at = |w0: f32, w1: f32| -> (f32, f32, f32) {
            let mut w = vec![0f32; n];
            (w[0], w[1]) = (w0, w1);
            let outs = b
                .execute_host(&bwd, &[&HostTensor::vec_f32(w), &xt, &tgt])
                .unwrap();
            let dw = outs[1].f32s().unwrap();
            (outs[2].f32s().unwrap()[0], dw[0], dw[1])
        };
        let (loss, g0, g1) = loss_at(0.4, -0.2);
        assert!(loss.is_finite() && loss > 0.0);
        let eps = 1e-2f32;
        let fd0 = (loss_at(0.4 + eps, -0.2).0 - loss_at(0.4 - eps, -0.2).0) / (2.0 * eps);
        let fd1 = (loss_at(0.4, -0.2 + eps).0 - loss_at(0.4, -0.2 - eps).0) / (2.0 * eps);
        assert!((fd0 - g0).abs() < 0.05 * g0.abs().max(0.1), "analytic {g0} vs fd {fd0}");
        assert!((fd1 - g1).abs() < 0.05 * g1.abs().max(0.1), "analytic {g1} vs fd {fd1}");
    }

    #[test]
    fn adam_moves_against_the_gradient_deterministically() {
        let (m, b) = setup();
        let adam = b.compile(&m, "adam_mid").unwrap();
        let n = m.param_count("mid").unwrap() as usize;
        let w = HostTensor::vec_f32(vec![0.5; n]);
        let g = HostTensor::vec_f32(vec![1.0; n]);
        let zero = HostTensor::vec_f32(vec![0.0; n]);
        let step = HostTensor::scalar_i32(1);
        let lr = HostTensor::scalar_f32(0.1);
        let run = || b.execute_host(&adam, &[&w, &g, &zero, &zero, &step, &lr]).unwrap();
        let a = run();
        assert_eq!(a, run(), "adam must be deterministic");
        let w2 = a[0].f32s().unwrap();
        // positive gradient → parameters decrease, by ≈ lr at step 1
        assert!(w2.iter().all(|&v| v < 0.5 && v > 0.5 - 0.11), "{:?}", &w2[..2]);
        // moments updated
        assert!(a[1].f32s().unwrap()[0] > 0.0);
        assert!(a[2].f32s().unwrap()[0] > 0.0);
    }

    #[test]
    fn fwd_first_embeds_tokens_shape_and_range() {
        let (m, b) = setup();
        let fwd = b.compile(&m, "first_fwd").unwrap();
        let n = m.param_count("first").unwrap() as usize;
        let mut w = vec![0f32; n];
        (w[0], w[1]) = (1.0, 0.0);
        let tok = HostTensor::I32 { data: vec![0, 1, 2, 31], shape: vec![2, 2] };
        let y = b
            .execute_host(&fwd, &[&HostTensor::vec_f32(w), &tok])
            .unwrap()
            .pop()
            .unwrap();
        assert_eq!(y.shape(), &[2, 2, 8]);
        let ys = y.f32s().unwrap();
        assert_eq!(ys.len(), 4 * 8);
        assert!(ys.iter().all(|v| v.abs() <= 1.0));
        // distinct tokens embed differently
        assert_ne!(&ys[0..8], &ys[8..16]);
        // repeated execution is bit-identical (pure function)
        let y2 = b
            .execute_host(&fwd, &[&HostTensor::vec_f32({
                let mut w = vec![0f32; n];
                (w[0], w[1]) = (1.0, 0.0);
                w
            }), &tok])
            .unwrap()
            .pop()
            .unwrap();
        assert_eq!(y, y2);
    }

    #[test]
    fn compile_rejects_unknown_and_unlisted_artifacts() {
        let (m, b) = setup();
        assert!(b.compile(&m, "nope_fwd").is_err(), "not in the manifest");
        assert!(b.compile(&m, "last_fwd").is_err(), "no sim semantics / not listed");
        // the b-suffixed sweep artifacts compile to the same ops
        assert!(b.compile(&m, "mid_fwd_b2").is_ok());
        assert!(b.compile(&m, "mid_bwd_b1").is_ok());
    }

    #[test]
    fn donated_fwd_input_is_consumed_in_place() {
        let (m, b) = setup();
        let fwd = b.compile(&m, "mid_fwd").unwrap();
        let n = m.param_count("mid").unwrap() as usize;
        let mut w = vec![0f32; n];
        (w[0], w[1]) = (0.5, 0.25);
        let wt = HostTensor::vec_f32(w);
        let x = HostTensor::F32 { data: vec![1.0, -2.0, 0.0], shape: vec![3] };
        let x_ptr = x.f32s().unwrap().as_ptr();
        let mut args = [Arg::Donated(x)];
        let mut pool = BufferPool::new();
        let mut out = Vec::new();
        b.execute_pooled(&fwd, Some(&wt), &mut args, &mut pool, &mut out).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].f32s().unwrap(), &[1.75, -2.75, 0.25]);
        assert_eq!(
            out[0].f32s().unwrap().as_ptr(),
            x_ptr,
            "donated x must become y in place"
        );
        assert!(matches!(args[0], Arg::Spent));
        assert_eq!(pool.misses, 0, "a fully-donated fwd draws nothing from the pool");
    }

    #[test]
    fn adam_rotates_donated_state_buffers() {
        let (m, b) = setup();
        let adam = b.compile(&m, "adam_mid").unwrap();
        let n = m.param_count("mid").unwrap() as usize;
        let mk = |v: f32| HostTensor::vec_f32(vec![v; n]);
        let (w, g, ms, vs) = (mk(0.5), mk(1.0), mk(0.0), mk(0.0));
        let step = HostTensor::scalar_i32(1);
        let lr = HostTensor::scalar_f32(0.1);
        // reference values from the owned path
        let fresh = b.execute_host(&adam, &[&w, &g, &ms, &vs, &step, &lr]).unwrap();
        let ptrs = [
            w.f32s().unwrap().as_ptr(),
            g.f32s().unwrap().as_ptr(),
            ms.f32s().unwrap().as_ptr(),
        ];
        let mut args = [
            Arg::Donated(w),
            Arg::Donated(g),
            Arg::Donated(ms),
            Arg::Donated(vs),
            Arg::Borrowed(&step),
            Arg::Borrowed(&lr),
        ];
        let mut pool = BufferPool::new();
        let mut out = Vec::new();
        b.execute_pooled(&adam, None, &mut args, &mut pool, &mut out).unwrap();
        assert_eq!(out, fresh, "donating adam must be bit-identical to the owned path");
        // w' in w's buffer, m' in g's, v' in m's; the old v buffer pools
        for (o, p) in out.iter().zip(ptrs.iter()) {
            assert_eq!(o.f32s().unwrap().as_ptr(), *p);
        }
        assert_eq!(pool.len(), 1, "the spare state buffer returns to the pool");
        assert_eq!(pool.misses, 0);
    }

    #[test]
    fn unpooled_baseline_matches_the_donating_backend() {
        let (m, b) = setup();
        let ub = UnpooledSimBackend::create(&m).unwrap();
        assert_eq!(ub.platform(), "sim-unpooled");
        let fwd_a = b.compile(&m, "mid_fwd").unwrap();
        let fwd_b = ub.compile(&m, "mid_fwd").unwrap();
        let n = m.param_count("mid").unwrap() as usize;
        let w = HostTensor::vec_f32((0..n).map(|i| i as f32 * 1e-3).collect());
        let x = HostTensor::F32 { data: vec![0.25, -1.5], shape: vec![2] };
        let ya = b.execute_host(&fwd_a, &[&w, &x]).unwrap();
        let yb = ub.execute_host(&fwd_b, &[&w, &x]).unwrap();
        assert_eq!(ya, yb);
    }
}
