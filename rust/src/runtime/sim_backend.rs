//! `SimBackend` — the deterministic in-tree execution backend.
//!
//! Executes manifest-described stage artifacts as **seeded f32 affine
//! ops on host buffers**: real forward/backward/loss plumbing with
//! reproducible numerics and no external dependency, so the whole
//! coordinator (channels, stashes, BPipe evict/load, Adam,
//! checkpointing) runs under `cargo test -q` by default.
//!
//! ## Stage semantics
//!
//! Every artifact name the python side would lower has a closed-form
//! interpretation over the manifest's shapes (`b`, `s`, `h`, `v`) and
//! per-kind parameter counts.  Only `params[0]` and `params[1]` carry
//! gradients (an affine model); the rest are inert ballast so parameter
//! vectors, optimizer state and checkpoints have realistic sizes:
//!
//! * `{kind}_init(seed) → params` — SplitMix64-seeded values in ±0.1;
//! * `first_fwd(w, tokens) → y` — `y[r,t,j] = w₀·emb(tok,j) + w₁`, with
//!   `emb` a fixed hash-based pseudo-embedding in [−1, 1);
//! * `mid_fwd(w, x) → y` — `y = (1 + w₀)·x + w₁`;
//! * `mid_bwd(w, x, dy) → (dx, dw)` — the exact reverse-mode adjoints;
//! * `last_bwd(w, x, targets) → (dx, dw, loss)` — per-position affine
//!   head `pred = w₀·mean_j(x) + w₁` against the normalized target
//!   token, mean-squared-error loss, exact gradients;
//! * `adam_{kind}(w, g, m, v, step, lr) → (w', m', v')` — standard
//!   bias-corrected Adam, f32 throughout.
//!
//! All reductions accumulate sequentially in index order, so results
//! are **bit-reproducible** — and because the ops are pure functions of
//! their inputs, a BPipe-rebalanced run (whose Evict/Load just move
//! stashes between stores) computes bit-identical losses to its
//! baseline, the paper's central claim, now asserted in tier-1
//! (`rust/tests/integration_runtime.rs`).

use super::artifact::Manifest;
use super::backend::{Backend, HostTensor};
use crate::util::SplitMix64;

/// Adam hyperparameters (the python side's defaults).
const BETA1: f32 = 0.9;
const BETA2: f32 = 0.999;
const EPS: f32 = 1e-8;

/// SplitMix64 finalizer over a raw index — the pseudo-embedding hash.
fn mix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic value in [−1, 1) from the hash's top 24 bits (exactly
/// representable in f32).
fn unit(x: u64) -> f32 {
    (mix(x) >> 40) as f32 / (1u64 << 23) as f32 - 1.0
}

/// The fixed pseudo-embedding of `(token, feature j)`.
fn emb(token: i32, j: u64) -> f32 {
    unit((token as u32 as u64).wrapping_mul(0x0100_0003).wrapping_add(j))
}

/// What a compiled sim artifact computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SimOp {
    Init,
    FwdFirst,
    FwdMid,
    BwdFirst,
    BwdMid,
    BwdLast,
    Adam,
}

/// A "compiled" sim artifact: the op plus the parameter-vector length
/// of its stage kind (used to size/validate parameter inputs).
pub struct SimExec {
    op: SimOp,
    n_params: usize,
    name: String,
}

/// The in-tree deterministic backend.  Device buffers are host tensors;
/// `upload` is a clone.
pub struct SimBackend {
    h: usize,
    vocab: u64,
}

impl SimBackend {
    fn check_params(&self, exe: &SimExec, t: &HostTensor) -> anyhow::Result<()> {
        anyhow::ensure!(
            t.len() == exe.n_params,
            "{}: params length {} != manifest's {}",
            exe.name,
            t.len(),
            exe.n_params
        );
        Ok(())
    }
}

impl Backend for SimBackend {
    type Exec = SimExec;
    type Buffer = HostTensor;

    fn create(manifest: &Manifest) -> anyhow::Result<Self> {
        anyhow::ensure!(manifest.spec.h >= 1, "sim backend needs h >= 1");
        anyhow::ensure!(manifest.spec.v >= 1, "sim backend needs v >= 1");
        Ok(SimBackend { h: manifest.spec.h as usize, vocab: manifest.spec.v })
    }

    fn platform(&self) -> String {
        "sim".into()
    }

    fn compile(&self, manifest: &Manifest, name: &str) -> anyhow::Result<SimExec> {
        // the artifact must be manifest-described, like a real lowered file
        manifest.meta(name)?;
        let (op, kind) = if let Some(kind) = name.strip_prefix("adam_") {
            (SimOp::Adam, kind)
        } else {
            let (kind, rest) = name
                .split_once('_')
                .ok_or_else(|| anyhow::anyhow!("unparseable artifact name {name:?}"))?;
            // strip the single-stage-sweep suffix: "fwd_b4" → "fwd"
            let base = rest.split('_').next().unwrap_or(rest);
            let op = match (kind, base) {
                (_, "init") => SimOp::Init,
                ("first", "fwd") => SimOp::FwdFirst,
                ("mid", "fwd") => SimOp::FwdMid,
                ("first", "bwd") => SimOp::BwdFirst,
                ("mid", "bwd") => SimOp::BwdMid,
                ("last", "bwd") => SimOp::BwdLast,
                _ => anyhow::bail!("artifact {name:?} has no sim semantics"),
            };
            (op, kind)
        };
        Ok(SimExec { op, n_params: manifest.param_count(kind)? as usize, name: name.to_string() })
    }

    fn upload(&self, t: &HostTensor) -> anyhow::Result<HostTensor> {
        Ok(t.clone())
    }

    fn execute(
        &self,
        exe: &SimExec,
        inputs: &[&HostTensor],
    ) -> anyhow::Result<Vec<HostTensor>> {
        let argc = |n: usize| -> anyhow::Result<()> {
            anyhow::ensure!(
                inputs.len() == n,
                "{}: expected {n} inputs, got {}",
                exe.name,
                inputs.len()
            );
            Ok(())
        };
        let h = self.h;
        match exe.op {
            SimOp::Init => {
                argc(1)?;
                let seed = inputs[0].i32s()?[0];
                let mut rng = SplitMix64::new((seed as i64 as u64) ^ 0x5EED_BA5E);
                let data: Vec<f32> =
                    (0..exe.n_params).map(|_| (rng.next_f64() * 0.2 - 0.1) as f32).collect();
                Ok(vec![HostTensor::vec_f32(data)])
            }
            SimOp::FwdFirst => {
                argc(2)?;
                self.check_params(exe, inputs[0])?;
                let w = inputs[0].f32s()?;
                let tok = inputs[1].i32s()?;
                let (w0, w1) = (w[0], w[1]);
                let mut y = Vec::with_capacity(tok.len() * h);
                for &t in tok {
                    for j in 0..h {
                        y.push(w0 * emb(t, j as u64) + w1);
                    }
                }
                let mut shape = inputs[1].shape().to_vec();
                shape.push(h as i64);
                Ok(vec![HostTensor::F32 { data: y, shape }])
            }
            SimOp::FwdMid => {
                argc(2)?;
                self.check_params(exe, inputs[0])?;
                let w = inputs[0].f32s()?;
                let x = inputs[1].f32s()?;
                let (scale, shift) = (1.0 + w[0], w[1]);
                let y: Vec<f32> = x.iter().map(|&v| scale * v + shift).collect();
                Ok(vec![HostTensor::F32 { data: y, shape: inputs[1].shape().to_vec() }])
            }
            SimOp::BwdFirst => {
                argc(3)?;
                self.check_params(exe, inputs[0])?;
                let tok = inputs[1].i32s()?;
                let dy = inputs[2].f32s()?;
                anyhow::ensure!(dy.len() == tok.len() * h, "{}: dy shape mismatch", exe.name);
                let (mut g0, mut g1) = (0f32, 0f32);
                for (p, &t) in tok.iter().enumerate() {
                    for j in 0..h {
                        let d = dy[p * h + j];
                        g0 += d * emb(t, j as u64);
                        g1 += d;
                    }
                }
                let mut dw = vec![0f32; exe.n_params];
                dw[0] = g0;
                dw[1] = g1;
                Ok(vec![HostTensor::vec_f32(dw)])
            }
            SimOp::BwdMid => {
                argc(3)?;
                self.check_params(exe, inputs[0])?;
                let w = inputs[0].f32s()?;
                let x = inputs[1].f32s()?;
                let dy = inputs[2].f32s()?;
                anyhow::ensure!(x.len() == dy.len(), "{}: x/dy length mismatch", exe.name);
                let scale = 1.0 + w[0];
                let dx: Vec<f32> = dy.iter().map(|&d| d * scale).collect();
                let (mut g0, mut g1) = (0f32, 0f32);
                for (d, xv) in dy.iter().zip(x.iter()) {
                    g0 += d * xv;
                    g1 += d;
                }
                let mut dw = vec![0f32; exe.n_params];
                dw[0] = g0;
                dw[1] = g1;
                Ok(vec![
                    HostTensor::F32 { data: dx, shape: inputs[2].shape().to_vec() },
                    HostTensor::vec_f32(dw),
                ])
            }
            SimOp::BwdLast => {
                argc(3)?;
                self.check_params(exe, inputs[0])?;
                let w = inputs[0].f32s()?;
                let x = inputs[1].f32s()?;
                let tgt = inputs[2].i32s()?;
                anyhow::ensure!(x.len() == tgt.len() * h, "{}: x shape mismatch", exe.name);
                let (w0, w1) = (w[0], w[1]);
                let inv_h = 1.0f32 / h as f32;
                let inv_n = 1.0f32 / tgt.len() as f32;
                let inv_v = 1.0f32 / self.vocab as f32;
                let mut dx = vec![0f32; x.len()];
                let (mut loss, mut g0, mut g1) = (0f32, 0f32, 0f32);
                for (p, &t) in tgt.iter().enumerate() {
                    let mut u = 0f32;
                    for j in 0..h {
                        u += x[p * h + j];
                    }
                    u *= inv_h;
                    let pred = w0 * u + w1;
                    let target = t as f32 * inv_v - 0.5;
                    let e = pred - target;
                    loss += e * e;
                    let dpred = 2.0 * e * inv_n;
                    g0 += dpred * u;
                    g1 += dpred;
                    let dxv = dpred * w0 * inv_h;
                    for j in 0..h {
                        dx[p * h + j] = dxv;
                    }
                }
                loss *= inv_n;
                let mut dw = vec![0f32; exe.n_params];
                dw[0] = g0;
                dw[1] = g1;
                Ok(vec![
                    HostTensor::F32 { data: dx, shape: inputs[1].shape().to_vec() },
                    HostTensor::vec_f32(dw),
                    HostTensor::scalar_f32(loss),
                ])
            }
            SimOp::Adam => {
                argc(6)?;
                self.check_params(exe, inputs[0])?;
                let w = inputs[0].f32s()?;
                let g = inputs[1].f32s()?;
                let m = inputs[2].f32s()?;
                let v = inputs[3].f32s()?;
                anyhow::ensure!(
                    g.len() == w.len() && m.len() == w.len() && v.len() == w.len(),
                    "{}: state length mismatch",
                    exe.name
                );
                let step = inputs[4].i32s()?[0];
                anyhow::ensure!(step >= 1, "{}: adam step must be >= 1", exe.name);
                let lr = inputs[5].f32s()?[0];
                let bc1 = 1.0 - BETA1.powi(step);
                let bc2 = 1.0 - BETA2.powi(step);
                let mut w2 = Vec::with_capacity(w.len());
                let mut m2 = Vec::with_capacity(w.len());
                let mut v2 = Vec::with_capacity(w.len());
                for i in 0..w.len() {
                    let mi = BETA1 * m[i] + (1.0 - BETA1) * g[i];
                    let vi = BETA2 * v[i] + (1.0 - BETA2) * g[i] * g[i];
                    let mhat = mi / bc1;
                    let vhat = vi / bc2;
                    w2.push(w[i] - lr * mhat / (vhat.sqrt() + EPS));
                    m2.push(mi);
                    v2.push(vi);
                }
                Ok(vec![
                    HostTensor::vec_f32(w2),
                    HostTensor::vec_f32(m2),
                    HostTensor::vec_f32(v2),
                ])
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Manifest, SimBackend) {
        let m = Manifest::synthetic(4, 8, 4, 2, 32, &[1, 2]);
        let b = SimBackend::create(&m).unwrap();
        (m, b)
    }

    #[test]
    fn init_is_deterministic_in_seed() {
        let (m, b) = setup();
        let init = b.compile(&m, "mid_init").unwrap();
        let run = |seed: i32| {
            b.execute_host(&init, &[&HostTensor::scalar_i32(seed)])
                .unwrap()
                .pop()
                .unwrap()
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
        let p = run(3);
        assert_eq!(p.len(), m.param_count("mid").unwrap() as usize);
        assert!(p.f32s().unwrap().iter().all(|v| v.abs() <= 0.1));
    }

    #[test]
    fn fwd_mid_is_affine_and_bwd_is_its_adjoint() {
        let (m, b) = setup();
        let n = m.param_count("mid").unwrap() as usize;
        let mut w = vec![0f32; n];
        (w[0], w[1]) = (0.5, 0.25);
        let wt = HostTensor::vec_f32(w);
        let x = HostTensor::F32 { data: vec![1.0, -2.0, 0.0], shape: vec![3] };
        let fwd = b.compile(&m, "mid_fwd").unwrap();
        let y = b.execute_host(&fwd, &[&wt, &x]).unwrap().pop().unwrap();
        assert_eq!(y.f32s().unwrap(), &[1.75, -2.75, 0.25]);
        let dy = HostTensor::F32 { data: vec![1.0, 1.0, 1.0], shape: vec![3] };
        let bwd = b.compile(&m, "mid_bwd").unwrap();
        let outs = b.execute_host(&bwd, &[&wt, &x, &dy]).unwrap();
        assert_eq!(outs[0].f32s().unwrap(), &[1.5, 1.5, 1.5]); // dy · (1 + w0)
        let dw = outs[1].f32s().unwrap();
        assert_eq!(dw[0], -1.0); // Σ dy·x
        assert_eq!(dw[1], 3.0); // Σ dy
        assert!(dw[2..].iter().all(|&g| g == 0.0));
    }

    #[test]
    fn last_bwd_gradient_matches_finite_difference() {
        let (m, b) = setup();
        let n = m.param_count("last").unwrap() as usize;
        let spec = &m.spec;
        let bwd = b.compile(&m, "last_bwd").unwrap();
        let positions = (spec.b * spec.s) as usize;
        let x: Vec<f32> = (0..positions * spec.h as usize)
            .map(|i| ((i % 13) as f32) * 0.05 - 0.3)
            .collect();
        let xt = HostTensor::F32 {
            data: x,
            shape: vec![spec.b as i64, spec.s as i64, spec.h as i64],
        };
        let tgt = HostTensor::I32 {
            data: (0..positions as i32).map(|i| i % spec.v as i32).collect(),
            shape: vec![spec.b as i64, spec.s as i64],
        };
        let loss_at = |w0: f32, w1: f32| -> (f32, f32, f32) {
            let mut w = vec![0f32; n];
            (w[0], w[1]) = (w0, w1);
            let outs = b
                .execute_host(&bwd, &[&HostTensor::vec_f32(w), &xt, &tgt])
                .unwrap();
            let dw = outs[1].f32s().unwrap();
            (outs[2].f32s().unwrap()[0], dw[0], dw[1])
        };
        let (loss, g0, g1) = loss_at(0.4, -0.2);
        assert!(loss.is_finite() && loss > 0.0);
        let eps = 1e-2f32;
        let fd0 = (loss_at(0.4 + eps, -0.2).0 - loss_at(0.4 - eps, -0.2).0) / (2.0 * eps);
        let fd1 = (loss_at(0.4, -0.2 + eps).0 - loss_at(0.4, -0.2 - eps).0) / (2.0 * eps);
        assert!((fd0 - g0).abs() < 0.05 * g0.abs().max(0.1), "analytic {g0} vs fd {fd0}");
        assert!((fd1 - g1).abs() < 0.05 * g1.abs().max(0.1), "analytic {g1} vs fd {fd1}");
    }

    #[test]
    fn adam_moves_against_the_gradient_deterministically() {
        let (m, b) = setup();
        let adam = b.compile(&m, "adam_mid").unwrap();
        let n = m.param_count("mid").unwrap() as usize;
        let w = HostTensor::vec_f32(vec![0.5; n]);
        let g = HostTensor::vec_f32(vec![1.0; n]);
        let zero = HostTensor::vec_f32(vec![0.0; n]);
        let step = HostTensor::scalar_i32(1);
        let lr = HostTensor::scalar_f32(0.1);
        let run = || b.execute_host(&adam, &[&w, &g, &zero, &zero, &step, &lr]).unwrap();
        let a = run();
        assert_eq!(a, run(), "adam must be deterministic");
        let w2 = a[0].f32s().unwrap();
        // positive gradient → parameters decrease, by ≈ lr at step 1
        assert!(w2.iter().all(|&v| v < 0.5 && v > 0.5 - 0.11), "{:?}", &w2[..2]);
        // moments updated
        assert!(a[1].f32s().unwrap()[0] > 0.0);
        assert!(a[2].f32s().unwrap()[0] > 0.0);
    }

    #[test]
    fn fwd_first_embeds_tokens_shape_and_range() {
        let (m, b) = setup();
        let fwd = b.compile(&m, "first_fwd").unwrap();
        let n = m.param_count("first").unwrap() as usize;
        let mut w = vec![0f32; n];
        (w[0], w[1]) = (1.0, 0.0);
        let tok = HostTensor::I32 { data: vec![0, 1, 2, 31], shape: vec![2, 2] };
        let y = b
            .execute_host(&fwd, &[&HostTensor::vec_f32(w), &tok])
            .unwrap()
            .pop()
            .unwrap();
        assert_eq!(y.shape(), &[2, 2, 8]);
        let ys = y.f32s().unwrap();
        assert_eq!(ys.len(), 4 * 8);
        assert!(ys.iter().all(|v| v.abs() <= 1.0));
        // distinct tokens embed differently
        assert_ne!(&ys[0..8], &ys[8..16]);
        // repeated execution is bit-identical (pure function)
        let y2 = b
            .execute_host(&fwd, &[&HostTensor::vec_f32({
                let mut w = vec![0f32; n];
                (w[0], w[1]) = (1.0, 0.0);
                w
            }), &tok])
            .unwrap()
            .pop()
            .unwrap();
        assert_eq!(y, y2);
    }

    #[test]
    fn compile_rejects_unknown_and_unlisted_artifacts() {
        let (m, b) = setup();
        assert!(b.compile(&m, "nope_fwd").is_err(), "not in the manifest");
        assert!(b.compile(&m, "last_fwd").is_err(), "no sim semantics / not listed");
        // the b-suffixed sweep artifacts compile to the same ops
        assert!(b.compile(&m, "mid_fwd_b2").is_ok());
        assert!(b.compile(&m, "mid_bwd_b1").is_ok());
    }
}
