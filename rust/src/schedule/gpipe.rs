//! GPipe schedule (Huang et al. 2019): all `m` forwards, then all `m`
//! backwards.  Simple, but every stage holds all `m` activation stashes
//! at the flush point — the memory profile 1F1B (and then BPipe)
//! progressively improves on.  Included as the schedule-comparison
//! baseline ablation.

use super::{Op, Placement, Schedule, ScheduleKind, StageProgram};

/// Generate the GPipe schedule for `p` stages and `m` microbatches.
pub fn gpipe(p: u64, m: u64) -> Schedule {
    assert!(p >= 1 && m >= 1);
    let programs = (0..p)
        .map(|s| {
            let mut ops = Vec::with_capacity(2 * m as usize);
            ops.extend((0..m).map(Op::fwd));
            // backward order is reversed at the boundary stage in real
            // GPipe implementations only w.r.t. chunk; per-microbatch
            // FIFO retirement keeps stash accounting identical.
            ops.extend((0..m).map(Op::bwd));
            StageProgram { stage: s, ops }
        })
        .collect();
    Schedule {
        p,
        m,
        chunks: 1,
        placement: Placement::Sequential,
        kind: ScheduleKind::GPipe,
        stage_bounds: None,
        programs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate;

    #[test]
    fn all_fwd_then_all_bwd() {
        let s = gpipe(4, 8);
        for st in 0..4 {
            let ops = &s.program(st).ops;
            assert!(ops[..8].iter().all(|o| o.kind == super::super::OpKind::Fwd));
            assert!(ops[8..].iter().all(|o| o.kind == super::super::OpKind::Bwd));
        }
    }

    #[test]
    fn stash_high_water_is_m() {
        // GPipe's memory problem: every stage peaks at m stashes
        let s = gpipe(4, 16);
        for st in 0..4 {
            assert_eq!(s.program(st).stash_high_water(), 16);
        }
    }

    #[test]
    fn validates() {
        for (p, m) in [(1, 1), (4, 8), (8, 64)] {
            validate(&gpipe(p, m)).unwrap();
        }
    }
}
