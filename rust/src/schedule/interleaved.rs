//! Interleaved 1F1B (virtual pipeline) — Megatron-LM's
//! `forward_backward_pipelining_with_interleaving`.
//!
//! Each physical stage hosts `v` model *chunks* (virtual stages), cutting
//! the bubble from `(p−1)/m` to `(p−1)/(v·m)` at the price of more
//! p2p communication and a *higher* activation stash count — which is why
//! the memory-imbalance story (and BPipe) still matters.  Included as the
//! schedule-comparison ablation baseline; BPipe itself applies to plain
//! 1F1B (paper §2.2).

use super::{Op, OpKind, Placement, Schedule, ScheduleKind, StageProgram};

/// Map forward-slot index `k` to (microbatch, chunk) — microbatches run
/// in groups of `p`; within a group, the chunk advances every `p` slots.
fn fwd_slot(k: u64, p: u64, v: u64) -> (u64, u64) {
    let group = k / (p * v);
    let chunk = (k % (p * v)) / p;
    let mb = group * p + (k % p);
    (mb, chunk)
}

/// Backward slots retire chunks in reverse order.
fn bwd_slot(k: u64, p: u64, v: u64) -> (u64, u64) {
    let (mb, chunk) = fwd_slot(k, p, v);
    (mb, v - 1 - chunk)
}

/// Generate the interleaved-1F1B schedule: `p` stages, `m` microbatches,
/// `v` chunks per stage.  Megatron requires `m % p == 0`.
pub fn interleaved(p: u64, m: u64, v: u64) -> Schedule {
    assert!(v >= 1, "need at least one chunk");
    assert!(m % p == 0, "interleaved schedule requires m ({m}) % p ({p}) == 0");
    let total = m * v;
    let programs = (0..p)
        .map(|s| {
            let mut warmup = (p - s - 1) * 2 + (v - 1) * p;
            warmup = warmup.min(total);
            let mut ops = Vec::with_capacity(2 * total as usize);
            for k in 0..warmup {
                let (mb, chunk) = fwd_slot(k, p, v);
                ops.push(Op { kind: OpKind::Fwd, mb, chunk });
            }
            let steady = total - warmup;
            for i in 0..steady {
                let (mb, chunk) = fwd_slot(warmup + i, p, v);
                ops.push(Op { kind: OpKind::Fwd, mb, chunk });
                let (mb, chunk) = bwd_slot(i, p, v);
                ops.push(Op { kind: OpKind::Bwd, mb, chunk });
            }
            for i in steady..total {
                let (mb, chunk) = bwd_slot(i, p, v);
                ops.push(Op { kind: OpKind::Bwd, mb, chunk });
            }
            StageProgram { stage: s, ops }
        })
        .collect();
    Schedule {
        p,
        m,
        chunks: v,
        placement: Placement::Sequential,
        kind: ScheduleKind::Interleaved { chunks: v },
        stage_bounds: None,
        programs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate;
    use std::collections::HashSet;

    #[test]
    fn v1_reduces_to_something_1f1b_shaped() {
        let s = interleaved(4, 8, 1);
        let base = crate::schedule::one_f_one_b(4, 8);
        // same op multiset per stage and same warmup depth ±1
        for st in 0..4 {
            assert_eq!(s.count(st, OpKind::Fwd), base.count(st, OpKind::Fwd));
            assert_eq!(s.count(st, OpKind::Bwd), base.count(st, OpKind::Bwd));
        }
        validate(&s).unwrap();
    }

    #[test]
    fn every_mb_chunk_pair_once() {
        let (p, m, v) = (4, 8, 2);
        let s = interleaved(p, m, v);
        for st in 0..p {
            let mut fwd = HashSet::new();
            let mut bwd = HashSet::new();
            for op in &s.program(st).ops {
                let set = if op.kind == OpKind::Fwd { &mut fwd } else { &mut bwd };
                assert!(set.insert((op.mb, op.chunk)), "dup {op:?} on stage {st}");
            }
            assert_eq!(fwd.len() as u64, m * v);
            assert_eq!(bwd.len() as u64, m * v);
        }
    }

    #[test]
    fn bwd_follows_fwd_per_chunk() {
        let s = interleaved(4, 8, 2);
        for st in 0..4 {
            let ops = &s.program(st).ops;
            for (i, op) in ops.iter().enumerate() {
                if op.kind == OpKind::Bwd {
                    let fwd_pos = ops
                        .iter()
                        .position(|o| o.kind == OpKind::Fwd && o.mb == op.mb && o.chunk == op.chunk)
                        .expect("bwd without fwd");
                    assert!(fwd_pos < i, "stage {st}: bwd {op:?} before its fwd");
                }
            }
        }
    }

    #[test]
    fn higher_stash_high_water_than_plain() {
        // interleaving trades memory for bubble: stash HW grows with v
        let plain = crate::schedule::one_f_one_b(4, 16);
        let il = interleaved(4, 16, 2);
        assert!(
            il.program(0).stash_high_water() > plain.program(0).stash_high_water()
        );
    }

    #[test]
    #[should_panic(expected = "m (6) % p (4)")]
    fn requires_divisibility() {
        interleaved(4, 6, 2);
    }

    #[test]
    fn validates() {
        for v in 1..=3 {
            validate(&interleaved(4, 8, v)).unwrap();
            validate(&interleaved(8, 16, v)).unwrap();
        }
    }
}
