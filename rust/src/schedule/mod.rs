//! Pipeline-parallel schedule generators.
//!
//! A [`Schedule`] is one op-list per stage ([`StageProgram`]), in program
//! order.  Generators:
//!
//! * [`gpipe()`] — all forwards, then all backwards (GPipe);
//! * [`one_f_one_b()`] — the 1F1B/DAPPLE schedule Megatron-LM uses and
//!   the paper builds on (§2.2);
//! * [`interleaved()`] — Megatron's interleaved-1F1B (virtual pipeline),
//!   for the schedule-comparison ablation;
//! * [`v_shaped()`] — a V-shaped two-chunk virtual pipeline in the
//!   controllable-memory family (Qi et al. 2024): chunk 0 flows
//!   stage 0→p−1, chunk 1 flows back p−1→0, equalizing stash pressure
//!   across stages by placement instead of by transfers;
//! * [`zigzag()`] — the general `v`-chunk zig-zag placement the V shape
//!   is the `v = 2` case of: chunks alternate direction down the pipe
//!   (`v = 4` is the W-shaped placement of the controllable-memory
//!   paper's Figure 5 family);
//! * [`synthesize()`] — not a fixed family at all: searches
//!   warmup-depth schedules (plus a family portfolio) under per-stage
//!   memory caps, scored by the DES cost model;
//! * [`crate::bpipe::rebalance()`] — the schedule-agnostic memory
//!   rebalancing transform (BPipe generalized beyond 1F1B), inserting
//!   activation Evict/Load ops keyed by `(mb, chunk)`;
//! * [`crate::bpipe::apply_bpipe`] — the paper's 1F1B-specific BPipe
//!   wrapper around `rebalance` (paper Figure 1).
//!
//! Schedules are *data*: the simulator executes them against a cost
//! model, and the real coordinator executes them against PJRT
//! executables — one source of truth for both.

pub mod gpipe;
pub mod interleaved;
pub mod one_f_one_b;
pub mod synthesize;
pub mod v_shaped;
pub mod validate;
pub mod zigzag;

pub use gpipe::gpipe;
pub use interleaved::interleaved;
pub use one_f_one_b::one_f_one_b;
pub use synthesize::{stash_count_caps, synthesize, try_synthesize, SynthesisError};
pub use v_shaped::v_shaped;
pub use validate::{validate, ValidationError};
pub use zigzag::zigzag;


/// What a stage does at one program step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Forward pass of one microbatch through this stage's layers.
    Fwd,
    /// Backward pass (consumes the stashed stage input).
    Bwd,
    /// BPipe: push the stash of a microbatch to the paired acceptor
    /// stage (frees local memory once the transfer completes).
    Evict,
    /// BPipe: fetch an evicted stash back before its backward.
    Load,
}

/// One scheduled operation on one stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Op {
    pub kind: OpKind,
    /// Microbatch index within the iteration (0-based).
    pub mb: u64,
    /// Virtual-pipeline chunk (always 0 except for interleaved/V-shaped).
    pub chunk: u64,
}

impl Op {
    pub fn fwd(mb: u64) -> Self {
        Op { kind: OpKind::Fwd, mb, chunk: 0 }
    }
    pub fn bwd(mb: u64) -> Self {
        Op { kind: OpKind::Bwd, mb, chunk: 0 }
    }
    pub fn evict(mb: u64) -> Self {
        Op { kind: OpKind::Evict, mb, chunk: 0 }
    }
    pub fn load(mb: u64) -> Self {
        Op { kind: OpKind::Load, mb, chunk: 0 }
    }
}

/// The op sequence one pipeline stage executes for one iteration.
#[derive(Debug, Clone, PartialEq)]
pub struct StageProgram {
    pub stage: u64,
    pub ops: Vec<Op>,
}

impl StageProgram {
    /// In-flight stash high-water mark implied by this program: +1 per
    /// Fwd, −1 per Evict, +1 per Load, −1 per Bwd.
    pub fn stash_high_water(&self) -> i64 {
        let mut cur = 0i64;
        let mut hw = 0i64;
        for op in &self.ops {
            match op.kind {
                OpKind::Fwd | OpKind::Load => cur += 1,
                OpKind::Bwd | OpKind::Evict => cur -= 1,
            }
            hw = hw.max(cur);
        }
        hw
    }
}

/// A schedule *family*: which generator to run — the lazy handle the
/// sweep stores per grid cell instead of a materialized (cloned)
/// [`Schedule`].  Building from the family on the worker thread keeps
/// [`crate::sim::sweep::SweepTask`]s tiny and the grid construction
/// allocation-light.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    OneFOneB,
    GPipe,
    /// Megatron interleaved-1F1B with `v` chunks per stage.
    Interleaved { v: u64 },
    VShaped,
    /// General `v`-chunk zig-zag placement (alternating chunk directions;
    /// `v = 4` is the W-shaped placement, `v = 2` duplicates [`VShaped`]).
    ZigZag { v: u64 },
}

impl Family {
    /// Run the family's generator for `p` stages and `m` microbatches.
    pub fn build(&self, p: u64, m: u64) -> Schedule {
        match *self {
            Family::OneFOneB => one_f_one_b(p, m),
            Family::GPipe => gpipe(p, m),
            Family::Interleaved { v } => interleaved(p, m, v),
            Family::VShaped => v_shaped(p, m),
            Family::ZigZag { v } => zigzag(p, m, v),
        }
    }

    /// Virtual-pipeline chunks the family's schedules host per stage
    /// (matches `build(p, m).chunks` without generating anything — the
    /// coordinator uses it to derive `p` from a manifest's total
    /// virtual-stage count before building the schedule).
    pub fn chunks(&self) -> u64 {
        match *self {
            Family::OneFOneB | Family::GPipe => 1,
            Family::Interleaved { v } | Family::ZigZag { v } => v,
            Family::VShaped => 2,
        }
    }

    /// Display name (sweep-report scenario column).
    pub fn label(&self) -> &'static str {
        match self {
            Family::OneFOneB => "1F1B",
            Family::GPipe => "GPipe",
            Family::Interleaved { .. } => "interleaved",
            Family::VShaped => "V-shaped",
            Family::ZigZag { v: 4 } => "W-shaped",
            Family::ZigZag { .. } => "zig-zag",
        }
    }

    /// Display name of the family composed with the rebalance transform.
    pub fn rebalanced_label(&self) -> &'static str {
        match self {
            Family::OneFOneB => "1F1B+rebalance",
            Family::GPipe => "GPipe+rebalance",
            Family::Interleaved { .. } => "interleaved+rebalance",
            Family::VShaped => "V-shaped+rebalance",
            Family::ZigZag { v: 4 } => "W-shaped+rebalance",
            Family::ZigZag { .. } => "zig-zag+rebalance",
        }
    }

    /// Display name of the family composed with the per-stage
    /// (capacity-derived, non-uniform) rebalance transform.
    pub fn stage_bounds_label(&self) -> &'static str {
        match self {
            Family::OneFOneB => "1F1B+stage-bounds",
            Family::GPipe => "GPipe+stage-bounds",
            Family::Interleaved { .. } => "interleaved+stage-bounds",
            Family::VShaped => "V-shaped+stage-bounds",
            Family::ZigZag { v: 4 } => "W-shaped+stage-bounds",
            Family::ZigZag { .. } => "zig-zag+stage-bounds",
        }
    }
}

/// Which generator produced a schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleKind {
    GPipe,
    OneFOneB,
    Interleaved { chunks: u64 },
    /// V-shaped two-chunk virtual pipeline (controllable-memory family).
    VShaped,
    /// General zig-zag `chunks`-way virtual pipeline (W shape at 4).
    ZigZag { chunks: u64 },
    /// A rebalanced schedule (BPipe generalized): Evict/Load ops keep
    /// every stage's own resident stash count ≤ `bound` (or, when
    /// [`Schedule::stage_bounds`] is set, ≤ that stage's own bound).
    BPipe { bound: u64 },
    /// Found by [`synthesize()`] rather than generated from a family:
    /// searched warmup-depth (W) schedules competing against a family
    /// portfolio under per-stage memory caps.  Always paired with
    /// `stage_bounds: Some(stash budgets)` so the caps it was
    /// synthesized under stay machine-enforced downstream.
    Synthesized,
}

/// How virtual-pipeline chunks map onto physical stages — the forward
/// dataflow direction the simulator derives cross-stage deps from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Every chunk flows stage 0→p−1; chunk c+1 starts where chunk c
    /// wrapped (plain + Megatron interleaved).
    Sequential,
    /// Chunks alternate direction: even chunks flow 0→p−1, odd chunks
    /// p−1→0, each starting on the physical stage where the previous
    /// chunk ended.  Two chunks make the V shape, four make the W.
    ZigZag,
}

impl Placement {
    /// The virtual-pipeline stage index of `chunk` hosted on physical
    /// `stage` of a `p`-deep pipeline.  Virtual stage `d` belongs to
    /// chunk `d / p`; within the chunk, sequential placements walk
    /// 0→p−1 while zig-zag placements alternate direction per chunk.
    pub fn virtual_stage(&self, p: u64, stage: u64, chunk: u64) -> u64 {
        match self {
            Placement::Sequential => chunk * p + stage,
            Placement::ZigZag => chunk * p + zigzag::zigzag_offset(p, stage, chunk),
        }
    }

    /// The physical stage hosting virtual stage `virt` — the inverse of
    /// [`Placement::virtual_stage`].  This is the routing function the
    /// real coordinator wires its activation/gradient channels from: the
    /// boundary `virt → virt + 1` connects `host_stage(virt)` to
    /// `host_stage(virt + 1)` (possibly the same worker, at zig-zag
    /// junctions).
    pub fn host_stage(&self, p: u64, virt: u64) -> u64 {
        let (chunk, offset) = (virt / p, virt % p);
        match self {
            Placement::Sequential => offset,
            // zigzag_offset is an involution per chunk
            Placement::ZigZag => zigzag::zigzag_offset(p, offset, chunk),
        }
    }
}

/// A complete pipeline schedule: one program per stage.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    /// pipeline depth (number of stages)
    pub p: u64,
    /// microbatches per iteration
    pub m: u64,
    /// virtual-pipeline chunks hosted per stage (1 unless interleaved /
    /// V-shaped / zig-zag) — op `chunk` fields range over `0..chunks`
    pub chunks: u64,
    /// chunk→stage dataflow layout
    pub placement: Placement,
    pub kind: ScheduleKind,
    /// Per-stage resident-stash bounds, set only by
    /// [`crate::bpipe::rebalance_bounded`] (non-uniform BPipe): the
    /// validator enforces `stash_high_water(s) ≤ stage_bounds[s]` on top
    /// of the uniform `BPipe { bound }` ceiling.
    pub stage_bounds: Option<Vec<u64>>,
    pub programs: Vec<StageProgram>,
}

impl Schedule {
    pub fn program(&self, stage: u64) -> &StageProgram {
        &self.programs[stage as usize]
    }

    /// Total op count across stages.
    pub fn num_ops(&self) -> usize {
        self.programs.iter().map(|p| p.ops.len()).sum()
    }

    /// Count ops of a kind on a stage.
    pub fn count(&self, stage: u64, kind: OpKind) -> usize {
        self.program(stage).ops.iter().filter(|o| o.kind == kind).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_constructors() {
        assert_eq!(Op::fwd(3), Op { kind: OpKind::Fwd, mb: 3, chunk: 0 });
        assert_eq!(Op::evict(1).kind, OpKind::Evict);
    }

    #[test]
    fn family_builds_every_generator() {
        for fam in [
            Family::OneFOneB,
            Family::GPipe,
            Family::Interleaved { v: 2 },
            Family::VShaped,
            Family::ZigZag { v: 3 },
            Family::ZigZag { v: 4 },
        ] {
            let s = fam.build(4, 8);
            validate(&s).unwrap_or_else(|e| panic!("{fam:?}: {e}"));
            assert!(!fam.label().is_empty());
            assert!(fam.rebalanced_label().ends_with("+rebalance"), "{fam:?}");
            assert!(fam.stage_bounds_label().ends_with("+stage-bounds"), "{fam:?}");
        }
        assert_eq!(Family::Interleaved { v: 3 }.build(4, 8).chunks, 3);
        assert_eq!(Family::ZigZag { v: 4 }.build(4, 8).chunks, 4);
        assert_eq!(Family::ZigZag { v: 4 }.label(), "W-shaped");
        assert_eq!(Family::ZigZag { v: 3 }.label(), "zig-zag");
    }

    #[test]
    fn family_chunks_match_built_schedules() {
        for fam in [
            Family::OneFOneB,
            Family::GPipe,
            Family::Interleaved { v: 3 },
            Family::VShaped,
            Family::ZigZag { v: 4 },
        ] {
            assert_eq!(fam.build(4, 8).chunks, fam.chunks(), "{fam:?}");
        }
    }

    #[test]
    fn placement_routing_round_trips() {
        for placement in [Placement::Sequential, Placement::ZigZag] {
            for p in [1u64, 2, 4, 5, 8] {
                for chunk in 0..4 {
                    for stage in 0..p {
                        let d = placement.virtual_stage(p, stage, chunk);
                        assert_eq!(d / p, chunk);
                        assert_eq!(
                            placement.host_stage(p, d),
                            stage,
                            "{placement:?} p={p} c={chunk} s={stage}"
                        );
                    }
                }
            }
        }
        // the V shape: chunk 0 flows 0→p−1, chunk 1 starts where it
        // ended (stage p−1) and flows back to 0
        assert_eq!(Placement::ZigZag.host_stage(4, 3), 3);
        assert_eq!(Placement::ZigZag.host_stage(4, 4), 3);
        assert_eq!(Placement::ZigZag.host_stage(4, 7), 0);
    }

    #[test]
    fn stash_high_water_counts() {
        let prog = StageProgram {
            stage: 0,
            ops: vec![Op::fwd(0), Op::fwd(1), Op::evict(1), Op::fwd(2), Op::bwd(0), Op::load(1), Op::bwd(1), Op::bwd(2)],
        };
        assert_eq!(prog.stash_high_water(), 2);
    }
}
