//! The 1F1B (one-forward-one-backward) schedule — DAPPLE / PipeDream-flush,
//! as implemented in Megatron-LM and assumed throughout the paper (§2.2).
//!
//! Stage `s` (0-based) of `p` runs:
//!
//! 1. **warmup** — `min(m, p − 1 − s)` forwards;
//! 2. **steady state** — alternating (Fwd, Bwd) pairs until all `m`
//!    forwards are issued (one backward retires for each new forward, so
//!    in-flight stashes stay at `p − s`);
//! 3. **cooldown** — the remaining backwards.
//!
//! This keeps stage 0 holding up to `p` microbatch stashes — the memory
//! imbalance BPipe exists to fix.

use super::{Op, Placement, Schedule, ScheduleKind, StageProgram};

/// Number of warmup forwards at `stage` (0-based) of `p` with `m`
/// microbatches.
pub fn warmup_fwds(p: u64, stage: u64, m: u64) -> u64 {
    (p - 1 - stage).min(m)
}

/// Generate the 1F1B schedule for `p` stages and `m` microbatches.
pub fn one_f_one_b(p: u64, m: u64) -> Schedule {
    assert!(p >= 1, "need at least one stage");
    assert!(m >= 1, "need at least one microbatch");
    let programs = (0..p)
        .map(|s| {
            let warmup = warmup_fwds(p, s, m);
            let mut ops = Vec::with_capacity(2 * m as usize);
            for i in 0..warmup {
                ops.push(Op::fwd(i));
            }
            // steady state: F(warmup), B(0), F(warmup+1), B(1), …
            let steady = m - warmup;
            for i in 0..steady {
                ops.push(Op::fwd(warmup + i));
                ops.push(Op::bwd(i));
            }
            // cooldown: remaining backwards
            for i in steady..m {
                ops.push(Op::bwd(i));
            }
            StageProgram { stage: s, ops }
        })
        .collect();
    Schedule {
        p,
        m,
        chunks: 1,
        placement: Placement::Sequential,
        kind: ScheduleKind::OneFOneB,
        stage_bounds: None,
        programs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{validate, OpKind};

    #[test]
    fn last_stage_strictly_alternates() {
        let s = one_f_one_b(4, 8);
        let ops = &s.program(3).ops;
        for (i, op) in ops.iter().enumerate() {
            let want = if i % 2 == 0 { OpKind::Fwd } else { OpKind::Bwd };
            assert_eq!(op.kind, want, "op {i}");
        }
    }

    #[test]
    fn stage0_warmup_is_p_minus_1() {
        let s = one_f_one_b(8, 64);
        let ops = &s.program(0).ops;
        assert!(ops[..7].iter().all(|o| o.kind == OpKind::Fwd));
        assert_eq!(ops[7], Op::fwd(7));
        assert_eq!(ops[8], Op::bwd(0));
    }

    #[test]
    fn op_counts() {
        let s = one_f_one_b(8, 64);
        for st in 0..8 {
            assert_eq!(s.count(st, OpKind::Fwd), 64);
            assert_eq!(s.count(st, OpKind::Bwd), 64);
        }
    }

    #[test]
    fn in_flight_high_water_is_p_minus_s() {
        // the paper's §2.2 claim: stage x stores p−x activations
        let p = 8;
        let s = one_f_one_b(p, 64);
        for st in 0..p {
            assert_eq!(s.program(st).stash_high_water(), (p - st) as i64);
        }
    }

    #[test]
    fn few_microbatches_clip_warmup() {
        let s = one_f_one_b(8, 2);
        for st in 0..8 {
            assert_eq!(s.count(st, OpKind::Fwd), 2);
            assert!(s.program(st).stash_high_water() <= 2);
        }
        validate(&s).unwrap();
    }

    #[test]
    fn validates() {
        for (p, m) in [(1, 1), (2, 3), (4, 8), (8, 64), (16, 128)] {
            validate(&one_f_one_b(p, m)).unwrap();
        }
    }
}
