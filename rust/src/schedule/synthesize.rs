//! Schedule **synthesis**: search the schedule space under per-stage
//! memory caps instead of enumerating the five hand-written families
//! (ROADMAP open item 1; cf. *Pipeline Parallelism with Controllable
//! Memory* and *OptPipe*, which cast pipeline scheduling as a
//! memory-constrained optimization problem).
//!
//! [`synthesize`] takes per-stage **byte** caps (heterogeneous clusters
//! fall out for free — a tighter cap on one stage just shrinks that
//! stage's stash budget) and returns the best schedule it can prove
//! feasible:
//!
//! 1. **Caps → stash budgets.**  Each stage's byte cap is converted to a
//!    resident-stash count via the [`MemoryModel`]:
//!    `counts[s] = (cap[s] − weights/opt − reserved) / act_per_mb`.
//!    A stage that cannot hold even one stash is a hard
//!    [`SynthesisError::Infeasible`] — no schedule exists.
//! 2. **Seed.**  A warmup-depth vector `W` (the list-scheduling lower
//!    bound): stage `s` runs `W_s` forwards before its first backward,
//!    then strict 1F1B steady state.  `W_s = min(p−1−s, m, counts[s]−1,
//!    W_{s−1})` — clipped to the stash budget and kept nonincreasing
//!    down the pipe.  Nonincreasing pure-compute W-schedules are
//!    deadlock-free under the channel-capacity protocol model (verified
//!    exhaustively for small shapes and by the mirrored property suite
//!    in `tests/property_synthesis.rs`); *increasing* depth vectors can
//!    deadlock, which is why [`project`] re-imposes monotonicity after
//!    every move.
//! 3. **Local search.**  First-improvement hill climbing over `W`
//!    (±1 shifts per stage, projected back into the feasible cone),
//!    scored by the zero-alloc DES — one [`SimWorkspace`] reused across
//!    every candidate, `trace` off.  Every candidate is
//!    validator-clean *by construction* (projection keeps it inside the
//!    proven-deadlock-free cone), so the search loop never simulates an
//!    invalid schedule.
//! 4. **Family portfolio.**  The searched winner competes against the
//!    known families (1F1B, GPipe, and a uniformly rebalanced 1F1B at
//!    the largest bound the caps admit).  Portfolio candidates are
//!    pruned with [`static_bounds`] first — a stage whose *own*
//!    program-order high-water (`lo`, a sound lower bound on the DES
//!    peak) already exceeds its stash budget is provably OOM and is
//!    skipped without simulating — then DES-scored and kept only if the
//!    *dynamic* per-stage stash high-water (own + accepted transfers,
//!    in-flight evictions included) fits the budget.
//!
//! The returned schedule carries `kind:`[`ScheduleKind::Synthesized`]
//! and `stage_bounds: Some(counts)`, so the validator, the
//! `analysis::check_plan` gate and the linearity checker all enforce
//! the caps it was synthesized under.  `tests/property_synthesis.rs`
//! fuzzes this contract over ≥300 mirrored-seed shapes;
//! `tests/golden_engine.rs` pins the exp-8 tight-cap winner, and
//! `tests/estimator_differential.rs` brackets it against the paper's
//! Eq.3/Eq.4 estimator.

use std::fmt;

use super::{gpipe, one_f_one_b, validate, Op, Placement, Schedule, ScheduleKind, StageProgram};
use crate::analysis::bounds::static_bounds;
use crate::bpipe::{derived_bound, pair_adjacent_layout, rebalance, sequential_layout, Layout};
use crate::config::ExperimentConfig;
use crate::model::memory::MemoryModel;
use crate::sim::{CostModel, SimOptions, SimWorkspace};

/// Why no schedule could be synthesized under the requested caps.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SynthesisError {
    /// `per_stage_mem_caps.len()` does not match the pipeline depth.
    CapsLen { expected: u64, got: usize },
    /// The [`CostModel`]'s experiment is configured for a different
    /// pipeline depth — the weight/activation split would be wrong.
    DepthMismatch { requested: u64, experiment: u64 },
    /// Stage `stage` cannot hold even one activation stash: its cap is
    /// below weights+optimizer+reserved+one microbatch of activations.
    Infeasible { stage: u64, cap_bytes: u64, floor_bytes: u64 },
}

impl fmt::Display for SynthesisError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SynthesisError::CapsLen { expected, got } => {
                write!(f, "expected {expected} per-stage caps, got {got}")
            }
            SynthesisError::DepthMismatch { requested, experiment } => write!(
                f,
                "synthesize(p = {requested}) against a cost model configured for p = {experiment}"
            ),
            SynthesisError::Infeasible { stage, cap_bytes, floor_bytes } => write!(
                f,
                "stage {stage} cannot hold one activation stash: cap {cap_bytes} B < \
                 weights+opt+reserved+1 stash = {floor_bytes} B"
            ),
        }
    }
}

impl std::error::Error for SynthesisError {}

/// Convert per-stage **byte** caps into per-stage resident-stash
/// budgets: `counts[s] = (cap[s] − weight_opt(s) − reserved) / act`.
/// The DES charges exactly `weight_opt + reserved + stash·act` per
/// stage, so `stash ≤ counts[s]` is equivalent to staying under the
/// byte cap.  Errs if any stage cannot hold a single stash.
pub fn stash_count_caps(
    e: &ExperimentConfig,
    per_stage_mem_caps: &[u64],
) -> Result<Vec<u64>, SynthesisError> {
    let p = e.parallel.p;
    if per_stage_mem_caps.len() != p as usize {
        return Err(SynthesisError::CapsLen { expected: p, got: per_stage_mem_caps.len() });
    }
    let mm = MemoryModel::new(e);
    let act = mm.activation_bytes_per_microbatch(0);
    (0..p)
        .map(|s| {
            let fixed = mm.weight_opt_bytes(s) + e.cluster.reserved_bytes;
            let count = per_stage_mem_caps[s as usize].saturating_sub(fixed) / act;
            if count == 0 {
                Err(SynthesisError::Infeasible {
                    stage: s,
                    cap_bytes: per_stage_mem_caps[s as usize],
                    floor_bytes: fixed + act,
                })
            } else {
                Ok(count)
            }
        })
        .collect()
}

/// Build the warmup-depth schedule for depth vector `w`: stage `s` runs
/// `min(W_s, m)` forwards, then alternates Fwd/Bwd (1F1B steady state),
/// then drains the remaining backwards.  `w` nonincreasing with
/// `W_s ≤ p−1−s` generalizes both 1F1B (`W_s = p−1−s`) and GPipe-at-
/// no-memory (`W = 0`, fully serialized).  Stash high-water is
/// `min(W_s + 1, m)` — the `+1` is the in-flight steady-state stash.
fn w_schedule(p: u64, m: u64, w: &[u64]) -> Schedule {
    let programs = (0..p)
        .map(|s| {
            let warm = w[s as usize].min(m);
            let mut ops = Vec::with_capacity(2 * m as usize);
            for mb in 0..warm {
                ops.push(Op::fwd(mb));
            }
            for i in 0..m - warm {
                ops.push(Op::fwd(warm + i));
                ops.push(Op::bwd(i));
            }
            for mb in m - warm..m {
                ops.push(Op::bwd(mb));
            }
            StageProgram { stage: s, ops }
        })
        .collect();
    Schedule {
        p,
        m,
        chunks: 1,
        placement: Placement::Sequential,
        kind: ScheduleKind::Synthesized,
        stage_bounds: None,
        programs,
    }
}

/// Clip a depth vector into the feasible cone, left to right:
/// `W_s ← min(W_s, p−1−s, m, counts[s]−1, W_{s−1})`.  The `counts[s]−1`
/// term keeps the steady-state high-water (`W_s + 1`) within the stash
/// budget; the running minimum keeps the vector nonincreasing (the
/// deadlock-freedom precondition).
fn project(p: u64, m: u64, counts: &[u64], w: &[u64]) -> Vec<u64> {
    let mut out = Vec::with_capacity(p as usize);
    let mut prev = u64::MAX;
    for s in 0..p {
        let ws = w[s as usize].min(p - 1 - s).min(m).min(counts[s as usize] - 1).min(prev);
        out.push(ws);
        prev = ws;
    }
    out
}

/// The list-scheduling seed: the deepest feasible warmup per stage.
fn seed_w(p: u64, m: u64, counts: &[u64]) -> Vec<u64> {
    project(p, m, counts, &vec![u64::MAX; p as usize])
}

/// Family candidates the searched schedule must beat: plain 1F1B and
/// GPipe (free when the caps are loose), and 1F1B uniformly rebalanced
/// at the largest bound the caps admit.  The rebalance bound is
/// `min(counts) − 1` — the DES parks an evicted stash until its
/// *transfer* completes, so an evictor's dynamic high-water overshoots
/// the program-order bound by one — clipped to the pair-mean
/// [`derived_bound`] the transform is tested across.
fn portfolio(p: u64, m: u64, counts: &[u64]) -> Vec<Schedule> {
    let mut out = vec![one_f_one_b(p, m), gpipe(p, m)];
    if p >= 2 {
        let base = one_f_one_b(p, m);
        let k = counts.iter().copied().min().unwrap().saturating_sub(1).min(derived_bound(&base));
        if k >= 2 {
            out.push(rebalance(&base, Some(k)));
        }
    }
    out
}

fn score_layout(e: &ExperimentConfig, p: u64) -> Layout {
    if e.cluster.n_nodes >= 1 && p % e.cluster.n_nodes == 0 {
        pair_adjacent_layout(p, e.cluster.n_nodes)
    } else {
        sequential_layout(p, 1)
    }
}

/// Synthesize the best schedule for `p` stages × `m` microbatches that
/// provably fits `per_stage_mem_caps` (bytes per stage), scored by the
/// DES under `cost`'s experiment.  See the module docs for the search
/// structure.  The result always carries
/// `kind:`[`ScheduleKind::Synthesized`] and
/// `stage_bounds: Some(stash budgets)`, is validator-clean, and its DES
/// stash high-water respects the budgets on every stage.
pub fn try_synthesize(
    p: u64,
    m: u64,
    per_stage_mem_caps: &[u64],
    cost: &CostModel,
) -> Result<Schedule, SynthesisError> {
    assert!(p >= 1 && m >= 1, "need at least one stage and one microbatch");
    let e = cost.e;
    if e.parallel.p != p {
        return Err(SynthesisError::DepthMismatch { requested: p, experiment: e.parallel.p });
    }
    let counts = stash_count_caps(e, per_stage_mem_caps)?;
    let layout = score_layout(e, p);
    let mut ws = SimWorkspace::new();
    // warm-start scoring: hill-climb neighbors differ from the incumbent
    // in one stage's warmup depth, so the DES replays the shared event
    // prefix from the previous candidate's snapshot (bit-identical to a
    // cold run — see `sim::engine`'s warm-start docs)
    let score = |s: &Schedule, ws: &mut SimWorkspace| {
        ws.run(e, s, &layout, SimOptions { trace: false, warm: true, recompute: false }).makespan
    };

    // -- seed + first-improvement hill climb over warmup depths ----------
    let mut w = seed_w(p, m, &counts);
    let mut best = score(&w_schedule(p, m, &w), &mut ws);
    for _round in 0..64 {
        let mut improved = false;
        for s in 0..p as usize {
            for dlt in [-1i64, 1] {
                let mut moved = w.clone();
                moved[s] = (moved[s] as i64 + dlt).max(0) as u64;
                let cand = project(p, m, &counts, &moved);
                if cand == w {
                    continue;
                }
                let mk = score(&w_schedule(p, m, &cand), &mut ws);
                if mk < best {
                    best = mk;
                    w = cand;
                    improved = true;
                }
            }
        }
        if !improved {
            break;
        }
    }
    let mut winner = w_schedule(p, m, &w);

    // -- family portfolio: prune statically, then filter on the DES ------
    for cand in portfolio(p, m, &counts) {
        // a stage whose own program-order high-water already exceeds its
        // budget is provably OOM — skip without simulating
        if static_bounds(&cand).iter().any(|b| b.lo > counts[b.stage as usize] as i64) {
            continue;
        }
        let stats = ws.run(e, &cand, &layout, SimOptions { trace: false, warm: true, recompute: false });
        let fits = ws
            .stash_high_water()
            .iter()
            .zip(&counts)
            .all(|(&hw, &budget)| hw <= budget as i64);
        if fits && stats.makespan < best {
            best = stats.makespan;
            winner = cand;
        }
    }

    winner.kind = ScheduleKind::Synthesized;
    winner.stage_bounds = Some(counts);
    validate(&winner).expect("synthesized schedule failed validation");
    Ok(winner)
}

/// Panicking wrapper around [`try_synthesize`] (mirrors
/// `plan_schedule` vs `try_plan_schedule`).
pub fn synthesize(p: u64, m: u64, per_stage_mem_caps: &[u64], cost: &CostModel) -> Schedule {
    match try_synthesize(p, m, per_stage_mem_caps, cost) {
        Ok(s) => s,
        Err(e) => panic!("schedule synthesis failed: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_experiment;

    fn caps_for_counts(e: &ExperimentConfig, counts: &[u64]) -> Vec<u64> {
        let mm = MemoryModel::new(e);
        let act = mm.activation_bytes_per_microbatch(0);
        counts
            .iter()
            .enumerate()
            .map(|(s, &c)| mm.weight_opt_bytes(s as u64) + e.cluster.reserved_bytes + c * act)
            .collect()
    }

    #[test]
    fn seed_is_nonincreasing_and_within_budget() {
        let counts = vec![3, 5, 1, 4];
        let w = seed_w(4, 8, &counts);
        assert_eq!(w, vec![2, 2, 0, 0]); // clipped by p−1−s, counts−1, prev
        for s in 0..4 {
            assert!(w[s] + 1 <= counts[s]);
        }
        for pair in w.windows(2) {
            assert!(pair[0] >= pair[1]);
        }
    }

    #[test]
    fn project_restores_monotonicity() {
        // bumping a downstream stage above its upstream neighbor must be
        // clipped back (increasing warmup vectors can deadlock)
        let counts = vec![9, 9, 9];
        assert_eq!(project(3, 4, &counts, &[0, 2, 0]), vec![0, 0, 0]);
        assert_eq!(project(3, 4, &counts, &[2, 2, 9]), vec![2, 1, 0]);
    }

    #[test]
    fn w_schedule_matches_1f1b_at_full_depth() {
        let p = 4;
        let m = 8;
        let full: Vec<u64> = (0..p).map(|s| p - 1 - s).collect();
        let ours = w_schedule(p, m, &full);
        let reference = one_f_one_b(p, m);
        assert_eq!(ours.programs, reference.programs);
    }

    #[test]
    fn rejects_wrong_caps_len() {
        let e = paper_experiment(8).unwrap();
        let cm = CostModel::new(&e);
        let err = try_synthesize(8, 16, &[e.cluster.hbm_bytes; 3], &cm).unwrap_err();
        assert!(matches!(err, SynthesisError::CapsLen { expected: 8, got: 3 }));
    }

    #[test]
    fn rejects_depth_mismatch() {
        let e = paper_experiment(8).unwrap();
        let cm = CostModel::new(&e);
        let err = try_synthesize(4, 16, &[e.cluster.hbm_bytes; 4], &cm).unwrap_err();
        assert!(matches!(err, SynthesisError::DepthMismatch { requested: 4, experiment: 8 }));
    }

    #[test]
    fn rejects_caps_below_one_stash() {
        let e = paper_experiment(8).unwrap();
        let cm = CostModel::new(&e);
        // stage 0 holds ~52 GiB of weights+opt alone; a 1 GiB cap is hopeless
        let mut caps = vec![e.cluster.hbm_bytes; 8];
        caps[0] = 1 << 30;
        let err = try_synthesize(8, 16, &caps, &cm).unwrap_err();
        assert!(matches!(err, SynthesisError::Infeasible { stage: 0, .. }));
    }

    #[test]
    fn winner_is_stamped_and_cap_clean() {
        let e = paper_experiment(8).unwrap();
        let counts = vec![3, 3, 2, 2, 2, 2, 2, 2];
        let caps = caps_for_counts(&e, &counts);
        let cm = CostModel::new(&e);
        let s = synthesize(8, 16, &caps, &cm);
        assert_eq!(s.kind, ScheduleKind::Synthesized);
        assert_eq!(s.stage_bounds.as_deref(), Some(&counts[..]));
        validate(&s).unwrap();
        // the DES's dynamic stash high-water also fits (not just the
        // program-order one the validator sees)
        let mut ws = SimWorkspace::new();
        ws.run(&e, &s, &score_layout(&e, 8), SimOptions { trace: false, warm: false, recompute: false });
        for (hw, &c) in ws.stash_high_water().iter().zip(&counts) {
            assert!(*hw <= c as i64, "{:?} vs {counts:?}", ws.stash_high_water());
        }
    }

    #[test]
    fn loose_caps_recover_family_throughput() {
        // with the whole HBM available the portfolio must not lose to a
        // starved warmup schedule: the winner's makespan is within the
        // best family cell's (rebalanced 1F1B simulates fine here)
        let e = paper_experiment(8).unwrap();
        let m = e.parallel.num_microbatches();
        let cm = CostModel::new(&e);
        let s = synthesize(8, m, &vec![e.cluster.hbm_bytes; 8], &cm);
        let layout = score_layout(&e, 8);
        let mut ws = SimWorkspace::new();
        let ours = ws.run(&e, &s, &layout, SimOptions { trace: false, warm: false, recompute: false }).makespan;
        let rb = rebalance(&one_f_one_b(8, m), None);
        let fam = ws.run(&e, &rb, &layout, SimOptions { trace: false, warm: false, recompute: false }).makespan;
        assert!(
            ours <= fam * 1.0000001,
            "synthesized {ours} should not lose to rebalanced 1F1B {fam}"
        );
    }
}
