//! V-shaped two-chunk virtual pipeline — the controllable-memory family
//! (Qi et al. 2024, "Pipeline Parallelism with Controllable Memory").
//!
//! Each physical stage hosts two model chunks placed in a **V**: chunk 0
//! occupies virtual stages `0..p` front-to-back, chunk 1 occupies virtual
//! stages `p..2p` back-to-front, so physical stage `s` runs virtual
//! stages `s` and `2p−1−s`.  Stage `p−1` finishes a microbatch's chunk-0
//! forward and immediately starts its chunk-1 forward; stage 0 holds both
//! the longest-lived chunk-0 stash and the shortest-lived chunk-1 stash.
//! The two lifetimes sum to ~constant across stages, so stash pressure is
//! **balanced by placement** — the same goal BPipe reaches with
//! transfers, making this the natural third scenario for the rebalancing
//! sweep (plain 1F1B is imbalanced, interleaved is anti-balanced,
//! V-shaped is balanced by construction).
//!
//! Since PR 3 this is the `v = 2` case of the general zig-zag placement:
//! [`v_shaped()`] is a thin wrapper over [`super::zigzag()`] that keeps the
//! `ScheduleKind::VShaped` tag (op-for-op identical programs).  See
//! [`super::zigzag()`] for the construction.

use super::{Schedule, ScheduleKind};

/// Generate the V-shaped schedule for `p` stages and `m` microbatches
/// (two chunks per stage) — `zigzag(p, m, 2)` with the V-shaped kind tag.
pub fn v_shaped(p: u64, m: u64) -> Schedule {
    let mut s = super::zigzag(p, m, 2);
    s.kind = ScheduleKind::VShaped;
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{interleaved, one_f_one_b, validate, OpKind};

    #[test]
    fn validates_across_shapes() {
        for (p, m) in [(1u64, 1u64), (2, 2), (2, 4), (4, 4), (4, 8), (4, 16), (8, 16), (8, 64)] {
            let s = v_shaped(p, m);
            validate(&s).unwrap_or_else(|e| panic!("p={p} m={m}: {e}"));
            for st in 0..p {
                assert_eq!(s.count(st, OpKind::Fwd) as u64, 2 * m, "p={p} m={m} stage {st}");
                assert_eq!(s.count(st, OpKind::Bwd) as u64, 2 * m, "p={p} m={m} stage {st}");
            }
        }
    }

    #[test]
    fn stash_pressure_balanced_at_scale() {
        // the V placement's point: stash high-water is (near-)uniform
        // across stages, unlike interleaved's front-loaded ramp
        let s = v_shaped(8, 64);
        let hws: Vec<i64> = (0..8).map(|st| s.program(st).stash_high_water()).collect();
        let spread = hws.iter().max().unwrap() - hws.iter().min().unwrap();
        assert!(spread <= 1, "V-shaped spread {spread} too large: {hws:?}");

        let il = interleaved(8, 64, 2);
        let il_hws: Vec<i64> = (0..8).map(|st| il.program(st).stash_high_water()).collect();
        let il_spread = il_hws.iter().max().unwrap() - il_hws.iter().min().unwrap();
        assert!(spread < il_spread, "V {hws:?} vs interleaved {il_hws:?}");
    }

    #[test]
    fn total_stash_comparable_to_plain_same_work() {
        // both chunks' stashes live on-stage; total pressure is higher
        // than plain 1F1B (two chunks) but bounded by ~2p+1
        let p = 8u64;
        let s = v_shaped(p, 64);
        for st in 0..p {
            let hw = s.program(st).stash_high_water();
            assert!(hw <= 2 * p as i64 + 1, "stage {st}: {hw}");
            assert!(hw > one_f_one_b(p, 64).program(st).stash_high_water() / 2);
        }
    }

    #[test]
    fn last_stage_runs_chunks_back_to_back() {
        // stage p−1 hosts virtual stages p−1 and p: a microbatch's chunk-1
        // fwd directly follows its chunk-0 fwd there
        let s = v_shaped(4, 8);
        let ops = &s.program(3).ops;
        let f0 = ops.iter().position(|o| o.kind == OpKind::Fwd && o.mb == 0 && o.chunk == 0).unwrap();
        let f1 = ops.iter().position(|o| o.kind == OpKind::Fwd && o.mb == 0 && o.chunk == 1).unwrap();
        assert!(f1 > f0);
        assert!(f1 - f0 <= 2, "chunk-1 fwd should closely follow chunk-0: {f0} vs {f1}");
    }

    #[test]
    fn keeps_v_shaped_kind_tag() {
        let s = v_shaped(4, 8);
        assert_eq!(s.kind, ScheduleKind::VShaped);
        assert_eq!(s.chunks, 2);
        assert_eq!(s.placement, crate::schedule::Placement::ZigZag);
    }
}
