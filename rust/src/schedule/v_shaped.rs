//! V-shaped two-chunk virtual pipeline — the controllable-memory family
//! (Qi et al. 2024, "Pipeline Parallelism with Controllable Memory").
//!
//! Each physical stage hosts two model chunks placed in a **V**: chunk 0
//! occupies virtual stages `0..p` front-to-back, chunk 1 occupies virtual
//! stages `p..2p` back-to-front, so physical stage `s` runs virtual
//! stages `s` and `2p−1−s`.  Stage `p−1` finishes a microbatch's chunk-0
//! forward and immediately starts its chunk-1 forward; stage 0 holds both
//! the longest-lived chunk-0 stash and the shortest-lived chunk-1 stash.
//! The two lifetimes sum to ~constant across stages, so stash pressure is
//! **balanced by placement** — the same goal BPipe reaches with
//! transfers, making this the natural third scenario for the rebalancing
//! sweep (plain 1F1B is imbalanced, interleaved is anti-balanced,
//! V-shaped is balanced by construction).
//!
//! Construction: take the 1F1B schedule of the `2p`-deep *virtual*
//! pipeline, assign each virtual op its completion slot under unit-time
//! list scheduling (Kahn order over the virtual dependency DAG), and
//! fold the two virtual programs of each physical stage into one op
//! stream ordered by those slots.  The result validates under the
//! standard per-stage invariants and carries `Placement::VShape` so the
//! simulator derives chunk-1 dataflow in the reverse stage direction.

use super::{Op, OpKind, Placement, Schedule, ScheduleKind, StageProgram};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Generate the V-shaped schedule for `p` stages and `m` microbatches
/// (two chunks per stage).
pub fn v_shaped(p: u64, m: u64) -> Schedule {
    assert!(p >= 1, "need at least one stage");
    assert!(m >= 1, "need at least one microbatch");
    let vp = (2 * p) as usize;
    let virt = super::one_f_one_b(2 * p, m);

    // node ids over the virtual schedule, in (virtual stage, op index) order
    let mut base = vec![0usize; vp + 1];
    for d in 0..vp {
        base[d + 1] = base[d] + virt.programs[d].ops.len();
    }
    let n = base[vp];
    // dense (virtual stage, kind, mb) -> op index table: one O(ops)
    // build instead of a linear scan per dependency lookup
    let m_us = m as usize;
    let mut pos_tab = vec![usize::MAX; vp * 2 * m_us];
    for d in 0..vp {
        for (j, op) in virt.programs[d].ops.iter().enumerate() {
            let k = if op.kind == OpKind::Fwd { 0 } else { 1 };
            pos_tab[(d * 2 + k) * m_us + op.mb as usize] = j;
        }
    }
    let pos = |d: usize, kind: OpKind, mb: u64| -> usize {
        let k = if kind == OpKind::Fwd { 0 } else { 1 };
        pos_tab[(d * 2 + k) * m_us + mb as usize]
    };

    // dependency edges of the virtual 1F1B DAG (unit-time ops)
    let mut deps: Vec<Vec<usize>> = vec![Vec::with_capacity(3); n];
    for d in 0..vp {
        for (j, op) in virt.programs[d].ops.iter().enumerate() {
            let id = base[d] + j;
            if j > 0 {
                deps[id].push(base[d] + j - 1);
            }
            match op.kind {
                OpKind::Fwd => {
                    if d > 0 {
                        deps[id].push(base[d - 1] + pos(d - 1, OpKind::Fwd, op.mb));
                    }
                }
                OpKind::Bwd => {
                    deps[id].push(base[d] + pos(d, OpKind::Fwd, op.mb));
                    if d + 1 < vp {
                        deps[id].push(base[d + 1] + pos(d + 1, OpKind::Bwd, op.mb));
                    }
                }
                OpKind::Evict | OpKind::Load => unreachable!("1f1b base has no transfers"),
            }
        }
    }

    // unit-time list schedule: finish slot of each virtual op
    let mut indeg = vec![0usize; n];
    let mut rev: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (id, ds) in deps.iter().enumerate() {
        indeg[id] = ds.len();
        for &d in ds {
            rev[d].push(id);
        }
    }
    let mut finish = vec![0u64; n];
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = (0..n)
        .filter(|&i| indeg[i] == 0)
        .map(|i| Reverse((0, i)))
        .collect();
    let mut done = 0usize;
    while let Some(Reverse((t, id))) = heap.pop() {
        done += 1;
        finish[id] = t + 1;
        for &nxt in &rev[id] {
            indeg[nxt] -= 1;
            if indeg[nxt] == 0 {
                let r = deps[nxt].iter().map(|&d| finish[d]).max().unwrap_or(0);
                heap.push(Reverse((r, nxt)));
            }
        }
    }
    assert_eq!(done, n, "virtual 1f1b DAG must be acyclic");

    // fold: physical stage s hosts virtual stages s (chunk 0) and
    // 2p-1-s (chunk 1), merged in finish-slot order
    let programs = (0..p as usize)
        .map(|s| {
            let mut items: Vec<(u64, usize, usize, Op)> = Vec::new();
            for (chunk, d) in [(0u64, s), (1u64, vp - 1 - s)] {
                for (j, op) in virt.programs[d].ops.iter().enumerate() {
                    items.push((finish[base[d] + j], d, j, Op { kind: op.kind, mb: op.mb, chunk }));
                }
            }
            items.sort_by_key(|&(f, d, j, _)| (f, d, j));
            StageProgram { stage: s as u64, ops: items.into_iter().map(|it| it.3).collect() }
        })
        .collect();

    Schedule {
        p,
        m,
        chunks: 2,
        placement: Placement::VShape,
        kind: ScheduleKind::VShaped,
        programs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{interleaved, one_f_one_b, validate};

    #[test]
    fn validates_across_shapes() {
        for (p, m) in [(1u64, 1u64), (2, 2), (2, 4), (4, 4), (4, 8), (4, 16), (8, 16), (8, 64)] {
            let s = v_shaped(p, m);
            validate(&s).unwrap_or_else(|e| panic!("p={p} m={m}: {e}"));
            for st in 0..p {
                assert_eq!(s.count(st, OpKind::Fwd) as u64, 2 * m, "p={p} m={m} stage {st}");
                assert_eq!(s.count(st, OpKind::Bwd) as u64, 2 * m, "p={p} m={m} stage {st}");
            }
        }
    }

    #[test]
    fn stash_pressure_balanced_at_scale() {
        // the V placement's point: stash high-water is (near-)uniform
        // across stages, unlike interleaved's front-loaded ramp
        let s = v_shaped(8, 64);
        let hws: Vec<i64> = (0..8).map(|st| s.program(st).stash_high_water()).collect();
        let spread = hws.iter().max().unwrap() - hws.iter().min().unwrap();
        assert!(spread <= 1, "V-shaped spread {spread} too large: {hws:?}");

        let il = interleaved(8, 64, 2);
        let il_hws: Vec<i64> = (0..8).map(|st| il.program(st).stash_high_water()).collect();
        let il_spread = il_hws.iter().max().unwrap() - il_hws.iter().min().unwrap();
        assert!(spread < il_spread, "V {hws:?} vs interleaved {il_hws:?}");
    }

    #[test]
    fn total_stash_comparable_to_plain_same_work() {
        // both chunks' stashes live on-stage; total pressure is higher
        // than plain 1F1B (two chunks) but bounded by ~2p+1
        let p = 8u64;
        let s = v_shaped(p, 64);
        for st in 0..p {
            let hw = s.program(st).stash_high_water();
            assert!(hw <= 2 * p as i64 + 1, "stage {st}: {hw}");
            assert!(hw > one_f_one_b(p, 64).program(st).stash_high_water() / 2);
        }
    }

    #[test]
    fn last_stage_runs_chunks_back_to_back() {
        // stage p−1 hosts virtual stages p−1 and p: a microbatch's chunk-1
        // fwd directly follows its chunk-0 fwd there
        let s = v_shaped(4, 8);
        let ops = &s.program(3).ops;
        let f0 = ops.iter().position(|o| o.kind == OpKind::Fwd && o.mb == 0 && o.chunk == 0).unwrap();
        let f1 = ops.iter().position(|o| o.kind == OpKind::Fwd && o.mb == 0 && o.chunk == 1).unwrap();
        assert!(f1 > f0);
        assert!(f1 - f0 <= 2, "chunk-1 fwd should closely follow chunk-0: {f0} vs {f1}");
    }
}
