//! Schedule invariant checking — the contract every generator (and the
//! rebalance transform) must uphold, enforced in unit tests, proptests
//! and defensively by the simulator/coordinator before executing a
//! schedule.
//!
//! All stash-residency invariants are tracked per `(mb, chunk)` key, so
//! rebalanced interleaved / V-shaped schedules are validated as strictly
//! as plain 1F1B ones.  A key may cycle Evict→Load more than once (the
//! generalized transform prefetches and may re-evict under pressure);
//! the state machine below permits that while still rejecting every
//! out-of-order combination.

use super::{OpKind, Schedule, ScheduleKind};
use std::collections::{HashMap, HashSet};
use std::fmt;

/// Why a schedule is malformed.
#[derive(Debug, Clone, PartialEq)]
pub enum ValidationError {
    WrongStageCount { expected: u64, got: usize },
    StageIdMismatch { index: usize, stage: u64 },
    DuplicateOp { stage: u64, kind: OpKind, mb: u64, chunk: u64 },
    MissingBwd { stage: u64, mb: u64, chunk: u64 },
    MissingFwd { stage: u64, mb: u64, chunk: u64 },
    BwdBeforeFwd { stage: u64, mb: u64, chunk: u64 },
    EvictWithoutFwd { stage: u64, mb: u64, chunk: u64 },
    LoadWithoutEvict { stage: u64, mb: u64, chunk: u64 },
    EvictNotReloaded { stage: u64, mb: u64, chunk: u64 },
    BwdWhileEvicted { stage: u64, mb: u64, chunk: u64 },
    NegativeStash { stage: u64, at_op: usize },
    BoundExceeded { stage: u64, bound: u64, high_water: i64 },
    /// A per-stage (non-uniform) bound was exceeded on its own stage.
    StageBoundExceeded { stage: u64, bound: u64, high_water: i64 },
    /// `stage_bounds` is set but its length is not `p`.
    StageBoundsWrongLength { expected: u64, got: usize },
    UnknownMicrobatch { stage: u64, mb: u64, m: u64 },
    UnknownChunk { stage: u64, chunk: u64, chunks: u64 },
}

impl fmt::Display for ValidationError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for ValidationError {}

/// Validate a schedule against the structural invariants:
///
/// 1. one program per stage, ids in order;
/// 2. every (mb, chunk) has exactly one Fwd and one Bwd per stage, with
///    Bwd after Fwd, and mb < m, chunk < chunks;
/// 3. per (mb, chunk): Evict only while the stash is resident, Load only
///    while it is evicted (possibly repeatedly), Bwd only while resident,
///    and nothing stays evicted at the end;
/// 4. the on-device stash count never goes negative, and for
///    `ScheduleKind::BPipe { bound }` never exceeds `bound` — nor, when
///    `stage_bounds` is set (non-uniform rebalance), the stage's own
///    per-stage bound.
pub fn validate(s: &Schedule) -> Result<(), ValidationError> {
    if s.programs.len() != s.p as usize {
        return Err(ValidationError::WrongStageCount { expected: s.p, got: s.programs.len() });
    }
    if let Some(bounds) = &s.stage_bounds {
        if bounds.len() != s.p as usize {
            return Err(ValidationError::StageBoundsWrongLength {
                expected: s.p,
                got: bounds.len(),
            });
        }
    }
    for (i, prog) in s.programs.iter().enumerate() {
        if prog.stage != i as u64 {
            return Err(ValidationError::StageIdMismatch { index: i, stage: prog.stage });
        }
        let st = prog.stage;
        let mut fwd_seen: HashSet<(u64, u64)> = HashSet::new();
        let mut bwd_seen: HashSet<(u64, u64)> = HashSet::new();
        // stash residency: None = not forwarded, Some(true) = resident,
        // Some(false) = evicted
        let mut resident: HashMap<(u64, u64), bool> = HashMap::new();
        let mut stash = 0i64;
        let mut high_water = 0i64;
        for (at, op) in prog.ops.iter().enumerate() {
            if op.mb >= s.m {
                return Err(ValidationError::UnknownMicrobatch { stage: st, mb: op.mb, m: s.m });
            }
            if op.chunk >= s.chunks {
                return Err(ValidationError::UnknownChunk {
                    stage: st, chunk: op.chunk, chunks: s.chunks,
                });
            }
            let key = (op.mb, op.chunk);
            match op.kind {
                OpKind::Fwd => {
                    if !fwd_seen.insert(key) {
                        return Err(ValidationError::DuplicateOp {
                            stage: st, kind: OpKind::Fwd, mb: op.mb, chunk: op.chunk,
                        });
                    }
                    resident.insert(key, true);
                    stash += 1;
                }
                OpKind::Bwd => {
                    if !fwd_seen.contains(&key) {
                        return Err(ValidationError::BwdBeforeFwd {
                            stage: st, mb: op.mb, chunk: op.chunk,
                        });
                    }
                    if !bwd_seen.insert(key) {
                        return Err(ValidationError::DuplicateOp {
                            stage: st, kind: OpKind::Bwd, mb: op.mb, chunk: op.chunk,
                        });
                    }
                    match resident.get(&key) {
                        Some(true) => {}
                        _ => {
                            return Err(ValidationError::BwdWhileEvicted {
                                stage: st, mb: op.mb, chunk: op.chunk,
                            })
                        }
                    }
                    resident.insert(key, false);
                    stash -= 1;
                }
                OpKind::Evict => {
                    if bwd_seen.contains(&key) || resident.get(&key) != Some(&true) {
                        return Err(ValidationError::EvictWithoutFwd {
                            stage: st, mb: op.mb, chunk: op.chunk,
                        });
                    }
                    resident.insert(key, false);
                    stash -= 1;
                }
                OpKind::Load => {
                    if bwd_seen.contains(&key) || resident.get(&key) != Some(&false) {
                        return Err(ValidationError::LoadWithoutEvict {
                            stage: st, mb: op.mb, chunk: op.chunk,
                        });
                    }
                    resident.insert(key, true);
                    stash += 1;
                }
            }
            if stash < 0 {
                return Err(ValidationError::NegativeStash { stage: st, at_op: at });
            }
            high_water = high_water.max(stash);
        }
        // completeness: every fwd got a bwd …
        for key in &fwd_seen {
            if !bwd_seen.contains(key) {
                return Err(ValidationError::MissingBwd { stage: st, mb: key.0, chunk: key.1 });
            }
        }
        // … and vice versa (implied, but keep symmetric reporting)
        for key in &bwd_seen {
            if !fwd_seen.contains(key) {
                return Err(ValidationError::MissingFwd { stage: st, mb: key.0, chunk: key.1 });
            }
        }
        // per-key evict/load symmetry: every evicted stash must have come
        // back before its backward, so per key the counts match and the
        // stage-total Evict/Load counts match too
        let evicts = prog.ops.iter().filter(|o| o.kind == OpKind::Evict).count();
        let loads = prog.ops.iter().filter(|o| o.kind == OpKind::Load).count();
        if evicts != loads {
            let key = prog
                .ops
                .iter()
                .find(|o| o.kind == OpKind::Evict)
                .map(|o| (o.mb, o.chunk))
                .unwrap_or((0, 0));
            return Err(ValidationError::EvictNotReloaded { stage: st, mb: key.0, chunk: key.1 });
        }
        if let ScheduleKind::BPipe { bound } = s.kind {
            if high_water > bound as i64 {
                return Err(ValidationError::BoundExceeded { stage: st, bound, high_water });
            }
        }
        // per-stage bounds are enforced whenever present, regardless of
        // the kind tag (the field doc's contract)
        if let Some(bounds) = &s.stage_bounds {
            let k = bounds[i];
            if high_water > k as i64 {
                return Err(ValidationError::StageBoundExceeded {
                    stage: st,
                    bound: k,
                    high_water,
                });
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{Op, OpKind, Placement, Schedule, ScheduleKind, StageProgram};

    fn sched(ops: Vec<Op>) -> Schedule {
        Schedule {
            p: 1,
            m: 8,
            chunks: 1,
            placement: Placement::Sequential,
            kind: ScheduleKind::OneFOneB,
            stage_bounds: None,
            programs: vec![StageProgram { stage: 0, ops }],
        }
    }

    #[test]
    fn rejects_bwd_before_fwd() {
        let s = sched(vec![Op::bwd(0), Op::fwd(0)]);
        assert!(matches!(validate(&s), Err(ValidationError::BwdBeforeFwd { .. })));
    }

    #[test]
    fn rejects_missing_bwd() {
        let s = sched(vec![Op::fwd(0)]);
        assert!(matches!(validate(&s), Err(ValidationError::MissingBwd { .. })));
    }

    #[test]
    fn rejects_bwd_while_evicted() {
        let s = sched(vec![Op::fwd(0), Op::evict(0), Op::bwd(0)]);
        assert!(matches!(validate(&s), Err(ValidationError::BwdWhileEvicted { .. })));
    }

    #[test]
    fn rejects_load_without_evict() {
        let s = sched(vec![Op::fwd(0), Op::load(0), Op::bwd(0)]);
        assert!(matches!(validate(&s), Err(ValidationError::LoadWithoutEvict { .. })));
    }

    #[test]
    fn rejects_double_fwd() {
        let s = sched(vec![Op::fwd(0), Op::fwd(0), Op::bwd(0)]);
        assert!(matches!(validate(&s), Err(ValidationError::DuplicateOp { .. })));
    }

    #[test]
    fn rejects_unknown_microbatch() {
        let s = sched(vec![Op::fwd(99), Op::bwd(99)]);
        assert!(matches!(validate(&s), Err(ValidationError::UnknownMicrobatch { .. })));
    }

    #[test]
    fn rejects_unknown_chunk() {
        let s = sched(vec![
            Op { kind: OpKind::Fwd, mb: 0, chunk: 1 },
            Op { kind: OpKind::Bwd, mb: 0, chunk: 1 },
        ]);
        assert!(matches!(validate(&s), Err(ValidationError::UnknownChunk { .. })));
    }

    #[test]
    fn rejects_evict_after_bwd() {
        let s = sched(vec![Op::fwd(0), Op::bwd(0), Op::evict(0), Op::load(0)]);
        assert!(matches!(validate(&s), Err(ValidationError::EvictWithoutFwd { .. })));
    }

    #[test]
    fn accepts_evict_load_cycle() {
        let s = sched(vec![Op::fwd(0), Op::evict(0), Op::load(0), Op::bwd(0)]);
        validate(&s).unwrap();
    }

    #[test]
    fn accepts_repeated_evict_load_cycles() {
        // the generalized transform may prefetch a stash back and re-evict
        // it under pressure — two full cycles on one key are legal
        let s = sched(vec![
            Op::fwd(0),
            Op::evict(0),
            Op::load(0),
            Op::evict(0),
            Op::load(0),
            Op::bwd(0),
        ]);
        validate(&s).unwrap();
    }

    #[test]
    fn chunk_keys_are_independent() {
        // evicting (mb 0, chunk 0) must not satisfy a load of (mb 0, chunk 1)
        let mut s = sched(vec![
            Op { kind: OpKind::Fwd, mb: 0, chunk: 0 },
            Op { kind: OpKind::Fwd, mb: 0, chunk: 1 },
            Op { kind: OpKind::Evict, mb: 0, chunk: 0 },
            Op { kind: OpKind::Load, mb: 0, chunk: 1 },
            Op { kind: OpKind::Bwd, mb: 0, chunk: 1 },
            Op { kind: OpKind::Bwd, mb: 0, chunk: 0 },
        ]);
        s.chunks = 2;
        assert!(matches!(
            validate(&s),
            Err(ValidationError::LoadWithoutEvict { stage: 0, mb: 0, chunk: 1 })
        ));
    }

    #[test]
    fn enforces_bpipe_bound() {
        let mut s = sched(vec![
            Op::fwd(0),
            Op::fwd(1),
            Op::fwd(2),
            Op::bwd(0),
            Op::bwd(1),
            Op::bwd(2),
        ]);
        s.kind = ScheduleKind::BPipe { bound: 2 };
        assert!(matches!(validate(&s), Err(ValidationError::BoundExceeded { .. })));
    }

    #[test]
    fn enforces_per_stage_bounds() {
        // high-water 3 passes the uniform bound (4) but violates the
        // stage's own non-uniform bound (2)
        let mut s = sched(vec![
            Op::fwd(0),
            Op::fwd(1),
            Op::fwd(2),
            Op::bwd(0),
            Op::bwd(1),
            Op::bwd(2),
        ]);
        s.kind = ScheduleKind::BPipe { bound: 4 };
        validate(&s).unwrap();
        s.stage_bounds = Some(vec![2]);
        assert!(matches!(
            validate(&s),
            Err(ValidationError::StageBoundExceeded { stage: 0, bound: 2, high_water: 3 })
        ));
        s.stage_bounds = Some(vec![3]);
        validate(&s).unwrap();
        // enforced whenever present, regardless of the kind tag
        s.kind = ScheduleKind::OneFOneB;
        s.stage_bounds = Some(vec![2]);
        assert!(matches!(validate(&s), Err(ValidationError::StageBoundExceeded { .. })));
    }

    #[test]
    fn rejects_wrong_length_stage_bounds() {
        let mut s = sched(vec![Op::fwd(0), Op::bwd(0)]);
        s.kind = ScheduleKind::BPipe { bound: 2 };
        s.stage_bounds = Some(vec![2, 2]);
        assert!(matches!(
            validate(&s),
            Err(ValidationError::StageBoundsWrongLength { expected: 1, got: 2 })
        ));
    }
}
