//! Schedule invariant checking — the contract every generator (and the
//! BPipe transform) must uphold, enforced in unit tests, proptests and
//! defensively by the simulator/coordinator before executing a schedule.

use super::{OpKind, Schedule, ScheduleKind};
use std::collections::{HashMap, HashSet};
use std::fmt;

/// Why a schedule is malformed.
#[derive(Debug, Clone, PartialEq)]
pub enum ValidationError {
    WrongStageCount { expected: u64, got: usize },
    StageIdMismatch { index: usize, stage: u64 },
    DuplicateOp { stage: u64, kind: OpKind, mb: u64, chunk: u64 },
    MissingBwd { stage: u64, mb: u64, chunk: u64 },
    MissingFwd { stage: u64, mb: u64, chunk: u64 },
    BwdBeforeFwd { stage: u64, mb: u64, chunk: u64 },
    EvictWithoutFwd { stage: u64, mb: u64 },
    LoadWithoutEvict { stage: u64, mb: u64 },
    EvictNotReloaded { stage: u64, mb: u64 },
    BwdWhileEvicted { stage: u64, mb: u64 },
    NegativeStash { stage: u64, at_op: usize },
    BoundExceeded { stage: u64, bound: u64, high_water: i64 },
    UnknownMicrobatch { stage: u64, mb: u64, m: u64 },
}

impl fmt::Display for ValidationError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for ValidationError {}

/// Validate a schedule against the structural invariants:
///
/// 1. one program per stage, ids in order;
/// 2. every (mb, chunk) has exactly one Fwd and one Bwd per stage, with
///    Bwd after Fwd, and mb < m;
/// 3. Evict only after the mb's Fwd, Load only after its Evict, Bwd only
///    while the stash is resident (Load-ed back if evicted);
/// 4. the on-device stash count never goes negative, and for
///    `ScheduleKind::BPipe { bound }` never exceeds `bound`.
pub fn validate(s: &Schedule) -> Result<(), ValidationError> {
    if s.programs.len() != s.p as usize {
        return Err(ValidationError::WrongStageCount { expected: s.p, got: s.programs.len() });
    }
    for (i, prog) in s.programs.iter().enumerate() {
        if prog.stage != i as u64 {
            return Err(ValidationError::StageIdMismatch { index: i, stage: prog.stage });
        }
        let st = prog.stage;
        let mut fwd_seen: HashSet<(u64, u64)> = HashSet::new();
        let mut bwd_seen: HashSet<(u64, u64)> = HashSet::new();
        // stash residency: None = not forwarded, Some(true) = resident,
        // Some(false) = evicted
        let mut resident: HashMap<(u64, u64), bool> = HashMap::new();
        let mut stash = 0i64;
        let mut high_water = 0i64;
        for (at, op) in prog.ops.iter().enumerate() {
            if op.mb >= s.m {
                return Err(ValidationError::UnknownMicrobatch { stage: st, mb: op.mb, m: s.m });
            }
            let key = (op.mb, op.chunk);
            match op.kind {
                OpKind::Fwd => {
                    if !fwd_seen.insert(key) {
                        return Err(ValidationError::DuplicateOp {
                            stage: st, kind: OpKind::Fwd, mb: op.mb, chunk: op.chunk,
                        });
                    }
                    resident.insert(key, true);
                    stash += 1;
                }
                OpKind::Bwd => {
                    if !fwd_seen.contains(&key) {
                        return Err(ValidationError::BwdBeforeFwd {
                            stage: st, mb: op.mb, chunk: op.chunk,
                        });
                    }
                    if !bwd_seen.insert(key) {
                        return Err(ValidationError::DuplicateOp {
                            stage: st, kind: OpKind::Bwd, mb: op.mb, chunk: op.chunk,
                        });
                    }
                    match resident.get(&key) {
                        Some(true) => {}
                        _ => return Err(ValidationError::BwdWhileEvicted { stage: st, mb: op.mb }),
                    }
                    resident.insert(key, false);
                    stash -= 1;
                }
                OpKind::Evict => {
                    if resident.get(&key) != Some(&true) {
                        return Err(ValidationError::EvictWithoutFwd { stage: st, mb: op.mb });
                    }
                    resident.insert(key, false);
                    stash -= 1;
                }
                OpKind::Load => {
                    if resident.get(&key) != Some(&false) || bwd_seen.contains(&key) {
                        return Err(ValidationError::LoadWithoutEvict { stage: st, mb: op.mb });
                    }
                    resident.insert(key, true);
                    stash += 1;
                }
            }
            if stash < 0 {
                return Err(ValidationError::NegativeStash { stage: st, at_op: at });
            }
            high_water = high_water.max(stash);
        }
        // completeness: every fwd got a bwd …
        for key in &fwd_seen {
            if !bwd_seen.contains(key) {
                return Err(ValidationError::MissingBwd { stage: st, mb: key.0, chunk: key.1 });
            }
        }
        // … and vice versa (implied, but keep symmetric reporting)
        for key in &bwd_seen {
            if !fwd_seen.contains(key) {
                return Err(ValidationError::MissingFwd { stage: st, mb: key.0, chunk: key.1 });
            }
        }
        // every evicted stash must have been loaded back (Bwd-while-
        // evicted already guards correctness; this guards op symmetry)
        let evicts = prog.ops.iter().filter(|o| o.kind == OpKind::Evict).count();
        let loads = prog.ops.iter().filter(|o| o.kind == OpKind::Load).count();
        if evicts != loads {
            let mb = prog.ops.iter().find(|o| o.kind == OpKind::Evict).map(|o| o.mb).unwrap_or(0);
            return Err(ValidationError::EvictNotReloaded { stage: st, mb });
        }
        if let ScheduleKind::BPipe { bound } = s.kind {
            if high_water > bound as i64 {
                return Err(ValidationError::BoundExceeded { stage: st, bound, high_water });
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{Op, Schedule, ScheduleKind, StageProgram};

    fn sched(ops: Vec<Op>) -> Schedule {
        Schedule {
            p: 1,
            m: 8,
            kind: ScheduleKind::OneFOneB,
            programs: vec![StageProgram { stage: 0, ops }],
        }
    }

    #[test]
    fn rejects_bwd_before_fwd() {
        let s = sched(vec![Op::bwd(0), Op::fwd(0)]);
        assert!(matches!(validate(&s), Err(ValidationError::BwdBeforeFwd { .. })));
    }

    #[test]
    fn rejects_missing_bwd() {
        let s = sched(vec![Op::fwd(0)]);
        assert!(matches!(validate(&s), Err(ValidationError::MissingBwd { .. })));
    }

    #[test]
    fn rejects_bwd_while_evicted() {
        let s = sched(vec![Op::fwd(0), Op::evict(0), Op::bwd(0)]);
        assert!(matches!(validate(&s), Err(ValidationError::BwdWhileEvicted { .. })));
    }

    #[test]
    fn rejects_load_without_evict() {
        let s = sched(vec![Op::fwd(0), Op::load(0), Op::bwd(0)]);
        assert!(matches!(validate(&s), Err(ValidationError::LoadWithoutEvict { .. })));
    }

    #[test]
    fn rejects_double_fwd() {
        let s = sched(vec![Op::fwd(0), Op::fwd(0), Op::bwd(0)]);
        assert!(matches!(validate(&s), Err(ValidationError::DuplicateOp { .. })));
    }

    #[test]
    fn rejects_unknown_microbatch() {
        let s = sched(vec![Op::fwd(99), Op::bwd(99)]);
        assert!(matches!(validate(&s), Err(ValidationError::UnknownMicrobatch { .. })));
    }

    #[test]
    fn accepts_evict_load_cycle() {
        let s = sched(vec![Op::fwd(0), Op::evict(0), Op::load(0), Op::bwd(0)]);
        validate(&s).unwrap();
    }

    #[test]
    fn enforces_bpipe_bound() {
        let mut s = sched(vec![
            Op::fwd(0),
            Op::fwd(1),
            Op::fwd(2),
            Op::bwd(0),
            Op::bwd(1),
            Op::bwd(2),
        ]);
        s.kind = ScheduleKind::BPipe { bound: 2 };
        assert!(matches!(validate(&s), Err(ValidationError::BoundExceeded { .. })));
    }
}
