//! General zig-zag virtual pipelines — the controllable-memory placement
//! family (Qi et al. 2024, "Pipeline Parallelism with Controllable
//! Memory") at arbitrary chunk counts.
//!
//! Each physical stage hosts `v` model chunks whose dataflow alternates
//! direction: even chunks flow stage 0→p−1, odd chunks p−1→0, and chunk
//! `c+1` begins on the physical stage where chunk `c` ended — so the
//! virtual pipeline traces a zig-zag over the devices.  `v = 2` is the
//! V shape ([`super::v_shaped()`] is a thin wrapper over this generator);
//! `v = 4` is the W-shaped placement.  For even `v` every stage hosts a
//! direction-balanced set of virtual stages, so stash lifetimes sum to
//! ~constant across stages (balance by placement); odd `v` leaves the
//! final down-sweep unpaired and re-introduces a front-loaded ramp —
//! the sweep exposes both.
//!
//! Construction mirrors the V-shaped one: take the 1F1B schedule of the
//! `v·p`-deep *virtual* pipeline, assign each virtual op its completion
//! slot under unit-time list scheduling (Kahn order over the virtual
//! dependency DAG), and fold each physical stage's `v` virtual programs
//! into one op stream ordered by those slots.  Physical stage `s` hosts
//! virtual stage `c·p + s` for even chunks and `c·p + (p−1−s)` for odd
//! ones.  The result validates under the standard per-stage invariants
//! and carries [`Placement::ZigZag`] so the simulator derives each
//! chunk's dataflow in the right direction.

use super::{Op, OpKind, Placement, Schedule, ScheduleKind, StageProgram};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Physical stage hosting virtual stage `c·p + off` of a zig-zag
/// placement — the inverse of the fold below.
#[inline]
pub fn zigzag_offset(p: u64, stage: u64, chunk: u64) -> u64 {
    if chunk % 2 == 0 {
        stage
    } else {
        p - 1 - stage
    }
}

/// Generate the `v`-chunk zig-zag schedule for `p` stages and `m`
/// microbatches.  `v = 1` degenerates to plain 1F1B dataflow; `v = 2`
/// is the V shape; `v = 4` the W.
pub fn zigzag(p: u64, m: u64, v: u64) -> Schedule {
    assert!(p >= 1, "need at least one stage");
    assert!(m >= 1, "need at least one microbatch");
    assert!(v >= 1, "need at least one chunk");
    let vp = (v * p) as usize;
    let virt = super::one_f_one_b(v * p, m);

    // node ids over the virtual schedule, in (virtual stage, op index) order
    let mut base = vec![0usize; vp + 1];
    for d in 0..vp {
        base[d + 1] = base[d] + virt.programs[d].ops.len();
    }
    let n = base[vp];
    // dense (virtual stage, kind, mb) -> op index table: one O(ops)
    // build instead of a linear scan per dependency lookup
    let m_us = m as usize;
    let mut pos_tab = vec![usize::MAX; vp * 2 * m_us];
    for d in 0..vp {
        for (j, op) in virt.programs[d].ops.iter().enumerate() {
            let k = if op.kind == OpKind::Fwd { 0 } else { 1 };
            pos_tab[(d * 2 + k) * m_us + op.mb as usize] = j;
        }
    }
    let pos = |d: usize, kind: OpKind, mb: u64| -> usize {
        let k = if kind == OpKind::Fwd { 0 } else { 1 };
        pos_tab[(d * 2 + k) * m_us + mb as usize]
    };

    // dependency edges of the virtual 1F1B DAG (unit-time ops)
    let mut deps: Vec<Vec<usize>> = vec![Vec::with_capacity(3); n];
    for d in 0..vp {
        for (j, op) in virt.programs[d].ops.iter().enumerate() {
            let id = base[d] + j;
            if j > 0 {
                deps[id].push(base[d] + j - 1);
            }
            match op.kind {
                OpKind::Fwd => {
                    if d > 0 {
                        deps[id].push(base[d - 1] + pos(d - 1, OpKind::Fwd, op.mb));
                    }
                }
                OpKind::Bwd => {
                    deps[id].push(base[d] + pos(d, OpKind::Fwd, op.mb));
                    if d + 1 < vp {
                        deps[id].push(base[d + 1] + pos(d + 1, OpKind::Bwd, op.mb));
                    }
                }
                OpKind::Evict | OpKind::Load => unreachable!("1f1b base has no transfers"),
            }
        }
    }

    // unit-time list schedule: finish slot of each virtual op
    let mut indeg = vec![0usize; n];
    let mut rev: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (id, ds) in deps.iter().enumerate() {
        indeg[id] = ds.len();
        for &d in ds {
            rev[d].push(id);
        }
    }
    let mut finish = vec![0u64; n];
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = (0..n)
        .filter(|&i| indeg[i] == 0)
        .map(|i| Reverse((0, i)))
        .collect();
    let mut done = 0usize;
    while let Some(Reverse((t, id))) = heap.pop() {
        done += 1;
        finish[id] = t + 1;
        for &nxt in &rev[id] {
            indeg[nxt] -= 1;
            if indeg[nxt] == 0 {
                let r = deps[nxt].iter().map(|&d| finish[d]).max().unwrap_or(0);
                heap.push(Reverse((r, nxt)));
            }
        }
    }
    assert_eq!(done, n, "virtual 1f1b DAG must be acyclic");

    // fold: physical stage s hosts virtual stage c·p + zigzag_offset per
    // chunk, merged in finish-slot order
    let programs = (0..p)
        .map(|s| {
            let mut items: Vec<(u64, usize, usize, Op)> = Vec::new();
            for chunk in 0..v {
                let d = (chunk * p + zigzag_offset(p, s, chunk)) as usize;
                for (j, op) in virt.programs[d].ops.iter().enumerate() {
                    items.push((finish[base[d] + j], d, j, Op { kind: op.kind, mb: op.mb, chunk }));
                }
            }
            items.sort_by_key(|&(f, d, j, _)| (f, d, j));
            StageProgram { stage: s, ops: items.into_iter().map(|it| it.3).collect() }
        })
        .collect();

    Schedule {
        p,
        m,
        chunks: v,
        placement: Placement::ZigZag,
        kind: ScheduleKind::ZigZag { chunks: v },
        stage_bounds: None,
        programs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{interleaved, v_shaped, validate};

    #[test]
    fn validates_across_shapes_and_chunk_counts() {
        for (p, m) in [(1u64, 1u64), (2, 2), (2, 4), (4, 4), (4, 8), (8, 16), (3, 5), (5, 7)] {
            for v in 1..=5 {
                let s = zigzag(p, m, v);
                validate(&s).unwrap_or_else(|e| panic!("p={p} m={m} v={v}: {e}"));
                for st in 0..p {
                    assert_eq!(s.count(st, OpKind::Fwd) as u64, v * m, "p={p} m={m} v={v}");
                    assert_eq!(s.count(st, OpKind::Bwd) as u64, v * m, "p={p} m={m} v={v}");
                }
            }
        }
    }

    #[test]
    fn v2_reproduces_v_shaped_exactly() {
        // v_shaped is a thin wrapper: op-identical output, only the kind
        // tag differs
        for (p, m) in [(2u64, 4u64), (4, 8), (8, 32)] {
            let z = zigzag(p, m, 2);
            let v = v_shaped(p, m);
            assert_eq!(z.programs, v.programs, "p={p} m={m}");
            assert_eq!(z.kind, ScheduleKind::ZigZag { chunks: 2 });
            assert_eq!(v.kind, ScheduleKind::VShaped);
        }
    }

    #[test]
    fn even_v_balances_stash_pressure() {
        // even chunk counts pair each down-sweep with an up-sweep, so the
        // per-stage stash high-water is (near-)uniform — the W keeps the
        // V's balance property; interleaved at the same v does not
        for v in [2i64, 4] {
            let s = zigzag(8, 64, v as u64);
            let hws: Vec<i64> = (0..8).map(|st| s.program(st).stash_high_water()).collect();
            let spread = hws.iter().max().unwrap() - hws.iter().min().unwrap();
            assert!(spread <= 1, "v={v} spread {spread}: {hws:?}");
            let il = interleaved(8, 64, v as u64);
            let il_hws: Vec<i64> = (0..8).map(|st| il.program(st).stash_high_water()).collect();
            let il_spread = il_hws.iter().max().unwrap() - il_hws.iter().min().unwrap();
            assert!(spread < il_spread, "v={v}: zigzag {hws:?} vs interleaved {il_hws:?}");
        }
    }

    #[test]
    fn odd_v_leaves_a_ramp() {
        // an odd chunk count has one unpaired down-sweep: the front of
        // the pipe carries more stash than the back (documented, and the
        // reason the sweep's W scenario uses v = 4)
        let s = zigzag(8, 64, 3);
        let hws: Vec<i64> = (0..8).map(|st| s.program(st).stash_high_water()).collect();
        assert!(hws[0] > hws[7], "{hws:?}");
    }

    #[test]
    fn junction_stages_run_chunks_back_to_back() {
        // chunk c ends and chunk c+1 begins on the same physical stage:
        // stage p−1 for even c, stage 0 for odd c.  A microbatch's
        // chunk-(c+1) forward closely follows its chunk-c forward there.
        let s = zigzag(4, 8, 4);
        for (c, stage) in [(0u64, 3u64), (1, 0), (2, 3)] {
            let ops = &s.program(stage).ops;
            let f0 = ops
                .iter()
                .position(|o| o.kind == OpKind::Fwd && o.mb == 0 && o.chunk == c)
                .unwrap();
            let f1 = ops
                .iter()
                .position(|o| o.kind == OpKind::Fwd && o.mb == 0 && o.chunk == c + 1)
                .unwrap();
            assert!(f1 > f0, "chunk {} before {} on stage {stage}", c + 1, c);
            assert!(f1 - f0 <= 3, "chunk-{} fwd should closely follow chunk-{c}: {f0} vs {f1}", c + 1);
        }
    }
}
