//! A100 kernel-level cost model — the simulator's clock.
//!
//! Every constant is documented and the whole model is calibrated against
//! ONE target: the paper's Table 5 single-stage MFUs (shape, not exact
//! values).  Table 3 whole-model numbers are *not* fitted — they emerge
//! from running the schedules through the DES engine on these per-op
//! times (see EXPERIMENTS.md).
//!
//! ## The §3.2 kernel story, mechanized
//!
//! The paper's profiling found GPT-3's BPipe "win" was mostly a kernel
//! switch: at b=1 the scale+softmax ran as separate fp32-casting,
//! memory-bound kernels; at b=2 Megatron's fused kernel kicked in.
//! Megatron's fused scaled-masked-softmax kernel has an eligibility rule
//! (from its source): it requires `attn_batches % 4 == 0` where
//! `attn_batches = b · a/t`, plus `s % 4 == 0`, `16 < s ≤ 16384`.
//!
//! * GPT-3 96B, t=4: a/t = 104/4 = **26** heads/rank → b=1 gives 26 (not
//!   divisible by 4, unfused slow path); b=2 gives 52 (fused). ✔ exp (7)/(8)
//! * LLaMA 65B, t=4: a/t = 64/4 = **16** → every b qualifies (always
//!   fused). ✔ why BPipe showed no kernel-switch gain on LLaMA
//! * flash attention bypasses the softmax kernel entirely. ✔ exp (9)/(10)

use crate::config::{AttentionMethod, ExperimentConfig};
use crate::model::flops;

/// Peak-fraction a well-shaped dense bf16 GEMM achieves on A100
/// (cuBLAS measured ~0.75–0.85 of the 312 TFLOP/s datasheet number).
pub const GEMM_EFF_MAX: f64 = 0.70;

/// Rows at which GEMM efficiency reaches half of max — models wave
/// quantization / launch amortization improving with larger microbatches
/// (the Table-5 "MFU grows with b" effect).
pub const GEMM_ROWS_HALF: f64 = 450.0;

/// Flash-attention's inner matmuls run below peak GEMM efficiency
/// (small `d`-dimension tiles): fraction of [`GEMM_EFF_MAX`].
pub const FLASH_EFF_FACTOR: f64 = 0.95;

/// Unfused scale+softmax HBM traffic, bytes per score element, forward:
/// cast f16→f32 (2r+4w) + scale (4r+4w) + mask (4r+4w) + softmax
/// (3 passes ≈ 12r+4w) + cast back (4r+2w) ≈ 42 B/elem.
pub const UNFUSED_SOFTMAX_FWD_B: f64 = 60.0;

/// Unfused softmax backward traffic (reads stashed probs + grad in f32,
/// writes f32, with casts): ≈ 26 B/elem.
pub const UNFUSED_SOFTMAX_BWD_B: f64 = 40.0;

/// Fused kernel forward: one f16 read + one f16 write ≈ 4 B/elem.
pub const FUSED_SOFTMAX_FWD_B: f64 = 4.0;

/// Fused kernel backward: read probs + dout, write dscores (f16) with an
/// in-register f32 row reduction ≈ 8 B/elem.
pub const FUSED_SOFTMAX_BWD_B: f64 = 8.0;

/// Elementwise/norm/residual/dropout HBM traffic per layer, bytes per
/// `b·s·h/t` element, forward / backward (Korthikanti-style accounting).
pub const ELEM_FWD_B: f64 = 40.0;
pub const ELEM_BWD_B: f64 = 64.0;

/// Kernel launches per transformer layer (fwd / bwd): matmuls + bias +
/// norms + residuals + dropout (+ softmax pieces are charged separately).
pub const LAUNCHES_FWD: f64 = 22.0;
pub const LAUNCHES_BWD: f64 = 38.0;

/// Achievable fraction of NVLink / IB / HBM peak bandwidth.
pub const LINK_EFF: f64 = 0.85;
pub const HBM_EFF: f64 = 0.90;

/// Fixed latency per BPipe transfer (rendezvous + NCCL launch).
pub const TRANSFER_LATENCY_S: f64 = 50e-6;

/// Cross-entropy + logits elementwise traffic, bytes per `b·s·v/t`
/// element on the head stage.
pub const CE_BYTES_PER_EL: f64 = 12.0;

/// Which softmax path the attention uses — the §3.2 mechanism.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SoftmaxKernel {
    /// separate cast/scale/mask/softmax kernels with f32 round-trips
    Unfused,
    /// Megatron's fused scaled-masked-softmax
    Fused,
    /// no softmax kernel at all (flash attention)
    Flash,
}

/// Megatron fused-softmax eligibility: `attn_batches % 4 == 0`,
/// `s % 4 == 0`, `16 < s ≤ 16384` (from Megatron-LM
/// `fused_softmax.py::is_kernel_available`).
pub fn fused_softmax_eligible(b: u64, a: u64, t: u64, s: u64) -> bool {
    let attn_batches = b * (a / t);
    attn_batches % 4 == 0 && s % 4 == 0 && s > 16 && s <= 16384
}

/// Per-stage forward/backward wall-clock for one microbatch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageTimes {
    pub fwd: f64,
    pub bwd: f64,
}

impl StageTimes {
    pub fn total(&self) -> f64 {
        self.fwd + self.bwd
    }
}

/// The calibrated cost model for one experiment configuration.
///
/// Borrows the config instead of cloning it so constructing one is free —
/// the sweep's inner loop builds a `CostModel` per simulated cell and must
/// not touch the heap (see [`super::engine::SimWorkspace`]).
#[derive(Debug, Clone, Copy)]
pub struct CostModel<'a> {
    pub e: &'a ExperimentConfig,
}

impl<'a> CostModel<'a> {
    pub fn new(e: &'a ExperimentConfig) -> Self {
        Self { e }
    }

    fn peak(&self) -> f64 {
        self.e.cluster.peak_flops
    }

    fn hbm(&self) -> f64 {
        self.e.cluster.hbm_bw * HBM_EFF
    }

    /// GEMM efficiency as a function of output rows (`b·s` for the big
    /// projections): saturating, so bigger microbatches run closer to peak.
    pub fn gemm_eff(&self, rows: f64) -> f64 {
        GEMM_EFF_MAX * rows / (rows + GEMM_ROWS_HALF)
    }

    /// Time for `flops` of dense GEMM work at `rows` output rows.
    fn gemm_time(&self, flops_: f64, rows: f64) -> f64 {
        flops_ / (self.peak() * self.gemm_eff(rows))
    }

    /// Which softmax kernel this config runs (the §3.2 selection rule).
    pub fn softmax_kernel(&self) -> SoftmaxKernel {
        let p = &self.e.parallel;
        let m = &self.e.model;
        match self.e.attention {
            AttentionMethod::FlashAttn2 => SoftmaxKernel::Flash,
            AttentionMethod::None | AttentionMethod::Recompute => {
                if fused_softmax_eligible(p.microbatch, m.a, p.t, m.s) {
                    SoftmaxKernel::Fused
                } else {
                    SoftmaxKernel::Unfused
                }
            }
        }
    }

    /// Score-tensor elements per layer on one rank: `b · (a/t) · s²`.
    fn softmax_elems(&self) -> f64 {
        let m = &self.e.model;
        let p = &self.e.parallel;
        (p.microbatch * (m.a / p.t) * m.s * m.s) as f64
    }

    /// Softmax wall-clock per layer (fwd, bwd), memory-bound.
    fn softmax_times(&self) -> (f64, f64) {
        let elems = self.softmax_elems();
        let launch = self.e.cluster.kernel_launch_s;
        match self.softmax_kernel() {
            SoftmaxKernel::Unfused => (
                elems * UNFUSED_SOFTMAX_FWD_B / self.hbm() + 5.0 * launch,
                elems * UNFUSED_SOFTMAX_BWD_B / self.hbm() + 3.0 * launch,
            ),
            SoftmaxKernel::Fused => (
                elems * FUSED_SOFTMAX_FWD_B / self.hbm() + launch,
                elems * FUSED_SOFTMAX_BWD_B / self.hbm() + launch,
            ),
            SoftmaxKernel::Flash => (0.0, 0.0),
        }
    }

    /// Tensor-parallel collective time per layer, one direction (fwd or
    /// bwd).  With sequence parallelism: 4 collectives (all-gather +
    /// reduce-scatter around attention and FFN), each moving
    /// `b·s·h·2·(t−1)/t` bytes over NVLink.
    pub fn tp_comm_time_per_layer(&self) -> f64 {
        let p = &self.e.parallel;
        if p.t <= 1 {
            return 0.0;
        }
        let m = &self.e.model;
        let bytes = (p.microbatch * m.s * m.h * 2) as f64 * (p.t - 1) as f64 / p.t as f64;
        let n_coll = 4.0;
        n_coll * (bytes / (self.e.cluster.nvlink_bw * LINK_EFF) + self.e.cluster.kernel_launch_s)
    }

    /// Forward time of one transformer layer on one rank.
    pub fn layer_fwd_time(&self) -> f64 {
        let m = &self.e.model;
        let p = &self.e.parallel;
        let lf = flops::layer_fwd_flops(m, p.microbatch, p.t);
        let rows = (p.microbatch * m.s) as f64;
        let proj_time = self.gemm_time(lf.qkv + lf.proj + lf.ffn, rows);
        let attn_eff = match self.softmax_kernel() {
            SoftmaxKernel::Flash => self.gemm_eff(rows) * FLASH_EFF_FACTOR,
            _ => self.gemm_eff(rows),
        };
        let attn_time = lf.attn_core / (self.peak() * attn_eff);
        let (sm_fwd, _) = self.softmax_times();
        let elem = ELEM_FWD_B * (p.microbatch * m.s * m.h / p.t) as f64 / self.hbm();
        let launches = LAUNCHES_FWD * self.e.cluster.kernel_launch_s;
        proj_time + attn_time + sm_fwd + elem + launches + self.tp_comm_time_per_layer()
    }

    /// Backward time of one transformer layer on one rank (≈2× forward
    /// matmuls, + attention recomputation when the method requires it).
    pub fn layer_bwd_time(&self) -> f64 {
        let m = &self.e.model;
        let p = &self.e.parallel;
        let lf = flops::layer_fwd_flops(m, p.microbatch, p.t);
        let rows = (p.microbatch * m.s) as f64;
        let proj_time = self.gemm_time(2.0 * (lf.qkv + lf.proj + lf.ffn), rows);
        let attn_eff = match self.softmax_kernel() {
            SoftmaxKernel::Flash => self.gemm_eff(rows) * FLASH_EFF_FACTOR,
            _ => self.gemm_eff(rows),
        };
        let mut attn_time = 2.0 * lf.attn_core / (self.peak() * attn_eff);
        let (sm_fwd, sm_bwd) = self.softmax_times();
        let mut sm_time = sm_bwd;
        // selective recompute: the attention core forward (matmuls +
        // softmax kernel) runs again inside bwd.  Flash-attn-2's bwd
        // recomputes too, but inside the fused kernel whose cost is
        // already covered by the 2x-forward factor (Dao 2023 reports
        // bwd ~2-2.5x fwd); it is not charged an extra pass here.
        if self.e.attention == AttentionMethod::Recompute {
            attn_time += lf.attn_core / (self.peak() * attn_eff);
            sm_time += sm_fwd;
        }
        let elem = ELEM_BWD_B * (p.microbatch * m.s * m.h / p.t) as f64 / self.hbm();
        let launches = LAUNCHES_BWD * self.e.cluster.kernel_launch_s;
        proj_time + attn_time + sm_time + elem + launches + self.tp_comm_time_per_layer()
    }

    /// Extra forward time on the first stage: embedding lookup (+ learned
    /// positions) — memory-bound gather.
    fn embed_fwd_time(&self) -> f64 {
        let m = &self.e.model;
        let p = &self.e.parallel;
        let bytes = (p.microbatch * m.s * m.h) as f64 / p.t as f64 * 3.0 * 2.0;
        bytes / self.hbm() + self.e.cluster.kernel_launch_s
    }

    /// Extra time on the last stage: LM-head matmul + cross-entropy.
    fn head_times(&self) -> (f64, f64) {
        let m = &self.e.model;
        let p = &self.e.parallel;
        let rows = (p.microbatch * m.s) as f64;
        let mm_fwd = 2.0 * (p.microbatch * m.s * m.h * m.v) as f64 / p.t as f64;
        let ce = CE_BYTES_PER_EL * (p.microbatch * m.s * m.v / p.t) as f64 / self.hbm();
        (
            self.gemm_time(mm_fwd, rows) + ce,
            self.gemm_time(2.0 * mm_fwd, rows) + ce,
        )
    }

    /// Layers per pipeline stage.
    fn layers_per_stage(&self) -> f64 {
        (self.e.model.l / self.e.parallel.p) as f64
    }

    /// Per-microbatch forward/backward time of `stage`.
    pub fn stage_times(&self, stage: u64) -> StageTimes {
        let n = self.layers_per_stage();
        let mut fwd = n * self.layer_fwd_time();
        let mut bwd = n * self.layer_bwd_time();
        if stage == 0 {
            fwd += self.embed_fwd_time();
            bwd += self.embed_fwd_time(); // grad scatter
        }
        if stage == self.e.parallel.p - 1 {
            let (hf, hb) = self.head_times();
            fwd += hf;
            bwd += hb;
        }
        StageTimes { fwd, bwd }
    }

    /// BPipe evict/load transfer time for one stash (one direction).
    pub fn transfer_time(&self, intra_node: bool) -> f64 {
        self.transfer_time_chunked(intra_node, 1)
    }

    /// Transfer time of one stash of a `chunks`-way virtual pipeline: a
    /// chunk stash holds only `1/chunks` of a stage's layers, so the
    /// payload (and hence the wire time) scales down with the chunk count.
    pub fn transfer_time_chunked(&self, intra_node: bool, chunks: u64) -> f64 {
        let mm = crate::model::memory::MemoryModel::new(self.e);
        let bytes = (mm.activation_bytes_per_microbatch(0) / chunks.max(1)) as f64;
        let bw = if intra_node {
            self.e.cluster.nvlink_bw * LINK_EFF
        } else {
            self.e.cluster.ib_bw * LINK_EFF
        };
        bytes / bw + TRANSFER_LATENCY_S
    }

    /// Single-stage MFU (the paper's Table-5 measurement): model FLOPs of
    /// an interior stage per microbatch over `t` devices running `T(b)`.
    pub fn single_stage_mfu(&self) -> f64 {
        let m = &self.e.model;
        let p = &self.e.parallel;
        let f_stage = flops::mid_stage_flops_per_microbatch(m, p.microbatch, p.p);
        let t = self.stage_times(1).total();
        f_stage / (p.t as f64 * self.peak() * t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{paper_experiment, paper_table5_mfu};

    #[test]
    fn fused_kernel_eligibility_reproduces_sec32() {
        // GPT-3 96B (a=104, t=4): b=1 → 26 attn batches → unfused
        assert!(!fused_softmax_eligible(1, 104, 4, 2048));
        // b=2 → 52 → fused (the hidden kernel switch of exp (7)→(8))
        assert!(fused_softmax_eligible(2, 104, 4, 2048));
        // LLaMA 65B (a=64, t=4): 16 heads/rank → always fused
        for b in [1, 2, 4] {
            assert!(fused_softmax_eligible(b, 64, 4, 2048));
        }
    }

    #[test]
    fn softmax_kernel_selection_per_experiment() {
        let k = |id| CostModel::new(&paper_experiment(id).unwrap()).softmax_kernel();
        assert_eq!(k(7), SoftmaxKernel::Unfused); // GPT b=1 recompute
        assert_eq!(k(8), SoftmaxKernel::Fused); // GPT b=2 recompute
        assert_eq!(k(9), SoftmaxKernel::Flash);
        assert_eq!(k(1), SoftmaxKernel::Fused); // LLaMA always fused
        assert_eq!(k(2), SoftmaxKernel::Fused);
    }

    #[test]
    fn gemm_eff_monotone_in_rows() {
        let e = paper_experiment(1).unwrap();
        let cm = CostModel::new(&e);
        assert!(cm.gemm_eff(4096.0) > cm.gemm_eff(2048.0));
        assert!(cm.gemm_eff(2048.0) < GEMM_EFF_MAX);
    }

    #[test]
    fn bwd_slower_than_fwd() {
        for id in 1..=10 {
            let e = paper_experiment(id).unwrap();
            let cm = CostModel::new(&e);
            let st = cm.stage_times(1);
            assert!(st.bwd > st.fwd, "exp {id}");
            assert!(st.bwd < 3.5 * st.fwd, "exp {id}");
        }
    }

    #[test]
    fn head_stage_slower_than_mid() {
        let e = paper_experiment(7).unwrap();
        let cm = CostModel::new(&e);
        assert!(cm.stage_times(7).total() > cm.stage_times(3).total());
    }

    /// Calibration gate: simulated single-stage MFUs must track the
    /// paper's Table 5 within a few points and preserve every ordering
    /// the paper's analysis relies on.
    #[test]
    fn table5_shape() {
        let mfu = |id: u32| CostModel::new(&paper_experiment(id).unwrap()).single_stage_mfu() * 100.0;
        for id in 1..=10u32 {
            let ours = mfu(id);
            let paper = paper_table5_mfu(id).unwrap();
            assert!(
                (ours - paper).abs() < 8.0,
                "exp {id}: ours {ours:.1} vs paper {paper:.1}"
            );
        }
        // orderings that drive the paper's conclusions:
        assert!(mfu(8) - mfu(7) > 10.0, "GPT kernel switch must be large");
        assert!(mfu(10) > mfu(9), "flash b=2 > b=1");
        assert!(mfu(10) - mfu(9) < 8.0, "flash gain is modest");
        for (lo, hi) in [(1, 2), (2, 3), (4, 5), (5, 6)] {
            assert!(mfu(hi) > mfu(lo), "LLaMA MFU grows with b: {lo} vs {hi}");
        }
    }

    #[test]
    fn transfer_overlaps_under_compute() {
        // paper §2.2: intra-node transfer ≪ fwd/bwd compute time
        let e = paper_experiment(8).unwrap();
        let cm = CostModel::new(&e);
        let st = cm.stage_times(1);
        assert!(cm.transfer_time(true) < st.fwd);
        // inter-node, it would NOT hide — the reason Figure 2 exists
        assert!(cm.transfer_time(false) > st.fwd);
    }
}
