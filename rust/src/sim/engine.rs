//! Discrete-event simulator: executes a [`Schedule`] against the
//! [`CostModel`](super::costmodel::CostModel) on a modeled cluster.
//!
//! Each stage has a FIFO **compute stream** (Fwd/Bwd) and each
//! evictor/acceptor pair a FIFO **transfer stream** (Evict/Load).  Ops
//! form a DAG:
//!
//! * `Fwd(s, i, c)` needs the previous hop of chunk `c`'s dataflow
//!   (`Fwd(s−1, i, c)` for sequential placement, the alternating-sweep
//!   path for [`Placement::ZigZag`] — V at 2 chunks, W at 4) and the
//!   previous compute op on stage `s`;
//! * `Bwd(s, i, c)` needs the downstream gradient along the reverse of
//!   that dataflow, its own `Fwd(s, i, c)`, the previous compute op, and
//!   — if the stash was evicted — the most recent `Load(s, i, c)`
//!   (rebalancing's only coupling into compute);
//! * `Evict/Load` need their triggering op and the previous transfer on
//!   the pair's link; a key may cycle Evict→Load repeatedly, so those
//!   deps are resolved by walking each program in order rather than by a
//!   unique per-key lookup.
//!
//! Completion times are computed by Kahn topological order; the engine
//! also tracks per-device stash residency over time (memory high-water,
//! OOM detection, with allocations applied before frees at equal
//! timestamps — conservative) and per-stream busy time (bubble fraction).
//!
//! ## Hot path: the zero-allocation workspace
//!
//! The DES inner loop is the cost of every cell in [`mod@super::sweep`]'s
//! experiment × schedule × bound × layout grid, so all per-run state
//! lives in a reusable [`SimWorkspace`] owned by each sweep worker:
//!
//! * dependency and reverse edges are flat **CSR arrays**
//!   (`dep_off`/`dep_edges`, plus a counts→prefix-sum→fill counting sort
//!   for the reverse direction) instead of per-node `Vec<Vec<usize>>`;
//! * compute-op lookups go through a dense precomputed index
//!   (`stage × {Fwd,Bwd} × mb × chunk → node id`) instead of a `HashMap`;
//! * the ready-event `BinaryHeap`, per-link free-times, per-node
//!   durations and the memory-event timeline are all workspace buffers
//!   cleared (capacity kept) between runs;
//! * trace collection is opt-in via [`SimOptions`] — steady-state sweep
//!   cells allocate **nothing** after warm-up (pinned by the
//!   counting-allocator test in `rust/tests/alloc_steady_state.rs`).
//!
//! [`SimWorkspace::run`] returns a heap-free [`SimStats`]; the
//! convenience wrapper [`simulate`] materializes the classic
//! [`SimResult`] (per-stage vectors + trace) from a throwaway workspace.
//! All float orderings go through `f64::total_cmp`, so a NaN (degenerate
//! zero-duration config) can never poison a comparator.
//!
//! ## Warm-start delta replay
//!
//! Adjacent cells of a bound sweep (and adjacent candidates in
//! `schedule::synthesize`'s hill climb) share almost their entire event
//! stream: rebalancing at bound `b` vs `b+1` moves only Evict/Load ops
//! around an identical compute sequence.  With [`SimOptions::warm`] the
//! workspace snapshots each run's flattened programs, durations and
//! start/end times; the next warm run compares per-stage
//! `(op, duration)` slots against the snapshot, finds every stage's
//! common prefix `P_s`, and derives a **divergence horizon** `D = min`
//! over divergent stages of `end(last common compute op before P_s)` —
//! no event anywhere in the DAG can be influenced by a divergent op
//! before `D`, because every op at a divergent slot has
//! `ready ≥ end(last common compute below it)` through its
//! program-order dependency chain.  Every common-prefix node with
//! `start < D` is copied from the snapshot; the event loop then resumes
//! with per-link free-times rebuilt from the copied transfers,
//! indegrees counting only non-copied dependencies, and the copied
//! `Bwd` ops' load-stall contributions re-accumulated in `(start, id)`
//! pop order — which, with strictly positive durations (checked; cold
//! fallback otherwise), reproduces the cold run's heap pop order
//! exactly, so the warm result is **bit-identical** to a cold run
//! (differentially tested per cell in `sweep.rs`).  The replayed/total
//! event counters ([`SimWorkspace::events_replayed`]) feed the sweep
//! telemetry.

use super::costmodel::{CostModel, StageTimes};
use crate::bpipe::{pairing, Layout};
use crate::config::ExperimentConfig;
use crate::model::{flops, memory::MemoryModel};
use crate::schedule::{Op, OpKind, Placement, Schedule};

/// One executed op, for timeline rendering (paper Figure 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub stage: u64,
    pub kind: OpKind,
    pub mb: u64,
    pub chunk: u64,
    pub start: f64,
    pub end: f64,
}

/// Simulation output for one training iteration.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// iteration wall-clock (seconds)
    pub makespan: f64,
    /// whole-model MFU (0..1), paper Eq. MFU definition
    pub mfu: f64,
    /// per-stage compute busy time (seconds)
    pub busy: Vec<f64>,
    /// 1 − mean(busy)/makespan
    pub bubble_fraction: f64,
    /// per-stage peak device memory, bytes (weights+opt+stash+reserved)
    pub mem_high_water: Vec<u64>,
    /// per-stage peak resident stash count (own + accepted from partner)
    pub stash_high_water: Vec<i64>,
    /// stage that exceeded HBM capacity, if any
    pub oom_stage: Option<u64>,
    /// total backward stall time waiting on BPipe loads (seconds)
    pub load_stall: f64,
    /// total bytes moved by BPipe transfers
    pub transfer_bytes: u64,
    /// executed-op timeline
    pub trace: Vec<TraceEvent>,
}

impl SimResult {
    pub fn mfu_pct(&self) -> f64 {
        self.mfu * 100.0
    }
}

/// Export a trace as CSV (`stage,kind,mb,chunk,start,end`) for external
/// plotting — the machine-readable companion of the Figure-1 renderer.
pub fn trace_to_csv(trace: &[TraceEvent]) -> String {
    let mut out = String::from("stage,kind,mb,chunk,start,end\n");
    for ev in trace {
        out.push_str(&format!(
            "{},{:?},{},{},{:.9},{:.9}\n",
            ev.stage, ev.kind, ev.mb, ev.chunk, ev.start, ev.end
        ));
    }
    out
}

/// Per-run output options: what the workspace collects beyond timings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SimOptions {
    /// Collect the per-op [`TraceEvent`] timeline (Figure-1 renderer,
    /// `--timeline`).  Sweep cells turn this off; the memory timeline is
    /// always tracked (it feeds OOM detection) but lives in reused
    /// workspace buffers either way.
    pub trace: bool,
    /// Warm-start delta replay: snapshot this run's event timeline in
    /// the workspace and, on the next warm run, replay the per-stage
    /// common program prefix up to the divergence horizon instead of
    /// re-simulating it (see the module docs § "Warm-start delta
    /// replay").  Results stay **bit-identical** to a cold run; the
    /// differential tests in `sweep.rs` pin it.  Off by default: only
    /// callers that run near-identical schedules back-to-back
    /// (descending-bound sweeps, `schedule::synthesize`'s scoring loop)
    /// profit from the snapshot copies.
    pub warm: bool,
    /// Recompute-vs-stash hybrid memory model (`bpipe sweep
    /// --recompute`): an evicted activation is **discarded** instead of
    /// transferred to the pair stage — Evict costs nothing and holds no
    /// acceptor-side memory, and the matching Load is a **recompute op**
    /// (one forward at the evicting stage's own cost) instead of a
    /// transfer back.  Neither op touches the inter-stage links, and
    /// `transfer_bytes` is 0; the recompute cost surfaces through
    /// `load_stall` (backwards waiting on the re-materialization) and
    /// the makespan.  This is the memory model a degraded fleet replica
    /// uses to trade compute for memory when no partner has stash room.
    /// Zero-duration Evicts fail the strictly-positive-durations gate,
    /// so recompute cells always run cold (warm replay falls back —
    /// soundly, since the prefix match also compares durations).
    pub recompute: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { trace: true, warm: false, recompute: false }
    }
}

/// Heap-free summary of one simulated iteration — everything a sweep
/// cell needs.  Per-stage vectors stay in the [`SimWorkspace`]
/// (accessors: [`SimWorkspace::busy`], [`SimWorkspace::mem_high_water`],
/// [`SimWorkspace::stash_high_water`], [`SimWorkspace::trace`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimStats {
    pub makespan: f64,
    pub mfu: f64,
    pub bubble_fraction: f64,
    /// max over stages of the per-stage peak device memory
    pub peak_mem_bytes: u64,
    /// max over stages of the per-stage peak resident stash count
    pub peak_stash: i64,
    pub oom_stage: Option<u64>,
    pub load_stall: f64,
    pub transfer_bytes: u64,
}

impl SimStats {
    pub fn mfu_pct(&self) -> f64 {
        self.mfu * 100.0
    }
}

const NONE: u32 = u32::MAX;

/// Dense `(stage, Fwd|Bwd, mb, chunk) → node id` slot — the hot-path
/// replacement for a per-op `HashMap` (compute ops are unique per key by
/// validation, so a flat array slot each suffices).
#[inline]
fn cix_slot(stage: usize, kind: OpKind, mb: u64, chunk: u64, m: usize, chunks: usize) -> usize {
    let k = match kind {
        OpKind::Fwd => 0,
        OpKind::Bwd => 1,
        _ => unreachable!("only compute ops are indexed"),
    };
    ((stage * 2 + k) * m + mb as usize) * chunks + chunk as usize
}

/// Node id of a compute op that validation guarantees to exist.
#[inline]
fn cix_get(
    cix: &[u32],
    stage: usize,
    kind: OpKind,
    mb: u64,
    chunk: u64,
    m: usize,
    chunks: usize,
) -> u32 {
    let id = cix[cix_slot(stage, kind, mb, chunk, m, chunks)];
    debug_assert_ne!(id, NONE, "missing compute op in validated schedule");
    id
}

/// Previous virtual-pipeline hop of chunk `chunk`'s forward dataflow at
/// stage `s` (backward deps are the reverse of this path).
///
/// Zig-zag placement: even chunks flow 0→p−1, odd chunks p−1→0; a
/// chunk's *offset* along its own sweep is `s` (even) or `p−1−s` (odd).
/// At offset 0 of chunk c > 0 the dep is the previous chunk's last hop,
/// which the placement puts on the SAME physical stage (the V/W
/// junction).  Two chunks reproduce the V shape exactly.
#[inline]
#[allow(clippy::too_many_arguments)]
fn fwd_dep(
    cix: &[u32],
    p: usize,
    m: usize,
    chunks: usize,
    zigzag: bool,
    s: usize,
    mb: u64,
    chunk: u64,
) -> Option<u32> {
    if !zigzag {
        if s > 0 {
            Some(cix_get(cix, s - 1, OpKind::Fwd, mb, chunk, m, chunks))
        } else if chunk > 0 {
            // interleaved wrap: chunk c at stage 0 consumes
            // chunk c−1 at stage p−1
            Some(cix_get(cix, p - 1, OpKind::Fwd, mb, chunk - 1, m, chunks))
        } else {
            None
        }
    } else {
        let off = if chunk % 2 == 0 { s } else { p - 1 - s };
        if off > 0 {
            let prev_s = if chunk % 2 == 0 { s - 1 } else { s + 1 };
            Some(cix_get(cix, prev_s, OpKind::Fwd, mb, chunk, m, chunks))
        } else if chunk > 0 {
            // zig-zag junction: chunk c starts where chunk c−1 ended
            Some(cix_get(cix, s, OpKind::Fwd, mb, chunk - 1, m, chunks))
        } else {
            None
        }
    }
}

/// Downstream gradient source for `Bwd(s, mb, chunk)` — the reverse of
/// the [`fwd_dep`] dataflow.
#[inline]
#[allow(clippy::too_many_arguments)]
fn bwd_dep(
    cix: &[u32],
    p: usize,
    m: usize,
    chunks: usize,
    zigzag: bool,
    s: usize,
    mb: u64,
    chunk: u64,
) -> Option<u32> {
    if !zigzag {
        if s + 1 < p {
            Some(cix_get(cix, s + 1, OpKind::Bwd, mb, chunk, m, chunks))
        } else if chunk + 1 < chunks as u64 {
            // interleaved wrap: grad for chunk c at stage p−1
            // comes from chunk c+1 at stage 0
            Some(cix_get(cix, 0, OpKind::Bwd, mb, chunk + 1, m, chunks))
        } else {
            None
        }
    } else {
        let off = if chunk % 2 == 0 { s } else { p - 1 - s };
        if off + 1 < p {
            let nxt_s = if chunk % 2 == 0 { s + 1 } else { s - 1 };
            Some(cix_get(cix, nxt_s, OpKind::Bwd, mb, chunk, m, chunks))
        } else if chunk + 1 < chunks as u64 {
            // zig-zag junction in reverse: chunk c's grad at its last hop
            // comes from chunk c+1 on the same stage
            Some(cix_get(cix, s, OpKind::Bwd, mb, chunk + 1, m, chunks))
        } else {
            None
        }
    }
}

/// `(ready_time, node id)` min-heap entry.  The total order goes through
/// `f64::total_cmp` (never panics, NaN-safe) with the id as a
/// deterministic tie-break.
struct Ev(f64, u32);

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        // keep == consistent with the total_cmp-based Ord (a derived
        // f64 == would disagree on -0.0/NaN and break the Eq contract)
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Ev {}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap: reverse on time, tie-break on id for determinism
        other.0.total_cmp(&self.0).then(other.1.cmp(&self.1))
    }
}

/// One stash-residency delta on one stage's memory timeline.
#[derive(Debug, Clone, Copy)]
struct MemEvent {
    t: f64,
    stage: u32,
    delta: i32,
}

/// Reusable per-thread simulation arena: every buffer the DES needs,
/// cleared (capacity kept) between runs so repeated [`SimWorkspace::run`]
/// calls on same-shaped schedules perform **zero heap allocations**.
///
/// One workspace per sweep worker thread; a workspace is `Send` (all
/// plain buffers) but deliberately not shared — each worker owns its own.
#[derive(Default)]
pub struct SimWorkspace {
    // -- topology (rebuilt per run) --------------------------------------
    /// stage → first node id (len p+1)
    base: Vec<u32>,
    /// node id → op (flattened programs, id order == program order)
    ops: Vec<Op>,
    /// node id → stage
    stage_of: Vec<u32>,
    /// dense compute index: `(stage, F|B, mb, chunk) → node id`
    cix: Vec<u32>,
    // -- CSR dependency edges (built in one walk: ids ascend, so the
    // offsets come out sorted for free) and the counting-sorted reverse --
    dep_off: Vec<u32>,
    dep_edges: Vec<u32>,
    rev_off: Vec<u32>,
    rev_edges: Vec<u32>,
    rev_cursor: Vec<u32>,
    /// node id of the Load a Bwd waits on (`NONE` if its stash never left)
    bwd_load_dep: Vec<u32>,
    // per-stage walk scratch, keyed by `mb·chunks + chunk`
    last_evict: Vec<u32>,
    last_load: Vec<u32>,
    // -- event-loop state -------------------------------------------------
    indeg: Vec<u32>,
    /// node id → duration (precomputed; the loop reads it twice per node)
    dur: Vec<f64>,
    start: Vec<f64>,
    end: Vec<f64>,
    heap: std::collections::BinaryHeap<Ev>,
    /// dense per-link free-time: nvlink pair k < p, then IB uplink per node
    link_free: Vec<f64>,
    link_of: Vec<u32>,
    intra: Vec<bool>,
    stage_times: Vec<StageTimes>,
    // -- aggregation ------------------------------------------------------
    busy: Vec<f64>,
    order: Vec<u32>,
    trace: Vec<TraceEvent>,
    events: Vec<MemEvent>,
    cur: Vec<i64>,
    stash_hw: Vec<i64>,
    mem_hw: Vec<u64>,
    // -- warm-start snapshot (SimOptions::warm) ---------------------------
    /// a snapshot of the previous warm run exists and had strictly
    /// positive durations (the replay soundness precondition)
    snap_valid: bool,
    snap_p: usize,
    snap_m: usize,
    snap_chunks: usize,
    snap_zigzag: bool,
    snap_base: Vec<u32>,
    snap_ops: Vec<Op>,
    snap_link_of: Vec<u32>,
    snap_dur: Vec<f64>,
    snap_start: Vec<f64>,
    snap_end: Vec<f64>,
    /// node id → copied-from-snapshot marker for the current run
    copied: Vec<bool>,
    /// per-stage common-prefix length scratch for the current run
    prefix: Vec<u32>,
    // -- telemetry (cumulative across runs; see `events_replayed`) --------
    events_total: u64,
    events_replayed: u64,
}

impl SimWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Per-stage compute busy time of the last run (seconds).
    pub fn busy(&self) -> &[f64] {
        &self.busy
    }

    /// Per-stage peak device memory of the last run (bytes).
    pub fn mem_high_water(&self) -> &[u64] {
        &self.mem_hw
    }

    /// Per-stage peak resident stash count of the last run.
    pub fn stash_high_water(&self) -> &[i64] {
        &self.stash_hw
    }

    /// Executed-op timeline of the last run (empty unless
    /// `SimOptions::trace` was set).
    pub fn trace(&self) -> &[TraceEvent] {
        &self.trace
    }

    /// Cumulative DES node count across every run of this workspace
    /// (warm or cold) — the denominator of the warm-start telemetry.
    pub fn events_total(&self) -> u64 {
        self.events_total
    }

    /// Cumulative count of nodes whose times were replayed from the
    /// warm-start snapshot instead of simulated.  The sweep aggregates
    /// these per-worker counters into
    /// [`SweepReport`](super::sweep::SweepReport).
    pub fn events_replayed(&self) -> u64 {
        self.events_replayed
    }

    /// Materialize the last run's full [`SimResult`] (allocates — the
    /// sweep reads [`SimStats`] + slices instead).
    pub fn to_result(&self, stats: &SimStats) -> SimResult {
        SimResult {
            makespan: stats.makespan,
            mfu: stats.mfu,
            busy: self.busy.clone(),
            bubble_fraction: stats.bubble_fraction,
            mem_high_water: self.mem_hw.clone(),
            stash_high_water: self.stash_hw.clone(),
            oom_stage: stats.oom_stage,
            load_stall: stats.load_stall,
            transfer_bytes: stats.transfer_bytes,
            trace: self.trace.clone(),
        }
    }

    /// Simulate one iteration of `schedule` for experiment `e` on
    /// `layout`, reusing this workspace's buffers.  Deterministic: the
    /// same inputs produce bit-identical stats regardless of what ran in
    /// the workspace before.
    ///
    /// The hot path trusts its (generator-produced, test-validated)
    /// schedules and does NOT re-validate — validation allocates, and
    /// this loop must not.  A malformed schedule cannot hang the engine
    /// but will panic: a dependency cycle trips the Kahn-completeness
    /// assert, a Load whose key was never evicted trips a labeled
    /// assert, and other structural violations (e.g. a Bwd with no
    /// matching Fwd) can surface as an unspecific index-out-of-bounds.
    /// Callers holding untrusted schedules should use the [`simulate`]
    /// wrapper, which always runs the full validator first.
    pub fn run(
        &mut self,
        e: &ExperimentConfig,
        schedule: &Schedule,
        layout: &Layout,
        opts: SimOptions,
    ) -> SimStats {
        let cm = CostModel::new(e);
        let mm = MemoryModel::new(e);
        let p = schedule.p as usize;
        let m = schedule.m as usize;
        let chunks = schedule.chunks.max(1) as usize;
        let zigzag = schedule.placement == Placement::ZigZag;

        // -- flatten: global node ids + dense compute index ---------------
        self.base.clear();
        self.base.push(0);
        self.ops.clear();
        self.stage_of.clear();
        for s in 0..p {
            for op in &schedule.programs[s].ops {
                self.ops.push(*op);
                self.stage_of.push(s as u32);
            }
            self.base.push(self.ops.len() as u32);
        }
        let n = self.ops.len();

        self.cix.clear();
        self.cix.resize(p * 2 * m * chunks, NONE);
        for id in 0..n {
            let op = self.ops[id];
            if matches!(op.kind, OpKind::Fwd | OpKind::Bwd) {
                let slot =
                    cix_slot(self.stage_of[id] as usize, op.kind, op.mb, op.chunk, m, chunks);
                self.cix[slot] = id as u32;
            }
        }

        // -- dependency edges: one walk in id order fills the CSR
        // directly (offsets ascend with the walk).  Evict/Load deps are
        // walk-local: a key may be evicted and reloaded repeatedly, so
        // each Load binds to the most recent Evict of its key and each
        // Bwd to the most recent Load (dense per-key scratch, reset per
        // stage).
        self.dep_off.clear();
        self.dep_edges.clear();
        self.bwd_load_dep.clear();
        self.bwd_load_dep.resize(n, NONE);
        let key_count = m * chunks;
        self.last_evict.clear();
        self.last_evict.resize(key_count, NONE);
        self.last_load.clear();
        self.last_load.resize(key_count, NONE);
        for s in 0..p {
            let mut prev_compute = NONE;
            self.last_evict.fill(NONE);
            self.last_load.fill(NONE);
            let lo = self.base[s] as usize;
            let hi = self.base[s + 1] as usize;
            for id in lo..hi {
                self.dep_off.push(self.dep_edges.len() as u32);
                let op = self.ops[id];
                let key = op.mb as usize * chunks + op.chunk as usize;
                match op.kind {
                    OpKind::Fwd => {
                        if prev_compute != NONE {
                            self.dep_edges.push(prev_compute);
                        }
                        if let Some(d) =
                            fwd_dep(&self.cix, p, m, chunks, zigzag, s, op.mb, op.chunk)
                        {
                            self.dep_edges.push(d);
                        }
                        prev_compute = id as u32;
                    }
                    OpKind::Bwd => {
                        if prev_compute != NONE {
                            self.dep_edges.push(prev_compute);
                        }
                        self.dep_edges.push(cix_get(
                            &self.cix,
                            s,
                            OpKind::Fwd,
                            op.mb,
                            op.chunk,
                            m,
                            chunks,
                        ));
                        if let Some(d) =
                            bwd_dep(&self.cix, p, m, chunks, zigzag, s, op.mb, op.chunk)
                        {
                            self.dep_edges.push(d);
                        }
                        if self.last_load[key] != NONE {
                            self.dep_edges.push(self.last_load[key]);
                            self.bwd_load_dep[id] = self.last_load[key];
                        }
                        prev_compute = id as u32;
                    }
                    OpKind::Evict | OpKind::Load => {
                        // issue point: the op preceding it in program order
                        if id > lo {
                            self.dep_edges.push(id as u32 - 1);
                        }
                        if op.kind == OpKind::Load {
                            let le = self.last_evict[key];
                            assert_ne!(
                                le, NONE,
                                "Load of a stash that was never evicted (invalid schedule)"
                            );
                            self.dep_edges.push(le);
                            self.last_load[key] = id as u32;
                        } else {
                            self.last_evict[key] = id as u32;
                            self.last_load[key] = NONE;
                        }
                        // link arbitration is time-based (FCFS per link)
                        // in the event loop below, not a static
                        // dependency — static chaining of a *shared*
                        // uplink across stages can create artificial
                        // cycles.
                    }
                }
            }
        }
        self.dep_off.push(self.dep_edges.len() as u32);

        // -- reverse CSR: counts → prefix sum → counting-sort fill --------
        self.indeg.clear();
        self.indeg.resize(n, 0);
        self.rev_off.clear();
        self.rev_off.resize(n + 1, 0);
        for &d in &self.dep_edges {
            self.rev_off[d as usize + 1] += 1;
        }
        for i in 0..n {
            self.rev_off[i + 1] += self.rev_off[i];
        }
        self.rev_cursor.clear();
        self.rev_cursor.extend_from_slice(&self.rev_off[..n]);
        self.rev_edges.clear();
        self.rev_edges.resize(self.dep_edges.len(), 0);
        for id in 0..n {
            self.indeg[id] = self.dep_off[id + 1] - self.dep_off[id];
            for ei in self.dep_off[id] as usize..self.dep_off[id + 1] as usize {
                let d = self.dep_edges[ei] as usize;
                let c = self.rev_cursor[d] as usize;
                self.rev_edges[c] = id as u32;
                self.rev_cursor[d] = c as u32 + 1;
            }
        }

        // -- per-node durations -------------------------------------------
        // interleaved/V chunks split a stage's layers `chunks` ways
        self.stage_times.clear();
        for s in 0..p {
            self.stage_times.push(cm.stage_times(s as u64));
        }
        let chunk_scale = 1.0 / chunks as f64;
        let t_intra = cm.transfer_time_chunked(true, chunks as u64);
        let t_inter = cm.transfer_time_chunked(false, chunks as u64);
        let n_nodes = layout.n_nodes as usize;
        self.intra.clear();
        self.link_of.clear();
        for s in 0..p {
            let intra = layout.pair_intra_node(p as u64, s as u64);
            self.intra.push(intra);
            self.link_of.push(if intra {
                s.min(p - 1 - s) as u32
            } else {
                (p + layout.node_of(s as u64) as usize) as u32
            });
        }
        self.dur.clear();
        for id in 0..n {
            let s = self.stage_of[id] as usize;
            self.dur.push(match self.ops[id].kind {
                OpKind::Fwd => self.stage_times[s].fwd * chunk_scale,
                OpKind::Bwd => self.stage_times[s].bwd * chunk_scale,
                // recompute hybrid: Evict discards (free), Load
                // re-materializes at the stage's own forward cost
                OpKind::Evict if opts.recompute => 0.0,
                OpKind::Load if opts.recompute => self.stage_times[s].fwd * chunk_scale,
                OpKind::Evict | OpKind::Load => {
                    if self.intra[s] {
                        t_intra
                    } else {
                        t_inter
                    }
                }
            });
        }

        // -- warm-start delta replay (module docs § "Warm-start delta
        // replay"): copy the timeline of every common-prefix node that
        // starts before the divergence horizon, then let the event loop
        // simulate only the remainder.  Soundness needs strictly
        // positive durations (heap pop order == (ready, id) order);
        // degenerate configs fall back to a cold run.
        self.start.clear();
        self.start.resize(n, 0.0);
        self.end.clear();
        self.end.resize(n, 0.0);
        self.copied.clear();
        self.copied.resize(n, false);
        let positive_durs = self.dur.iter().all(|&d| d > 0.0);
        let mut replayed = 0usize;
        if opts.warm
            && self.snap_valid
            && positive_durs
            && self.snap_p == p
            && self.snap_m == m
            && self.snap_chunks == chunks
            && self.snap_zigzag == zigzag
            && self.snap_link_of == self.link_of
        {
            // per-stage common prefix: slots equal in op AND duration
            // (duration equality subsumes cost-model differences)
            self.prefix.clear();
            let mut horizon = f64::INFINITY;
            for s in 0..p {
                let lo = self.base[s] as usize;
                let slo = self.snap_base[s] as usize;
                let new_len = self.base[s + 1] as usize - lo;
                let old_len = self.snap_base[s + 1] as usize - slo;
                let mut k = 0usize;
                while k < new_len.min(old_len)
                    && self.ops[lo + k] == self.snap_ops[slo + k]
                    && self.dur[lo + k] == self.snap_dur[slo + k]
                {
                    k += 1;
                }
                self.prefix.push(k as u32);
                if k < new_len || k < old_len {
                    // a divergent op's ready time is bounded below by
                    // the end of the last common compute op beneath it
                    let mut h = 0f64;
                    for j in (0..k).rev() {
                        if matches!(self.snap_ops[slo + j].kind, OpKind::Fwd | OpKind::Bwd) {
                            h = self.snap_end[slo + j];
                            break;
                        }
                    }
                    horizon = horizon.min(h);
                }
            }
            for s in 0..p {
                let lo = self.base[s] as usize;
                let slo = self.snap_base[s] as usize;
                for k in 0..self.prefix[s] as usize {
                    if self.snap_start[slo + k] < horizon {
                        self.copied[lo + k] = true;
                        self.start[lo + k] = self.snap_start[slo + k];
                        self.end[lo + k] = self.snap_end[slo + k];
                        replayed += 1;
                    }
                }
            }
        }
        self.events_total += n as u64;
        self.events_replayed += replayed as u64;

        // -- event-driven timing with FCFS link arbitration ---------------
        // Ops become READY when all logical deps complete; compute ops
        // start at their ready time (program-order deps already serialize
        // the stage's compute stream); transfer ops additionally queue
        // FCFS on their link.  Events are processed in ready-time order,
        // which makes the link free-time bookkeeping causally consistent.
        self.link_free.clear();
        self.link_free.resize(p + n_nodes, 0.0);
        self.heap.clear();
        let mut load_stall = 0f64;
        if replayed == 0 {
            for id in 0..n {
                if self.indeg[id] == 0 {
                    self.heap.push(Ev(0.0, id as u32));
                }
            }
        } else {
            // resume mid-timeline: per-link free times are the max end
            // over the replayed grants (FCFS grant order restricted to a
            // link is a prefix of pop order, so nothing is missing)
            for id in 0..n {
                if self.copied[id] && matches!(self.ops[id].kind, OpKind::Evict | OpKind::Load) {
                    let l = self.link_of[self.stage_of[id] as usize] as usize;
                    self.link_free[l] = self.link_free[l].max(self.end[id]);
                }
            }
            // replayed Bwd load-stall contributions, re-accumulated in
            // (start, id) order == the cold run's heap pop order (Bwd
            // start equals ready, and every copied Bwd pops before every
            // non-copied one), so the f64 sum is bit-identical
            self.order.clear();
            for id in 0..n {
                if self.copied[id]
                    && self.ops[id].kind == OpKind::Bwd
                    && self.bwd_load_dep[id] != NONE
                {
                    self.order.push(id as u32);
                }
            }
            let start = &self.start;
            self.order.sort_unstable_by(|&a, &b| {
                start[a as usize].total_cmp(&start[b as usize]).then(a.cmp(&b))
            });
            for &idu in &self.order {
                let id = idu as usize;
                let load = self.bwd_load_dep[id];
                let mut without = 0f64;
                for ei in self.dep_off[id] as usize..self.dep_off[id + 1] as usize {
                    let d = self.dep_edges[ei];
                    if d != load {
                        without = without.max(self.end[d as usize]);
                    }
                }
                load_stall += (self.end[load as usize] - without).max(0.0);
            }
            // non-copied nodes wait only on their non-copied deps; the
            // copied ones already contribute through the ready max
            for id in 0..n {
                if self.copied[id] {
                    continue;
                }
                let mut live = 0u32;
                let mut r = 0f64;
                for ei in self.dep_off[id] as usize..self.dep_off[id + 1] as usize {
                    let d = self.dep_edges[ei] as usize;
                    if self.copied[d] {
                        r = r.max(self.end[d]);
                    } else {
                        live += 1;
                    }
                }
                self.indeg[id] = live;
                if live == 0 {
                    self.heap.push(Ev(r, id as u32));
                }
            }
        }
        let mut done = 0usize;
        while let Some(Ev(ready, idu)) = self.heap.pop() {
            done += 1;
            let id = idu as usize;
            let kind = self.ops[id].kind;
            let t0 = match kind {
                // recompute ops run on the stage's own compute stream
                // (program-order deps serialize them), never on a link
                OpKind::Evict | OpKind::Load if !opts.recompute => {
                    let l = self.link_of[self.stage_of[id] as usize] as usize;
                    let s0 = ready.max(self.link_free[l]);
                    self.link_free[l] = s0 + self.dur[id];
                    s0
                }
                _ => ready,
            };
            self.start[id] = t0;
            self.end[id] = t0 + self.dur[id];
            if kind == OpKind::Bwd && self.bwd_load_dep[id] != NONE {
                let load = self.bwd_load_dep[id];
                let mut without = 0f64;
                for ei in self.dep_off[id] as usize..self.dep_off[id + 1] as usize {
                    let d = self.dep_edges[ei];
                    if d != load {
                        without = without.max(self.end[d as usize]);
                    }
                }
                load_stall += (self.end[load as usize] - without).max(0.0);
            }
            for ei in self.rev_off[id] as usize..self.rev_off[id + 1] as usize {
                let nxt = self.rev_edges[ei] as usize;
                self.indeg[nxt] -= 1;
                if self.indeg[nxt] == 0 {
                    let mut r = 0f64;
                    for dj in self.dep_off[nxt] as usize..self.dep_off[nxt + 1] as usize {
                        r = r.max(self.end[self.dep_edges[dj] as usize]);
                    }
                    self.heap.push(Ev(r, nxt as u32));
                }
            }
        }
        assert_eq!(done, n - replayed, "dependency cycle in schedule DAG");

        // -- snapshot for the next warm run -------------------------------
        if opts.warm && positive_durs {
            self.snap_valid = true;
            self.snap_p = p;
            self.snap_m = m;
            self.snap_chunks = chunks;
            self.snap_zigzag = zigzag;
            self.snap_base.clear();
            self.snap_base.extend_from_slice(&self.base);
            self.snap_ops.clear();
            self.snap_ops.extend_from_slice(&self.ops);
            self.snap_link_of.clear();
            self.snap_link_of.extend_from_slice(&self.link_of);
            self.snap_dur.clear();
            self.snap_dur.extend_from_slice(&self.dur);
            self.snap_start.clear();
            self.snap_start.extend_from_slice(&self.start);
            self.snap_end.clear();
            self.snap_end.extend_from_slice(&self.end);
        }

        // -- aggregate -----------------------------------------------------
        let mut makespan = 0f64;
        for &t in &self.end {
            makespan = makespan.max(t);
        }
        self.busy.clear();
        self.busy.resize(p, 0.0);
        for id in 0..n {
            if matches!(self.ops[id].kind, OpKind::Fwd | OpKind::Bwd) {
                self.busy[self.stage_of[id] as usize] += self.end[id] - self.start[id];
            }
        }

        self.trace.clear();
        if opts.trace {
            // stable-by-start order without a stable sort's scratch
            // allocation: ids ascend initially, so (start, id) reproduces
            // the program-order tie-break exactly
            self.order.clear();
            self.order.extend(0..n as u32);
            let start = &self.start;
            self.order.sort_unstable_by(|&a, &b| {
                start[a as usize].total_cmp(&start[b as usize]).then(a.cmp(&b))
            });
            for &idu in &self.order {
                let id = idu as usize;
                let op = self.ops[id];
                self.trace.push(TraceEvent {
                    stage: self.stage_of[id] as u64,
                    kind: op.kind,
                    mb: op.mb,
                    chunk: op.chunk,
                    start: self.start[id],
                    end: self.end[id],
                });
            }
        }

        // -- memory timeline ----------------------------------------------
        // a stash of a chunked schedule holds only 1/chunks of the
        // stage's layers, so stash (and transfer) bytes scale by the
        // chunk count
        let act = mm.activation_bytes_per_microbatch(0) / chunks as u64;
        self.events.clear();
        for id in 0..n {
            let s = self.stage_of[id];
            let partner = pairing::partner(p as u64, s as u64) as u32;
            match self.ops[id].kind {
                OpKind::Fwd => self.events.push(MemEvent { t: self.end[id], stage: s, delta: 1 }),
                OpKind::Bwd => self.events.push(MemEvent { t: self.end[id], stage: s, delta: -1 }),
                OpKind::Evict => {
                    // freed locally only once the transfer lands; acceptor
                    // allocates at transfer start (conservative overlap).
                    // Recompute mode discards instead: no partner side.
                    self.events.push(MemEvent { t: self.end[id], stage: s, delta: -1 });
                    if !opts.recompute {
                        self.events.push(MemEvent { t: self.start[id], stage: partner, delta: 1 });
                    }
                }
                OpKind::Load => {
                    self.events.push(MemEvent { t: self.start[id], stage: s, delta: 1 });
                    if !opts.recompute {
                        self.events.push(MemEvent { t: self.end[id], stage: partner, delta: -1 });
                    }
                }
            }
        }
        // allocations apply before frees at equal timestamps, so a load
        // starting exactly when a backward retires (or an evict lands)
        // counts both stashes resident — conservative peak accounting
        self.events.sort_unstable_by(|a, b| a.t.total_cmp(&b.t).then(b.delta.cmp(&a.delta)));
        self.cur.clear();
        self.cur.resize(p, 0);
        self.stash_hw.clear();
        self.stash_hw.resize(p, 0);
        for ev in &self.events {
            let s = ev.stage as usize;
            self.cur[s] += ev.delta as i64;
            self.stash_hw[s] = self.stash_hw[s].max(self.cur[s]);
        }
        self.mem_hw.clear();
        for s in 0..p {
            self.mem_hw.push(
                mm.weight_opt_bytes(s as u64)
                    + e.cluster.reserved_bytes
                    + self.stash_hw[s] as u64 * act,
            );
        }

        let mut oom_stage = None;
        let mut peak_mem = 0u64;
        let mut peak_stash = 0i64;
        for s in 0..p {
            if oom_stage.is_none() && self.mem_hw[s] > e.cluster.hbm_bytes {
                oom_stage = Some(s as u64);
            }
            peak_mem = peak_mem.max(self.mem_hw[s]);
            peak_stash = peak_stash.max(self.stash_hw[s]);
        }

        let mut transfers = 0u64;
        for op in &self.ops {
            if matches!(op.kind, OpKind::Evict | OpKind::Load) {
                transfers += 1;
            }
        }

        let model_flops = flops::model_flops_per_iteration(&e.model, e.parallel.global_batch);
        let devices = e.parallel.devices() as f64;
        let mfu = model_flops / (devices * e.cluster.peak_flops * makespan);
        let mut mean_busy = 0f64;
        for &b in &self.busy {
            mean_busy += b;
        }
        let mean_busy = mean_busy / p as f64;

        SimStats {
            makespan,
            mfu,
            bubble_fraction: 1.0 - mean_busy / makespan,
            peak_mem_bytes: peak_mem,
            peak_stash,
            oom_stage,
            load_stall,
            // recompute mode moves nothing between stages
            transfer_bytes: if opts.recompute { 0 } else { transfers * act },
        }
    }
}

/// Simulate one iteration of `schedule` for experiment `e` on `layout`.
///
/// Convenience wrapper: validates, runs a throwaway [`SimWorkspace`]
/// with trace collection on, and materializes the full [`SimResult`].
/// Sweep-style callers that simulate many cells should hold a workspace
/// and call [`SimWorkspace::run`] instead.
pub fn simulate(e: &ExperimentConfig, schedule: &Schedule, layout: &Layout) -> SimResult {
    crate::schedule::validate(schedule).expect("refusing to simulate an invalid schedule");
    let mut ws = SimWorkspace::new();
    let stats = ws.run(e, schedule, layout, SimOptions::default());
    ws.to_result(&stats)
}

/// Build the schedule an experiment config implies (1F1B, +BPipe if
/// enabled) with the pair-adjacent layout, simulate one iteration.
pub fn simulate_experiment(e: &ExperimentConfig) -> SimResult {
    let m = e.parallel.num_microbatches();
    let base = crate::schedule::one_f_one_b(e.parallel.p, m);
    let schedule = if e.bpipe {
        crate::bpipe::apply_bpipe(&base, None)
    } else {
        base
    };
    let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
    simulate(e, &schedule, &layout)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bpipe::{derived_bound, rebalance};
    use crate::config::{paper_experiment, paper_experiments};
    use crate::schedule::{gpipe, interleaved, one_f_one_b, v_shaped};

    #[test]
    fn makespan_exceeds_critical_path_lower_bound() {
        let e = paper_experiment(7).unwrap();
        let r = simulate_experiment(&e);
        let cm = CostModel::new(&e);
        let st = cm.stage_times(1);
        let m = e.parallel.num_microbatches() as f64;
        // lower bound: one stage's serial work
        assert!(r.makespan >= m * st.total());
        // upper bound sanity: and not 3× it
        assert!(r.makespan < 3.0 * m * st.total());
    }

    #[test]
    fn mfu_in_sane_range_for_all_rows() {
        for e in paper_experiments() {
            let r = simulate_experiment(&e);
            assert!(
                r.mfu_pct() > 20.0 && r.mfu_pct() < 70.0,
                "exp {:?}: {:.1}%",
                e.id,
                r.mfu_pct()
            );
            assert!(r.oom_stage.is_none(), "exp {:?} must fit", e.id);
        }
    }

    #[test]
    fn gpipe_slower_than_1f1b_same_memory_model() {
        let e = paper_experiment(9).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let g = simulate(&e, &gpipe(e.parallel.p, m), &layout);
        let f = simulate(&e, &one_f_one_b(e.parallel.p, m), &layout);
        // same bubble (flush at the end either way) but GPipe peaks at m stashes
        assert!(g.mem_high_water[0] > f.mem_high_water[0]);
        assert!((g.makespan - f.makespan) / f.makespan < 0.05);
    }

    #[test]
    fn bpipe_reduces_stage0_memory() {
        let mut e = paper_experiment(8).unwrap();
        let r_bpipe = simulate_experiment(&e);
        e.bpipe = false;
        let r_plain = simulate_experiment(&e);
        assert!(r_bpipe.mem_high_water[0] < r_plain.mem_high_water[0]);
        // plain 1F1B at b=2 OOMs on GPT-3 96B (why exp (8) needs BPipe)
        assert_eq!(r_plain.oom_stage, Some(0));
        assert!(r_bpipe.oom_stage.is_none());
    }

    #[test]
    fn bpipe_overhead_small_when_intra_node() {
        // BPipe at the same b must cost only a little (overlapped xfers)
        let mut e = paper_experiment(7).unwrap(); // b=1, fits without
        e.bpipe = true;
        let with = simulate_experiment(&e);
        e.bpipe = false;
        let without = simulate_experiment(&e);
        let overhead = with.makespan / without.makespan - 1.0;
        assert!(
            (0.0..0.08).contains(&overhead),
            "BPipe overhead {overhead:.3} out of range"
        );
    }

    #[test]
    fn memory_high_water_matches_analytical_model() {
        let e = paper_experiment(7).unwrap();
        let r = simulate_experiment(&e);
        let mm = MemoryModel::new(&e);
        for s in 0..e.parallel.p {
            let analytic = mm.peak_bytes_1f1b(s);
            let simulated = r.mem_high_water[s as usize];
            assert_eq!(simulated, analytic, "stage {s}");
        }
    }

    #[test]
    fn trace_is_complete_and_ordered() {
        let e = paper_experiment(7).unwrap();
        let r = simulate_experiment(&e);
        let m = e.parallel.num_microbatches() as usize;
        assert_eq!(
            r.trace.iter().filter(|t| t.kind == OpKind::Fwd).count(),
            m * e.parallel.p as usize
        );
        for w in r.trace.windows(2) {
            assert!(w[0].start <= w[1].start);
        }
    }

    #[test]
    fn load_stall_zero_when_no_bpipe() {
        let e = paper_experiment(7).unwrap();
        let r = simulate_experiment(&e);
        assert_eq!(r.load_stall, 0.0);
        assert_eq!(r.transfer_bytes, 0);
    }

    #[test]
    fn interleaved_cuts_bubble() {
        let e = paper_experiment(9).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let plain = simulate(&e, &one_f_one_b(e.parallel.p, m), &layout);
        let il = simulate(&e, &crate::schedule::interleaved(e.parallel.p, m, 2), &layout);
        assert!(il.bubble_fraction < plain.bubble_fraction);
    }

    #[test]
    fn rebalanced_interleaved_flattens_memory() {
        // the tentpole end-to-end: rebalance(interleaved) simulates, and
        // the derived bound flattens the 23..9 stash ramp to a uniform
        // pair mean (16 per stage for p=8, m=64, v=2; +1 transient slot
        // from the conservative load/retire overlap accounting)
        let e = paper_experiment(8).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let il = interleaved(e.parallel.p, m, 2);
        let plain = simulate(&e, &il, &layout);
        let rb = rebalance(&il, None);
        let r = simulate(&e, &rb, &layout);
        let spread = |hw: &[i64]| hw.iter().max().unwrap() - hw.iter().min().unwrap();
        assert!(
            spread(&r.stash_high_water) < spread(&plain.stash_high_water),
            "{:?} vs {:?}",
            r.stash_high_water,
            plain.stash_high_water
        );
        let peak = |v: &[u64]| *v.iter().max().unwrap();
        assert!(peak(&r.mem_high_water) < peak(&plain.mem_high_water));
        // transfers hide under compute on the pair-adjacent layout
        assert!(r.makespan / plain.makespan < 1.05);
    }

    #[test]
    fn chunked_stash_bytes_scale_with_chunk_count() {
        // satellite fix: a v-chunk stash pins 1/v of a stage's layers —
        // the interleaved timeline must account act/v per stash
        let e = paper_experiment(9).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let r = simulate(&e, &interleaved(e.parallel.p, m, 2), &layout);
        let mm = MemoryModel::new(&e);
        let act = mm.activation_bytes_per_microbatch(0);
        for s in 0..e.parallel.p as usize {
            let stash_bytes =
                r.mem_high_water[s] - mm.weight_opt_bytes(s as u64) - e.cluster.reserved_bytes;
            assert_eq!(stash_bytes, r.stash_high_water[s] as u64 * (act / 2), "stage {s}");
        }
    }

    #[test]
    fn v_shaped_simulates_with_balanced_stashes() {
        let e = paper_experiment(8).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let r = simulate(&e, &v_shaped(e.parallel.p, m), &layout);
        assert!(r.makespan > 0.0 && r.mfu > 0.0);
        let spread = r.stash_high_water.iter().max().unwrap()
            - r.stash_high_water.iter().min().unwrap();
        assert!(spread <= 1, "V-shaped per-device stash {:?}", r.stash_high_water);
    }

    #[test]
    fn w_shaped_cuts_bubble_but_costs_memory() {
        // zig-zag at v = 4 (the W placement): shorter iteration than the
        // V (more chunks, smaller bubble), still balanced by placement,
        // but four live chunks per stage cost more stash memory
        let e = paper_experiment(8).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let w = simulate(&e, &crate::schedule::zigzag(e.parallel.p, m, 4), &layout);
        let v = simulate(&e, &v_shaped(e.parallel.p, m), &layout);
        assert!(w.makespan < v.makespan, "W {} vs V {}", w.makespan, v.makespan);
        let spread = w.stash_high_water.iter().max().unwrap()
            - w.stash_high_water.iter().min().unwrap();
        assert!(spread <= 1, "W per-device stash {:?}", w.stash_high_water);
        assert!(w.mem_high_water[3] > v.mem_high_water[3]);
    }

    #[test]
    fn per_stage_bounds_simulate_and_flatten() {
        // capacity-derived non-uniform bounds on exp (8)'s 1F1B: fits
        // (uniform 1F1B OOMs at stage 0) with less transfer traffic than
        // the uniform derived bound
        let e = paper_experiment(8).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let base = one_f_one_b(e.parallel.p, m);
        let bounds = crate::bpipe::capacity_stage_bounds(&e, &base);
        let per = simulate(&e, &crate::bpipe::rebalance_bounded(&base, &bounds), &layout);
        let uni = simulate(&e, &rebalance(&base, None), &layout);
        assert_eq!(per.oom_stage, None, "{:?}", per.mem_high_water);
        assert!(per.transfer_bytes < uni.transfer_bytes);
    }

    #[test]
    fn rebalance_composes_with_v_shaped_in_sim() {
        let e = paper_experiment(8).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let base = v_shaped(e.parallel.p, m);
        let bound = derived_bound(&base);
        let r = simulate(&e, &rebalance(&base, Some(bound)), &layout);
        assert!(r.makespan > 0.0, "rebalanced V-shaped must execute");
    }

    #[test]
    fn trace_collection_is_opt_in() {
        let e = paper_experiment(8).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let sched = one_f_one_b(e.parallel.p, m);
        let mut ws = SimWorkspace::new();
        let with = ws.run(&e, &sched, &layout, SimOptions { trace: true, warm: false, recompute: false });
        assert_eq!(ws.trace().len(), sched.num_ops());
        let without = ws.run(&e, &sched, &layout, SimOptions { trace: false, warm: false, recompute: false });
        assert!(ws.trace().is_empty(), "trace must be skipped when opted out");
        // ... with identical stats either way
        assert_eq!(with, without);
    }

    #[test]
    fn warm_runs_match_fresh_simulate_across_descending_bounds() {
        // the warm-start core claim at engine level: a warm workspace
        // fed one family at descending bounds replays a prefix of each
        // timeline yet stays bit-identical to a fresh cold engine —
        // start/end of every node (via the trace), the load-stall f64
        // accumulation, and the memory timeline all match exactly
        let e = paper_experiment(8).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let base = one_f_one_b(e.parallel.p, m);
        let mut ws = SimWorkspace::new();
        let opts = SimOptions { trace: true, warm: true, recompute: false };
        for bound in crate::bpipe::bound_range(&base).rev() {
            let sched = rebalance(&base, Some(bound));
            let stats = ws.run(&e, &sched, &layout, opts);
            let fresh = simulate(&e, &sched, &layout);
            assert_eq!(stats.makespan, fresh.makespan, "bound {bound}");
            assert_eq!(stats.load_stall, fresh.load_stall, "bound {bound}");
            assert_eq!(ws.trace(), &fresh.trace[..], "bound {bound}");
            assert_eq!(ws.mem_high_water(), &fresh.mem_high_water[..], "bound {bound}");
            assert_eq!(ws.stash_high_water(), &fresh.stash_high_water[..], "bound {bound}");
        }
        assert!(ws.events_replayed() > 0, "descending bounds must replay a prefix");
        assert!(ws.events_replayed() < ws.events_total());
    }

    #[test]
    fn warm_workspace_survives_shape_and_family_changes() {
        // incompatible snapshots (different placement, chunk count, op
        // streams) must fall back to a cold run, never corrupt results
        let e = paper_experiment(8).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let scheds = [
            one_f_one_b(e.parallel.p, m),
            rebalance(&interleaved(e.parallel.p, m, 2), None),
            gpipe(e.parallel.p, m),
            v_shaped(e.parallel.p, m),
            one_f_one_b(e.parallel.p, m),
        ];
        let mut ws = SimWorkspace::new();
        for sched in &scheds {
            let stats = ws.run(&e, sched, &layout, SimOptions { trace: true, warm: true, recompute: false });
            let fresh = simulate(&e, sched, &layout);
            assert_eq!(stats.makespan, fresh.makespan);
            assert_eq!(stats.load_stall, fresh.load_stall);
            assert_eq!(ws.trace(), &fresh.trace[..]);
        }
    }

    #[test]
    fn recompute_mode_drops_transfers_and_partner_memory() {
        // hybrid memory model: a rebalanced schedule under --recompute
        // moves zero bytes, charges the acceptor stage no stash memory,
        // and pays for the re-materialization in time instead — so its
        // makespan differs from the stash/transfer execution of the very
        // same schedule, while an unrebalanced schedule (no Evict/Load)
        // is identical under both modes
        let e = paper_experiment(8).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let base = one_f_one_b(e.parallel.p, m);
        let sched = rebalance(&base, Some(derived_bound(&base)));
        let mut ws = SimWorkspace::new();
        let stash =
            ws.run(&e, &sched, &layout, SimOptions { trace: false, warm: false, recompute: false });
        let stash_peak = ws.stash_high_water().to_vec();
        let stash_mem = ws.mem_high_water().to_vec();
        let rec =
            ws.run(&e, &sched, &layout, SimOptions { trace: false, warm: false, recompute: true });
        let rec_peak = ws.stash_high_water().to_vec();
        let rec_mem = ws.mem_high_water().to_vec();
        assert!(stash.transfer_bytes > 0, "rebalanced schedule must transfer in stash mode");
        assert_eq!(rec.transfer_bytes, 0, "recompute mode must not touch the links");
        assert!(rec.makespan > 0.0 && rec.makespan.is_finite());
        // evictor-local events are identical in both modes, but acceptors
        // get no partner allocations under recompute — so every stage's
        // resident peak (and hence device high-water) is bounded by the
        // stash-mode run's
        for s in 0..rec_peak.len() {
            assert!(
                rec_peak[s] <= stash_peak[s] && rec_mem[s] <= stash_mem[s],
                "stage {s}: recompute peak {}/{} vs stash {}/{}",
                rec_peak[s], rec_mem[s], stash_peak[s], stash_mem[s]
            );
        }
        // a schedule without Evict/Load ops is mode-insensitive
        let plain =
            ws.run(&e, &base, &layout, SimOptions { trace: false, warm: false, recompute: true });
        let plain_cold =
            ws.run(&e, &base, &layout, SimOptions { trace: false, warm: false, recompute: false });
        assert_eq!(plain, plain_cold, "no Evict/Load: modes must agree exactly");
    }

    #[test]
    fn workspace_reuse_matches_fresh_simulate() {
        // one workspace across schedules of very different shapes must
        // produce the same numbers as a fresh engine every time
        let e = paper_experiment(8).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let scheds = [
            one_f_one_b(e.parallel.p, m),
            rebalance(&interleaved(e.parallel.p, m, 2), None),
            gpipe(e.parallel.p, m),
            v_shaped(e.parallel.p, m),
            one_f_one_b(e.parallel.p, m),
        ];
        let mut ws = SimWorkspace::new();
        for sched in &scheds {
            let stats = ws.run(&e, sched, &layout, SimOptions { trace: true, warm: false, recompute: false });
            let fresh = simulate(&e, sched, &layout);
            assert_eq!(stats.makespan, fresh.makespan);
            assert_eq!(stats.load_stall, fresh.load_stall);
            assert_eq!(ws.mem_high_water(), &fresh.mem_high_water[..]);
            assert_eq!(ws.stash_high_water(), &fresh.stash_high_water[..]);
            assert_eq!(ws.trace(), &fresh.trace[..]);
            assert_eq!(ws.busy(), &fresh.busy[..]);
        }
    }
}
